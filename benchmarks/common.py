"""Shared benchmark utilities: agent training/caching, CSV emission."""
from __future__ import annotations

import os
import sys
import time
from typing import Dict, List, Optional

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

from repro.config.base import ServingConfig  # noqa: E402
from repro.core.baselines import (DDQNAgent, EDFScheduler,  # noqa: E402
                                  FixedScheduler, GAScheduler, PPOAgent,
                                  TACAgent)
from repro.core.interference import NNInterferencePredictor  # noqa: E402
from repro.core.sac import SACAgent, SACConfig  # noqa: E402
from repro.serving.bcedge import run_episode  # noqa: E402
from repro.serving.features import queue_feature_index, state_dim  # noqa: E402
from repro.serving.simulator import EdgeServingEnv  # noqa: E402
from repro.configs.paper_edge_models import EDGE_MODELS  # noqa: E402

MODELS = list(EDGE_MODELS.keys())
STATE_DIM = state_dim(MODELS)

#: smoke mode (``benchmarks/run.py --smoke``, CI): every figure runs its
#: full code path at toy scale — minutes for the whole suite — so
#: benchmark scripts cannot silently rot. Numbers are NOT meaningful.
SMOKE = os.environ.get("BENCH_SMOKE", "0") == "1"
FAST = SMOKE or os.environ.get("BENCH_FAST", "1") != "0"
EP_MS = 2_000.0 if SMOKE else (20_000.0 if FAST else 60_000.0)
TRAIN_EPS = 2 if SMOKE else (16 if FAST else 36)

#: trained-agent cache — figures sharing a (kind, platform, rps, guard)
#: configuration reuse one training run (the paper trains once offline
#: and deploys, §V-A)
_AGENT_CACHE = {}


def make_agent(kind: str, cfg: ServingConfig, seed: int = 0):
    n = cfg.n_actions
    if kind == "sac":
        return SACAgent(STATE_DIM, n, SACConfig(batch_size=256, lr=5e-4),
                        seed=seed)
    if kind == "tac":
        return TACAgent(STATE_DIM, n, batch_size=256, seed=seed)
    if kind == "ppo":
        return PPOAgent(STATE_DIM, n, seed=seed)
    if kind == "ddqn":
        return DDQNAgent(STATE_DIM, n, batch_size=256, seed=seed)
    if kind == "ga":
        return GAScheduler(STATE_DIM, n, seed=seed)
    if kind == "edf":
        return EDFScheduler(cfg.batch_sizes, cfg.concurrency_levels,
                            queue_feature_index(MODELS),
                            n_models=len(MODELS),
                            arrival_rps=cfg.arrival_rps,
                            platform=cfg.platform)
    if kind == "fixed":
        return FixedScheduler(cfg.pair_to_action(2, 2))
    raise KeyError(kind)


class GreedyWrapper:
    """Frozen greedy policy view of a trained agent."""

    def __init__(self, agent):
        self.agent = agent
        self.name = agent.name

    def act(self, s, greedy=False):
        return self.agent.act(s, greedy=True)

    def observe(self, *a):
        pass

    def update(self):
        return {}


def train_agent(kind: str, cfg: ServingConfig, episodes: int = TRAIN_EPS,
                seed: int = 0, guard: bool = True,
                predictor: Optional[NNInterferencePredictor] = None,
                cache: bool = True):
    """Online training over ``episodes`` episodes; returns
    (agent, predictor, history). Non-learning schedulers skip training
    (one probe episode for the history). Results are cached by
    (kind, platform, rps, guard, episodes, seed).
    """
    key = (kind, cfg.platform, cfg.arrival_rps, guard, episodes, seed)
    if cache and key in _AGENT_CACHE:
        return _AGENT_CACHE[key]
    agent = make_agent(kind, cfg, seed)
    history: List[Dict] = []
    pred = predictor
    if pred is None and guard:
        pred = NNInterferencePredictor(seed=seed)
    n_eps = episodes if getattr(agent, "learns", False) else 1
    for ep in range(n_eps):
        env = EdgeServingEnv(cfg, episode_ms=EP_MS, seed=seed * 100 + ep)
        res = run_episode(env, agent, pred, guard=guard,
                          learn=getattr(agent, "learns", False))
        row = dict(res.summary)
        row["episode"] = ep
        row["mean_loss"] = float(np.mean(res.losses)) if res.losses else 0.0
        row["per_model_throughput"] = dict(res.per_model_throughput)
        row["per_model_latency"] = dict(res.per_model_latency)
        history.append(row)
    out = (agent, pred, history)
    if cache:
        _AGENT_CACHE[key] = out
    return out


def eval_agent(agent, cfg: ServingConfig, predictor=None, guard=True,
               seed: int = 999, episode_ms: float = EP_MS,
               n_seeds: int = 3):
    """Greedy evaluation averaged over ``n_seeds`` episodes (single-episode
    serving metrics are high-variance). Returns (last_env, result) where
    result.summary holds seed-averaged metrics and the per-model maps come
    from the pooled episodes."""
    envs, results = [], []
    for i in range(n_seeds):
        env = EdgeServingEnv(cfg, episode_ms=episode_ms, seed=seed + i)
        res = run_episode(env, GreedyWrapper(agent), predictor, guard=guard,
                          learn=False)
        envs.append(env)
        results.append(res)
    keys = set().union(*(r.summary.keys() for r in results))
    avg = {k: float(np.mean([r.summary.get(k, 0.0) for r in results]))
           for k in keys}
    pooled_u, pooled_thr, pooled_lat = {}, {}, {}
    dur_s = n_seeds * episode_ms / 1000.0
    for r in results:
        for m, v in r.per_model_utility.items():
            pooled_u.setdefault(m, []).append(v)
        for m, v in r.per_model_throughput.items():
            pooled_thr[m] = pooled_thr.get(m, 0.0) + v / n_seeds
        for m, v in r.per_model_latency.items():
            pooled_lat.setdefault(m, []).append(v)
    out = results[-1]
    out.summary = avg
    out.per_model_utility = {m: float(np.mean(v))
                             for m, v in pooled_u.items()}
    out.per_model_throughput = pooled_thr
    out.per_model_latency = {m: float(np.mean(v))
                             for m, v in pooled_lat.items()}
    return envs[-1], out


def emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.2f},{derived}")


def timed(fn, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) * 1e6
