"""Paper Fig. 10: convergence of SAC (ours) vs PPO / DDQN / GA.

Paper claim: max-entropy SAC converges 1.8x–3.7x faster. We measure
episodes-to-threshold on the episode-mean utility curve.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import SMOKE, emit, train_agent
from repro.config.base import ServingConfig


def _episodes_to_reach(curve, frac=0.85):
    curve = np.asarray(curve, np.float64)
    if len(curve) == 0:
        return len(curve)
    lo = curve[0]
    hi = np.max(curve)
    if hi <= lo:
        return len(curve)
    thresh = lo + frac * (hi - lo)
    # smoothed first crossing
    smooth = np.convolve(curve, np.ones(2) / 2, mode="same")
    for i, v in enumerate(smooth):
        if v >= thresh:
            return i + 1
    return len(curve)


def main(fast: bool = True) -> dict:
    cfg = ServingConfig()
    eps = 2 if SMOKE else (8 if fast else 24)
    curves, losses = {}, {}
    for kind in ("sac", "ppo", "ddqn", "ga"):
        _, _, hist = train_agent(kind, cfg, episodes=eps,
                                 guard=(kind == "sac"), cache=False)
        curves[kind] = [h.get("mean_utility", 0.0) for h in hist]
        losses[kind] = [h.get("mean_loss", 0.0) for h in hist]
        emit(f"fig10.curve.{kind}", 0.0,
             "utility=[" + " ".join(f"{u:.2f}" for u in curves[kind]) + "]")
    conv = {k: _episodes_to_reach(v) for k, v in curves.items()}
    speedups = {k: conv[k] / max(conv["sac"], 1)
                for k in ("ppo", "ddqn", "ga")}
    emit("fig10.summary", 0.0,
         " ".join(f"{k}_episodes={v}" for k, v in conv.items()) + " " +
         " ".join(f"speedup_vs_{k}={v:.1f}x" for k, v in speedups.items())
         + " (paper: 1.8x-3.7x)")
    return {"conv": conv, "curves": curves}


if __name__ == "__main__":
    main()
