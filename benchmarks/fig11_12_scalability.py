"""Paper Figs. 11/12: scalability across heterogeneous edge platforms
(Jetson Nano / TX2 / Xavier NX) — utility, peak throughput, mean latency
for BCEdge vs TAC vs DeepRT. Paper: BCEdge wins on all three platforms;
more compute => higher utility (+30%/+19% on Nano, +39%/+27% on TX2)."""
from __future__ import annotations

import dataclasses

from benchmarks.common import emit, eval_agent, train_agent
from repro.config.base import ServingConfig

PLATFORMS = ("jetson_nano", "jetson_tx2", "xavier_nx")
# the three models the paper uses for the scalability study
SCal_MODELS = ("yolo", "res", "bert")


def main(fast: bool = True) -> dict:
    out = {}
    for platform in PLATFORMS:
        cfg = ServingConfig(platform=platform)
        row = {}
        for kind, guard in (("sac", True), ("tac", False), ("edf", False)):
            agent, pred, _ = train_agent(kind, cfg, guard=guard)
            env, res = eval_agent(agent, cfg, pred, guard=guard)
            s = res.summary
            row[kind] = s
            emit(f"fig11_12.{platform}.{kind}", 0.0,
                 f"util={s.get('mean_utility', 0):.2f} "
                 f"thr={s.get('throughput_rps', 0):.1f}rps "
                 f"lat={s.get('mean_latency_ms', 0):.0f}ms "
                 f"viol={s.get('slo_violation_rate', 0):.3f}")
        out[platform] = row
        sac_u = row["sac"].get("mean_utility", 0)
        edf_u = row["edf"].get("mean_utility", 1e-9)
        tac_u = row["tac"].get("mean_utility", 1e-9)
        emit(f"fig11_12.{platform}.summary", 0.0,
             f"gain_vs_deeprt={100*(sac_u-edf_u)/abs(edf_u):.0f}% "
             f"gain_vs_tac={100*(sac_u-tac_u)/abs(tac_u):.0f}%")
    # ordering check: richer platform => higher BCEdge utility
    order = [out[p]["sac"].get("mean_utility", 0) for p in PLATFORMS]
    emit("fig11_12.ordering", 0.0,
         f"nano={order[0]:.2f} tx2={order[1]:.2f} nx={order[2]:.2f} "
         f"monotone={order[0] <= order[1] <= order[2]}")
    return out


if __name__ == "__main__":
    main()
