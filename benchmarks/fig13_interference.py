"""Paper Fig. 13: interference-predictor error CDF — NN vs linear
regression. Paper: NN predicts 90% of cases within 2.69% error and 95%
within 3.25%, about half the linear model's error."""
from __future__ import annotations

import numpy as np

from benchmarks.common import SMOKE, emit
from repro.config.base import ServingConfig
from repro.core.interference import (LinearInterferencePredictor,
                                     NNInterferencePredictor)
from repro.serving.bcedge import collect_interference_dataset


def main(fast: bool = True) -> dict:
    cfg = ServingConfig()
    # paper protocol: 2000 samples, 1600 train / 400 validation
    n = 200 if SMOKE else 2000
    X, y = collect_interference_dataset(cfg, n=n, seed=3)
    # paper protocol: 1600 train / 400 validation (80/20)
    n_train = int(0.8 * len(X))
    idx = np.random.default_rng(0).permutation(len(X))
    tr, va = idx[:n_train], idx[n_train:]

    out = {}
    for predictor in (NNInterferencePredictor(lr=3e-3),
                      LinearInterferencePredictor()):
        predictor.fit(X[tr], y[tr],
                      epochs=300 if SMOKE else (4000 if fast else 8000))
        preds = np.array([predictor.predict(x) for x in X[va]])
        rel_err = np.abs(preds - y[va]) / np.maximum(np.abs(y[va]), 1e-9)
        p90 = float(np.percentile(rel_err, 90) * 100)
        p95 = float(np.percentile(rel_err, 95) * 100)
        med = float(np.percentile(rel_err, 50) * 100)
        out[predictor.name] = (med, p90, p95)
        emit(f"fig13.{predictor.name}", 0.0,
             f"median_err={med:.2f}% p90_err={p90:.2f}% p95_err={p95:.2f}%")
    ratio = out["linear"][1] / max(out["nn"][1], 1e-9)
    emit("fig13.summary", 0.0,
         f"nn_p90={out['nn'][1]:.2f}% linear_p90={out['linear'][1]:.2f}% "
         f"linear/nn={ratio:.2f}x (paper: ~2x, nn p90<=2.69%)")
    return out


if __name__ == "__main__":
    main()
