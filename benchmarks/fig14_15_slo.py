"""Paper Figs. 14/15: SLO violation rates.

Fig. 14: BCEdge with vs without the interference predictor at 30 rps
(paper: 9.2% -> 4.1%). Fig. 15: violation rate vs request rate for
BCEdge / TAC / DeepRT (paper: BCEdge lowest everywhere, <=5% at 40 rps,
53%/25% lower than DeepRT/TAC on average)."""
from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks.common import emit, eval_agent, train_agent
from repro.config.base import ServingConfig


def main(fast: bool = True) -> dict:
    out = {}

    # ---- Fig. 14: predictor ablation at 30 rps -------------------------
    cfg = ServingConfig(arrival_rps=30.0)
    for label, guard in (("with_predictor", True), ("without", False)):
        agent, pred, _ = train_agent("sac", cfg, guard=guard)
        env, res = eval_agent(agent, cfg, pred, guard=guard)
        v = res.summary.get("slo_violation_rate", 1.0)
        out[f"fig14.{label}"] = v
        emit(f"fig14.{label}", 0.0, f"violation_rate={v:.3f}")
    emit("fig14.summary", 0.0,
         f"with={out['fig14.with_predictor']:.3f} "
         f"without={out['fig14.without']:.3f} "
         f"improved={out['fig14.with_predictor'] < out['fig14.without']} "
         "(paper: 9.2%->4.1%)")

    # ---- Fig. 15: violation vs rps --------------------------------------
    rates = (10, 20, 30, 40) if not fast else (10, 30, 40)
    rows = {}
    for kind, guard in (("sac", True), ("tac", False), ("edf", False)):
        rows[kind] = []
        for rps in rates:
            cfg_r = ServingConfig(arrival_rps=float(rps))
            agent, pred, _ = train_agent(kind, cfg_r,
                                         episodes=10 if fast else 24,
                                         guard=guard)
            env, res = eval_agent(agent, cfg_r, pred, guard=guard)
            rows[kind].append(res.summary.get("slo_violation_rate", 1.0))
        emit(f"fig15.{kind}", 0.0,
             " ".join(f"rps{r}={v:.3f}" for r, v in zip(rates, rows[kind])))
    sac_avg = np.mean(rows["sac"])
    emit("fig15.summary", 0.0,
         f"bcedge_avg={sac_avg:.3f} tac_avg={np.mean(rows['tac']):.3f} "
         f"deeprt_avg={np.mean(rows['edf']):.3f} "
         f"bcedge_lowest={all(np.mean(rows['sac']) <= np.mean(rows[k]) for k in ('tac', 'edf'))}")
    out["fig15"] = rows
    return out


if __name__ == "__main__":
    main()
