"""Paper Fig. 16: scheduling overhead (decision latency) per scheduler.

Paper: BCEdge's average overhead is 26%/43% lower than DeepRT/TAC. We
measure wall-clock act()+update() per decision. (Absolute numbers are
CPU-container specific; the comparison across schedulers is the artifact.)
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, make_agent, train_agent
from repro.config.base import ServingConfig
from repro.serving.simulator import EdgeServingEnv


def main(fast: bool = True) -> dict:
    cfg = ServingConfig()
    out = {}
    for kind in ("sac", "tac", "edf"):
        agent, pred, _ = train_agent(kind, cfg,
                                     guard=(kind == "sac"))
        env = EdgeServingEnv(cfg, episode_ms=10_000.0, seed=5)
        s = env.reset()
        times = []
        done, steps = False, 0
        while not done and steps < 400:
            # deployment-path overhead: the paper trains offline and
            # deploys the policy, so the per-decision cost is act() only
            t0 = time.perf_counter()
            a = agent.act(s, greedy=True)
            times.append((time.perf_counter() - t0) * 1e3)
            s, _, done, _ = env.step(a)
            steps += 1
        # drop jit-warmup outliers
        arr = np.sort(np.asarray(times))[: max(1, int(0.95 * len(times)))]
        mean_ms = float(np.mean(arr))
        out[kind] = mean_ms
        emit(f"fig16.{kind}", mean_ms * 1e3, f"decision_ms={mean_ms:.3f}")
    emit("fig16.summary", 0.0,
         f"bcedge={out['sac']:.3f}ms tac={out['tac']:.3f}ms "
         f"deeprt={out['edf']:.3f}ms")
    return out


if __name__ == "__main__":
    main()
