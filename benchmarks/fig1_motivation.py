"""Paper Fig. 1: throughput/latency surface over (batch, concurrency).

Reproduces the motivational observation: both knobs matter, moderate
settings win, extremes collapse (memory overflow region included).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timed
from repro.config.base import ServingConfig
from repro.core.baselines import FixedScheduler
from repro.serving.bcedge import run_episode
from repro.serving.simulator import EdgeServingEnv

GRID_B = (1, 4, 16, 64, 128)
GRID_M = (1, 2, 4, 8)


def main(fast: bool = True) -> dict:
    cfg = ServingConfig()
    ep_ms = 10_000.0 if fast else 30_000.0
    surface = {}
    best, worst = None, None
    for b in GRID_B:
        for mc in GRID_M:
            env = EdgeServingEnv(cfg, episode_ms=ep_ms, seed=1)
            agent = FixedScheduler(cfg.pair_to_action(b, mc))
            res, us = timed(run_episode, env, agent, learn=False)
            s = res.summary
            surface[(b, mc)] = s
            emit(f"fig1.b{b}.mc{mc}", us,
                 f"thr={s['throughput_rps']:.1f}rps "
                 f"lat={s['mean_latency_ms']:.1f}ms "
                 f"viol={s['slo_violation_rate']:.3f} "
                 f"ovf={s['overflow_rate']:.2f}")
            key = (b, mc)
            if best is None or s["mean_utility"] > surface[best][
                    "mean_utility"]:
                best = key
            if worst is None or s["mean_utility"] < surface[worst][
                    "mean_utility"]:
                worst = key
    # the paper's claim: the optimum is interior (moderate b AND m_c)
    interior = best[0] not in (GRID_B[0], GRID_B[-1]) or \
        best[1] not in (GRID_M[0], GRID_M[-1])
    emit("fig1.summary", 0.0,
         f"best=(b={best[0]},mc={best[1]}) worst=(b={worst[0]},"
         f"mc={worst[1]}) interior_optimum={interior}")
    return {"best": best, "worst": worst, "surface": surface}


if __name__ == "__main__":
    main()
