"""Paper Fig. 7: normalized utility per model — BCEdge vs TAC vs DeepRT.

Paper claim: BCEdge beats DeepRT by ~37% and TAC by ~25% on average.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (MODELS, emit, eval_agent, make_agent,
                               train_agent)
from repro.config.base import ServingConfig


def main(fast: bool = True) -> dict:
    cfg = ServingConfig()
    results = {}
    per_model = {}
    for kind, guard in (("sac", True), ("tac", False), ("edf", False)):
        agent, pred, hist = train_agent(kind, cfg, guard=guard)
        env, res = eval_agent(agent, cfg, pred, guard=guard)
        results[kind] = res.summary.get("mean_utility", float("-inf"))
        per_model[kind] = res.per_model_utility
    u_max = max(abs(v) for v in results.values() if np.isfinite(v)) or 1.0
    for m in MODELS:
        row = " ".join(
            f"{k}={per_model[k].get(m, 0.0):.2f}" for k in per_model)
        emit(f"fig7.{m}", 0.0, row)
    sac, tac, edf = results["sac"], results["tac"], results["edf"]
    gain_deeprt = 100.0 * (sac - edf) / max(abs(edf), 1e-6)
    gain_tac = 100.0 * (sac - tac) / max(abs(tac), 1e-6)
    emit("fig7.summary", 0.0,
         f"bcedge={sac:.3f} tac={tac:.3f} deeprt={edf:.3f} "
         f"gain_vs_deeprt={gain_deeprt:.1f}% gain_vs_tac={gain_tac:.1f}% "
         f"(paper: +37%/+25%)")
    return {"results": results, "per_model": per_model}


if __name__ == "__main__":
    main()
