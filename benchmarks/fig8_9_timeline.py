"""Paper Figs. 8/9: per-model throughput + latency over the scheduling
run, starting untrained (paper: 3000 s, saturating ~1500 s once the
scheduler finds the per-model sweet spots).

Rendered from the online-training trajectory of the shared BCEdge agent
(each episode = one timeline segment). Signature behaviour checked:
utility rises / violations fall from the first third to the last third."""
from __future__ import annotations

import numpy as np

from benchmarks.common import MODELS, emit, train_agent
from repro.config.base import ServingConfig


def main(fast: bool = True) -> dict:
    cfg = ServingConfig()
    agent, pred, hist = train_agent("sac", cfg)  # shared with fig7/14
    n = len(hist)
    thr = {m: [h["per_model_throughput"].get(m, 0.0) for h in hist]
           for m in MODELS}
    lat = {m: [h["per_model_latency"].get(m, 0.0) for h in hist]
           for m in MODELS}
    for m in MODELS:
        emit(f"fig8.thr.{m}", 0.0,
             "rps_per_episode=[" + " ".join(f"{v:.1f}" for v in thr[m]) + "]")
        emit(f"fig9.lat.{m}", 0.0,
             "ms_per_episode=[" + " ".join(f"{v:.0f}" for v in lat[m]) + "]")
    utils = [h.get("mean_utility", 0.0) for h in hist]
    viols = [h.get("slo_violation_rate", 1.0) for h in hist]
    third = max(1, n // 3)
    early_u, late_u = np.mean(utils[:third]), np.mean(utils[-third:])
    early_v, late_v = np.mean(viols[:third]), np.mean(viols[-third:])
    emit("fig8_9.summary", 0.0,
         f"util_early={early_u:.2f} util_late={late_u:.2f} "
         f"viol_early={early_v:.3f} viol_late={late_v:.3f} "
         f"improving={late_u >= early_u or late_v <= early_v}")
    return {"thr": thr, "utils": utils}


if __name__ == "__main__":
    main()
