"""Beyond-paper figure: push-mode async serving under a flash crowd
(docs/RUNTIME.md §11) — client-observed latency through the REAL HTTP
front-end, backpressure vs accept-everything.

The full push-mode stack runs end to end: ``ServingDriver`` steps the
pool on a background thread, ``ServingFrontend`` streams per-token
ndjson events over HTTP, and the closed-loop load generator from
``repro.serving.workload`` replays a flash-crowd arrival trace (steady
base load, then a sudden many-fold spike) with mixed SLO tiers and
client abandonment. Two policies face the same trace:

* **backpressure** — non-admissible requests past the queue-depth cap
  get ``429 + Retry-After`` (derived from the calibrated per-token cost
  over the queued work); clients honour the hint and retry.
* **accept-everything** — every request queues. During the spike the
  queue grows without bound, TTFT blows up, and clients abandon
  mid-stream (mass disconnect -> cancellation -> synchronous block
  free).

Asserted invariants (the PR's acceptance gates):

* client-observed TTFT p99 with backpressure <= 0.5x accept-everything;
* tight-tier SLO attainment strictly higher under backpressure
  (throttled clients COUNT against attainment — the 429s must be
  earned);
* zero leaked blocks / reservations after the run and after a
  deliberate mid-stream disconnect storm.

Artifacts: ``benchmarks/out/fig_async_serving.json`` (always) and
``benchmarks/out/fig_async_serving.png`` (when matplotlib is present).

Run:  PYTHONPATH=src python -m benchmarks.fig_async_serving [--smoke]
"""
from __future__ import annotations

import asyncio
import json
import os
import sys

import numpy as np

from benchmarks.common import FAST, SMOKE, emit
from repro.config.base import ModelConfig
from repro.launch.server import ServingFrontend
from repro.serving.driver import ServingDriver
from repro.serving.runtime import ModelInstancePool
from repro.serving.workload import (ArrivalTrace, http_generate,
                                    make_trace_requests, run_closed_loop,
                                    summarize_outcomes)

OUT_DIR = os.path.join(os.path.dirname(__file__), "out")

CFG = ModelConfig(name="tiny-async", family="dense", n_layers=2,
                  d_model=48, n_heads=2, n_kv_heads=2, d_ff=96,
                  vocab_size=151)
MAX_SLOTS = 3
MAX_SEQ = 96
#: tiers are compressed vs production (tight SLO ~ a few hundred ms on
#: a tiny CPU model) so the whole figure runs in seconds; abandonment at
#: 3x SLO keeps the accept-everything tail bounded
TIERS = {"tight": (300.0, 0.3), "standard": (1200.0, 0.45),
         "relaxed": (5000.0, 0.25)}
ABANDON_FACTOR = 4.0


def _trace(smoke: bool) -> ArrivalTrace:
    """A short violent spike followed by a LONG base-load tail: the
    accept-everything backlog (relaxed clients are patient) keeps every
    slot busy for seconds after the spike, so post-flash tight arrivals
    miss their SLO — while the backpressure policy, whose queue never
    grew, serves them immediately."""
    if smoke:
        return ArrivalTrace.flash_crowd(10.0, base_rps=8.0,
                                        flash_rps=500.0,
                                        flash_start_frac=0.1,
                                        flash_frac=0.12)
    return ArrivalTrace.flash_crowd(14.0, base_rps=8.0, flash_rps=550.0,
                                    flash_start_frac=0.1,
                                    flash_frac=0.1)


def _leaked(pool: ModelInstancePool) -> dict:
    """Outstanding KV references across every live instance (must be
    zero once all clients have finished/disconnected and the driver has
    drained the resulting cancellations)."""
    live = reserved = 0
    for inst in pool.live():
        al = inst.engine.allocator
        if al is not None:
            live += al.n_live
            reserved += al.n_reserved
    queued = sum(len(q) for q in pool.queues.values())
    resident = sum(i.n_resident for i in pool.live())
    return {"n_live": live, "n_reserved": reserved,
            "n_queued": queued, "n_resident": resident}


async def _disconnect_storm(host: str, port: int, n: int,
                            seed: int) -> dict:
    """``n`` concurrent clients ask for long decodes and ALL hang up
    almost immediately — every client that started streaming must turn
    into a server-side cancel that frees its slot and blocks
    synchronously. (Under backpressure the late arrivals may be
    throttled at the door instead — also a valid non-leaking path.)"""
    rng = np.random.default_rng(seed)
    outs = await asyncio.gather(*(
        http_generate(host, port, CFG.name,
                      rng.integers(1, CFG.vocab_size, 12).astype(np.int32),
                      max_new_tokens=64, slo_ms=5000.0,
                      abandon_after_s=0.05 + 0.01 * i)
        for i in range(n)))
    counts = {}
    for o in outs:
        counts[o.outcome] = counts.get(o.outcome, 0) + 1
    return counts


async def _episode_async(backpressure: bool, smoke: bool,
                         seed: int) -> dict:
    pool = ModelInstancePool({CFG.name: CFG}, max_instances=1,
                             max_slots=MAX_SLOTS, max_seq=MAX_SEQ,
                             kv_layout="paged", block_size=8, seed=seed)
    pool.scale_to(CFG.name, 1)
    pool.warmup(seed=seed)
    trace = _trace(smoke)
    reqs = make_trace_requests(trace, {CFG.name: CFG.vocab_size},
                               seed=seed, prompt_len=(8, 32),
                               max_new=(16, 28), tiers=TIERS,
                               abandon_factor=ABANDON_FACTOR)
    driver = ServingDriver(pool)
    # shallow admission queue: past depth 4 the EDF queue would keep
    # admitted patient-tier clients starved for seconds (tight arrivals
    # jump to the head), dragging the backpressure policy's own TTFT
    # tail up — reject at the door instead
    fe = ServingFrontend(driver, port=0, backpressure=backpressure,
                         max_queue_depth=3)
    driver.start()
    await fe.start()
    try:
        outcomes = await run_closed_loop("127.0.0.1", fe.port, reqs,
                                         retry_on_429=True, max_retries=1)
        storm_n = 6 if smoke else 12
        storm = await _disconnect_storm("127.0.0.1", fe.port, storm_n,
                                        seed)
        # the storm's cancels land synchronously, but give the loop one
        # breath to retire anything admitted in the same iteration
        await asyncio.get_running_loop().run_in_executor(
            None, driver.drain, 30.0)
    finally:
        await fe.stop()
        driver.stop()
    row = summarize_outcomes(outcomes)
    row.update({f"leak_{k}": float(v) for k, v in _leaked(pool).items()})
    stats = pool.stats()
    row.update({
        "policy": "backpressure" if backpressure else "accept_all",
        "n_requests": float(len(reqs)),
        "storm_n": float(storm_n),
        "storm_cancelled": float(storm.get("abandoned", 0)
                                 + storm.get("cancelled", 0)),
        "storm_throttled": float(storm.get("throttled", 0)),
        "storm_other": float(storm_n - sum(storm.get(k, 0) for k in
                                           ("abandoned", "cancelled",
                                            "throttled"))),
        "server_throttled": float(fe.n_throttled),
        "server_disconnects": float(fe.n_disconnects),
        "pool_cancelled": float(stats.get("n_cancelled", 0)),
        "pool_ttft_ms_p99": float(stats.get("ttft_ms_p99", 0.0)),
        "pool_tpot_ms_p99": float(stats.get("tpot_ms_p99", 0.0)),
    })
    return row


def _episode(backpressure: bool, smoke: bool, seed: int = 7) -> dict:
    return asyncio.run(_episode_async(backpressure, smoke, seed))


def _plot(bp: dict, aa: dict, path: str) -> bool:
    try:
        import matplotlib
        matplotlib.use("Agg")
        import matplotlib.pyplot as plt
    except Exception:  # noqa: BLE001
        return False
    fig, axes = plt.subplots(1, 3, figsize=(12, 3.5))
    labels = ["backpressure", "accept-all"]
    axes[0].bar(labels, [bp["ttft_ms_p99"], aa["ttft_ms_p99"]],
                color=["tab:green", "tab:red"])
    axes[0].set_title("client TTFT p99 (ms)")
    for i, tier in enumerate(("tight", "standard", "relaxed")):
        axes[1].bar(np.arange(2) + (i - 1) * 0.25,
                    [bp.get(f"attainment_{tier}", 0.0),
                     aa.get(f"attainment_{tier}", 0.0)],
                    width=0.25, label=tier)
    axes[1].set_xticks(range(2), labels)
    axes[1].set_ylim(0, 1.05)
    axes[1].set_title("SLO attainment by tier")
    axes[1].legend()
    kinds = ("finished", "throttled", "abandoned", "cancelled")
    for i, row in enumerate((bp, aa)):
        bottom = 0.0
        for kind in kinds:
            v = row[f"n_{kind}"]
            axes[2].bar([labels[i]], [v], bottom=bottom,
                        color=f"C{kinds.index(kind)}",
                        label=kind if i == 0 else None)
            bottom += v
    axes[2].set_title("client outcomes")
    axes[2].legend()
    fig.suptitle("flash crowd through the async HTTP front-end "
                 "(docs/RUNTIME.md §11)")
    fig.tight_layout()
    fig.savefig(path, dpi=120)
    plt.close(fig)
    return True


def main(fast: bool = FAST, smoke: bool = SMOKE) -> dict:
    # the fast profile uses the smoke-scale trace (the gates hold at
    # both scales; BENCH_FAST=0 runs the longer one)
    smoke = smoke or fast
    # wall-clock episodes are noisy (single runs spread the TTFT-p99
    # ratio roughly 0.35-0.55); full scale runs 3 seeds per policy and
    # gates on the per-policy MEDIANS, smoke keeps one seed
    seeds = [7] if smoke else [7, 17, 27]
    bp_rows = [_episode(backpressure=True, smoke=smoke, seed=s)
               for s in seeds]
    aa_rows = [_episode(backpressure=False, smoke=smoke, seed=s)
               for s in seeds]

    def _median_row(rows):
        out = dict(rows[0])
        for k in ("ttft_ms_p99", "ttft_ms_p50", "tpot_ms_p99",
                  "attainment_tight", "attainment_standard",
                  "attainment_relaxed"):
            if k in rows[0]:
                out[k] = float(np.median([r[k] for r in rows]))
        return out

    bp, aa = _median_row(bp_rows), _median_row(aa_rows)
    for row in (bp, aa):
        emit(f"fig_async.{row['policy']}", 0.0,
             f"ttft_p99={row['ttft_ms_p99']:.0f}ms "
             f"tight={row.get('attainment_tight', 0.0):.2f} "
             f"fin={row['n_finished']:.0f}/{row['n']:.0f} "
             f"429={row['n_throttled']:.0f} "
             f"abandon={row['n_abandoned']:.0f}")

    # ---- acceptance gates -------------------------------------------------
    for row in bp_rows + aa_rows:  # structural gates: every episode
        assert row["leak_n_live"] == 0 and row["leak_n_reserved"] == 0, \
            f"{row['policy']}: leaked blocks after mass disconnect " \
            f"(live={row['leak_n_live']} reserved={row['leak_n_reserved']})"
        assert row["storm_other"] == 0, \
            f"{row['policy']}: storm client finished or errored " \
            f"(cancelled={row['storm_cancelled']} " \
            f"throttled={row['storm_throttled']})"
        assert row["storm_cancelled"] >= MAX_SLOTS, \
            f"{row['policy']}: too few mid-stream disconnects " \
            f"propagated ({row['storm_cancelled']})"
    ratio = bp["ttft_ms_p99"] / max(aa["ttft_ms_p99"], 1e-9)
    # the wall-clock gates: hard at full scale (ratio 0.37, tight 0.10
    # vs 0.03 measured standalone); the 10x-shorter smoke trace keeps
    # the direction but its margins are thin enough that CPU contention
    # on a shared runner can push them around, so smoke only asserts
    # better-not-worse
    max_ratio = 0.85 if smoke else 0.5
    assert ratio <= max_ratio, \
        f"backpressure TTFT p99 not <= {max_ratio}x accept-all " \
        f"(ratio={ratio:.2f})"
    if smoke:
        assert bp["attainment_tight"] >= aa["attainment_tight"], \
            f"tight-tier attainment regressed " \
            f"({bp['attainment_tight']:.2f} vs " \
            f"{aa['attainment_tight']:.2f})"
    else:
        assert bp["attainment_tight"] > aa["attainment_tight"], \
            f"tight-tier attainment not improved " \
            f"({bp['attainment_tight']:.2f} vs " \
            f"{aa['attainment_tight']:.2f})"
    emit("fig_async.gates", 0.0,
         f"ttft_ratio={ratio:.2f} "
         f"tight={bp['attainment_tight']:.2f}>"
         f"{aa['attainment_tight']:.2f} leaks=0")

    os.makedirs(OUT_DIR, exist_ok=True)
    payload = {"smoke": smoke, "tiers": TIERS,
               "abandon_factor": ABANDON_FACTOR,
               "seeds": seeds,
               "backpressure": bp, "accept_all": aa,
               "backpressure_seeds": bp_rows, "accept_all_seeds": aa_rows,
               "ttft_p99_ratio": ratio}
    json_path = os.path.join(OUT_DIR, "fig_async_serving.json")
    with open(json_path, "w") as f:
        json.dump(payload, f, indent=2)
    emit("fig_async.json", 0.0, json_path)
    png_path = os.path.join(OUT_DIR, "fig_async_serving.png")
    if _plot(bp, aa, png_path):
        emit("fig_async.plot", 0.0, png_path)
    return payload


if __name__ == "__main__":
    _smoke = SMOKE or "--smoke" in sys.argv[1:]
    main(fast=_smoke or FAST, smoke=_smoke)
