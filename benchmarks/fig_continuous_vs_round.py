"""Beyond-paper figure: continuous (iteration-level) vs round batching.

Runs the SAME decode-heavy workload (autoregressive requests, geometric
decode lengths, docs/ARCHITECTURE.md §5) through both execution modes of
the simulator and compares goodput (SLO-met throughput), p50 latency and
utility. Round mode runs every batch to completion — the whole batch
waits for its longest sequence — while continuous mode evicts finished
sequences at iteration boundaries and admits queued ones into the freed
slots, which is where the goodput gap comes from.

Artifacts: ``benchmarks/out/fig_continuous_vs_round.json`` (always) and
``benchmarks/out/fig_continuous_vs_round.png`` (when matplotlib is
available).

Run:  PYTHONPATH=src python -m benchmarks.fig_continuous_vs_round
"""
from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.common import emit
from repro.config.base import ServingConfig
from repro.core.baselines import FixedScheduler
from repro.serving.bcedge import run_episode
from repro.serving.simulator import EdgeServingEnv

OUT_DIR = os.path.join(os.path.dirname(__file__), "out")

#: decode-heavy workload: mean 6 geometric decode iterations per request
DECODE_STEPS_MEAN = 6.0
CONFIGS = ((4, 2), (8, 2))  # (b, m_c) slot/concurrency points


def _run(mode: str, b: int, m_c: int, seeds, episode_ms: float,
         rps: float) -> dict:
    keys = ("goodput_rps", "throughput_rps", "p50_latency_ms",
            "mean_latency_ms", "slo_violation_rate", "mean_utility",
            "mean_queue_wait_ms", "mean_iters")
    acc = {k: [] for k in keys}
    for seed in seeds:
        cfg = ServingConfig(arrival_rps=rps, exec_mode=mode,
                            decode_steps_mean=DECODE_STEPS_MEAN)
        env = EdgeServingEnv(cfg, episode_ms=episode_ms, seed=seed)
        sched = FixedScheduler(cfg.pair_to_action(b, m_c))
        res = run_episode(env, sched, predictor=None, guard=False,
                          learn=False)
        for k in keys:
            acc[k].append(res.summary.get(k, 0.0))
    return {k: float(np.mean(v)) for k, v in acc.items()}


def _plot(rows: dict, path: str) -> bool:
    try:
        import matplotlib
        matplotlib.use("Agg")
        import matplotlib.pyplot as plt
    except Exception:  # noqa: BLE001
        return False
    labels = [f"b={b},mc={m}" for b, m in CONFIGS]
    x = np.arange(len(labels))
    fig, axes = plt.subplots(1, 2, figsize=(9, 3.5))
    for ax, metric, title in (
            (axes[0], "goodput_rps", "goodput (SLO-met rps)"),
            (axes[1], "p50_latency_ms", "p50 latency (ms)")):
        for i, mode in enumerate(("round", "continuous")):
            vals = [rows[f"{mode}.b{b}.mc{m}"][metric] for b, m in CONFIGS]
            ax.bar(x + (i - 0.5) * 0.35, vals, width=0.35, label=mode)
        ax.set_xticks(x, labels)
        ax.set_title(title)
        ax.legend()
    fig.suptitle(f"continuous vs round, decode-heavy workload "
                 f"(mean {DECODE_STEPS_MEAN:.0f} iters/request)")
    fig.tight_layout()
    fig.savefig(path, dpi=120)
    plt.close(fig)
    return True


def main(fast: bool = True) -> dict:
    seeds = (0, 1) if fast else (0, 1, 2, 3, 4)
    episode_ms = 10_000.0 if fast else 30_000.0
    rps = 30.0
    rows = {}
    for b, m_c in CONFIGS:
        for mode in ("round", "continuous"):
            key = f"{mode}.b{b}.mc{m_c}"
            rows[key] = _run(mode, b, m_c, seeds, episode_ms, rps)
            emit(f"fig_cont.{key}", 0.0,
                 f"goodput={rows[key]['goodput_rps']:.1f}rps "
                 f"p50={rows[key]['p50_latency_ms']:.0f}ms "
                 f"viol={rows[key]['slo_violation_rate']:.2f}")

    # headline: best config per mode
    best = {m: max((rows[f"{m}.b{b}.mc{mc}"] for b, mc in CONFIGS),
                   key=lambda r: r["goodput_rps"])
            for m in ("round", "continuous")}
    wins = best["continuous"]["goodput_rps"] >= best["round"]["goodput_rps"]
    emit("fig_cont.summary", 0.0,
         f"continuous_goodput={best['continuous']['goodput_rps']:.1f} "
         f"round_goodput={best['round']['goodput_rps']:.1f} "
         f"continuous_wins={wins}")

    os.makedirs(OUT_DIR, exist_ok=True)
    payload = {
        "decode_steps_mean": DECODE_STEPS_MEAN,
        "arrival_rps": rps,
        "episode_ms": episode_ms,
        "seeds": list(seeds),
        "rows": rows,
        "best": best,
        "continuous_wins_goodput": bool(wins),
    }
    json_path = os.path.join(OUT_DIR, "fig_continuous_vs_round.json")
    with open(json_path, "w") as f:
        json.dump(payload, f, indent=2)
    emit("fig_cont.json", 0.0, json_path)
    png_path = os.path.join(OUT_DIR, "fig_continuous_vs_round.png")
    if _plot(rows, png_path):
        emit("fig_cont.plot", 0.0, png_path)
    return payload


if __name__ == "__main__":
    main()
