"""Beyond-paper figure: fused paged-attention prefill vs the legacy
staging round trip (docs/ARCHITECTURE.md §5; recipe + expected numbers
in docs/EXPERIMENTS.md §Fused kernels).

The legacy chunked-prefill path materialized a per-slot STAGING cache:
admission allocated a fresh single-sequence cache, every chunk
attended that side cache, and completion scattered the whole thing
back into the slot (``_graft``). The fused path deletes the round trip
for paged all-linear stacks — each chunk attends the shared pool
directly through the slot's block-table row, so KV is written exactly
once, in place. The ``prefill_mode="staging"`` override for paged
engines is gone; the round trip survives only where fused prefill
cannot reach (the dense layout, and hybrid stacks), which is exactly
the baseline measured here.

Two engines — the dense engine (staging round trip, no prefix reuse)
vs the fused paged engine (prefix cache on) — drain the SAME
prefill-heavy prefix-templated admission burst (a shared 96-token
prefix + equal-length unique tails — sharing is pad-offset-sensitive,
§5 — and short decode tails). The staging engine re-prefills
the whole shared prefix for every request and scatters the staging
cache back at completion; the fused engine touches only the uncached
tail — a ~4x smaller prefill token stream. Each non-compile iteration
contributes a ``(tokens processed, wall ms)`` sample;
``latency_model.fit_token_cost`` fits
``iter_ms ≈ base + per_token · tokens`` per engine (reported for the
roofline story — the SLOPES are not directly comparable across
layouts, paged block-gather attention pays more per token on CPU than
a dense contiguous cache), and the headline metric is the median
non-compile DRAIN WALL TIME over N_REPEATS drains.

Asserted (the acceptance bar):
  * drain wall time strictly LOWER for fused than the staging round
    trip on the same trace, with strictly fewer tokens processed (the
    cached prefix is skipped, not re-bought);
  * fused greedy outputs token-identical to the dense-engine reference
    for EVERY paged engine variant: plain paged, budgeted, prefix
    cache (hit + miss), and speculative decoding (spec_k > 0) with
    prefix reuse.

Artifacts: ``benchmarks/out/fig_fused_kernels.json`` (always) and
``benchmarks/out/fig_fused_kernels.png`` (when matplotlib is there).

Run:  PYTHONPATH=src python -m benchmarks.fig_fused_kernels
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import FAST, SMOKE, emit
from repro.config.base import ModelConfig
from repro.serving import latency_model
from repro.serving.engine import ContinuousBatchingEngine

OUT_DIR = os.path.join(os.path.dirname(__file__), "out")

TINY = ModelConfig(name="tiny-fused", family="dense", n_layers=2,
                   d_model=64, n_heads=2, n_kv_heads=2, d_ff=128,
                   vocab_size=211)

BLOCK_SIZE = 8
MAX_SEQ = 256
MAX_SLOTS = 4
TOKEN_BUDGET = 48
PREFIX_TOKENS = 96                            # shared, block-aligned
#: per-request unique tails — EQUAL length: prefix sharing is
#: pad-offset-sensitive (the §5 hash chain covers the padded prefix),
#: so same-length prompts are what actually share blocks
TAIL_LENS = (16,) * 12
#: short decode tail: the trace is PREFILL-heavy (an admission burst
#: of templated prompts) — the regime the fused prefill kernel serves;
#: a decode-heavy trace would mostly measure the decode step, which
#: the prefill rework does not touch
MAX_NEW = 6
N_REPEATS = 3                                 # timing repeats per mode


def _trace(seed: int = 0):
    rng = np.random.default_rng(seed)
    v = TINY.vocab_size
    prefix = rng.integers(1, v, PREFIX_TOKENS).astype(np.int32)
    return [np.concatenate([prefix,
                            rng.integers(1, v, n).astype(np.int32)])
            for n in TAIL_LENS]


def _make(mode: str, share_from, **kw):
    """``"fused"`` builds the paged engine (block-table fused prefill);
    ``"staging"`` builds the dense engine, the one layout that still
    runs the legacy round trip (chunk into a per-slot staging cache,
    graft on completion — and no prefix cache, so every request
    re-prefills the shared prefix: the work the fused path deletes)."""
    if mode == "staging":
        kw.pop("prefix_cache", None)
        return ContinuousBatchingEngine(
            TINY, max_slots=MAX_SLOTS, max_seq=MAX_SEQ, seed=0,
            share_from=share_from, **kw)
    return ContinuousBatchingEngine(
        TINY, max_slots=MAX_SLOTS, max_seq=MAX_SEQ, seed=0,
        share_from=share_from, kv_layout="paged", block_size=BLOCK_SIZE,
        prefill_mode=mode, **kw)


def _timed_drain(eng, prompts):
    """Drain the trace, sampling (tokens, ms) per non-compile step and
    the total non-compile wall time of the drain."""
    for p in prompts:
        eng.submit(p, max_new_tokens=MAX_NEW)
    samples = []
    outputs = {}
    drain_ms = 0.0
    while (eng.waiting or eng.active_slots) and eng.n_iters < 20_000:
        t0 = time.perf_counter()
        done = eng.step()
        ms = (time.perf_counter() - t0) * 1e3
        for r in done:
            outputs[r.request_id] = r.tokens
        if not eng.last_step_compiled:
            drain_ms += ms
            if eng.last_step_tokens > 0:
                samples.append((eng.last_step_tokens, ms))
    assert len(outputs) == len(prompts), \
        f"{len(outputs)}/{len(prompts)} drained"
    return samples, outputs, drain_ms


def _fit_mode(mode: str, prompts, share_from):
    """Warm the jit cache on a throwaway pass, then fit the token-cost
    model and take the median drain wall time over N_REPEATS measured
    drains of the same trace."""
    warm = _make(mode, share_from, token_budget=TOKEN_BUDGET,
                 prefix_cache=True)
    _timed_drain(warm, prompts)
    samples = []
    outputs = None
    drains = []
    for _ in range(N_REPEATS):
        eng = _make(mode, share_from, token_budget=TOKEN_BUDGET,
                    prefix_cache=True)
        s, outputs, drain_ms = _timed_drain(eng, prompts)
        samples.extend(s)
        drains.append(drain_ms)
    base, per_tok = latency_model.fit_token_cost(samples)
    return {"mode": mode, "base_ms": base, "per_token_ms": per_tok,
            "drain_ms": float(np.median(drains)),
            "trace_tokens": sum(t for t, _ in samples) // N_REPEATS,
            "n_samples": len(samples)}, samples, outputs


# --------------------------------------------- token-identity variants
def _variant_engines(share_from):
    """Every paged engine shape the fused path serves."""
    return {
        "plain": _make("fused", share_from),
        "budgeted": _make("fused", share_from,
                          token_budget=TOKEN_BUDGET),
        "prefix_cache": _make("fused", share_from, prefix_cache=True,
                              token_budget=TOKEN_BUDGET),
        "speculative": _make("fused", share_from, prefix_cache=True,
                             spec_k=3),
    }


def _identity_prompts(seed: int = 7):
    """Shared-prefix family (prefix-cache hits + full-cover duplicate)
    plus divergent one-offs."""
    rng = np.random.default_rng(seed)
    v = TINY.vocab_size
    shared = rng.integers(1, v, 20).astype(np.int32)
    ps = [np.concatenate([shared, rng.integers(1, v, n).astype(np.int32)])
          for n in (4, 12)]
    ps += [rng.integers(1, v, 9).astype(np.int32), ps[0].copy()]
    return ps


def _check_identity(share_from) -> dict:
    prompts = _identity_prompts()
    checked = {}
    ref = _make("staging", share_from).run(prompts, max_new_tokens=8)
    for name, fused in _variant_engines(share_from).items():
        got = fused.run(prompts, max_new_tokens=8)
        for r_ref, r_got in zip(ref, got):
            assert np.array_equal(r_ref.tokens, r_got.tokens), \
                f"variant {name} rid={r_ref.request_id}: fused output " \
                f"diverges from the dense reference"
        checked[name] = len(prompts)
        emit(f"fig_fused.identity.{name}", 0.0,
             f"{len(prompts)} requests token-identical")
    return checked


def _plot(rows, samples, path: str) -> bool:
    try:
        import matplotlib
        matplotlib.use("Agg")
        import matplotlib.pyplot as plt
    except Exception:  # noqa: BLE001
        return False
    fig, ax = plt.subplots(figsize=(6, 4))
    colors = {"staging": "#888", "fused": "#2a7"}
    for row in rows:
        pts = samples[row["mode"]]
        xs = [t for t, _ in pts]
        ys = [m for _, m in pts]
        ax.scatter(xs, ys, s=8, alpha=0.35, color=colors[row["mode"]])
        xf = np.linspace(0, max(xs), 50)
        ax.plot(xf, row["base_ms"] + row["per_token_ms"] * xf,
                color=colors[row["mode"]],
                label=f"{row['mode']}: {row['per_token_ms']*1e3:.1f} "
                      f"us/token")
    ax.set_xlabel("tokens processed in iteration")
    ax.set_ylabel("iteration wall ms")
    ax.set_title("chunked prefill: staging round trip vs fused "
                 "block-table attention")
    ax.legend()
    fig.tight_layout()
    fig.savefig(path, dpi=120)
    plt.close(fig)
    return True


def main(fast: bool = FAST) -> dict:
    global PREFIX_TOKENS, TAIL_LENS, MAX_NEW, N_REPEATS, MAX_SEQ
    if SMOKE:
        # toy scale: the code paths, not the numbers
        PREFIX_TOKENS, TAIL_LENS, MAX_NEW, N_REPEATS = 24, (8, 8), 4, 1
        MAX_SEQ = 128
    template = ContinuousBatchingEngine(TINY, max_slots=1,
                                        max_seq=MAX_SEQ, seed=0)
    prompts = _trace()

    staging, s_samples, s_out = _fit_mode("staging", prompts, template)
    fused, f_samples, f_out = _fit_mode("fused", prompts, template)
    for rid, toks in s_out.items():
        assert np.array_equal(toks, f_out[rid]), \
            f"trace rid={rid}: fused output diverges from staging"

    for row in (staging, fused):
        emit(f"fig_fused.{row['mode']}", 0.0,
             f"drain={row['drain_ms']:.1f}ms "
             f"tokens={row['trace_tokens']} "
             f"base={row['base_ms']:.3f}ms "
             f"per_token={row['per_token_ms']*1e3:.2f}us "
             f"n={row['n_samples']}")
    ratio = staging["drain_ms"] / max(fused["drain_ms"], 1e-9)
    emit("fig_fused.drain_ratio", 0.0, f"{ratio:.2f}x")
    if not SMOKE:
        # the acceptance bar (docs/EXPERIMENTS.md §Fused kernels): the
        # fused engine prefills only uncached tail tokens, so it drains
        # the prefix-templated trace in strictly less wall time than
        # the dense engine's staging round trip over the full prefix.
        # (Per-token SLOPE is not comparable across layouts — paged
        # block-gather attention costs more per token on CPU than a
        # dense contiguous cache; the token count is what fused wins.)
        assert fused["drain_ms"] < staging["drain_ms"], \
            f"fused drain {fused['drain_ms']:.1f}ms not below " \
            f"staging {staging['drain_ms']:.1f}ms"
        assert fused["trace_tokens"] < staging["trace_tokens"], \
            "fused should process fewer tokens (cached prefix skipped)"

    identity = _check_identity(template)

    os.makedirs(OUT_DIR, exist_ok=True)
    payload = {"prefix_tokens": PREFIX_TOKENS,
               "tail_lens": list(TAIL_LENS), "max_new": MAX_NEW,
               "token_budget": TOKEN_BUDGET, "block_size": BLOCK_SIZE,
               "repeats": N_REPEATS, "rows": [staging, fused],
               "per_token_ratio": ratio,
               "token_identity_variants": identity}
    json_path = os.path.join(OUT_DIR, "fig_fused_kernels.json")
    with open(json_path, "w") as f:
        json.dump(payload, f, indent=2)
    emit("fig_fused.json", 0.0, json_path)
    png_path = os.path.join(OUT_DIR, "fig_fused_kernels.png")
    if _plot([staging, fused],
             {"staging": s_samples, "fused": f_samples}, png_path):
        emit("fig_fused.plot", 0.0, png_path)
    return payload


if __name__ == "__main__":
    main()
