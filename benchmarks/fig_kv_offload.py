"""Beyond-paper figure: the host-memory KV offload tier under preemption
(docs/RUNTIME.md §8, docs/ARCHITECTURE.md §5; recipe + expected numbers
in docs/EXPERIMENTS.md §KV offload).

Two pools on the same workload, differing only in ``preempt_mode``. A
single paged instance with a constrained device block budget serves two
long-context batch requests (the "hogs") whose SLO is sized to absorb
preemption waits and swap round-trips but NOT context replays, while
a stream of tight-SLO urgent requests arrives and preempts them:

- **recompute** frees each victim's blocks; on resume the whole 256+
  token context re-prefills through the chunked-prefill budget (32+
  iterations at ``TOKEN_BUDGET=8``) — the hog pays the replay and
  blows its deadline.
- **swap** moves the victim's blocks to the host tier
  (``jax.device_get`` per block run); resume re-maps them and decodes
  on the next iteration, so the hog's deadline survives the same
  preemption churn.

Asserted acceptance (the ISSUE-10 criteria):

1. tight-SLO attainment of the preempted class with swap strictly
   beats recompute-only (aggregated over ``TRIALS`` runs per mode),
2. swap-resume output is token-identical to recompute-resume (both are
   checked against an uninterrupted reference run),
3. zero blocks — device or host — leak after drain.

Artifacts: ``benchmarks/out/fig_kv_offload.json`` (always) and
``benchmarks/out/fig_kv_offload.png`` (when matplotlib is available).

Run:  PYTHONPATH=src python -m benchmarks.fig_kv_offload
"""
from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.common import FAST, SMOKE, emit
from repro.config.base import ModelConfig
from repro.serving.engine import ContinuousBatchingEngine
from repro.serving.runtime import ModelInstancePool

OUT_DIR = os.path.join(os.path.dirname(__file__), "out")

TINY = ModelConfig(name="tiny-offload", family="dense", n_layers=4,
                   d_model=128, n_heads=4, n_kv_heads=4, d_ff=256,
                   vocab_size=211)

CACHE_LEN = 512
BLOCK_SIZE = 8
# two full hog contexts + an urgent fit, but nothing is free: a swapped
# hog's blocks must round-trip through the host tier to come back
KV_BLOCK_BUDGET = 128
KV_HOST_BLOCKS = 128
TOKEN_BUDGET = 8           # chunked re-prefill: the cost swap avoids
HOG_PROMPT = 248           # left-pads to the 256 bucket → 32+ blocks
HOG_TOKENS = 40 if SMOKE else 160
HOG_SLO_MS = 2000.0        # ~4x uninterrupted: absorbs preempt waits +
                           # swap round-trips, not 256-token replays
N_URGENT = 2
URGENT_PROMPT = 24
URGENT_TOKENS = 16         # enough predicted service time that the
                           # preempt trigger fires near arrival
URGENT_SLO_MS = 300.0
URGENT_EVERY_S = 0.5
TRIALS = 1 if SMOKE else 3


def _two_tier_leaks(pool) -> dict:
    """Post-drain two-tier conservation over every live paged engine."""
    dev_live = host_live = 0
    dev_ok = host_ok = True
    for insts in pool.instances.values():
        for inst in insts:
            al = getattr(inst.engine, "allocator", None)
            if al is None:
                continue
            dev_live += al.n_live
            host_live += al.n_host_live
            dev_ok &= al.n_free + al.n_cached + al.n_live == al.n_blocks
            host_ok &= (al.n_host_free + al.n_host_cached +
                        al.n_host_live == al.n_host_blocks)
    return {"device_live": dev_live, "host_live": host_live,
            "device_conserved": bool(dev_ok),
            "host_conserved": bool(host_ok)}


def _run_trial(mode: str, ref: np.ndarray, hog_prompt: np.ndarray,
               seed: int) -> dict:
    """One hog/urgent contention run under one preempt mode."""
    pool = ModelInstancePool({TINY.name: TINY}, max_instances=1,
                             max_slots=2, max_seq=CACHE_LEN, seed=0,
                             kv_layout="paged", block_size=BLOCK_SIZE,
                             kv_block_budget=KV_BLOCK_BUDGET,
                             kv_host_blocks=KV_HOST_BLOCKS,
                             token_budget=TOKEN_BUDGET,
                             preemption=True, max_preemptions=100,
                             preempt_cooldown_steps=4,
                             preempt_mode=mode)
    pool.scale_to(TINY.name, 1)
    pool.warmup(seed=0)
    rng = np.random.default_rng(seed)
    # calibrate the contention fit (the preemption trigger needs it) and
    # warm the long-prompt prefill shapes before measurement
    for _ in range(2):
        pool.submit(TINY.name, hog_prompt, slo_ms=60_000.0,
                    max_new_tokens=4)
    pool.run_until_drained()

    hogs = [pool.submit(TINY.name, hog_prompt, slo_ms=HOG_SLO_MS,
                        max_new_tokens=HOG_TOKENS) for _ in range(2)]
    urgent_ids = []
    next_urgent = URGENT_EVERY_S
    t0 = pool.now()
    done = []
    for _ in range(100_000):
        if len(urgent_ids) < N_URGENT and pool.now() - t0 >= next_urgent:
            urgent_ids.append(pool.submit(
                TINY.name,
                rng.integers(1, TINY.vocab_size, URGENT_PROMPT).astype(
                    np.int32),
                slo_ms=URGENT_SLO_MS, max_new_tokens=URGENT_TOKENS))
            next_urgent += URGENT_EVERY_S
        done.extend(pool.step())
        if len(done) == len(hogs) + N_URGENT and len(urgent_ids) == N_URGENT:
            break
    by_id = {r.request_id: r for r in done}
    urgent = [by_id[i] for i in urgent_ids]
    hog_res = [by_id[i] for i in hogs]
    leaks = _two_tier_leaks(pool)
    stats = pool.stats()
    return {
        "mode": mode,
        "seed": seed,
        "n_preempted": pool.n_preempted,
        "n_swap_preempted": pool.n_swap_preempted,
        "hog_latency_ms": [float(r.latency_ms) for r in hog_res],
        "hog_met": [bool(not r.violated) for r in hog_res],
        "urgent_met": [bool(not r.violated) for r in urgent],
        "urgent_latency_ms": [float(r.latency_ms) for r in urgent],
        "hog_token_identical": bool(all(
            np.array_equal(r.tokens, ref) for r in hog_res)),
        "wall_s": float(pool.now() - t0),
        "swap_base_ms": float(stats.get("swap_base_ms", 0.0)),
        "swap_ms_per_mb": float(stats.get("swap_ms_per_mb", 0.0)),
        **leaks,
    }


def _aggregate(trials: list) -> dict:
    hogs_met = [m for t in trials for m in t["hog_met"]]
    urg_met = [m for t in trials for m in t["urgent_met"]]
    return {
        "mode": trials[0]["mode"],
        "hog_slo_attainment": float(np.mean(hogs_met)),
        "urgent_slo_attainment": float(np.mean(urg_met)),
        "hog_latency_max_ms": float(max(
            x for t in trials for x in t["hog_latency_ms"])),
        "n_preempted": sum(t["n_preempted"] for t in trials),
        "n_swap_preempted": sum(t["n_swap_preempted"] for t in trials),
        "token_identical": all(t["hog_token_identical"] for t in trials),
        "trials": trials,
    }


def _plot(rows: list, path: str) -> bool:
    try:
        import matplotlib
        matplotlib.use("Agg")
        import matplotlib.pyplot as plt
    except Exception:  # noqa: BLE001
        return False
    fig, axes = plt.subplots(1, 2, figsize=(8, 3.5))
    labels = [r["mode"] for r in rows]
    axes[0].bar(labels, [r["hog_slo_attainment"] for r in rows],
                color=["#888", "#2a7"])
    axes[0].set_ylim(0, 1.05)
    axes[0].set_title(
        f"preempted-class attainment ({HOG_SLO_MS:.0f}ms SLO)")
    for r, xs in zip(rows, ([0.9, 1.1], [1.9, 2.1])):
        lats = [x for t in r["trials"] for x in t["hog_latency_ms"]]
        axes[1].scatter([xs[i % 2] for i in range(len(lats))], lats,
                        label=r["mode"], s=18)
    axes[1].axhline(HOG_SLO_MS, color="#c33", ls="--", lw=1,
                    label="SLO")
    axes[1].set_ylabel("hog completion ms")
    axes[1].set_xticks([1, 2], labels)
    axes[1].set_title("replay cost vs swap round-trip")
    axes[1].legend(fontsize=7)
    fig.suptitle("KV offload: swap-resume vs recompute-resume")
    fig.tight_layout()
    fig.savefig(path, dpi=120)
    plt.close(fig)
    return True


def main(fast: bool = FAST) -> dict:
    rng = np.random.default_rng(1)
    hog_prompt = rng.integers(1, TINY.vocab_size, HOG_PROMPT).astype(
        np.int32)
    # uninterrupted reference completion for the token-identity check
    ref = ContinuousBatchingEngine(
        TINY, max_slots=2, max_seq=CACHE_LEN, seed=0, kv_layout="paged",
        block_size=BLOCK_SIZE).run(
            [hog_prompt], max_new_tokens=HOG_TOKENS)[0].tokens

    rows = []
    for mode in ("recompute", "swap"):
        trials = [_run_trial(mode, ref, hog_prompt, seed=2 + k)
                  for k in range(TRIALS)]
        rows.append(_aggregate(trials))
    for r in rows:
        emit(f"fig_kv_offload.{r['mode']}", 0.0,
             f"hog_slo={r['hog_slo_attainment']:.2f} "
             f"urgent_slo={r['urgent_slo_attainment']:.2f} "
             f"hog_max={r['hog_latency_max_ms']:.0f}ms "
             f"preempts={r['n_preempted']} "
             f"swaps={r['n_swap_preempted']} "
             f"identical={r['token_identical']}")
    rec, swp = rows

    # acceptance 2: both resume flavours replay to the same completion
    # as the uninterrupted run — so swap-resume == recompute-resume
    assert rec["token_identical"], \
        "recompute-resume diverged from the uninterrupted reference"
    assert swp["token_identical"], \
        "swap-resume diverged from the uninterrupted reference"
    # acceptance 3: nothing leaks in either tier after drain
    for r in rows:
        for t in r["trials"]:
            assert t["device_live"] == 0 and t["host_live"] == 0, \
                f"{r['mode']}: live blocks survived drain"
            assert t["device_conserved"] and t["host_conserved"], \
                f"{r['mode']}: block conservation violated post-drain"
    if not SMOKE:
        assert rec["n_preempted"] > 0 and swp["n_preempted"] > 0, \
            "preemption never fired — the workload lost its contention"
        assert swp["n_swap_preempted"] > 0, "swap mode never swapped"
        assert rec["n_swap_preempted"] == 0, "recompute mode swapped"
        # acceptance 1: the preempted class strictly gains from not
        # paying the chunked context replay on every resume
        assert swp["hog_slo_attainment"] > rec["hog_slo_attainment"], \
            (f"swap did not beat recompute: "
             f"{swp['hog_slo_attainment']:.2f} vs "
             f"{rec['hog_slo_attainment']:.2f}")

    os.makedirs(OUT_DIR, exist_ok=True)
    payload = {"rows": rows, "hog_prompt": HOG_PROMPT,
               "hog_tokens": HOG_TOKENS, "hog_slo_ms": HOG_SLO_MS,
               "n_urgent": N_URGENT, "urgent_slo_ms": URGENT_SLO_MS,
               "token_budget": TOKEN_BUDGET,
               "kv_block_budget": KV_BLOCK_BUDGET,
               "kv_host_blocks": KV_HOST_BLOCKS, "trials": TRIALS}
    json_path = os.path.join(OUT_DIR, "fig_kv_offload.json")
    with open(json_path, "w") as f:
        json.dump(payload, f, indent=2)
    emit("fig_kv_offload.json", 0.0, json_path)
    png_path = os.path.join(OUT_DIR, "fig_kv_offload.png")
    if _plot(rows, png_path):
        emit("fig_kv_offload.plot", 0.0, png_path)
    return payload


if __name__ == "__main__":
    main()
