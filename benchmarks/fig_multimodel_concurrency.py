"""Beyond-paper figure: REAL multi-model concurrency (utility vs m_c).

Sweeps the number of live engine instances per model on the
``ModelInstancePool`` runtime (docs/RUNTIME.md) — two heterogeneous
reduced models served CONCURRENTLY, wall-clock latencies and all. This
is the paper's Fig.-1 concurrency axis measured on real jit-compiled
execution instead of the analytic simulator: scaling m_c up first buys
throughput (more KV slots drain the queue), then costs latency as the
instances contend for the host (the pool's calibrated contention model
quantifies exactly that inflation).

The models are tiny on purpose — the point is the *shape* of the
utility-vs-m_c curve under real contention at CPU-feasible scale, not
absolute numbers. ``BENCH_FAST=0`` lengthens the per-point episodes.

Artifacts: ``benchmarks/out/fig_multimodel_concurrency.json`` (always)
and ``benchmarks/out/fig_multimodel_concurrency.png`` (when matplotlib
is available).

Run:  PYTHONPATH=src python -m benchmarks.fig_multimodel_concurrency
"""
from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.common import FAST, emit
from repro.config.base import ModelConfig
from repro.serving.runtime import ModelInstancePool

OUT_DIR = os.path.join(os.path.dirname(__file__), "out")

MODELS = {
    "tiny-dense": ModelConfig(name="tiny-dense", family="dense",
                              n_layers=2, d_model=64, n_heads=2,
                              n_kv_heads=2, d_ff=128, vocab_size=211),
    "tiny-wide": ModelConfig(name="tiny-wide", family="dense",
                             n_layers=2, d_model=96, n_heads=2,
                             n_kv_heads=2, d_ff=192, vocab_size=193),
}
M_C_SWEEP = (1, 2, 3)
MAX_SLOTS = 2
MAX_NEW = 12
SLO_MS = 350.0
#: offered load per model — just above one instance's service capacity
#: on an idle host, so the m_c=1 point queues and scaling up has a
#: regime to escape from
RPS_PER_MODEL = 28.0


def _run_point(m_c: int, duration_s: float, rps_per_model: float,
               seed: int = 0) -> dict:
    """One fixed-allocation episode: every model pinned at m_c."""
    pool = ModelInstancePool(MODELS, max_instances=m_c * len(MODELS),
                             max_slots=MAX_SLOTS, max_seq=64, seed=seed)
    rng = np.random.default_rng(seed)
    for m in MODELS:
        pool.scale_to(m, m_c)
    pool.warmup(seed=seed)

    import time
    next_arrival = {m: rng.exponential(1.0 / rps_per_model)
                    for m in MODELS}
    t0 = time.perf_counter()
    while time.perf_counter() - t0 < duration_s:
        now = time.perf_counter() - t0
        for m, cfg in MODELS.items():
            while next_arrival[m] <= now:
                prompt = rng.integers(1, cfg.vocab_size,
                                      rng.integers(4, 16)).astype(np.int32)
                pool.submit(m, prompt, slo_ms=SLO_MS,
                            max_new_tokens=MAX_NEW)
                next_arrival[m] += rng.exponential(1.0 / rps_per_model)
        if any(i.n_resident for i in pool.live()) \
                or any(pool.queues.values()):
            pool.step()
        else:
            time.sleep(0.001)
    # arrivals stop at the cutoff, but everything still queued/in-flight
    # is drained and COUNTED — saturated points must pay for their
    # backlog, or slo_attainment at low m_c would be inflated
    pool.run_until_drained()
    dur = time.perf_counter() - t0

    t1, c = pool.contention()
    iters = [ms for _, ms in pool.contention_samples]
    row = {"m_c": m_c, "contention_t1_ms": t1, "contention_c": c,
           "mean_iter_ms": float(np.mean(iters)) if iters else 0.0,
           "per_model": {}}
    for m in MODELS:
        served = [r for r in pool.results(m) if not r.rejected]
        lats = [r.latency_ms for r in served]
        rep = pool.report()[m]
        row["per_model"][m] = {
            "throughput_rps": len(served) / max(dur, 1e-6),
            "offered_rps": rps_per_model,
            "slo_attainment": rep["slo_attainment"],
            "mean_utility": rep["mean_utility"],
            "p50_latency_ms": float(np.percentile(lats, 50)) if lats
            else 0.0,
            "p99_latency_ms": float(np.percentile(lats, 99)) if lats
            else 0.0,
        }
    return row


def _plot(rows: list, path: str) -> bool:
    try:
        import matplotlib
        matplotlib.use("Agg")
        import matplotlib.pyplot as plt
    except Exception:  # noqa: BLE001
        return False
    xs = [r["m_c"] for r in rows]
    fig, axes = plt.subplots(1, 3, figsize=(12, 3.5))
    for ax, metric, title in (
            (axes[0], "mean_utility", "mean utility (Eq. 3)"),
            (axes[1], "p50_latency_ms", "p50 latency (ms)"),
            (axes[2], "slo_attainment", "SLO attainment")):
        for m in MODELS:
            ax.plot(xs, [r["per_model"][m][metric] for r in rows],
                    marker="o", label=m)
        ax.set_xlabel("m_c (live instances per model)")
        ax.set_xticks(xs)
        ax.set_title(title)
        ax.legend()
    fig.suptitle("real multi-model concurrency on the instance pool "
                 f"(slots/instance={MAX_SLOTS}, {MAX_NEW} decode iters)")
    fig.tight_layout()
    fig.savefig(path, dpi=120)
    plt.close(fig)
    return True


def main(fast: bool = FAST) -> dict:
    duration_s = 4.0 if fast else 12.0
    rps_per_model = RPS_PER_MODEL
    rows = []
    for m_c in M_C_SWEEP:
        row = _run_point(m_c, duration_s, rps_per_model)
        rows.append(row)
        for m in MODELS:
            pm = row["per_model"][m]
            emit(f"fig_mm.mc{m_c}.{m}", 0.0,
                 f"thr={pm['throughput_rps']:.1f}rps "
                 f"p50={pm['p50_latency_ms']:.0f}ms "
                 f"slo={pm['slo_attainment']:.2f} "
                 f"u={pm['mean_utility']:.2f}")
        emit(f"fig_mm.mc{m_c}.contention", 0.0,
             f"t1={row['contention_t1_ms']:.1f}ms "
             f"c={row['contention_c']:.2f}")

    # headline: the utility-maximising m_c per model (the knob BCEdge's
    # scheduler is supposed to find)
    best = {m: max(rows, key=lambda r: r["per_model"][m]["mean_utility"])
            ["m_c"] for m in MODELS}
    emit("fig_mm.best_mc", 0.0, json.dumps(best))

    os.makedirs(OUT_DIR, exist_ok=True)
    payload = {"m_c_sweep": list(M_C_SWEEP), "max_slots": MAX_SLOTS,
               "max_new_tokens": MAX_NEW, "slo_ms": SLO_MS,
               "rps_per_model": rps_per_model, "duration_s": duration_s,
               "rows": rows, "best_mc": best}
    json_path = os.path.join(OUT_DIR, "fig_multimodel_concurrency.json")
    with open(json_path, "w") as f:
        json.dump(payload, f, indent=2)
    emit("fig_mm.json", 0.0, json_path)
    png_path = os.path.join(OUT_DIR, "fig_multimodel_concurrency.png")
    if _plot(rows, png_path):
        emit("fig_mm.plot", 0.0, png_path)
    return payload


if __name__ == "__main__":
    main()
