"""Beyond-paper figure: paged vs dense KV-cache memory management
(docs/ARCHITECTURE.md §5, docs/RUNTIME.md §7; recipe + expected numbers
in docs/EXPERIMENTS.md §Paged KV).

Two panels, both on a decode-heavy workload (short prompts, long
decodes — the regime where dense per-slot slabs waste the most cache):

1. **Resident capacity** — one `ContinuousBatchingEngine` per layout
   under the SAME token budget. Dense commits `cache_len` tokens per
   slot, so the budget caps the slot count; paged only occupies the
   blocks a sequence actually needs, so the same budget holds ≥1.5×
   (typically ~4×) more concurrently resident sequences. Reported as
   peak resident sequences, sequences-per-GB (using the model's
   analytic KV bytes/token), and the engines' own `kv_waste_frac`.

2. **Pool concurrency vs m_c** — a `ModelInstancePool` per layout under
   the SAME shared block budget, every model pinned at m_c instances,
   draining a fixed request burst (closed loop, so the numbers do not
   depend on how loaded the host happens to be). Dense instances must
   fit their whole slab in the budget, so `scale_to` clamps at m_c=1;
   paged instances take right-sized grants and the pool admits on real
   free-block counts, so the same budget reaches m_c=4 — more resident
   sequences, shorter queue waits, higher per-request utility.

Artifacts: ``benchmarks/out/fig_paged_kv.json`` (always) and
``benchmarks/out/fig_paged_kv.png`` (when matplotlib is available).

Run:  PYTHONPATH=src python -m benchmarks.fig_paged_kv
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import FAST, SMOKE, emit
from repro.config.base import ModelConfig
from repro.serving.engine import ContinuousBatchingEngine
from repro.serving.runtime import ModelInstancePool

OUT_DIR = os.path.join(os.path.dirname(__file__), "out")

TINY = ModelConfig(name="tiny-paged", family="dense", n_layers=2,
                   d_model=64, n_heads=2, n_kv_heads=2, d_ff=128,
                   vocab_size=211)

BLOCK_SIZE = 16
CACHE_LEN = 256            # per-sequence max (what dense commits per slot)
BUDGET_TOKENS = 2048       # shared KV token budget for both layouts
MAX_NEW = 16               # decode-heavy: prompts 4..12 tokens
N_REQUESTS = 64

POOL_MAX_SEQ = 128
POOL_MAX_SLOTS = 2
POOL_BUDGET_BLOCKS = 16    # 256 tokens: ONE dense slab, 4 right-sized grants
M_C_SWEEP = (1, 2, 3, 4)
POOL_SLO_MS = 2000.0       # burst drain: deadlines generous, latency ranks
POOL_BURST = 48


def kv_bytes_per_token(cfg: ModelConfig) -> int:
    """Analytic f32 KV bytes per cache token (linear attention layers)."""
    n_kv_layers = sum(1 for k in cfg.layer_kinds()
                      if k in ("attn", "attn_dense")
                      and cfg.sliding_window is None)
    return n_kv_layers * 2 * cfg.n_kv_heads * cfg.head_dim * 4


def _run_engine(layout: str, seed: int = 0) -> dict:
    """Drain N_REQUESTS short prompts through one engine whose KV memory
    is capped at BUDGET_TOKENS; track peak residency and waste."""
    if layout == "dense":
        eng = ContinuousBatchingEngine(TINY,
                                       max_slots=BUDGET_TOKENS // CACHE_LEN,
                                       max_seq=CACHE_LEN)
    else:
        eng = ContinuousBatchingEngine(TINY, max_slots=32,
                                       max_seq=CACHE_LEN,
                                       kv_layout="paged",
                                       block_size=BLOCK_SIZE,
                                       kv_blocks=BUDGET_TOKENS // BLOCK_SIZE)
    rng = np.random.default_rng(seed)
    for _ in range(N_REQUESTS):
        eng.submit(rng.integers(1, TINY.vocab_size,
                                rng.integers(4, 13)).astype(np.int32),
                   max_new_tokens=MAX_NEW)
    peak_resident = 0
    waste = []
    t0 = time.perf_counter()
    n_done = 0
    while (eng.waiting or eng.active_slots) and eng.n_iters < 10_000:
        n_done += len(eng.step())
        peak_resident = max(peak_resident, len(eng.active_slots))
        waste.append(eng.stats()["kv_waste_frac"])
    dur_s = time.perf_counter() - t0
    assert n_done == N_REQUESTS, f"{layout}: {n_done}/{N_REQUESTS} served"
    budget_gb = BUDGET_TOKENS * kv_bytes_per_token(TINY) / 1e9
    return {
        "layout": layout,
        "budget_tokens": BUDGET_TOKENS,
        "peak_resident": peak_resident,
        "sequences_per_gb": peak_resident / budget_gb,
        "mean_kv_waste_frac": float(np.mean(waste)),
        "n_iters": eng.n_iters,
        "throughput_rps": n_done / max(dur_s, 1e-6),
    }


def _run_pool_point(layout: str, m_c: int, burst: int = POOL_BURST,
                    seed: int = 0) -> dict:
    """Drain a fixed burst through a fixed (layout, m_c) allocation
    under the shared block budget (closed loop)."""
    kw = dict(kv_block_budget=POOL_BUDGET_BLOCKS, block_size=BLOCK_SIZE)
    if layout == "paged":
        # right-size the grant to the workload (prompt bucket + decode
        # tokens per slot) instead of the dense-equivalent slab
        per_slot = -(-(16 + MAX_NEW) // BLOCK_SIZE)
        kw.update(kv_layout="paged",
                  blocks_per_instance=POOL_MAX_SLOTS * per_slot)
    pool = ModelInstancePool({TINY.name: TINY}, max_instances=max(M_C_SWEEP),
                             max_slots=POOL_MAX_SLOTS, max_seq=POOL_MAX_SEQ,
                             seed=seed, **kw)
    reached = pool.scale_to(TINY.name, m_c)
    pool.warmup(seed=seed)
    rng = np.random.default_rng(seed)
    for _ in range(burst):
        pool.submit(TINY.name,
                    rng.integers(1, TINY.vocab_size,
                                 rng.integers(4, 13)).astype(np.int32),
                    slo_ms=POOL_SLO_MS, max_new_tokens=MAX_NEW)
    peak_resident = 0
    done = []
    t0 = time.perf_counter()
    steps = 0
    while len(done) < burst and steps < 20_000:
        done.extend(pool.step())
        peak_resident = max(peak_resident,
                            sum(i.n_resident for i in pool.live()))
        steps += 1
    assert len(done) == burst, \
        f"{layout} m_c={m_c}: {len(done)}/{burst} drained in {steps} steps"
    makespan_s = time.perf_counter() - t0
    lats = [r.latency_ms for r in done if not r.rejected]
    occ = pool.kv_occupancy()
    return {
        "layout": layout, "m_c_requested": m_c, "m_c_reached": reached,
        "peak_resident": peak_resident,
        "makespan_s": makespan_s,
        "throughput_rps": burst / max(makespan_s, 1e-6),
        "p50_latency_ms": float(np.percentile(lats, 50)) if lats else 0.0,
        "mean_utility": float(np.mean(
            [r.utility for r in done if not r.rejected])) if lats else 0.0,
        "free_blocks": occ["free_blocks"],
        "tokens_per_seq": occ["tokens_per_seq"],
    }


def _plot(cap_rows: list, pool_rows: list, path: str) -> bool:
    try:
        import matplotlib
        matplotlib.use("Agg")
        import matplotlib.pyplot as plt
    except Exception:  # noqa: BLE001
        return False
    fig, axes = plt.subplots(1, 3, figsize=(12, 3.5))
    layouts = [r["layout"] for r in cap_rows]
    axes[0].bar(layouts, [r["sequences_per_gb"] for r in cap_rows],
                color=["#888", "#2a7"])
    axes[0].set_title("resident sequences per GB of KV")
    axes[1].bar(layouts, [r["mean_kv_waste_frac"] for r in cap_rows],
                color=["#888", "#2a7"])
    axes[1].set_title("mean KV waste fraction")
    for layout, marker in (("dense", "s"), ("paged", "o")):
        rows = [r for r in pool_rows if r["layout"] == layout]
        axes[2].plot([r["m_c_requested"] for r in rows],
                     [r["peak_resident"] for r in rows],
                     marker=marker, label=f"{layout} resident")
        axes[2].plot([r["m_c_requested"] for r in rows],
                     [r["m_c_reached"] for r in rows],
                     marker=marker, linestyle="--",
                     label=f"{layout} m_c reached")
    axes[2].set_xlabel("m_c requested (shared block budget)")
    axes[2].set_title("pool concurrency under one budget")
    axes[2].legend(fontsize=7)
    fig.suptitle(f"paged vs dense KV under a {BUDGET_TOKENS}-token budget")
    fig.tight_layout()
    fig.savefig(path, dpi=120)
    plt.close(fig)
    return True


def main(fast: bool = FAST) -> dict:
    global N_REQUESTS, M_C_SWEEP
    if SMOKE:
        N_REQUESTS, M_C_SWEEP = 8, (1, 2)
    cap_rows = [_run_engine("dense"), _run_engine("paged")]
    for r in cap_rows:
        emit(f"fig_paged.capacity.{r['layout']}", 0.0,
             f"peak={r['peak_resident']} "
             f"seq/GB={r['sequences_per_gb']:.0f} "
             f"waste={r['mean_kv_waste_frac']:.2f}")
    ratio = cap_rows[1]["peak_resident"] / max(1, cap_rows[0]["peak_resident"])
    emit("fig_paged.capacity.ratio", 0.0, f"{ratio:.2f}x")

    burst = 8 if SMOKE else (POOL_BURST if fast else 3 * POOL_BURST)
    pool_rows = []
    for layout in ("dense", "paged"):
        for m_c in M_C_SWEEP:
            row = _run_pool_point(layout, m_c, burst)
            pool_rows.append(row)
            emit(f"fig_paged.pool.{layout}.mc{m_c}", 0.0,
                 f"reached={row['m_c_reached']} "
                 f"resident={row['peak_resident']} "
                 f"p50={row['p50_latency_ms']:.0f}ms "
                 f"u={row['mean_utility']:.2f}")

    # headline: at the largest requested m_c, how many sequences the two
    # layouts actually keep resident under the SAME block budget
    top = {layout: max((r for r in pool_rows if r["layout"] == layout),
                       key=lambda r: r["m_c_requested"])
           for layout in ("dense", "paged")}
    pool_ratio = top["paged"]["peak_resident"] \
        / max(1, top["dense"]["peak_resident"])
    emit("fig_paged.pool.resident_ratio", 0.0, f"{pool_ratio:.2f}x")

    os.makedirs(OUT_DIR, exist_ok=True)
    payload = {"budget_tokens": BUDGET_TOKENS, "block_size": BLOCK_SIZE,
               "cache_len": CACHE_LEN, "max_new_tokens": MAX_NEW,
               "capacity": cap_rows, "capacity_ratio": ratio,
               "pool_budget_blocks": POOL_BUDGET_BLOCKS,
               "pool": pool_rows, "pool_resident_ratio": pool_ratio}
    json_path = os.path.join(OUT_DIR, "fig_paged_kv.json")
    with open(json_path, "w") as f:
        json.dump(payload, f, indent=2)
    emit("fig_paged.json", 0.0, json_path)
    png_path = os.path.join(OUT_DIR, "fig_paged_kv.png")
    if _plot(cap_rows, pool_rows, png_path):
        emit("fig_paged.plot", 0.0, png_path)
    return payload


if __name__ == "__main__":
    main()
