"""Beyond-paper figure: chunked prefill + SLO-aware preemption on the
real continuous engine (docs/ARCHITECTURE.md §5, docs/RUNTIME.md §8;
recipe + expected numbers in docs/EXPERIMENTS.md §Preemption).

Two panels on the mixed workload BCEdge's SLO story lives or dies on —
long prompts sharing an engine with short-SLO short requests:

1. **Iteration-latency bound** — one engine, short decode-heavy
   residents plus periodic long-prompt arrivals. Uncapped admission
   processes a whole 256-token prompt inside one iteration, so resident
   decodes stall for the full prefill (the p99 iteration spike). With a
   per-iteration token budget the same prompt lands as bounded chunks
   interleaved with decodes: p99 iteration time stays within ~2x the
   pure-decode iteration (the acceptance bound this module asserts).

2. **SLO attainment under preemption** — a pool whose slots are held by
   long, lazy-SLO hogs while tight-SLO requests arrive. Without
   preemption the urgent class waits out whole hog residencies and
   violates; with the EDF policy (largest-slack victim, hysteresis) it
   preempts into the freed slot and meets its deadline, while every
   preempted hog resumes to a token-identical completion (asserted
   against an uninterrupted reference run).

Artifacts: ``benchmarks/out/fig_preemption_chunked.json`` (always) and
``benchmarks/out/fig_preemption_chunked.png`` (when matplotlib is
available).

Run:  PYTHONPATH=src python -m benchmarks.fig_preemption_chunked
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import FAST, SMOKE, emit
from repro.config.base import ModelConfig
from repro.serving.engine import ContinuousBatchingEngine
from repro.serving.runtime import ModelInstancePool

OUT_DIR = os.path.join(os.path.dirname(__file__), "out")

TINY = ModelConfig(name="tiny-preempt", family="dense", n_layers=4,
                   d_model=128, n_heads=4, n_kv_heads=4, d_ff=256,
                   vocab_size=211)

# panel 1: engine iteration-latency bound
CACHE_LEN = 768
N_SLOTS = 4
LONG_PROMPT = 500          # bucket 512 — the prefill spike
SHORT_PROMPT = 8
TOKEN_BUDGET = 32
N_STEPS = 60 if SMOKE else 240
LONG_EVERY = 30            # steps between long-prompt arrivals

# panel 2: pool preemption SLO attainment
POOL_CACHE_LEN = 512
HOG_TOKENS = 60 if SMOKE else 200   # hog residency length (decode steps)
N_URGENT = 2 if SMOKE else 5
URGENT_SLO_MS = 400.0
URGENT_EVERY_S = 0.12


def _run_engine_panel(token_budget) -> dict:
    """Mixed long-prompt/short-decode traffic on one engine; returns the
    per-iteration latency distribution split into pure-decode and
    prefill-carrying steps (compile steps excluded)."""
    eng = ContinuousBatchingEngine(TINY, max_slots=N_SLOTS,
                                   max_seq=CACHE_LEN,
                                   token_budget=token_budget)
    rng = np.random.default_rng(0)
    short = lambda: rng.integers(  # noqa: E731
        1, TINY.vocab_size, SHORT_PROMPT).astype(np.int32)
    long_p = lambda: rng.integers(  # noqa: E731
        1, TINY.vocab_size, LONG_PROMPT).astype(np.int32)
    # warm every shape this run will touch (compile time is not the
    # phenomenon being measured)
    eng.submit(long_p(), max_new_tokens=2)
    eng.submit(short(), max_new_tokens=2)
    while eng.active_slots or eng.waiting:
        eng.step()

    for _ in range(N_SLOTS - 1):
        eng.submit(short(), max_new_tokens=1000)  # long-lived residents
    decode_ms, prefill_ms = [], []
    for step in range(N_STEPS):
        if step % LONG_EVERY == 5:
            eng.submit(long_p(), max_new_tokens=4)
        has_prefill = eng.prefill_backlog_tokens > 0
        t0 = time.perf_counter()
        eng.step()
        ms = (time.perf_counter() - t0) * 1000.0
        if eng.last_step_compiled:
            continue
        (prefill_ms if has_prefill else decode_ms).append(ms)
    assert decode_ms and prefill_ms, "workload never mixed the phases"
    all_ms = decode_ms + prefill_ms
    return {
        "token_budget": token_budget or 0,
        "decode_p50_ms": float(np.percentile(decode_ms, 50)),
        # host-noise spikes (container CPU jitter) land in BOTH classes,
        # so the headline bound compares p99 against the pure-decode p99
        "decode_p99_ms": float(np.percentile(decode_ms, 99)),
        "p99_ms": float(np.percentile(all_ms, 99)),
        "max_ms": float(np.max(all_ms)),
        "prefill_steps": len(prefill_ms),
        "n_steps": len(all_ms),
    }


def _run_pool_panel(preemption: bool) -> dict:
    """Tight-SLO arrivals against slots held by lazy-SLO hogs; returns
    SLO attainment per class and the preempt-resume identity check."""
    pool = ModelInstancePool({TINY.name: TINY}, max_instances=1,
                             max_slots=2, max_seq=POOL_CACHE_LEN, seed=0,
                             preemption=preemption, max_preemptions=100,
                             preempt_cooldown_steps=4)
    pool.scale_to(TINY.name, 1)
    pool.warmup(seed=0)
    rng = np.random.default_rng(1)
    # calibrate the contention fit (the preemption trigger needs it)
    for _ in range(2):
        pool.submit(TINY.name, rng.integers(1, TINY.vocab_size, 8).astype(
            np.int32), slo_ms=60_000.0, max_new_tokens=8)
    pool.run_until_drained()

    hog_prompt = rng.integers(1, TINY.vocab_size, 20).astype(np.int32)
    ref = ContinuousBatchingEngine(
        TINY, max_slots=2, max_seq=POOL_CACHE_LEN,
        seed=0).run([hog_prompt], max_new_tokens=HOG_TOKENS)[0].tokens

    hogs = [pool.submit(TINY.name, hog_prompt, slo_ms=600_000.0,
                        max_new_tokens=HOG_TOKENS) for _ in range(2)]
    urgent_ids = []
    next_urgent = URGENT_EVERY_S
    t0 = pool.now()
    done = []
    for _ in range(50_000):
        if len(urgent_ids) < N_URGENT and pool.now() - t0 >= next_urgent:
            urgent_ids.append(pool.submit(
                TINY.name,
                rng.integers(1, TINY.vocab_size, 8).astype(np.int32),
                slo_ms=URGENT_SLO_MS, max_new_tokens=2))
            next_urgent += URGENT_EVERY_S
        done.extend(pool.step())
        if len(done) == len(hogs) + N_URGENT and len(urgent_ids) == N_URGENT:
            break
    by_id = {r.request_id: r for r in done}
    urgent = [by_id[i] for i in urgent_ids]
    hog_res = [by_id[i] for i in hogs]
    identical = all(np.array_equal(r.tokens, ref) for r in hog_res)
    return {
        "preemption": preemption,
        "n_preempted": pool.n_preempted,
        "urgent_slo_attainment": float(np.mean(
            [not r.violated for r in urgent])),
        "urgent_p99_ms": float(np.percentile(
            [r.latency_ms for r in urgent], 99)),
        "hog_tokens_ok": all(len(r.tokens) == HOG_TOKENS for r in hog_res),
        "hog_token_identical": bool(identical),
    }


def _plot(eng_rows: list, pool_rows: list, path: str) -> bool:
    try:
        import matplotlib
        matplotlib.use("Agg")
        import matplotlib.pyplot as plt
    except Exception:  # noqa: BLE001
        return False
    fig, axes = plt.subplots(1, 2, figsize=(9, 3.5))
    labels = ["uncapped", f"budget={TOKEN_BUDGET}"]
    x = np.arange(len(eng_rows))
    axes[0].bar(x - 0.2, [r["decode_p99_ms"] for r in eng_rows], 0.4,
                label="pure-decode p99", color="#888")
    axes[0].bar(x + 0.2, [r["p99_ms"] for r in eng_rows], 0.4,
                label="all-iterations p99", color="#c33")
    axes[0].set_xticks(x, labels)
    axes[0].set_ylabel("iteration ms")
    axes[0].set_title("chunked prefill bounds iteration latency")
    axes[0].legend(fontsize=7)
    labels2 = ["no preemption", "preemption"]
    axes[1].bar(labels2, [r["urgent_slo_attainment"] for r in pool_rows],
                color=["#888", "#2a7"])
    axes[1].set_ylim(0, 1.05)
    axes[1].set_title(f"tight-SLO attainment ({URGENT_SLO_MS:.0f}ms class)")
    fig.suptitle("SLO-aware preemption + chunked prefill")
    fig.tight_layout()
    fig.savefig(path, dpi=120)
    plt.close(fig)
    return True


def main(fast: bool = FAST) -> dict:
    eng_rows = [_run_engine_panel(None), _run_engine_panel(TOKEN_BUDGET)]
    for r in eng_rows:
        emit(f"fig_preempt.engine.budget{r['token_budget']}", 0.0,
             f"decode_p50={r['decode_p50_ms']:.2f}ms "
             f"p99={r['p99_ms']:.2f}ms max={r['max_ms']:.2f}ms")
    capped = eng_rows[1]
    bound = capped["p99_ms"] / max(capped["decode_p99_ms"], 1e-9)
    uncapped_bound = eng_rows[0]["p99_ms"] / max(
        eng_rows[0]["decode_p99_ms"], 1e-9)
    emit("fig_preempt.engine.p99_over_decode", 0.0,
         f"capped={bound:.2f}x uncapped={uncapped_bound:.2f}x")
    if not SMOKE:
        # acceptance: budgeted iterations stay within ~2x a pure-decode
        # iteration even while 512-token prompts are arriving
        assert bound <= 2.0, f"chunked p99 bound violated: {bound:.2f}x"

    pool_rows = [_run_pool_panel(False), _run_pool_panel(True)]
    for r in pool_rows:
        emit(f"fig_preempt.pool.preempt{int(r['preemption'])}", 0.0,
             f"urgent_slo={r['urgent_slo_attainment']:.2f} "
             f"p99={r['urgent_p99_ms']:.0f}ms "
             f"n_preempted={r['n_preempted']} "
             f"identical={r['hog_token_identical']}")
    assert pool_rows[1]["hog_token_identical"], \
        "preempt-resume output diverged from the uninterrupted run"
    if not SMOKE:
        assert pool_rows[1]["n_preempted"] > 0, "preemption never fired"
        assert pool_rows[1]["urgent_slo_attainment"] >= \
            pool_rows[0]["urgent_slo_attainment"], \
            "preemption did not improve tight-SLO attainment"

    os.makedirs(OUT_DIR, exist_ok=True)
    payload = {"engine": eng_rows, "p99_over_decode_p99": bound,
               "pool": pool_rows, "token_budget": TOKEN_BUDGET,
               "long_prompt": LONG_PROMPT, "hog_tokens": HOG_TOKENS,
               "urgent_slo_ms": URGENT_SLO_MS}
    json_path = os.path.join(OUT_DIR, "fig_preemption_chunked.json")
    with open(json_path, "w") as f:
        json.dump(payload, f, indent=2)
    emit("fig_preempt.json", 0.0, json_path)
    png_path = os.path.join(OUT_DIR, "fig_preemption_chunked.png")
    if _plot(eng_rows, pool_rows, png_path):
        emit("fig_preempt.plot", 0.0, png_path)
    return payload


if __name__ == "__main__":
    main()
