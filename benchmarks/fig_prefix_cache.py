"""Beyond-paper figure: prefix caching with copy-on-write block sharing
(docs/ARCHITECTURE.md §5; recipe + expected numbers in
docs/EXPERIMENTS.md §Prefix cache).

Templated edge workload: every prompt is a 512-token shared prefix (a
system prompt / task preamble) plus a short per-request tail — the
regime where duplicated prefix KV is the dominant memory waste. Two
engines under the SAME tight block budget drain the same burst:

1. **no-cache baseline** — every request re-prefills and physically
   stores the full prompt, so the budget caps concurrent residency at
   ``budget / request_blocks``;
2. **prefix cache** — full immutable prompt blocks are shared at
   refcount+1 (copy-on-write tails, LRU revival of evicted blocks), so
   after the first request each admission charges only its private
   tail + decode blocks.

Asserted (the PR's acceptance bar):
  * >= 2x peak admission capacity (concurrently resident sequences),
  * >= 2x prefill-token reduction (chunked-prefill work actually run),
  * greedy outputs token-identical per request across the two engines.

Artifacts: ``benchmarks/out/fig_prefix_cache.json`` (always) and
``benchmarks/out/fig_prefix_cache.png`` (when matplotlib is available).

Run:  PYTHONPATH=src python -m benchmarks.fig_prefix_cache
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import FAST, SMOKE, emit
from repro.config.base import ModelConfig
from repro.serving.engine import ContinuousBatchingEngine

OUT_DIR = os.path.join(os.path.dirname(__file__), "out")

TINY = ModelConfig(name="tiny-prefix", family="dense", n_layers=2,
                   d_model=64, n_heads=2, n_kv_heads=2, d_ff=128,
                   vocab_size=211)

BLOCK_SIZE = 16
PREFIX_TOKENS = 512        # the acceptance point: 512-token shared prefix
TAIL_TOKENS = 16           # per-request unique tail (fixed length:
#                            left-padding makes sharing length-sensitive)
MAX_NEW = 16
MAX_SEQ = 704              # prompt bucket 640 + decode room
MAX_SLOTS = 8
BUDGET_BLOCKS = 96         # ~2.3 no-cache requests' worth of blocks
N_REQUESTS = 12


def _workload(seed: int = 0):
    rng = np.random.default_rng(seed)
    prefix = rng.integers(1, TINY.vocab_size, PREFIX_TOKENS).astype(
        np.int32)
    return [np.concatenate(
        [prefix, rng.integers(1, TINY.vocab_size, TAIL_TOKENS).astype(
            np.int32)]) for _ in range(N_REQUESTS)]


def _run(prefix_cache: bool, prompts, share_from=None):
    eng = ContinuousBatchingEngine(
        TINY, max_slots=MAX_SLOTS, max_seq=MAX_SEQ, seed=0,
        share_from=share_from, kv_layout="paged", block_size=BLOCK_SIZE,
        kv_blocks=BUDGET_BLOCKS, prefix_cache=prefix_cache)
    # seed request: with the cache on it publishes the prefix blocks;
    # the baseline pays the same warmup, so the comparison stays fair
    eng.run([prompts[0]], max_new_tokens=MAX_NEW)
    for p in prompts:
        eng.submit(p, max_new_tokens=MAX_NEW)
    chunk0 = eng.n_prefill_chunk_tokens
    peak_resident = 0
    peak_shared = 0.0
    outputs = {}
    t0 = time.perf_counter()
    while (eng.waiting or eng.active_slots) and eng.n_iters < 20_000:
        for r in eng.step():
            outputs[r.request_id] = r.tokens
        peak_resident = max(peak_resident, len(eng.active_slots))
        peak_shared = max(peak_shared, eng.stats()["kv_shared_frac"])
    dur_s = time.perf_counter() - t0
    assert len(outputs) == N_REQUESTS, \
        f"{len(outputs)}/{N_REQUESTS} drained"
    s = eng.stats()
    return eng, {
        "prefix_cache": prefix_cache,
        "budget_blocks": BUDGET_BLOCKS,
        "peak_resident": peak_resident,
        "prefill_tokens": int(eng.n_prefill_chunk_tokens - chunk0),
        "prefix_hit_rate": s["prefix_hit_rate"],
        "peak_kv_shared_frac": peak_shared,
        "kv_waste_frac": s["kv_waste_frac"],
        "makespan_s": dur_s,
        "throughput_rps": N_REQUESTS / max(dur_s, 1e-6),
        "outputs": outputs,
    }


def _plot(rows: list, path: str) -> bool:
    try:
        import matplotlib
        matplotlib.use("Agg")
        import matplotlib.pyplot as plt
    except Exception:  # noqa: BLE001
        return False
    fig, axes = plt.subplots(1, 3, figsize=(11, 3.3))
    labels = ["no cache", "prefix cache"]
    axes[0].bar(labels, [r["peak_resident"] for r in rows],
                color=["#888", "#2a7"])
    axes[0].set_title("peak resident sequences\n(same block budget)")
    axes[1].bar(labels, [r["prefill_tokens"] for r in rows],
                color=["#888", "#2a7"])
    axes[1].set_title("prefill tokens actually run")
    axes[2].bar(labels, [r["peak_kv_shared_frac"] for r in rows],
                color=["#888", "#2a7"])
    axes[2].set_title("peak shared-block fraction")
    fig.suptitle(
        f"{PREFIX_TOKENS}-token shared prefixes, "
        f"{BUDGET_BLOCKS * BLOCK_SIZE}-token KV budget")
    fig.tight_layout()
    fig.savefig(path, dpi=120)
    plt.close(fig)
    return True


def main(fast: bool = FAST) -> dict:
    global PREFIX_TOKENS, TAIL_TOKENS, MAX_SEQ, BUDGET_BLOCKS, N_REQUESTS
    if SMOKE:
        # toy scale: the code paths, not the numbers
        PREFIX_TOKENS, TAIL_TOKENS = 48, 8
        MAX_SEQ, BUDGET_BLOCKS, N_REQUESTS = 128, 24, 4
    prompts = _workload()
    base_eng, base = _run(False, prompts)
    _, cached = _run(True, prompts, share_from=base_eng)

    # token identity: per request id (submission order matches)
    for rid, toks in base.pop("outputs").items():
        assert np.array_equal(toks, cached["outputs"][rid]), \
            f"request {rid}: cached output diverges from baseline"
    cached.pop("outputs")

    cap_ratio = cached["peak_resident"] / max(1, base["peak_resident"])
    prefill_ratio = base["prefill_tokens"] \
        / max(1, cached["prefill_tokens"])
    for row in (base, cached):
        label = "cached" if row["prefix_cache"] else "baseline"
        emit(f"fig_prefix.{label}", 0.0,
             f"resident={row['peak_resident']} "
             f"prefill_tokens={row['prefill_tokens']} "
             f"hit={row['prefix_hit_rate']:.2f} "
             f"shared={row['peak_kv_shared_frac']:.2f}")
    emit("fig_prefix.capacity_ratio", 0.0, f"{cap_ratio:.2f}x")
    emit("fig_prefix.prefill_reduction", 0.0, f"{prefill_ratio:.2f}x")
    if not SMOKE:
        # the PR's acceptance bar (docs/EXPERIMENTS.md §Prefix cache)
        assert cap_ratio >= 2.0, \
            f"admission capacity gain {cap_ratio:.2f}x < 2x"
        assert prefill_ratio >= 2.0, \
            f"prefill-token reduction {prefill_ratio:.2f}x < 2x"

    os.makedirs(OUT_DIR, exist_ok=True)
    payload = {"prefix_tokens": PREFIX_TOKENS, "tail_tokens": TAIL_TOKENS,
               "block_size": BLOCK_SIZE, "max_new_tokens": MAX_NEW,
               "budget_blocks": BUDGET_BLOCKS, "n_requests": N_REQUESTS,
               "rows": [base, cached], "capacity_ratio": cap_ratio,
               "prefill_reduction": prefill_ratio,
               "token_identical": True}
    json_path = os.path.join(OUT_DIR, "fig_prefix_cache.json")
    with open(json_path, "w") as f:
        json.dump(payload, f, indent=2)
    emit("fig_prefix.json", 0.0, json_path)
    png_path = os.path.join(OUT_DIR, "fig_prefix_cache.png")
    if _plot([base, cached], png_path):
        emit("fig_prefix.plot", 0.0, png_path)
    return payload


if __name__ == "__main__":
    main()
