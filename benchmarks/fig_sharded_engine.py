"""Beyond-paper figure: what TP degree buys a KV-tight pool
(docs/RUNTIME.md §10; recipe in docs/EXPERIMENTS.md §Sharded engine).

The scheduler's fifth axis is the TP degree: a degree-d instance spans
d devices of the shared set, head-sharding its paged block pool over
the mesh's model axis. Because each KV block is spread over d devices,
the pool charges a degree-d instance only ``ceil(grant/d)`` blocks of
the SHARED per-device budget while the engine keeps the full grant —
one budget block buys d pool blocks. On a budget-bound pool that is
the capacity the guard trades against the collective surcharge
(``tp_collective_ms_per_token``) when it prices the layout.

Measured panel (subprocess — the forced-host device flag must predate
the jax import, and ``benchmarks/run.py`` imports every figure into
one process): two pools drain the SAME decode-heavy trace under the
same tight ``kv_block_budget``, one pinned to the tp_degrees=(1,)
layout, one at the tp_degree=2 layout the guard may now pick. Engine
admission reserves worst-case blocks per request, so the tp=1 pool
holds half the residents and its queue waits double; goodput (requests
served within SLO per second) and per-request Eq.-3 utility — both
computed from wall-clock latency including queue wait — improve at
tp=2 despite the slower sharded step. Each layout drains the trace
``N_REPS`` times (interleaved, pools reused so compiles stay out of
the measured region) and the median drain is reported.

Analytic panel (in-process): per-degree KV capacity multiplier and the
collective surcharge for the 7B-ish shape from
``roofline_table.TP_SHAPES``, showing the trade the guard prices.

Asserted (the PR's acceptance bar, skipped in SMOKE): tp=2 goodput
AND mean utility strictly above the tp_degrees=(1,) layout on the
same trace.

Artifacts: ``benchmarks/out/fig_sharded_engine.json`` (always) and
``benchmarks/out/fig_sharded_engine.png`` (when matplotlib is there).

Run:  PYTHONPATH=src python -m benchmarks.fig_sharded_engine
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks.common import FAST, SMOKE, emit
from benchmarks.roofline_table import TP_SHAPES
from repro.serving.bcedge import tp_collective_ms_per_token

OUT_DIR = os.path.join(os.path.dirname(__file__), "out")
SRC = os.path.join(os.path.dirname(__file__), "..", "src")

N_REQ = 32
MAX_NEW = 40
PROMPT_LEN = 24
KV_BUDGET = 40          # blocks — tight: the binding resource
SLO_MS = 600_000.0      # generous: goodput == drained throughput
N_REPS = 5              # interleaved drains per layout; median reported

_CODE_BODY = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import json
import numpy as np
from repro.config.base import ModelConfig
from repro.serving.runtime import ModelInstancePool

TINY = ModelConfig(name="tiny-tp", family="dense", n_layers=2,
                   d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
                   vocab_size=97)
RNG = np.random.default_rng(3)


def make_pool(tp):
    pool = ModelInstancePool({"tiny-tp": TINY}, max_instances=2,
                             max_slots=12, max_seq=128,
                             kv_layout="paged", block_size=8,
                             kv_block_budget=P["kv_budget"],
                             tp_degree=tp, n_devices=2, seed=0)
    assert pool.scale_to("tiny-tp", 1) == 1
    # warm pass compiles prefill/decode for this layout: every
    # measured drain below reuses the pool, so compile time never
    # lands inside a measured makespan
    for _ in range(2):
        pool.submit("tiny-tp", RNG.integers(1, 97, P["prompt_len"])
                    .astype(np.int32), slo_ms=P["slo_ms"],
                    max_new_tokens=P["max_new"])
    pool.run_until_drained()
    return pool


def drain_once(pool):
    rids = {pool.submit("tiny-tp",
                        RNG.integers(1, 97, P["prompt_len"])
                        .astype(np.int32), slo_ms=P["slo_ms"],
                        max_new_tokens=P["max_new"])
            for _ in range(P["n_req"])}
    pool.run_until_drained(max_steps=50_000)
    rs = [r for r in pool.results("tiny-tp") if r.request_id in rids]
    assert len(rs) == P["n_req"] and not any(r.rejected for r in rs)
    makespan = max(r.finish_s for r in rs) - min(r.submit_s for r in rs)
    good = [r for r in rs if not r.violated]
    lat = [r.latency_ms for r in rs]
    return {"goodput_rps": len(good) / makespan,
            "mean_utility": float(np.mean([r.utility for r in rs])),
            "mean_latency_ms": float(np.mean(lat)),
            "p95_latency_ms": float(np.percentile(lat, 95)),
            "makespan_s": makespan}


def summarize(pool, reps):
    # per-metric median over the drains: one slow-machine blip cannot
    # flip the layout comparison
    med = {k: float(np.median([r[k] for r in reps])) for k in reps[0]}
    inst = pool.running("tiny-tp")[0]
    per_req = inst.engine.request_blocks(P["prompt_len"], P["max_new"])
    out = dict(med)
    out.update({
        "tp": inst.tp_degree,
        "kv_charge_blocks": inst.kv_blocks,
        "kv_pool_blocks": inst.engine.allocator.n_blocks,
        "resident_capacity": inst.engine.allocator.n_blocks // per_req,
        "reps": reps})
    return out


# interleave the layouts' drains so machine-load drift hits both
pools = {1: make_pool(1), 2: make_pool(2)}
reps = {1: [], 2: []}
for _ in range(P["n_reps"]):
    for tp in (1, 2):
        reps[tp].append(drain_once(pools[tp]))
out = {"tp1": summarize(pools[1], reps[1]),
       "tp2": summarize(pools[2], reps[2])}
print("RESULT " + json.dumps(out))
"""


def _measure() -> dict:
    params = {"n_req": N_REQ, "max_new": MAX_NEW,
              "prompt_len": PROMPT_LEN, "kv_budget": KV_BUDGET,
              "slo_ms": SLO_MS, "n_reps": N_REPS}
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("XLA_FLAGS", None)
    code = f"P = {json.dumps(params)}\n" + _CODE_BODY
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=1200)
    assert out.returncode == 0, out.stderr[-3000:]
    for line in out.stdout.splitlines():
        if line.startswith("RESULT "):
            return json.loads(line[len("RESULT "):])
    raise AssertionError(f"no RESULT line in:\n{out.stdout[-2000:]}")


def _analytic() -> list:
    """Per-degree capacity multiplier vs collective surcharge for the
    7B server shape — the two sides of the guard's layout price."""
    label, cfg, b, ctx = TP_SHAPES[0]
    return [{"shape": label, "tp": d, "kv_capacity_x": float(d),
             "collective_ms_per_token": tp_collective_ms_per_token(cfg, d)}
            for d in (1, 2, 4, 8)]


def _plot(meas: dict, path: str) -> bool:
    try:
        import matplotlib
        matplotlib.use("Agg")
        import matplotlib.pyplot as plt
    except Exception:  # noqa: BLE001
        return False
    fig, (ax, ax2) = plt.subplots(1, 2, figsize=(9, 4))
    labels = ["tp_degrees=(1,)", "guard picks tp=2"]
    colors = ["#888", "#2a7"]
    rows = [meas["tp1"], meas["tp2"]]
    ax.bar(labels, [r["goodput_rps"] for r in rows], color=colors)
    for i, r in enumerate(rows):
        ax.text(i, r["goodput_rps"],
                f"{r['resident_capacity']} resident\n"
                f"{r['kv_pool_blocks']} blocks", ha="center", va="bottom")
    ax.set_ylabel("goodput (req/s within SLO)")
    ax.set_title("same trace, same shared KV budget")
    ax2.bar(labels, [r["mean_latency_ms"] for r in rows], color=colors)
    ax2.set_ylabel("mean latency ms (incl. queue wait)")
    ax2.set_title("queue wait under the block budget")
    fig.suptitle("TP degree as a scheduler axis on a KV-tight pool")
    fig.tight_layout()
    fig.savefig(path, dpi=120)
    plt.close(fig)
    return True


def main(fast: bool = FAST) -> dict:
    global N_REQ, MAX_NEW, N_REPS
    if SMOKE:
        # toy scale: the code paths, not the numbers
        N_REQ, MAX_NEW, N_REPS = 6, 8, 1

    meas = _measure()
    t1, t2 = meas["tp1"], meas["tp2"]
    for k, r in (("tp1", t1), ("tp2", t2)):
        emit(f"fig_sharded.{k}", 0.0,
             f"goodput={r['goodput_rps']:.2f}rps "
             f"utility={r['mean_utility']:.3f} "
             f"lat={r['mean_latency_ms']:.0f}ms "
             f"residents={r['resident_capacity']} "
             f"blocks={r['kv_pool_blocks']}")
    emit("fig_sharded.gain", 0.0,
         f"goodput={t2['goodput_rps']/max(t1['goodput_rps'],1e-9):.2f}x "
         f"capacity={t2['kv_pool_blocks']}/{t1['kv_pool_blocks']}blocks")
    if not SMOKE:
        # the PR's acceptance bar (docs/EXPERIMENTS.md §Sharded engine)
        assert t2["goodput_rps"] > t1["goodput_rps"], \
            f"tp=2 goodput {t2['goodput_rps']:.3f} not above " \
            f"tp_degrees=(1,) {t1['goodput_rps']:.3f}"
        assert t2["mean_utility"] > t1["mean_utility"], \
            f"tp=2 utility {t2['mean_utility']:.4f} not above " \
            f"tp_degrees=(1,) {t1['mean_utility']:.4f}"

    arows = _analytic()
    emit("fig_sharded.analytic", 0.0,
         f"{arows[0]['shape']}: " + " ".join(
             f"tp{r['tp']}={r['collective_ms_per_token']*1e3:.0f}us/tok"
             for r in arows[1:]))

    os.makedirs(OUT_DIR, exist_ok=True)
    payload = {"n_req": N_REQ, "max_new": MAX_NEW,
               "prompt_len": PROMPT_LEN, "kv_budget": KV_BUDGET,
               "measured": meas, "analytic": arows}
    json_path = os.path.join(OUT_DIR, "fig_sharded_engine.json")
    with open(json_path, "w") as f:
        json.dump(payload, f, indent=2)
    emit("fig_sharded.json", 0.0, json_path)
    png_path = os.path.join(OUT_DIR, "fig_sharded_engine.png")
    if _plot(meas, png_path):
        emit("fig_sharded.plot", 0.0, png_path)
    return payload


if __name__ == "__main__":
    main()
