"""Beyond-paper figure: self-speculative decoding with batched parallel
verification (docs/ARCHITECTURE.md §speculation; recipe + expected
numbers in docs/EXPERIMENTS.md §Speculative decoding).

Decode-heavy repetitive trace — the prompt-lookup regime: short
periodic prompts whose greedy continuations settle into repeating
motifs (templated/boilerplate generation). A screening pass generates a
few tokens per candidate with the k=0 engine and keeps the prompts
whose output tail is periodic, so the measured trace is honestly
drawn from the baseline's own behaviour, not hand-picked token ids.
Two engines share weights and drain the same trace:

1. **k=0 baseline** — one committed token per slot per iteration;
2. **speculative k=4** — the n-gram proposer drafts up to 4 tokens per
   slot from the sequence's own history; ONE verify forward over the
   paged cache scores all drafts; the longest matching prefix commits
   and rejected tail blocks roll back at block granularity.

Asserted (the PR's acceptance bar):
  * >= 1.5x decode throughput (tokens/s over the drain),
  * greedy outputs token-identical per request across the two engines.

Artifacts: ``benchmarks/out/fig_speculative.json`` (always) and
``benchmarks/out/fig_speculative.png`` (when matplotlib is available).

Run:  PYTHONPATH=src python -m benchmarks.fig_speculative
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import FAST, SMOKE, emit
from repro.config.base import ModelConfig
from repro.serving.engine import ContinuousBatchingEngine

OUT_DIR = os.path.join(os.path.dirname(__file__), "out")

TINY = ModelConfig(name="tiny-spec", family="dense", n_layers=2,
                   d_model=64, n_heads=2, n_kv_heads=2, d_ff=128,
                   vocab_size=211)

BLOCK_SIZE = 16
SPEC_K = 4
PROMPT_TOKENS = 16         # short prompts: the trace is decode-heavy
MAX_NEW = 160              # long continuations amortize the screen
MAX_SEQ = 256
MAX_SLOTS = 4
N_REQUESTS = 8
N_CANDIDATES = 32          # screened down to the periodic-output subset
SCREEN_TOKENS = 48         # screening generation length
TAIL_PERIOD_MAX = 4        # "periodic" = tail repeats with period <= 4


def _tail_period(tokens, tail: int = 24, max_p: int = TAIL_PERIOD_MAX):
    """Smallest period of the trailing ``tail`` tokens, or None."""
    t = list(tokens)[-tail:]
    for p in range(1, max_p + 1):
        if len(t) > p and all(t[i] == t[i + p] for i in range(len(t) - p)):
            return p
    return None


def _workload(base: ContinuousBatchingEngine, seed: int = 1):
    """Screen periodic-motif candidate prompts with the BASELINE engine
    and keep those whose greedy continuation is itself periodic — then
    tile the survivors to ``N_REQUESTS`` streams (a templated workload
    re-issues the same prompts; each copy still occupies its own slot
    and pays its own decode)."""
    rng = np.random.default_rng(seed)
    cands = []
    for _ in range(N_CANDIDATES):
        motif = rng.integers(1, TINY.vocab_size, int(rng.integers(2, 5)))
        reps = int(np.ceil(PROMPT_TOKENS / len(motif)))
        cands.append(np.tile(motif, reps)[:PROMPT_TOKENS].astype(np.int32))
    screened = base.run(cands, max_new_tokens=SCREEN_TOKENS)
    periods = {r.request_id: _tail_period(r.tokens) for r in screened}
    sel = sorted((rid for rid, p in periods.items() if p is not None),
                 key=lambda rid: periods[rid])   # shortest period first
    assert sel, "no candidate produced a periodic continuation"
    return [cands[sel[i % len(sel)]] for i in range(N_REQUESTS)]


def _run(spec_k: int, prompts, share_from):
    eng = ContinuousBatchingEngine(
        TINY, max_slots=MAX_SLOTS, max_seq=MAX_SEQ, seed=0,
        share_from=share_from, kv_layout="paged", block_size=BLOCK_SIZE,
        spec_k=spec_k)
    # warm the verify/decode compile for the measured shapes
    eng.run(prompts[:2], max_new_tokens=4)
    for p in prompts:
        eng.submit(p, max_new_tokens=MAX_NEW)
    outputs = {}
    t0 = time.perf_counter()
    while (eng.waiting or eng.active_slots) and eng.n_iters < 50_000:
        for r in eng.step():
            outputs[r.request_id] = r.tokens
    dur_s = time.perf_counter() - t0
    assert len(outputs) == N_REQUESTS, \
        f"{len(outputs)}/{N_REQUESTS} drained"
    n_tokens = sum(len(t) for t in outputs.values())
    s = eng.stats()
    al = eng.allocator
    if al is not None:   # rollback must leave the pool conserved
        assert al.n_live == 0 and al.n_reserved == 0
        assert al.n_free + al.n_cached == al.n_blocks
    return {
        "spec_k": spec_k,
        "tokens": n_tokens,
        "iters": int(s["n_iters"]),
        "accept_rate": s["spec_accept_rate"],
        "proposed": int(s["n_spec_proposed"]),
        "accepted": int(s["n_spec_accepted"]),
        "makespan_s": dur_s,
        "tokens_per_s": n_tokens / max(dur_s, 1e-6),
        "outputs": outputs,
    }


def _plot(rows: list, path: str) -> bool:
    try:
        import matplotlib
        matplotlib.use("Agg")
        import matplotlib.pyplot as plt
    except Exception:  # noqa: BLE001
        return False
    fig, axes = plt.subplots(1, 3, figsize=(11, 3.3))
    labels = [f"k={r['spec_k']}" for r in rows]
    axes[0].bar(labels, [r["tokens_per_s"] for r in rows],
                color=["#888", "#2a7"])
    axes[0].set_title("decode throughput (tokens/s)")
    axes[1].bar(labels, [r["iters"] for r in rows],
                color=["#888", "#2a7"])
    axes[1].set_title("engine iterations to drain")
    axes[2].bar(labels, [r["accept_rate"] for r in rows],
                color=["#888", "#2a7"])
    axes[2].set_title("draft acceptance rate")
    fig.suptitle(
        f"self-speculative decoding, k={SPEC_K}, "
        f"{N_REQUESTS}x{MAX_NEW}-token decode-heavy trace")
    fig.tight_layout()
    fig.savefig(path, dpi=120)
    plt.close(fig)
    return True


def main(fast: bool = FAST) -> dict:
    global MAX_NEW, N_REQUESTS, N_CANDIDATES, SCREEN_TOKENS
    if SMOKE:
        # toy scale: the code paths, not the numbers
        MAX_NEW, N_REQUESTS = 24, 4
        N_CANDIDATES, SCREEN_TOKENS = 8, 24
    base_eng = ContinuousBatchingEngine(
        TINY, max_slots=MAX_SLOTS, max_seq=MAX_SEQ, seed=0,
        kv_layout="paged", block_size=BLOCK_SIZE)
    try:
        prompts = _workload(base_eng)
    except AssertionError:
        if not SMOKE:
            raise
        # toy screen may find nothing; the code path is what SMOKE runs
        rng = np.random.default_rng(1)
        prompts = [rng.integers(1, TINY.vocab_size, PROMPT_TOKENS)
                   .astype(np.int32) for _ in range(N_REQUESTS)]
    base = _run(0, prompts, share_from=base_eng)
    spec = _run(SPEC_K, prompts, share_from=base_eng)

    # token identity: per request id (submission order matches)
    for rid, toks in base.pop("outputs").items():
        assert np.array_equal(toks, spec["outputs"][rid]), \
            f"request {rid}: speculative output diverges from baseline"
    spec.pop("outputs")

    speedup = spec["tokens_per_s"] / max(base["tokens_per_s"], 1e-9)
    for row in (base, spec):
        label = f"k{row['spec_k']}"
        emit(f"fig_spec.{label}", 0.0,
             f"tok/s={row['tokens_per_s']:.0f} iters={row['iters']} "
             f"accept={row['accept_rate']:.2f}")
    emit("fig_spec.speedup", 0.0, f"{speedup:.2f}x")
    if not SMOKE:
        # the PR's acceptance bar (docs/EXPERIMENTS.md §Speculative)
        assert speedup >= 1.5, \
            f"speculative tokens/s gain {speedup:.2f}x < 1.5x"

    os.makedirs(OUT_DIR, exist_ok=True)
    payload = {"spec_k": SPEC_K, "prompt_tokens": PROMPT_TOKENS,
               "max_new_tokens": MAX_NEW, "block_size": BLOCK_SIZE,
               "n_requests": N_REQUESTS, "rows": [base, spec],
               "speedup": speedup, "token_identical": True}
    json_path = os.path.join(OUT_DIR, "fig_speculative.json")
    with open(json_path, "w") as f:
        json.dump(payload, f, indent=2)
    emit("fig_spec.json", 0.0, json_path)
    png_path = os.path.join(OUT_DIR, "fig_speculative.png")
    if _plot([base, spec], png_path):
        emit("fig_spec.plot", 0.0, png_path)
    return payload


if __name__ == "__main__":
    main()
