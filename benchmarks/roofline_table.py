"""docs/EXPERIMENTS.md §Roofline: render the per-(arch x shape x mesh) table
from the dry-run JSON artifacts in experiments/dryrun*/."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit

V5E_HBM_GB = 16.0


def load_results(dirname: str):
    rows = []
    for path in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def render_table(rows, fit_budget_gb: float = V5E_HBM_GB) -> str:
    lines = [
        "| arch | shape | mesh | GiB/dev | fits | compute ms | memory ms |"
        " collective ms | dominant | useful FLOP ratio |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r["status"] == "skipped":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — |"
                f" — | — | skip: {r['reason'][:40]} | — |")
            continue
        if r["status"] != "ok":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | ERROR |"
                f" {r['error'][:40]} | | | | | |")
            continue
        gib = r["bytes_per_device"] / 2 ** 30
        t = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {gib:.2f} |"
            f" {'Y' if gib <= fit_budget_gb else 'N'} |"
            f" {t['compute_s']*1e3:.2f} | {t['memory_s']*1e3:.2f} |"
            f" {t['collective_s']*1e3:.2f} |"
            f" {r['dominant'].split('_')[0]} |"
            f" {r['useful_flops_ratio']:.2f} |")
    return "\n".join(lines)


def main(fast: bool = True) -> dict:
    base = os.path.join(os.getcwd(), "experiments", "dryrun")
    rows = load_results(base)
    if not rows:
        emit("roofline.table", 0.0, "no dry-run artifacts found; run "
             "PYTHONPATH=src python -m repro.launch.dryrun --all first")
        return {}
    ok = [r for r in rows if r["status"] == "ok"]
    fit = sum(1 for r in ok
              if r["bytes_per_device"] / 2 ** 30 <= V5E_HBM_GB)
    dominant = {}
    for r in ok:
        dominant[r["dominant"]] = dominant.get(r["dominant"], 0) + 1
    emit("roofline.summary", 0.0,
         f"cases={len(rows)} ok={len(ok)} "
         f"fits_16GiB={fit}/{len(ok)} dominant={dominant}")
    print(render_table(rows))
    return {"rows": rows}


if __name__ == "__main__":
    main()
