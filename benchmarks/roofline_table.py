"""docs/EXPERIMENTS.md §Roofline: render the per-(arch x shape x mesh) table
from the dry-run JSON artifacts in experiments/dryrun*/, plus the
analytic roofline for the fused paged-attention kernels
(``paged_prefill_attention`` chunk prefill and
``paged_decode_attention_splitk``) at representative serving shapes."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit
from repro.config.base import InputShape, ModelConfig
from repro.launch.roofline import (HBM_BW, ICI_BW, PEAK_FLOPS,
                                   workload_cost)
from repro.serving.bcedge import tp_collective_ms_per_token

V5E_HBM_GB = 16.0


def load_results(dirname: str):
    rows = []
    for path in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def render_table(rows, fit_budget_gb: float = V5E_HBM_GB) -> str:
    lines = [
        "| arch | shape | mesh | GiB/dev | fits | compute ms | memory ms |"
        " collective ms | dominant | useful FLOP ratio |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r["status"] == "skipped":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — |"
                f" — | — | skip: {r['reason'][:40]} | — |")
            continue
        if r["status"] != "ok":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | ERROR |"
                f" {r['error'][:40]} | | | | | |")
            continue
        gib = r["bytes_per_device"] / 2 ** 30
        t = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {gib:.2f} |"
            f" {'Y' if gib <= fit_budget_gb else 'N'} |"
            f" {t['compute_s']*1e3:.2f} | {t['memory_s']*1e3:.2f} |"
            f" {t['collective_s']*1e3:.2f} |"
            f" {r['dominant'].split('_')[0]} |"
            f" {r['useful_flops_ratio']:.2f} |")
    return "\n".join(lines)


# =====================================================================
# fused paged-attention kernels (src/repro/kernels): analytic roofline
# =====================================================================
#: (label, batch, q_heads, kv_heads, head_dim, context, chunk, n_splits)
#: — a 7B-ish server shape and a small edge shape, long and short ctx
KERNEL_SHAPES = [
    ("edge-short", 1, 8, 8, 64, 512, 64, 4),
    ("edge-long", 4, 8, 8, 64, 2048, 64, 8),
    ("7b-decode", 8, 32, 8, 128, 2048, 128, 8),
    ("7b-batch", 16, 32, 8, 128, 1024, 128, 4),
]
KV_DTYPE_BYTES = 2  # bf16 pool


def kernel_rows(shapes=KERNEL_SHAPES):
    """Analytic roofline per kernel per shape (single chip).

    * decode (split-K): FLOPs = 4*B*H*ctx*hd (QK^T + PV, 2 flops/MAC),
      HBM = the K+V stream over the live context; the split axis divides
      the serial KV stream across ``n_splits`` cores at the price of a
      partial-output combine (n_splits f32 partials per (b, h)).
    * prefill (chunk T over block tables): FLOPs = 4*B*H*T*ctx*hd, HBM =
      one K+V stream + the chunk's own KV write. The STAGING round trip
      this kernel replaces moved prefix KV three extra times (pool ->
      staging gather, staging attention re-read, staging -> pool graft),
      reported as ``staging_bytes`` for the traffic-saved column.
    """
    rows = []
    for label, b, h, kv, hd, ctx, chunk, n_splits in shapes:
        kv_stream = 2 * b * kv * ctx * hd * KV_DTYPE_BYTES
        # ---- split-K decode
        flops = 4.0 * b * h * ctx * hd
        combine = b * h * hd * n_splits * 4 * 2  # write + read partials
        serial_ms = max(flops / PEAK_FLOPS, kv_stream / HBM_BW) * 1e3
        splitk_ms = max(flops / PEAK_FLOPS,
                        (kv_stream / n_splits + combine) / HBM_BW) * 1e3
        rows.append({
            "kernel": "paged_decode_splitk", "shape": label,
            "ctx": ctx, "flops": flops, "hbm_bytes": kv_stream + combine,
            "intensity": flops / (kv_stream + combine),
            "serial_ms": serial_ms, "latency_ms": splitk_ms,
            "n_splits": n_splits, "bound": "memory"
            if kv_stream / HBM_BW > flops / PEAK_FLOPS else "compute"})
        # ---- fused chunk prefill
        pflops = 4.0 * b * h * chunk * ctx * hd
        chunk_write = 2 * b * kv * chunk * hd * KV_DTYPE_BYTES
        fused_bytes = kv_stream + chunk_write
        staging_bytes = fused_bytes + 3 * kv_stream  # the deleted trips
        pf_ms = max(pflops / PEAK_FLOPS, fused_bytes / HBM_BW) * 1e3
        rows.append({
            "kernel": "paged_prefill", "shape": label, "ctx": ctx,
            "flops": pflops, "hbm_bytes": fused_bytes,
            "intensity": pflops / fused_bytes, "serial_ms": pf_ms,
            "latency_ms": pf_ms, "n_splits": 1,
            "staging_bytes": staging_bytes, "bound": "memory"
            if fused_bytes / HBM_BW > pflops / PEAK_FLOPS else "compute"})
    return rows


def render_kernel_table(rows) -> str:
    lines = [
        "| kernel | shape | ctx | GFLOP | MiB | FLOP/B | bound |"
        " latency us | vs serial | staging traffic |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        speedup = r["serial_ms"] / max(r["latency_ms"], 1e-12)
        staging = f"{r['staging_bytes'] / 2**20:.1f} MiB" \
            if "staging_bytes" in r else "—"
        lines.append(
            f"| {r['kernel']} | {r['shape']} | {r['ctx']} |"
            f" {r['flops']/1e9:.2f} | {r['hbm_bytes']/2**20:.1f} |"
            f" {r['intensity']:.1f} | {r['bound']} |"
            f" {r['latency_ms']*1e3:.1f} |"
            f" {speedup:.2f}x (K={r['n_splits']}) | {staging} |")
    return "\n".join(lines)


# =====================================================================
# tensor-parallel collectives: analytic TP trade-off per degree
# =====================================================================
#: (label, ModelConfig, batch, context) — the decode shapes the sharded
#: engine serves; a 7B-ish server model and a 1B-ish edge model
TP_SHAPES = [
    ("7b-decode",
     ModelConfig(name="tp-7b", family="dense", n_layers=32, d_model=4096,
                 n_heads=32, n_kv_heads=8, d_ff=11008, vocab_size=32_000),
     8, 2048),
    ("edge-1b",
     ModelConfig(name="tp-1b", family="dense", n_layers=16, d_model=2048,
                 n_heads=16, n_kv_heads=16, d_ff=5632, vocab_size=32_000),
    4, 1024),
]
TP_DEGREES = (1, 2, 4, 8)


def tp_rows(shapes=TP_SHAPES, degrees=TP_DEGREES):
    """Analytic decode-step roofline per TP degree.

    compute/memory come from the shared ``workload_cost`` model divided
    over ``d`` chips (``WorkloadCost.terms``); collective bytes are the
    per-token psum payload the guard prices
    (``serving.bcedge.tp_collective_ms_per_token``: 2 ring all-reduces
    of the (d_model,) residual per layer, ``2(d-1)/d`` bf16 bytes per
    chip) times the batch. Step latency = max(compute, memory) +
    collective; the speedup column shows where extra chips stop paying
    for the wire.
    """
    rows = []
    for label, cfg, b, ctx in shapes:
        shape = InputShape(f"tp-{label}", ctx, b, "decode")
        cost = workload_cost(cfg, shape)
        base_ms = None
        for d in degrees:
            coll_per_chip = b * tp_collective_ms_per_token(cfg, d) \
                * ICI_BW / 1000.0  # back to bytes for terms()
            t = cost.terms(d, coll_per_chip)
            step_ms = (max(t["compute_s"], t["memory_s"])
                       + t["collective_s"]) * 1e3
            if base_ms is None:
                base_ms = step_ms
            rows.append({
                "shape": label, "tp": d, "batch": b, "ctx": ctx,
                "compute_ms": t["compute_s"] * 1e3,
                "memory_ms": t["memory_s"] * 1e3,
                "collective_ms": t["collective_s"] * 1e3,
                "step_ms": step_ms, "speedup": base_ms / step_ms,
                "coll_frac": (t["collective_s"] * 1e3) / step_ms,
                "bound": "collective"
                if t["collective_s"] > max(t["compute_s"], t["memory_s"])
                else ("memory" if t["memory_s"] > t["compute_s"]
                      else "compute")})
    return rows


def render_tp_table(rows) -> str:
    lines = [
        "| shape | tp | batch | ctx | compute ms | memory ms |"
        " collective ms | step ms | speedup | coll % | bound |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        lines.append(
            f"| {r['shape']} | {r['tp']} | {r['batch']} | {r['ctx']} |"
            f" {r['compute_ms']:.3f} | {r['memory_ms']:.3f} |"
            f" {r['collective_ms']:.3f} | {r['step_ms']:.3f} |"
            f" {r['speedup']:.2f}x | {r['coll_frac']*100:.0f}% |"
            f" {r['bound']} |")
    return "\n".join(lines)


def main(fast: bool = True) -> dict:
    krows = kernel_rows()
    mem_bound = sum(1 for r in krows if r["bound"] == "memory")
    emit("roofline.kernels", 0.0,
         f"cases={len(krows)} memory_bound={mem_bound}/{len(krows)}")
    print(render_kernel_table(krows))

    trows = tp_rows()
    best = {r["shape"]: r for r in trows if r["speedup"] == max(
        x["speedup"] for x in trows if x["shape"] == r["shape"])}
    emit("roofline.tp_collectives", 0.0,
         f"cases={len(trows)} best_degree=" + ",".join(
             f"{s}:tp{r['tp']}({r['speedup']:.2f}x)"
             for s, r in sorted(best.items())))
    print(render_tp_table(trows))

    base = os.path.join(os.getcwd(), "experiments", "dryrun")
    rows = load_results(base)
    if not rows:
        emit("roofline.table", 0.0, "no dry-run artifacts found; run "
             "PYTHONPATH=src python -m repro.launch.dryrun --all first")
        return {"kernels": krows, "tp": trows}
    ok = [r for r in rows if r["status"] == "ok"]
    fit = sum(1 for r in ok
              if r["bytes_per_device"] / 2 ** 30 <= V5E_HBM_GB)
    dominant = {}
    for r in ok:
        dominant[r["dominant"]] = dominant.get(r["dominant"], 0) + 1
    emit("roofline.summary", 0.0,
         f"cases={len(rows)} ok={len(ok)} "
         f"fits_16GiB={fit}/{len(ok)} dominant={dominant}")
    print(render_table(rows))
    return {"rows": rows, "kernels": krows, "tp": trows}


if __name__ == "__main__":
    main()
