"""Benchmark harness entry point — one module per paper figure/table.

Prints ``name,us_per_call,derived`` CSV rows.
``BENCH_FAST=0 PYTHONPATH=src python -m benchmarks.run`` for full-length
runs; the default is the fast profile (shorter episodes, fewer seeds).
``--only fig7`` runs a single module. ``--smoke`` runs every module at
toy scale (the CI job that keeps benchmark scripts from rotting —
numbers are meaningless, only the code paths are exercised).
"""
from __future__ import annotations

import argparse
import os
import sys
import time
import traceback

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

MODULES = [
    "fig1_motivation",
    "fig7_utility",
    "fig8_9_timeline",
    "fig10_convergence",
    "fig11_12_scalability",
    "fig13_interference",
    "fig14_15_slo",
    "fig16_overhead",
    "fig_continuous_vs_round",
    "fig_multimodel_concurrency",
    "fig_paged_kv",
    "fig_preemption_chunked",
    "fig_prefix_cache",
    "fig_speculative",
    "fig_fused_kernels",
    "fig_sharded_engine",
    "fig_async_serving",
    "fig_kv_offload",
    "roofline_table",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter on module names")
    ap.add_argument("--smoke", action="store_true",
                    help="toy-scale run of every module (CI rot check)")
    args = ap.parse_args()
    if args.smoke:
        # must land before benchmarks.common is imported by any module
        os.environ["BENCH_SMOKE"] = "1"
    fast = args.smoke or os.environ.get("BENCH_FAST", "1") != "0"
    failures = 0
    for name in MODULES:
        if args.only and args.only not in name:
            continue
        mod = __import__(f"benchmarks.{name}", fromlist=["main"])
        t0 = time.time()
        print(f"# === {name} ===", flush=True)
        try:
            mod.main(fast=fast)
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"{name},0.00,ERROR", flush=True)
            traceback.print_exc()
        print(f"# {name} took {time.time()-t0:.1f}s", flush=True)
    if failures:
        raise SystemExit(f"{failures} benchmark module(s) failed")


if __name__ == "__main__":
    main()
