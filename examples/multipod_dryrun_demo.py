"""Lower + compile one (arch x shape) on the production mesh and print the
roofline terms — the smallest possible multi-pod dry-run demo.

Run:  PYTHONPATH=src python examples/multipod_dryrun_demo.py \
          [--arch qwen3-0.6b] [--shape decode_32k] [--multi-pod]

NOTE: must run as its own process — the 512-device flag is set before jax
initialises.
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    from repro.launch.dryrun import run_case  # sets XLA_FLAGS on import

    res = run_case(args.arch, args.shape, args.multi_pod, out_dir=None)
    if res["status"] != "ok":
        raise SystemExit(res.get("error", res.get("reason")))
    r = res["roofline"]
    print(f"\narch={args.arch} shape={args.shape} mesh={res['mesh']}")
    print(f"bytes/device      : {res['bytes_per_device']/2**30:.2f} GiB")
    print(f"compute roofline  : {r['compute_s']*1e3:.2f} ms")
    print(f"memory roofline   : {r['memory_s']*1e3:.2f} ms")
    print(f"collective        : {r['collective_s']*1e3:.2f} ms")
    print(f"dominant term     : {res['dominant']}")
    print(f"useful FLOP ratio : {res['useful_flops_ratio']:.2f}")


if __name__ == "__main__":
    main()
