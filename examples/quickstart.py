"""Quickstart: train the BCEdge SAC scheduler in the edge simulator and
compare against DeepRT (EDF) and the best fixed Triton-style config.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

from repro.config.base import ServingConfig  # noqa: E402
from repro.core.baselines import EDFScheduler, FixedScheduler  # noqa: E402
from repro.core.interference import NNInterferencePredictor  # noqa: E402
from repro.core.sac import SACAgent, SACConfig  # noqa: E402
from repro.serving.bcedge import run_episode  # noqa: E402
from repro.serving.features import queue_feature_index, state_dim  # noqa: E402
from repro.serving.simulator import EdgeServingEnv  # noqa: E402


def main():
    cfg = ServingConfig()  # Xavier NX, 30 rps/model, paper Table IV SLOs
    models = list(EdgeServingEnv(cfg, episode_ms=1).models)
    dim = state_dim(models)

    print("== BCEdge (max-entropy SAC + interference guard), training ==")
    agent = SACAgent(dim, cfg.n_actions, SACConfig(batch_size=256, lr=5e-4))
    pred = NNInterferencePredictor()
    for ep in range(6):
        env = EdgeServingEnv(cfg, episode_ms=20_000, seed=ep)
        res = run_episode(env, agent, pred, guard=True)
        s = res.summary
        print(f"  ep{ep}: utility={s['mean_utility']:.2f} "
              f"violations={s['slo_violation_rate']:.1%} "
              f"latency={s['mean_latency_ms']:.0f}ms")

    print("== Evaluation (greedy) vs baselines ==")

    class Greedy:
        def act(self, s, greedy=False):
            return agent.act(s, greedy=True)

        def observe(self, *a):
            pass

        def update(self):
            return {}

    rows = {}
    for name, sched, guard in (
            ("BCEdge", Greedy(), True),
            ("DeepRT(EDF)", EDFScheduler(cfg.batch_sizes,
                                         cfg.concurrency_levels,
                                         queue_feature_index(models)), False),
            ("Fixed(b=2,mc=2)", FixedScheduler(cfg.pair_to_action(2, 2)),
             False)):
        env = EdgeServingEnv(cfg, episode_ms=20_000, seed=99)
        res = run_episode(env, sched, pred if guard else None, guard=guard,
                          learn=False)
        rows[name] = res.summary
        s = res.summary
        print(f"  {name:16s} utility={s['mean_utility']:6.2f} "
              f"thr={s['throughput_rps']:6.1f}rps "
              f"viol={s['slo_violation_rate']:.1%} "
              f"lat={s['mean_latency_ms']:.0f}ms")
    gain = rows["BCEdge"]["mean_utility"] - rows["DeepRT(EDF)"]["mean_utility"]
    print(f"\nBCEdge utility gain vs DeepRT: {gain:+.2f} "
          f"(paper reports +37% on average)")


if __name__ == "__main__":
    main()
