"""End-to-end serving driver: the BCEdge scheduler batching REAL model
inference (reduced qwen3 running under jit on this host), wall-clock
latencies and all.

Requests with token prompts arrive Poisson; the SAC scheduler picks the
batch size per round; the engine executes prefill+decode; utilities are
computed from measured latencies.

Run:  PYTHONPATH=src python examples/serve_llm.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

from repro.config import get_reduced_config  # noqa: E402
from repro.config.base import ServingConfig  # noqa: E402
from repro.core.sac import SACAgent, SACConfig  # noqa: E402
from repro.core.utility import utility  # noqa: E402
from repro.serving.engine import InferenceEngine  # noqa: E402


def main(duration_s: float = 20.0, rps: float = 12.0, slo_ms: float = 1500.0):
    cfg = get_reduced_config("qwen3-0.6b")
    print(f"loading reduced {cfg.name} "
          f"(d={cfg.d_model}, L={cfg.n_layers})...")
    engine = InferenceEngine(cfg, max_seq=128)
    # warm the compile cache
    engine.generate([np.arange(8, dtype=np.int32)], max_new_tokens=2)

    scfg = ServingConfig(batch_sizes=(1, 2, 4, 8),
                         concurrency_levels=(1,))
    agent = SACAgent(4, scfg.n_actions,
                     SACConfig(batch_size=32, lr=1e-3), seed=0)
    rng = np.random.default_rng(0)

    queue = []
    t0 = time.perf_counter()
    next_arrival = rng.exponential(1.0 / rps)
    served = violations = rounds = 0
    lat_sum = 0.0
    state = np.zeros(4, np.float32)
    while time.perf_counter() - t0 < duration_s:
        now = time.perf_counter() - t0
        while next_arrival <= now:
            queue.append((next_arrival,
                          rng.integers(1, cfg.vocab_size,
                                       rng.integers(4, 24)).astype(np.int32)))
            next_arrival += rng.exponential(1.0 / rps)
        if not queue:
            time.sleep(0.002)
            continue
        oldest_age = now - queue[0][0]
        state = np.array([np.log1p(len(queue)), oldest_age,
                          np.log1p(served), 1.0], np.float32)
        a = agent.act(state)
        b, _ = scfg.action_to_pair(a)
        batch = queue[:b]
        queue = queue[b:]
        res = engine.generate([p for _, p in batch], max_new_tokens=4)
        done_t = time.perf_counter() - t0
        lats = [(done_t - arr) * 1000.0 for arr, _ in batch]
        viol = sum(1 for l in lats if l > slo_ms)
        served += len(batch)
        violations += viol
        lat_sum += sum(lats)
        rounds += 1
        u = utility(len(batch) / max(res.total_ms / 1000, 1e-3),
                    np.mean(lats) / 1000.0,
                    slo_ms / 1000.0 * len(batch), 1) - 2.0 * viol / len(batch)
        s2 = np.array([np.log1p(len(queue)), 0.0, np.log1p(served), 1.0],
                      np.float32)
        agent.observe(state, a, u, s2, False)
        agent.update()
    dur = time.perf_counter() - t0
    print(f"served {served} requests in {dur:.1f}s "
          f"({served/dur:.1f} rps) over {rounds} rounds")
    print(f"mean latency {lat_sum/max(served,1):.0f}ms, "
          f"violations {violations/max(served,1):.1%} (SLO {slo_ms:.0f}ms)")


if __name__ == "__main__":
    main()
