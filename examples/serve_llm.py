"""End-to-end serving driver: the BCEdge scheduler batching REAL model
inference (reduced qwen3 running under jit on this host), wall-clock
latencies and all.

Thin wrapper around the importable entry point
``repro.launch.engine_serve`` (also reachable as
``python -m repro.launch.serve --engine [--exec-mode continuous]``).

Run:  PYTHONPATH=src python examples/serve_llm.py [round|continuous]
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch import engine_serve  # noqa: E402


def main(exec_mode: str = "round"):
    engine_serve.main(exec_mode=exec_mode)


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "round")
