"""Train a dense LM for a few hundred steps on synthetic Markov data —
the end-to-end training driver (loss must drop well below the uniform
floor). Default is a ~20M model sized for this CPU container; pass
``--full`` for the ~100M configuration (TPU-scale demo).

Run:  PYTHONPATH=src python examples/train_small_lm.py [--steps 200]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.config.base import ModelConfig  # noqa: E402
from repro.common.types import fmt_count, param_count  # noqa: E402
from repro.train.trainer import Trainer, TrainerConfig  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--full", action="store_true",
                    help="~100M-param configuration")
    args = ap.parse_args()

    if args.full:
        cfg = ModelConfig(
            name="lm-100m", family="dense", n_layers=10, d_model=640,
            n_heads=10, n_kv_heads=10, d_ff=2560, vocab_size=32_000,
            rope="rope", activation="silu", norm="rmsnorm")
    else:
        cfg = ModelConfig(
            name="lm-20m", family="dense", n_layers=6, d_model=384,
            n_heads=6, n_kv_heads=6, d_ff=1536, vocab_size=2_048,
            rope="rope", activation="silu", norm="rmsnorm")
    trainer = Trainer(cfg, TrainerConfig(
        batch=args.batch, seq_len=args.seq, steps=args.steps,
        lr=1e-3, warmup=20, ckpt_path=args.ckpt))
    n = param_count(trainer.state.params)
    print(f"model: {cfg.name}, {fmt_count(n)} params")
    stats = trainer.run()
    import math

    floor = math.log(cfg.vocab_size)
    print(f"loss {stats['first_loss']:.3f} -> {stats['final_loss']:.3f} "
          f"(uniform floor {floor:.2f}); learned structure: "
          f"{stats['final_loss'] < floor - 1.0}")


if __name__ == "__main__":
    main()
