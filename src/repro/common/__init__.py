from repro.common import tree, types  # noqa: F401
