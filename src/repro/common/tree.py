"""Small pytree helpers used by the trainer / checkpointing."""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def flatten_with_paths(tree: Any) -> List[Tuple[str, jax.Array]]:
    """Flatten a pytree into (dotted-path, leaf) pairs (stable order)."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(_path_str(p) for p in path)
        out.append((key, leaf))
    return out


def _path_str(entry) -> str:
    if isinstance(entry, jax.tree_util.DictKey):
        return str(entry.key)
    if isinstance(entry, jax.tree_util.SequenceKey):
        return str(entry.idx)
    if isinstance(entry, jax.tree_util.GetAttrKey):
        return str(entry.name)
    return str(entry)


def tree_zeros_like(tree: Any) -> Any:
    return jax.tree.map(jnp.zeros_like, tree)


def tree_add(a: Any, b: Any) -> Any:
    return jax.tree.map(jnp.add, a, b)


def tree_scale(tree: Any, s) -> Any:
    return jax.tree.map(lambda x: x * s, tree)


def tree_map_with_path(fn: Callable[[str, jax.Array], Any], tree: Any) -> Any:
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: fn("/".join(_path_str(p) for p in path), leaf), tree
    )


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def to_numpy(tree: Any) -> Any:
    return jax.tree.map(lambda x: np.asarray(x), tree)


def tree_size_report(tree: Any, top: int = 12) -> str:
    rows = sorted(flatten_with_paths(tree), key=lambda kv: -kv[1].size)[:top]
    return "\n".join(f"  {k:60s} {tuple(v.shape)} {v.dtype}" for k, v in rows)


def named_dict(tree: Any) -> Dict[str, np.ndarray]:
    return {k: np.asarray(v) for k, v in flatten_with_paths(tree)}
