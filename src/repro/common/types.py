"""Shared type aliases and dtype policy helpers."""
from __future__ import annotations

from typing import Any, Dict, Mapping

import jax
import jax.numpy as jnp

Params = Any  # pytree of jnp arrays
PRNGKey = jax.Array

#: dtype policy used throughout: params are stored in ``param_dtype`` and
#: compute runs in ``compute_dtype`` (bf16 on TPU targets, f32 on CPU tests).
DEFAULT_PARAM_DTYPE = jnp.float32
DEFAULT_COMPUTE_DTYPE = jnp.bfloat16


def cast_tree(tree: Params, dtype) -> Params:
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        tree,
    )


def shape_dtype(tree: Params) -> Dict[str, Any]:
    """ShapeDtypeStruct skeleton of a pytree (for dry-runs / documentation)."""
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def param_count(tree: Params) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(tree))


def param_bytes(tree: Params) -> int:
    return sum(int(x.size) * x.dtype.itemsize for x in jax.tree.leaves(tree))


def fmt_count(n: int) -> str:
    for unit, div in (("T", 1e12), ("B", 1e9), ("M", 1e6), ("K", 1e3)):
        if n >= div:
            return f"{n / div:.2f}{unit}"
    return str(n)


def fmt_bytes(n: float) -> str:
    for unit, div in (("TiB", 2**40), ("GiB", 2**30), ("MiB", 2**20), ("KiB", 2**10)):
        if n >= div:
            return f"{n / div:.2f}{unit}"
    return f"{n:.0f}B"


def assert_finite(name: str, x: jax.Array) -> None:
    if not bool(jnp.isfinite(x).all()):
        raise FloatingPointError(f"non-finite values in {name}")


def merge_dicts(*ds: Mapping[str, Any]) -> Dict[str, Any]:
    out: Dict[str, Any] = {}
    for d in ds:
        out.update(d)
    return out
