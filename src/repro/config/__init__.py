from repro.config.base import (  # noqa: F401
    InputShape,
    ModelConfig,
    ServingConfig,
    INPUT_SHAPES,
)
from repro.config.registry import (  # noqa: F401
    get_config,
    list_archs,
    register,
    get_reduced_config,
)
