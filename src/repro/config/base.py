"""Config dataclasses.

``ModelConfig`` is the single declarative description a model is built from;
every assigned architecture is a ``ModelConfig`` instance in
``repro.configs.<id>``. ``InputShape`` describes the four assigned workload
shapes. ``ServingConfig`` parameterises the BCEdge serving layer.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    # identity
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    source: str = ""  # citation from the assignment table

    # trunk dims
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: Optional[int] = None  # default d_model // n_heads
    d_ff: int = 1024
    vocab_size: int = 1024

    # attention flavour
    rope: str = "rope"  # rope | rope2d | mrope | none
    qk_norm: bool = False
    sliding_window: Optional[int] = None  # tokens; None = full attention
    rope_theta: float = 10_000.0

    # per-layer block pattern, cycled over layers. entries:
    #   "attn" (global; MoE FFN when n_experts > 0), "attn_dense" (global
    #   attention with a dense FFN even in MoE models — llama4 interleave),
    #   "local_attn" (windowed), "rglru" (RG-LRU), "rwkv"
    block_pattern: Tuple[str, ...] = ("attn",)

    # MoE
    n_experts: int = 0  # 0 = dense FFN
    top_k: int = 1
    moe_dense_residual: bool = False  # arctic: dense MLP in parallel w/ MoE
    dense_ff: Optional[int] = None  # width of the dense residual MLP
    capacity_factor: float = 1.25

    # encoder-decoder
    enc_dec: bool = False
    n_enc_layers: int = 0

    # modality frontend stub: None | "vision" | "audio"
    frontend: Optional[str] = None
    frontend_tokens: int = 0  # stub embeddings prepended at prefill

    # misc
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    activation: str = "silu"  # silu | gelu
    tie_embeddings: bool = False
    rwkv_head_size: int = 64
    rglru_width: Optional[int] = None  # RG-LRU recurrent width (default d_model)
    logit_softcap: Optional[float] = None

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // max(self.n_heads, 1))
        if self.family in ("vlm", "audio") and self.frontend is None:
            object.__setattr__(
                self, "frontend", "vision" if self.family == "vlm" else "audio"
            )
        assert self.n_heads % max(self.n_kv_heads, 1) == 0, (
            f"{self.name}: n_heads={self.n_heads} not divisible by "
            f"n_kv_heads={self.n_kv_heads}"
        )

    # ---- derived ------------------------------------------------------
    @property
    def q_per_kv(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    def layer_kinds(self) -> Tuple[str, ...]:
        pat = self.block_pattern
        return tuple(pat[i % len(pat)] for i in range(self.n_layers))

    @property
    def attention_free(self) -> bool:
        return all(k in ("rwkv", "rglru") for k in self.layer_kinds())

    @property
    def subquadratic(self) -> bool:
        """True if no layer attends over unbounded context."""
        for k in self.layer_kinds():
            if k in ("attn", "attn_dense") and self.sliding_window is None:
                return False
        return True

    def param_count_estimate(self, active_only: bool = False) -> int:
        """Analytic parameter count (embeddings + trunk), used for rooflines.

        ``active_only`` counts only the routed experts a token actually
        uses (top_k of n_experts) — the MoE "active params" figure.
        """
        d, hd = self.d_model, self.head_dim
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        attn_p = (d * hd * self.n_heads + 2 * d * hd * self.n_kv_heads
                  + hd * self.n_heads * d)
        gated = self.activation in ("silu", "geglu")
        n_mats = 3 if gated else 2

        def dense_ffn(width):
            return n_mats * d * width

        moe_ffn = 0
        if self.n_experts:
            n_e = self.top_k if active_only else self.n_experts
            moe_ffn = n_e * 3 * d * self.d_ff + d * self.n_experts
            if self.moe_dense_residual:
                moe_ffn += 3 * d * (self.dense_ff or self.d_ff)

        total = emb
        rec_w = self.rglru_width or d
        for k in self.layer_kinds():
            if k in ("attn", "local_attn"):
                total += attn_p
                total += moe_ffn if self.n_experts else dense_ffn(self.d_ff)
            elif k == "attn_dense":
                total += attn_p + dense_ffn(self.dense_ff or self.d_ff)
            elif k == "rglru":
                total += (2 * d * rec_w + 2 * rec_w * rec_w + 4 * rec_w
                          + rec_w * d) + dense_ffn(self.d_ff)
            elif k == "rwkv":
                total += (6 * d * d + 10 * d * 32          # time mix
                          + 2 * d * self.d_ff + d * d)     # channel mix
        if self.enc_dec:
            total += self.n_enc_layers * (attn_p + dense_ffn(self.d_ff))
            total += self.n_layers * attn_p  # decoder cross-attention
        return int(total)


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ServingConfig:
    """BCEdge scheduler + serving layer parameters (paper §IV/§V-A).

    ``exec_mode`` selects the execution substrate the scheduler drives
    (docs/ARCHITECTURE.md §5):

    * ``"round"`` — the paper's semantics: a (b, m_c) round runs to
      completion, every request in the batch waits for the slowest one.
    * ``"continuous"`` — iteration-level batching: the action is
      reinterpreted as (max slots per instance, concurrency); requests
      join/leave the running batch at decode-iteration boundaries.

    ``decode_steps_mean`` parameterises how autoregressive the workload
    is: each request needs a geometrically-distributed number of decode
    iterations with this mean (1.0 = the paper's single-shot CNN/BERT
    requests, where both modes coincide round-for-round).

    ``prefill_tokens_mean`` > 0 gives continuous-mode requests a prompt
    that must be prefilled before decoding (geometric, that mean);
    ``token_budgets`` then becomes a third co-optimised action axis —
    the per-iteration cap on prefill-chunk + decode tokens (0 =
    uncapped), the knob that bounds iteration latency under long-prompt
    arrivals (docs/ARCHITECTURE.md §5). ``preemption`` enables the
    SLO-aware eviction policy (trigger/victim/hysteresis in
    docs/RUNTIME.md §8) in the continuous simulator.

    ``shared_prefix_tokens`` > 0 makes the workload a *templated* one
    (docs/ARCHITECTURE.md §5): each request's prompt starts with one of
    ``prefix_population`` shared prefixes of that length (system prompts
    / per-model task preambles), on top of its geometric unique tail.
    With ``prefix_cache`` on, the simulator's sessions skip the prefill
    of a prefix an earlier request of the same model already paid — the
    analytic twin of the engine's block-sharing prefix cache, so learned
    policies see cache dynamics.
    """

    batch_sizes: Tuple[int, ...] = (1, 2, 4, 8, 16, 32, 64, 128)
    concurrency_levels: Tuple[int, ...] = (1, 2, 3, 4, 5, 6, 7, 8)
    arrival_rps: float = 30.0  # Poisson rate (paper: 30 rps)
    platform: str = "xavier_nx"  # see serving/platforms.py
    slo_scale: float = 1.0  # multiply per-model SLOs (stress knob)
    max_queue: int = 512
    seed: int = 0
    use_interference_predictor: bool = True
    exec_mode: str = "round"  # "round" | "continuous"
    decode_steps_mean: float = 1.0  # mean decode iterations per request
    #: per-iteration token-budget action axis (0 = uncapped); the default
    #: single level keeps the (b, m_c) action space unchanged
    token_budgets: Tuple[int, ...] = (0,)
    prefill_tokens_mean: float = 0.0  # mean prompt tokens (0 = single-shot)
    preemption: bool = False  # SLO-aware eviction (continuous mode)
    preempt_margin_ms: float = 50.0  # victim must out-slack urgent by this
    max_preemptions: int = 2  # per-request cap (anti-thrash)
    #: shared-prefix (templated) workload: prefix length in tokens
    #: (0 = no shared prefixes) drawn from a population of distinct
    #: prefixes; prefix_cache lets sessions skip already-paid prefixes
    shared_prefix_tokens: float = 0.0
    prefix_population: int = 4
    prefix_cache: bool = False
    #: speculative-decoding depth action axis (docs/ARCHITECTURE.md §5):
    #: per-iteration draft depth k (0 = plain autoregressive decode); the
    #: default single level keeps the (b, m_c, tb) action space unchanged
    spec_depths: Tuple[int, ...] = (0,)
    #: simulator twin: probability each draft token is accepted (the
    #: per-draft Bernoulli of the acceptance-dependent step cost model)
    spec_accept_rate: float = 0.6
    #: tensor-parallel degree action axis (docs/RUNTIME.md §10): devices
    #: one instance spans on its 1D ("model",) mesh. OUTERMOST axis, so
    #: the default single level keeps every narrower codec — and every
    #: policy trained before the axis existed — encoding-stable
    tp_degrees: Tuple[int, ...] = (1,)

    def __post_init__(self):
        assert self.exec_mode in ("round", "continuous"), self.exec_mode
        assert self.decode_steps_mean >= 1.0, self.decode_steps_mean
        assert self.token_budgets, "need at least one token-budget level"
        assert all(t >= 0 for t in self.token_budgets), self.token_budgets
        assert self.prefill_tokens_mean >= 0.0, self.prefill_tokens_mean
        assert self.shared_prefix_tokens >= 0.0, self.shared_prefix_tokens
        assert self.prefix_population >= 1, self.prefix_population
        assert self.spec_depths, "need at least one speculation depth"
        assert all(k >= 0 for k in self.spec_depths), self.spec_depths
        assert 0.0 <= self.spec_accept_rate <= 1.0, self.spec_accept_rate
        assert self.tp_degrees, "need at least one TP degree"
        assert all(d >= 1 for d in self.tp_degrees), self.tp_degrees

    @property
    def n_actions(self) -> int:
        return len(self.batch_sizes) * len(self.concurrency_levels) * \
            len(self.token_budgets) * len(self.spec_depths) * \
            len(self.tp_degrees)

    def action_to_pair(self, a: int) -> Tuple[int, int]:
        nb = len(self.batch_sizes)
        a = a % (nb * len(self.concurrency_levels))
        return self.batch_sizes[a % nb], self.concurrency_levels[a // nb]

    def pair_to_action(self, b: int, m_c: int) -> int:
        """(b, m_c) at the first (most permissive) token-budget level —
        the exact pre-token-budget action encoding, kept stable so
        existing callers and trained policies are unaffected."""
        return self.concurrency_levels.index(m_c) * len(self.batch_sizes) + \
            self.batch_sizes.index(b)

    def action_to_triple(self, a: int) -> Tuple[int, int, int]:
        """(b, m_c, token_budget) — token budget 0 means uncapped. The
        modulus folds away any outer (speculation-depth) axis, keeping
        the narrower codec stable for pre-k callers."""
        nb, nm = len(self.batch_sizes), len(self.concurrency_levels)
        nt = len(self.token_budgets)
        a = a % (nb * nm * nt)
        b, m_c = self.action_to_pair(a)
        return b, m_c, self.token_budgets[a // (nb * nm)]

    def triple_to_action(self, b: int, m_c: int, token_budget: int) -> int:
        nb, nm = len(self.batch_sizes), len(self.concurrency_levels)
        return self.token_budgets.index(token_budget) * nb * nm + \
            self.pair_to_action(b, m_c)

    def action_to_quad(self, a: int) -> Tuple[int, int, int, int]:
        """(b, m_c, token_budget, spec_k) — the speculation depth sits
        outside the pair/triple digits: every narrower codec reads the
        same inner digits, so trained policies and existing callers see
        identical encodings at spec_depths=(0,). The modulus folds away
        the (outermost) TP-degree axis for pre-tp callers."""
        nb, nm = len(self.batch_sizes), len(self.concurrency_levels)
        nt, nk = len(self.token_budgets), len(self.spec_depths)
        a = a % (nb * nm * nt * nk)
        b, m_c, tb = self.action_to_triple(a)
        return b, m_c, tb, self.spec_depths[a // (nb * nm * nt)]

    def quad_to_action(self, b: int, m_c: int, token_budget: int,
                       spec_k: int) -> int:
        nb, nm = len(self.batch_sizes), len(self.concurrency_levels)
        nt = len(self.token_budgets)
        return self.spec_depths.index(spec_k) * nb * nm * nt + \
            self.triple_to_action(b, m_c, token_budget)

    def action_to_quint(self, a: int) -> Tuple[int, int, int, int, int]:
        """(b, m_c, token_budget, spec_k, tp_degree) — the TP degree is
        the OUTERMOST axis (same construction as the spec_k axis before
        it), so at tp_degrees=(1,) every action encodes exactly as the
        quad codec and narrower callers fold it away by modulus."""
        nb, nm = len(self.batch_sizes), len(self.concurrency_levels)
        nt, nk = len(self.token_budgets), len(self.spec_depths)
        b, m_c, tb, sk = self.action_to_quad(a)
        return b, m_c, tb, sk, self.tp_degrees[a // (nb * nm * nt * nk)]

    def quint_to_action(self, b: int, m_c: int, token_budget: int,
                        spec_k: int, tp_degree: int) -> int:
        nb, nm = len(self.batch_sizes), len(self.concurrency_levels)
        nt, nk = len(self.token_budgets), len(self.spec_depths)
        return self.tp_degrees.index(tp_degree) * nb * nm * nt * nk + \
            self.quad_to_action(b, m_c, token_budget, spec_k)
