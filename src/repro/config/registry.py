"""Architecture registry: ``--arch <id>`` resolution.

Config modules in ``repro.configs`` call :func:`register` at import time.
``get_config(arch)`` imports the configs package lazily so that importing
``repro.config`` alone never drags in model code.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Callable, Dict, List, Optional

from repro.config.base import ModelConfig

_REGISTRY: Dict[str, ModelConfig] = {}
_REDUCERS: Dict[str, Callable[[ModelConfig], ModelConfig]] = {}


def register(cfg: ModelConfig, reducer: Optional[Callable] = None) -> ModelConfig:
    if cfg.name in _REGISTRY:
        raise ValueError(f"duplicate arch id {cfg.name!r}")
    _REGISTRY[cfg.name] = cfg
    if reducer is not None:
        _REDUCERS[cfg.name] = reducer
    return cfg


def _ensure_loaded() -> None:
    if not _REGISTRY:
        importlib.import_module("repro.configs")


def get_config(arch: str) -> ModelConfig:
    _ensure_loaded()
    if arch not in _REGISTRY:
        raise KeyError(
            f"unknown arch {arch!r}; available: {sorted(_REGISTRY)}"
        )
    return _REGISTRY[arch]


def list_archs() -> List[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


def default_reduce(cfg: ModelConfig) -> ModelConfig:
    """Reduced smoke variant: 2 layers, d_model<=512, <=4 experts."""
    d_model = min(cfg.d_model, 256)
    head_dim = 64
    n_heads = max(2, min(cfg.n_heads, d_model // head_dim * 2))
    n_heads = max(2, d_model // head_dim)
    q_per_kv = cfg.q_per_kv
    n_kv = max(1, n_heads // min(q_per_kv, n_heads))
    n_heads = n_kv * min(q_per_kv, n_heads)
    changes = dict(
        n_layers=2,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=head_dim,
        d_ff=min(cfg.d_ff, 512),
        vocab_size=min(cfg.vocab_size, 1024),
        n_experts=min(cfg.n_experts, 4) if cfg.n_experts else 0,
        n_enc_layers=min(cfg.n_enc_layers, 2),
        dense_ff=min(cfg.dense_ff, 512) if cfg.dense_ff else None,
        sliding_window=min(cfg.sliding_window, 64) if cfg.sliding_window else None,
        frontend_tokens=min(cfg.frontend_tokens, 8) if cfg.frontend_tokens else 0,
        rglru_width=min(cfg.rglru_width, d_model) if cfg.rglru_width else None,
        # no capacity drops at smoke scale => decode == full-forward exactly
        capacity_factor=8.0,
    )
    return dataclasses.replace(cfg, **changes)


def get_reduced_config(arch: str) -> ModelConfig:
    _ensure_loaded()
    cfg = get_config(arch)
    reducer = _REDUCERS.get(arch, default_reduce)
    return reducer(cfg)
