"""Assigned architecture configs. Importing this package registers all
architectures with ``repro.config.registry``; select via ``--arch <id>``."""
from repro.configs import (  # noqa: F401
    llama4_maverick_400b,
    rwkv6_3b,
    starcoder2_15b,
    qwen2_vl_7b,
    recurrentgemma_2b,
    chatglm3_6b,
    seamless_m4t_large_v2,
    yi_34b,
    arctic_480b,
    qwen3_0_6b,
    paper_edge_models,
)

#: the ten pool-assigned architectures (paper's own edge models excluded)
ASSIGNED = [
    "llama4-maverick-400b-a17b",
    "rwkv6-3b",
    "starcoder2-15b",
    "qwen2-vl-7b",
    "recurrentgemma-2b",
    "chatglm3-6b",
    "seamless-m4t-large-v2",
    "yi-34b",
    "arctic-480b",
    "qwen3-0.6b",
]
