"""Snowflake Arctic 480B [hf:Snowflake/snowflake-arctic-base] — dense-MoE
hybrid: every layer has a 128-expert top-2 MoE *in parallel with* a dense
residual MLP branch."""
from repro.config.base import ModelConfig
from repro.config.registry import register

CONFIG = register(ModelConfig(
    name="arctic-480b",
    family="moe",
    source="hf:Snowflake/snowflake-arctic-base",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=4864,            # expert width
    dense_ff=7168,        # parallel dense residual MLP width
    vocab_size=32_000,
    n_experts=128,
    top_k=2,
    moe_dense_residual=True,
    rope="rope",
    activation="silu",
    norm="rmsnorm",
))
