"""ChatGLM3-6B [arXiv:2406.12793] — GQA(kv=2), GLM-style partial ("2d")
rotary on half the head dim, swiGLU FFN."""
from repro.config.base import ModelConfig
from repro.config.registry import register

CONFIG = register(ModelConfig(
    name="chatglm3-6b",
    family="dense",
    source="arXiv:2406.12793",
    n_layers=28,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    head_dim=128,
    d_ff=13_696,
    vocab_size=65_024,
    rope="rope2d",
    activation="silu",
    norm="rmsnorm",
))
