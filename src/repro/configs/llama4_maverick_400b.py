"""Llama-4 Maverick 400B-A17B [hf:meta-llama/Llama-4-Scout-17B-16E family].

MoE with 128 routed experts, top-1 routing, interleaved dense/MoE layers
(every second layer routed — that interleave is what lands total params at
~400B with 17B active). Early-fusion multimodality is out of backbone scope
(the assignment tags this [moe], not [vlm]).
"""
from repro.config.base import ModelConfig
from repro.config.registry import register

CONFIG = register(ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,           # routed expert width
    dense_ff=16384,      # dense-layer FFN width
    vocab_size=202_048,
    n_experts=128,
    top_k=1,
    block_pattern=("attn_dense", "attn"),  # dense / MoE interleave
    rope="rope",
    rope_theta=500_000.0,
    activation="silu",
    norm="rmsnorm",
))
