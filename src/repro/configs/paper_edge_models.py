"""The paper's own six served DNN models (Table IV) with their SLOs.

These are the tenants of the BCEdge serving experiments (Figs. 7-16). Each
profile carries the analytic compute/memory footprint that parameterises the
edge latency model (serving/latency_model.py); small-but-real JAX versions
of the networks live in models/cnn.py for the runnable examples.

FLOPs are per-image forward FLOPs at the paper's 224x224 input (TinyBERT:
per Speech-Commands utterance), taken from the source papers and scaled to
224 resolution where the reference resolution differs.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple


@dataclasses.dataclass(frozen=True)
class EdgeModelProfile:
    name: str
    short: str
    task: str
    input_shape: Tuple[int, ...]
    slo_ms: float          # Table IV
    gflops: float          # per-sample forward GFLOPs
    params_m: float        # millions of parameters
    activation_mb: float   # per-sample activation footprint (MB, fp16)


EDGE_MODELS: Dict[str, EdgeModelProfile] = {
    "yolo": EdgeModelProfile(
        "YOLO-v5s", "yolo", "detection", (3, 224, 224),
        slo_ms=138.0, gflops=2.03, params_m=7.2, activation_mb=24.0),
    "mob": EdgeModelProfile(
        "MobileNet-v3", "mob", "classification", (3, 224, 224),
        slo_ms=86.0, gflops=0.22, params_m=5.4, activation_mb=8.0),
    "res": EdgeModelProfile(
        "ResNet-18", "res", "classification", (3, 224, 224),
        slo_ms=58.0, gflops=1.82, params_m=11.7, activation_mb=12.0),
    "eff": EdgeModelProfile(
        "EfficientNet-B0", "eff", "classification", (3, 224, 224),
        slo_ms=93.0, gflops=0.39, params_m=5.3, activation_mb=16.0),
    "inc": EdgeModelProfile(
        "Inception-v3", "inc", "classification", (3, 224, 224),
        slo_ms=66.0, gflops=3.19, params_m=23.8, activation_mb=18.0),
    "bert": EdgeModelProfile(
        "TinyBERT", "bert", "speech", (1, 14),
        slo_ms=114.0, gflops=0.12, params_m=14.5, activation_mb=4.0),
}
