"""Qwen2-VL-7B [arXiv:2409.12191] — M-RoPE (t/h/w factorised rotary),
dynamic-resolution ViT frontend. The ViT is a stub per the brief:
``input_specs`` supplies patch embeddings (B, F, d_model); the backbone
applies the learned projector + M-RoPE positions over the vision span.
"""
from repro.config.base import ModelConfig
from repro.config.registry import register

CONFIG = register(ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    source="arXiv:2409.12191",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    head_dim=128,
    d_ff=18_944,
    vocab_size=152_064,
    rope="mrope",
    rope_theta=1_000_000.0,
    frontend="vision",
    frontend_tokens=1024,   # stubbed patch-embedding span
    activation="silu",
    norm="rmsnorm",
))
