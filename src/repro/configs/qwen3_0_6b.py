"""Qwen3-0.6B [hf:Qwen/Qwen3-8B family] — qk_norm (RMSNorm on per-head q/k),
GQA(kv=8), head_dim 128 decoupled from d_model, tied embeddings.

A beyond-paper sliding-window variant ("qwen3-0.6b-swa", w=8192) is also
registered so a small dense arch covers long_500k (see
docs/ARCHITECTURE.md §8)."""
import dataclasses

from repro.config.base import ModelConfig
from repro.config.registry import register

CONFIG = register(ModelConfig(
    name="qwen3-0.6b",
    family="dense",
    source="hf:Qwen/Qwen3-8B",
    n_layers=28,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=3072,
    vocab_size=151_936,
    rope="rope",
    rope_theta=1_000_000.0,
    qk_norm=True,
    tie_embeddings=True,
    activation="silu",
    norm="rmsnorm",
))

SWA_VARIANT = register(dataclasses.replace(
    CONFIG, name="qwen3-0.6b-swa", sliding_window=8192))
