"""RecurrentGemma-2B (Griffin) [arXiv:2402.19427] — RG-LRU temporal blocks
with local attention every third layer (1 attn : 2 recurrent), MQA (kv=1),
window 2048. Sub-quadratic: runs long_500k. 26 layers = 8 scanned
(rglru, rglru, local_attn) units + an unrolled (rglru, rglru) tail.
"""
import dataclasses

from repro.config.base import ModelConfig
from repro.config.registry import default_reduce, register

CONFIG = register(
    ModelConfig(
        name="recurrentgemma-2b",
        family="hybrid",
        source="arXiv:2402.19427",
        n_layers=26,
        d_model=2560,
        n_heads=10,
        n_kv_heads=1,
        head_dim=256,
        d_ff=7680,
        vocab_size=256_000,
        block_pattern=("rglru", "rglru", "local_attn"),
        sliding_window=2048,
        rglru_width=2560,
        rope="rope",
        activation="geglu",
        norm="rmsnorm",
        tie_embeddings=True,
        logit_softcap=30.0,
    ),
    # 3 reduced layers so the smoke test exercises one full pattern unit
    reducer=lambda cfg: dataclasses.replace(default_reduce(cfg), n_layers=3),
)
