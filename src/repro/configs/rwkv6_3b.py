"""RWKV-6 "Finch" 3B [arXiv:2404.05892] — attention-free, data-dependent
decay linear recurrence. Sub-quadratic: runs long_500k."""
from repro.config.base import ModelConfig
from repro.config.registry import register

CONFIG = register(ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    source="arXiv:2404.05892",
    n_layers=32,
    d_model=2560,
    n_heads=40,          # d_model / rwkv_head_size
    n_kv_heads=40,
    d_ff=8960,
    vocab_size=65_536,
    block_pattern=("rwkv",),
    rope="none",
    rwkv_head_size=64,
    norm="layernorm",
))
