"""SeamlessM4T-large-v2 [arXiv:2308.11596] — encoder-decoder multimodal
translator. Backbone only per the brief: the conformer speech frontend is a
stub; ``input_specs`` supplies frame embeddings (B, seq/4, d_model) to a
24-layer bidirectional encoder; the 24-layer decoder (self + cross attn)
is what decode shapes lower. MHA kv=16 (no grouping)."""
from repro.config.base import ModelConfig
from repro.config.registry import register

CONFIG = register(ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    source="arXiv:2308.11596",
    n_layers=24,            # decoder layers
    n_enc_layers=24,
    enc_dec=True,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    # true vocab is 256206; padded +2 to a multiple of 16 so the embedding
    # shards evenly over the model axis (standard Megatron-style vocab pad)
    vocab_size=256_208,
    rope="rope",
    frontend="audio",
    activation="gelu",
    norm="layernorm",
))
