"""StarCoder2-15B [arXiv:2402.19173] — GQA(kv=4), RoPE, 4k sliding window
(the model card trains with window attention, which also makes long_500k
lowerable for this dense arch)."""
from repro.config.base import ModelConfig
from repro.config.registry import register

CONFIG = register(ModelConfig(
    name="starcoder2-15b",
    family="dense",
    source="arXiv:2402.19173",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=4,
    head_dim=128,
    d_ff=24_576,
    vocab_size=49_152,
    rope="rope",
    rope_theta=100_000.0,
    sliding_window=4096,
    activation="gelu",   # plain (ungated) MLP, 4x width
    norm="layernorm",
))
