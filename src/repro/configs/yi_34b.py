"""Yi-34B [arXiv:2403.04652] — llama-architecture dense, GQA(kv=8)."""
from repro.config.base import ModelConfig
from repro.config.registry import register

CONFIG = register(ModelConfig(
    name="yi-34b",
    family="dense",
    source="arXiv:2403.04652",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=20_480,
    vocab_size=64_000,
    rope="rope",
    rope_theta=5_000_000.0,
    activation="silu",
    norm="rmsnorm",
))
