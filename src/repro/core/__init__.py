"""BCEdge core: the paper's contribution — utility objective, discrete
max-entropy SAC scheduler, baseline schedulers, interference predictor."""
from repro.core.utility import utility, scheduling_slot  # noqa: F401
from repro.core.sac import SACAgent  # noqa: F401
