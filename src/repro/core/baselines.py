"""Baseline schedulers (paper §V-B).

* ``TACAgent``   — "Triton with Actor-Critic": advantage actor-critic
                   WITHOUT the entropy term (the paper's key ablation).
* ``PPOAgent``   — on-policy clipped-surrogate PPO.
* ``DDQNAgent``  — double deep Q-network, epsilon-greedy.
* ``GAScheduler``— genetic algorithm over the (b, m_c) grid; fitness = U.
* ``EDFScheduler``— DeepRT-style earliest-deadline-first dynamic batching,
                   no concurrency (m_c = 1).
* ``FixedScheduler`` — static (b, m_c) (Triton default configuration).

All expose the common interface: ``act(state) -> action``,
``observe(s, a, r, s2, done)``, ``update() -> metrics``.
"""
from __future__ import annotations

import functools
from typing import Dict, List, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.networks import mlp_apply, mlp_init, soft_update
from repro.core.replay import ReplayBuffer
from repro.train.optimizer import adam, apply_updates


# =====================================================================
# TAC — actor-critic without entropy
# =====================================================================
class _ACState(NamedTuple):
    policy: Dict
    value: Dict
    opt_p: Tuple
    opt_v: Tuple


@functools.partial(jax.jit, static_argnames=("gamma", "lr"))
def _ac_update(state: _ACState, batch: Dict, gamma: float, lr: float):
    opt = adam(lr)
    s, a, r, s2, done = (batch["s"], batch["a"], batch["r"], batch["s2"],
                         batch["done"])
    v2 = mlp_apply(state.value, s2)[:, 0]
    target = r + gamma * (1 - done) * v2
    target = jax.lax.stop_gradient(target)

    def value_loss(vp):
        v = mlp_apply(vp, s)[:, 0]
        return jnp.mean(jnp.square(v - target))

    lv, gv = jax.value_and_grad(value_loss)(state.value)
    adv = jax.lax.stop_gradient(target - mlp_apply(state.value, s)[:, 0])

    def policy_loss(pp):
        logits = mlp_apply(pp, s)
        logp = jax.nn.log_softmax(logits, axis=-1)
        logp_a = jnp.take_along_axis(logp, a[:, None], -1)[:, 0]
        return -jnp.mean(logp_a * adv)  # NOTE: no entropy bonus (TAC)

    lp, gp = jax.value_and_grad(policy_loss)(state.policy)
    uv, opt_v = opt.update(gv, state.opt_v, state.value)
    up, opt_p = opt.update(gp, state.opt_p, state.policy)
    new = _ACState(apply_updates(state.policy, up),
                   apply_updates(state.value, uv), opt_p, opt_v)
    return new, {"critic_loss": lv, "actor_loss": lp}


class TACAgent:
    name = "tac"
    learns = True

    def __init__(self, state_dim: int, n_actions: int, lr: float = 1e-3,
                 gamma: float = 0.9, batch_size: int = 512, seed: int = 0):
        ks = jax.random.split(jax.random.PRNGKey(seed), 2)
        opt = adam(lr)
        policy = mlp_init(ks[0], state_dim, n_actions)
        value = mlp_init(ks[1], state_dim, 1)
        self.state = _ACState(policy, value, opt.init(policy),
                              opt.init(value))
        self.replay = ReplayBuffer(state_dim, 100_000, seed)
        self.lr, self.gamma, self.batch_size = lr, gamma, batch_size
        self._rng = jax.random.PRNGKey(seed + 1)
        self.metrics: Dict[str, float] = {}

    def act(self, s, greedy: bool = False) -> int:
        logits = mlp_apply(self.state.policy, jnp.asarray(s))
        if greedy:
            return int(jnp.argmax(logits))
        self._rng, k = jax.random.split(self._rng)
        return int(jax.random.categorical(k, logits))

    def observe(self, s, a, r, s2, done):
        self.replay.add(s, a, r, s2, done)

    def update(self):
        if len(self.replay) < self.batch_size:
            return {}
        batch = {k: jnp.asarray(v) for k, v in
                 self.replay.sample(self.batch_size).items()}
        self.state, m = _ac_update(self.state, batch, self.gamma, self.lr)
        self.metrics = {k: float(v) for k, v in m.items()}
        return self.metrics


# =====================================================================
# PPO
# =====================================================================
class _PPOState(NamedTuple):
    policy: Dict
    value: Dict
    opt_p: Tuple
    opt_v: Tuple


@functools.partial(jax.jit, static_argnames=("lr", "clip"))
def _ppo_update(state: _PPOState, batch: Dict, lr: float, clip: float):
    opt = adam(lr)
    s, a, logp_old, adv, ret = (batch["s"], batch["a"], batch["logp"],
                                batch["adv"], batch["ret"])
    adv = (adv - adv.mean()) / (adv.std() + 1e-8)

    def policy_loss(pp):
        logits = mlp_apply(pp, s)
        logp = jax.nn.log_softmax(logits, -1)
        logp_a = jnp.take_along_axis(logp, a[:, None], -1)[:, 0]
        ratio = jnp.exp(logp_a - logp_old)
        return -jnp.mean(jnp.minimum(
            ratio * adv, jnp.clip(ratio, 1 - clip, 1 + clip) * adv))

    def value_loss(vp):
        v = mlp_apply(vp, s)[:, 0]
        return jnp.mean(jnp.square(v - ret))

    lp, gp = jax.value_and_grad(policy_loss)(state.policy)
    lv, gv = jax.value_and_grad(value_loss)(state.value)
    up, opt_p = opt.update(gp, state.opt_p, state.policy)
    uv, opt_v = opt.update(gv, state.opt_v, state.value)
    new = _PPOState(apply_updates(state.policy, up),
                    apply_updates(state.value, uv), opt_p, opt_v)
    return new, {"actor_loss": lp, "critic_loss": lv}


class PPOAgent:
    name = "ppo"
    learns = True

    def __init__(self, state_dim: int, n_actions: int, lr: float = 1e-3,
                 gamma: float = 0.9, lam: float = 0.95, clip: float = 0.2,
                 horizon: int = 256, epochs: int = 4, seed: int = 0):
        ks = jax.random.split(jax.random.PRNGKey(seed), 2)
        opt = adam(lr)
        policy = mlp_init(ks[0], state_dim, n_actions)
        value = mlp_init(ks[1], state_dim, 1)
        self.state = _PPOState(policy, value, opt.init(policy),
                               opt.init(value))
        self.lr, self.gamma, self.lam = lr, gamma, lam
        self.clip, self.horizon, self.epochs = clip, horizon, epochs
        self.buf: List[Tuple] = []
        self._rng = jax.random.PRNGKey(seed + 1)
        self.metrics: Dict[str, float] = {}

    def act(self, s, greedy: bool = False) -> int:
        logits = mlp_apply(self.state.policy, jnp.asarray(s))
        if greedy:
            return int(jnp.argmax(logits))
        self._rng, k = jax.random.split(self._rng)
        a = int(jax.random.categorical(k, logits))
        logp = float(jax.nn.log_softmax(logits)[a])
        self._last_logp = logp
        return a

    def observe(self, s, a, r, s2, done):
        v = float(mlp_apply(self.state.value, jnp.asarray(s))[0])
        self.buf.append((s, a, r, getattr(self, "_last_logp", 0.0), v,
                         float(done)))

    def update(self):
        if len(self.buf) < self.horizon:
            return {}
        s = np.array([t[0] for t in self.buf], np.float32)
        a = np.array([t[1] for t in self.buf], np.int32)
        r = np.array([t[2] for t in self.buf], np.float32)
        logp = np.array([t[3] for t in self.buf], np.float32)
        v = np.array([t[4] for t in self.buf], np.float32)
        done = np.array([t[5] for t in self.buf], np.float32)
        # GAE
        adv = np.zeros_like(r)
        last = 0.0
        next_v = np.append(v[1:], v[-1])
        for t in reversed(range(len(r))):
            delta = r[t] + self.gamma * (1 - done[t]) * next_v[t] - v[t]
            last = delta + self.gamma * self.lam * (1 - done[t]) * last
            adv[t] = last
        ret = adv + v
        batch = {"s": jnp.asarray(s), "a": jnp.asarray(a),
                 "logp": jnp.asarray(logp), "adv": jnp.asarray(adv),
                 "ret": jnp.asarray(ret)}
        for _ in range(self.epochs):
            self.state, m = _ppo_update(self.state, batch, self.lr,
                                        self.clip)
        self.buf.clear()
        self.metrics = {k: float(v) for k, v in m.items()}
        return self.metrics


# =====================================================================
# DDQN
# =====================================================================
class _DQNState(NamedTuple):
    q: Dict
    q_target: Dict
    opt: Tuple
    step: jax.Array


@functools.partial(jax.jit, static_argnames=("gamma", "lr", "tau"))
def _ddqn_update(state: _DQNState, batch: Dict, gamma: float, lr: float,
                 tau: float):
    opt = adam(lr)
    s, a, r, s2, done = (batch["s"], batch["a"], batch["r"], batch["s2"],
                         batch["done"])
    # double-Q: argmax under online net, value under target net
    a2 = jnp.argmax(mlp_apply(state.q, s2), axis=-1)
    q2 = jnp.take_along_axis(mlp_apply(state.q_target, s2),
                             a2[:, None], -1)[:, 0]
    target = jax.lax.stop_gradient(r + gamma * (1 - done) * q2)

    def loss(qp):
        q = jnp.take_along_axis(mlp_apply(qp, s), a[:, None], -1)[:, 0]
        return jnp.mean(jnp.square(q - target))

    l, g = jax.value_and_grad(loss)(state.q)
    u, opt_state = opt.update(g, state.opt, state.q)
    q = apply_updates(state.q, u)
    q_target = soft_update(state.q_target, q, tau)
    return _DQNState(q, q_target, opt_state, state.step + 1), {
        "critic_loss": l}


class DDQNAgent:
    name = "ddqn"
    learns = True

    def __init__(self, state_dim: int, n_actions: int, lr: float = 1e-3,
                 gamma: float = 0.9, tau: float = 0.005,
                 batch_size: int = 512, eps_decay: float = 3e-4,
                 seed: int = 0):
        rng = jax.random.PRNGKey(seed)
        opt = adam(lr)
        q = mlp_init(rng, state_dim, n_actions)
        self.state = _DQNState(q, jax.tree.map(jnp.copy, q), opt.init(q),
                               jnp.zeros((), jnp.int32))
        self.replay = ReplayBuffer(state_dim, 100_000, seed)
        self.lr, self.gamma, self.tau = lr, gamma, tau
        self.batch_size, self.eps_decay = batch_size, eps_decay
        self.n_actions = n_actions
        self.steps = 0
        self.np_rng = np.random.default_rng(seed)
        self.metrics: Dict[str, float] = {}

    @property
    def epsilon(self) -> float:
        return max(0.05, 1.0 - self.eps_decay * self.steps)

    def act(self, s, greedy: bool = False) -> int:
        self.steps += 1
        if not greedy and self.np_rng.random() < self.epsilon:
            return int(self.np_rng.integers(self.n_actions))
        return int(jnp.argmax(mlp_apply(self.state.q, jnp.asarray(s))))

    def observe(self, s, a, r, s2, done):
        self.replay.add(s, a, r, s2, done)

    def update(self):
        if len(self.replay) < self.batch_size:
            return {}
        batch = {k: jnp.asarray(v) for k, v in
                 self.replay.sample(self.batch_size).items()}
        self.state, m = _ddqn_update(self.state, batch, self.gamma,
                                     self.lr, self.tau)
        self.metrics = {k: float(v) for k, v in m.items()}
        return self.metrics


# =====================================================================
# GA — heuristic baseline
# =====================================================================
class GAScheduler:
    """Evolves a population of actions; fitness = observed utility.
    Ignores the state (the paper's GA port optimises a static config)."""

    name = "ga"
    learns = True

    def __init__(self, state_dim: int, n_actions: int, pop: int = 24,
                 mut_p: float = 0.15, seed: int = 0):
        self.n_actions = n_actions
        self.rng = np.random.default_rng(seed)
        self.pop = self.rng.integers(0, n_actions, size=pop)
        self.fitness = np.full(pop, -np.inf)
        self.cursor = 0
        self.mut_p = mut_p
        self.metrics: Dict[str, float] = {}

    def act(self, s, greedy: bool = False) -> int:
        if greedy:
            return int(self.pop[int(np.argmax(self.fitness))])
        return int(self.pop[self.cursor])

    def observe(self, s, a, r, s2, done):
        # running average fitness of the individual just evaluated
        f = self.fitness[self.cursor]
        self.fitness[self.cursor] = r if not np.isfinite(f) else 0.8 * f + 0.2 * r
        self.cursor = (self.cursor + 1) % len(self.pop)

    def update(self):
        if self.cursor != 0 or not np.isfinite(self.fitness).all():
            return {}
        # generation step: tournament selection + crossover + mutation
        n = len(self.pop)
        order = np.argsort(-self.fitness)
        elite = self.pop[order[: n // 4]]
        children = []
        while len(children) < n - len(elite):
            pa, pb = self.rng.choice(elite, 2)
            child = pa if self.rng.random() < 0.5 else pb
            if self.rng.random() < self.mut_p:
                child = int(self.rng.integers(self.n_actions))
            children.append(child)
        self.pop = np.concatenate([elite, np.array(children, dtype=int)])
        best = float(np.max(self.fitness))
        self.fitness = np.full(n, -np.inf)
        self.metrics = {"best_fitness": best,
                        "critic_loss": -best}  # convergence proxy
        return self.metrics


# =====================================================================
# EDF (DeepRT) and Fixed
# =====================================================================
class EDFScheduler:
    """DeepRT [12]: soft real-time EDF dynamic batching.

    Faithful behaviour: picks the LARGEST batch whose estimated completion
    (offline single-tenant latency profile + expected fill wait) still
    meets the earliest deadline; never runs concurrent instances and —
    crucially, per the paper's comparison table — has NO interference
    prediction, so its feasibility estimates are single-tenant-optimistic
    and break under multi-tenant contention.

    Decodes queue length / age / SLO / model compute from the featurized
    state (layout in serving/features.py).
    """

    name = "edf"
    learns = False

    def __init__(self, batch_sizes, concurrency_levels, queue_feature: int,
                 n_models: int = 6, arrival_rps: float = 30.0,
                 platform: str = "xavier_nx", **_):
        self.batch_sizes = list(batch_sizes)
        self.queue_feature = queue_feature
        self.n_models = n_models
        self.arrival_rps = arrival_rps
        from repro.serving.platforms import PLATFORMS

        self.hw = PLATFORMS[platform]

    def act(self, s, greedy: bool = False) -> int:
        from repro.serving import latency_model as lm
        from repro.configs.paper_edge_models import EdgeModelProfile

        qlen = max(1.0, float(np.expm1(s[self.queue_feature])))
        slo_ms = float(s[self.n_models]) * 100.0
        gflops = float(np.expm1(s[self.n_models + 1]))
        age_ratio = float(np.expm1(s[self.queue_feature + 1]))
        slack_ms = max(slo_ms * (1.0 - age_ratio), 2.0)
        prof = EdgeModelProfile("x", "x", "x", (3, 224, 224), slo_ms,
                                gflops, 10.0, 12.0)
        pick = self.batch_sizes[0]
        for b in sorted(self.batch_sizes, reverse=True):
            fill_wait = max(0.0, b - qlen) * 1000.0 / self.arrival_rps
            est = lm.estimate_execution(self.hw, prof, b, 1)  # single-tenant
            if fill_wait + est.total_ms <= slack_ms:
                pick = b
                break
        return self.batch_sizes.index(pick)  # m_c index 0 => m_c = 1

    def observe(self, *a):
        pass

    def update(self):
        return {}


class FixedScheduler:
    name = "fixed"
    learns = False

    def __init__(self, action: int, **_):
        self.action = action

    def act(self, s, greedy: bool = False) -> int:
        return self.action

    def observe(self, *a):
        pass

    def update(self):
        return {}
