"""SLO-aware interference predictor (paper §IV-F, Figs. 5/13/14).

A two-layer MLP predicts the end-to-end latency of a (batch, concurrency)
schedule from the currently available resources — capturing the *nonlinear*
latency inflation when concurrent instances contend (the paper shows a
linear-regression model has ~2x the error). Trained online from profiler
feedback by minimising squared error.

Feature vector (matches Fig. 5): [mem_avail, cpu_util, accel_util,
m_c, b, model_gflops, model_mem].
"""
from __future__ import annotations

import functools
from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.networks import mlp_apply, mlp_init
from repro.train.optimizer import adam, apply_updates

N_FEATURES = 7


class _PredState(NamedTuple):
    net: Dict
    opt: Tuple


@functools.partial(jax.jit, static_argnames=("lr",))
def _pred_update(state: _PredState, x: jax.Array, y: jax.Array, lr: float):
    opt = adam(lr)

    def loss(net):
        pred = mlp_apply(net, x)[:, 0]
        return jnp.mean(jnp.square(pred - y))

    l, g = jax.value_and_grad(loss)(state.net)
    u, opt_state = opt.update(g, state.opt, state.net)
    return _PredState(apply_updates(state.net, u), opt_state), l


class NNInterferencePredictor:
    """Predicts log-latency (seconds); exp() at the boundary for stability."""

    name = "nn"

    def __init__(self, lr: float = 1e-3, seed: int = 0,
                 batch_size: int = 64):
        rng = jax.random.PRNGKey(seed)
        opt = adam(lr)
        net = mlp_init(rng, N_FEATURES, 1)
        self.state = _PredState(net, opt.init(net))
        self.lr = lr
        self.batch_size = batch_size
        self.xs: list = []
        self.ys: list = []
        self.rng = np.random.default_rng(seed)
        # running feature standardisation (Welford-ish, numpy)
        self._mu = np.zeros(N_FEATURES, np.float32)
        self._var = np.ones(N_FEATURES, np.float32)
        self._count = 0

    def _norm(self, X: np.ndarray) -> np.ndarray:
        # winsorize: feature dims the training data barely varied (e.g.
        # model-specific footprints when one model dominates the
        # samples) otherwise normalise to huge values for other models,
        # and the MLP saturates at its output clip instead of falling
        # back on the dims it did learn (b, m_c, utilisation)
        z = (X - self._mu) / np.sqrt(self._var + 1e-6)
        return np.clip(z, -6.0, 6.0)

    def _update_stats(self, X: np.ndarray) -> None:
        X = np.atleast_2d(X)
        n = len(X)
        tot = self._count + n
        mu = (self._mu * self._count + X.sum(0)) / tot
        var = (self._var * self._count
               + ((X - mu) ** 2).sum(0)) / tot
        self._mu, self._var, self._count = mu.astype(np.float32), \
            np.maximum(var, 1e-6).astype(np.float32), tot

    def predict(self, feats: np.ndarray) -> float:
        x = self._norm(np.asarray(feats, np.float32))
        out = mlp_apply(self.state.net, jnp.asarray(x))
        return float(np.exp(np.clip(out[..., 0], -10, 6)))

    def observe(self, feats: np.ndarray, latency_s: float) -> None:
        self.xs.append(np.asarray(feats, np.float32))
        self.ys.append(np.log(max(latency_s, 1e-6)))
        if len(self.xs) >= self.batch_size:
            self.fit_step()

    def fit_step(self, epochs: int = 8) -> float:
        if not self.xs:
            return 0.0
        X = np.stack(self.xs)
        self._update_stats(X)
        x = jnp.asarray(self._norm(X))
        y = jnp.asarray(np.asarray(self.ys, np.float32))
        loss = 0.0
        for _ in range(epochs):
            self.state, loss = _pred_update(self.state, x, y, self.lr)
        self.xs, self.ys = [], []
        return float(loss)

    def fit(self, X: np.ndarray, y_latency: np.ndarray,
            epochs: int = 200) -> float:
        """Offline fit (Fig. 13 protocol: 1600 train / 400 validation)."""
        self._update_stats(np.asarray(X, np.float32))
        x = jnp.asarray(self._norm(np.asarray(X, np.float32)))
        y = jnp.asarray(np.log(np.maximum(y_latency, 1e-6)), jnp.float32)
        loss = 0.0
        for _ in range(epochs):
            self.state, loss = _pred_update(self.state, x, y, self.lr)
        return float(loss)


class LinearInterferencePredictor:
    """Ridge linear regression baseline [refs 16, 46 in the paper]."""

    name = "linear"

    def __init__(self, ridge: float = 1e-3, **_):
        self.w = np.zeros(N_FEATURES + 1, np.float32)
        self.ridge = ridge
        self._X: list = []
        self._y: list = []

    def _design(self, X: np.ndarray) -> np.ndarray:
        X = np.atleast_2d(X)
        return np.concatenate([X, np.ones((len(X), 1))], axis=1)

    def predict(self, feats: np.ndarray) -> float:
        z = float((self._design(np.asarray(feats, np.float32)) @ self.w)[0])
        return float(np.exp(np.clip(z, -10, 6)))

    def observe(self, feats: np.ndarray, latency_s: float) -> None:
        self._X.append(np.asarray(feats, np.float32))
        self._y.append(np.log(max(latency_s, 1e-6)))
        if len(self._X) % 256 == 0:
            self.fit(np.stack(self._X), np.exp(np.asarray(self._y)))

    def fit(self, X: np.ndarray, y_latency: np.ndarray, **_) -> float:
        A = self._design(X)
        y = np.log(np.maximum(y_latency, 1e-6))
        reg = self.ridge * np.eye(A.shape[1])
        self.w = np.linalg.solve(A.T @ A + reg, A.T @ y).astype(np.float32)
        resid = A @ self.w - y
        return float(np.mean(resid ** 2))


def interference_features(mem_avail_gb: float, cpu_util: float,
                          accel_util: float, m_c: int, b: int,
                          gflops: float, model_mem_gb: float) -> np.ndarray:
    return np.array([mem_avail_gb, cpu_util, accel_util, float(m_c),
                     np.log1p(float(b)), np.log1p(gflops),
                     model_mem_gb], np.float32)


def engine_features(cfg, m_c: int, b: int,
                    total_instances: int) -> np.ndarray:
    """Fig.-5 feature vector for a MEASURED engine iteration
    (docs/RUNTIME.md): the multi-model pool has no hardware counters on
    this host, so utilisation is proxied by live-instance counts and the
    per-sample compute/memory footprint is derived analytically from the
    served ``ModelConfig`` (2 FLOPs per active parameter per token).

    ``m_c``/``b`` are the instances and active slots of the observed
    model; ``total_instances`` counts every live instance in the pool
    (other tenants included), which is what drives contention.
    """
    active_p = cfg.param_count_estimate(active_only=True)
    gflops = 2.0 * active_p / 1e9
    weights_gb = 4.0 * cfg.param_count_estimate() / 1e9  # fp32 on host
    return interference_features(
        mem_avail_gb=max(0.0, 8.0 - total_instances * weights_gb),
        cpu_util=min(1.0, 0.125 * total_instances),
        accel_util=min(1.0, 0.125 * total_instances),
        m_c=m_c, b=b, gflops=gflops,
        model_mem_gb=m_c * weights_gb)
