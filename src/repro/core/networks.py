"""Small MLPs for the scheduler agents (paper §V-A: two ReLU hidden layers
of 128 and 64 units) — pure JAX, shared by SAC / TAC / PPO / DDQN and the
interference predictor."""
from __future__ import annotations

from typing import Dict, List, Sequence

import jax
import jax.numpy as jnp

HIDDEN = (128, 64)


def mlp_init(rng, in_dim: int, out_dim: int,
             hidden: Sequence[int] = HIDDEN,
             out_scale: float = 1.0) -> Dict:
    sizes = [in_dim, *hidden, out_dim]
    ks = jax.random.split(rng, len(sizes) - 1)
    layers: List[Dict] = []
    for i, (k, (a, b)) in enumerate(zip(ks, zip(sizes[:-1], sizes[1:]))):
        scale = jnp.sqrt(2.0 / a)
        if i == len(sizes) - 2:
            scale = scale * out_scale
        w = jax.random.normal(k, (a, b), jnp.float32) * scale
        layers.append({"w": w, "b": jnp.zeros((b,), jnp.float32)})
    return {"layers": layers}


def mlp_apply(params: Dict, x: jax.Array) -> jax.Array:
    h = x
    layers = params["layers"]
    for i, layer in enumerate(layers):
        h = h @ layer["w"] + layer["b"]
        if i < len(layers) - 1:
            h = jax.nn.relu(h)
    return h


def soft_update(target: Dict, online: Dict, tau: float) -> Dict:
    return jax.tree.map(lambda t, o: (1 - tau) * t + tau * o, target, online)
