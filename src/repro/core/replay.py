"""Uniform replay buffer (numpy ring), paper buffer size 1e6.

Storage is allocated lazily in geometrically-growing chunks: the paper's
1e6-transition capacity would eagerly commit two ``(1e6, state_dim)``
float32 arrays even for a smoke run that stores a few hundred
transitions. Arrays start at ``INITIAL_ROWS`` and double (capped at
``capacity``) as transitions arrive; ring semantics and ``sample()``
behaviour are unchanged — once ``capacity`` rows have been written the
write index wraps and old transitions are overwritten in order.
"""
from __future__ import annotations

from typing import Dict

import numpy as np


class ReplayBuffer:
    INITIAL_ROWS = 1024

    def __init__(self, state_dim: int, capacity: int = 1_000_000,
                 seed: int = 0):
        self.capacity = capacity
        self.state_dim = state_dim
        rows = min(capacity, self.INITIAL_ROWS)
        self.s = np.zeros((rows, state_dim), np.float32)
        self.a = np.zeros((rows,), np.int32)
        self.r = np.zeros((rows,), np.float32)
        self.s2 = np.zeros((rows, state_dim), np.float32)
        self.done = np.zeros((rows,), np.float32)
        self.idx = 0
        self.full = False
        self.rng = np.random.default_rng(seed)

    @property
    def allocated_rows(self) -> int:
        return self.s.shape[0]

    def _grow(self) -> None:
        """Double the backing arrays (capped at ``capacity``)."""
        rows = min(self.capacity, max(1, 2 * self.allocated_rows))
        extra = rows - self.allocated_rows
        if extra <= 0:
            return
        for name in ("s", "a", "r", "s2", "done"):
            arr = getattr(self, name)
            pad = np.zeros((extra,) + arr.shape[1:], arr.dtype)
            setattr(self, name, np.concatenate([arr, pad]))

    def __len__(self) -> int:
        return self.capacity if self.full else self.idx

    def add(self, s, a, r, s2, done) -> None:
        i = self.idx
        if i >= self.allocated_rows:
            self._grow()
        self.s[i] = s
        self.a[i] = a
        self.r[i] = r
        self.s2[i] = s2
        self.done[i] = float(done)
        self.idx = (i + 1) % self.capacity
        self.full = self.full or self.idx == 0

    def sample(self, batch: int) -> Dict[str, np.ndarray]:
        n = len(self)
        idx = self.rng.integers(0, n, size=batch)
        return {"s": self.s[idx], "a": self.a[idx], "r": self.r[idx],
                "s2": self.s2[idx], "done": self.done[idx]}
