"""Uniform replay buffer (numpy ring), paper buffer size 1e6."""
from __future__ import annotations

from typing import Dict

import numpy as np


class ReplayBuffer:
    def __init__(self, state_dim: int, capacity: int = 1_000_000,
                 seed: int = 0):
        self.capacity = capacity
        self.s = np.zeros((capacity, state_dim), np.float32)
        self.a = np.zeros((capacity,), np.int32)
        self.r = np.zeros((capacity,), np.float32)
        self.s2 = np.zeros((capacity, state_dim), np.float32)
        self.done = np.zeros((capacity,), np.float32)
        self.idx = 0
        self.full = False
        self.rng = np.random.default_rng(seed)

    def __len__(self) -> int:
        return self.capacity if self.full else self.idx

    def add(self, s, a, r, s2, done) -> None:
        i = self.idx
        self.s[i] = s
        self.a[i] = a
        self.r[i] = r
        self.s2[i] = s2
        self.done[i] = float(done)
        self.idx = (i + 1) % self.capacity
        self.full = self.full or self.idx == 0

    def sample(self, batch: int) -> Dict[str, np.ndarray]:
        n = len(self)
        idx = self.rng.integers(0, n, size=batch)
        return {"s": self.s[idx], "a": self.a[idx], "r": self.r[idx],
                "s2": self.s2[idx], "done": self.done[idx]}
