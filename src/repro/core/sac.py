"""Discrete Soft Actor-Critic scheduler (paper §IV-B, Algorithm 1).

Maximum-entropy objective (Eq. 5): maximise Σ γ^t [r + α H(π(·|s))].
Components map 1:1 onto the paper:

* twin soft-Q critics + target copies, min-of-two to curb overestimation;
* soft state value (Eq. 8):  V(s) = π(s)ᵀ [Q(s) − α log π(s)];
* critic loss = soft Bellman residual (Eq. 9);
* actor loss = KL-projection surrogate (Eq. 11):
      J_π = E_s [ π(s)ᵀ (α log π(s) − Q(s)) ];
* automatic temperature (Eq. 12) against a target entropy H̄.

All updates are jit-compiled pure functions over a NamedTuple state.
"""
from __future__ import annotations

import functools
from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.networks import mlp_apply, mlp_init, soft_update
from repro.core.replay import ReplayBuffer
from repro.train.optimizer import adam, apply_updates


class SACState(NamedTuple):
    policy: Dict
    q1: Dict
    q2: Dict
    q1_target: Dict
    q2_target: Dict
    log_alpha: jax.Array
    opt_policy: Tuple
    opt_q1: Tuple
    opt_q2: Tuple
    opt_alpha: Tuple
    step: jax.Array


class SACConfig(NamedTuple):
    gamma: float = 0.9
    tau: float = 0.005
    lr: float = 1e-3          # paper: Adam, lr 1e-3
    batch_size: int = 512     # paper: mini-batch 512
    reward_scale: float = 0.25
    target_entropy_scale: float = 0.25
    update_every: int = 1


def _policy_dist(policy, s):
    logits = mlp_apply(policy, s)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return jnp.exp(logp), logp


@functools.partial(jax.jit, static_argnames=("cfg", "n_actions"))
def sac_update(state: SACState, batch: Dict, cfg: SACConfig,
               n_actions: int) -> Tuple[SACState, Dict]:
    opt = adam(cfg.lr)
    s, a, r, s2, done = (batch["s"], batch["a"],
                         batch["r"] * cfg.reward_scale, batch["s2"],
                         batch["done"])
    alpha = jnp.exp(state.log_alpha)
    target_entropy = cfg.target_entropy_scale * jnp.log(float(n_actions))

    # ---- critic update (Eq. 7-9) -------------------------------------
    pi2, logp2 = _policy_dist(state.policy, s2)
    q1_t = mlp_apply(state.q1_target, s2)
    q2_t = mlp_apply(state.q2_target, s2)
    v2 = jnp.sum(pi2 * (jnp.minimum(q1_t, q2_t) - alpha * logp2), axis=-1)
    target = r + cfg.gamma * (1.0 - done) * v2  # (B,)
    target = jax.lax.stop_gradient(target)

    def critic_loss(qp):
        q = mlp_apply(qp, s)
        qa = jnp.take_along_axis(q, a[:, None], axis=-1)[:, 0]
        return 0.5 * jnp.mean(jnp.square(qa - target))

    l1, g1 = jax.value_and_grad(critic_loss)(state.q1)
    l2, g2 = jax.value_and_grad(critic_loss)(state.q2)
    u1, opt_q1 = opt.update(g1, state.opt_q1, state.q1)
    u2, opt_q2 = opt.update(g2, state.opt_q2, state.q2)
    q1 = apply_updates(state.q1, u1)
    q2 = apply_updates(state.q2, u2)

    # ---- actor update (Eq. 11) ----------------------------------------
    q_min = jax.lax.stop_gradient(
        jnp.minimum(mlp_apply(q1, s), mlp_apply(q2, s)))

    def actor_loss(pp):
        pi, logp = _policy_dist(pp, s)
        return jnp.mean(jnp.sum(pi * (alpha * logp - q_min), axis=-1))

    la, ga = jax.value_and_grad(actor_loss)(state.policy)
    up, opt_policy = opt.update(ga, state.opt_policy, state.policy)
    policy = apply_updates(state.policy, up)

    # ---- temperature update (Eq. 12) -----------------------------------
    pi, logp = _policy_dist(policy, s)
    entropy = -jnp.sum(pi * logp, axis=-1)

    def alpha_loss(log_alpha):
        return jnp.mean(jnp.exp(log_alpha) *
                        jax.lax.stop_gradient(entropy - target_entropy))

    lt, gt = jax.value_and_grad(alpha_loss)(state.log_alpha)
    ut, opt_alpha = opt.update(gt, state.opt_alpha, state.log_alpha)
    log_alpha = jnp.clip(state.log_alpha + ut, -4.0, 1.5)

    # ---- target sync ----------------------------------------------------
    q1_target = soft_update(state.q1_target, q1, cfg.tau)
    q2_target = soft_update(state.q2_target, q2, cfg.tau)

    new_state = SACState(policy, q1, q2, q1_target, q2_target, log_alpha,
                         opt_policy, opt_q1, opt_q2, opt_alpha,
                         state.step + 1)
    metrics = {"critic_loss": 0.5 * (l1 + l2), "actor_loss": la,
               "alpha": jnp.exp(log_alpha), "entropy": jnp.mean(entropy),
               "alpha_loss": lt}
    return new_state, metrics


@functools.partial(jax.jit, static_argnames=())
def sac_act(policy: Dict, s: jax.Array, rng) -> jax.Array:
    logits = mlp_apply(policy, s)
    return jax.random.categorical(rng, logits)


class SACAgent:
    """Online wrapper: replay + act/observe/update, numpy at the boundary."""

    name = "sac"
    learns = True

    def __init__(self, state_dim: int, n_actions: int,
                 cfg: SACConfig = SACConfig(), seed: int = 0,
                 buffer_size: int = 1_000_000):
        self.cfg = cfg
        self.n_actions = n_actions
        rng = jax.random.PRNGKey(seed)
        ks = jax.random.split(rng, 6)
        opt = adam(cfg.lr)
        # small policy head => near-uniform initial policy (max entropy)
        policy = mlp_init(ks[0], state_dim, n_actions, out_scale=0.01)
        q1 = mlp_init(ks[1], state_dim, n_actions)
        q2 = mlp_init(ks[2], state_dim, n_actions)
        log_alpha = jnp.zeros((), jnp.float32)
        self.state = SACState(
            policy, q1, q2, jax.tree.map(jnp.copy, q1),
            jax.tree.map(jnp.copy, q2), log_alpha,
            opt.init(policy), opt.init(q1), opt.init(q2),
            opt.init(log_alpha), jnp.zeros((), jnp.int32))
        self.replay = ReplayBuffer(state_dim, buffer_size, seed)
        self._rng = jax.random.PRNGKey(seed + 1)
        self.metrics: Dict[str, float] = {}

    def act(self, s: np.ndarray, greedy: bool = False) -> int:
        if greedy:
            logits = mlp_apply(self.state.policy, jnp.asarray(s))
            return int(jnp.argmax(logits))
        self._rng, k = jax.random.split(self._rng)
        return int(sac_act(self.state.policy, jnp.asarray(s), k))

    def observe(self, s, a, r, s2, done) -> None:
        self.replay.add(s, a, r, s2, done)

    def update(self) -> Dict[str, float]:
        if len(self.replay) < self.cfg.batch_size:
            return {}
        batch = {k: jnp.asarray(v) for k, v in
                 self.replay.sample(self.cfg.batch_size).items()}
        self.state, m = sac_update(self.state, batch, self.cfg,
                                   self.n_actions)
        self.metrics = {k: float(v) for k, v in m.items()}
        return self.metrics

    # ---- deployment checkpointing (paper §V-A: train offline, deploy) ---
    def save(self, path: str) -> str:
        from repro.train.checkpoint import save_checkpoint

        nets = {"policy": self.state.policy, "q1": self.state.q1,
                "q2": self.state.q2, "q1_target": self.state.q1_target,
                "q2_target": self.state.q2_target,
                "log_alpha": self.state.log_alpha}
        return save_checkpoint(path, nets, {"n_actions": self.n_actions,
                                            "step": int(self.state.step)})

    def load(self, path: str) -> None:
        from repro.train.checkpoint import load_checkpoint, restore_like

        loaded = load_checkpoint(path)
        if loaded["__meta__"].get("n_actions") != self.n_actions:
            raise ValueError("checkpoint action-space mismatch")
        nets = {"policy": self.state.policy, "q1": self.state.q1,
                "q2": self.state.q2, "q1_target": self.state.q1_target,
                "q2_target": self.state.q2_target,
                "log_alpha": self.state.log_alpha}
        restored = restore_like(nets, loaded)
        self.state = self.state._replace(
            policy=restored["policy"], q1=restored["q1"],
            q2=restored["q2"], q1_target=restored["q1_target"],
            q2_target=restored["q2_target"],
            log_alpha=restored["log_alpha"])
