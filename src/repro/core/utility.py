"""BCEdge utility objective (paper Eqs. 1, 3, 4).

Eq. 1: the i-th scheduling time slot is the batch SLO budget divided by the
number of concurrent instances::

    t_i = (Σ_{j=1..b} SLO_j) / m_c

Eq. 3: the throughput/latency trade-off utility::

    U = log( T(b, m_c) / ( L(b, m_c) / t_i ) )

L / t_i ∈ (0, 1] when the batch meets its slot budget, so U rewards high
throughput and penalises latency *relative to the SLO budget* — a model with
loose SLOs tolerates larger batches. (Eq. 4 writes "min U" but the text,
reward definition r_t = U and all experiments maximise it; we treat that as
a typo and maximise.)

The constrained form (Eq. 4) is enforced by the environment: actions whose
predicted memory exceeds capacity or whose predicted latency violates the
SLO are penalised (soft constraint via the utility collapse + explicit
violation penalty), mirroring how the real system would observe them.
"""
from __future__ import annotations

import numpy as np


def scheduling_slot(slo_sum_s: float, m_c: int) -> float:
    """Eq. 1. ``slo_sum_s`` = Σ SLO over the batch, in seconds."""
    return slo_sum_s / max(m_c, 1)


def utility(throughput_rps: float, latency_s: float, slo_sum_s: float,
            m_c: int, eps: float = 1e-6) -> float:
    """Eq. 3. Higher is better."""
    slot = scheduling_slot(slo_sum_s, m_c)
    norm_latency = latency_s / max(slot, eps)
    return float(np.log(max(throughput_rps, eps) / max(norm_latency, eps)))


def normalized_utility(u: float, u_max: float) -> float:
    return u / u_max if u_max > 0 else 0.0
