"""Pallas TPU kernels for the serving hot spots (flash/decode attention,
RWKV-6 scan, RG-LRU scan, grouped MoE GEMM) + jnp oracles in ref.py."""
from repro.kernels import ops  # noqa: F401
