"""Flash-decoding: one query token against a long KV cache, Pallas TPU.

Grid (batch, q_head, cache_blocks) with the cache sweep innermost and
sequential; the running max / denominator / accumulator live in VMEM
scratch. Cache blocks stream HBM->VMEM; the query row and accumulator stay
resident. Invalid cache slots (ring-buffer holes, unwritten tail) are
masked via the ``valid`` operand, which also carries per-row positions so
the same kernel serves linear and ring caches.

Two cache layouts share the same online-softmax body:

* ``decode_attention``        — dense (B, C, KV, hd) per-slot caches.
* ``paged_decode_attention``  — vLLM-style block pool (N, bs, KV, hd)
  indirected through a per-sequence **block table** (docs/ARCHITECTURE.md
  §5): the grid sweeps *logical* blocks and the block table, scalar-
  prefetched so the index map can resolve logical→physical before the
  DMA is issued, picks the physical pool block to stream. The ragged
  tail is masked from per-sequence lengths (the paged counterpart of the
  ``valid`` operand).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1.0e30


def _decode_kernel(q_ref, k_ref, v_ref, valid_ref, o_ref, m_scr, l_scr,
                   acc_scr, *, scale: float, n_blocks: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)          # (1, hd)
    k = k_ref[0, 0].astype(jnp.float32)          # (bc, hd)
    v = v_ref[0, 0].astype(jnp.float32)          # (bc, hd)
    valid = valid_ref[0]                          # (1, bc) bool
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (1,bc)
    s = jnp.where(valid, s, NEG)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(j == n_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("scale", "block_c", "interpret"))
def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     valid: jax.Array, scale: float, *, block_c: int = 512,
                     interpret: bool = False) -> jax.Array:
    """q (B,1,H,hd); k/v (B,C,KV,hd); valid (B,C) bool -> (B,1,H,hd)."""
    B, _, H, hd = q.shape
    C, KV = k.shape[1], k.shape[2]
    qpk = H // KV
    bc = min(block_c, C)
    C_pad = -(-C // bc) * bc
    kt = jnp.moveaxis(k, 2, 1)  # (B,KV,C,hd)
    vt = jnp.moveaxis(v, 2, 1)
    val = valid
    if C_pad != C:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, C_pad - C), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, C_pad - C), (0, 0)))
        val = jnp.pad(valid, ((0, 0), (0, C_pad - C)))
    qt = jnp.moveaxis(q, 2, 1)  # (B,H,1,hd)
    val = val[:, None, :]  # (B,1,C)
    n_blocks = C_pad // bc

    kernel = functools.partial(_decode_kernel, scale=scale,
                               n_blocks=n_blocks)
    out = pl.pallas_call(
        kernel,
        grid=(B, H, n_blocks),
        in_specs=[
            pl.BlockSpec((1, 1, 1, hd), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bc, hd),
                         lambda b, h, j, _qpk=qpk: (b, h // _qpk, j, 0)),
            pl.BlockSpec((1, 1, bc, hd),
                         lambda b, h, j, _qpk=qpk: (b, h // _qpk, j, 0)),
            pl.BlockSpec((1, 1, bc), lambda b, h, j: (b, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, hd), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, 1, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt, val)
    return jnp.moveaxis(out, 1, 2)


def _paged_decode_kernel(tbl_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                         m_scr, l_scr, acc_scr, *, scale: float,
                         block_size: int, n_blocks: int):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # the serial sweep is bounded by the sequence's live block count,
    # not the table width: blocks past the frontier are skipped entirely
    @pl.when(j * block_size < len_ref[b])
    def _update():
        q = q_ref[0, 0].astype(jnp.float32)            # (1, hd)
        k = k_ref[0, :, 0].astype(jnp.float32)         # (bs, hd)
        v = v_ref[0, :, 0].astype(jnp.float32)         # (bs, hd)
        # ragged tail: logical slot j*bs + i is valid iff < seq_len[b]
        slot = jax.lax.broadcasted_iota(jnp.int32, (1, block_size), 1) \
            + j * block_size
        valid = slot < len_ref[b]
        s = jnp.dot(q, k.T,
                    preferred_element_type=jnp.float32) * scale  # (1,bs)
        s = jnp.where(valid, s, NEG)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(j == n_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def _dead_to_null(j, tbl, lens, b, bs):
    """Index-map helper: physical block for logical block j of sequence
    b, with blocks past the frontier redirected to the null block so the
    padded tail of the table is never read (its entries may be garbage).
    """
    return jnp.where(j * bs < lens[b], tbl[b, j], 0)


@functools.partial(jax.jit,
                   static_argnames=("scale", "max_blocks", "interpret"))
def paged_decode_attention(q: jax.Array, k_pool: jax.Array,
                           v_pool: jax.Array, block_tables: jax.Array,
                           seq_lens: jax.Array, scale: float, *,
                           max_blocks: int | None = None,
                           interpret: bool = False) -> jax.Array:
    """Flash-decoding over a paged KV pool.

    q (B,1,H,hd); k_pool/v_pool (N, bs, KV, hd) physical blocks;
    block_tables (B, nb) int32 — logical block j of sequence b lives in
    physical block ``block_tables[b, j]``. Entries past a sequence's
    frontier (``j*bs >= seq_lens[b]``) are **never read**: the index map
    redirects dead columns to the null block and ``pl.when`` skips their
    compute, so the serial sweep is bounded by each sequence's live
    block count rather than the table width. ``max_blocks`` (static)
    additionally trims the grid when the caller knows a tighter bound on
    ``max(ceil(seq_lens / bs))``. seq_lens (B,) int32 — number of valid
    logical slots per sequence. Returns (B,1,H,hd).
    """
    B, _, H, hd = q.shape
    bs = k_pool.shape[1]
    KV = k_pool.shape[2]
    qpk = H // KV
    nb = block_tables.shape[1]
    if max_blocks is not None:
        nb = max(1, min(nb, max_blocks))
    qt = jnp.moveaxis(q, 2, 1)  # (B,H,1,hd)

    kernel = functools.partial(_paged_decode_kernel, scale=scale,
                               block_size=bs, n_blocks=nb)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # block_tables, seq_lens
        grid=(B, H, nb),
        in_specs=[
            pl.BlockSpec((1, 1, 1, hd),
                         lambda b, h, j, tbl, lens: (b, h, 0, 0)),
            pl.BlockSpec((1, bs, 1, hd),
                         lambda b, h, j, tbl, lens, _qpk=qpk, _bs=bs:
                         (_dead_to_null(j, tbl, lens, b, _bs),
                          0, h // _qpk, 0)),
            pl.BlockSpec((1, bs, 1, hd),
                         lambda b, h, j, tbl, lens, _qpk=qpk, _bs=bs:
                         (_dead_to_null(j, tbl, lens, b, _bs),
                          0, h // _qpk, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, hd),
                               lambda b, h, j, tbl, lens: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, 1, hd), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), seq_lens.astype(jnp.int32),
      qt, k_pool, v_pool)
    return jnp.moveaxis(out, 1, 2)


def _paged_splitk_kernel(tbl_ref, len_ref, q_ref, k_ref, v_ref,
                         o_ref, m_ref, l_ref, m_scr, l_scr, acc_scr, *,
                         scale: float, block_size: int,
                         blocks_per_split: int):
    b = pl.program_id(0)
    s = pl.program_id(2)
    jj = pl.program_id(3)
    j = s * blocks_per_split + jj  # logical block index

    @pl.when(jj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(j * block_size < len_ref[b])
    def _update():
        q = q_ref[0, 0].astype(jnp.float32)            # (1, hd)
        k = k_ref[0, :, 0].astype(jnp.float32)         # (bs, hd)
        v = v_ref[0, :, 0].astype(jnp.float32)         # (bs, hd)
        slot = jax.lax.broadcasted_iota(jnp.int32, (1, block_size), 1) \
            + j * block_size
        valid = slot < len_ref[b]
        sc = jnp.dot(q, k.T,
                     preferred_element_type=jnp.float32) * scale  # (1,bs)
        sc = jnp.where(valid, sc, NEG)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(sc, axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(sc - m_new[:, None])
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(jj == blocks_per_split - 1)
    def _finalize():
        # per-split partials: UNNORMALIZED accumulator plus the split's
        # running max / denominator; the host-side reduction combines
        # them with a stable log-sum-exp
        o_ref[0, 0, 0] = acc_scr[0].astype(o_ref.dtype)
        m_ref[0, 0, 0] = m_scr[...]
        l_ref[0, 0, 0] = l_scr[...]


@functools.partial(jax.jit,
                   static_argnames=("scale", "n_splits", "interpret"))
def paged_decode_attention_splitk(q: jax.Array, k_pool: jax.Array,
                                  v_pool: jax.Array,
                                  block_tables: jax.Array,
                                  seq_lens: jax.Array, scale: float, *,
                                  n_splits: int = 4,
                                  interpret: bool = False) -> jax.Array:
    """Split-K flash-decoding over a paged KV pool.

    Same contract as :func:`paged_decode_attention`, but the logical KV
    axis is partitioned into ``n_splits`` independent grid slices, each
    producing a partial (max, denominator, unnormalized accumulator)
    triple; a block-wise max/sum reduction pass outside the kernel
    rescales and merges them. On hardware the splits run in parallel, so
    long-context decode latency drops from O(blocks) to
    O(blocks / n_splits + n_splits). Dead blocks (past each sequence's
    frontier) are skipped and their table entries never read; a split
    whose every block is dead contributes weight exp(NEG - m) = 0.
    """
    B, _, H, hd = q.shape
    bs = k_pool.shape[1]
    KV = k_pool.shape[2]
    qpk = H // KV
    nb = block_tables.shape[1]
    n_splits = max(1, min(n_splits, nb))
    bps = -(-nb // n_splits)          # blocks per split
    n_splits = -(-nb // bps)          # drop splits that would be empty
    qt = jnp.moveaxis(q, 2, 1)  # (B,H,1,hd)

    def _phys(j, tbl, lens, b, _bs=bs, _nb=nb):
        # clamp j for the (padded) final split before the table read;
        # dead blocks (incl. all j >= nb, whose slots are >= lens) are
        # redirected to the null block and skipped in-kernel
        jc = jnp.minimum(j, _nb - 1)
        return jnp.where(j * _bs < lens[b], tbl[b, jc], 0)

    kernel = functools.partial(_paged_splitk_kernel, scale=scale,
                               block_size=bs, blocks_per_split=bps)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # block_tables, seq_lens
        grid=(B, H, n_splits, bps),
        in_specs=[
            pl.BlockSpec((1, 1, 1, hd),
                         lambda b, h, s, jj, tbl, lens: (b, h, 0, 0)),
            pl.BlockSpec((1, bs, 1, hd),
                         lambda b, h, s, jj, tbl, lens, _qpk=qpk, _bps=bps:
                         (_phys(s * _bps + jj, tbl, lens, b),
                          0, h // _qpk, 0)),
            pl.BlockSpec((1, bs, 1, hd),
                         lambda b, h, s, jj, tbl, lens, _qpk=qpk, _bps=bps:
                         (_phys(s * _bps + jj, tbl, lens, b),
                          0, h // _qpk, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, hd),
                         lambda b, h, s, jj, tbl, lens: (b, h, s, 0)),
            pl.BlockSpec((1, 1, 1, 1),
                         lambda b, h, s, jj, tbl, lens: (b, h, s, 0)),
            pl.BlockSpec((1, 1, 1, 1),
                         lambda b, h, s, jj, tbl, lens: (b, h, s, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, hd), jnp.float32),
        ],
    )
    o_p, m_p, l_p = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, H, n_splits, hd), jnp.float32),
            jax.ShapeDtypeStruct((B, H, n_splits, 1), jnp.float32),
            jax.ShapeDtypeStruct((B, H, n_splits, 1), jnp.float32),
        ],
        interpret=interpret,
    )(block_tables.astype(jnp.int32), seq_lens.astype(jnp.int32),
      qt, k_pool, v_pool)

    # reduction pass: rescale each split's partials to the global max
    m_p, l_p = m_p[..., 0], l_p[..., 0]            # (B,H,S)
    m_g = jnp.max(m_p, axis=-1, keepdims=True)     # (B,H,1)
    w = jnp.exp(m_p - m_g)                         # empty split -> 0
    l_g = jnp.maximum(jnp.sum(l_p * w, axis=-1), 1e-30)      # (B,H)
    o = jnp.sum(o_p * w[..., None], axis=2) / l_g[..., None]  # (B,H,hd)
    return o[:, None].astype(q.dtype)
