"""Blockwise online-softmax (flash) attention, Pallas TPU.

TPU-native tiling: the grid is (batch, q_head, q_blocks, k_blocks) with the
K dimension innermost and *sequential* — running max / denominator / output
accumulator live in VMEM scratch and persist across the K sweep for one
(b, h, q_block). Block shapes default to 128/256, MXU-aligned. GQA is
handled in the K/V index maps (kv_head = q_head // q_per_kv) so KV blocks
are fetched once per group member without materialising repeats.

Causal + sliding-window masking is applied with a finite NEG constant so
fully-masked K blocks contribute exp(0-likes)=0 without NaNs.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1.0e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, block_q: int, block_k: int, causal: bool,
                  window: Optional[int], n_k_blocks: int, t_total: int):
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)  # (bq, hd)
    k = k_ref[0, 0].astype(jnp.float32)  # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)  # (bk, hd)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    i = pl.program_id(2)
    q_pos = i * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 0)
    k_pos = j * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 1)
    mask = k_pos < t_total  # never attend to T-padding
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_new = l_prev * alpha + jnp.sum(p, axis=-1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(j == n_k_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("scale", "causal", "window", "block_q", "block_k",
                     "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    scale: float, causal: bool = True,
                    window: Optional[int] = None, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False
                    ) -> jax.Array:
    """q (B,S,H,hd); k/v (B,T,KV,hd) -> (B,S,H,hd).

    S and T are padded to the block sizes internally; positions are
    0..S-1 / 0..T-1 (prefill semantics).
    """
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    qpk = H // KV
    bq, bk = min(block_q, S), min(block_k, T)
    S_pad = -(-S // bq) * bq
    T_pad = -(-T // bk) * bk
    qt = jnp.moveaxis(q, 2, 1)  # (B,H,S,hd)
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)
    if S_pad != S:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, S_pad - S), (0, 0)))
    if T_pad != T:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, T_pad - T), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, T_pad - T), (0, 0)))
        # padded K columns must never win the max: rely on causal/window
        # masking (q_pos < T <= k_pos for pad) when causal; else mask here
    n_q, n_k = S_pad // bq, T_pad // bk

    kernel = functools.partial(
        _flash_kernel, scale=scale, block_q=bq, block_k=bk, causal=causal,
        window=window, n_k_blocks=n_k, t_total=T)
    out = pl.pallas_call(
        kernel,
        grid=(B, H, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, i, j, _qpk=qpk: (b, h // _qpk, j, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, i, j, _qpk=qpk: (b, h // _qpk, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S_pad, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return jnp.moveaxis(out[:, :, :S, :], 1, 2)
