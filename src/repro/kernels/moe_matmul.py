"""Grouped per-expert GEMM, Pallas TPU.

x (E, Cap, d) @ w (E, d, f) -> (E, Cap, f): grid (E, Cap/bc, f/bf, d/bd)
with the contraction (d) sweep innermost and sequential, accumulating in a
VMEM f32 scratch tile; MXU-aligned 128-multiples by default. The expert dim
is the natural expert-parallel shard axis.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _moe_kernel(x_ref, w_ref, o_ref, acc_scr, *, n_d_blocks: int):
    kd = pl.program_id(3)

    @pl.when(kd == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    x = x_ref[0].astype(jnp.float32)  # (bc, bd)
    w = w_ref[0].astype(jnp.float32)  # (bd, bf)
    acc_scr[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)

    @pl.when(kd == n_d_blocks - 1)
    def _final():
        o_ref[0] = acc_scr[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_c", "block_f", "block_d", "interpret"))
def moe_matmul(x: jax.Array, w: jax.Array, *, block_c: int = 128,
               block_f: int = 128, block_d: int = 128,
               interpret: bool = False) -> jax.Array:
    """x (E,C,d) @ w (E,d,f) -> (E,C,f)."""
    E, C, D = x.shape
    F = w.shape[2]
    bc, bf, bd = min(block_c, C), min(block_f, F), min(block_d, D)
    Cp, Fp, Dp = (-(-C // bc) * bc, -(-F // bf) * bf, -(-D // bd) * bd)
    xp = jnp.pad(x, ((0, 0), (0, Cp - C), (0, Dp - D)))
    wp = jnp.pad(w, ((0, 0), (0, Dp - D), (0, Fp - F)))
    n_d = Dp // bd

    kernel = functools.partial(_moe_kernel, n_d_blocks=n_d)
    out = pl.pallas_call(
        kernel,
        grid=(E, Cp // bc, Fp // bf, n_d),
        in_specs=[
            pl.BlockSpec((1, bc, bd), lambda e, i, j, kd: (e, i, kd)),
            pl.BlockSpec((1, bd, bf), lambda e, i, j, kd: (e, kd, j)),
        ],
        out_specs=pl.BlockSpec((1, bc, bf), lambda e, i, j, kd: (e, i, j)),
        out_shape=jax.ShapeDtypeStruct((E, Cp, Fp), x.dtype),
        scratch_shapes=[pltpu.VMEM((bc, bf), jnp.float32)],
        interpret=interpret,
    )(xp, wp)
    return out[:, :C, :F]
