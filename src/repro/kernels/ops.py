"""jit'd dispatch wrappers for the Pallas kernels.

On TPU the kernels lower natively; everywhere else (this CPU container)
they run in ``interpret=True`` mode, which executes the kernel body with
the same blocking/masking logic — that is what the per-kernel allclose
tests validate. ``ref.py`` holds the pure-jnp oracles.
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.kernels import ref  # noqa: F401  (re-exported for tests)
from repro.kernels.decode_attention import decode_attention as _decode
from repro.kernels.decode_attention import (
    paged_decode_attention as _paged_decode)
from repro.kernels.decode_attention import (
    paged_decode_attention_splitk as _paged_decode_splitk)
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.prefill_attention import (
    paged_prefill_attention as _paged_prefill)
from repro.kernels.moe_matmul import moe_matmul as _moe
from repro.kernels.rglru_scan import rglru_scan as _rglru
from repro.kernels.rwkv6_scan import rwkv6_scan as _rwkv


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, positions=None, window: Optional[int] = None,
                    scale: float, causal: bool = True, block_q: int = 128,
                    block_k: int = 128):
    """positions accepted for API parity with the model layer; the kernel
    assumes contiguous 0..S-1 prefill positions (asserted by the caller)."""
    del positions
    return _flash(q, k, v, scale=scale, causal=causal, window=window,
                  block_q=block_q, block_k=block_k,
                  interpret=_interpret())


def decode_attention(q, k, v, valid, scale: float, block_c: int = 512):
    return _decode(q, k, v, valid, scale, block_c=block_c,
                   interpret=_interpret())


def paged_decode_attention(q, k_pool, v_pool, block_tables, seq_lens,
                           scale: float, max_blocks: Optional[int] = None):
    return _paged_decode(q, k_pool, v_pool, block_tables, seq_lens, scale,
                         max_blocks=max_blocks, interpret=_interpret())


def paged_decode_attention_splitk(q, k_pool, v_pool, block_tables,
                                  seq_lens, scale: float,
                                  n_splits: int = 4):
    return _paged_decode_splitk(q, k_pool, v_pool, block_tables, seq_lens,
                                scale, n_splits=n_splits,
                                interpret=_interpret())


def paged_prefill_attention(q, k_pool, v_pool, block_tables, pos,
                            scale: float):
    return _paged_prefill(q, k_pool, v_pool, block_tables, pos, scale,
                          interpret=_interpret())


def rwkv6_scan(r, k, v, w, u, state, chunk: int = 64):
    return _rwkv(r, k, v, w, u, state, chunk=chunk, interpret=_interpret())


def rglru_scan(a, x, h0, chunk: int = 128, block_w: int = 512):
    return _rglru(a, x, h0, chunk=chunk, block_w=block_w,
                  interpret=_interpret())


def moe_matmul(x, w, **kw):
    return _moe(x, w, interpret=_interpret(), **kw)
