"""Fused chunk-prefill attention over a paged KV pool, Pallas TPU.

The chunked-prefill path used to gather every logical block of a
sequence into a dense per-slot staging cache before running attention
(docs/ARCHITECTURE.md §5). This kernel removes that round trip: chunk
queries attend *directly* through the block table, streaming physical
pool blocks HBM->VMEM exactly like :mod:`repro.kernels.decode_attention`
but with a whole query chunk resident instead of one row.

Grid (batch, q_head, logical_blocks) with the KV sweep innermost and
sequential; the online-softmax carry (running max / denominator /
accumulator, one row per chunk query) lives in VMEM scratch. The block
table and per-sequence lengths are scalar-prefetched so the index map
resolves logical→physical before the DMA is issued. Three masks happen
in-kernel:

* **causal chunk suffix** — query row i sits at absolute position
  ``pos[b] + i`` and may only see logical slots ``<= pos[b] + i``;
* **ragged tail** — slots past ``pos[b] + T`` within the last live
  block are excluded by the same comparison;
* **dead blocks** — logical blocks entirely past the sequence frontier
  are skipped via ``pl.when`` *and* their table entries are never read:
  the index map redirects them to the null block, so padded table
  columns may hold arbitrary garbage.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1.0e30


def _paged_prefill_kernel(tbl_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                          m_scr, l_scr, acc_scr, *, scale: float,
                          block_size: int, n_blocks: int, chunk: int,
                          t_pad: int):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # blocks entirely past the sequence frontier contribute nothing:
    # skip the matmul and leave the carry untouched
    @pl.when(j * block_size < len_ref[b])
    def _update():
        q = q_ref[0, 0].astype(jnp.float32)        # (t_pad, hd)
        k = k_ref[0, :, 0].astype(jnp.float32)     # (bs, hd)
        v = v_ref[0, :, 0].astype(jnp.float32)     # (bs, hd)
        s = jnp.dot(q, k.T,
                    preferred_element_type=jnp.float32) * scale  # (t_pad,bs)
        # query row i is at absolute position pos[b]+i = len[b]-chunk+i
        # and attends logical slots <= its own position (this single
        # comparison is both the causal mask and the ragged tail mask)
        slot = jax.lax.broadcasted_iota(jnp.int32, (t_pad, block_size), 1) \
            + j * block_size
        qpos = jax.lax.broadcasted_iota(jnp.int32, (t_pad, block_size), 0) \
            + (len_ref[b] - chunk)
        s = jnp.where(slot <= qpos, s, NEG)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(j == n_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def paged_prefill_attention(q: jax.Array, k_pool: jax.Array,
                            v_pool: jax.Array, block_tables: jax.Array,
                            pos: jax.Array, scale: float, *,
                            interpret: bool = False) -> jax.Array:
    """Chunk-query attention directly over a paged KV pool.

    q (B,T,H,hd) — a chunk of T query rows per sequence, row i at
    absolute position ``pos[b] + i``; k_pool/v_pool (N, bs, KV, hd)
    physical blocks, with the chunk's own K/V already written through
    the table; block_tables (B, nb) int32 — entries for blocks past the
    chunk frontier are never read (they may hold arbitrary values);
    pos (B,) int32 chunk start positions. Returns (B,T,H,hd) where row i
    attended logical slots ``0..pos[b]+i``.
    """
    B, T, H, hd = q.shape
    bs, KV = k_pool.shape[1], k_pool.shape[2]
    qpk = H // KV
    nb = block_tables.shape[1]
    t_pad = -(-T // 8) * 8  # sublane-align the chunk axis
    qt = jnp.moveaxis(q, 2, 1)  # (B,H,T,hd)
    if t_pad != T:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, t_pad - T), (0, 0)))
    lens = pos.astype(jnp.int32) + T  # live logical slots per sequence

    kernel = functools.partial(_paged_prefill_kernel, scale=scale,
                               block_size=bs, n_blocks=nb, chunk=T,
                               t_pad=t_pad)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # block_tables, lens
        grid=(B, H, nb),
        in_specs=[
            pl.BlockSpec((1, 1, t_pad, hd),
                         lambda b, h, j, tbl, lens: (b, h, 0, 0)),
            pl.BlockSpec((1, bs, 1, hd),
                         lambda b, h, j, tbl, lens, _qpk=qpk, _bs=bs:
                         (jnp.where(j * _bs < lens[b], tbl[b, j], 0),
                          0, h // _qpk, 0)),
            pl.BlockSpec((1, bs, 1, hd),
                         lambda b, h, j, tbl, lens, _qpk=qpk, _bs=bs:
                         (jnp.where(j * _bs < lens[b], tbl[b, j], 0),
                          0, h // _qpk, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, t_pad, hd),
                               lambda b, h, j, tbl, lens: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((t_pad,), jnp.float32),
            pltpu.VMEM((t_pad,), jnp.float32),
            pltpu.VMEM((t_pad, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, t_pad, hd), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), lens, qt, k_pool, v_pool)
    return jnp.moveaxis(out[:, :, :T], 1, 2)
