"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG = -1.0e30


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        scale: float, causal: bool = True,
                        window: Optional[int] = None) -> jax.Array:
    """q (B,S,H,hd); k/v (B,T,KV,hd) -> (B,S,H,hd). Positions are 0..S-1."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, S, KV, G, hd).astype(jnp.float32)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg,
                        k.astype(jnp.float32)) * scale
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((S, k.shape[1]), bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= (qpos - kpos) < window
    scores = jnp.where(mask[None, None, None], scores, NEG)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", probs, v.astype(jnp.float32))
    return out.reshape(B, S, H, hd).astype(q.dtype)


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         valid: jax.Array, scale: float) -> jax.Array:
    """q (B,1,H,hd); k/v (B,C,KV,hd); valid (B,C) -> (B,1,H,hd)."""
    B, _, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, hd).astype(jnp.float32)
    scores = jnp.einsum("bkgh,btkh->bkgt", qg, k.astype(jnp.float32)) * scale
    scores = jnp.where(valid[:, None, None, :], scores, NEG)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgt,btkh->bkgh", probs, v.astype(jnp.float32))
    return out.reshape(B, 1, H, hd).astype(q.dtype)


def paged_decode_attention_ref(q: jax.Array, k_pool: jax.Array,
                               v_pool: jax.Array, block_tables: jax.Array,
                               seq_lens: jax.Array,
                               scale: float) -> jax.Array:
    """q (B,1,H,hd); k/v pools (N,bs,KV,hd); block_tables (B,nb) int32;
    seq_lens (B,) valid logical slots -> (B,1,H,hd). Gathers the logical
    view then defers to :func:`decode_attention_ref`."""
    B = q.shape[0]
    nb = block_tables.shape[1]
    bs = k_pool.shape[1]
    k = k_pool[block_tables].reshape((B, nb * bs) + k_pool.shape[2:])
    v = v_pool[block_tables].reshape((B, nb * bs) + v_pool.shape[2:])
    valid = jnp.arange(nb * bs)[None, :] < seq_lens[:, None]
    return decode_attention_ref(q, k, v, valid, scale)


def paged_prefill_attention_ref(q: jax.Array, k_pool: jax.Array,
                                v_pool: jax.Array, block_tables: jax.Array,
                                pos: jax.Array, scale: float) -> jax.Array:
    """q (B,T,H,hd) chunk queries, row i at absolute position
    ``pos[b]+i``; k/v pools (N,bs,KV,hd); block_tables (B,nb) int32;
    pos (B,) int32 -> (B,T,H,hd). Gathers the logical view then applies
    the shifted-causal mask ``slot <= pos + i`` (which also cuts the
    ragged tail past the chunk frontier)."""
    B, T, H, hd = q.shape
    nb = block_tables.shape[1]
    bs, KV = k_pool.shape[1], k_pool.shape[2]
    G = H // KV
    k = k_pool[block_tables].reshape((B, nb * bs) + k_pool.shape[2:])
    v = v_pool[block_tables].reshape((B, nb * bs) + v_pool.shape[2:])
    qg = q.reshape(B, T, KV, G, hd).astype(jnp.float32)
    scores = jnp.einsum("btkgh,bskh->bkgts", qg,
                        k.astype(jnp.float32)) * scale
    slot = jnp.arange(nb * bs)[None, None, :]
    qpos = (pos[:, None] + jnp.arange(T))[:, :, None]
    mask = slot <= qpos                       # (B, T, nb*bs)
    scores = jnp.where(mask[:, None, None], scores, NEG)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgts,bskh->btkgh", probs, v.astype(jnp.float32))
    return out.reshape(B, T, H, hd).astype(q.dtype)


def paged_decode_attention_splitk_ref(q: jax.Array, k_pool: jax.Array,
                                      v_pool: jax.Array,
                                      block_tables: jax.Array,
                                      seq_lens: jax.Array,
                                      scale: float) -> jax.Array:
    """The split-K kernel partitions work, not math: its oracle is the
    plain paged decode reference."""
    return paged_decode_attention_ref(q, k_pool, v_pool, block_tables,
                                      seq_lens, scale)


def rwkv6_scan_ref(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
                   u: jax.Array, state: jax.Array):
    """All of r/k/v/w: (B,S,H,hd) f32; u (H,hd); state (B,H,hd,hd).

    Returns (out (B,S,H,hd), final_state). out_t = r_t·(state + u∘k_t v_tᵀ),
    state' = w_t∘state + k_t v_tᵀ  (decay applied per *key* channel).
    """

    def step(st, xs):
        rt, kt, vt, wt = xs
        kv = kt[..., :, None] * vt[..., None, :]
        out = jnp.einsum("bhi,bhij->bhj", rt, st + u[None, :, :, None] * kv)
        st = wt[..., :, None] * st + kv
        return st, out

    xs = tuple(jnp.moveaxis(x, 1, 0) for x in (r, k, v, w))
    final, outs = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(outs, 0, 1), final


def rglru_scan_ref(a: jax.Array, gated_in: jax.Array, h0: jax.Array):
    """a, gated_in: (B,S,W) f32; h0 (B,W). h_t = a_t*h_{t-1} + gated_in_t."""

    def step(h, xs):
        at, gt = xs
        h = at * h + gt
        return h, h

    xs = (jnp.moveaxis(a, 1, 0), jnp.moveaxis(gated_in, 1, 0))
    final, hs = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(hs, 0, 1), final


def moe_matmul_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """x (E,C,d) @ w (E,d,f) -> (E,C,f), per-expert GEMM."""
    return jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                      w.astype(jnp.float32)).astype(x.dtype)
