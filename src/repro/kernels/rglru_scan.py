"""RG-LRU diagonal gated recurrence, chunked Pallas TPU kernel.

h_t = a_t ∘ h_{t-1} + x_t  (x = sqrt(1-a²)·i·u precomputed by the layer).

Diagonal recurrence => width channels are independent: grid is
(batch, width_blocks, time_chunks), time innermost/sequential with the
h-state block in VMEM scratch. Within a chunk, a first-order blelloch-free
sequential fori steps time over a (block_w,)-vector — the VPU lane dim.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(a_ref, x_ref, h0_ref, o_ref, hT_ref, h_scr, *,
                  chunk: int, n_chunks: int):
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        h_scr[...] = h0_ref[...].astype(jnp.float32)

    a = a_ref[0].astype(jnp.float32)  # (chunk, bw)
    x = x_ref[0].astype(jnp.float32)

    def step(t, carry):
        h, out = carry
        h = a[t] * h + x[t]
        out = out.at[t].set(h)
        return h, out

    h = h_scr[...][0]  # (bw,)
    out0 = jnp.zeros_like(a)
    h, out = jax.lax.fori_loop(0, chunk, step, (h, out0))
    o_ref[0] = out.astype(o_ref.dtype)
    h_scr[...] = h[None, :]

    @pl.when(c == n_chunks - 1)
    def _final():
        hT_ref[...] = h_scr[...].astype(hT_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("chunk", "block_w", "interpret"))
def rglru_scan(a: jax.Array, x: jax.Array, h0: jax.Array, *,
               chunk: int = 128, block_w: int = 512,
               interpret: bool = False):
    """a, x: (B,S,W) f32; h0 (B,W) f32 -> (hs (B,S,W), h_final (B,W))."""
    B, S, W = a.shape
    ch = min(chunk, S)
    bw = min(block_w, W)
    S_pad = -(-S // ch) * ch
    W_pad = -(-W // bw) * bw

    def prep(z, pad_val=0.0):
        if S_pad != S or W_pad != W:
            z = jnp.pad(z, ((0, 0), (0, S_pad - S), (0, W_pad - W)),
                        constant_values=pad_val)
        return z

    a_p = prep(a, 1.0)  # padded steps keep state
    x_p = prep(x, 0.0)
    h0_p = jnp.pad(h0, ((0, 0), (0, W_pad - W))) if W_pad != W else h0
    n_chunks = S_pad // ch
    n_w = W_pad // bw

    kernel = functools.partial(_rglru_kernel, chunk=ch, n_chunks=n_chunks)
    hs, h_final = pl.pallas_call(
        kernel,
        grid=(B, n_w, n_chunks),
        in_specs=[
            pl.BlockSpec((1, ch, bw), lambda b, wblk, c: (b, c, wblk)),
            pl.BlockSpec((1, ch, bw), lambda b, wblk, c: (b, c, wblk)),
            pl.BlockSpec((1, bw), lambda b, wblk, c: (b, wblk)),
        ],
        out_specs=[
            pl.BlockSpec((1, ch, bw), lambda b, wblk, c: (b, c, wblk)),
            pl.BlockSpec((1, bw), lambda b, wblk, c: (b, wblk)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S_pad, W_pad), a.dtype),
            jax.ShapeDtypeStruct((B, W_pad), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((1, bw), jnp.float32)],
        interpret=interpret,
    )(a_p, x_p, h0_p)
    return hs[:, :S, :W], h_final[:, :W]
