"""RWKV-6 WKV recurrence, chunked Pallas TPU kernel.

The GPU reference (RWKV CUDA) assigns one thread-block per (batch, head)
and serially scans time with the state in registers. The TPU rethink: grid
(batch, head, time_chunks) with the chunk dimension innermost and
sequential — the (hd x hd) state matrix lives in VMEM scratch and carries
across chunks; within a chunk a fori_loop steps time while the VPU
vectorises over the hd lanes of the state rows. r/k/v/w stream in
chunk-sized VMEM blocks.

    out_t   = r_t · (state + u ∘ k_t v_tᵀ)
    state' = w_t ∘ state + k_t v_tᵀ        (decay per key channel)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rwkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, o_ref, sT_ref,
                 state_scr, *, chunk: int, n_chunks: int):
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        state_scr[...] = s0_ref[0, 0].astype(jnp.float32)

    r = r_ref[0, 0].astype(jnp.float32)  # (chunk, hd)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    w = w_ref[0, 0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)     # (1, hd) -> (hd,)
    u = u.reshape(-1)

    def step(t, carry):
        state, out = carry
        kv = k[t][:, None] * v[t][None, :]          # (hd, hd)
        y = (r[t][:, None] * (state + u[:, None] * kv)).sum(axis=0)
        out = out.at[t].set(y)
        state = w[t][:, None] * state + kv
        return state, out

    state = state_scr[...]
    out0 = jnp.zeros_like(r)
    state, out = jax.lax.fori_loop(0, chunk, step, (state, out0))
    o_ref[0, 0] = out.astype(o_ref.dtype)
    state_scr[...] = state

    @pl.when(c == n_chunks - 1)
    def _final():
        sT_ref[0, 0] = state_scr[...].astype(sT_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_scan(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
               u: jax.Array, state: jax.Array, *, chunk: int = 64,
               interpret: bool = False):
    """r/k/v/w (B,S,H,hd) f32; u (H,hd); state (B,H,hd,hd) f32.

    Returns (out (B,S,H,hd), final_state (B,H,hd,hd)). S padded to chunk.
    """
    B, S, H, hd = r.shape
    ch = min(chunk, S)
    S_pad = -(-S // ch) * ch
    n_chunks = S_pad // ch

    def prep(x, pad_val=0.0):
        x = jnp.moveaxis(x, 2, 1)  # (B,H,S,hd)
        if S_pad != S:
            x = jnp.pad(x, ((0, 0), (0, 0), (0, S_pad - S), (0, 0)),
                        constant_values=pad_val)
        return x

    # pad decay with 1.0 so padded steps leave the state untouched
    rt, kt, vt = prep(r), prep(k), prep(v)
    wt = prep(w, pad_val=1.0)

    kernel = functools.partial(_rwkv_kernel, chunk=ch, n_chunks=n_chunks)
    out, s_final = pl.pallas_call(
        kernel,
        grid=(B, H, n_chunks),
        in_specs=[
            pl.BlockSpec((1, 1, ch, hd), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, ch, hd), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, ch, hd), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, ch, hd), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, hd), lambda b, h, c: (h, 0)),
            pl.BlockSpec((1, 1, hd, hd), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, ch, hd), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, hd, hd), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, S_pad, hd), r.dtype),
            jax.ShapeDtypeStruct((B, H, hd, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(rt, kt, vt, wt, u, state)
    return jnp.moveaxis(out[:, :, :S, :], 1, 2), s_final
