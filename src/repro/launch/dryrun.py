"""Multi-pod dry-run: prove every (arch x shape x mesh) lowers + compiles.

MUST set the device-count flag before ANY other import (jax locks the
device count on first init).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b \
        --shape prefill_32k [--multi-pod]
    PYTHONPATH=src python -m repro.launch.dryrun --all
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import argparse          # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402
from typing import Dict, Optional  # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.config import INPUT_SHAPES, get_config  # noqa: E402
from repro.config.base import InputShape, ModelConfig  # noqa: E402
from repro.launch import roofline, sharding  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.train.optimizer import adam  # noqa: E402

SERVE_DTYPE = jnp.bfloat16
TRAIN_DTYPE = jnp.float32
OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def build_case(arch: str, shape_name: str, mesh, sharding_mode: str = "tp"):
    """Returns (fn, args_abstract, in_shardings) for jit lowering."""
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    dtype = TRAIN_DTYPE if shape.kind == "train" else SERVE_DTYPE
    model = build_model(cfg, remat=(shape.kind == "train"),
                        compute_dtype=(jnp.bfloat16
                                       if shape.kind == "train" else None))
    if shape.name == "long_500k" and not model.supports_shape(shape):
        return None  # documented skip (docs/ARCHITECTURE.md §4)

    params_abs = model.abstract_params(dtype)
    p_mode = "2d" if sharding_mode in ("2d", "decode2d") else "tp"
    p_shard = sharding.param_shardings(mesh, params_abs, mode=p_mode)
    inputs = model.input_specs(shape, SERVE_DTYPE)
    in_shard = sharding.input_shardings(mesh, cfg, inputs,
                                        mode=sharding_mode)

    if shape.kind == "train":
        opt = adam(1e-4)
        opt_abs = jax.eval_shape(opt.init, params_abs)
        opt_shard = jax.tree.map(
            lambda _: None, opt_abs, is_leaf=lambda x: False)
        # mu/nu shaped like params -> same shardings; step scalar replicated
        opt_shard = jax.tree.map(
            lambda leaf, ab: sharding.replicated(mesh)
            if ab.ndim == 0 else None, opt_abs, opt_abs)

        def match_param_sharding(opt_tree):
            def fix(path, leaf):
                # AdamState(step, mu, nu): mu/nu mirror the param tree
                if path.startswith("1/") or path.startswith("2/"):
                    sub = path.split("/", 1)[1]
                    return _lookup(p_shard, sub)
                return sharding.replicated(mesh)

            from repro.common.tree import tree_map_with_path

            return tree_map_with_path(fix, opt_tree)

        def _lookup(tree, path):
            node = tree
            for part in path.split("/"):
                if isinstance(node, (list, tuple)):
                    node = node[int(part)]
                else:
                    node = node[part]
            return node

        opt_shard = match_param_sharding(opt_abs)

        def train_step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(model.loss)(params, batch)
            updates, opt_state = opt.update(grads, opt_state, params)
            from repro.train.optimizer import apply_updates

            params = apply_updates(params, updates)
            return params, opt_state, loss

        args = (params_abs, opt_abs, inputs)
        shards = (p_shard, opt_shard, in_shard)
        return train_step, args, shards, (0, 1)  # donate params+opt

    if shape.kind == "prefill":
        def prefill(params, batch):
            return model.prefill(params, batch)

        return prefill, (params_abs, inputs), (p_shard, in_shard), ()

    # decode
    cache_len = shape.seq_len
    cache_abs = model.cache_spec(shape.global_batch, cache_len, SERVE_DTYPE)
    c_shard = sharding.cache_shardings(mesh, cfg, cache_abs,
                                       shape.global_batch,
                                       mode=sharding_mode)

    def serve_step(params, cache, batch):
        return model.decode_step(params, cache, batch)

    return serve_step, (params_abs, cache_abs, inputs), \
        (p_shard, c_shard, in_shard), (1,)  # donate the KV cache


def run_case(arch: str, shape_name: str, multi_pod: bool,
             out_dir: Optional[str] = None, verbose: bool = True,
             sharding_mode: str = "tp") -> Dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    chips = 512 if multi_pod else 256
    if sharding_mode == "auto":
        # best-known layout per shape kind (§Perf): decode of models whose
        # bf16 weights exceed the model-axis HBM budget uses replicated
        # batch + 2D weights + both-axes cache ("decode2d"); everything
        # else keeps batch-on-data TP — for models that FIT at TP-16,
        # sharded-batch TP psums (B/16,1,d) beat decode2d's full-batch
        # psums by 16x (see docs/EXPERIMENTS.md §Perf iteration log).
        cfg_probe = get_config(arch)
        w_gib_tp = cfg_probe.param_count_estimate() * 2 / 16 / 2 ** 30
        sharding_mode = ("decode2d"
                         if (INPUT_SHAPES[shape_name].kind == "decode"
                             and w_gib_tp > 4.0)
                         else "tp")
    case = build_case(arch, shape_name, mesh, sharding_mode)
    result: Dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                    "sharding": sharding_mode}
    if case is None:
        result["status"] = "skipped"
        result["reason"] = ("full-attention arch at 512k decode "
                            "(docs/ARCHITECTURE.md §4)")
        _emit(result, out_dir, verbose)
        return result
    fn, args, shards, donate = case
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    try:
        t0 = time.time()
        with mesh:
            lowered = jax.jit(fn, in_shardings=shards,
                              donate_argnums=donate).lower(*args)
            t_lower = time.time() - t0
            t0 = time.time()
            compiled = lowered.compile()
            t_compile = time.time() - t0
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        colls = roofline.parse_collectives(hlo)
        wl = roofline.workload_cost(cfg, shape)
        per_chip_coll = colls["total_bytes"]  # per-device HLO shapes
        terms = wl.terms(chips, per_chip_coll)
        dominant = max(("compute_s", "memory_s", "collective_s"),
                       key=lambda k: terms[k])
        result.update({
            "status": "ok",
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "bytes_per_device": int(getattr(
                mem, "temp_size_in_bytes", 0) + getattr(
                mem, "argument_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "arg_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "cost_flops_raw": float(cost.get("flops", 0.0)) if cost else 0.0,
            "collectives": colls,
            "analytic": {
                "flops": wl.flops, "hbm_bytes": wl.hbm_bytes,
                "model_flops": wl.model_flops,
                "param_bytes": wl.param_bytes,
            },
            "roofline": {k: terms[k] for k in
                         ("compute_s", "memory_s", "collective_s")},
            "dominant": dominant,
            "useful_flops_ratio": (wl.model_flops / wl.flops
                                   if wl.flops else 0.0),
        })
    except Exception as e:  # noqa: BLE001 — a failure here is a finding
        result["status"] = "error"
        result["error"] = f"{type(e).__name__}: {e}"
        result["traceback"] = traceback.format_exc()[-2000:]
    _emit(result, out_dir, verbose)
    return result


def _emit(result: Dict, out_dir: Optional[str], verbose: bool) -> None:
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        path = os.path.join(
            out_dir,
            f"{result['arch']}_{result['shape']}_{result['mesh']}.json")
        with open(path, "w") as f:
            json.dump(result, f, indent=1, default=str)
    if verbose:
        if result["status"] == "ok":
            r = result["roofline"]
            print(f"[dryrun] {result['arch']:28s} {result['shape']:12s} "
                  f"{result['mesh']:8s} OK "
                  f"mem/dev={result['bytes_per_device']/2**30:.2f}GiB "
                  f"compute={r['compute_s']*1e3:.2f}ms "
                  f"memory={r['memory_s']*1e3:.2f}ms "
                  f"coll={r['collective_s']*1e3:.2f}ms "
                  f"dom={result['dominant'].split('_')[0]} "
                  f"(compile {result['compile_s']:.0f}s)", flush=True)
        elif result["status"] == "skipped":
            print(f"[dryrun] {result['arch']:28s} {result['shape']:12s} "
                  f"{result['mesh']:8s} SKIP ({result['reason']})",
                  flush=True)
        else:
            print(f"[dryrun] {result['arch']:28s} {result['shape']:12s} "
                  f"{result['mesh']:8s} ERROR {result['error'][:160]}",
                  flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=list(INPUT_SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="all assigned archs x shapes on this mesh")
    ap.add_argument("--sharding", default="auto",
                    choices=["auto", "tp", "2d", "decode2d"])
    ap.add_argument("--out-dir", default=os.path.join(
        os.getcwd(), "experiments", "dryrun"))
    args = ap.parse_args()

    from repro.configs import ASSIGNED

    if args.all:
        archs = ASSIGNED
        shapes = list(INPUT_SHAPES)
    else:
        archs = [args.arch] if args.arch else ASSIGNED
        shapes = [args.shape] if args.shape else list(INPUT_SHAPES)

    failures = 0
    for arch in archs:
        for shape in shapes:
            res = run_case(arch, shape, args.multi_pod, args.out_dir,
                           sharding_mode=args.sharding)
            failures += res["status"] == "error"
    if failures:
        raise SystemExit(f"{failures} dry-run case(s) failed")


if __name__ == "__main__":
    main()
