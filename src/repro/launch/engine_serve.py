"""Real-engine serving driver (importable entry point for
``python -m repro.launch.serve --engine``; docs/ARCHITECTURE.md §6).

The BCEdge scheduler batching REAL model inference — a reduced
architecture running under jit on this host, wall-clock latencies and
all. Requests with token prompts arrive Poisson; utilities are computed
from measured latencies (Eq. 3).

Two execution modes, mirroring the simulator's ``exec_mode``:

* ``round`` — the SAC scheduler picks the batch size per round and the
  ``InferenceEngine`` runs each round to completion (paper §IV-D);
* ``continuous`` — the ``ContinuousBatchingEngine`` decodes a fixed set
  of KV slots one iteration at a time; arrivals are submitted as they
  land and join at iteration boundaries (docs/ARCHITECTURE.md §5).

Run:  PYTHONPATH=src python -m repro.launch.serve --engine
      PYTHONPATH=src python -m repro.launch.serve --engine \
          --exec-mode continuous
"""
from __future__ import annotations

import time

import numpy as np

from repro.config import get_reduced_config
from repro.config.base import ServingConfig
from repro.core.sac import SACAgent, SACConfig
from repro.core.utility import utility
from repro.serving.engine import ContinuousBatchingEngine, InferenceEngine


def _report(served: int, violations: int, rounds: int, lat_sum: float,
            dur: float, slo_ms: float, label: str) -> None:
    print(f"[{label}] served {served} requests in {dur:.1f}s "
          f"({served/max(dur,1e-6):.1f} rps) over {rounds} rounds/iters")
    print(f"[{label}] mean latency {lat_sum/max(served,1):.0f}ms, "
          f"violations {violations/max(served,1):.1%} (SLO {slo_ms:.0f}ms)")


def serve_round(arch: str = "qwen3-0.6b", duration_s: float = 20.0,
                rps: float = 12.0, slo_ms: float = 1500.0) -> None:
    """Round mode: SAC picks b per round, engine runs it to completion."""
    cfg = get_reduced_config(arch)
    print(f"loading reduced {cfg.name} "
          f"(d={cfg.d_model}, L={cfg.n_layers})...")
    engine = InferenceEngine(cfg, max_seq=128)
    # warm the compile cache
    engine.generate([np.arange(8, dtype=np.int32)], max_new_tokens=2)

    scfg = ServingConfig(batch_sizes=(1, 2, 4, 8),
                         concurrency_levels=(1,))
    agent = SACAgent(4, scfg.n_actions,
                     SACConfig(batch_size=32, lr=1e-3), seed=0)
    rng = np.random.default_rng(0)

    queue = []
    t0 = time.perf_counter()
    next_arrival = rng.exponential(1.0 / rps)
    served = violations = rounds = 0
    lat_sum = 0.0
    state = np.zeros(4, np.float32)
    while time.perf_counter() - t0 < duration_s:
        now = time.perf_counter() - t0
        while next_arrival <= now:
            queue.append((next_arrival,
                          rng.integers(1, cfg.vocab_size,
                                       rng.integers(4, 24)).astype(np.int32)))
            next_arrival += rng.exponential(1.0 / rps)
        if not queue:
            time.sleep(0.002)
            continue
        oldest_age = now - queue[0][0]
        state = np.array([np.log1p(len(queue)), oldest_age,
                          np.log1p(served), 1.0], np.float32)
        a = agent.act(state)
        b, _ = scfg.action_to_pair(a)
        batch = queue[:b]
        queue = queue[b:]
        res = engine.generate([p for _, p in batch], max_new_tokens=4)
        done_t = time.perf_counter() - t0
        lats = [(done_t - arr) * 1000.0 for arr, _ in batch]
        viol = sum(1 for l in lats if l > slo_ms)
        served += len(batch)
        violations += viol
        lat_sum += sum(lats)
        rounds += 1
        u = utility(len(batch) / max(res.total_ms / 1000, 1e-3),
                    np.mean(lats) / 1000.0,
                    slo_ms / 1000.0 * len(batch), 1) - 2.0 * viol / len(batch)
        s2 = np.array([np.log1p(len(queue)), 0.0, np.log1p(served), 1.0],
                      np.float32)
        agent.observe(state, a, u, s2, False)
        agent.update()
    _report(served, violations, rounds, lat_sum,
            time.perf_counter() - t0, slo_ms, "round")


def serve_continuous(arch: str = "qwen3-0.6b", duration_s: float = 20.0,
                     rps: float = 12.0, slo_ms: float = 1500.0,
                     max_slots: int = 4) -> None:
    """Continuous mode: arrivals are submitted into the slot engine as
    they land and join the running batch at iteration boundaries."""
    cfg = get_reduced_config(arch)
    print(f"loading reduced {cfg.name} "
          f"(d={cfg.d_model}, L={cfg.n_layers}), "
          f"{max_slots} slots...")
    engine = ContinuousBatchingEngine(cfg, max_slots=max_slots, max_seq=128)
    rng = np.random.default_rng(0)

    t0 = time.perf_counter()
    next_arrival = rng.exponential(1.0 / rps)
    submit_t = {}
    served = violations = 0
    lat_sum = 0.0
    while time.perf_counter() - t0 < duration_s:
        now = time.perf_counter() - t0
        while next_arrival <= now:
            prompt = rng.integers(1, cfg.vocab_size,
                                  rng.integers(4, 24)).astype(np.int32)
            rid = engine.submit(prompt, max_new_tokens=4)
            submit_t[rid] = next_arrival
            next_arrival += rng.exponential(1.0 / rps)
        if not engine.active_slots and not engine.waiting:
            time.sleep(0.002)
            continue
        for r in engine.step():
            done_t = time.perf_counter() - t0
            lat = (done_t - submit_t.pop(r.request_id, done_t)) * 1000.0
            served += 1
            lat_sum += lat
            violations += int(lat > slo_ms)
    _report(served, violations, engine.n_iters, lat_sum,
            time.perf_counter() - t0, slo_ms, "continuous")
    print(f"[continuous] engine stats: {engine.stats()}")


def main(exec_mode: str = "round", arch: str = "qwen3-0.6b",
         duration_s: float = 20.0, rps: float = 12.0,
         slo_ms: float = 1500.0) -> None:
    if exec_mode == "continuous":
        serve_continuous(arch, duration_s, rps, slo_ms)
    else:
        serve_round(arch, duration_s, rps, slo_ms)


if __name__ == "__main__":
    main()
