"""Real-engine serving driver (importable entry point for
``python -m repro.launch.serve --engine``; docs/ARCHITECTURE.md §6).

The BCEdge scheduler batching REAL model inference — a reduced
architecture running under jit on this host, wall-clock latencies and
all. Requests with token prompts arrive Poisson; utilities are computed
from measured latencies (Eq. 3).

Three serving modes:

* ``round`` — the SAC scheduler picks the batch size per round and the
  ``InferenceEngine`` runs each round to completion (paper §IV-D);
* ``continuous`` — the ``ContinuousBatchingEngine`` decodes a fixed set
  of KV slots one iteration at a time; arrivals are submitted as they
  land and join at iteration boundaries (docs/ARCHITECTURE.md §5);
* ``--models a,b`` multi-model pool serve — N concurrent engine
  instances across heterogeneous models behind the ``ModelInstancePool``
  runtime, with the ``PoolScheduler`` driving the REAL (b, m_c) action
  per model (docs/RUNTIME.md; continuous-only).

Run:  PYTHONPATH=src python -m repro.launch.serve --engine
      PYTHONPATH=src python -m repro.launch.serve --engine \
          --exec-mode continuous
      PYTHONPATH=src python -m repro.launch.serve --engine \
          --models qwen3-0.6b,recurrentgemma-2b --exec-mode continuous
"""
from __future__ import annotations

import time
from typing import Callable, Dict, Optional, Sequence

import numpy as np

from repro.config import get_reduced_config
from repro.config.base import ServingConfig
from repro.core.interference import NNInterferencePredictor
from repro.core.sac import SACAgent, SACConfig
from repro.core.utility import utility
from repro.serving.bcedge import PoolScheduler
from repro.serving.engine import (SEQ_BUCKETS, ContinuousBatchingEngine,
                                  InferenceEngine, _bucket,
                                  supports_speculation)
from repro.serving.runtime import ModelInstancePool

#: unique-tail length _shared_prefix_prompts appends to every prefix
#: (fixed: left-padding makes prefix sharing length-sensitive)
_PREFIX_TAIL = 8


def _serve_max_seq(shared_prefix_tokens: int, default: int = 128) -> int:
    """Cache length sized to the generated workload: a templated prompt
    (prefix + fixed tail) must fit its bucket AND leave decode room —
    with the historical default for untemplated traffic."""
    if not shared_prefix_tokens:
        return default
    bucket = _bucket(shared_prefix_tokens + _PREFIX_TAIL,
                     buckets=SEQ_BUCKETS)
    return max(default, bucket + 64)


def _report(served: int, violations: int, rounds: int, lat_sum: float,
            dur: float, slo_ms: float, label: str) -> None:
    print(f"[{label}] served {served} requests in {dur:.1f}s "
          f"({served/max(dur,1e-6):.1f} rps) over {rounds} rounds/iters")
    print(f"[{label}] mean latency {lat_sum/max(served,1):.0f}ms, "
          f"violations {violations/max(served,1):.1%} (SLO {slo_ms:.0f}ms)")


def serve_round(arch: str = "qwen3-0.6b", duration_s: float = 20.0,
                rps: float = 12.0, slo_ms: float = 1500.0) -> None:
    """Round mode: SAC picks b per round, engine runs it to completion."""
    cfg = get_reduced_config(arch)
    print(f"loading reduced {cfg.name} "
          f"(d={cfg.d_model}, L={cfg.n_layers})...")
    engine = InferenceEngine(cfg, max_seq=128)
    # warm the compile cache
    engine.generate([np.arange(8, dtype=np.int32)], max_new_tokens=2)

    scfg = ServingConfig(batch_sizes=(1, 2, 4, 8),
                         concurrency_levels=(1,))
    agent = SACAgent(4, scfg.n_actions,
                     SACConfig(batch_size=32, lr=1e-3), seed=0)
    rng = np.random.default_rng(0)

    queue = []
    t0 = time.perf_counter()
    next_arrival = rng.exponential(1.0 / rps)
    served = violations = rounds = 0
    lat_sum = 0.0
    state = np.zeros(4, np.float32)
    while time.perf_counter() - t0 < duration_s:
        now = time.perf_counter() - t0
        while next_arrival <= now:
            queue.append((next_arrival,
                          rng.integers(1, cfg.vocab_size,
                                       rng.integers(4, 24)).astype(np.int32)))
            next_arrival += rng.exponential(1.0 / rps)
        if not queue:
            time.sleep(0.002)
            continue
        oldest_age = now - queue[0][0]
        state = np.array([np.log1p(len(queue)), oldest_age,
                          np.log1p(served), 1.0], np.float32)
        a = agent.act(state)
        b, _ = scfg.action_to_pair(a)
        batch = queue[:b]
        queue = queue[b:]
        res = engine.generate([p for _, p in batch], max_new_tokens=4)
        done_t = time.perf_counter() - t0
        lats = [(done_t - arr) * 1000.0 for arr, _ in batch]
        viol = sum(1 for l in lats if l > slo_ms)
        served += len(batch)
        violations += viol
        lat_sum += sum(lats)
        rounds += 1
        u = utility(len(batch) / max(res.total_ms / 1000, 1e-3),
                    np.mean(lats) / 1000.0,
                    slo_ms / 1000.0 * len(batch), 1) - 2.0 * viol / len(batch)
        s2 = np.array([np.log1p(len(queue)), 0.0, np.log1p(served), 1.0],
                      np.float32)
        agent.observe(state, a, u, s2, False)
        agent.update()
    _report(served, violations, rounds, lat_sum,
            time.perf_counter() - t0, slo_ms, "round")


def _shared_prefix_prompts(rng, vocab: int, shared_prefix_tokens: int,
                           population: int = 4):
    """Prompt factory for templated workloads: draws one of
    ``population`` fixed shared prefixes plus a random unique tail of a
    FIXED length (left-padding makes sharing length-sensitive — see
    docs/ARCHITECTURE.md §5)."""
    prefixes = [rng.integers(1, vocab, shared_prefix_tokens).astype(
        np.int32) for _ in range(population)]

    def draw():
        tail = rng.integers(1, vocab, _PREFIX_TAIL).astype(np.int32)
        return np.concatenate(
            [prefixes[int(rng.integers(population))], tail])
    return draw


def serve_continuous(arch: str = "qwen3-0.6b", duration_s: float = 20.0,
                     rps: float = 12.0, slo_ms: float = 1500.0,
                     max_slots: int = 4, kv_layout: str = "dense",
                     kv_block_budget: Optional[int] = None,
                     token_budget: Optional[int] = None,
                     prefix_cache: bool = False,
                     shared_prefix_tokens: int = 0,
                     spec_k: int = 0) -> None:
    """Continuous mode: arrivals are submitted into the slot engine as
    they land and join the running batch at iteration boundaries. With
    ``kv_layout="paged"``, ``kv_block_budget`` caps the engine's block
    pool (default: the dense-equivalent worst case). ``token_budget``
    caps per-iteration prefill+decode tokens (chunked prefill,
    docs/ARCHITECTURE.md §5). ``prefix_cache`` shares full immutable
    prompt blocks across same-prefix sequences (paged only);
    ``shared_prefix_tokens`` makes the generated workload templated so
    the cache has something to hit. ``spec_k`` enables self-speculative
    decoding: up to k n-gram drafts per slot verified in one forward
    (docs/ARCHITECTURE.md §speculation); models whose cache cannot
    rewind serve with it off."""
    cfg = get_reduced_config(arch)
    if spec_k > 0 and not supports_speculation(cfg):
        print(f"{cfg.name}: cache not rewindable "
              f"(recurrent/windowed layers); serving with spec_k=0")
        spec_k = 0
    print(f"loading reduced {cfg.name} "
          f"(d={cfg.d_model}, L={cfg.n_layers}), "
          f"{max_slots} slots, {kv_layout} KV, "
          f"token budget {token_budget or 'uncapped'}, "
          f"prefix cache {'on' if prefix_cache else 'off'}, "
          f"spec_k {spec_k or 'off'}...")
    engine = ContinuousBatchingEngine(cfg, max_slots=max_slots,
                                      max_seq=_serve_max_seq(
                                          shared_prefix_tokens),
                                      kv_layout=kv_layout,
                                      kv_blocks=kv_block_budget,
                                      token_budget=token_budget,
                                      prefix_cache=prefix_cache,
                                      spec_k=spec_k)
    rng = np.random.default_rng(0)
    draw_prompt = _shared_prefix_prompts(
        rng, cfg.vocab_size, shared_prefix_tokens) \
        if shared_prefix_tokens else None

    t0 = time.perf_counter()
    next_arrival = rng.exponential(1.0 / rps)
    submit_t = {}
    served = violations = 0
    lat_sum = 0.0
    while time.perf_counter() - t0 < duration_s:
        now = time.perf_counter() - t0
        while next_arrival <= now:
            prompt = draw_prompt() if draw_prompt is not None else \
                rng.integers(1, cfg.vocab_size,
                             rng.integers(4, 24)).astype(np.int32)
            rid = engine.submit(prompt, max_new_tokens=4)
            submit_t[rid] = next_arrival
            next_arrival += rng.exponential(1.0 / rps)
        if not engine.active_slots and not engine.waiting:
            time.sleep(0.002)
            continue
        for r in engine.step():
            done_t = time.perf_counter() - t0
            lat = (done_t - submit_t.pop(r.request_id, done_t)) * 1000.0
            served += 1
            lat_sum += lat
            violations += int(lat > slo_ms)
    _report(served, violations, engine.n_iters, lat_sum,
            time.perf_counter() - t0, slo_ms, "continuous")
    print(f"[continuous] engine stats: {engine.stats()}")


def serve_pool(models: Sequence[str] = ("qwen3-0.6b", "recurrentgemma-2b"),
               duration_s: float = 20.0, rps: float = 12.0,
               slo_ms: float = 2000.0, max_instances: int = 4,
               max_slots: int = 4, max_new_tokens: int = 4,
               control_ms: float = 500.0, seed: int = 0,
               kv_layout: str = "dense",
               kv_block_budget: Optional[int] = None,
               token_budget: Optional[int] = None,
               preemption: bool = False,
               kv_host_blocks: int = 0,
               preempt_mode: str = "auto",
               prefix_cache: bool = False,
               shared_prefix_tokens: int = 0,
               spec_k: int = 0
               ) -> Dict[str, Dict[str, float]]:
    """Multi-model pool serve (docs/RUNTIME.md): Poisson arrivals per
    model are routed by deadline into a ``ModelInstancePool`` of live
    engine instances while the ``PoolScheduler`` re-decides (b, m_c) per
    model once per Eq.-1 slot (clamped to [control_ms, 2000] ms).
    ``kv_layout="paged"`` serves every instance from the block-pool KV
    layout under a shared ``kv_block_budget`` (docs/RUNTIME.md §7).
    ``token_budget`` adds the per-iteration token cap as a third
    scheduler axis and ``preemption`` enables SLO-aware eviction
    (docs/RUNTIME.md §8). ``kv_host_blocks`` gives every paged instance
    a host-memory KV tier so eviction can swap instead of recompute;
    ``preempt_mode`` picks recompute/swap/auto (costed, per victim). ``prefix_cache`` shares full immutable prompt
    blocks across same-prefix sequences on pageable models, with router
    prefix affinity (docs/RUNTIME.md §7); pair it with
    ``shared_prefix_tokens`` so the generated workload is templated.
    ``spec_k`` caps self-speculative decoding and adds the proposal
    depth as the FOURTH scheduler axis (k ∈ {0, k/2, k}; rewind-capable
    models only, docs/ARCHITECTURE.md §speculation).
    Returns the pool's per-model report."""
    cfgs = {m: get_reduced_config(m) for m in models}
    for m, cfg in cfgs.items():
        print(f"loading reduced {cfg.name} "
              f"(d={cfg.d_model}, L={cfg.n_layers})...")
    pool = ModelInstancePool(cfgs, max_instances=max_instances,
                             max_slots=max_slots,
                             max_seq=_serve_max_seq(shared_prefix_tokens),
                             seed=seed,
                             strict_admission=True,
                             predictor=NNInterferencePredictor(seed=seed),
                             kv_layout=kv_layout,
                             kv_block_budget=kv_block_budget,
                             preemption=preemption,
                             kv_host_blocks=max(0, kv_host_blocks),
                             preempt_mode=preempt_mode,
                             prefix_cache=prefix_cache,
                             spec_k=spec_k)
    per_model_mc = max(1, max_instances // max(1, len(cfgs)))
    scfg = ServingConfig(
        batch_sizes=tuple(b for b in (1, 2, 4, 8) if b <= max_slots),
        concurrency_levels=tuple(range(1, per_model_mc + 1)),
        token_budgets=(0,) if not token_budget
        else (0, 2 * token_budget, token_budget),
        spec_depths=(0,) if not spec_k
        else tuple(sorted({0, max(1, spec_k // 2), spec_k})))
    sched = PoolScheduler(pool, scfg,
                          slo_ms={m: slo_ms for m in cfgs},
                          decode_steps_mean=max_new_tokens, seed=seed)
    sched.control()  # initial allocation (spawns the first instances)

    # warm the jit caches (prefill buckets + decode shape) so compile
    # time never counts against request SLOs, then zero the metrics
    for m in cfgs:
        if pool.m_c(m) == 0:
            pool.scale_to(m, 1)
    pool.warmup(seed=seed)

    rng = np.random.default_rng(seed)
    draw_prompt = {m: _shared_prefix_prompts(rng, cfg.vocab_size,
                                             shared_prefix_tokens)
                   for m, cfg in cfgs.items()} if shared_prefix_tokens \
        else None
    per_rps = rps / max(1, len(cfgs))
    next_arrival = {m: rng.exponential(1.0 / per_rps) for m in cfgs}
    next_control = control_ms / 1000.0
    t0 = time.perf_counter()
    while time.perf_counter() - t0 < duration_s:
        now = time.perf_counter() - t0
        for m, cfg in cfgs.items():
            while next_arrival[m] <= now:
                prompt = draw_prompt[m]() if draw_prompt is not None \
                    else rng.integers(1, cfg.vocab_size,
                                      rng.integers(4, 24)).astype(np.int32)
                pool.submit(m, prompt, slo_ms=slo_ms,
                            max_new_tokens=max_new_tokens)
                next_arrival[m] += rng.exponential(1.0 / per_rps)
        if now >= next_control:
            applied = sched.control()
            slot = max(pool.slot_ms(m) for m in cfgs)
            next_control = now + float(
                np.clip(slot, control_ms, 2000.0)) / 1000.0
            print(f"[pool t={now:5.1f}s] (b, m_c)={applied} "
                  f"live={pool.total_live()}")
        if not any(i.n_resident for i in pool.live()) \
                and not any(pool.queues.values()):
            time.sleep(0.002)
            continue
        sched.record(pool.step())

    report = pool.report()
    dur = time.perf_counter() - t0
    for m, row in report.items():
        print(f"[pool:{m}] served {row['served']:.0f} "
              f"({row['served']/max(dur,1e-6):.1f} rps), "
              f"SLO attainment {row['slo_attainment']:.1%}, "
              f"mean latency {row['mean_latency_ms']:.0f}ms, "
              f"utility {row['mean_utility']:.2f}, m_c={row['m_c']:.0f}, "
              f"preempted {row['preempted']:.0f}")
    print(f"[pool] stats: {pool.stats()}")
    print(f"[pool] guard interventions: {sched.guard_interventions}")
    return report


def serve_http(models: Sequence[str] = ("qwen3-0.6b",),
               host: str = "127.0.0.1", port: int = 8808,
               slo_ms: float = 2000.0, max_instances: int = 4,
               max_slots: int = 4, seed: int = 0,
               kv_layout: str = "paged",
               kv_block_budget: Optional[int] = None,
               backpressure: bool = True, max_queue_depth: int = 8,
               control_ms: float = 500.0,
               ready: Optional[Callable[[int], None]] = None,
               configs: Optional[Dict] = None) -> None:
    """Push-mode HTTP serving (docs/RUNTIME.md §11): the pool runs on a
    background :class:`~repro.serving.driver.ServingDriver` thread with
    the ``PoolScheduler`` re-deciding (b, m_c) on a wall-clock tick, and
    an asyncio :class:`~repro.launch.server.ServingFrontend` streams
    per-token events over HTTP until interrupted. ``ready(port)`` fires
    once the socket is bound (``port=0`` picks an ephemeral port).
    ``configs`` overrides the registry lookup with explicit
    ``ModelConfig`` objects — tools/server_smoke.py serves a tiny
    throwaway model that way."""
    import asyncio

    from repro.launch.server import ServingFrontend
    from repro.serving.driver import ServingDriver

    cfgs = configs or {m: get_reduced_config(m) for m in models}
    for m, cfg in cfgs.items():
        print(f"loading reduced {cfg.name} "
              f"(d={cfg.d_model}, L={cfg.n_layers})...")
    pool = ModelInstancePool(cfgs, max_instances=max_instances,
                             max_slots=max_slots, max_seq=128, seed=seed,
                             kv_layout=kv_layout,
                             kv_block_budget=kv_block_budget)
    per_model_mc = max(1, max_instances // max(1, len(cfgs)))
    scfg = ServingConfig(
        batch_sizes=tuple(b for b in (1, 2, 4, 8) if b <= max_slots),
        concurrency_levels=tuple(range(1, per_model_mc + 1)))
    sched = PoolScheduler(pool, scfg,
                          slo_ms={m: slo_ms for m in cfgs},
                          seed=seed)
    sched.control()
    for m in cfgs:
        if pool.m_c(m) == 0:
            pool.scale_to(m, 1)
    pool.warmup(seed=seed)

    async def _run() -> None:
        with ServingDriver(pool, on_tick=sched.tick,
                           tick_interval_s=control_ms / 1000.0) as driver:
            fe = ServingFrontend(driver, host=host, port=port,
                                 backpressure=backpressure,
                                 max_queue_depth=max_queue_depth,
                                 default_slo_ms=slo_ms)
            await fe.start()
            print(f"[http] serving {sorted(cfgs)} on "
                  f"http://{host}:{fe.port} "
                  f"(backpressure {'on' if backpressure else 'off'})")
            if ready is not None:
                ready(fe.port)
            try:
                await fe.serve_forever()
            except asyncio.CancelledError:
                pass
            finally:
                await fe.stop()
                print(f"[http] stopped; stats: {driver.stats()}")

    try:
        asyncio.run(_run())
    except KeyboardInterrupt:
        print("[http] interrupted")


def main(exec_mode: str = "round", arch: str = "qwen3-0.6b",
         duration_s: float = 20.0, rps: float = 12.0,
         slo_ms: float = 1500.0, models: Optional[Sequence[str]] = None,
         max_instances: int = 4, kv_layout: str = "dense",
         kv_block_budget: Optional[int] = None,
         token_budget: Optional[int] = None,
         preemption: bool = False, kv_host_blocks: int = 0,
         preempt_mode: str = "auto", prefix_cache: bool = False,
         shared_prefix_tokens: float = 0.0, spec_k: int = 0,
         serve_http_port: Optional[int] = None,
         backpressure: bool = True, max_queue_depth: int = 8) -> None:
    if serve_http_port is not None:
        serve_http(models or [arch], port=serve_http_port, slo_ms=slo_ms,
                   max_instances=max_instances,
                   kv_layout=kv_layout if kv_layout else "paged",
                   kv_block_budget=kv_block_budget,
                   backpressure=backpressure,
                   max_queue_depth=max_queue_depth)
    elif models:
        if exec_mode != "continuous":
            print("multi-model pool serving is continuous-only; "
                  "running with --exec-mode continuous")
        serve_pool(models, duration_s, rps, slo_ms,
                   max_instances=max_instances, kv_layout=kv_layout,
                   kv_block_budget=kv_block_budget,
                   token_budget=token_budget, preemption=preemption,
                   kv_host_blocks=kv_host_blocks,
                   preempt_mode=preempt_mode,
                   prefix_cache=prefix_cache,
                   shared_prefix_tokens=int(shared_prefix_tokens),
                   spec_k=spec_k)
    elif exec_mode == "continuous":
        serve_continuous(arch, duration_s, rps, slo_ms,
                         kv_layout=kv_layout,
                         kv_block_budget=kv_block_budget,
                         token_budget=token_budget,
                         prefix_cache=prefix_cache,
                         shared_prefix_tokens=int(shared_prefix_tokens),
                         spec_k=spec_k)
    else:
        if kv_layout != "dense":
            print("round mode always uses the dense per-round cache; "
                  "--kv-layout applies to continuous/pool serving")
        if token_budget or preemption or prefix_cache or spec_k \
                or kv_host_blocks:
            print("chunked prefill / preemption / prefix caching / "
                  "speculation / KV offload are continuous-engine "
                  "features; ignored in round mode")
        serve_round(arch, duration_s, rps, slo_ms)


if __name__ == "__main__":
    main()
