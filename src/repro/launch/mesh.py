"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state. Single pod: 16x16 = 256 chips, axes
(data, model). Multi-pod: 2x16x16 = 512 chips with a leading "pod" axis
(data parallelism across pods — the lowest-bandwidth boundary gets the
least-frequent collective, the gradient all-reduce).
"""
from __future__ import annotations

import jax


def _axis_type_kwargs(n_axes: int) -> dict:
    """jax >= 0.4.35 takes explicit axis_types; older versions (like the
    CI container's) have no ``jax.sharding.AxisType`` and default every
    axis to Auto anyway."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def make_debug_mesh(n_data: int = 2, n_model: int = 2):
    """Small mesh for tests (requires >= n_data*n_model host devices)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"),
                         **_axis_type_kwargs(2))


def batch_axes(mesh) -> tuple:
    """The axes the global batch is sharded over."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)
