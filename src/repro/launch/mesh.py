"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state. Single pod: 16x16 = 256 chips, axes
(data, model). Multi-pod: 2x16x16 = 512 chips with a leading "pod" axis
(data parallelism across pods — the lowest-bandwidth boundary gets the
least-frequent collective, the gradient all-reduce).
"""
from __future__ import annotations

import jax


def _axis_type_kwargs(n_axes: int) -> dict:
    """jax >= 0.4.35 takes explicit axis_types; older versions (like the
    CI container's) have no ``jax.sharding.AxisType`` and default every
    axis to Auto anyway."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def _require_devices(n: int, what: str) -> None:
    """Fail BEFORE ``Mesh`` construction with an actionable message —
    jax's own error ("len(devices) < prod(shape)") names neither the
    mesh being built nor the CPU workaround."""
    have = len(jax.devices())
    if have < n:
        raise ValueError(
            f"{what} needs {n} devices but jax sees only {have}. On a "
            f"CPU host, set XLA_FLAGS=--xla_force_host_platform_device_"
            f"count={n} BEFORE importing jax (tests do this by "
            f"launching a subprocess; see tests/test_sharding_dryrun.py)")


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 512 if multi_pod else 256
    _require_devices(n, f"make_production_mesh(multi_pod={multi_pod})")
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def make_debug_mesh(n_data: int = 2, n_model: int = 2):
    """Small mesh for tests (requires >= n_data*n_model host devices)."""
    _require_devices(n_data * n_model,
                     f"make_debug_mesh({n_data}, {n_model})")
    return jax.make_mesh((n_data, n_model), ("data", "model"),
                         **_axis_type_kwargs(2))


def make_tp_mesh(tp_degree: int, devices=None):
    """1D ``("model",)``-only mesh for one tensor-parallel serving
    instance, carved from an explicit device subset — the instance
    pool hands each engine its slice of the shared device set, so
    co-resident instances at different TP degrees partition the same
    hardware. ``devices=None`` takes the first ``tp_degree`` of
    ``jax.devices()`` (single-engine use and tests)."""
    import numpy as np
    if tp_degree < 1:
        raise ValueError(f"tp_degree must be >= 1, got {tp_degree}")
    if devices is None:
        _require_devices(tp_degree, f"make_tp_mesh({tp_degree})")
        devices = jax.devices()[:tp_degree]
    if len(devices) != tp_degree:
        raise ValueError(
            f"make_tp_mesh({tp_degree}) given {len(devices)} devices")
    return jax.sharding.Mesh(np.asarray(devices), ("model",),
                             **_axis_type_kwargs(1))


def batch_axes(mesh) -> tuple:
    """The axes the global batch is sharded over."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)
