"""Roofline analysis (docs/EXPERIMENTS.md §Roofline).

Three terms per (arch x shape x mesh), in seconds:

    compute    = FLOPs / (chips * peak_FLOP/s)
    memory     = HBM bytes / (chips * HBM_bw)
    collective = collective bytes per chip / link_bw

Sources:
* FLOPs/HBM-bytes: an ANALYTIC workload model (documented below). XLA's
  ``cost_analysis`` does NOT multiply while-loop bodies by their trip
  count (verified empirically), and every model here scans over layer
  units — so raw cost_analysis under-reports by ~n_layers x. We therefore
  report the analytic numbers as the roofline terms and the raw
  cost_analysis numbers alongside (with the caveat) as a lower bound.
* collective bytes: parsed from the SPMD-partitioned ``compiled.as_text()``
  (shapes there are per-device). Ops inside while bodies are multiplied by
  the loop's trip count when XLA annotates it, else by the known scan
  lengths passed in ``loop_hints``.

Hardware constants (TPU v5e): 197 bf16 TFLOP/s, 819 GB/s HBM,
~50 GB/s/link ICI (per the brief).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

from repro.config.base import InputShape, ModelConfig

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter",
                  "all-to-all", "collective-permute")

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                "s8": 1, "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8,
                "f8e4m3fn": 1, "f8e5m2": 1, "s16": 2, "u16": 2}


# =====================================================================
# analytic workload model
# =====================================================================
@dataclasses.dataclass
class WorkloadCost:
    flops: float            # total FLOPs for the step (global)
    hbm_bytes: float        # total HBM traffic for the step (global)
    model_flops: float      # 6*N*D (train) / 2*N*D (inference) reference
    param_bytes: float

    def terms(self, chips: int, collective_bytes_per_chip: float,
              dtype_bytes: int = 2) -> Dict[str, float]:
        return {
            "compute_s": self.flops / (chips * PEAK_FLOPS),
            "memory_s": self.hbm_bytes / (chips * HBM_BW),
            "collective_s": collective_bytes_per_chip / ICI_BW,
            "model_flops": self.model_flops,
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
        }


def _layer_flops_per_token(cfg: ModelConfig, kind: str, ctx_len: float,
                           decode: bool) -> float:
    """Forward FLOPs per token for one layer of ``kind``."""
    d, hd, H, KV = cfg.d_model, cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    f = cfg.d_ff
    fl = 0.0
    if kind in ("attn", "attn_dense", "local_attn"):
        fl += 2 * d * H * hd + 2 * 2 * d * KV * hd + 2 * H * hd * d  # qkvo
        fl += 4 * ctx_len * H * hd                                   # scores+values
        if cfg.enc_dec:  # cross attention
            enc_len = ctx_len / 4
            fl += 2 * d * H * hd + 2 * H * hd * d + 4 * enc_len * H * hd
        if cfg.n_experts and kind != "attn_dense":
            fl += 2 * d * cfg.n_experts                              # router
            fl += cfg.top_k * (3 if cfg.activation in ("silu", "geglu")
                               else 2) * 2 * d * f
            if cfg.moe_dense_residual:
                fl += 3 * 2 * d * (cfg.dense_ff or f)
        else:
            width = (cfg.dense_ff or f) if kind == "attn_dense" else f
            n_mats = 3 if cfg.activation in ("silu", "geglu") else 2
            fl += n_mats * 2 * d * width
    elif kind == "rwkv":
        fl += 5 * 2 * d * d + 2 * d * d        # r/k/v/g/o + W_o
        fl += 10 * 2 * d * 32                  # token-shift loras
        fl += 5 * d * hd                       # wkv recurrence per token
        fl += 2 * 2 * d * cfg.d_ff + 2 * d * d  # channel mix
    elif kind == "rglru":
        w = cfg.rglru_width or d
        fl += 2 * 2 * d * w + 2 * 2 * w * w + 2 * 4 * w + 6 * w + 2 * w * d
        n_mats = 3 if cfg.activation in ("silu", "geglu") else 2
        fl += n_mats * 2 * d * cfg.d_ff
    return fl


def workload_cost(cfg: ModelConfig, shape: InputShape,
                  dtype_bytes: int = 2) -> WorkloadCost:
    B, S = shape.global_batch, shape.seq_len
    kinds = cfg.layer_kinds()
    params = cfg.param_count_estimate()
    active_params = cfg.param_count_estimate(active_only=True)
    p_bytes = params * dtype_bytes

    if shape.kind == "decode":
        n_tok = B  # one token per sequence
        fl = 0.0
        for kind in kinds:
            ctx = S
            if kind == "local_attn" or cfg.sliding_window:
                ctx = min(S, cfg.sliding_window or 2048)
            if kind in ("rwkv", "rglru"):
                ctx = 0
            fl += n_tok * _layer_flops_per_token(cfg, kind, ctx, True)
        fl += n_tok * 2 * cfg.d_model * cfg.vocab_size
        # HBM: weights (active experts only for small batches) + cache
        expert_frac = min(1.0, B * cfg.top_k / max(cfg.n_experts, 1)) \
            if cfg.n_experts else 1.0
        moe_bytes = (params - active_params) * 0  # handled via frac below
        w_bytes = p_bytes if not cfg.n_experts else (
            active_params * dtype_bytes
            + (params - active_params) * dtype_bytes * expert_frac)
        cache_bytes = 0.0
        for kind in kinds:
            if kind in ("attn", "attn_dense", "local_attn"):
                ctx = min(S, cfg.sliding_window or S)
                if kind == "local_attn":
                    ctx = min(S, cfg.sliding_window or 2048)
                cache_bytes += 2 * B * ctx * cfg.n_kv_heads * cfg.head_dim \
                    * dtype_bytes
            elif kind == "rwkv":
                hd = cfg.rwkv_head_size
                cache_bytes += B * (cfg.d_model // hd) * hd * hd * 4
            elif kind == "rglru":
                cache_bytes += B * (cfg.rglru_width or cfg.d_model) * 4
        hbm = w_bytes + cache_bytes + n_tok * cfg.d_model * dtype_bytes * \
            len(kinds) * 8
        model_fl = 2 * active_params * n_tok
        return WorkloadCost(fl, hbm, model_fl, p_bytes)

    # train / prefill: N = B*S tokens
    n_tok = B * S
    fl = 0.0
    for kind in kinds:
        ctx = S / 2  # causal average
        if kind == "local_attn" or cfg.sliding_window:
            ctx = min(S / 2, (cfg.sliding_window or 2048))
        if kind in ("rwkv", "rglru"):
            ctx = 0
        fl += n_tok * _layer_flops_per_token(cfg, kind, ctx, False)
    if cfg.enc_dec:
        enc_tok = B * (S // 4)
        for _ in range(cfg.n_enc_layers):
            fl += enc_tok * _layer_flops_per_token(
                dataclasses.replace(cfg, enc_dec=False), "attn", S / 8,
                False)
    if shape.kind == "train":
        fl += n_tok * 2 * cfg.d_model * cfg.vocab_size  # lm head
        fl *= 3  # fwd + bwd
        acts = 2 * n_tok * cfg.d_model * dtype_bytes * len(kinds) * 10
        hbm = 3 * p_bytes + 2 * p_bytes * 2 + acts  # w fwd/bwd + opt + acts
        model_fl = 6 * active_params * n_tok
    else:  # prefill
        fl += B * 2 * cfg.d_model * cfg.vocab_size  # last-token logits
        acts = 2 * n_tok * cfg.d_model * dtype_bytes * len(kinds) * 6
        hbm = p_bytes + acts
        model_fl = 2 * active_params * n_tok
    return WorkloadCost(fl, hbm, model_fl, p_bytes)


# =====================================================================
# HLO collective parsing
# =====================================================================
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\(?[^=]*?\)?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.M)
_COMP_RE = re.compile(r"^(%?[\w.\-]+)\s*(?:\([^)]*\))?\s*->.*{\s*$")
_TRIP_RE = re.compile(r'known_trip_count=\{"?(\d+)"?\}')


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for dim in dims.split(","):
            if dim.strip():
                n *= int(dim)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _computations(hlo_text: str) -> Dict[str, str]:
    """Split an HLO module into {computation_name: body_text}."""
    comps: Dict[str, list] = {}
    cur = None
    for line in hlo_text.splitlines():
        if line and not line[0].isspace() and "{" in line:
            m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)", line)
            if m:
                cur = m.group(1)
                comps[cur] = []
                continue
        if cur is not None:
            comps[cur].append(line)
    return {k: "\n".join(v) for k, v in comps.items()}


def _while_trips(comps: Dict[str, str]) -> Dict[str, int]:
    """Map while-BODY computation name -> trip count.

    XLA CPU does not annotate ``known_trip_count``; scan loops compare an
    iteration counter against a constant in the *condition* computation, so
    we read the largest integer constant there. Nested loops compose by
    multiplying through the call chain.
    """
    body_cond = {}
    for comp, text in comps.items():
        for m in re.finditer(
                r"while\(.*?\),\s*condition=%?([\w.\-]+),\s*"
                r"body=%?([\w.\-]+)", text):
            body_cond[m.group(2)] = (m.group(1), comp)
    trips: Dict[str, int] = {}
    for body, (cond, _parent) in body_cond.items():
        consts = [int(c) for c in re.findall(
            r"constant\((\d+)\)", comps.get(cond, ""))]
        trips[body] = max(consts) if consts else 1

    # propagate nesting: a body called from another body inherits its factor
    def factor(body, depth=0):
        if depth > 8 or body not in body_cond:
            return 1
        _, parent = body_cond[body]
        return trips.get(body, 1) * factor(parent, depth + 1) \
            if parent in body_cond else trips.get(body, 1)

    return {b: factor(b) for b in body_cond}


def parse_collectives(hlo_text: str,
                      loop_hints: Optional[Dict[str, int]] = None
                      ) -> Dict[str, float]:
    """Sum per-device collective bytes from a partitioned HLO module.

    Ops inside while bodies are multiplied by the loop trip count (derived
    from the loop-condition constant — XLA CPU lacks known_trip_count
    annotations; nested loops multiply through).

    NOTE: the CPU backend legalises bf16 dots to f32, so partial-sum
    all-reduces that would be bf16 on TPU appear as f32 here — treat the
    bytes as a ~2x-conservative upper bound for bf16 models.
    """
    comps = _computations(hlo_text)
    trips = _while_trips(comps)
    totals = {op: 0.0 for op in COLLECTIVE_OPS}
    counts = {op: 0 for op in COLLECTIVE_OPS}
    for comp, text in comps.items():
        mult = trips.get(comp, 1)
        for line in text.splitlines():
            m = _OP_RE.match(line)
            if m:
                shape_str, op = m.group(1), m.group(2)
                totals[op] += _shape_bytes(shape_str) * mult
                counts[op] += mult
    out = {f"{op}_bytes": v for op, v in totals.items()}
    out.update({f"{op}_count": float(c) for op, c in counts.items()})
    out["total_bytes"] = sum(totals.values())
    return out
