"""Serving launcher: BCEdge scheduler over the edge simulator (default) or
the real-JAX engine (``--engine``), in round or continuous execution mode
(docs/ARCHITECTURE.md §5).

    PYTHONPATH=src python -m repro.launch.serve --platform xavier_nx \
        --episodes 6 --rps 30
    PYTHONPATH=src python -m repro.launch.serve --exec-mode continuous \
        --decode-steps 6
    PYTHONPATH=src python -m repro.launch.serve --engine --arch qwen3-0.6b
    PYTHONPATH=src python -m repro.launch.serve --engine \
        --models qwen3-0.6b,recurrentgemma-2b --exec-mode continuous \
        --max-instances 4
    PYTHONPATH=src python -m repro.launch.serve --engine --serve-http \
        --port 8808 --models qwen3-0.6b
"""
from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--platform", default="xavier_nx",
                    choices=["xavier_nx", "jetson_tx2", "jetson_nano",
                             "tpu_v5e"])
    ap.add_argument("--rps", type=float, default=30.0)
    ap.add_argument("--episodes", type=int, default=6)
    ap.add_argument("--episode-ms", type=float, default=20_000.0)
    ap.add_argument("--no-guard", action="store_true")
    ap.add_argument("--exec-mode", default="round",
                    choices=["round", "continuous"],
                    help="round = run-to-completion (b, m_c) rounds "
                         "(paper §IV-D); continuous = iteration-level "
                         "batching (docs/ARCHITECTURE.md §5)")
    ap.add_argument("--decode-steps", type=float, default=1.0,
                    help="mean decode iterations per request (geometric); "
                         ">1 makes the workload autoregressive")
    ap.add_argument("--engine", action="store_true",
                    help="serve a real reduced model instead of the sim")
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--models", default=None,
                    help="comma-separated arch ids for the multi-model "
                         "pool serve mode (docs/RUNTIME.md); requires "
                         "--engine, continuous-only")
    ap.add_argument("--max-instances", type=int, default=4,
                    help="pool-wide live engine instance budget shared "
                         "by all --models")
    ap.add_argument("--kv-layout", default="dense",
                    choices=["dense", "paged"],
                    help="continuous-engine KV cache layout: dense "
                         "per-slot slabs or the vLLM-style block pool "
                         "(docs/ARCHITECTURE.md §5)")
    ap.add_argument("--kv-block-budget", type=int, default=None,
                    help="total KV blocks shared by all pool instances "
                         "(paged only; default: unlimited, each "
                         "instance gets its dense-equivalent grant)")
    ap.add_argument("--token-budget", type=int, default=None,
                    help="per-iteration cap on prefill-chunk + decode "
                         "tokens (chunked prefill, docs/ARCHITECTURE.md "
                         "§5); engine/pool: fixed cap + scheduler axis; "
                         "simulator: adds an action level. Default: "
                         "uncapped")
    ap.add_argument("--preemption", action="store_true",
                    help="SLO-aware preemption: evict the largest-slack "
                         "resident when an urgent request cannot be "
                         "admitted (docs/RUNTIME.md §8)")
    ap.add_argument("--kv-host-blocks", type=int, default=0,
                    help="host-memory KV block tier per paged engine "
                         "instance: preempted sequences can swap their "
                         "blocks to host instead of recomputing, and "
                         "the prefix cache spills cold blocks there "
                         "before invalidating (docs/RUNTIME.md §8). "
                         "Default: 0 (no host tier)")
    ap.add_argument("--preempt-mode", default="auto",
                    choices=["auto", "recompute", "swap"],
                    help="preemption eviction mode: recompute frees KV "
                         "and re-prefills on resume, swap moves it to "
                         "the host tier (needs --kv-host-blocks), auto "
                         "prices both with the calibrated token-cost "
                         "and swap-bandwidth fits and picks the "
                         "cheaper per victim (docs/RUNTIME.md §8)")
    ap.add_argument("--prefill-tokens", type=float, default=0.0,
                    help="simulator: mean prompt tokens per request "
                         "(geometric; 0 = single-shot, no prefill "
                         "modeling)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="vLLM-style prefix caching: paged engines "
                         "share full immutable prompt blocks at "
                         "refcount+1 (copy-on-write tails, LRU reuse of "
                         "evicted blocks; docs/ARCHITECTURE.md §5); the "
                         "simulator skips already-paid shared prefixes. "
                         "Engine/pool modes require --kv-layout paged")
    ap.add_argument("--shared-prefix-tokens", type=float, default=0.0,
                    help="templated workload: every prompt starts with "
                         "one of a small population of shared prefixes "
                         "of this many tokens (the regime "
                         "--prefix-cache exploits). Default: 0 (off)")
    ap.add_argument("--serve-http", action="store_true",
                    help="push-mode HTTP serving (docs/RUNTIME.md §11): "
                         "background driver steps the pool, asyncio "
                         "front-end streams per-token ndjson events; "
                         "requires --engine. Runs until interrupted")
    ap.add_argument("--port", type=int, default=8808,
                    help="--serve-http listen port (0 = ephemeral)")
    ap.add_argument("--no-backpressure", action="store_true",
                    help="--serve-http: disable 429 admission "
                         "backpressure (accept-everything)")
    ap.add_argument("--max-queue-depth", type=int, default=8,
                    help="--serve-http: queued requests tolerated per "
                         "model before non-admissible arrivals get "
                         "429 + Retry-After")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="self-speculative decoding depth: propose up "
                         "to k n-gram draft tokens per slot and verify "
                         "them in one forward (docs/ARCHITECTURE.md "
                         "§speculation); engine/pool: cap + fourth "
                         "scheduler axis; simulator: adds an action "
                         "level. Continuous-only. Default: 0 (off)")
    args = ap.parse_args()

    if args.models and not args.engine:
        ap.error("--models requires --engine (the simulator is already "
                 "multi-tenant over the paper's Table-IV models)")
    if args.prefix_cache and args.engine and args.kv_layout != "paged":
        ap.error("--prefix-cache on the engine needs --kv-layout paged "
                 "(sharing is block-granular)")
    if args.serve_http and not args.engine:
        ap.error("--serve-http requires --engine (the HTTP front-end "
                 "streams real engine tokens)")
    if args.kv_host_blocks and args.kv_layout != "paged":
        ap.error("--kv-host-blocks needs --kv-layout paged (the host "
                 "tier holds KV blocks)")
    if args.preempt_mode == "swap" and args.kv_host_blocks <= 0:
        ap.error("--preempt-mode swap needs --kv-host-blocks > 0 "
                 "(there is nowhere to swap to)")

    if args.engine:
        from repro.launch import engine_serve

        models = [m for m in (args.models or "").split(",") if m] or None
        engine_serve.main(exec_mode=args.exec_mode, arch=args.arch,
                          models=models, max_instances=args.max_instances,
                          kv_layout=args.kv_layout,
                          kv_block_budget=args.kv_block_budget,
                          token_budget=args.token_budget,
                          preemption=args.preemption,
                          kv_host_blocks=max(0, args.kv_host_blocks),
                          preempt_mode=args.preempt_mode,
                          prefix_cache=args.prefix_cache,
                          shared_prefix_tokens=args.shared_prefix_tokens,
                          spec_k=max(0, args.spec_k),
                          serve_http_port=args.port if args.serve_http
                          else None,
                          backpressure=not args.no_backpressure,
                          max_queue_depth=args.max_queue_depth)
        return

    from repro.config.base import ServingConfig
    from repro.core.interference import NNInterferencePredictor
    from repro.core.sac import SACAgent, SACConfig
    from repro.serving.bcedge import run_episode
    from repro.serving.features import state_dim
    from repro.serving.simulator import EdgeServingEnv

    from repro.serving.profiler import PerformanceProfiler

    cfg = ServingConfig(platform=args.platform, arrival_rps=args.rps,
                        exec_mode=args.exec_mode,
                        decode_steps_mean=max(1.0, args.decode_steps),
                        prefill_tokens_mean=max(0.0, args.prefill_tokens),
                        token_budgets=(0,) if not args.token_budget
                        else (0, args.token_budget),
                        preemption=args.preemption,
                        shared_prefix_tokens=max(
                            0.0, args.shared_prefix_tokens),
                        prefix_cache=args.prefix_cache,
                        spec_depths=(0,) if args.spec_k <= 0
                        else (0, args.spec_k))
    env0 = EdgeServingEnv(cfg, episode_ms=1.0)
    agent = SACAgent(state_dim(env0.models), cfg.n_actions,
                     SACConfig(batch_size=256, lr=5e-4))
    pred = None if args.no_guard else NNInterferencePredictor()
    profiler = PerformanceProfiler()
    for ep in range(args.episodes):
        env = EdgeServingEnv(cfg, episode_ms=args.episode_ms, seed=ep)
        res = run_episode(env, agent, pred, guard=not args.no_guard)
        profiler.reset_env()
        profiler.poll(env)
        s = res.summary
        util = profiler.utilization()
        print(f"ep{ep}: utility={s['mean_utility']:.2f} "
              f"thr={s['throughput_rps']:.1f}rps "
              f"goodput={s['goodput_rps']:.1f}rps "
              f"viol={s['slo_violation_rate']:.1%} "
              f"lat={s['mean_latency_ms']:.0f}ms "
              f"busy={util['busy_frac']:.0%} "
              f"overhead={sum(res.overhead_ms)/max(len(res.overhead_ms),1):.2f}ms/decision")
    # profiler-informed per-model configurations (§IV-E)
    for m in env0.models:
        best = profiler.best_config(m, max_violation=0.2)
        if best:
            print(f"profile[{m}]: best (b, m_c) within 20% violations "
                  f"= {best}")


if __name__ == "__main__":
    main()
