"""Asyncio HTTP front-end for the serving pool (docs/RUNTIME.md §11).

Stdlib-only (``asyncio.start_server`` + hand-rolled HTTP/1.1): the
container this repo targets ships no HTTP framework, and the protocol
surface is deliberately small —

* ``POST /v1/generate`` — JSON body
  ``{"model": str, "prompt": [int, ...], "max_new_tokens": int,
  "slo_ms": float}``; streams newline-delimited JSON events
  (``accepted``, ``prefill``, ``decode``, ``token``, ``preempted``,
  ``finished``, ``rejected``, ``cancelled``) with chunked transfer
  encoding, one chunk per event, flushed as the pool emits them.
* ``GET /v1/stats`` — pool ``stats()`` + ``report()`` as JSON.
* ``GET /healthz`` — liveness.

Two production behaviours the benchmark asserts on:

* **cancellation on disconnect** — while streaming, the handler watches
  the client socket for EOF; a client that goes away cancels its request
  through the driver, which evicts the slot and frees its blocks
  synchronously (mass disconnect frees capacity immediately).
* **backpressure** — when the pool's ``admission_headroom`` says the
  request cannot start now and the queue is past ``max_queue_depth``,
  the server answers ``429 Too Many Requests`` with a ``Retry-After``
  header derived from the calibrated per-token cost over the work queued
  ahead (``accept_all=True`` disables this — the accept-everything
  baseline the figure compares against).

Events cross from the driver thread into asyncio via
``loop.call_soon_threadsafe`` onto a per-request queue — pool listeners
stay cheap and never touch the socket.
"""
from __future__ import annotations

import asyncio
import json
from typing import Dict, Optional, Tuple

import numpy as np

from repro.serving.driver import ServingDriver

#: hard cap on request-body size (prompts are token-id lists, not text)
_MAX_BODY = 1 << 20


def _http_response(status: str, body: bytes,
                   headers: Optional[Dict[str, str]] = None) -> bytes:
    head = [f"HTTP/1.1 {status}",
            "Content-Type: application/json",
            f"Content-Length: {len(body)}",
            "Connection: close"]
    for k, v in (headers or {}).items():
        head.append(f"{k}: {v}")
    return ("\r\n".join(head) + "\r\n\r\n").encode() + body


def _json_response(status: str, obj,
                   headers: Optional[Dict[str, str]] = None) -> bytes:
    return _http_response(status, json.dumps(obj).encode(), headers)


class ServingFrontend:
    """HTTP server over a running :class:`ServingDriver`.

    The frontend does not own the driver's lifecycle — callers start and
    stop driver and frontend separately (tools/server_smoke.py shows the
    full wiring)::

        with ServingDriver(pool, on_tick=sched.tick) as driver:
            fe = ServingFrontend(driver, port=0)
            await fe.start()
            ...
            await fe.stop()
    """

    def __init__(self, driver: ServingDriver, host: str = "127.0.0.1",
                 port: int = 8808, backpressure: bool = True,
                 max_queue_depth: int = 8,
                 default_slo_ms: float = 1000.0,
                 default_max_new: int = 8):
        self.driver = driver
        self.host = host
        self.port = port          # 0 = ephemeral; real port set by start()
        #: False = accept-everything baseline: every request queues, no
        #: 429 is ever returned (the policy the figure shows collapsing)
        self.backpressure = backpressure
        #: queued requests tolerated per model before a non-admissible
        #: request is bounced with 429 + Retry-After
        self.max_queue_depth = max_queue_depth
        self.default_slo_ms = default_slo_ms
        self.default_max_new = default_max_new
        self.n_streamed = 0
        self.n_throttled = 0
        self.n_disconnects = 0
        self._server: Optional[asyncio.base_events.Server] = None

    # ---- lifecycle -------------------------------------------------------
    async def start(self) -> None:
        self._server = await asyncio.start_server(
            self._handle, self.host, self.port)
        self.port = self._server.sockets[0].getsockname()[1]

    async def stop(self) -> None:
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
            self._server = None

    async def serve_forever(self) -> None:
        async with self._server:
            await self._server.serve_forever()

    # ---- request plumbing ------------------------------------------------
    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        try:
            method, path, body = await self._read_request(reader)
            if method == "GET" and path == "/healthz":
                writer.write(_json_response("200 OK", {"ok": True}))
            elif method == "GET" and path == "/v1/stats":
                writer.write(_json_response("200 OK", {
                    "stats": self.driver.stats(),
                    "report": self.driver.report(),
                    "frontend": {"n_streamed": self.n_streamed,
                                 "n_throttled": self.n_throttled,
                                 "n_disconnects": self.n_disconnects}}))
            elif method == "POST" and path == "/v1/generate":
                await self._generate(reader, writer, body)
            else:
                writer.write(_json_response(
                    "404 Not Found", {"error": f"no route {path}"}))
            await writer.drain()
        except (ConnectionError, asyncio.IncompleteReadError, ValueError):
            pass  # client went away / malformed request line
        finally:
            writer.close()
            try:
                await writer.wait_closed()
            except (ConnectionError, OSError):
                pass

    @staticmethod
    async def _read_request(reader: asyncio.StreamReader
                            ) -> Tuple[str, str, bytes]:
        line = await reader.readline()
        parts = line.decode("latin-1").split()
        if len(parts) < 2:
            raise ValueError("malformed request line")
        method, path = parts[0].upper(), parts[1]
        length = 0
        while True:
            h = await reader.readline()
            if h in (b"\r\n", b"\n", b""):
                break
            name, _, val = h.decode("latin-1").partition(":")
            if name.strip().lower() == "content-length":
                length = min(int(val.strip()), _MAX_BODY)
        body = await reader.readexactly(length) if length else b""
        return method, path, body

    def _parse_generate(self, body: bytes):
        req = json.loads(body.decode() or "{}")
        model = req.get("model")
        if model not in self.driver.pool.configs:
            raise KeyError(
                f"unknown model {model!r}; pool serves "
                f"{sorted(self.driver.pool.configs)}")
        prompt = np.asarray(req.get("prompt", []), np.int32)
        if prompt.ndim != 1 or len(prompt) == 0:
            raise ValueError("prompt must be a non-empty list of token ids")
        max_new = int(req.get("max_new_tokens", self.default_max_new))
        slo_ms = float(req.get("slo_ms", self.default_slo_ms))
        return model, prompt, max_new, slo_ms

    # ---- streaming generate ----------------------------------------------
    async def _generate(self, reader: asyncio.StreamReader,
                        writer: asyncio.StreamWriter,
                        body: bytes) -> None:
        try:
            model, prompt, max_new, slo_ms = self._parse_generate(body)
        except (KeyError, ValueError, json.JSONDecodeError) as e:
            writer.write(_json_response("400 Bad Request",
                                        {"error": str(e)}))
            return
        if self.backpressure:
            head = self.driver.admission_headroom(model, len(prompt),
                                                  max_new)
            if not head["admissible_now"] \
                    and head["queue_depth"] >= self.max_queue_depth:
                self.n_throttled += 1
                retry = head["retry_after_s"]
                writer.write(_json_response(
                    "429 Too Many Requests",
                    {"error": "admission backlog", "retry_after_s": retry,
                     "queue_depth": head["queue_depth"],
                     "backlog_tokens": head["backlog_tokens"]},
                    headers={"Retry-After": f"{retry:.3f}"}))
                return

        loop = asyncio.get_running_loop()
        events: asyncio.Queue = asyncio.Queue()

        def listener(ev: dict) -> None:
            # driver thread -> asyncio loop; put_nowait is loop-internal
            loop.call_soon_threadsafe(events.put_nowait, ev)

        # submit + listener registration under one lock acquisition so
        # no event can fire before the listener is attached
        with self.driver.lock:
            try:
                rid = self.driver.pool.submit(
                    model, prompt, slo_ms=slo_ms, max_new_tokens=max_new)
            except ValueError as e:  # never-fitting shape
                writer.write(_json_response("400 Bad Request",
                                            {"error": str(e)}))
                return
            self.driver.pool.add_listener(rid, listener)

        writer.write((
            "HTTP/1.1 200 OK\r\n"
            "Content-Type: application/x-ndjson\r\n"
            "Transfer-Encoding: chunked\r\n"
            "Connection: close\r\n\r\n").encode())
        self._write_chunk(writer, {"event": "accepted", "request_id": rid})
        await writer.drain()

        # the body was fully consumed, so any further read returns only
        # on EOF — the client hanging up mid-stream (an abrupt RST is
        # the same signal as a clean close)
        async def eof_watch() -> bytes:
            try:
                return await reader.read(1)
            except (ConnectionError, OSError):
                return b""

        eof_task = asyncio.ensure_future(eof_watch())
        get_task: Optional[asyncio.Task] = None
        try:
            while True:
                get_task = asyncio.ensure_future(events.get())
                done, _ = await asyncio.wait(
                    {get_task, eof_task},
                    return_when=asyncio.FIRST_COMPLETED)
                if eof_task in done and get_task not in done:
                    get_task.cancel()
                    self.n_disconnects += 1
                    self.driver.cancel(rid)
                    return
                ev = get_task.result()
                self._write_chunk(writer, ev)
                await writer.drain()
                if ev["event"] in ("finished", "cancelled", "rejected"):
                    self.n_streamed += 1
                    self._write_final_chunk(writer)
                    await writer.drain()
                    return
        except (ConnectionError, OSError):
            self.n_disconnects += 1
            self.driver.cancel(rid)
        finally:
            self.driver.remove_listener(rid)
            for t in (eof_task, get_task):
                if t is not None and not t.done():
                    t.cancel()

    @staticmethod
    def _write_chunk(writer: asyncio.StreamWriter, obj) -> None:
        data = (json.dumps(obj) + "\n").encode()
        writer.write(f"{len(data):x}\r\n".encode() + data + b"\r\n")

    @staticmethod
    def _write_final_chunk(writer: asyncio.StreamWriter) -> None:
        writer.write(b"0\r\n\r\n")
