"""Sharding rules: parameter/cache/input PartitionSpecs for the 2D/3D mesh.

Baseline layout (the §Perf hillclimb iterates from here):
* tensor parallelism over the ``model`` axis — attention/MLP projections
  column-/row-sharded, embeddings vocab-sharded, MoE experts
  expert-parallel over ``model``;
* batch over ``(pod, data)``;
* KV caches: batch on data axes + *cache length* on ``model``: decode
  attention then computes scores locally per length-shard and only psums
  the (B,H) softmax statistics and the (B,H,hd) weighted values — a
  distributed flash-decode. (Sharding n_kv_heads is impossible — kv is
  1..16 and uneven; sharding head_dim would psum (B,H,C) score tensors.)
* recurrent states: RWKV (B,H,hdk,hdv) sharded on the *value* dim so the
  per-step outer-product recurrence is local (decay/bonus contract over
  the key dim); RG-LRU width on ``model`` (diagonal => local).

Rules are path-keyed; stacked scan-unit params carry one extra leading
(n_units) dim which maps to None.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.common.tree import tree_map_with_path
from repro.config.base import InputShape, ModelConfig

M = "model"


def _base_spec(path: str, eff_ndim: int) -> Tuple:
    """Spec for the *unstacked* trailing dims of a leaf."""
    parts = path.split("/")
    name = parts[-1]
    parent = parts[-2] if len(parts) > 1 else ""

    if name == "embed":
        return (M, None)
    if name == "lm_head":
        return (None, M)
    if name in ("wq", "wk", "wv"):
        return (None, M)
    if name == "wo":
        return (M, None)
    if name in ("w_up", "w_gate") and eff_ndim == 3:   # MoE experts (E,d,f)
        return (M, None, None)
    if name == "w_down" and eff_ndim == 3:             # MoE experts (E,f,d)
        return (M, None, None)
    if name in ("w_up", "w_gate"):
        return (None, M)
    if name == "w_down":
        return (M, None)
    if name == "router":
        return (None, M)
    # RWKV
    if parent == "time_mix":
        if name in ("W_r", "W_k", "W_v", "W_g"):
            return (None, M)
        if name == "W_o":
            return (M, None)
        if name == "u":
            return (None, M)        # (H, hd): shard hd
        if name == "w_base":
            return (M,)
    if parent == "channel_mix":
        if name in ("W_k", "W_r"):
            return (None, M)
        if name == "W_v":
            return (M, None)
    # RG-LRU
    if name in ("W_x",) or (parent == "rec" and name == "W_gate"):
        return (None, M)
    if name in ("W_a", "W_i"):
        return (None, M)
    if parent == "rec" and name == "W_o":
        return (M, None)
    if name == "conv_w":
        return (None, M)
    if name in ("conv_b", "lam", "b_a", "b_i"):
        return (M,)
    if name == "frontend_proj":
        return (None, M)
    # norms, biases, loras, mu_*: replicate
    return tuple(None for _ in range(eff_ndim))


def param_pspec(path: str, leaf, mode: str = "tp",
                data_size: int = 16) -> P:
    parts = path.split("/")
    # stacked scan-unit params: "units/<pos>/..." carries a leading dim
    stacked = 1 if parts[0] == "units" or (
        parts[0] == "encoder" and parts[1] == "layers") else 0
    eff_ndim = leaf.ndim - stacked
    spec = _base_spec(path, eff_ndim)
    if len(spec) != eff_ndim:  # rule mismatch -> replicate (safe default)
        spec = tuple(None for _ in range(eff_ndim))
    if mode == "2d" and eff_ndim >= 2:
        # §Perf iteration: additionally shard the other weight dim over
        # `data` (2D weight sharding). XLA/GSPMD then picks per-use between
        # gathering the weight (FSDP-style, good for big-token steps) and
        # partial contraction + reduce (2D TP, good for decode). Only
        # upgrade a dim whose size divides the data axis.
        spec_l = list(spec)
        for i, s in enumerate(spec_l):
            dim = leaf.shape[stacked + i]
            if s is None and dim % data_size == 0:
                spec_l[i] = "data"
                break
        spec = tuple(spec_l)
    return P(*((None,) * stacked + spec))


def param_shardings(mesh, params_abstract, mode: str = "tp") -> Any:
    data_size = mesh.shape.get("data", 1)
    return tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, param_pspec(path, leaf, mode, data_size)),
        params_abstract)


# ---------------------------------------------------------------- inputs
def _batch_spec(mesh, global_batch: int):
    axes = [a for a in ("pod", "data") if a in mesh.axis_names]
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    if global_batch % n == 0:
        return tuple(axes)
    return None  # e.g. long_500k batch=1: replicate


def input_shardings(mesh, cfg: ModelConfig, specs: Dict,
                    mode: str = "tp") -> Dict:
    out = {}
    for key, sds in specs.items():
        b_ax = None if mode == "decode2d" else _batch_spec(
            mesh, sds.shape[0])
        trailing = (None,) * (len(sds.shape) - 1)
        out[key] = NamedSharding(mesh, P(b_ax, *trailing))
    return out


# ---------------------------------------------------------------- caches
def cache_pspec(path: str, leaf, mesh, batch_axes,
                mode: str = "tp") -> NamedSharding:
    parts = path.split("/")
    name = parts[-1]
    stacked = 1 if parts[0] == "units" else 0
    b_ax = None if mode == "decode2d" else batch_axes
    # decode2d: batch replicated => shard the cache length over BOTH axes
    len_ax = (tuple(a for a in ("data", M) if a in mesh.axis_names)
              if mode == "decode2d" else M)
    if name in ("k", "v", "ck", "cv"):
        clen = leaf.shape[stacked + 1]
        n_len = 1
        axes = len_ax if isinstance(len_ax, tuple) else (len_ax,)
        for a in axes:
            n_len *= mesh.shape[a]
        spec = (b_ax, len_ax if clen % n_len == 0 else M, None, None)
    elif name == "att_state":
        spec = (b_ax, None, None, M)          # (B, H, hd_k, hd_v): shard v
    elif name in ("att_shift", "ffn_shift", "h"):
        spec = (b_ax, M)                      # (B, d|w)
    elif name == "conv":
        spec = (b_ax, None, M)                # (B, 3, w)
    else:
        spec = tuple(None for _ in range(leaf.ndim - stacked))
    return NamedSharding(mesh, P(*((None,) * stacked + spec)))


def cache_shardings(mesh, cfg: ModelConfig, cache_abstract,
                    global_batch: int, mode: str = "tp") -> Any:
    b_ax = _batch_spec(mesh, global_batch)
    return tree_map_with_path(
        lambda path, leaf: cache_pspec(path, leaf, mesh, b_ax, mode),
        cache_abstract)


def replicated(mesh):
    return NamedSharding(mesh, P())


# ------------------------------------------- serving-engine TP shardings
# The continuous serving engine spans a 1D ("model",)-only mesh carved
# from the pool's shared device set (launch/mesh.make_tp_mesh): slots
# and block tables are replicated, and the model dimension is TENSOR
# parallelism only. Unlike the launch-scale rules above, serving configs
# (tiny test models included) have dims the model axis need not divide —
# odd vocab sizes, 2-head caches on a 4-way mesh — so every spec here is
# filtered through ``_fit_mesh``: a sharded axis that does not divide
# its dim falls back to replicated instead of failing inside jit.

def _fit_mesh(spec: Tuple, shape: Tuple[int, ...], mesh) -> Tuple:
    """Drop spec axes absent from ``mesh`` or not dividing their dim."""
    out = []
    for s, dim in zip(spec, shape):
        keep = (s is not None and s in mesh.axis_names
                and dim % mesh.shape[s] == 0)
        out.append(s if keep else None)
    return tuple(out)


def engine_param_shardings(mesh, params_abstract) -> Any:
    """``param_shardings`` for a serving instance: the launch TP rules,
    divisibility-filtered per leaf (e.g. a 97-entry embedding stays
    replicated on a 2-way mesh while wq/wk/wv/wo shard)."""
    def sharding(path, leaf):
        spec = tuple(param_pspec(path, leaf, mode="tp"))
        return NamedSharding(mesh, P(*_fit_mesh(spec, leaf.shape, mesh)))
    return tree_map_with_path(sharding, params_abstract)


def engine_cache_pspec(path: str, leaf, mesh) -> P:
    """PartitionSpec for one serving-engine cache leaf — dense slot
    slabs and paged block pools alike. Linear KV leaves, dense
    ``(B, S, n_kv, hd)`` and paged ``(n_blocks, bs, n_kv, hd)``, shard
    the HEAD axis over ``model``, matching the column-sharded
    wq/wk/wv: scores and the weighted-value contraction stay local per
    head shard and the row-sharded wo psums once per step. (The
    launch-scale rules shard dense cache LENGTH instead — right for
    16-chip axes where n_kv never divides, wrong here where the block
    axis is gathered through replicated tables and head counts are
    chosen to divide the TP degree.) Recurrent/windowed leaves follow
    the launch rules: att_state value dim, shift/conv width on
    ``model``. Anything not divisible replicates."""
    parts = path.split("/")
    name = parts[-1]
    stacked = 1 if parts[0] == "units" else 0
    if name in ("k", "v", "ck", "cv"):
        spec = (None, None, M, None)
    elif name == "att_state":
        spec = (None, None, None, M)          # (B, H, hd_k, hd_v)
    elif name in ("att_shift", "ffn_shift", "h"):
        spec = (None, M)                      # (B, d|w)
    elif name == "conv":
        spec = (None, None, M)                # (B, 3, w)
    else:
        spec = tuple(None for _ in range(leaf.ndim - stacked))
    spec = _fit_mesh(spec, leaf.shape[stacked:], mesh)
    return P(*((None,) * stacked + spec))


def engine_cache_shardings(mesh, cache_abstract) -> Any:
    return tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, engine_cache_pspec(path, leaf, mesh)),
        cache_abstract)
