"""Training launcher: ``PYTHONPATH=src python -m repro.launch.train
--arch qwen3-0.6b --reduced --steps 100``.

Full (non-reduced) configs are for real TPU pods; on this host always pass
``--reduced``.
"""
from __future__ import annotations

import argparse

from repro.config import get_config, get_reduced_config, list_archs
from repro.train.trainer import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    cfg = get_reduced_config(args.arch) if args.reduced \
        else get_config(args.arch)
    trainer = Trainer(cfg, TrainerConfig(
        batch=args.batch, seq_len=args.seq, steps=args.steps, lr=args.lr,
        ckpt_path=args.ckpt))
    stats = trainer.run()
    print(f"final loss: {stats['final_loss']:.4f}")


if __name__ == "__main__":
    main()
