"""GQA attention: full-sequence (train/prefill) and single-token decode.

Three full-sequence implementations, selected by ``impl``:

* ``naive``   — materialises (S, T) scores; fine for short smoke shapes and
                used as the correctness oracle.
* ``chunked`` — lax.map over query chunks; peak memory O(C*T) instead of
                O(S*T). This is the shape the dry-run lowers at 32k so the
                compiled HLO never materialises a quadratic buffer.
* ``kernel``  — Pallas flash-attention (TPU target; interpret-mode on CPU).

Decode attends one new token against a KV cache. Caches are linear
(``cache_len == max_seq``) or ring buffers (``cache_len == window``) for
sliding-window layers; ring entries store keys already rotated at their
absolute positions.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig
from repro.models.layers import dense_init, norm_init, apply_norm
from repro.models.rope import apply_rope

NEG_INF = -2.0e38


# ---------------------------------------------------------------- params
def attn_init(rng, cfg: ModelConfig, dtype) -> Dict:
    ks = jax.random.split(rng, 4)
    d, hd = cfg.d_model, cfg.head_dim
    p = {
        "wq": dense_init(ks[0], d, cfg.n_heads * hd, dtype),
        "wk": dense_init(ks[1], d, cfg.n_kv_heads * hd, dtype),
        "wv": dense_init(ks[2], d, cfg.n_kv_heads * hd, dtype),
        "wo": dense_init(ks[3], cfg.n_heads * hd, d, dtype,
                         scale=1.0 / jnp.sqrt(cfg.n_heads * hd)),
    }
    if cfg.qk_norm:
        p["q_norm"] = norm_init(hd, "rmsnorm", dtype)
        p["k_norm"] = norm_init(hd, "rmsnorm", dtype)
    return p


def _project_qkv(p: Dict, x: jax.Array, cfg: ModelConfig,
                 positions: jax.Array,
                 mrope_positions=None) -> Tuple[jax.Array, jax.Array, jax.Array]:
    B, S, _ = x.shape
    hd = cfg.head_dim
    q = (x @ p["wq"]).reshape(B, S, cfg.n_heads, hd)
    k = (x @ p["wk"]).reshape(B, S, cfg.n_kv_heads, hd)
    v = (x @ p["wv"]).reshape(B, S, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = apply_norm(p["q_norm"], q, "rmsnorm")
        k = apply_norm(p["k_norm"], k, "rmsnorm")
    q = apply_rope(q, positions, cfg.rope, cfg.rope_theta, mrope_positions)
    k = apply_rope(k, positions, cfg.rope, cfg.rope_theta, mrope_positions)
    return q, k, v


def _sdpa(q, k, v, mask, scale) -> jax.Array:
    """q (B,Sq,H,hd), k/v (B,T,KV,hd), mask (B,Sq,T) bool -> (B,Sq,H,hd)."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", probs, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


def _causal_mask(q_pos: jax.Array, k_pos: jax.Array,
                 window: Optional[int]) -> jax.Array:
    """q_pos (B,Sq), k_pos (B,T) -> (B,Sq,T) bool."""
    m = q_pos[:, :, None] >= k_pos[:, None, :]
    if window is not None:
        m &= (q_pos[:, :, None] - k_pos[:, None, :]) < window
    return m


# ---------------------------------------------------------------- full seq
def attention_full(p: Dict, x: jax.Array, cfg: ModelConfig,
                   positions: jax.Array, *, window: Optional[int] = None,
                   impl: str = "auto", chunk: int = 512,
                   mrope_positions=None) -> jax.Array:
    B, S, _ = x.shape
    q, k, v = _project_qkv(p, x, cfg, positions, mrope_positions)
    scale = 1.0 / float(cfg.head_dim) ** 0.5
    if impl == "auto":
        impl = "naive" if S <= 2048 else "chunked"
    if impl == "kernel":
        from repro.kernels import ops as kops

        out = kops.flash_attention(q, k, v, positions=positions,
                                   window=window, scale=scale)
    elif impl == "naive":
        mask = _causal_mask(positions, positions, window)
        out = _sdpa(q, k, v, mask, scale)
    elif impl == "chunked":
        if S % chunk:
            chunk = S  # degenerate fallback for odd smoke shapes
        n = S // chunk
        # §Perf (context parallelism): head counts (40/28/10/56...) do not
        # divide the 16-way model axis, so GSPMD otherwise shards head_dim
        # and psums the FULL (B,KV,G,C,T) scores tensor per chunk (the
        # 960 GiB/step finding on llama4 prefill). Sharding K/V on the
        # SEQUENCE dim makes per-chunk scores local; only the softmax
        # stats and the (B,C,H,hd) output reduce across the model axis.
        from repro.models.shard_hooks import constrain

        bspec = ("pod", "data")
        k = constrain(k, bspec, "model", None, None)
        v = constrain(v, bspec, "model", None, None)
        qc = jnp.moveaxis(q.reshape(B, n, chunk, cfg.n_heads, cfg.head_dim),
                          1, 0)  # (n, B, C, H, hd)
        qc = constrain(qc, None, bspec, None, None, None)
        pc = jnp.moveaxis(positions.reshape(B, n, chunk), 1, 0)

        def one(args):
            qi, pi = args
            mask = _causal_mask(pi, positions, window)
            return _sdpa(qi, k, v, mask, scale)

        out = jax.lax.map(one, (qc, pc))  # (n, B, C, H, hd)
        out = jnp.moveaxis(out, 0, 1).reshape(B, S, cfg.n_heads, cfg.head_dim)
    else:
        raise ValueError(f"unknown attention impl {impl!r}")
    return out.reshape(B, S, -1) @ p["wo"]


# ---------------------------------------------------------------- decode
def init_kv_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype) -> Dict:
    hd = cfg.head_dim
    return {
        "k": jnp.zeros((batch, cache_len, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((batch, cache_len, cfg.n_kv_heads, hd), dtype),
    }


def kv_cache_spec(cfg: ModelConfig, batch: int, cache_len: int, dtype) -> Dict:
    hd = cfg.head_dim
    shp = (batch, cache_len, cfg.n_kv_heads, hd)
    return {"k": jax.ShapeDtypeStruct(shp, dtype),
            "v": jax.ShapeDtypeStruct(shp, dtype)}


def init_paged_kv_cache(cfg: ModelConfig, n_blocks: int, block_size: int,
                        dtype) -> Dict:
    """Block-pool KV layout (docs/ARCHITECTURE.md §5): one physical pool
    of ``n_blocks`` blocks of ``block_size`` tokens shared by every
    sequence, indirected through per-sequence block tables. Block 0 is
    conventionally the *null block* (sink for inactive batch rows)."""
    hd = cfg.head_dim
    shp = (n_blocks, block_size, cfg.n_kv_heads, hd)
    return {"k": jnp.zeros(shp, dtype), "v": jnp.zeros(shp, dtype)}


def paged_kv_cache_spec(cfg: ModelConfig, n_blocks: int, block_size: int,
                        dtype) -> Dict:
    hd = cfg.head_dim
    shp = (n_blocks, block_size, cfg.n_kv_heads, hd)
    return {"k": jax.ShapeDtypeStruct(shp, dtype),
            "v": jax.ShapeDtypeStruct(shp, dtype)}


def _write_cache(cache: jax.Array, new: jax.Array, slot: jax.Array) -> jax.Array:
    """cache (B,C,KV,hd), new (B,1,KV,hd), slot (B,) -> updated cache."""

    def row(c, n, s):
        return jax.lax.dynamic_update_slice(c, n, (s, 0, 0))

    return jax.vmap(row)(cache, new, slot)


def _write_paged(pool: jax.Array, new: jax.Array, tables: jax.Array,
                 pos: jax.Array) -> jax.Array:
    """pool (N,bs,KV,hd); new (B,1,KV,hd); tables (B,nb); pos (B,).

    Scatter each sequence's new K/V row into physical slot
    ``tables[b, pos//bs] * bs + pos % bs``. Distinct live sequences own
    distinct blocks, so the only colliding writes are inactive rows
    aimed at the null block — last-write-wins there is harmless because
    null-block contents are never read as valid."""
    N, bs = pool.shape[0], pool.shape[1]
    B = new.shape[0]
    flat = pool.reshape((N * bs,) + pool.shape[2:])
    phys = tables[jnp.arange(B), pos // bs] * bs + pos % bs
    flat = flat.at[phys].set(new[:, 0])
    return flat.reshape(pool.shape)


def attention_decode_paged(p: Dict, x: jax.Array, cache: Dict,
                           tables: jax.Array, pos: jax.Array,
                           cfg: ModelConfig, *, impl: str = "auto"
                           ) -> Tuple[jax.Array, Dict]:
    """Paged-counterpart of :func:`attention_decode` for linear
    (non-windowed) layers: the new K/V is scattered through the block
    table and the query attends the gathered logical view. Attended
    positions are exactly ``slots <= pos`` — the same set the dense
    layout attends — so greedy decode is token-identical across
    layouts."""
    B = x.shape[0]
    nb = tables.shape[1]
    bs = cache["k"].shape[1]
    q, k_new, v_new = _project_qkv(p, x, cfg, pos[:, None])
    cache = {"k": _write_paged(cache["k"], k_new, tables, pos),
             "v": _write_paged(cache["v"], v_new, tables, pos)}
    scale = 1.0 / float(cfg.head_dim) ** 0.5
    if impl == "kernel":
        from repro.kernels import ops as kops

        out = kops.paged_decode_attention(q, cache["k"], cache["v"],
                                          tables, pos + 1, scale)
    else:
        k = cache["k"][tables].reshape((B, nb * bs) + cache["k"].shape[2:])
        v = cache["v"][tables].reshape((B, nb * bs) + cache["v"].shape[2:])
        valid = jnp.arange(nb * bs, dtype=jnp.int32)[None, :] <= pos[:, None]
        out = _sdpa(q, k, v, valid[:, None, :], scale)
    return out.reshape(B, 1, -1) @ p["wo"], cache


def _write_paged_chunk(pool: jax.Array, new: jax.Array, tables: jax.Array,
                       pos: jax.Array) -> jax.Array:
    """pool (N,bs,KV,hd); new (B,T,KV,hd); tables (B,nb); pos (B,).

    Multi-row counterpart of :func:`_write_paged`: row ``j`` of each
    sequence's chunk lands in ``tables[b, (pos+j)//bs] * bs +
    (pos+j) % bs``. Table columns past a sequence's allocated blocks are
    the null block, so out-of-range rows (speculative drafts past a
    slot's participation depth, inactive batch rows) collide harmlessly
    there; callers must pad ``tables`` wide enough that ``(pos+T-1)//bs``
    never clips into a LIVE column (JAX clamps out-of-bounds gathers)."""
    N, bs = pool.shape[0], pool.shape[1]
    B, T = new.shape[0], new.shape[1]
    flat = pool.reshape((N * bs,) + pool.shape[2:])
    p = pos[:, None] + jnp.arange(T, dtype=jnp.int32)[None, :]  # (B,T)
    blk = jnp.take_along_axis(tables, p // bs, axis=1)
    phys = (blk * bs + p % bs).reshape(-1)
    flat = flat.at[phys].set(new.reshape((B * T,) + new.shape[2:]))
    return flat.reshape(pool.shape)


def attention_chunk_paged(p: Dict, x: jax.Array, cache: Dict,
                          tables: jax.Array, pos: jax.Array,
                          cfg: ModelConfig, *, impl: str = "auto"
                          ) -> Tuple[jax.Array, Dict]:
    """Speculative-verification chunk over the paged layout
    (docs/ARCHITECTURE.md §5): score ``T`` candidate tokens ``x`` (B,T,d)
    at positions ``pos..pos+T-1`` in one forward. The chunk's K/V is
    scattered through the block table FIRST, then each query attends the
    gathered logical view under the causal mask ``slot <= pos+j`` —
    exactly the positions sequential decode of token ``j`` would attend,
    so the logits at column ``j`` match :func:`attention_decode_paged`
    token for token. Rows for later-rejected candidates stay in the pool
    as garbage but are never attended before being overwritten (decode
    masks ``slots <= pos``; the engine additionally frees whole rejected
    blocks back to its allocator).

    Also the fused chunked-prefill body: the engine's fused prefill path
    calls this per chunk, so prefix-cache hits and chunk continuations
    attend shared blocks directly through the table with no staging
    gather. ``impl="kernel"`` dispatches to the fused Pallas kernel
    (:func:`repro.kernels.ops.paged_prefill_attention`), which streams
    physical blocks instead of gathering the logical view."""
    B, T, _ = x.shape
    nb = tables.shape[1]
    bs = cache["k"].shape[1]
    q_pos = pos[:, None] + jnp.arange(T, dtype=jnp.int32)[None, :]
    q, k_new, v_new = _project_qkv(p, x, cfg, q_pos)
    cache = {"k": _write_paged_chunk(cache["k"], k_new, tables, pos),
             "v": _write_paged_chunk(cache["v"], v_new, tables, pos)}
    scale = 1.0 / float(cfg.head_dim) ** 0.5
    if impl == "kernel":
        from repro.kernels import ops as kops

        out = kops.paged_prefill_attention(q, cache["k"], cache["v"],
                                           tables, pos, scale)
    else:
        k = cache["k"][tables].reshape((B, nb * bs) + cache["k"].shape[2:])
        v = cache["v"][tables].reshape((B, nb * bs) + cache["v"].shape[2:])
        mask = jnp.arange(nb * bs, dtype=jnp.int32)[None, None, :] \
            <= q_pos[:, :, None]
        out = _sdpa(q, k, v, mask, scale)
    return out.reshape(B, T, -1) @ p["wo"], cache


def _write_chunk_linear(cache: jax.Array, new: jax.Array,
                        pos: jax.Array) -> jax.Array:
    """cache (B,C,KV,hd), new (B,T,KV,hd), pos (B,) -> rows pos..pos+T-1
    of each sequence overwritten with the chunk's K/V."""

    def row(c, n, s):
        return jax.lax.dynamic_update_slice(c, n, (s, 0, 0))

    return jax.vmap(row)(cache, new, pos)


def _write_chunk_ring(cache: jax.Array, new: jax.Array,
                      pos: jax.Array) -> jax.Array:
    """Ring-buffer chunk write: slot ``(pos+j) % C`` must end up holding
    the LAST position of the chunk that maps to it (T may exceed the
    window, in which case early chunk positions are overwritten — the
    same final state sequential decode writes would leave)."""
    B, C = cache.shape[0], cache.shape[1]
    T = new.shape[1]
    slots = jnp.arange(C, dtype=jnp.int32)[None, :]           # (1, C)
    j0 = (slots - pos[:, None]) % C                           # (B, C)
    j_last = j0 + ((T - 1 - j0) // C) * C                     # largest < T
    written = j0 < T
    j_safe = jnp.clip(j_last, 0, T - 1)
    picked = jnp.take_along_axis(
        new, j_safe[:, :, None, None], axis=1)                # (B, C, KV, hd)
    return jnp.where(written[:, :, None, None], picked, cache)


def attention_prefill_chunk(p: Dict, x: jax.Array, cache: Dict,
                            pos: jax.Array, cfg: ModelConfig, *,
                            window: Optional[int] = None,
                            impl: str = "auto") -> Tuple[jax.Array, Dict]:
    """Chunked-prefill continuation (docs/ARCHITECTURE.md §5): process
    ``T`` new tokens ``x`` (B,T,d) starting at absolute position ``pos``
    (B,) against a dense decode cache previously filled up to ``pos``.

    Each chunk query attends (a) the cache contents earlier chunks wrote
    and (b) the causal prefix of its own chunk — exactly the positions a
    full-sequence prefill attends, so chunking is math-identical to
    :func:`attention_full` per query row. The chunk's K/V is then written
    into the cache (linear: rows pos..pos+T-1; windowed: ring slots
    modulo the capacity) leaving the same state sequential decode writes
    would leave."""
    B, T, _ = x.shape
    C = cache["k"].shape[1]
    q_pos = pos[:, None] + jnp.arange(T, dtype=jnp.int32)[None, :]
    q, k_new, v_new = _project_qkv(p, x, cfg, q_pos)
    slots = jnp.arange(C, dtype=jnp.int32)[None, :]
    if window is not None:
        # ring: slot s holds the largest p <= pos-1 with p % C == s
        prev = pos[:, None] - 1
        k_pos_old = prev - ((prev - slots) % C)
        old_valid = k_pos_old >= 0
    else:
        k_pos_old = jnp.broadcast_to(slots, (B, C))
        old_valid = slots < pos[:, None]
    old_mask = old_valid[:, None, :] & _causal_mask(q_pos, k_pos_old, window)
    chunk_mask = _causal_mask(q_pos, q_pos, window)
    k_cat = jnp.concatenate([cache["k"], k_new], axis=1)
    v_cat = jnp.concatenate([cache["v"], v_new], axis=1)
    mask = jnp.concatenate([old_mask, chunk_mask], axis=2)
    scale = 1.0 / float(cfg.head_dim) ** 0.5
    out = _sdpa(q, k_cat, v_cat, mask, scale)
    write = _write_chunk_ring if window is not None else _write_chunk_linear
    cache = {"k": write(cache["k"], k_new, pos),
             "v": write(cache["v"], v_new, pos)}
    return out.reshape(B, T, -1) @ p["wo"], cache


def attention_decode(p: Dict, x: jax.Array, cache: Dict, pos: jax.Array,
                     cfg: ModelConfig, *, window: Optional[int] = None,
                     impl: str = "auto") -> Tuple[jax.Array, Dict]:
    """x (B,1,d); pos (B,) absolute position of the new token."""
    B = x.shape[0]
    C = cache["k"].shape[1]
    q, k_new, v_new = _project_qkv(p, x, cfg, pos[:, None])
    slot = pos % C if window is not None else pos
    cache = {"k": _write_cache(cache["k"], k_new, slot),
             "v": _write_cache(cache["v"], v_new, slot)}
    # absolute position held by each cache slot
    slots = jnp.arange(C, dtype=jnp.int32)[None, :]
    if window is not None:
        # ring buffer: slot s holds the largest p <= pos with p % C == s
        k_pos = pos[:, None] - ((pos[:, None] - slots) % C)
        valid = (k_pos >= 0) & (k_pos > pos[:, None] - window)
    else:
        k_pos = slots
        valid = slots <= pos[:, None]
    scale = 1.0 / float(cfg.head_dim) ** 0.5
    if impl == "kernel":
        from repro.kernels import ops as kops

        out = kops.decode_attention(q, cache["k"], cache["v"], valid, scale)
    else:
        mask = valid[:, None, :]  # (B,1,C)
        out = _sdpa(q, cache["k"], cache["v"], mask, scale)
    return out.reshape(B, 1, -1) @ p["wo"], cache
