"""The paper's six served edge models (Table IV) as runnable JAX networks.

These power the runnable edge-serving examples and tests. They are compact
but architecturally faithful implementations (residual basic blocks for
ResNet-18, SE inverted residuals for MobileNetV3/EfficientNet-B0, parallel
inception branches for Inception-v3, a CSP-style backbone + detect head
for YOLOv5s, and a small BERT encoder for TinyBERT). The serving simulator
uses the analytic profiles in configs/paper_edge_models.py; these nets are
the real-execution path.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


# ---------------------------------------------------------------- conv utils
def conv_init(rng, kh, kw, cin, cout, dtype=jnp.float32):
    fan_in = kh * kw * cin
    w = jax.random.normal(rng, (kh, kw, cin, cout), jnp.float32) * \
        jnp.sqrt(2.0 / fan_in)
    return w.astype(dtype)


def conv(x, w, stride=1, groups=1):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups)


def bn_init(c):
    return {"scale": jnp.ones((c,)), "bias": jnp.zeros((c,))}


def bn(p, x, eps=1e-5):
    # inference-style norm over batch+spatial (we serve, not train, these)
    mu = jnp.mean(x, axis=(0, 1, 2), keepdims=True)
    var = jnp.var(x, axis=(0, 1, 2), keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * p["scale"] + p["bias"]


def relu(x):
    return jax.nn.relu(x)


# ---------------------------------------------------------------- ResNet-18
def resnet18_init(rng, n_classes=1000, width=16):
    """width=64 is the true ResNet-18; smaller widths for CPU smoke."""
    ks = iter(jax.random.split(rng, 64))
    w = width
    p: Dict = {"stem": conv_init(next(ks), 7, 7, 3, w),
               "stem_bn": bn_init(w)}
    stages = [(w, 2), (2 * w, 2), (4 * w, 2), (8 * w, 2)]
    cin = w
    p["blocks"] = []
    for cout, n_blocks in stages:
        for b in range(n_blocks):
            stride = 2 if (b == 0 and cout != w) else 1
            blk = {
                "c1": conv_init(next(ks), 3, 3, cin, cout),
                "bn1": bn_init(cout),
                "c2": conv_init(next(ks), 3, 3, cout, cout),
                "bn2": bn_init(cout),
                "stride": stride,
            }
            if stride != 1 or cin != cout:
                blk["proj"] = conv_init(next(ks), 1, 1, cin, cout)
            p["blocks"].append(blk)
            cin = cout
    p["head"] = dense_init(next(ks), cin, n_classes, jnp.float32)
    return p


def resnet18_apply(p, x):
    x = relu(bn(p["stem_bn"], conv(x, p["stem"], stride=2)))
    x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, 3, 3, 1),
                              (1, 2, 2, 1), "SAME")
    for blk in p["blocks"]:
        h = relu(bn(blk["bn1"], conv(x, blk["c1"], stride=blk["stride"])))
        h = bn(blk["bn2"], conv(h, blk["c2"]))
        sc = conv(x, blk["proj"], stride=blk["stride"]) if "proj" in blk \
            else x
        x = relu(h + sc)
    x = jnp.mean(x, axis=(1, 2))
    return x @ p["head"]


# ------------------------------------------------- MobileNetV3 / EffNet-B0
def _se_init(ks, c, r=4):
    return {"w1": conv_init(next(ks), 1, 1, c, max(1, c // r)),
            "w2": conv_init(next(ks), 1, 1, max(1, c // r), c)}


def _se(p, x):
    s = jnp.mean(x, axis=(1, 2), keepdims=True)
    s = relu(conv(s, p["w1"]))
    s = jax.nn.sigmoid(conv(s, p["w2"]))
    return x * s


def _mbconv_init(ks, cin, cout, expand, stride, kernel=3):
    mid = cin * expand
    blk = {"expand": conv_init(next(ks), 1, 1, cin, mid),
           "bn_e": bn_init(mid),
           "dw": conv_init(next(ks), kernel, kernel, 1, mid),
           "bn_d": bn_init(mid),
           "se": _se_init(ks, mid),
           "proj": conv_init(next(ks), 1, 1, mid, cout),
           "bn_p": bn_init(cout),
           "stride": stride}
    return blk


def _mbconv(blk, x):
    h = jax.nn.hard_swish(bn(blk["bn_e"], conv(x, blk["expand"])))
    # depthwise: groups == channels, weight (k,k,1,mid)
    mid = h.shape[-1]
    h = jax.nn.hard_swish(bn(blk["bn_d"], conv(
        h, blk["dw"], stride=blk["stride"], groups=mid)))
    h = _se(blk["se"], h)
    h = bn(blk["bn_p"], conv(h, blk["proj"]))
    if blk["stride"] == 1 and x.shape[-1] == h.shape[-1]:
        h = h + x
    return h


def mobilenetv3_init(rng, n_classes=1000, width=8):
    ks = iter(jax.random.split(rng, 128))
    p: Dict = {"stem": conv_init(next(ks), 3, 3, 3, width),
               "stem_bn": bn_init(width)}
    spec = [(width, 1, 1), (2 * width, 4, 2), (2 * width, 3, 1),
            (4 * width, 4, 2), (6 * width, 4, 1), (10 * width, 6, 2)]
    cin = width
    p["blocks"] = []
    for cout, expand, stride in spec:
        p["blocks"].append(_mbconv_init(ks, cin, cout, expand, stride))
        cin = cout
    p["head1"] = conv_init(next(ks), 1, 1, cin, 4 * cin)
    p["head_bn"] = bn_init(4 * cin)
    p["head2"] = dense_init(next(ks), 4 * cin, n_classes, jnp.float32)
    return p


def mobilenetv3_apply(p, x):
    x = jax.nn.hard_swish(bn(p["stem_bn"], conv(x, p["stem"], stride=2)))
    for blk in p["blocks"]:
        x = _mbconv(blk, x)
    x = jax.nn.hard_swish(bn(p["head_bn"], conv(x, p["head1"])))
    x = jnp.mean(x, axis=(1, 2))
    return x @ p["head2"]


efficientnet_b0_init = mobilenetv3_init   # same MBConv family
efficientnet_b0_apply = mobilenetv3_apply


# ---------------------------------------------------------------- Inception
def _inception_block_init(ks, cin, c1, c3, c5, cp):
    return {
        "b1": conv_init(next(ks), 1, 1, cin, c1),
        "b3a": conv_init(next(ks), 1, 1, cin, c3 // 2),
        "b3b": conv_init(next(ks), 3, 3, c3 // 2, c3),
        "b5a": conv_init(next(ks), 1, 1, cin, c5 // 2),
        "b5b": conv_init(next(ks), 5, 5, c5 // 2, c5),
        "bp": conv_init(next(ks), 1, 1, cin, cp),
    }


def _inception_block(p, x):
    b1 = relu(conv(x, p["b1"]))
    b3 = relu(conv(relu(conv(x, p["b3a"])), p["b3b"]))
    b5 = relu(conv(relu(conv(x, p["b5a"])), p["b5b"]))
    bp = relu(conv(jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 3, 3, 1), (1, 1, 1, 1), "SAME"),
        p["bp"]))
    return jnp.concatenate([b1, b3, b5, bp], axis=-1)


def inception_v3_init(rng, n_classes=1000, width=8):
    ks = iter(jax.random.split(rng, 96))
    p: Dict = {"stem": conv_init(next(ks), 3, 3, 3, 2 * width),
               "stem_bn": bn_init(2 * width)}
    cin = 2 * width
    p["blocks"] = []
    for mult in (1, 2, 3):
        c = width * mult
        p["blocks"].append(_inception_block_init(ks, cin, c, 2 * c, c, c))
        cin = c + 2 * c + c + c
    p["head"] = dense_init(next(ks), cin, n_classes, jnp.float32)
    return p


def inception_v3_apply(p, x):
    x = relu(bn(p["stem_bn"], conv(x, p["stem"], stride=2)))
    for i, blk in enumerate(p["blocks"]):
        x = _inception_block(blk, x)
        x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, 3, 3, 1),
                                  (1, 2, 2, 1), "SAME")
    x = jnp.mean(x, axis=(1, 2))
    return x @ p["head"]


# ---------------------------------------------------------------- YOLOv5s
def _csp_init(ks, cin, cout):
    return {"c1": conv_init(next(ks), 1, 1, cin, cout // 2),
            "c2": conv_init(next(ks), 1, 1, cin, cout // 2),
            "c3": conv_init(next(ks), 3, 3, cout // 2, cout // 2),
            "merge": conv_init(next(ks), 1, 1, cout, cout),
            "bn": bn_init(cout)}


def _csp(p, x):
    a = jax.nn.silu(conv(x, p["c1"]))
    a = a + jax.nn.silu(conv(a, p["c3"]))
    b = jax.nn.silu(conv(x, p["c2"]))
    return jax.nn.silu(bn(p["bn"], conv(jnp.concatenate([a, b], -1),
                                        p["merge"])))


def yolov5s_init(rng, n_classes=80, n_anchors=3, width=8):
    ks = iter(jax.random.split(rng, 64))
    p: Dict = {"stem": conv_init(next(ks), 6, 6, 3, width),
               "stem_bn": bn_init(width)}
    cin = width
    p["stages"] = []
    for mult in (2, 4, 8):
        cout = width * mult
        p["stages"].append({"down": conv_init(next(ks), 3, 3, cin, cout),
                            "bn": bn_init(cout),
                            "csp": _csp_init(ks, cout, cout)})
        cin = cout
    p["detect"] = conv_init(next(ks), 1, 1, cin,
                            n_anchors * (5 + n_classes))
    return p


def yolov5s_apply(p, x):
    """Returns detection map (B, H', W', anchors*(5+classes))."""
    x = jax.nn.silu(bn(p["stem_bn"], conv(x, p["stem"], stride=2)))
    for st in p["stages"]:
        x = jax.nn.silu(bn(st["bn"], conv(x, st["down"], stride=2)))
        x = _csp(st["csp"], x)
    return conv(x, p["detect"])


# ---------------------------------------------------------------- TinyBERT
def tinybert_init(rng, vocab=30522, d=128, n_layers=4, n_heads=2,
                  n_classes=35):
    from repro.config.base import ModelConfig
    from repro.models.transformer import init_params

    cfg = ModelConfig(name="_tinybert", family="dense", n_layers=n_layers,
                      d_model=d, n_heads=n_heads, n_kv_heads=n_heads,
                      d_ff=4 * d, vocab_size=vocab, norm="layernorm",
                      activation="gelu", rope="rope")
    rng1, rng2 = jax.random.split(rng)
    p = init_params(rng1, cfg)
    p["cls"] = dense_init(rng2, d, n_classes, jnp.float32)
    return p, cfg


def tinybert_apply(params_cfg, tokens):
    """Speech-command classification over a token sequence (B, T)."""
    p, cfg = params_cfg
    from repro.models.transformer import _embed_inputs, _trunk_full

    x, positions, _ = _embed_inputs(p, {"tokens": tokens}, cfg)
    ctx = {"positions": positions, "attn_impl": "naive", "chunk": 64,
           "return_cache": False}
    x, _, _ = _trunk_full(p, x, cfg, ctx, remat=False)
    return jnp.mean(x, axis=1) @ p["cls"]


EDGE_NETS = {
    "res": (resnet18_init, resnet18_apply),
    "mob": (mobilenetv3_init, mobilenetv3_apply),
    "eff": (efficientnet_b0_init, efficientnet_b0_apply),
    "inc": (inception_v3_init, inception_v3_apply),
    "yolo": (yolov5s_init, yolov5s_apply),
}
