"""Shared layers: initialisers, norms, MLPs, embeddings.

All layers are pure functions ``apply(params, x, cfg)`` over nested-dict
params; initialisers mirror them with ``init(rng, ...)`` returning the dict.
Compute runs in the dtype of ``x``; norm statistics accumulate in f32.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------- init
def dense_init(rng, d_in: int, d_out: int, dtype, scale: float | None = None):
    scale = scale if scale is not None else (1.0 / jnp.sqrt(d_in))
    return (jax.random.normal(rng, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def embed_init(rng, vocab: int, d: int, dtype):
    return (jax.random.normal(rng, (vocab, d), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------- norms
def norm_init(d: int, kind: str, dtype) -> Dict:
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def apply_norm(p: Dict, x: jax.Array, kind: str, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        rms = jnp.sqrt(jnp.mean(jnp.square(xf), axis=-1, keepdims=True) + eps)
        out = xf / rms * p["scale"].astype(jnp.float32)
    elif kind == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mu) / jnp.sqrt(var + eps)
        out = out * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:
        raise ValueError(f"unknown norm {kind!r}")
    return out.astype(x.dtype)


# ---------------------------------------------------------------- MLP
def mlp_init(rng, d: int, f: int, gated: bool, dtype) -> Dict:
    ks = jax.random.split(rng, 3)
    p = {"w_up": dense_init(ks[0], d, f, dtype),
         "w_down": dense_init(ks[1], f, d, dtype)}
    if gated:
        p["w_gate"] = dense_init(ks[2], d, f, dtype)
    return p


def apply_mlp(p: Dict, x: jax.Array, activation: str) -> jax.Array:
    # "silu" -> swiGLU (gated), "geglu" -> gated gelu, "gelu" -> plain MLP
    act = jax.nn.silu if activation == "silu" else jax.nn.gelu
    up = x @ p["w_up"]
    if "w_gate" in p:
        up = act(x @ p["w_gate"]) * up
    else:
        up = act(up)
    return up @ p["w_down"]


# ---------------------------------------------------------------- embed
def apply_embed(table: jax.Array, tokens: jax.Array) -> jax.Array:
    return jnp.take(table, tokens, axis=0)


def unembed(x: jax.Array, table_or_head: jax.Array, tied: bool,
            softcap: float | None = None) -> jax.Array:
    """x: (..., d) -> logits (..., V). ``table_or_head`` is (V, d) if tied
    (the embedding table) else (d, V)."""
    if tied:
        logits = x @ table_or_head.T
    else:
        logits = x @ table_or_head
    if softcap:
        logits = softcap * jnp.tanh(logits.astype(jnp.float32) / softcap)
        logits = logits.astype(x.dtype)
    return logits
