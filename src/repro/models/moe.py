"""Mixture-of-Experts FFN with capacity-bucketed scatter/gather dispatch.

TPU-native formulation: tokens are scattered into a dense (E, Cap, d)
buffer (so the per-expert matmul is a single MXU-friendly einsum with the
expert dim shardable over the ``model`` mesh axis = expert parallelism),
then gathered back with their gate weights. Dropped tokens (over capacity)
fall back to the residual path, as in GShard/Switch.

Supports top-1 (llama4-maverick style) and top-2 + dense residual branch
(arctic style).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig
from repro.models.layers import dense_init, mlp_init, apply_mlp


def moe_init(rng, cfg: ModelConfig, dtype) -> Dict:
    ks = jax.random.split(rng, 5)
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    scale = 1.0 / jnp.sqrt(d)
    p = {
        "router": dense_init(ks[0], d, E, dtype, scale=0.02),
        "w_gate": (jax.random.normal(ks[1], (E, d, f), jnp.float32) * scale
                   ).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (E, d, f), jnp.float32) * scale
                 ).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (E, f, d), jnp.float32)
                   * (1.0 / jnp.sqrt(f))).astype(dtype),
    }
    if cfg.moe_dense_residual:
        p["dense_mlp"] = mlp_init(ks[4], d, cfg.dense_ff or cfg.d_ff,
                                  gated=True, dtype=dtype)
    return p


def _capacity(n_tokens: int, cfg: ModelConfig) -> int:
    cap = int(cfg.capacity_factor * n_tokens * cfg.top_k / cfg.n_experts)
    return max(8, -(-cap // 8) * 8)  # >=8, rounded up to a multiple of 8


def moe_apply(p: Dict, x: jax.Array, cfg: ModelConfig
              ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x (B,S,d) -> (y (B,S,d), aux metrics incl. load-balance loss)."""
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    N = B * S
    xf = x.reshape(N, d)
    logits = (xf @ p["router"]).astype(jnp.float32)  # (N,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)  # (N,k)
    if k > 1:
        gate_vals = gate_vals / (gate_vals.sum(-1, keepdims=True) + 1e-9)

    cap = _capacity(N, cfg)
    flat_e = gate_idx.reshape(N * k)  # expert id per (token, choice)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.float32)  # (N*k, E)
    pos = jnp.take_along_axis(
        jnp.cumsum(onehot, axis=0) - 1.0, flat_e[:, None], axis=1
    )[:, 0].astype(jnp.int32)  # position within expert
    keep = pos < cap
    pos = jnp.where(keep, pos, 0)

    # scatter tokens into (E, Cap, d)
    xk = jnp.repeat(xf, k, axis=0) if k > 1 else xf  # (N*k, d)
    contrib = jnp.where(keep[:, None], xk, 0.0)
    buf = jnp.zeros((E, cap, d), x.dtype).at[flat_e, pos].add(
        contrib.astype(x.dtype))

    # expert swiGLU — expert-parallel over the `model` axis. At decode
    # scale (small capacity) we additionally pin the expert-FFN hidden dim
    # to the `data` axis: GSPMD then contracts partially + psums tiny
    # (E,cap,d) tensors instead of all-gathering the expert weights
    # (§Perf iteration 2 — the weight-gather temp buffers were 12.8 GiB/dev
    # on arctic decode).
    from repro.models.shard_hooks import constrain

    buf = constrain(buf, "model", None, None)
    two_d = cap <= 64
    h = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    if two_d:
        h = constrain(h, "model", None, "data")
        u = constrain(u, "model", None, "data")
    h = jax.nn.silu(h) * u
    y_e = jnp.einsum("ecf,efd->ecd", h, p["w_down"])  # (E,Cap,d)
    y_e = constrain(y_e, "model", None, None)

    # gather back and weight by gates
    y_tok = y_e[flat_e, pos]  # (N*k, d)
    y_tok = y_tok * (gate_vals.reshape(N * k, 1) * keep[:, None]).astype(x.dtype)
    y = y_tok.reshape(N, k, d).sum(axis=1) if k > 1 else y_tok

    # aux: switch-style load-balance loss + router z-loss
    frac_tokens = jnp.mean(
        jax.nn.one_hot(gate_idx[:, 0], E, dtype=jnp.float32), axis=0)
    mean_probs = jnp.mean(probs, axis=0)
    lb_loss = E * jnp.sum(frac_tokens * mean_probs)
    z_loss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    dropped = 1.0 - jnp.mean(keep.astype(jnp.float32))

    if cfg.moe_dense_residual:
        y = y + apply_mlp(p["dense_mlp"], xf, cfg.activation)

    aux = {"lb_loss": lb_loss, "z_loss": z_loss, "drop_frac": dropped}
    return y.reshape(B, S, d), aux
