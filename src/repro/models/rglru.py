"""RecurrentGemma / Griffin recurrent block: conv1d + RG-LRU.

RG-LRU (real-gated linear recurrent unit)::

    r_t = sigmoid(W_a x_t + b_a)          # recurrence gate
    i_t = sigmoid(W_i x_t + b_i)          # input gate
    log a_t = -c * softplus(Λ) * r_t      # data-gated diagonal decay
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ u_t)

wrapped in the Griffin gated block: a GeLU branch multiplies the recurrent
branch, preceded by a short causal conv1d (width 4). The jnp reference scans
over time; ``repro.kernels.rglru_scan`` is the chunked TPU kernel.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig
from repro.models.layers import dense_init, norm_init

CONV_W = 4
DECAY_C = 8.0


def rglru_init(rng, cfg: ModelConfig, dtype) -> Dict:
    d = cfg.d_model
    w = cfg.rglru_width or d
    ks = iter(jax.random.split(rng, 8))
    # Λ initialised so decay a ∈ (0.9, 0.999) at r=1 (long memory)
    lam = jnp.log(jnp.expm1(-jnp.log(
        jnp.linspace(0.9, 0.999, w, dtype=jnp.float32)) / DECAY_C))
    return {
        "norm": norm_init(d, cfg.norm, dtype),
        "W_x": dense_init(next(ks), d, w, dtype),
        "W_gate": dense_init(next(ks), d, w, dtype),
        "conv_w": (jax.random.normal(next(ks), (CONV_W, w), jnp.float32)
                   * (1.0 / CONV_W)).astype(dtype),
        "conv_b": jnp.zeros((w,), dtype),
        "W_a": dense_init(next(ks), w, w, dtype, scale=0.01),
        "b_a": jnp.zeros((w,), dtype),
        "W_i": dense_init(next(ks), w, w, dtype, scale=0.01),
        "b_i": jnp.zeros((w,), dtype),
        "lam": lam.astype(dtype),
        "W_o": dense_init(next(ks), w, d, dtype),
    }


def _conv1d_causal(u: jax.Array, conv_w: jax.Array, conv_b: jax.Array,
                   hist: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv. u (B,S,w); hist (B,CONV_W-1,w) from the
    previous segment. Returns (out (B,S,w), new_hist)."""
    full = jnp.concatenate([hist, u], axis=1)  # (B, S+3, w)
    out = jnp.zeros_like(u)
    S = u.shape[1]
    for i in range(CONV_W):
        out = out + full[:, i: i + S, :] * conv_w[CONV_W - 1 - i][None, None, :]
    new_hist = full[:, -(CONV_W - 1):, :]
    return out + conv_b, new_hist


def _gates(p: Dict, u: jax.Array):
    """Returns (a, gated_in) in u's dtype (bf16-safe; the scan carry stays
    f32). Gate math runs in f32 internally."""
    r = jax.nn.sigmoid((u @ p["W_a"] + p["b_a"]).astype(jnp.float32))
    i = jax.nn.sigmoid((u @ p["W_i"] + p["b_i"]).astype(jnp.float32))
    log_a = -DECAY_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    gated_in = jnp.sqrt(jnp.maximum(1.0 - jnp.square(a), 1e-12)) * (
        i * u.astype(jnp.float32))
    return a.astype(u.dtype), gated_in.astype(u.dtype)


def rglru_seq(p: Dict, x: jax.Array, cfg: ModelConfig, state: Dict
              ) -> Tuple[jax.Array, Dict]:
    """Full-sequence Griffin recurrent block. x is the *normed* input."""
    from repro.models.shard_hooks import constrain

    bspec = ("pod", "data")
    u = x @ p["W_x"]
    u, new_conv = _conv1d_causal(u, p["conv_w"], p["conv_b"], state["conv"])
    # §Perf: replicate u's width once (ONE bf16 all-gather) so the W_a/W_i
    # gate projections are local column-parallel matmuls — GSPMD otherwise
    # emits a partial-sum all-reduce of (B,S,w) per projection (2-4x the
    # bytes, and f32 on this backend).
    u = constrain(u, bspec, None, None)
    a, gated_in = _gates(p, u)
    # pin the time-scan operands to ONE layout (batch on data, width on
    # model, time replicated): without this GSPMD reshards the carried
    # state every timestep ("involuntary full rematerialization" — §Perf)
    a = constrain(a, bspec, None, "model")
    gated_in = constrain(gated_in, bspec, None, "model")

    def step(h, t):
        h = a[:, t].astype(jnp.float32) * h + \
            gated_in[:, t].astype(jnp.float32)
        return h, h.astype(a.dtype)

    h0 = constrain(state["h"].astype(jnp.float32), bspec, "model")
    new_h, hs = jax.lax.scan(step, h0, jnp.arange(x.shape[1]))
    hs = jnp.moveaxis(hs, 0, 1).astype(x.dtype)  # (B,S,w)
    gate = jax.nn.gelu(x @ p["W_gate"])
    out = (gate * hs) @ p["W_o"]
    return out, {"h": new_h.astype(state["h"].dtype), "conv": new_conv}


def rglru_decode(p: Dict, x: jax.Array, cfg: ModelConfig, state: Dict
                 ) -> Tuple[jax.Array, Dict]:
    """x (B,1,d) normed input; single recurrent step."""
    u = x @ p["W_x"]  # (B,1,w)
    full = jnp.concatenate([state["conv"], u], axis=1)  # (B,CONV_W,w)
    u1 = jnp.einsum("bcw,cw->bw", full, p["conv_w"][::-1]) + p["conv_b"]
    a, gated_in = _gates(p, u1)
    h = a * state["h"].astype(jnp.float32) + gated_in
    gate = jax.nn.gelu(x[:, 0, :] @ p["W_gate"])
    out = (gate * h.astype(x.dtype)) @ p["W_o"]
    return out[:, None, :], {"h": h.astype(state["h"].dtype),
                             "conv": full[:, 1:, :]}


def rglru_state_init(cfg: ModelConfig, batch: int, dtype) -> Dict:
    w = cfg.rglru_width or cfg.d_model
    return {"h": jnp.zeros((batch, w), jnp.float32),
            "conv": jnp.zeros((batch, CONV_W - 1, w), dtype)}


def rglru_state_spec(cfg: ModelConfig, batch: int, dtype) -> Dict:
    w = cfg.rglru_width or cfg.d_model
    return {"h": jax.ShapeDtypeStruct((batch, w), jnp.float32),
            "conv": jax.ShapeDtypeStruct((batch, CONV_W - 1, w), dtype)}
