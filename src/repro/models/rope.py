"""Rotary position embedding variants.

``rope``   — standard half-rotation RoPE (llama / starcoder2 / yi / qwen3).
``rope2d`` — chatglm-style: RoPE applied to the first half of the head dim,
             second half passes through (GLM's "2d" partial rotary).
``mrope``  — qwen2-vl multimodal RoPE: head-dim split into 3 sections that
             rotate with (temporal, height, width) position ids. Text tokens
             use t=h=w=linear position, so mrope == rope for pure text.
``none``   — no positional rotation (rwkv, rg-lru branches).

Positions are passed explicitly so decode (position = cache length) and
ring-buffer windowed caches work with the same code path.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def _angles(positions: jax.Array, dim: int, theta: float) -> jax.Array:
    """positions (...,) -> angles (..., dim/2) in f32."""
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    return positions.astype(jnp.float32)[..., None] * inv


def _rotate(x: jax.Array, ang: jax.Array) -> jax.Array:
    """x (..., dim) with angles (..., dim/2); pairs are (even, odd) halves."""
    d2 = x.shape[-1] // 2
    x1, x2 = x[..., :d2], x[..., d2:]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], -1)
    return out.astype(x.dtype)


def apply_rope(x: jax.Array, positions: jax.Array, variant: str,
               theta: float = 10_000.0,
               mrope_positions: Optional[Tuple[jax.Array, ...]] = None
               ) -> jax.Array:
    """x: (B, S, H, hd); positions: (B, S) absolute token positions."""
    if variant == "none":
        return x
    hd = x.shape[-1]
    if variant == "rope":
        ang = _angles(positions, hd, theta)[:, :, None, :]  # (B,S,1,hd/2)
        return _rotate(x, ang)
    if variant == "rope2d":
        half = hd // 2
        ang = _angles(positions, half, theta)[:, :, None, :]
        rot = _rotate(x[..., :half], ang)
        return jnp.concatenate([rot, x[..., half:]], -1)
    if variant == "mrope":
        # three sections of the rotary dims keyed by (t, h, w) position ids
        if mrope_positions is None:
            mrope_positions = (positions, positions, positions)
        sec = hd // 2 // 4  # section unit; t gets 2 units, h and w one each
        splits = (2 * sec, sec, (hd // 2) - 3 * sec)
        angs = []
        for pos, width in zip(mrope_positions, splits):
            inv = 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))
            angs.append(pos.astype(jnp.float32)[..., None] * inv)
        # interleave: first 2*sec freqs from t, next sec from h, rest from w
        a_t, a_h, a_w = angs
        ang = jnp.concatenate(
            [a_t[..., : splits[0]],
             a_h[..., splits[0]: splits[0] + splits[1]],
             a_w[..., splits[0] + splits[1]:]], -1)[:, :, None, :]
        return _rotate(x, ang)
    raise ValueError(f"unknown rope variant {variant!r}")


def default_positions(batch: int, seq: int, offset=0) -> jax.Array:
    return jnp.arange(seq, dtype=jnp.int32)[None, :] + jnp.zeros(
        (batch, 1), jnp.int32) + offset


def vision_grid_positions(batch: int, n_tokens: int, grid_hw: int
                          ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Synthetic (t, h, w) ids for stubbed vision patches laid on a grid."""
    idx = jnp.arange(n_tokens, dtype=jnp.int32)
    t = jnp.zeros_like(idx)
    h = idx // grid_hw
    w = idx % grid_hw
    tile = lambda v: jnp.broadcast_to(v[None, :], (batch, n_tokens))  # noqa: E731
    return tile(t), tile(h), tile(w)
