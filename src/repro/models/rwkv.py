"""RWKV-6 ("Finch") block: data-dependent-decay linear attention.

Per head with key/value dim ``hd``::

    out_t  = r_t^T (state_t + diag(u) k_t v_t^T)
    state_{t+1} = diag(w_t) state_t + k_t v_t^T

where the decay ``w_t`` and the token-shift interpolation weights are
data-dependent through low-rank adapters (the RWKV-6 novelty vs RWKV-5).
The sequence form here is a jnp ``lax.scan`` reference; the TPU hot path is
``repro.kernels.rwkv6_scan`` (chunked, state carried in VMEM scratch).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig
from repro.models.layers import dense_init, norm_init, apply_norm

LORA_DIM = 32
MIX_NAMES = ("r", "k", "v", "w", "g")


def rwkv_init(rng, cfg: ModelConfig, dtype) -> Dict:
    d = cfg.d_model
    hd = cfg.rwkv_head_size
    H = d // hd
    ks = iter(jax.random.split(rng, 24))
    tm: Dict = {"norm": norm_init(d, cfg.norm, dtype)}
    tm["mu_x"] = jnp.zeros((d,), dtype)
    for nm in MIX_NAMES:
        tm[f"mu_{nm}"] = jnp.zeros((d,), dtype)
        tm[f"A_{nm}"] = dense_init(next(ks), d, LORA_DIM, dtype, scale=0.01)
        tm[f"B_{nm}"] = dense_init(next(ks), LORA_DIM, d, dtype, scale=0.01)
    for nm in ("r", "k", "v", "g", "o"):
        tm[f"W_{nm}"] = dense_init(next(ks), d, d, dtype)
    # decay base: initialised so w ~ exp(-exp(.)) spans (0, 1) across channels
    decay_span = jnp.linspace(-6.0, 1.0, d, dtype=jnp.float32)
    tm["w_base"] = decay_span.astype(dtype)
    tm["u"] = (jax.random.normal(next(ks), (H, hd), jnp.float32) * 0.1
               ).astype(dtype)
    tm["ln_x"] = norm_init(hd, "rmsnorm", dtype)  # per-head output norm

    cm: Dict = {"norm": norm_init(d, cfg.norm, dtype)}
    cm["mu_k"] = jnp.zeros((d,), dtype)
    cm["mu_r"] = jnp.zeros((d,), dtype)
    cm["W_k"] = dense_init(next(ks), d, cfg.d_ff, dtype)
    cm["W_v"] = dense_init(next(ks), cfg.d_ff, d, dtype)
    cm["W_r"] = dense_init(next(ks), d, d, dtype)
    return {"time_mix": tm, "channel_mix": cm}


def _token_shift(x: jax.Array, last: jax.Array) -> jax.Array:
    """x (B,S,d), last (B,d) = final token of the previous segment."""
    return jnp.concatenate([last[:, None, :], x[:, :-1, :]], axis=1)


def _ddlerp(tm: Dict, x, xx, nm: str) -> jax.Array:
    """RWKV-6 data-dependent lerp between x and shifted x."""
    base = x + xx * tm["mu_x"]
    lora = jnp.tanh(base @ tm[f"A_{nm}"]) @ tm[f"B_{nm}"]
    return x + xx * (tm[f"mu_{nm}"] + lora)


def _rkvwg(tm: Dict, x: jax.Array, shifted: jax.Array, H: int, hd: int):
    xx = shifted - x
    r = (_ddlerp(tm, x, xx, "r") @ tm["W_r"])
    k = (_ddlerp(tm, x, xx, "k") @ tm["W_k"])
    v = (_ddlerp(tm, x, xx, "v") @ tm["W_v"])
    g = jax.nn.silu(_ddlerp(tm, x, xx, "g") @ tm["W_g"])
    w_in = _ddlerp(tm, x, xx, "w")
    log_w = tm["w_base"].astype(jnp.float32) + (
        jnp.tanh(w_in @ tm["A_w"]) @ tm["B_w"]).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(log_w))  # (…, d) in (0,1)
    shp = x.shape[:-1] + (H, hd)
    return (r.reshape(shp), k.reshape(shp), v.reshape(shp),
            w.reshape(shp), g)


def _wkv_step(state, rkvw):
    """state (B,H,hd,hd); r/k/v/w (B,H,hd) for one timestep."""
    r, k, v, w, u = rkvw
    kv = k[..., :, None] * v[..., None, :]  # (B,H,hd,hd)
    out = jnp.einsum("bhi,bhij->bhj", r, state + u[None, :, :, None] * kv)
    new_state = w[..., :, None] * state + kv
    return new_state, out


def time_mix_seq(tm: Dict, x: jax.Array, cfg: ModelConfig,
                 state: jax.Array, shift: jax.Array
                 ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Full-sequence time-mix. x (B,S,d); state (B,H,hd,hd); shift (B,d).

    Returns (out (B,S,d), new_state, new_shift).
    """
    B, S, d = x.shape
    hd = cfg.rwkv_head_size
    H = d // hd
    shifted = _token_shift(x, shift)
    r, k, v, w, g = _rkvwg(tm, x, shifted, H, hd)
    u = tm["u"].astype(jnp.float32)
    # NOTE (§Perf iteration 6): pinning the scan operands (state sharded on
    # the value dim) was tried and MEASURED WORSE (19.4s vs 14.4s of
    # collectives on train_4k) — GSPMD's own layout for the WKV scan beats
    # the hand-chosen one; constraints reverted.

    def step(st, t):
        return _wkv_step(st, (r[:, t].astype(jnp.float32),
                              k[:, t].astype(jnp.float32),
                              v[:, t].astype(jnp.float32),
                              w[:, t].astype(jnp.float32), u))

    new_state, outs = jax.lax.scan(step, state.astype(jnp.float32),
                                   jnp.arange(S))
    out = jnp.moveaxis(outs, 0, 1)  # (B,S,H,hd)
    out = apply_norm(tm["ln_x"], out.astype(x.dtype), "rmsnorm")
    out = (out.reshape(B, S, d) * g) @ tm["W_o"]
    return out, new_state.astype(state.dtype), x[:, -1, :]


def time_mix_decode(tm: Dict, x: jax.Array, cfg: ModelConfig,
                    state: jax.Array, shift: jax.Array):
    """Single-token decode. x (B,1,d)."""
    B, _, d = x.shape
    hd = cfg.rwkv_head_size
    H = d // hd
    xt = x[:, 0, :]
    r, k, v, w, g = _rkvwg(tm, xt, shift, H, hd)
    u = tm["u"].astype(jnp.float32)
    new_state, out = _wkv_step(state.astype(jnp.float32),
                               (r.astype(jnp.float32), k.astype(jnp.float32),
                                v.astype(jnp.float32), w.astype(jnp.float32),
                                u))
    out = apply_norm(tm["ln_x"], out[:, :, None, :].swapaxes(1, 2
                     ).astype(x.dtype), "rmsnorm")  # (B,1,H,hd)
    out = (out.reshape(B, 1, d) * g[:, None, :]) @ tm["W_o"]
    return out, new_state.astype(state.dtype), xt


def channel_mix(cm: Dict, x: jax.Array, shift: jax.Array
                ) -> Tuple[jax.Array, jax.Array]:
    """x (B,S,d) (S may be 1); returns (out, new_shift)."""
    shifted = _token_shift(x, shift)
    xx = shifted - x
    xk = x + xx * cm["mu_k"]
    xr = x + xx * cm["mu_r"]
    k = jnp.square(jax.nn.relu(xk @ cm["W_k"]))
    out = jax.nn.sigmoid(xr @ cm["W_r"]) * (k @ cm["W_v"])
    return out, x[:, -1, :]


def rwkv_state_init(cfg: ModelConfig, batch: int, dtype) -> Dict:
    d = cfg.d_model
    hd = cfg.rwkv_head_size
    H = d // hd
    return {
        "att_state": jnp.zeros((batch, H, hd, hd), jnp.float32),
        "att_shift": jnp.zeros((batch, d), dtype),
        "ffn_shift": jnp.zeros((batch, d), dtype),
    }


def rwkv_state_spec(cfg: ModelConfig, batch: int, dtype) -> Dict:
    d = cfg.d_model
    hd = cfg.rwkv_head_size
    H = d // hd
    return {
        "att_state": jax.ShapeDtypeStruct((batch, H, hd, hd), jnp.float32),
        "att_shift": jax.ShapeDtypeStruct((batch, d), dtype),
        "ffn_shift": jax.ShapeDtypeStruct((batch, d), dtype),
    }


def rwkv_block(p: Dict, x: jax.Array, cfg: ModelConfig, state: Dict,
               decode: bool, norm_kind: str) -> Tuple[jax.Array, Dict]:
    """Residual RWKV block (time-mix + channel-mix)."""
    h = apply_norm(p["time_mix"]["norm"], x, norm_kind)
    fn = time_mix_decode if decode else time_mix_seq
    att, new_att_state, new_att_shift = fn(
        p["time_mix"], h, cfg, state["att_state"], state["att_shift"])
    x = x + att
    h = apply_norm(p["channel_mix"]["norm"], x, norm_kind)
    ffn, new_ffn_shift = channel_mix(p["channel_mix"], h, state["ffn_shift"])
    x = x + ffn
    return x, {"att_state": new_att_state, "att_shift": new_att_shift,
               "ffn_shift": new_ffn_shift}
