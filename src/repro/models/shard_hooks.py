"""Sharding-constraint hooks usable from model code.

``constrain(x, *spec)`` applies ``with_sharding_constraint`` when tracing
under a mesh context and silently no-ops otherwise (CPU smoke tests, no
mesh). Axis names absent from the active mesh are dropped from the spec.
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


def _active_mesh():
    try:
        from jax._src.mesh import thread_resources

        m = thread_resources.env.physical_mesh
        if m is None or m.empty:
            return None
        return m
    except Exception:  # noqa: BLE001
        return None


def constrain(x, *spec):
    import os

    if os.environ.get("REPRO_NO_CONSTRAIN"):  # §Perf ablation switch
        return x
    m = _active_mesh()
    if m is None:
        return x
    names = set(m.axis_names)

    def clean(entry):
        if entry is None:
            return None
        if isinstance(entry, (tuple, list)):
            kept = tuple(a for a in entry if a in names)
            return kept if kept else None
        return entry if entry in names else None

    cleaned = tuple(clean(e) for e in spec)
    # drop axes that do not divide the dim
    final = []
    for dim, entry in zip(x.shape, cleaned):
        if entry is None:
            final.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        size = 1
        for a in axes:
            size *= m.shape[a]
        final.append(entry if dim % size == 0 else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(m, P(*final)))
