"""Composable decoder trunk covering all assigned architecture families.

Layer stack = ``n_units`` repetitions of ``cfg.block_pattern`` executed under
``lax.scan`` (stacked params — keeps HLO size and compile time independent of
depth, MaxText-style) plus an unrolled tail when ``n_layers`` is not a
multiple of the pattern length. Encoder-decoder (seamless) adds a scanned
bidirectional encoder and per-layer cross-attention.

Forward modes:
  * ``loss(params, batch)``        — teacher-forced LM loss (train_4k)
  * ``prefill(params, batch)``     — logits + populated cache (prefill_32k)
  * ``decode_step(params, cache, batch)`` — one token (decode_32k/long_500k)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import InputShape, ModelConfig
from repro.models import attention as attn
from repro.models import rglru as rg
from repro.models import rwkv as rk
from repro.models.layers import (apply_embed, apply_mlp, apply_norm,
                                 dense_init, embed_init, mlp_init, norm_init,
                                 unembed)
from repro.models.moe import moe_apply, moe_init
from repro.models.rope import default_positions, vision_grid_positions

LOSS_CHUNK = 256


# =====================================================================
# parameter construction
# =====================================================================
def _layer_init(rng, cfg: ModelConfig, kind: str, dtype,
                cross: bool) -> Dict:
    ks = iter(jax.random.split(rng, 8))
    if kind == "rwkv":
        return rk.rwkv_init(next(ks), cfg, dtype)
    p: Dict[str, Any] = {}
    if kind == "rglru":
        p["rec"] = rg.rglru_init(next(ks), cfg, dtype)
    else:  # attn | local_attn
        p["attn_norm"] = norm_init(cfg.d_model, cfg.norm, dtype)
        p["attn"] = attn.attn_init(next(ks), cfg, dtype)
        if cross:
            p["cross_norm"] = norm_init(cfg.d_model, cfg.norm, dtype)
            p["cross"] = attn.attn_init(next(ks), cfg, dtype)
    p["ffn_norm"] = norm_init(cfg.d_model, cfg.norm, dtype)
    if cfg.n_experts and kind != "attn_dense":
        p["ffn"] = moe_init(next(ks), cfg, dtype)
    else:
        width = (cfg.dense_ff or cfg.d_ff) if kind == "attn_dense" else cfg.d_ff
        p["ffn"] = mlp_init(next(ks), cfg.d_model, width,
                            gated=(cfg.activation in ("silu", "geglu")),
                            dtype=dtype)
    return p


def _stack(trees: List[Any]) -> Any:
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def init_params(rng, cfg: ModelConfig, dtype=jnp.float32) -> Dict:
    ks = iter(jax.random.split(rng, 64))
    kinds = cfg.layer_kinds()
    k = len(cfg.block_pattern)
    n_units = cfg.n_layers // k
    tail_kinds = kinds[n_units * k:]
    p: Dict[str, Any] = {
        "embed": embed_init(next(ks), cfg.vocab_size, cfg.d_model, dtype),
        "final_norm": norm_init(cfg.d_model, cfg.norm, dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(next(ks), cfg.d_model, cfg.vocab_size,
                                  dtype, scale=0.02)
    cross = cfg.enc_dec
    if n_units:
        units = []
        for pos, kind in enumerate(cfg.block_pattern):
            per_unit = [_layer_init(next(ks), cfg, kind, dtype, cross)
                        for _ in range(n_units)]
            units.append(_stack(per_unit))
        p["units"] = tuple(units)
    if tail_kinds:
        p["tail"] = tuple(_layer_init(next(ks), cfg, kind, dtype, cross)
                          for kind in tail_kinds)
    if cfg.frontend is not None:
        p["frontend_proj"] = dense_init(next(ks), cfg.d_model, cfg.d_model,
                                        dtype)
    if cfg.enc_dec:
        enc_layers = [_layer_init(next(ks), cfg, "attn", dtype, cross=False)
                      for _ in range(cfg.n_enc_layers)]
        p["encoder"] = {"layers": _stack(enc_layers),
                        "final_norm": norm_init(cfg.d_model, cfg.norm, dtype)}
    return p


def abstract_params(cfg: ModelConfig, dtype=jnp.float32):
    rng = jax.random.PRNGKey(0)
    return jax.eval_shape(lambda r: init_params(r, cfg, dtype), rng)


# =====================================================================
# single-layer application
# =====================================================================
def _ffn_apply(p, x, cfg: ModelConfig,
               kind: str = "attn") -> Tuple[jax.Array, jax.Array]:
    if cfg.n_experts and kind != "attn_dense":
        y, aux = moe_apply(p, x, cfg)
        return y, aux["lb_loss"] + 1e-3 * aux["z_loss"]
    return apply_mlp(p, x, cfg.activation), jnp.zeros((), jnp.float32)


def _layer_full(p: Dict, x: jax.Array, cfg: ModelConfig, kind: str,
                ctx: Dict) -> Tuple[jax.Array, jax.Array, Dict]:
    """Full-sequence layer. Returns (x, aux_loss, cache_out)."""
    cache_out: Dict = {}
    aux = jnp.zeros((), jnp.float32)
    window = _window_for(cfg, kind)
    if kind == "rwkv":
        state = rk.rwkv_state_init(cfg, x.shape[0], x.dtype)
        x, new_state = rk.rwkv_block(p, x, cfg, state, decode=False,
                                     norm_kind=cfg.norm)
        return x, aux, new_state
    if kind == "rglru":
        state = rg.rglru_state_init(cfg, x.shape[0], x.dtype)
        h = apply_norm(p["rec"]["norm"], x, cfg.norm)
        out, new_state = rg.rglru_seq(p["rec"], h, cfg, state)
        x = x + out
        cache_out = new_state
    else:
        h = apply_norm(p["attn_norm"], x, cfg.norm)
        out = attn.attention_full(
            p["attn"], h, cfg, ctx["positions"], window=window,
            impl=ctx["attn_impl"], chunk=ctx["chunk"],
            mrope_positions=ctx.get("mrope_positions"))
        x = x + out
        if ctx.get("return_cache"):
            cache_out = _prefill_kv(p["attn"], h, cfg, ctx, window)
        if cfg.enc_dec and "cross" in p:
            h = apply_norm(p["cross_norm"], x, cfg.norm)
            out, ck, cv = _cross_full(p["cross"], h, ctx["enc_out"], cfg)
            x = x + out
            if ctx.get("return_cache"):
                cache_out = {**cache_out, "ck": ck, "cv": cv}
    h = apply_norm(p["ffn_norm"], x, cfg.norm)
    y, ffn_aux = _ffn_apply(p["ffn"], h, cfg, kind)
    return x + y, aux + ffn_aux, cache_out


def _layer_decode(p: Dict, x: jax.Array, cfg: ModelConfig, kind: str,
                  cache: Dict, ctx: Dict) -> Tuple[jax.Array, Dict]:
    window = _window_for(cfg, kind)
    if kind == "rwkv":
        return rk.rwkv_block(p, x, cfg, cache, decode=True,
                             norm_kind=cfg.norm)
    if kind == "rglru":
        h = apply_norm(p["rec"]["norm"], x, cfg.norm)
        out, new_state = rg.rglru_decode(p["rec"], h, cfg, cache)
        x = x + out
        new_cache = new_state
    else:
        h = apply_norm(p["attn_norm"], x, cfg.norm)
        tables = ctx.get("block_tables")
        if tables is not None and window is None:
            # paged layout covers linear KV layers only; ring buffers
            # (windowed) are already bounded by the window and stay dense
            out, kv = attn.attention_decode_paged(
                p["attn"], h, {"k": cache["k"], "v": cache["v"]}, tables,
                ctx["pos"], cfg, impl=ctx["attn_impl"])
        else:
            out, kv = attn.attention_decode(
                p["attn"], h, {"k": cache["k"], "v": cache["v"]},
                ctx["pos"], cfg, window=window, impl=ctx["attn_impl"])
        x = x + out
        new_cache = dict(kv)
        if cfg.enc_dec and "cross" in p:
            h = apply_norm(p["cross_norm"], x, cfg.norm)
            out = _cross_cached(p["cross"], h, cache["ck"], cache["cv"], cfg)
            x = x + out
            new_cache["ck"], new_cache["cv"] = cache["ck"], cache["cv"]
    h = apply_norm(p["ffn_norm"], x, cfg.norm)
    y, _ = _ffn_apply(p["ffn"], h, cfg, kind)
    return x + y, new_cache


def _layer_chunk(p: Dict, x: jax.Array, cfg: ModelConfig, kind: str,
                 cache: Dict, ctx: Dict) -> Tuple[jax.Array, Dict]:
    """Chunked-prefill continuation layer (docs/ARCHITECTURE.md §5):
    process T tokens starting at ``ctx["pos"]`` against a dense decode
    cache. Recurrent layers run their sequence form from the carried
    state; attention layers attend cache + causal chunk prefix."""
    window = _window_for(cfg, kind)
    if kind == "rwkv":
        return rk.rwkv_block(p, x, cfg, cache, decode=False,
                             norm_kind=cfg.norm)
    if kind == "rglru":
        h = apply_norm(p["rec"]["norm"], x, cfg.norm)
        out, new_state = rg.rglru_seq(p["rec"], h, cfg, cache)
        x = x + out
        new_cache = new_state
    else:
        h = apply_norm(p["attn_norm"], x, cfg.norm)
        tables = ctx.get("block_tables")
        if tables is not None and window is None:
            # paged layout covers linear KV layers only (same gate as
            # _layer_decode) — used by the speculative verify forward
            out, kv = attn.attention_chunk_paged(
                p["attn"], h, {"k": cache["k"], "v": cache["v"]}, tables,
                ctx["pos"], cfg, impl=ctx["attn_impl"])
        else:
            out, kv = attn.attention_prefill_chunk(
                p["attn"], h, {"k": cache["k"], "v": cache["v"]},
                ctx["pos"], cfg, window=window, impl=ctx["attn_impl"])
        x = x + out
        new_cache = dict(kv)
    h = apply_norm(p["ffn_norm"], x, cfg.norm)
    y, _ = _ffn_apply(p["ffn"], h, cfg, kind)
    return x + y, new_cache


def _window_for(cfg: ModelConfig, kind: str) -> Optional[int]:
    if kind == "local_attn":
        return cfg.sliding_window or 2048
    return cfg.sliding_window  # dense archs may run windowed (long_500k)


def _prefill_kv(p, h, cfg: ModelConfig, ctx, window) -> Dict:
    """Recompute the rotated K/V for the cache at prefill time."""
    _, k, v = attn._project_qkv(p, h, cfg, ctx["positions"],
                                ctx.get("mrope_positions"))
    if window is not None:
        # ring buffer capacity is ALWAYS the window (decode slot arithmetic
        # is modulo the capacity); keep the last `window` positions
        S = k.shape[1]
        n_keep = min(window, S)
        idx = jnp.arange(S - n_keep, S)
        slots = idx % window
        ring_k = jnp.zeros((k.shape[0], window, cfg.n_kv_heads,
                            cfg.head_dim), k.dtype)
        ring_v = jnp.zeros_like(ring_k)
        ring_k = ring_k.at[:, slots].set(k[:, idx])
        ring_v = ring_v.at[:, slots].set(v[:, idx])
        return {"k": ring_k, "v": ring_v}
    return {"k": k, "v": v}


# ---- cross attention -------------------------------------------------
def _cross_kv(p, enc_out, cfg: ModelConfig):
    B, T, _ = enc_out.shape
    k = (enc_out @ p["wk"]).reshape(B, T, cfg.n_kv_heads, cfg.head_dim)
    v = (enc_out @ p["wv"]).reshape(B, T, cfg.n_kv_heads, cfg.head_dim)
    return k, v


def _cross_core(p, x, k, v, cfg: ModelConfig):
    B, S, _ = x.shape
    q = (x @ p["wq"]).reshape(B, S, cfg.n_heads, cfg.head_dim)
    mask = jnp.ones((B, S, k.shape[1]), bool)
    out = attn._sdpa(q, k, v, mask, 1.0 / float(cfg.head_dim) ** 0.5)
    return out.reshape(B, S, -1) @ p["wo"]


def _cross_full(p, x, enc_out, cfg: ModelConfig):
    k, v = _cross_kv(p, enc_out, cfg)
    return _cross_core(p, x, k, v, cfg), k, v


def _cross_cached(p, x, ck, cv, cfg: ModelConfig):
    return _cross_core(p, x, ck, cv, cfg)


# =====================================================================
# trunk
# =====================================================================
def _split_layers(cfg: ModelConfig) -> Tuple[int, Tuple[str, ...]]:
    k = len(cfg.block_pattern)
    n_units = cfg.n_layers // k
    return n_units, cfg.layer_kinds()[n_units * k:]


def _trunk_full(params: Dict, x: jax.Array, cfg: ModelConfig, ctx: Dict,
                remat: bool) -> Tuple[jax.Array, jax.Array, Dict]:
    n_units, tail_kinds = _split_layers(cfg)
    caches: Dict[str, Any] = {}
    aux_total = jnp.zeros((), jnp.float32)

    if n_units:
        def unit_body(carry, unit_params):
            x, aux = carry
            cache_outs = []
            for pos, kind in enumerate(cfg.block_pattern):
                x, a, c = _layer_full(unit_params[pos], x, cfg, kind, ctx)
                aux = aux + a
                cache_outs.append(c)
            return (x, aux), tuple(cache_outs)

        body = jax.checkpoint(unit_body) if remat else unit_body
        (x, aux_total), unit_caches = jax.lax.scan(
            body, (x, aux_total), params["units"])
        caches["units"] = unit_caches
    if tail_kinds:
        tail_caches = []
        for p_l, kind in zip(params["tail"], tail_kinds):
            x, a, c = _layer_full(p_l, x, cfg, kind, ctx)
            aux_total = aux_total + a
            tail_caches.append(c)
        caches["tail"] = tuple(tail_caches)
    return x, aux_total, caches


def _trunk_decode(params: Dict, x: jax.Array, cfg: ModelConfig,
                  cache: Dict, ctx: Dict) -> Tuple[jax.Array, Dict]:
    n_units, tail_kinds = _split_layers(cfg)
    new_cache: Dict[str, Any] = {}
    if n_units:
        def unit_body(x, scanned):
            unit_params, unit_cache = scanned
            new_unit_cache = []
            for pos, kind in enumerate(cfg.block_pattern):
                x, c = _layer_decode(unit_params[pos], x, cfg, kind,
                                     unit_cache[pos], ctx)
                new_unit_cache.append(c)
            return x, tuple(new_unit_cache)

        x, unit_caches = jax.lax.scan(
            unit_body, x, (params["units"], cache["units"]))
        new_cache["units"] = unit_caches
    if tail_kinds:
        tail_caches = []
        for p_l, kind, c_l in zip(params["tail"], tail_kinds, cache["tail"]):
            x, c = _layer_decode(p_l, x, cfg, kind, c_l, ctx)
            tail_caches.append(c)
        new_cache["tail"] = tuple(tail_caches)
    return x, new_cache


def _trunk_chunk(params: Dict, x: jax.Array, cfg: ModelConfig,
                 cache: Dict, ctx: Dict) -> Tuple[jax.Array, Dict]:
    n_units, tail_kinds = _split_layers(cfg)
    new_cache: Dict[str, Any] = {}
    if n_units:
        def unit_body(x, scanned):
            unit_params, unit_cache = scanned
            new_unit_cache = []
            for pos, kind in enumerate(cfg.block_pattern):
                x, c = _layer_chunk(unit_params[pos], x, cfg, kind,
                                    unit_cache[pos], ctx)
                new_unit_cache.append(c)
            return x, tuple(new_unit_cache)

        x, unit_caches = jax.lax.scan(
            unit_body, x, (params["units"], cache["units"]))
        new_cache["units"] = unit_caches
    if tail_kinds:
        tail_caches = []
        for p_l, kind, c_l in zip(params["tail"], tail_kinds, cache["tail"]):
            x, c = _layer_chunk(p_l, x, cfg, kind, c_l, ctx)
            tail_caches.append(c)
        new_cache["tail"] = tuple(tail_caches)
    return x, new_cache


def _encoder_apply(params: Dict, embeds: jax.Array, cfg: ModelConfig,
                   proj: jax.Array) -> jax.Array:
    x = embeds @ proj
    B, T, _ = x.shape
    positions = default_positions(B, T)
    ctx = {"positions": positions, "attn_impl": "auto", "chunk": 512,
           "return_cache": False}

    def body(x, layer_p):
        h = apply_norm(layer_p["attn_norm"], x, cfg.norm)
        q, k, v = attn._project_qkv(layer_p["attn"], h, cfg, positions)
        mask = jnp.ones((B, T, T), bool)  # bidirectional
        out = attn._sdpa(q, k, v, mask, 1.0 / float(cfg.head_dim) ** 0.5)
        x = x + out.reshape(B, T, -1) @ layer_p["attn"]["wo"]
        h = apply_norm(layer_p["ffn_norm"], x, cfg.norm)
        y, _ = _ffn_apply(layer_p["ffn"], h, cfg)
        return x + y, None

    x, _ = jax.lax.scan(body, x, params["encoder"]["layers"])
    return apply_norm(params["encoder"]["final_norm"], x, cfg.norm)


# =====================================================================
# embedding / positions / loss
# =====================================================================
def _embed_inputs(params: Dict, batch: Dict, cfg: ModelConfig
                  ) -> Tuple[jax.Array, jax.Array, Optional[Tuple]]:
    """Returns (x (B,S,d), positions (B,S), mrope_positions or None)."""
    tokens = batch["tokens"]
    B = tokens.shape[0]
    x_tok = apply_embed(params["embed"], tokens)
    if cfg.frontend is not None and not cfg.enc_dec:
        fe = batch["frontend_embeds"] @ params["frontend_proj"]
        x = jnp.concatenate([fe.astype(x_tok.dtype), x_tok], axis=1)
        F = fe.shape[1]
        S = x.shape[1]
        positions = default_positions(B, S)
        mrope = None
        if cfg.rope == "mrope":
            grid = max(1, int(F ** 0.5))
            t_v, h_v, w_v = vision_grid_positions(B, F, grid)
            lin = default_positions(B, S - F, offset=F)
            mk = lambda vis, off: jnp.concatenate([vis, lin], 1)  # noqa: E731
            mrope = (mk(t_v, 0), mk(h_v, 0), mk(w_v, 0))
        return x, positions, mrope
    positions = default_positions(B, tokens.shape[1])
    return x_tok, positions, None


def _lm_logits(params: Dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    x = apply_norm(params["final_norm"], x, cfg.norm)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    return unembed(x, head, cfg.tie_embeddings, cfg.logit_softcap)


def _xent_chunked(params: Dict, x: jax.Array, labels: jax.Array,
                  cfg: ModelConfig) -> jax.Array:
    """Cross-entropy without materialising (B,S,V): lax.map over S-chunks."""
    B, S, d = x.shape
    chunk = LOSS_CHUNK if S % LOSS_CHUNK == 0 else S
    n = S // chunk
    xc = jnp.moveaxis(x.reshape(B, n, chunk, d), 1, 0)
    lc = jnp.moveaxis(labels.reshape(B, n, chunk), 1, 0)

    def one(args):
        xi, li = args
        logits = _lm_logits(params, xi, cfg).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, li[..., None], axis=-1)[..., 0]
        return jnp.mean(logz - gold)

    losses = jax.lax.map(one, (xc, lc))
    return jnp.mean(losses)


# =====================================================================
# cache construction
# =====================================================================
def paged_layer_kind(cfg: ModelConfig, kind: str) -> bool:
    """True when ``kind``'s decode cache uses the block-pool layout under
    a paged cache: linear (non-windowed) attention KV only. Recurrent
    states are O(1) per sequence and ring buffers are bounded by their
    window, so both stay per-slot dense."""
    if kind in ("rwkv", "rglru") or cfg.enc_dec:
        return False
    return _window_for(cfg, kind) is None


def _layer_cache_spec(cfg: ModelConfig, kind: str, batch: int,
                      cache_len: int, dtype, abstract: bool,
                      paged: Optional[Tuple[int, int]] = None) -> Dict:
    window = _window_for(cfg, kind)
    if kind == "rwkv":
        fn = rk.rwkv_state_spec if abstract else rk.rwkv_state_init
        return fn(cfg, batch, dtype)
    if kind == "rglru":
        fn = rg.rglru_state_spec if abstract else rg.rglru_state_init
        return fn(cfg, batch, dtype)
    if paged is not None and paged_layer_kind(cfg, kind):
        n_blocks, block_size = paged
        fn = attn.paged_kv_cache_spec if abstract else attn.init_paged_kv_cache
        return fn(cfg, n_blocks, block_size, dtype)
    clen = min(cache_len, window) if window is not None else cache_len
    fn = attn.kv_cache_spec if abstract else attn.init_kv_cache
    c = fn(cfg, batch, clen, dtype)
    if cfg.enc_dec:
        enc_len = ModelSpecs.enc_len(cache_len)
        shp = (batch, enc_len, cfg.n_kv_heads, cfg.head_dim)
        if abstract:
            c["ck"] = jax.ShapeDtypeStruct(shp, dtype)
            c["cv"] = jax.ShapeDtypeStruct(shp, dtype)
        else:
            c["ck"] = jnp.zeros(shp, dtype)
            c["cv"] = jnp.zeros(shp, dtype)
    return c


def _stack_spec(specs: List[Any]) -> Any:
    def stack_leaf(*leaves):
        if isinstance(leaves[0], jax.ShapeDtypeStruct):
            return jax.ShapeDtypeStruct((len(leaves),) + leaves[0].shape,
                                        leaves[0].dtype)
        return jnp.stack(leaves)

    return jax.tree.map(stack_leaf, *specs)


def pad_cache(cfg: ModelConfig, cache: Dict, extra: int) -> Dict:
    """Extend linear (non-windowed) KV caches by ``extra`` slots so a
    prefill cache of S entries can absorb decode writes at S..S+extra-1.
    Ring buffers (windowed layers) and recurrent states are fixed-size and
    pass through untouched. Cross-attention K/V is static.

    Paged caches never come through here: a block pool has no length
    axis to pad — capacity grows by *allocating blocks*
    (``scatter_blocks`` + the engine's ``BlockAllocator``), which is the
    whole point of the layout."""
    n_units, tail_kinds = _split_layers(cfg)

    def pad_layer(kind: str, c: Dict, stacked: bool) -> Dict:
        if kind in ("rwkv", "rglru") or _window_for(cfg, kind) is not None:
            return c
        axis = 2 if stacked else 1  # cache-length axis
        out = dict(c)
        for key in ("k", "v"):
            widths = [(0, 0)] * c[key].ndim
            widths[axis] = (0, extra)
            out[key] = jnp.pad(c[key], widths)
        return out

    new: Dict[str, Any] = {}
    if "units" in cache:
        new["units"] = tuple(
            pad_layer(kind, c, stacked=True)
            for kind, c in zip(cfg.block_pattern, cache["units"]))
    if "tail" in cache:
        new["tail"] = tuple(
            pad_layer(kind, c, stacked=False)
            for kind, c in zip(tail_kinds, cache["tail"]))
    return new


def make_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype,
               abstract: bool = False,
               paged: Optional[Tuple[int, int]] = None) -> Dict:
    """Decode-cache pytree for ``batch`` slots of ``cache_len`` tokens.

    ``paged=(n_blocks, block_size)`` switches linear attention KV layers
    to the block-pool layout ``(n_blocks, block_size, KV, hd)`` shared by
    all slots (docs/ARCHITECTURE.md §5); windowed ring buffers and
    recurrent states keep their per-slot dense layout in both modes.
    """
    if paged is not None and cfg.enc_dec:
        raise NotImplementedError(
            "paged KV caches do not support encoder-decoder models")
    n_units, tail_kinds = _split_layers(cfg)
    cache: Dict[str, Any] = {}
    if n_units:
        units = []
        for kind in cfg.block_pattern:
            per = [_layer_cache_spec(cfg, kind, batch, cache_len, dtype,
                                     abstract, paged)
                   for _ in range(n_units)]
            units.append(_stack_spec(per))
        cache["units"] = tuple(units)
    if tail_kinds:
        cache["tail"] = tuple(
            _layer_cache_spec(cfg, kind, batch, cache_len, dtype, abstract,
                              paged)
            for kind in tail_kinds)
    return cache


# =====================================================================
# block-granular cache surgery (paged layout)
# =====================================================================
def gather_blocks(pool: jax.Array, block_ids: jax.Array) -> jax.Array:
    """Pure gather: pool (N, bs, ...) + ids (n,) -> (n*bs, ...) logical
    rows in block-table order."""
    bs = pool.shape[1]
    n = block_ids.shape[0]
    return pool[block_ids].reshape((n * bs,) + pool.shape[2:])


def gather_blocks_stacked(pool: jax.Array, block_ids: jax.Array
                          ) -> jax.Array:
    """:func:`gather_blocks` for scan-stacked unit caches: pool
    (U, N, bs, ...) + ids (n,) -> (U, n*bs, ...) logical rows in
    block-table order — the read-side primitive prefix-cache staging
    fills are built from."""
    bs = pool.shape[2]
    n = block_ids.shape[0]
    g = pool[:, block_ids]
    return g.reshape((pool.shape[0], n * bs) + pool.shape[3:])


def _rows_to_blocks(rows: jax.Array, n: int, bs: int) -> jax.Array:
    """Fold a token axis (third-from-last, length T <= n*bs) into
    (n, bs) blocks, zero-padding the ragged tail of the last block."""
    pad = n * bs - rows.shape[-3]
    if pad < 0:
        raise ValueError(
            f"{rows.shape[-3]} rows exceed {n} blocks of {bs}")
    if pad:
        widths = [(0, 0)] * rows.ndim
        widths[-3] = (0, pad)
        rows = jnp.pad(rows, widths)
    return rows.reshape(rows.shape[:-3] + (n, bs) + rows.shape[-2:])


def scatter_blocks(pool: jax.Array, rows: jax.Array,
                   block_ids: jax.Array) -> jax.Array:
    """Pure scatter: write ``rows`` (T, ...) with T <= n*bs into physical
    blocks ``block_ids`` (n,) of ``pool`` (N, bs, ...), zero-padding the
    ragged tail of the last block. This is the block-granular primitive
    prefill grafting is built from — the paged analogue of the dense
    engines' row scatter."""
    blocks = _rows_to_blocks(rows, block_ids.shape[0], pool.shape[1])
    return pool.at[block_ids].set(blocks)


def scatter_blocks_stacked(pool: jax.Array, rows: jax.Array,
                           block_ids: jax.Array) -> jax.Array:
    """:func:`scatter_blocks` for scan-stacked unit caches: pool
    (U, N, bs, ...), rows (U, T, ...) — the same physical blocks written
    in every unit's pool (direct indexed scatter; a vmap here would
    retrace on every admission)."""
    blocks = _rows_to_blocks(rows, block_ids.shape[0], pool.shape[2])
    return pool.at[:, block_ids].set(blocks)


# =====================================================================
# public model API
# =====================================================================
class ModelSpecs:
    VLM_FRONTEND_TOKENS = 1024
    ENC_RATIO = 4  # seamless: encoder frames = seq // 4

    @staticmethod
    def enc_len(seq_len: int) -> int:
        return max(8, seq_len // ModelSpecs.ENC_RATIO)


@dataclasses.dataclass
class Model:
    cfg: ModelConfig
    attn_impl: str = "auto"
    chunk: int = 512
    remat: bool = True
    #: cast params to this dtype for the forward pass (mixed precision:
    #: bf16 compute against f32 master weights — §Perf: halves every
    #: activation collective and activation buffer). None = no cast.
    compute_dtype: Any = None

    def _cast(self, params):
        if self.compute_dtype is None:
            return params
        from repro.common.types import cast_tree

        return cast_tree(params, self.compute_dtype)

    # ---- params ------------------------------------------------------
    def init(self, rng, dtype=jnp.float32):
        return init_params(rng, self.cfg, dtype)

    def abstract_params(self, dtype=jnp.float32):
        return abstract_params(self.cfg, dtype)

    # ---- forward: train ----------------------------------------------
    def loss(self, params, batch) -> jax.Array:
        cfg = self.cfg
        params = self._cast(params)
        x, positions, mrope = _embed_inputs(params, batch, cfg)
        ctx = {"positions": positions, "mrope_positions": mrope,
               "attn_impl": self.attn_impl, "chunk": self.chunk,
               "return_cache": False}
        if cfg.enc_dec:
            ctx["enc_out"] = _encoder_apply(params, batch["frontend_embeds"],
                                            cfg, params["frontend_proj"])
        x, aux, _ = _trunk_full(params, x, cfg, ctx, remat=self.remat)
        if cfg.frontend is not None and not cfg.enc_dec:
            F = batch["frontend_embeds"].shape[1]
            x = x[:, F:, :]
        loss = _xent_chunked(params, x, batch["labels"], cfg)
        return loss + 0.01 * aux

    # ---- forward: prefill ----------------------------------------------
    def prefill(self, params, batch):
        cfg = self.cfg
        params = self._cast(params)
        x, positions, mrope = _embed_inputs(params, batch, cfg)
        ctx = {"positions": positions, "mrope_positions": mrope,
               "attn_impl": self.attn_impl, "chunk": self.chunk,
               "return_cache": True}
        if cfg.enc_dec:
            ctx["enc_out"] = _encoder_apply(params, batch["frontend_embeds"],
                                            cfg, params["frontend_proj"])
        x, _, cache = _trunk_full(params, x, cfg, ctx, remat=False)
        logits = _lm_logits(params, x[:, -1:, :], cfg)
        return logits, cache

    # ---- forward: chunked prefill ---------------------------------------
    def prefill_chunk(self, params, cache, batch):
        """Chunked-prefill continuation (docs/ARCHITECTURE.md §5):
        ``batch = {"tokens": (B,T), "pos": (B,)}`` processes T tokens
        starting at absolute position ``pos`` against a DENSE decode
        cache previously filled up to ``pos`` (zeros on first chunk) —
        or, when ``batch["block_tables"]`` is present, directly against
        a PAGED pool: the chunk's K/V is scattered through the table and
        its queries attend earlier blocks in place (the engine's fused
        prefill path, no staging gather/scatter round trip).
        Returns (last-position logits, cache). Attention attends exactly
        the positions a full prefill attends, recurrent layers run their
        sequence form from the carried state — so a prompt processed in
        chunks is math-identical to one processed in a single prefill.
        Frontend/encoder-decoder inputs are not supported (the
        continuous engine gates them to the single-shot prefill path)."""
        cfg = self.cfg
        if cfg.enc_dec or cfg.frontend is not None:
            raise NotImplementedError(
                "prefill_chunk supports plain token prompts only")
        params = self._cast(params)
        x = apply_embed(params["embed"], batch["tokens"])
        ctx = {"pos": batch["pos"], "attn_impl": self.attn_impl,
               "block_tables": batch.get("block_tables")}
        x, new_cache = _trunk_chunk(params, x, cfg, cache, ctx)
        logits = _lm_logits(params, x[:, -1:, :], cfg)
        return logits, new_cache

    # ---- forward: speculative verification -------------------------------
    def verify_step(self, params, cache, batch):
        """Speculative-decoding verification forward
        (docs/ARCHITECTURE.md §5): ``batch = {"tokens": (B,W), "pos":
        (B,)}`` plus, for paged caches, ``"block_tables": (B, nb)``
        scores W candidate tokens per sequence in ONE forward — the
        logits at column ``j`` are exactly what sequential
        :meth:`decode_step` of ``tokens[:, j]`` at position ``pos + j``
        would produce — and writes their K/V rows. Returns
        (all-position logits (B,W,V), cache).

        The engine is responsible for masking / rolling back the rows of
        rejected candidates; that is only sound for rewindable caches
        (linear-attention KV), so callers gate on
        ``serving.engine.supports_speculation``. Paged callers must also
        pad ``block_tables`` with null-block columns so rows past
        ``cache_len`` cannot clip into live blocks."""
        cfg = self.cfg
        if cfg.enc_dec or cfg.frontend is not None:
            raise NotImplementedError(
                "verify_step supports plain token prompts only")
        params = self._cast(params)
        x = apply_embed(params["embed"], batch["tokens"])
        ctx = {"pos": batch["pos"], "attn_impl": self.attn_impl,
               "block_tables": batch.get("block_tables")}
        x, new_cache = _trunk_chunk(params, x, cfg, cache, ctx)
        logits = _lm_logits(params, x, cfg)
        return logits, new_cache

    # ---- forward: decode -----------------------------------------------
    def decode_step(self, params, cache, batch):
        """batch = {"tokens": (B,1), "pos": (B,)} plus, for paged caches,
        "block_tables": (B, nb) int32; returns (logits, cache)."""
        cfg = self.cfg
        params = self._cast(params)
        x = apply_embed(params["embed"], batch["tokens"])
        ctx = {"pos": batch["pos"], "attn_impl": self.attn_impl,
               "block_tables": batch.get("block_tables")}
        x, new_cache = _trunk_decode(params, x, cfg, cache, ctx)
        logits = _lm_logits(params, x, cfg)
        return logits, new_cache

    # ---- caches ---------------------------------------------------------
    def init_cache(self, batch: int, cache_len: int, dtype=jnp.float32):
        return make_cache(self.cfg, batch, cache_len, dtype, abstract=False)

    def cache_spec(self, batch: int, cache_len: int, dtype=jnp.float32):
        return make_cache(self.cfg, batch, cache_len, dtype, abstract=True)

    def init_paged_cache(self, batch: int, cache_len: int, n_blocks: int,
                         block_size: int, dtype=jnp.float32):
        """Paged decode cache: linear-attention KV in a shared
        ``(n_blocks, block_size, KV, hd)`` pool, windowed/recurrent state
        per-slot dense at ``batch`` slots (docs/ARCHITECTURE.md §5)."""
        return make_cache(self.cfg, batch, cache_len, dtype,
                          abstract=False, paged=(n_blocks, block_size))

    def paged_cache_spec(self, batch: int, cache_len: int, n_blocks: int,
                         block_size: int, dtype=jnp.float32):
        return make_cache(self.cfg, batch, cache_len, dtype,
                          abstract=True, paged=(n_blocks, block_size))

    # ---- input specs (dry-run stand-ins) ---------------------------------
    def input_specs(self, shape: InputShape, dtype=jnp.float32) -> Dict:
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        tok = lambda b, s: jax.ShapeDtypeStruct((b, s), jnp.int32)  # noqa
        if shape.kind == "train":
            specs: Dict[str, Any] = {}
            if cfg.enc_dec:
                specs["frontend_embeds"] = jax.ShapeDtypeStruct(
                    (B, ModelSpecs.enc_len(S), cfg.d_model), dtype)
                specs["tokens"] = tok(B, S)
                specs["labels"] = tok(B, S)
            elif cfg.frontend is not None:
                F = min(cfg.frontend_tokens or ModelSpecs.VLM_FRONTEND_TOKENS,
                        S // 2)
                specs["frontend_embeds"] = jax.ShapeDtypeStruct(
                    (B, F, cfg.d_model), dtype)
                specs["tokens"] = tok(B, S - F)
                specs["labels"] = tok(B, S - F)
            else:
                specs["tokens"] = tok(B, S)
                specs["labels"] = tok(B, S)
            return specs
        if shape.kind == "prefill":
            specs = {"tokens": tok(B, S)}
            if cfg.enc_dec:
                specs["frontend_embeds"] = jax.ShapeDtypeStruct(
                    (B, ModelSpecs.enc_len(S), cfg.d_model), dtype)
            elif cfg.frontend is not None:
                F = min(cfg.frontend_tokens or ModelSpecs.VLM_FRONTEND_TOKENS,
                        S // 2)
                specs["frontend_embeds"] = jax.ShapeDtypeStruct(
                    (B, F, cfg.d_model), dtype)
                specs["tokens"] = tok(B, S - F)
            return specs
        # decode: one token against a cache of length S
        return {"tokens": tok(B, 1),
                "pos": jax.ShapeDtypeStruct((B,), jnp.int32)}

    def supports_shape(self, shape: InputShape) -> bool:
        cfg = self.cfg
        if shape.name == "long_500k":
            return cfg.subquadratic
        return True


def build_model(cfg: ModelConfig, **kw) -> Model:
    return Model(cfg, **kw)
