"""BCEdge serving layer (paper Fig. 2; component map in
docs/ARCHITECTURE.md §1): request queues, workload, latency model,
simulator, real-JAX engines, profiler, and the framework facade."""
from repro.serving.simulator import EdgeServingEnv  # noqa: F401
from repro.serving.platforms import PLATFORMS  # noqa: F401
