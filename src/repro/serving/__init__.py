"""BCEdge serving layer (paper Fig. 2; component map in
docs/ARCHITECTURE.md §1): request queues, workload, latency model,
simulator, real-JAX engines, the multi-model instance-pool runtime
(docs/RUNTIME.md), profiler, and the framework facade."""
from repro.serving.simulator import EdgeServingEnv  # noqa: F401
from repro.serving.platforms import PLATFORMS  # noqa: F401


def __getattr__(name):
    # lazy: the runtime drags in jax via the engine; keep bare
    # `import repro.serving` light for the simulator-only paths
    if name == "ModelInstancePool":
        from repro.serving.runtime import ModelInstancePool
        return ModelInstancePool
    raise AttributeError(name)
