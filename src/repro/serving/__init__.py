from repro.serving.simulator import EdgeServingEnv  # noqa: F401
from repro.serving.platforms import PLATFORMS  # noqa: F401
