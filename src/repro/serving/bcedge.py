"""BCEdge framework facade: agent + SLO guard (interference predictor) +
profiler, driving the serving environment (paper Fig. 2 architecture).

The learning-based scheduler picks (b, m_c); before dispatch, the
SLO-aware interference predictor estimates the round latency — if it
exceeds the scheduling-slot budget (Eq. 1) or memory capacity, the guard
degrades the action to the nearest feasible (b, m_c) (paper §IV-F: the
predictor "guides the scheduler to make more robust decisions").

Two driver classes live here:

* ``BCEdgeScheduler`` + ``run_episode`` — the simulator path (paper
  experiments, Figs. 7-16);
* ``PoolScheduler`` — the REAL runtime path: the same (b, m_c) action
  applied to a ``ModelInstancePool`` of live engine instances
  (docs/RUNTIME.md), where b caps active slots per instance and m_c
  scales the instance count via the pool lifecycle API.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import numpy as np

from repro.config.base import ServingConfig
from repro.configs.paper_edge_models import EDGE_MODELS
from repro.core.interference import NNInterferencePredictor
from repro.launch.roofline import ICI_BW
from repro.serving import latency_model as lm
from repro.serving.simulator import EdgeServingEnv


@dataclasses.dataclass
class EpisodeResult:
    """Aggregated outcome of one serving episode (the quantities the
    paper's Figs. 7-16 are computed from)."""
    summary: Dict[str, float]
    rewards: List[float]
    losses: List[float]
    overhead_ms: List[float]
    per_model_utility: Dict[str, float]
    per_model_throughput: Dict[str, float]
    per_model_latency: Dict[str, float]
    timeline: List[Dict]


class BCEdgeScheduler:
    """Agent + SLO guard, the paper's Fig.-2 scheduler block (§IV-B with
    the §IV-F predictor guard; continuous-mode reinterpretation in
    docs/ARCHITECTURE.md §7)."""

    def __init__(self, env: EdgeServingEnv, agent,
                 predictor: Optional[NNInterferencePredictor] = None,
                 guard: bool = True):
        self.env = env
        self.agent = agent
        self.predictor = predictor
        self.guard = guard and predictor is not None
        self.guard_interventions = 0

    # ---- SLO guard --------------------------------------------------------
    def _feasible(self, model: str, b: int, m_c: int) -> bool:
        """Deadline feasibility: the predicted round latency (plus the
        batch-formation wait still ahead) must fit the OLDEST queued
        request's remaining SLO budget — the paper's predictor-guided
        robustness mechanism (§IV-F).

        Under exec_mode="continuous" the predictor is trained on
        PER-ITERATION latency (see ``run_episode``), so Eq.-1 feasibility
        is checked per iteration: one predicted iteration must fit the
        per-iteration share of the budget, i.e. the remaining SLO budget
        divided by the expected decode length of a request."""
        q = self.env.queues[model]
        cfg = self.env.cfg
        prof = EDGE_MODELS[model]
        slo = prof.slo_ms * cfg.slo_scale
        age = q.peek_oldest_age(self.env.now)
        fill_wait = max(0.0, b - len(q)) * 1000.0 / \
            max(cfg.arrival_rps, 1e-3)
        budget_ms = max(slo - age - fill_wait, 2.0)
        if cfg.exec_mode == "continuous":
            budget_ms /= max(cfg.decode_steps_mean, 1.0)
        feats = self.env.predict_features(model, b, m_c)
        pred_lat_ms = self.predictor.predict(feats) * 1000.0
        _, other_mem = self.env._other_load(exclude=model)
        mem = m_c * lm.instance_memory_gb(prof, b) + other_mem
        return pred_lat_ms <= budget_ms and mem <= self.env.hw.mem_gb

    def select_action(self, state: np.ndarray, model: str) -> int:
        a = self.agent.act(state)
        if not self.guard:
            return a
        # under backlog (oldest request already deep into its SLO) the
        # guard steps aside: throughput is the only way out, and degrading
        # to smaller rounds would death-spiral the queue
        q = self.env.queues[model]
        prof = EDGE_MODELS[model]
        if q.peek_oldest_age(self.env.now) >= 0.5 * prof.slo_ms * \
                self.env.cfg.slo_scale:
            return a
        cfg = self.env.cfg
        b, m_c = cfg.action_to_pair(a)
        if self._feasible(model, b, m_c):
            return a
        # degrade toward feasibility: shrink batch first, then concurrency
        self.guard_interventions += 1
        bs, ms = list(cfg.batch_sizes), list(cfg.concurrency_levels)
        bi, mi = bs.index(b), ms.index(m_c)
        while bi > 0 or mi > 0:
            if bi > 0:
                bi -= 1
            elif mi > 0:
                mi -= 1
            if self._feasible(model, bs[bi], ms[mi]):
                break
        return cfg.pair_to_action(bs[bi], ms[mi])


def run_episode(env: EdgeServingEnv, agent,
                predictor: Optional[NNInterferencePredictor] = None,
                guard: bool = True, learn: bool = True,
                update_every: int = 1, max_steps: int = 100_000
                ) -> EpisodeResult:
    sched = BCEdgeScheduler(env, agent, predictor, guard)
    s = env.reset()
    rewards: List[float] = []
    losses: List[float] = []
    overheads: List[float] = []
    timeline: List[Dict] = []
    done, steps = False, 0
    seen_rounds = 0
    while not done and steps < max_steps:
        model = env._focus
        t0 = time.perf_counter()
        a = sched.select_action(s, model)
        s2, r, done, info = env.step(a)
        if learn:
            for (ts, ta, tr, ts2, tdone) in info["transitions"]:
                agent.observe(ts, ta, tr, ts2, tdone)
            if steps % update_every == 0:
                m = agent.update()
                if m and "critic_loss" in m:
                    losses.append(m["critic_loss"])
        overheads.append((time.perf_counter() - t0) * 1000.0)
        # feed the predictor every newly completed round
        new_rounds = env.history[seen_rounds:]
        seen_rounds = len(env.history)
        for rnd in new_rounds:
            rewards.append(rnd.utility)
            timeline.append({"t_ms": rnd.finish_ms, "model": rnd.model,
                             "reward": rnd.utility, "b": rnd.b,
                             "m_c": rnd.m_c, "n": rnd.n_requests,
                             "violations": rnd.violations})
            if predictor is not None and rnd.features is not None:
                # round mode: the target is the round latency; continuous
                # mode: the PER-ITERATION latency (the guard checks Eq.-1
                # feasibility per iteration, see _feasible)
                actual_s = max(rnd.finish_ms - rnd.start_ms, 1e-3) / 1000.0
                if rnd.exec_mode == "continuous":
                    actual_s /= max(rnd.n_iters, 1)
                predictor.observe(rnd.features, actual_s)
        s = s2
        steps += 1

    # per-model aggregates
    per_u: Dict[str, List[float]] = {}
    per_thr: Dict[str, float] = {}
    per_lat: Dict[str, List[float]] = {}
    for rnd in env.history:
        per_u.setdefault(rnd.model, []).append(rnd.utility)
        per_thr[rnd.model] = per_thr.get(rnd.model, 0.0) + rnd.n_requests
        per_lat.setdefault(rnd.model, []).extend(rnd.latencies_ms)
    dur_s = max(env.now, 1.0) / 1000.0
    return EpisodeResult(
        summary=env.summarize(),
        rewards=rewards,
        losses=losses,
        overhead_ms=overheads,
        per_model_utility={m: float(np.mean(v)) for m, v in per_u.items()},
        per_model_throughput={m: v / dur_s for m, v in per_thr.items()},
        per_model_latency={m: float(np.mean(v)) for m, v in per_lat.items()},
        timeline=timeline,
    )


#: state vector fed to the per-model pool agents (docs/RUNTIME.md):
#: [log1p(queue), oldest slack s, own m_c share, total live share,
#:  log1p(predicted iter ms), log1p(Eq.-1 slot ms),
#:  KV budget headroom frac (1.0 for dense/unlimited pools),
#:  log1p(prefill backlog tokens), log1p(preemptions since last decision),
#:  prefix-cache hit rate (0.0 for dense / cache-off pools),
#:  speculative acceptance rate (0.0 for spec-off pools),
#:  shared-device-set utilization (0.0 for unbudgeted pools),
#:  host-tier occupancy frac (swapped + spilled blocks over the host
#:  pool; 0.0 for pools without a KV offload tier) — the agent sees
#:  how much preempted/cold state is parked off-device, i.e. how
#:  cheap further preemption currently is (docs/RUNTIME.md §8)]
POOL_STATE_DIM = 13


def tp_collective_ms_per_token(model_cfg, tp_degree: int) -> float:
    """Analytic per-token collective surcharge at TP degree ``d``
    (docs/RUNTIME.md §10): each layer psums its (d_model,) residual
    twice per token (the row-sharded attention wo and the MLP down
    projection), and a ring all-reduce moves ``2(d-1)/d`` of the bf16
    payload per chip — the ``collective_s`` roofline term
    (``launch.roofline.WorkloadCost.terms``) at those bytes. This is
    what the guard layers on top of the measured per-degree token-cost
    fit, so a degree with no samples yet is still priced."""
    if tp_degree <= 1:
        return 0.0
    bytes_per_chip = model_cfg.n_layers * 2 \
        * 2.0 * (tp_degree - 1) / tp_degree * model_cfg.d_model * 2
    return bytes_per_chip / ICI_BW * 1000.0


class PoolScheduler:
    """(b, m_c) scheduler over a REAL ``ModelInstancePool``
    (docs/RUNTIME.md): b caps the active slots per instance, m_c is
    applied through ``pool.scale_to`` so the action actually spawns or
    drains live engine instances. One agent per model; the SLO guard
    degrades infeasible actions using the contention model the pool
    calibrates from its own measured iteration latencies (the real-engine
    counterpart of the §IV-F predictor guard)."""

    def __init__(self, pool, cfg: ServingConfig,
                 slo_ms: Optional[Dict[str, float]] = None,
                 decode_steps_mean: float = 8.0, guard: bool = True,
                 learn: bool = True, seed: int = 0, agents=None):
        self.pool = pool
        self.cfg = cfg
        self.slo_ms = dict(slo_ms or {})
        self.decode_steps_mean = max(1.0, decode_steps_mean)
        self.guard = guard
        self.learn = learn
        self.guard_interventions = 0
        if agents is None:
            from repro.core.sac import SACAgent, SACConfig
            agents = {m: SACAgent(POOL_STATE_DIM, cfg.n_actions,
                                  SACConfig(batch_size=32, lr=1e-3),
                                  seed=seed + i)
                      for i, m in enumerate(pool.configs)}
        self.agents = agents
        self._last: Dict[str, tuple] = {}      # model -> (state, action)
        self._since: Dict[str, list] = {m: [] for m in pool.configs}
        #: per-model preemption counter at the last decision (the state
        #: vector feeds the delta, docs/RUNTIME.md §8)
        self._preempt_seen: Dict[str, int] = {m: 0 for m in pool.configs}
        #: results already harvested from pool history by ``tick()``
        self._tick_seen: Dict[str, int] = {m: 0 for m in pool.configs}

    # ---- feedback --------------------------------------------------------
    def record(self, results) -> None:
        """Feed finished PoolResults back (call after every pool.step)."""
        for r in results:
            self._since[r.model].append(r)

    def _reward(self, model: str) -> float:
        """Mean per-request Eq.-3 utility since the last decision, with
        the simulator's Eq.-4 violation penalty."""
        rs = self._since[model]
        self._since[model] = []
        if not rs:
            return 0.0
        served = [r.utility for r in rs if not r.rejected]
        u = float(np.mean(served)) if served else 0.0
        return u - 3.5 * sum(r.violated for r in rs) / len(rs)

    # ---- state / guard ---------------------------------------------------
    def _state(self, model: str) -> np.ndarray:
        p = self.pool
        t1, c = p.contention()
        pred = lm.predicted_iter_ms(t1, c, max(1, p.total_live()))
        slack = p.oldest_slack_ms(model)
        slack = min(slack, 10_000.0)
        occ = p.kv_occupancy()
        headroom = 1.0
        if occ["budget_tokens"] > 0.0:
            # the budget is consumed by BOTH live token residency and
            # committed spawn grants (an idle instance still holds its
            # grant, so scaling up can be blocked at used_tokens ~ 0);
            # report the tighter of the two so the agent sees the
            # binding constraint
            committed = occ["committed_blocks"] * p.block_size
            headroom = max(0.0, 1.0 - max(occ["used_tokens"], committed)
                           / occ["budget_tokens"])
        preempts = getattr(p, "preempts_by_model", {}).get(model, 0)
        new_preempts = preempts - self._preempt_seen.get(model, 0)
        self._preempt_seen[model] = preempts
        return np.array([
            np.log1p(p.queue_len(model)),
            slack / 1000.0,
            p.m_c(model) / max(1, p.max_instances),
            p.total_live() / max(1, p.max_instances),
            np.log1p(max(pred, 0.0)),
            np.log1p(max(p.slot_ms(model), 0.0)),
            headroom,
            np.log1p(max(0, p.prefill_backlog_tokens(model))),
            np.log1p(max(0, new_preempts)),
            float(occ.get("prefix_hit_rate", 0.0)),
            min(1.0, max(0.0, float(p.spec_accept_rate()))),
            min(1.0, p.devices_in_use() / p.n_devices)
            if getattr(p, "n_devices", None) else 0.0,
            min(1.0, max(0.0, float(occ.get("host_frac", 0.0)))),
        ], np.float32)

    def _kv_feasible(self, model: str, b: int, m_c: int) -> bool:
        """Eq.-4 (memory) feasibility against the pool's REAL shared KV
        block budget: the proposed allocation's predicted resident
        tokens — b slots × m_c instances at the MEASURED tokens/sequence
        (``latency_model.fit_occupancy``) — plus what the other tenants
        measurably occupy must fit the budget. Dense pools / unlimited
        budgets / uncalibrated occupancy default to feasible (the
        analytic curve never blocked the real runtime either).

        This is the *demand* side of Eq. 4; the *allocation* side
        (committed spawn grants) is enforced physically by
        ``pool.scale_to``/``can_spawn`` clamping on free blocks, and is
        surfaced to the agent via the headroom state feature.

        With prefix caching on, the demand is priced in *effective*
        blocks: the measured shared fraction discounts the per-sequence
        footprint (a block mapped by k sequences charges the budget
        once), so the scheduler can exploit sharing when it sizes
        (b, m_c) instead of leaving the freed capacity idle."""
        occ = self.pool.kv_occupancy()
        budget = occ["budget_tokens"]
        tps = occ["tokens_per_seq"]
        if budget <= 0.0 or tps <= 0.0:
            return True
        used_others = occ["used_tokens"] - self.pool.kv_used_tokens(model)
        need = lm.predicted_kv_tokens(tps, b * m_c)
        shared = min(max(occ.get("shared_frac", 0.0), 0.0), 0.95)
        need *= 1.0 - shared
        return need + used_others <= budget

    def _iter_budget_ms(self, model: str) -> float:
        """Per-iteration share of the most urgent request's slack."""
        slack = self.pool.oldest_slack_ms(model)
        if slack == float("inf"):
            slack = self.slo_ms.get(model, 1000.0)
        return max(slack, 2.0) / self.decode_steps_mean

    def _feasible(self, model: str, b: int, m_c: int,
                  token_budget: int = 0, spec_k: int = 0,
                  tp_degree: int = 1) -> bool:
        """Eq.-1 feasibility per iteration at the PROPOSED overlap: the
        calibrated contention model's predicted pool-iteration latency
        must fit the most urgent request's per-iteration budget. The
        prediction counts BUSY instances (what the samples are recorded
        against) at the proposed concurrency. The b axis does not enter
        the contention model, but it does enter the KV-budget guard
        (``_kv_feasible``), the real-occupancy counterpart of the
        simulator's Eq.-4 memory check.

        A nonzero ``token_budget`` is additionally priced by the
        token-cost fit (docs/RUNTIME.md §8): one iteration doing
        ``token_budget`` tokens of prefill+decode work must also fit the
        per-iteration budget — this is what makes the Eq.-1 guard REAL
        for long-prompt admissions instead of advisory.

        A nonzero ``spec_k`` adds the verify-forward surcharge: every
        decoding slot processes ``1 + k`` tokens per iteration instead
        of one, so ``k * b`` extra tokens are priced through the same
        token-cost fit. With no explicit token budget the decode floor
        is ``b`` tokens (one per slot), so the priced work is
        ``b + k * b``.

        ``tp_degree`` prices the LAYOUT (docs/RUNTIME.md §10): the
        proposed ``m_c`` instances each span ``tp_degree`` devices, so
        (a) the other tenants' devices plus ``m_c * tp_degree`` must
        fit the pool's shared device set, and (b) iteration work is
        priced through that degree's own token-cost fit plus the
        analytic per-token collective surcharge
        (``tp_collective_ms_per_token``)."""
        if not self._kv_feasible(model, b, m_c):
            return False
        n_dev = getattr(self.pool, "n_devices", None)
        if n_dev:
            dev_others = sum(i.tp_degree for i in self.pool.live()
                             if i.model != model)
            if dev_others + m_c * tp_degree > n_dev:
                return False
        budget = self._iter_budget_ms(model)
        t1, c = self.pool.contention()
        if t1 > 0.0:
            busy_others = self.pool.busy_count() - sum(
                1 for i in self.pool.live(model) if i.n_resident > 0)
            if lm.predicted_iter_ms(t1, c, max(1, busy_others + m_c)) \
                    > budget:
                return False
        work = token_budget
        if spec_k > 0:
            work = (token_budget if token_budget > 0 else b) + spec_k * b
        if tp_degree > 1 and work == 0:
            work = b  # decode floor: the collective surcharge is per token
        if work > 0:
            base, per_tok = self.pool.token_cost(tp_degree) \
                if tp_degree > 1 else self.pool.token_cost()
            per_tok += tp_collective_ms_per_token(
                self.pool.configs[model], tp_degree)
            if per_tok > 0.0 and lm.predicted_token_iter_ms(
                    base, per_tok, work) > budget:
                return False
        return True

    def _apply(self, model: str, a: int) -> int:
        cfg = self.cfg
        b, m_c, tb, sk, tp = cfg.action_to_quint(a)
        # under backlog the guard steps aside (same rationale as the
        # simulator path: only throughput clears an old queue)
        slo = self.slo_ms.get(model, 1000.0)
        backlog = self.pool.oldest_slack_ms(model) < 0.5 * slo
        if self.guard and not backlog and \
                not self._feasible(model, b, m_c, tb, sk, tp):
            self.guard_interventions += 1
            bs_levels = list(cfg.batch_sizes)
            ms = list(cfg.concurrency_levels)
            # token budgets ordered most→least iteration work (0 =
            # uncapped sorts first); degrading walks toward tighter caps
            tbs = sorted(cfg.token_budgets,
                         key=lambda t: float("inf") if t == 0 else t,
                         reverse=True)
            # speculation depths ordered deepest→shallowest: walking
            # forward sheds the verify surcharge until k collapses to 0
            ks = sorted(cfg.spec_depths, reverse=True)
            # TP degrees widest→narrowest: stepping down sheds the
            # per-token collective surcharge AND frees (m_c·Δd) devices
            tps = sorted(cfg.tp_degrees, reverse=True)
            bi, mi = bs_levels.index(b), ms.index(m_c)
            ti, ki, di = tbs.index(tb), ks.index(sk), tps.index(tp)
            # degrade speculation first (it is pure surcharge — k*b
            # extra verify tokens — and dropping it never sheds
            # capacity), then the token budget (a tighter cap bounds
            # the iteration), then the TP degree (collectives and
            # devices go, per-instance KV capacity shrinks), then
            # concurrency (it both contends and multiplies KV
            # residency), then batch
            while ki < len(ks) - 1 or ti < len(tbs) - 1 \
                    or di < len(tps) - 1 or mi > 0 or bi > 0:
                if ki < len(ks) - 1:
                    ki += 1
                elif ti < len(tbs) - 1:
                    ti += 1
                elif di < len(tps) - 1:
                    di += 1
                elif mi > 0:
                    mi -= 1
                else:
                    bi -= 1
                if self._feasible(model, bs_levels[bi], ms[mi],
                                  tbs[ti], ks[ki], tps[di]):
                    break
            b, m_c, tb, sk, tp = bs_levels[bi], ms[mi], tbs[ti], \
                ks[ki], tps[di]
        self.pool.set_slot_cap(model, b)
        if hasattr(self.pool, "set_tp_degree"):
            # before scale_to: a degree change drains mismatched
            # instances and the scale-up respawns at the new layout
            self.pool.set_tp_degree(model, tp)
        self.pool.scale_to(model, m_c)
        self.pool.set_token_budget(model, tb or None)
        self.pool.set_spec_k(model, sk)
        return cfg.quint_to_action(b, m_c, tb, sk, tp)

    # ---- decision epoch --------------------------------------------------
    def control(self) -> Dict[str, tuple]:
        """One decision per model: close the previous (s, a, r, s')
        transition, pick a new (b, m_c), and apply it to the pool. Call
        once per Eq.-1 slot (docs/RUNTIME.md)."""
        applied = {}
        for model, agent in self.agents.items():
            s = self._state(model)
            if self.learn and model in self._last:
                s0, a0 = self._last[model]
                agent.observe(s0, a0, self._reward(model), s, False)
                agent.update()
            a = self._apply(model, agent.act(s))
            self._last[model] = (s, a)
            applied[model] = self.cfg.action_to_pair(a)
        return applied

    def tick(self, pool=None) -> Dict[str, tuple]:
        """Push-mode decision epoch (docs/RUNTIME.md §11): harvest the
        results completed since the last call straight from the pool's
        history, then run ``control()``. Signature matches the
        ``ServingDriver.on_tick`` hook, which invokes it on a wall-clock
        cadence against LIVE queue state — the pool argument is
        positional sugar and must be this scheduler's own pool.

        Under the driver the serving loop never sees a ``step()`` return
        value to ``record()``, so the tick replays the per-model results
        appended since the last harvest instead."""
        if pool is not None and pool is not self.pool:
            raise ValueError("tick() got a different pool than the one "
                             "this scheduler controls")
        for model in self.pool.configs:
            hist = self.pool.results(model)
            seen = self._tick_seen.get(model, 0)
            if seen > len(hist):  # pool.reset_metrics() cleared history
                seen = 0
            self.record(hist[seen:])
            self._tick_seen[model] = len(hist)
        return self.control()


def collect_interference_dataset(cfg: ServingConfig, n: int = 2000,
                                 seed: int = 0):
    """Fig. 13 protocol: random (b, m_c) probes; features + actual latency."""
    env = EdgeServingEnv(cfg, seed=seed)
    rng = np.random.default_rng(seed)
    X, y = [], []
    pending: Dict[tuple, np.ndarray] = {}
    s = env.reset()
    done = False
    seen = 0
    while len(X) < n:
        if done:
            env.seed += 1
            s = env.reset()
            pending.clear()
            seen = 0
        a = int(rng.integers(env.n_actions))
        s, r, done, info = env.step(a)
        for rnd in env.history[seen:]:
            # overflow rounds take the failure-penalty path, not the
            # interference latency model — they are not prediction targets
            if rnd.features is not None and not rnd.overflow:
                X.append(rnd.features)
                y.append(max(rnd.finish_ms - rnd.start_ms, 1e-3) / 1000.0)
        seen = len(env.history)
    return np.stack(X[:n]), np.asarray(y[:n], np.float64)
