"""BCEdge framework facade: agent + SLO guard (interference predictor) +
profiler, driving the serving environment (paper Fig. 2 architecture).

The learning-based scheduler picks (b, m_c); before dispatch, the
SLO-aware interference predictor estimates the round latency — if it
exceeds the scheduling-slot budget (Eq. 1) or memory capacity, the guard
degrades the action to the nearest feasible (b, m_c) (paper §IV-F: the
predictor "guides the scheduler to make more robust decisions").
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import numpy as np

from repro.config.base import ServingConfig
from repro.configs.paper_edge_models import EDGE_MODELS
from repro.core.interference import NNInterferencePredictor
from repro.serving import latency_model as lm
from repro.serving.simulator import EdgeServingEnv


@dataclasses.dataclass
class EpisodeResult:
    """Aggregated outcome of one serving episode (the quantities the
    paper's Figs. 7-16 are computed from)."""
    summary: Dict[str, float]
    rewards: List[float]
    losses: List[float]
    overhead_ms: List[float]
    per_model_utility: Dict[str, float]
    per_model_throughput: Dict[str, float]
    per_model_latency: Dict[str, float]
    timeline: List[Dict]


class BCEdgeScheduler:
    """Agent + SLO guard, the paper's Fig.-2 scheduler block (§IV-B with
    the §IV-F predictor guard; continuous-mode reinterpretation in
    docs/ARCHITECTURE.md §7)."""

    def __init__(self, env: EdgeServingEnv, agent,
                 predictor: Optional[NNInterferencePredictor] = None,
                 guard: bool = True):
        self.env = env
        self.agent = agent
        self.predictor = predictor
        self.guard = guard and predictor is not None
        self.guard_interventions = 0

    # ---- SLO guard --------------------------------------------------------
    def _feasible(self, model: str, b: int, m_c: int) -> bool:
        """Deadline feasibility: the predicted round latency (plus the
        batch-formation wait still ahead) must fit the OLDEST queued
        request's remaining SLO budget — the paper's predictor-guided
        robustness mechanism (§IV-F).

        Under exec_mode="continuous" the predictor is trained on
        PER-ITERATION latency (see ``run_episode``), so Eq.-1 feasibility
        is checked per iteration: one predicted iteration must fit the
        per-iteration share of the budget, i.e. the remaining SLO budget
        divided by the expected decode length of a request."""
        q = self.env.queues[model]
        cfg = self.env.cfg
        prof = EDGE_MODELS[model]
        slo = prof.slo_ms * cfg.slo_scale
        age = q.peek_oldest_age(self.env.now)
        fill_wait = max(0.0, b - len(q)) * 1000.0 / \
            max(cfg.arrival_rps, 1e-3)
        budget_ms = max(slo - age - fill_wait, 2.0)
        if cfg.exec_mode == "continuous":
            budget_ms /= max(cfg.decode_steps_mean, 1.0)
        feats = self.env.predict_features(model, b, m_c)
        pred_lat_ms = self.predictor.predict(feats) * 1000.0
        _, other_mem = self.env._other_load(exclude=model)
        mem = m_c * lm.instance_memory_gb(prof, b) + other_mem
        return pred_lat_ms <= budget_ms and mem <= self.env.hw.mem_gb

    def select_action(self, state: np.ndarray, model: str) -> int:
        a = self.agent.act(state)
        if not self.guard:
            return a
        # under backlog (oldest request already deep into its SLO) the
        # guard steps aside: throughput is the only way out, and degrading
        # to smaller rounds would death-spiral the queue
        q = self.env.queues[model]
        prof = EDGE_MODELS[model]
        if q.peek_oldest_age(self.env.now) >= 0.5 * prof.slo_ms * \
                self.env.cfg.slo_scale:
            return a
        cfg = self.env.cfg
        b, m_c = cfg.action_to_pair(a)
        if self._feasible(model, b, m_c):
            return a
        # degrade toward feasibility: shrink batch first, then concurrency
        self.guard_interventions += 1
        bs, ms = list(cfg.batch_sizes), list(cfg.concurrency_levels)
        bi, mi = bs.index(b), ms.index(m_c)
        while bi > 0 or mi > 0:
            if bi > 0:
                bi -= 1
            elif mi > 0:
                mi -= 1
            if self._feasible(model, bs[bi], ms[mi]):
                break
        return cfg.pair_to_action(bs[bi], ms[mi])


def run_episode(env: EdgeServingEnv, agent,
                predictor: Optional[NNInterferencePredictor] = None,
                guard: bool = True, learn: bool = True,
                update_every: int = 1, max_steps: int = 100_000
                ) -> EpisodeResult:
    sched = BCEdgeScheduler(env, agent, predictor, guard)
    s = env.reset()
    rewards: List[float] = []
    losses: List[float] = []
    overheads: List[float] = []
    timeline: List[Dict] = []
    done, steps = False, 0
    seen_rounds = 0
    while not done and steps < max_steps:
        model = env._focus
        t0 = time.perf_counter()
        a = sched.select_action(s, model)
        s2, r, done, info = env.step(a)
        if learn:
            for (ts, ta, tr, ts2, tdone) in info["transitions"]:
                agent.observe(ts, ta, tr, ts2, tdone)
            if steps % update_every == 0:
                m = agent.update()
                if m and "critic_loss" in m:
                    losses.append(m["critic_loss"])
        overheads.append((time.perf_counter() - t0) * 1000.0)
        # feed the predictor every newly completed round
        new_rounds = env.history[seen_rounds:]
        seen_rounds = len(env.history)
        for rnd in new_rounds:
            rewards.append(rnd.utility)
            timeline.append({"t_ms": rnd.finish_ms, "model": rnd.model,
                             "reward": rnd.utility, "b": rnd.b,
                             "m_c": rnd.m_c, "n": rnd.n_requests,
                             "violations": rnd.violations})
            if predictor is not None and rnd.features is not None:
                # round mode: the target is the round latency; continuous
                # mode: the PER-ITERATION latency (the guard checks Eq.-1
                # feasibility per iteration, see _feasible)
                actual_s = max(rnd.finish_ms - rnd.start_ms, 1e-3) / 1000.0
                if rnd.exec_mode == "continuous":
                    actual_s /= max(rnd.n_iters, 1)
                predictor.observe(rnd.features, actual_s)
        s = s2
        steps += 1

    # per-model aggregates
    per_u: Dict[str, List[float]] = {}
    per_thr: Dict[str, float] = {}
    per_lat: Dict[str, List[float]] = {}
    for rnd in env.history:
        per_u.setdefault(rnd.model, []).append(rnd.utility)
        per_thr[rnd.model] = per_thr.get(rnd.model, 0.0) + rnd.n_requests
        per_lat.setdefault(rnd.model, []).extend(rnd.latencies_ms)
    dur_s = max(env.now, 1.0) / 1000.0
    return EpisodeResult(
        summary=env.summarize(),
        rewards=rewards,
        losses=losses,
        overhead_ms=overheads,
        per_model_utility={m: float(np.mean(v)) for m, v in per_u.items()},
        per_model_throughput={m: v / dur_s for m, v in per_thr.items()},
        per_model_latency={m: float(np.mean(v)) for m, v in per_lat.items()},
        timeline=timeline,
    )


def collect_interference_dataset(cfg: ServingConfig, n: int = 2000,
                                 seed: int = 0):
    """Fig. 13 protocol: random (b, m_c) probes; features + actual latency."""
    env = EdgeServingEnv(cfg, seed=seed)
    rng = np.random.default_rng(seed)
    X, y = [], []
    pending: Dict[tuple, np.ndarray] = {}
    s = env.reset()
    done = False
    seen = 0
    while len(X) < n:
        if done:
            env.seed += 1
            s = env.reset()
            pending.clear()
            seen = 0
        a = int(rng.integers(env.n_actions))
        s, r, done, info = env.step(a)
        for rnd in env.history[seen:]:
            # overflow rounds take the failure-penalty path, not the
            # interference latency model — they are not prediction targets
            if rnd.features is not None and not rnd.overflow:
                X.append(rnd.features)
                y.append(max(rnd.finish_ms - rnd.start_ms, 1e-3) / 1000.0)
        seen = len(env.history)
    return np.stack(X[:n]), np.asarray(y[:n], np.float64)
