"""Background serving driver (docs/RUNTIME.md §11): the non-blocking
iteration loop that turns the pull-mode pool (caller drives ``step()``)
into a push-mode serving core.

``ServingDriver`` owns a daemon thread that steps a
:class:`~repro.serving.runtime.ModelInstancePool` continuously whenever
work is pending, sleeping briefly when idle. Every pool access — the
loop's ``step()``, front-end ``submit``/``cancel``, the scheduler's
control epoch — serialises on one re-entrant lock, so the pool itself
stays single-threaded (engines hold jit caches and numpy state that are
not thread-safe) while callers never block on a drain.

The optional ``on_tick`` hook is the scheduler's new decision cadence:
instead of deciding "between drains", the driver invokes it on a
wall-clock interval against live queue state (BCEdge's Eq. 1 slot,
docs/RUNTIME.md §2) while holding the pool lock.

Lifecycle events reach front-ends through the pool's per-request
listeners (``pool.add_listener``), which fire inside ``step()`` on THIS
thread — listeners must be cheap and non-reentrant (bridge to your own
loop, e.g. ``asyncio.call_soon_threadsafe``).
"""
from __future__ import annotations

import threading
import time
from typing import Callable, Optional

from repro.serving.runtime import ModelInstancePool, PoolResult


class ServingDriver:
    """Steps ``pool`` on a background thread; thread-safe facade for
    submit/cancel/stats. Usable as a context manager::

        with ServingDriver(pool, on_tick=sched_tick) as driver:
            rid = driver.submit("qwen", prompt, slo_ms=500.0)
            ...
    """

    def __init__(self, pool: ModelInstancePool,
                 idle_sleep_s: float = 0.002,
                 on_tick: Optional[Callable] = None,
                 tick_interval_s: float = 0.25):
        self.pool = pool
        self.idle_sleep_s = idle_sleep_s
        #: ``on_tick(pool)`` invoked under the pool lock at most once per
        #: ``tick_interval_s`` — the scheduler's wall-clock control epoch
        self.on_tick = on_tick
        self.tick_interval_s = tick_interval_s
        self.lock = threading.RLock()
        self.n_loop_steps = 0
        self.n_ticks = 0
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._next_tick = 0.0
        #: a loop-thread exception is re-raised to the NEXT caller of
        #: stop() instead of dying silently on a daemon thread
        self._error: Optional[BaseException] = None

    # ---- lifecycle -------------------------------------------------------
    @property
    def running(self) -> bool:
        return self._thread is not None and self._thread.is_alive()

    def start(self) -> "ServingDriver":
        if self.running:
            raise RuntimeError("driver already running")
        self._stop.clear()
        self._error = None
        self._next_tick = time.perf_counter()
        self._thread = threading.Thread(
            target=self._loop, name="serving-driver", daemon=True)
        self._thread.start()
        return self

    def stop(self, timeout: float = 30.0) -> None:
        """Stop the loop (idempotent). Re-raises a loop-thread crash so
        test/benchmark harnesses cannot pass on a dead driver."""
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout)
            if self._thread.is_alive():  # pragma: no cover - hang guard
                raise RuntimeError("serving driver failed to stop")
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def __enter__(self) -> "ServingDriver":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # ---- thread-safe pool facade ----------------------------------------
    def submit(self, *args, **kwargs) -> int:
        with self.lock:
            return self.pool.submit(*args, **kwargs)

    def cancel(self, request_id: int) -> Optional[PoolResult]:
        with self.lock:
            return self.pool.cancel(request_id)

    def add_listener(self, request_id: int, fn: Callable) -> None:
        with self.lock:
            self.pool.add_listener(request_id, fn)

    def remove_listener(self, request_id: int) -> None:
        with self.lock:
            self.pool.remove_listener(request_id)

    def admission_headroom(self, *args, **kwargs):
        with self.lock:
            return self.pool.admission_headroom(*args, **kwargs)

    def stats(self):
        with self.lock:
            return self.pool.stats()

    def report(self):
        with self.lock:
            return self.pool.report()

    def drain(self, timeout_s: float = 60.0) -> None:
        """Block the CALLING thread until the pool has no progressable
        work (the background loop keeps stepping; this only polls)."""
        deadline = time.perf_counter() + timeout_s
        while time.perf_counter() < deadline:
            with self.lock:
                if not (self.pool._work_pending()
                        and self.pool._can_progress()):
                    return
            time.sleep(self.idle_sleep_s)
        raise TimeoutError(f"pool not drained after {timeout_s}s")

    # ---- loop ------------------------------------------------------------
    def _loop(self) -> None:
        try:
            while not self._stop.is_set():
                stepped = False
                with self.lock:
                    now = time.perf_counter()
                    if self.on_tick is not None and now >= self._next_tick:
                        self._next_tick = now + self.tick_interval_s
                        self.on_tick(self.pool)
                        self.n_ticks += 1
                    if self.pool._work_pending() \
                            and self.pool._can_progress():
                        self.pool.step()
                        self.n_loop_steps += 1
                        stepped = True
                if not stepped:
                    # idle (or unprogressable until a tick scales up):
                    # yield the lock so submits/cancels never starve
                    time.sleep(self.idle_sleep_s)
        except BaseException as e:  # noqa: BLE001 - surfaced in stop()
            self._error = e
