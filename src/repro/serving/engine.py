"""Real-JAX inference engine: batched prefill + decode with KV caches.

This is the execution backend the BCEdge scheduler drives when serving an
actual model (examples/serve_llm.py): requests carry token prompts, the
dynamic batcher forms (b, m_c) rounds, and the engine runs jit-compiled
prefill/decode with shape bucketing (so the compile cache stays small).
On CPU it serves the reduced configs; on a TPU pod the same code runs the
full configs under the production mesh.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import ModelConfig
from repro.models import build_model
from repro.models.transformer import pad_cache


def _bucket(n: int, buckets=(1, 2, 4, 8, 16, 32, 64, 128)) -> int:
    for b in buckets:
        if n <= b:
            return b
    return buckets[-1]


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray          # (B, new)
    prefill_ms: float
    decode_ms: float
    total_ms: float


class InferenceEngine:
    def __init__(self, cfg: ModelConfig, max_seq: int = 512,
                 dtype=jnp.float32, seed: int = 0):
        self.cfg = cfg
        self.max_seq = max_seq
        self.model = build_model(cfg, remat=False)
        self.params = self.model.init(jax.random.PRNGKey(seed), dtype)
        self._prefill = jax.jit(self.model.prefill)
        self._decode = jax.jit(self.model.decode_step)

    def _make_batch(self, prompts: List[np.ndarray]
                    ) -> Tuple[Dict, int, np.ndarray]:
        B = _bucket(len(prompts))
        S = _bucket(max(len(p) for p in prompts),
                    buckets=(16, 32, 64, 128, 256, 512))
        toks = np.zeros((B, S), np.int32)
        lens = np.zeros((B,), np.int32)
        for i, p in enumerate(prompts):
            toks[i, S - len(p):] = p  # left-pad (last position = last token)
            lens[i] = len(p)
        batch = {"tokens": jnp.asarray(toks)}
        if self.cfg.frontend is not None and not self.cfg.enc_dec:
            F = self.cfg.frontend_tokens
            batch["frontend_embeds"] = jnp.zeros(
                (B, F, self.cfg.d_model), jnp.float32)
        if self.cfg.enc_dec:
            batch["frontend_embeds"] = jnp.zeros(
                (B, max(8, S // 4), self.cfg.d_model), jnp.float32)
        return batch, S, lens

    def generate(self, prompts: List[np.ndarray], max_new_tokens: int = 8,
                 greedy: bool = True, seed: int = 0) -> GenerationResult:
        t0 = time.perf_counter()
        batch, S, lens = self._make_batch(prompts)
        B = batch["tokens"].shape[0]
        logits, cache = self._prefill(self.params, batch)
        logits.block_until_ready()
        t1 = time.perf_counter()
        cache = pad_cache(self.cfg, cache, max_new_tokens)
        F = 0
        if self.cfg.frontend is not None and not self.cfg.enc_dec:
            F = batch["frontend_embeds"].shape[1]
        pos = jnp.full((B,), F + S, jnp.int32)
        out = np.zeros((B, max_new_tokens), np.int32)
        rng = jax.random.PRNGKey(seed)
        tok = jnp.argmax(logits[:, -1, :], -1).astype(jnp.int32)
        for t in range(max_new_tokens):
            out[:, t] = np.asarray(tok)
            logits, cache = self._decode(
                self.params, cache, {"tokens": tok[:, None], "pos": pos})
            if greedy:
                tok = jnp.argmax(logits[:, -1, :], -1).astype(jnp.int32)
            else:
                rng, k = jax.random.split(rng)
                tok = jax.random.categorical(k, logits[:, -1, :]).astype(
                    jnp.int32)
            pos = pos + 1
        tok.block_until_ready()
        t2 = time.perf_counter()
        return GenerationResult(out[: len(prompts)],
                                (t1 - t0) * 1e3, (t2 - t1) * 1e3,
                                (t2 - t0) * 1e3)
