"""Real-JAX inference engines: round-based and continuous (iteration-level)
batching over jit-compiled prefill/decode with KV caches.

Two execution backends the BCEdge scheduler can drive when serving an
actual model (``repro.launch.engine_serve``):

* ``InferenceEngine`` — the paper's round semantics (§IV-D): the dynamic
  batcher forms a (b, m_c) round, the whole batch runs prefill + a fixed
  number of decode steps to completion, then the next round starts.
* ``ContinuousBatchingEngine`` — iteration-level scheduling
  (docs/ARCHITECTURE.md §5): a fixed set of KV-cache *slots* is decoded
  one token per step; finished sequences are evicted at iteration
  boundaries and queued prompts are prefilled into the freed slots, so
  short sequences never wait for the longest one in their batch.

Both keep the jit compile cache small via shape bucketing: prompts are
padded to power-of-two-ish buckets, and the continuous engine decodes a
single fixed (n_slots, cache_len) shape for its whole lifetime.
On CPU they serve the reduced configs; on a TPU pod the same code runs
the full configs under the production mesh.
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib
import time
from collections import OrderedDict
from typing import Callable, Dict, List, Optional, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import ModelConfig
from repro.launch.sharding import (engine_cache_shardings,
                                   engine_param_shardings, replicated)
from repro.models import build_model
from repro.models.transformer import (_split_layers, pad_cache,
                                      paged_layer_kind, scatter_blocks,
                                      scatter_blocks_stacked)


def _bucket(n: int, buckets=(1, 2, 4, 8, 16, 32, 64, 128)) -> int:
    for b in buckets:
        if n <= b:
            return b
    # clamping here would silently under-count S downstream (the cache-fit
    # check in ContinuousBatchingEngine.submit would pass for prompts that
    # do not fit), so over-length input is an error at the boundary
    raise ValueError(
        f"size {n} exceeds the largest bucket {buckets[-1]}")


SEQ_BUCKETS = (16, 32, 64, 128, 256, 512, 640)


def supports_prefix_cache(cfg: ModelConfig) -> bool:
    """Prefix caching shares physical KV *blocks*, so it needs every
    layer's decode state to live in the block pool: linear-attention KV
    only (``paged_layer_kind``). Recurrent states and windowed ring
    buffers are per-slot dense — a shared-prefix hit would also need the
    recurrent state at the block boundary, which the cache does not
    hold — and frontend/enc-dec models bypass the chunked path
    entirely."""
    if cfg.frontend is not None or cfg.enc_dec:
        return False
    return all(paged_layer_kind(cfg, k) for k in cfg.layer_kinds())

def supports_speculation(cfg: ModelConfig) -> bool:
    """Speculative decoding needs every layer's decode state to be
    REWINDABLE: rejected linear-attention KV rows are masked by ``pos``
    (dense) or freed back to the allocator at block granularity (paged),
    but a recurrent state advances irreversibly per token and a windowed
    ring buffer aliases rejected writes over live positions — neither
    can be rolled back. That is the same layer predicate prefix caching
    needs (all decode state in plain linear KV), so the gates coincide;
    frontend/enc-dec models additionally bypass the chunked forward the
    verify pass is built on."""
    return supports_prefix_cache(cfg)


def sample_tokens(logits, greedy: bool = True, seed: int = 0) -> np.ndarray:
    """Sample next tokens from ``logits`` (..., V) over the trailing
    vocabulary axis: argmax when ``greedy`` (the deterministic path every
    engine's token-identity guarantee rests on), else a seeded
    categorical draw. Accepts (V,), (B, V) or (B, W, V) — the single
    sampling site shared by round decode, admission, chunked-prefill
    completion, continuous decode and speculative verification. Returns
    an int32 ndarray shaped ``logits.shape[:-1]``."""
    if greedy:
        return np.asarray(jnp.argmax(logits, -1).astype(jnp.int32))
    key = jax.random.PRNGKey(seed)
    return np.asarray(
        jax.random.categorical(key, jnp.asarray(logits)).astype(jnp.int32))


#: largest chunked-prefill piece; pieces are powers of two up to this, so
#: the chunk compile cache is bounded at one shape per piece size
_MAX_CHUNK = 512


def make_prefill_batch(cfg: ModelConfig, prompts: List[np.ndarray]
                       ) -> Tuple[Dict, int, np.ndarray]:
    """Left-pad ``prompts`` into a bucketed (B, S) token batch.

    Shared by both engines so a prompt prefilled alone (continuous
    admission) sees exactly the shapes it would see inside a round batch —
    one compiled prefill per (B-bucket, S-bucket) pair.
    """
    B = _bucket(len(prompts))
    S = _bucket(max(len(p) for p in prompts), buckets=SEQ_BUCKETS)
    toks = np.zeros((B, S), np.int32)
    lens = np.zeros((B,), np.int32)
    for i, p in enumerate(prompts):
        toks[i, S - len(p):] = p  # left-pad (last position = last token)
        lens[i] = len(p)
    batch = {"tokens": jnp.asarray(toks)}
    if cfg.frontend is not None and not cfg.enc_dec:
        F = cfg.frontend_tokens
        batch["frontend_embeds"] = jnp.zeros(
            (B, F, cfg.d_model), jnp.float32)
    if cfg.enc_dec:
        batch["frontend_embeds"] = jnp.zeros(
            (B, max(8, S // 4), cfg.d_model), jnp.float32)
    return batch, S, lens


@dataclasses.dataclass
class GenerationResult:
    """Output of one round-mode ``generate`` call (paper §IV-D round)."""
    tokens: np.ndarray          # (B, new)
    prefill_ms: float
    decode_ms: float
    total_ms: float


class InferenceEngine:
    """Round-based (run-to-completion) execution backend (paper §IV-D).

    ``generate`` runs one (b,)-batch round: bucketed prefill, then
    ``max_new_tokens`` lock-step decode iterations for every request in
    the batch. This is the execution substrate the paper's (b, m_c)
    scheduler assumes; see ``ContinuousBatchingEngine`` for the
    iteration-level alternative.
    """

    def __init__(self, cfg: ModelConfig, max_seq: int = 512,
                 dtype=jnp.float32, seed: int = 0):
        self.cfg = cfg
        self.max_seq = max_seq
        self.model = build_model(cfg, remat=False)
        self.params = self.model.init(jax.random.PRNGKey(seed), dtype)
        self._prefill = jax.jit(self.model.prefill)
        self._decode = jax.jit(self.model.decode_step)

    def _make_batch(self, prompts: List[np.ndarray]
                    ) -> Tuple[Dict, int, np.ndarray]:
        return make_prefill_batch(self.cfg, prompts)

    def generate(self, prompts: List[np.ndarray], max_new_tokens: int = 8,
                 greedy: bool = True, seed: int = 0) -> GenerationResult:
        t0 = time.perf_counter()
        batch, S, lens = self._make_batch(prompts)
        B = batch["tokens"].shape[0]
        logits, cache = self._prefill(self.params, batch)
        logits.block_until_ready()
        t1 = time.perf_counter()
        cache = pad_cache(self.cfg, cache, max_new_tokens)
        F = 0
        if self.cfg.frontend is not None and not self.cfg.enc_dec:
            F = batch["frontend_embeds"].shape[1]
        pos = jnp.full((B,), F + S, jnp.int32)
        out = np.zeros((B, max_new_tokens), np.int32)
        rng = jax.random.PRNGKey(seed)
        tok = jnp.asarray(sample_tokens(logits[:, -1, :]))
        for t in range(max_new_tokens):
            out[:, t] = np.asarray(tok)
            logits, cache = self._decode(
                self.params, cache, {"tokens": tok[:, None], "pos": pos})
            if greedy:
                tok = jnp.asarray(sample_tokens(logits[:, -1, :]))
            else:
                rng, k = jax.random.split(rng)
                tok = jax.random.categorical(k, logits[:, -1, :]).astype(
                    jnp.int32)
            pos = pos + 1
        tok.block_until_ready()
        t2 = time.perf_counter()
        return GenerationResult(out[: len(prompts)],
                                (t1 - t0) * 1e3, (t2 - t1) * 1e3,
                                (t2 - t0) * 1e3)


# =====================================================================
# speculative proposers (docs/ARCHITECTURE.md §5)
# =====================================================================
class NGramProposer:
    """Self-speculative (prompt-lookup) drafting: find the most recent
    earlier occurrence of the context's trailing n-gram and propose the
    tokens that followed it, falling back to shorter n-grams and finally
    to repeating the last token. Pure host-side lookup — no extra model
    forward — so a wrong draft costs only the verify lane it rode in;
    the verification pass makes proposal quality a throughput knob,
    never a correctness one."""

    def __init__(self, n: int = 2):
        self.n = max(1, n)

    def propose(self, context: np.ndarray, k: int) -> np.ndarray:
        """``context`` (1-D int32, prompt + emitted + pending) -> (k,)
        draft tokens continuing it."""
        ctx = np.asarray(context, np.int32)
        L = len(ctx)
        cont = None
        for n in range(min(self.n, L - 1), 0, -1):
            tail = ctx[L - n:]
            for i in range(L - n - 1, -1, -1):
                if np.array_equal(ctx[i:i + n], tail):
                    cont = ctx[i + n:i + n + k]
                    break
            if cont is not None and len(cont):
                break
        if cont is None or len(cont) == 0:
            cont = ctx[L - 1:] if L else np.zeros(1, np.int32)
        reps = -(-k // len(cont))
        return np.tile(cont, reps)[:k].astype(np.int32)


class DraftModelProposer:
    """Draft-model proposal: a small model greedily decodes ``k`` tokens
    from the (tail of the) full context, re-prefilled per call.
    Stateless by design — keeping a draft KV cache consistent across
    preemption, prefix sharing and rollback would mirror the entire
    target engine's bookkeeping for a heuristic whose only job is
    guessing; re-prefilling a bounded context window keeps the proposer
    trivially correct under every schedule. Verification guarantees
    output identity regardless of what the draft proposes."""

    def __init__(self, cfg: ModelConfig, seed: int = 0,
                 context_window: int = 256):
        self.engine = InferenceEngine(cfg, max_seq=1024, seed=seed)
        self.context_window = context_window

    def propose(self, context: np.ndarray, k: int) -> np.ndarray:
        ctx = np.asarray(context, np.int32)[-self.context_window:]
        if len(ctx) == 0:
            return np.zeros(k, np.int32)
        res = self.engine.generate([ctx], max_new_tokens=k)
        return res.tokens[0].astype(np.int32)


# =====================================================================
# continuous (iteration-level) batching
# =====================================================================
class BlockAllocator:
    """Reference-counted free-list allocator over a paged KV block pool,
    with a hash-keyed cache of full immutable prefix blocks
    (docs/ARCHITECTURE.md §5).

    ``n_blocks`` usable blocks of ``block_size`` tokens; physical ids are
    1..n_blocks (id 0 is the null block inactive batch rows write into,
    never handed out). Admission *reserves* a sequence's worst-case block
    count up front, so the lazy per-decode-boundary ``alloc_reserved``
    can never fail mid-sequence; eviction decrements refcounts and
    cancels the unfilled remainder of the reservation.

    Prefix caching (docs/ARCHITECTURE.md §5): the engine ``register``s a
    full, immutable prompt block under its token-chain hash key;
    ``acquire`` maps that physical block into another sequence at
    refcount+1, so N same-prefix residents hold the prefix ONCE. A block
    whose refcount drops to zero returns to the free list when it is
    unregistered, or parks in an LRU pool when it is cached —
    evicted-but-cached blocks are reclaimed (oldest first, cache entry
    invalidated) when an allocation finds the free list empty.

    Invariants (asserted in tests/test_paged_kv.py and fuzzed in
    tests/test_engine_fuzz.py):
      * ``n_free + n_cached + n_live == n_blocks`` (the three id sets
        are disjoint — conservation);
      * ``n_free + n_cached - n_reserved == n_available >= 0``;
      * a block mapped by k sequences has refcount k (no block is owned
        by two slots without a refcount);
      * the null block 0 is never allocated;
      * LRU reclaim only ever takes refcount-0 blocks.

    ``free`` verifies ownership against the outstanding-id set and raises
    on a double free (more ``free``s than the refcount ever granted) or
    a duplicate id within one call — a silently re-freed id would hand
    the same physical block to two sequences.

    Host tier (``host_blocks > 0``, docs/ARCHITECTURE.md §5): a second
    id space 1..host_blocks of host-memory blocks the engine can swap
    KV into. Two populations share it, under one LRU discipline that
    spans both tiers:
      * *swapped* blocks (``_host_live``) — a preempted sequence's KV,
        owned by its ``PreemptedRequest`` snapshot until resume or
        cancel frees them (never reclaimed underneath the owner);
      * *spilled* blocks (``_host_lru`` / ``_host_cache``) — refcount-0
        prefix-cache blocks that would otherwise be invalidated by
        device-LRU reclaim; their cache entry moves to the host tier
        instead, and a later ``acquire`` revives them back to a device
        block (``unspill_fn`` copies the bytes). Spilled entries are
        reclaimable (oldest first) when the host tier itself fills.
    Host conservation mirrors the device invariant:
    ``n_host_free + n_host_cached + n_host_live == n_host_blocks``.
    The allocator is pure bookkeeping — the engine provides
    ``spill_fn(device_id, host_id)`` / ``unspill_fn(host_id, device_id)``
    hooks that move the actual bytes.
    """

    def __init__(self, n_blocks: int, block_size: int,
                 host_blocks: int = 0):
        if n_blocks < 1:
            raise ValueError("need at least one usable block")
        if host_blocks < 0:
            raise ValueError(f"host_blocks must be >= 0, got {host_blocks}")
        self.n_blocks = n_blocks
        self.block_size = block_size
        self._free = list(range(n_blocks, 0, -1))  # pop() -> low ids first
        self._outstanding: Set[int] = set()
        self._refcount: Dict[int, int] = {}
        #: prefix cache: chain-hash key -> block id, plus the reverse map
        #: and the LRU pool of refcount-0 cached (reclaimable) blocks
        self._cache: Dict[str, int] = {}
        self._block_key: Dict[int, str] = {}
        self._lru: "OrderedDict[int, None]" = OrderedDict()
        self.n_reserved = 0
        self.n_reclaimed = 0    # cached blocks evicted under pressure
        # ---- host tier ----
        self.n_host_blocks = host_blocks
        self._host_free = list(range(host_blocks, 0, -1))
        self._host_live: Set[int] = set()         # swapped sequence KV
        self._host_cache: Dict[str, int] = {}     # spilled prefix blocks
        self._host_key: Dict[int, str] = {}
        self._host_lru: "OrderedDict[int, None]" = OrderedDict()
        #: engine-provided byte movers; None = host tier is inert (the
        #: device LRU falls back to plain invalidation on reclaim)
        self.spill_fn: Optional[Callable[[int, int], None]] = None
        self.unspill_fn: Optional[Callable[[int, int], None]] = None
        self.n_spilled = 0      # device LRU entries demoted to host
        self.n_unspilled = 0    # host entries revived to device
        self.n_host_reclaimed = 0  # spilled entries evicted under pressure
        self.n_swapped_out = 0  # sequence blocks swapped device -> host
        self.n_swapped_in = 0   # sequence blocks swapped host -> device

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_cached(self) -> int:
        """Refcount-0 blocks parked in the prefix-cache LRU pool —
        reclaimable, so they count toward ``n_available``."""
        return len(self._lru)

    @property
    def n_live(self) -> int:
        """Distinct physical blocks with refcount >= 1 (shared blocks
        count ONCE — the quantity budget accounting charges)."""
        return len(self._outstanding)

    @property
    def n_available(self) -> int:
        """Blocks neither live nor promised to an admitted slot
        (evicted-but-cached LRU blocks are reclaimable, so they count)."""
        return len(self._free) + len(self._lru) - self.n_reserved

    # ---- host tier (docs/ARCHITECTURE.md §5) -----------------------------
    @property
    def n_host_free(self) -> int:
        return len(self._host_free)

    @property
    def n_host_cached(self) -> int:
        """Spilled prefix blocks parked in the host LRU — reclaimable."""
        return len(self._host_lru)

    @property
    def n_host_live(self) -> int:
        """Host blocks owned by swapped (preempted) sequences — pinned
        until their snapshot resumes or is cancelled."""
        return len(self._host_live)

    @property
    def n_host_available(self) -> int:
        """Host blocks a swap-out could claim right now: free plus
        reclaimable spilled entries (live swapped blocks are never
        reclaimed underneath their owner)."""
        return len(self._host_free) + len(self._host_lru)

    def _host_alloc(self) -> Optional[int]:
        """One host block: free list first, then reclaim the oldest
        spilled entry (its cache key is invalidated — the spanning LRU's
        final eviction). None when every host block is swap-pinned."""
        if self._host_free:
            return self._host_free.pop()
        if self._host_lru:
            hid, _ = self._host_lru.popitem(last=False)
            key = self._host_key.pop(hid)
            del self._host_cache[key]
            self.n_host_reclaimed += 1
            return hid
        return None

    def swap_out_alloc(self, n: int) -> Optional[List[int]]:
        """Claim ``n`` host blocks for a preempted sequence's KV (the
        swap-out side of ``preempt(mode="swap")``). All-or-nothing:
        None when fewer than ``n`` are available."""
        if self.n_host_available < n:
            return None
        ids = []
        for _ in range(n):
            hid = self._host_alloc()
            assert hid is not None
            self._host_live.add(hid)
            ids.append(hid)
        self.n_swapped_out += n
        return ids

    def host_free(self, ids: List[int]) -> None:
        """Release a swap snapshot's host blocks (resume landed, or the
        request was cancelled). Same double-free discipline as ``free``."""
        if len(set(ids)) != len(ids):
            raise ValueError(f"duplicate host block ids in host_free: {ids}")
        for i in ids:
            if i not in self._host_live:
                raise ValueError(
                    f"host_free of block {i}: not currently swapped out")
        for i in ids:
            self._host_live.discard(i)
            self._host_free.append(i)

    def blocks_for(self, n_tokens: int) -> int:
        return -(-max(0, n_tokens) // self.block_size)

    def refcount(self, bid: int) -> int:
        return self._refcount.get(bid, 0)

    def reserve(self, n: int) -> bool:
        """Promise ``n`` blocks to a sequence; False when they are not
        available (the caller keeps the request queued)."""
        if self.n_available < n:
            return False
        self.n_reserved += n
        return True

    def unreserve(self, n: int) -> None:
        assert 0 <= n <= self.n_reserved
        self.n_reserved -= n

    def _reclaim_lru(self) -> int:
        """Evict the least-recently-parked cached block. With a host
        tier attached (``spill_fn`` set) the cache entry is demoted to a
        host block instead of invalidated — the device LRU spills into
        the host LRU, one eviction chain spanning both tiers; without
        one (or when the host tier is swap-pinned full) the entry is
        invalidated and the id behaves like a fresh free block."""
        bid, _ = self._lru.popitem(last=False)
        key = self._block_key.pop(bid)
        del self._cache[key]
        self.n_reclaimed += 1
        if self.spill_fn is not None:
            hid = self._host_alloc()
            if hid is not None:
                self.spill_fn(bid, hid)
                self._host_cache[key] = hid
                self._host_key[hid] = key
                self._host_lru[hid] = None
                self.n_spilled += 1
        return bid

    def alloc_reserved(self) -> int:
        """Convert one previously reserved block into a physical id,
        reclaiming from the cached-LRU pool when the free list is empty
        (never a block with live references — the LRU holds refcount-0
        blocks only)."""
        assert self.n_reserved > 0, "alloc without reservation"
        self.n_reserved -= 1
        bid = self._free.pop() if self._free else self._reclaim_lru()
        self._outstanding.add(bid)
        self._refcount[bid] = 1
        return bid

    def free(self, ids: List[int]) -> None:
        """Drop one reference per id. A block reaching refcount 0 returns
        to the free list — or parks in the cached-LRU pool when it is
        registered in the prefix cache, so a future same-prefix admission
        can revive it. Raises ``ValueError`` on an out-of-range id, a
        duplicate within ``ids``, or a double free (an id with no live
        references left) — any of which would corrupt the free list and
        alias one physical block to two sequences."""
        if len(set(ids)) != len(ids):
            raise ValueError(f"duplicate block ids in free(): {ids}")
        for i in ids:
            if not 0 < i <= self.n_blocks:
                raise ValueError(
                    f"block id {i} outside 1..{self.n_blocks}")
            if i not in self._outstanding:
                raise ValueError(
                    f"double free of block {i}: not currently allocated")
        for i in ids:
            self._refcount[i] -= 1
            if self._refcount[i] > 0:
                continue  # still referenced by another sequence
            del self._refcount[i]
            self._outstanding.discard(i)
            if i in self._block_key:
                self._lru[i] = None  # evicted but cached (reclaimable)
            else:
                self._free.append(i)

    # ---- prefix cache (docs/ARCHITECTURE.md §5) --------------------------
    def cached(self, key: str) -> bool:
        """True when either tier holds ``key`` — a spilled host entry is
        still a hit (``acquire`` revives it to a device block)."""
        return key in self._cache or key in self._host_cache

    def cached_live(self, key: str) -> bool:
        """True when ``key``'s block is currently mapped by a live
        sequence — sharing it costs no extra capacity."""
        bid = self._cache.get(key)
        return bid is not None and bid in self._outstanding

    def register(self, key: str, bid: int) -> None:
        """Publish a full immutable block under its chain-hash key. The
        block must be live (its writer still owns it); first writer wins
        on a key collision, and a block only ever carries one key (its
        content determines the whole chain)."""
        assert bid in self._outstanding, f"register of non-live block {bid}"
        if key in self._cache or bid in self._block_key:
            return
        if key in self._host_cache:
            # a live device copy supersedes the spilled one: drop the
            # host entry so every key names exactly one physical block
            hid = self._host_cache.pop(key)
            del self._host_key[hid]
            self._host_lru.pop(hid, None)
            self._host_free.append(hid)
        self._cache[key] = bid
        self._block_key[bid] = key

    def acquire(self, key: str) -> Optional[int]:
        """Map the cached block for ``key`` into another sequence:
        refcount+1 for a live block (costs nothing), revival for an
        LRU-parked one (consumes one available block — refused when
        every remaining block is already promised to a reservation).
        Returns the block id, or None on a miss. A key whose block was
        spilled to the host tier is revived: a fresh device block is
        claimed (free list, else device-LRU reclaim), ``unspill_fn``
        copies the bytes back, and the cache entry moves home — also
        refused when every available block is promised."""
        bid = self._cache.get(key)
        if bid is not None:
            if bid in self._outstanding:
                self._refcount[bid] += 1
                return bid
            # revive from the LRU pool; guard the reservation promise
            if self.n_available < 1:
                return None
            del self._lru[bid]
            self._outstanding.add(bid)
            self._refcount[bid] = 1
            return bid
        hid = self._host_cache.get(key)
        if hid is None or self.unspill_fn is None:
            return None
        if self.n_available < 1:
            return None
        # detach the host entry FIRST: claiming the device block below
        # can itself reclaim-and-spill, and must not be able to evict
        # the very entry being revived
        del self._host_cache[key]
        del self._host_key[hid]
        del self._host_lru[hid]
        bid = self._free.pop() if self._free else self._reclaim_lru()
        self.unspill_fn(hid, bid)
        self._host_free.append(hid)
        self._outstanding.add(bid)
        self._refcount[bid] = 1
        self._cache[key] = bid
        self._block_key[bid] = key
        self.n_unspilled += 1
        return bid


@dataclasses.dataclass
class _Slot:
    """One KV-cache slot: the sequence prefilling or decoding in batch
    row i. The chunked-prefill state machine lives here: an admitted
    sequence starts PREFILLING (``prefill_pos < len(seq_tokens)``),
    advances by budget-bounded chunks into its ``staging`` cache, and
    becomes DECODING once the graft lands (docs/ARCHITECTURE.md §5)."""
    request_id: int = -1
    remaining: int = 0          # tokens still to emit
    n_emitted: int = 0
    tokens: List[int] = dataclasses.field(default_factory=list)
    submit_s: float = 0.0
    admit_s: float = 0.0
    # paged layout only: physical blocks owned, and how many of the
    # admission reservation remain unallocated (alloc-on-decode-boundary)
    blocks: List[int] = dataclasses.field(default_factory=list)
    n_outstanding: int = 0
    #: leading blocks of ``blocks`` mapped from the prefix cache at
    #: refcount+1 — immutable; graft/decode writes start past them
    n_shared: int = 0
    # chunked prefill state machine
    seq_tokens: Optional[np.ndarray] = None  # padded prompt (+ resume ctx)
    base_len: int = 0           # padded-prompt length at FIRST admission
    prefill_pos: int = 0        # tokens of seq_tokens processed so far
    staging: object = None      # single-seq cache chunks accumulate into
    # accounting satellites
    requested_new: int = 0      # caller-requested max_new (pre-clamp)
    truncated: bool = False
    n_preempted: int = 0
    #: engine-clock time the FIRST token landed (carried across
    #: preemption/resume so TTFT reflects the original first token;
    #: -1 before any token)
    first_token_s: float = -1.0
    # speculative decoding: drafts proposed / accepted for this sequence
    # since (re-)admission — preemption recomputes, so these reset with
    # the slot; the engine-level counters stay monotonic
    n_spec_proposed: int = 0
    n_spec_accepted: int = 0

    @property
    def active(self) -> bool:
        return self.request_id >= 0

    @property
    def prefilling(self) -> bool:
        return self.active and self.seq_tokens is not None \
            and self.prefill_pos < len(self.seq_tokens)


@dataclasses.dataclass
class PreemptedRequest:
    """Resumable snapshot of a preempted sequence (docs/RUNTIME.md §8).

    Two flavours, distinguished by ``host_blocks``:

    * **recompute** (``host_blocks is None``): ``seq_tokens`` holds the
      padded prompt plus every token emitted so far, re-prefilled in
      chunks on resume — greedy output is token-identical to an
      uninterrupted run.
    * **swap** (``preempt(mode="swap")``): the sequence's KV blocks were
      copied to the allocator's host tier instead of discarded.
      ``seq_tokens`` stays the original padded prompt; the emitted
      tokens, decode position and pending token are carried verbatim so
      resume re-maps the blocks onto fresh device ids and continues
      decoding with NO recompute. The snapshot owns its host blocks
      until resume or cancel, and is pinned to the engine whose host
      pool holds them (``host_engine_id``) — ``release_swap`` converts
      it back to a recompute snapshot when that engine goes away.
    """
    request_id: int
    seq_tokens: np.ndarray      # padded prompt (+ emitted, recompute only)
    base_len: int               # emitted tokens = seq_tokens[base_len:]
    max_new: int                # tokens still to emit
    submit_s: float
    requested_new: int
    truncated: bool
    n_preempted: int
    first_token_s: float = -1.0
    # ---- swap-mode state (None/unused for recompute snapshots) ----
    tokens: Optional[List[int]] = None   # emitted tokens (swap carries
    #                                      them outside seq_tokens)
    pos: int = -1                        # decode frontier at preemption
    pending_tok: int = 0                 # sampled-but-unwritten token
    host_blocks: Optional[List[int]] = None
    host_engine_id: int = 0              # id() of the owning engine

    @property
    def swapped(self) -> bool:
        return self.host_blocks is not None


def to_recompute(req: PreemptedRequest) -> PreemptedRequest:
    """Rebuild a swap snapshot as a recompute snapshot WITHOUT touching
    any allocator — for callers whose owning engine is already retired
    (its host pool, blocks included, died with it). Token identity
    holds: the recompute context is the padded prompt plus the emitted
    tokens, and greedy re-prefill regenerates the dropped pending token
    deterministically. Prefer ``engine.release_swap`` while the engine
    is alive — it returns the host blocks properly."""
    if not req.swapped:
        return req
    seq = np.concatenate([req.seq_tokens,
                          np.asarray(req.tokens, np.int32)])
    return PreemptedRequest(
        req.request_id, seq, base_len=req.base_len, max_new=req.max_new,
        submit_s=req.submit_s, requested_new=req.requested_new,
        truncated=req.truncated, n_preempted=req.n_preempted,
        first_token_s=req.first_token_s)


@dataclasses.dataclass
class _WaitingReq:
    """One queued admission: a fresh prompt, (``prepadded``) a preempted
    sequence whose bucket padding is already baked in, or (``swap``) a
    swap-mode snapshot whose KV waits in the host tier — admitted
    straight to DECODE, no prefill."""
    request_id: int
    prompt: np.ndarray
    max_new: int
    submit_s: float
    prepadded: bool = False
    base_len: int = -1          # resumes only
    requested_new: int = 0
    truncated: bool = False
    n_preempted: int = 0
    first_token_s: float = -1.0
    swap: Optional[PreemptedRequest] = None


@dataclasses.dataclass
class ContinuousResult:
    """One finished sequence from the continuous engine
    (docs/ARCHITECTURE.md §5 accounting: per-request, not per-round)."""
    request_id: int
    tokens: np.ndarray          # (n_emitted,)
    submit_s: float             # perf_counter timestamps (engine clock)
    admit_s: float
    finish_s: float
    n_iters: int                # decode iterations this sequence was live
    #: fewer tokens than requested were emitted (submit-time cache-room
    #: clamp, or the capacity clip at cache_len) — surfaced so callers
    #: never mistake a truncated completion for a full one
    truncated: bool = False
    #: times this sequence was preempted and recomputed
    n_preempted: int = 0
    #: speculative drafts proposed / accepted while this sequence was
    #: resident (since the last re-admission, if it was preempted)
    n_spec_proposed: int = 0
    n_spec_accepted: int = 0
    #: engine-clock time the first token landed (preemption-safe: the
    #: ORIGINAL first token, not the post-resume one; -1 if none landed)
    first_token_s: float = -1.0
    #: the request was cancelled (client disconnect / explicit cancel):
    #: ``tokens`` holds the partial completion emitted before the cancel
    cancelled: bool = False

    @property
    def queue_wait_s(self) -> float:
        return self.admit_s - self.submit_s

    @property
    def ttft_s(self) -> float:
        """Submit -> first token on the engine clock (-1 if no token)."""
        return self.first_token_s - self.submit_s \
            if self.first_token_s >= 0 else -1.0

    @property
    def tpot_s(self) -> float:
        """Mean seconds per token after the first (-1 below 2 tokens)."""
        if self.first_token_s < 0 or len(self.tokens) < 2:
            return -1.0
        return (self.finish_s - self.first_token_s) \
            / (len(self.tokens) - 1)


class ContinuousBatchingEngine:
    """Iteration-level batching backend (docs/ARCHITECTURE.md §5; the
    SLICE/Orca-style execution mode the simulator's
    ``exec_mode="continuous"`` models analytically).

    A fixed number of KV-cache slots is allocated once at
    ``(n_slots, cache_len)``; every ``step()`` runs ONE jit-compiled
    decode iteration over all slots (a single compiled shape for the
    engine's lifetime). At iteration boundaries finished sequences are
    evicted — their slot is freed immediately — and queued prompts are
    prefilled (one compile per prompt-length bucket) and grafted into
    free slots. Admission cost is one host-side cache scatter per
    request, which is fine at the reduced-config scale this repo serves;
    a production engine would fuse the graft into the prefill kernel.

    ``kv_layout="paged"`` replaces the dense per-slot cache for linear
    attention layers with a block pool + ``BlockAllocator``: a slot only
    occupies the blocks its sequence actually needs (prompt bucket +
    requested decode tokens) instead of a full ``cache_len`` row, so the
    same token budget holds materially more concurrent sequences.
    Admission is gated on free blocks, blocks are physically allocated
    when decode crosses a block boundary, and eviction returns them to
    the free list. Greedy outputs are token-identical to the dense
    layout (asserted in tests/test_paged_kv.py).
    """

    def __init__(self, cfg: ModelConfig, max_slots: int = 4,
                 max_seq: int = 256, dtype=jnp.float32, seed: int = 0,
                 share_from: "ContinuousBatchingEngine" = None,
                 kv_layout: str = "dense", block_size: int = 16,
                 kv_blocks: int = None, kv_host_blocks: int = 0,
                 token_budget: Optional[int] = None,
                 prefix_cache: bool = False,
                 spec_k: int = 0, spec_ngram: int = 2,
                 proposer=None, prefill_mode: str = "auto",
                 mesh=None):
        if cfg.enc_dec:
            # cross-attention K/V is unmasked (_cross_core attends every
            # encoder row), so grafting a shorter prefilled ck/cv into the
            # slot cache would attend zero-padded garbage rows
            raise NotImplementedError(
                "continuous batching does not support encoder-decoder "
                "architectures yet; use InferenceEngine")
        if kv_layout not in ("dense", "paged"):
            raise ValueError(f"unknown kv_layout {kv_layout!r}")
        if token_budget is not None and token_budget < 1:
            raise ValueError(f"token_budget must be >= 1, got {token_budget}")
        self.cfg = cfg
        self.n_slots = max(1, max_slots)
        self.cache_len = max_seq
        self.kv_layout = kv_layout
        self.dtype = dtype
        #: per-iteration cap on prefill-chunk + resident-decode tokens
        #: (docs/ARCHITECTURE.md §5; None = uncapped, prompts prefill in
        #: one pass of bucket-sized chunks). Mutable between steps — the
        #: PoolScheduler co-optimises it with (b, m_c).
        self.token_budget = token_budget
        #: chunked prefill needs plain token prompts; frontend models
        #: keep the single-shot prefill admission path (and therefore
        #: do not support preemption-resume)
        self.chunked = cfg.frontend is None and not cfg.enc_dec
        if prefix_cache:
            if kv_layout != "paged":
                raise ValueError(
                    "prefix_cache needs kv_layout='paged' (sharing is "
                    "block-granular)")
            if not supports_prefix_cache(cfg):
                raise ValueError(
                    f"{cfg.name}: prefix_cache needs every layer's decode "
                    "state in the block pool (linear attention only); "
                    "recurrent/windowed/frontend layers keep per-slot "
                    "dense state the cache cannot share")
        self.prefix_cache = prefix_cache
        #: chunked-prefill execution mode. "fused" runs every prefill
        #: chunk DIRECTLY against the paged pool through the slot's
        #: block table (repro.models.attention.attention_chunk_paged):
        #: no per-slot staging cache, no prefix gather, no completion
        #: graft scatter. "auto" picks fused whenever the layout
        #: supports it: paged + chunked + every layer's decode state in
        #: the block pool (the same gate as the prefix cache — a dense
        #: per-slot leaf cannot take a batch-1 chunk against the shared
        #: pool pytree). Layouts that fail the gate (dense, hybrid
        #: stacks) keep the staging-cache round trip: chunk into a
        #: per-slot staging cache, scatter-graft on completion. The
        #: legacy "staging" override for paged all-linear stacks is
        #: gone — fused is the only paged prefill path.
        if prefill_mode not in ("auto", "fused"):
            raise ValueError(f"unknown prefill_mode {prefill_mode!r}")
        fused_ok = (kv_layout == "paged" and self.chunked
                    and supports_prefix_cache(cfg))
        if prefill_mode == "fused" and not fused_ok:
            raise ValueError(
                "prefill_mode='fused' needs kv_layout='paged', the "
                "chunked-prefill path, and every layer's decode state "
                "in the block pool")
        self.fused_prefill = fused_ok if prefill_mode == "auto" \
            else prefill_mode == "fused"
        if spec_k < 0:
            raise ValueError(f"spec_k must be >= 0, got {spec_k}")
        if spec_k > 0 and not supports_speculation(cfg):
            raise ValueError(
                f"{cfg.name}: speculative decoding needs every layer's "
                "decode state rewindable (linear attention only); "
                "recurrent states advance irreversibly and windowed ring "
                "buffers alias rejected writes over live positions")
        #: max speculation depth this engine compiled for (fixed: the
        #: dense scratch margin and the verify block-table padding depend
        #: on it); ``spec_k`` below is the CURRENT depth, mutable between
        #: steps — the PoolScheduler's fourth action axis — and clamped
        #: to ``spec_max`` at use
        self.spec_max = spec_k
        self.spec_k = spec_k
        self.proposer = proposer if proposer is not None \
            else NGramProposer(spec_ngram)
        self.n_spec_proposed = 0
        self.n_spec_accepted = 0
        self.n_spec_steps = 0
        self._spec_shapes: Set[int] = set()
        #: prefix-cache accounting (tokens; rate = hit / (hit + chunked))
        self.n_prefix_lookups = 0
        self.n_prefix_hits = 0
        self.n_prefix_hit_tokens = 0
        self.n_prefill_chunk_tokens = 0
        #: tensor parallelism (docs/ARCHITECTURE.md §11): a 1D
        #: ``("model",)`` mesh (launch/mesh.make_tp_mesh) this instance
        #: spans. Params are placed under the launch TP rules, the KV
        #: cache — dense slabs and the paged block pool alike — is
        #: HEAD-sharded, and the step functions are jitted with
        #: NamedSharding in/out specs. Block tables, the allocator and
        #: every slot/queue structure stay host-side (replicated): the
        #: scheduler's view of the engine is layout-independent.
        self.mesh = mesh
        if mesh is not None:
            if "model" not in mesh.axis_names:
                raise ValueError(
                    f"engine mesh needs a 'model' axis, got "
                    f"{mesh.axis_names}")
            if not self.chunked:
                raise NotImplementedError(
                    "tensor-parallel serving needs the chunked-prefill "
                    "path (frontend engines stay single-device)")
            tp = int(mesh.shape["model"])
            if cfg.n_kv_heads % tp or cfg.n_heads % tp:
                raise ValueError(
                    f"{cfg.name}: tp_degree {tp} must divide n_heads "
                    f"{cfg.n_heads} and n_kv_heads {cfg.n_kv_heads} "
                    "(the KV pool is head-sharded over the model axis)")
        if share_from is not None and share_from.cfg == cfg:
            # co-resident instances of the same model share weights and
            # jit caches (docs/RUNTIME.md: spawn must be cheap for the
            # pool's scale_to to be a usable action); the KV slot cache
            # below stays per-instance. Sharing requires the SAME mesh:
            # the donor's params live in that layout and its jits carry
            # its in/out shardings, so the pool keys templates by
            # (model, tp_degree).
            if getattr(share_from, "mesh", None) != mesh:
                raise ValueError(
                    "share_from donor spans a different mesh; instances "
                    "share weights/jit only at the same TP degree")
            self.model = share_from.model
            self.params = share_from.params
            self._prefill = share_from._prefill
            self._prefill_chunk = share_from._prefill_chunk
            self._decode = share_from._decode
            self._verify = getattr(share_from, "_verify", None)
            if self._verify is None and supports_speculation(cfg):
                self._verify = jax.jit(self.model.verify_step)
        else:
            self.model = build_model(cfg, remat=False)
            self.params = self.model.init(jax.random.PRNGKey(seed), dtype)
            if mesh is not None:
                self.params = jax.device_put(
                    self.params, engine_param_shardings(mesh, self.params))
            self._prefill = jax.jit(self.model.prefill)
            if mesh is None:
                self._prefill_chunk = jax.jit(self.model.prefill_chunk) \
                    if self.chunked else None
                self._decode = jax.jit(self.model.decode_step)
                self._verify = jax.jit(self.model.verify_step) \
                    if supports_speculation(cfg) else None
            else:
                # sharded step jits need the cache pytree for their
                # in/out specs — created after the cache init below
                self._prefill_chunk = None
                self._decode = None
                self._verify = None
        if kv_host_blocks < 0:
            raise ValueError(
                f"kv_host_blocks must be >= 0, got {kv_host_blocks}")
        if kv_host_blocks > 0:
            if kv_layout != "paged":
                raise ValueError(
                    "kv_host_blocks needs kv_layout='paged' (the host "
                    "tier swaps block-granular KV)")
            if mesh is not None:
                raise ValueError(
                    "the host KV tier is single-device for now: swap-in "
                    "writes outside jit would drop the pool's sharding")
        self.kv_host_blocks = kv_host_blocks
        #: host-tier stack gate: swapping a sequence (or spilling a
        #: prefix block) moves ONLY block-pool state, so every layer's
        #: decode state must live there — the same all-linear predicate
        #: prefix caching needs. Hybrid stacks keep recompute-on-resume.
        self.swap_ok = kv_host_blocks > 0 and supports_prefix_cache(cfg)
        if kv_layout == "paged":
            self.block_size = block_size
            self.blocks_per_slot = -(-self.cache_len // block_size)
            if kv_blocks is None:
                # dense-equivalent worst case: admission can never refuse
                # a request the dense layout would have taken
                kv_blocks = self.n_slots * self.blocks_per_slot
            self.allocator = BlockAllocator(kv_blocks, block_size,
                                            host_blocks=kv_host_blocks)
            # pool array includes the null block 0 (id range 0..kv_blocks)
            self.cache = self.model.init_paged_cache(
                self.n_slots, self.cache_len, kv_blocks + 1, block_size,
                dtype)
            self.block_tables = np.zeros(
                (self.n_slots, self.blocks_per_slot), np.int32)
            if self.swap_ok:
                self.host_pool = self._make_host_pool()
                self.allocator.spill_fn = self._spill_block
                self.allocator.unspill_fn = self._unspill_block
            else:
                self.host_pool = None
        else:
            self.block_size = 0
            self.allocator = None
            self.block_tables = None
            self.host_pool = None
            # speculative verify writes up to spec_max rows past a slot's
            # frontier before acceptance is known; dynamic_update_slice
            # CLAMPS out-of-bounds starts (it would silently overwrite
            # valid earlier rows), so the physical slab carries a scratch
            # margin. cache_len stays the LOGICAL capacity everywhere.
            self.cache = self.model.init_cache(
                self.n_slots, self.cache_len + self.spec_max, dtype)
        if mesh is not None:
            # place the cache across the mesh (heads sharded, block axis
            # whole: tables gather it locally on every shard), then jit
            # the step functions with explicit NamedSharding in/out
            # specs. The batch dict — tokens, pos, block tables — is
            # replicated: every shard sees the same schedule. The same
            # cache shardings tree serves the per-slot staging caches
            # non-fused layouts chunk into (same pytree structure, and
            # specs never shard the batch/length dims that differ).
            cshard = engine_cache_shardings(mesh, self.cache)
            self.cache = jax.device_put(self.cache, cshard)
            if self._decode is None:
                pshard = engine_param_shardings(mesh, self.params)
                rep = replicated(mesh)
                self._prefill_chunk = jax.jit(
                    self.model.prefill_chunk,
                    in_shardings=(pshard, cshard, rep),
                    out_shardings=(rep, cshard)) if self.chunked else None
                self._decode = jax.jit(
                    self.model.decode_step,
                    in_shardings=(pshard, cshard, rep),
                    out_shardings=(rep, cshard))
                self._verify = jax.jit(
                    self.model.verify_step,
                    in_shardings=(pshard, cshard, rep),
                    out_shardings=(rep, cshard)) \
                    if supports_speculation(cfg) else None
        self.pos = np.zeros((self.n_slots,), np.int32)
        self.pending_tok = np.zeros((self.n_slots,), np.int32)
        self.slots = [_Slot() for _ in range(self.n_slots)]
        self.waiting: List[_WaitingReq] = []
        self.n_iters = 0
        self.n_admitted = 0
        self.n_evicted = 0
        self.n_preempted = 0
        self.n_cancelled = 0
        #: host-tier accounting (docs/ARCHITECTURE.md §5): swap-mode
        #: preempts/resumes, and observed transfers as (bytes, ms)
        #: samples — the pool's swap-bandwidth calibration reads these
        #: (latency_model.fit_swap_cost)
        self.n_swap_preempts = 0
        self.n_swap_resumes = 0
        self.swap_samples: List[Tuple[int, float]] = []
        #: push-mode lifecycle hooks (docs/RUNTIME.md §11). Both fire
        #: synchronously inside engine calls, so handlers must be cheap
        #: and must not reenter the engine.
        #: ``on_token(request_id, token, index)`` — per emitted token;
        #: ``index`` is the global completion position, stable across
        #: preemption/resume (re-prefilled context tokens never refire).
        self.on_token: Optional[Callable] = None
        #: ``on_state(request_id, state)`` with state in
        #: {"prefill", "decode"} — slot assignment and prefill completion
        self.on_state: Optional[Callable] = None
        #: tokens processed by the last step() (prefill chunks + resident
        #: decode) and whether it compiled a new shape — the pool's
        #: token-cost calibration reads both (docs/RUNTIME.md §8)
        self.last_step_tokens = 0
        self.last_step_compiled = False
        self._decode_warm = False
        self.prefill_shapes: Set[Tuple[int, int]] = set()
        self._next_id = 0
        self._t0 = time.perf_counter()

    # ---- bookkeeping -----------------------------------------------------
    def _now(self) -> float:
        return time.perf_counter() - self._t0

    def _note_tokens(self, s: _Slot, n_new: int) -> None:
        """Stamp ``first_token_s`` and fire ``on_token`` for the last
        ``n_new`` entries of ``s.tokens``. Indices are global completion
        positions: tokens emitted before a preemption live in the
        re-prefilled context (``seq_tokens[base_len:]``) and offset the
        post-resume ones, so a streaming consumer sees every position
        exactly once."""
        if s.first_token_s < 0:
            s.first_token_s = self._now()
        if self.on_token is not None:
            prior = len(s.seq_tokens) - s.base_len \
                if s.seq_tokens is not None else 0
            base = prior + len(s.tokens) - n_new
            for j in range(n_new):
                self.on_token(s.request_id,
                              int(s.tokens[len(s.tokens) - n_new + j]),
                              base + j)

    @property
    def tp_degree(self) -> int:
        """Devices this instance spans (1 = single-device engine)."""
        return int(self.mesh.shape["model"]) if self.mesh is not None else 1

    @property
    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if not s.active]

    @property
    def active_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s.active]

    @property
    def decoding_slots(self) -> List[int]:
        """Active slots whose prefill has completed (the rows a decode
        iteration advances)."""
        return [i for i, s in enumerate(self.slots)
                if s.active and not s.prefilling]

    @property
    def prefilling_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s.prefilling]

    @property
    def prefill_backlog_tokens(self) -> int:
        """Prompt tokens not yet prefilled: the unprocessed remainder of
        in-slot chunked prefills plus the padded length of every waiting
        prompt — a state feature for the scheduler (docs/RUNTIME.md §8)."""
        backlog = sum(len(s.seq_tokens) - s.prefill_pos
                      for s in self.slots if s.prefilling)
        for w in self.waiting:
            if w.swap is not None:
                continue  # swap resumes re-map blocks, zero prefill
            backlog += len(w.prompt) if w.prepadded else \
                self._frontend_tokens() + _bucket(len(w.prompt),
                                                  buckets=SEQ_BUCKETS)
        return backlog

    def _frontend_tokens(self) -> int:
        return self.cfg.frontend_tokens if (self.cfg.frontend is not None
                                            and not self.cfg.enc_dec) else 0

    def _seq_tokens(self, prompt_len: int, max_new: int) -> int:
        """Cache positions a sequence occupies: frontend + bucketed
        prompt + decode tokens (left-pad rows included — they are
        attended, so the paged layout must hold them too)."""
        return self._frontend_tokens() \
            + _bucket(prompt_len, buckets=SEQ_BUCKETS) + max_new

    def request_blocks(self, prompt_len: int, max_new: int) -> int:
        """Worst-case blocks a request of this shape reserves at
        admission (paged layout)."""
        room = self.cache_len - self._seq_tokens(prompt_len, 0)
        return self.allocator.blocks_for(
            self._seq_tokens(prompt_len, min(max_new, room)))

    def resume_blocks(self, req: PreemptedRequest) -> int:
        """Worst-case blocks a preempted sequence reserves on resume:
        its already-padded context plus the tokens still to emit. For a
        swap snapshot that is frontier + remaining — numerically the
        same footprint (``pos + max_new`` is invariant along a decode),
        just derived from the carried position."""
        if req.swapped:
            return self.allocator.blocks_for(req.pos + req.max_new)
        return self.allocator.blocks_for(
            len(req.seq_tokens) + req.max_new)

    def admissible(self, prompt_len: int, max_new: int,
                   pending_blocks: int = 0,
                   resume: Optional[PreemptedRequest] = None,
                   prompt: Optional[np.ndarray] = None) -> bool:
        """Could a request of this shape be admitted right now? Dense:
        a free slot. Paged: a free slot AND enough unreserved blocks
        (the real memory constraint, docs/ARCHITECTURE.md §5).
        ``pending_blocks`` debits blocks a caller has already promised
        to earlier requests it routed this pass but that the engine has
        not reserved yet (reservation happens inside ``admit``). With
        ``resume`` the block need is the preempted sequence's padded
        context instead of the fresh-prompt shape. When the actual
        ``prompt`` tokens are given and the prefix cache is on, blocks
        the cache holds LIVE are discounted — sharing them costs no
        capacity, which is exactly the admission headroom prefix caching
        buys."""
        if not self.free_slots:
            return False
        if self.kv_layout != "paged":
            return True
        if resume is not None:
            need = self.resume_blocks(resume)
            # swap resumes never map shared prefix blocks (their KV
            # comes back from the host tier wholesale), so the sharing
            # discount applies to recompute snapshots only
            if self.prefix_cache and not resume.swapped:
                need -= self._live_shared_blocks_prepadded(
                    resume.seq_tokens)
        else:
            need = self.request_blocks(prompt_len, max_new)
            if self.prefix_cache and prompt is not None:
                need -= self._live_shared_blocks(prompt)
        return self.allocator.n_available - pending_blocks >= max(0, need)

    # ---- admission -------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 8) -> int:
        """Queue a prompt; it joins a slot at the next iteration boundary.

        Raises only when the prompt can never fit a sequence's
        ``cache_len`` budget. Transient pressure (no free slot, or — in
        the paged layout — no free blocks) just keeps it queued; the
        paged admission gate is the allocator's free-block count, not
        dense ``cache_len`` headroom. A ``max_new_tokens`` that exceeds
        the remaining cache room is clamped, and the clamp is RECORDED:
        the finished ``ContinuousResult`` carries ``truncated=True`` so
        callers never mistake a shortened completion for a full one."""
        S = _bucket(len(prompt), buckets=SEQ_BUCKETS)
        F = self._frontend_tokens()
        room = self.cache_len - (F + S)
        if room < 1:
            raise ValueError(
                f"prompt bucket {S} (+{F} frontend) does not fit cache_len "
                f"{self.cache_len}")
        if self.kv_layout == "paged":
            # a reservation that exceeds the whole pool could never be
            # admitted — queuing it would livelock the FIFO head forever
            # (same boundary rule as the over-length bucket check above)
            need = self.allocator.blocks_for(
                F + S + min(max_new_tokens, room))
            if need > self.allocator.n_blocks:
                raise ValueError(
                    f"request needs {need} blocks, pool has only "
                    f"{self.allocator.n_blocks}")
        rid = self._next_id
        self._next_id += 1
        granted = min(max_new_tokens, room)
        self.waiting.append(_WaitingReq(
            rid, np.asarray(prompt, np.int32), granted, self._now(),
            requested_new=max_new_tokens,
            truncated=granted < max_new_tokens))
        return rid

    def submit_resume(self, req: PreemptedRequest) -> int:
        """Re-queue a preempted sequence (possibly from another engine
        instance of the same model). A fresh engine request id is
        allocated — the caller correlates via its own bookkeeping; the
        engine-internal ``preempt(requeue=True)`` path keeps the original
        id instead. The padded context always fits ``cache_len`` because
        ``len(seq_tokens) + max_new`` equals the original admitted
        footprint."""
        if not self.chunked:
            raise NotImplementedError(
                "preemption-resume needs the chunked-prefill path "
                "(plain token prompts)")
        if req.swapped and req.host_engine_id != id(self):
            raise ValueError(
                "swap snapshot is pinned to the engine holding its host "
                "blocks; release_swap() it there to resume elsewhere")
        rid = self._next_id
        self._next_id += 1
        self.waiting.append(_WaitingReq(
            rid, np.asarray(req.seq_tokens, np.int32), req.max_new,
            req.submit_s, prepadded=True, base_len=req.base_len,
            requested_new=req.requested_new, truncated=req.truncated,
            n_preempted=req.n_preempted,
            first_token_s=req.first_token_s,
            swap=req if req.swapped else None))
        return rid

    # ---- prefix cache (docs/ARCHITECTURE.md §5) --------------------------
    @staticmethod
    @functools.lru_cache(maxsize=4096)
    def _chain_keys_cached(model: str, block_size: int,
                           seq_bytes: bytes) -> Tuple[str, ...]:
        """Memoized: the router hashes the same prompt once per
        candidate instance per pass otherwise — keys depend only on
        (model, block size, padded tokens), never on engine state."""
        seq = np.frombuffer(seq_bytes, np.int32)
        keys: List[str] = []
        h = hashlib.sha1(model.encode())
        for i in range(len(seq) // block_size):
            h.update(seq[i * block_size:(i + 1) * block_size].tobytes())
            keys.append(h.hexdigest())
        return tuple(keys)

    def _chain_keys(self, seq: np.ndarray) -> Tuple[str, ...]:
        """Chain-hash key per FULL block of ``seq``: an incremental
        digest over model id + the token ids up to and including that
        block, so a key matches iff the entire padded prefix matches
        (left-pad rows are attended, hence part of the content)."""
        return self._chain_keys_cached(
            self.cfg.name, self.block_size,
            np.ascontiguousarray(seq, np.int32).tobytes())

    def _prefix_lookup(self, seq: np.ndarray
                       ) -> Tuple[List[str], int, Optional[str]]:
        """Longest cached block-aligned prefix of ``seq``. Returns
        (keys of full blocks to map SHARED, first uncached token
        position, copy-on-write source key or None).

        When the cached chain covers the whole (block-aligned) sequence,
        the last block is NOT mapped shared: its final token must be
        recomputed (the first decode step needs its logits) and the
        graft that lands it writes the whole block — so the cached block
        is duplicated into the slot's private tail block on divergence
        (``_copy_pool_block``), and writes only ever target unshared
        blocks."""
        keys = self._chain_keys(seq)
        n_hit = 0
        for k in keys:
            if not self.allocator.cached(k):
                break
            n_hit += 1
        bs = self.block_size
        if n_hit and n_hit * bs >= len(seq):
            return keys[:n_hit - 1], len(seq) - 1, keys[n_hit - 1]
        return keys[:n_hit], n_hit * bs, None

    def _padded_seq(self, prompt: np.ndarray) -> np.ndarray:
        S = _bucket(len(prompt), buckets=SEQ_BUCKETS)
        seq = np.zeros((S,), np.int32)
        seq[S - len(prompt):] = prompt
        return seq

    def cached_prefix_tokens(self, prompt: np.ndarray,
                             prepadded: bool = False) -> int:
        """Tokens of ``prompt`` the prefix cache currently holds — the
        router's prefix-affinity signal (docs/RUNTIME.md §7). Read-only:
        nothing is acquired."""
        if not self.prefix_cache:
            return 0
        seq = np.asarray(prompt, np.int32) if prepadded \
            else self._padded_seq(np.asarray(prompt, np.int32))
        _, pos0, _ = self._prefix_lookup(seq)
        return pos0

    def _live_shared_blocks_prepadded(self, seq: np.ndarray) -> int:
        """Blocks an admission of the padded sequence ``seq`` would map
        from LIVE cached blocks (refcount >= 1) — sharing those costs no
        capacity, so ``admissible`` discounts them. LRU-parked hits are
        excluded: reviving one consumes an available block anyway."""
        keys = self._chain_keys(np.asarray(seq, np.int32))
        n = 0
        for k in keys:
            if not self.allocator.cached_live(k):
                break
            n += 1
        if n and n * self.block_size >= len(seq):
            n -= 1  # last block stays private (copy-on-write)
        return n

    def _live_shared_blocks(self, prompt: np.ndarray) -> int:
        return self._live_shared_blocks_prepadded(
            self._padded_seq(np.asarray(prompt, np.int32)))

    def _copy_pool_block(self, dst: int, src: int) -> None:
        """Device-copy one physical pool block across every paged layer
        (fused-prefill copy-on-write: a fully-covering cached chain's
        tail block is duplicated into the slot's private tail block, so
        re-scoring its final token never writes a shared block). Every
        layer is paged here — the fused gate mirrors
        ``supports_prefix_cache``."""
        def copy(c, stacked: bool):
            out = dict(c)
            for key in ("k", "v"):
                pool = c[key]
                out[key] = pool.at[:, dst].set(pool[:, src]) if stacked \
                    else pool.at[dst].set(pool[src])
            return out

        new: Dict = {}
        if "units" in self.cache:
            new["units"] = tuple(copy(c, stacked=True)
                                 for c in self.cache["units"])
        if "tail" in self.cache:
            new["tail"] = tuple(copy(c, stacked=False)
                                for c in self.cache["tail"])
        self.cache = new

    # ---- host KV tier: swap data plane (docs/ARCHITECTURE.md §5) ---------
    def _make_host_pool(self) -> Dict:
        """Pinned-host mirror of the paged pool: one numpy array per
        paged k/v leaf with the block axis resized to
        ``kv_host_blocks + 1`` (host ids 1.. index it directly; row 0 is
        dead, mirroring the device null block). Only built for
        fully-pageable stacks (``swap_ok``), so every layer is paged."""
        def mirror(c, stacked: bool):
            out = {}
            for key in ("k", "v"):
                p = c[key]
                shp = (p.shape[0], self.kv_host_blocks + 1) + p.shape[2:] \
                    if stacked else \
                    (self.kv_host_blocks + 1,) + p.shape[1:]
                out[key] = np.zeros(shp, p.dtype)
            return out

        hp: Dict = {}
        if "units" in self.cache:
            hp["units"] = tuple(mirror(c, stacked=True)
                                for c in self.cache["units"])
        if "tail" in self.cache:
            hp["tail"] = tuple(mirror(c, stacked=False)
                               for c in self.cache["tail"])
        return hp

    @property
    def swap_bytes_per_block(self) -> int:
        """Bytes one block occupies across every paged layer's k+v —
        the unit the swap-cost fit is priced in."""
        if self.host_pool is None:
            return 0
        n = 0
        for c in self.host_pool.get("units", ()):
            for key in ("k", "v"):
                p = c[key]
                n += p[:, 0].nbytes
        for c in self.host_pool.get("tail", ()):
            for key in ("k", "v"):
                n += c[key][0].nbytes
        return n

    def _swap_out_blocks(self, dev_ids: List[int],
                         host_ids: List[int]) -> None:
        """Copy physical pool blocks ``dev_ids`` into host blocks
        ``host_ids``: one fused gather + ``jax.device_get`` per layer
        (batched over the whole block run, not per block). The
        device_get blocks until the transfer lands, so the recorded
        (bytes, ms) sample measures true device->host bandwidth."""
        t0 = time.perf_counter()
        didx = jnp.asarray(dev_ids, jnp.int32)
        hidx = np.asarray(host_ids, np.int64)
        n_bytes = 0
        def pull(c, hpc, stacked: bool):
            nonlocal n_bytes
            for key in ("k", "v"):
                pool = c[key]
                g = pool[:, didx] if stacked else pool[didx]
                arr = np.asarray(jax.device_get(g))
                n_bytes += arr.nbytes
                if stacked:
                    hpc[key][:, hidx] = arr
                else:
                    hpc[key][hidx] = arr

        for c, hpc in zip(self.cache.get("units", ()),
                          self.host_pool.get("units", ())):
            pull(c, hpc, stacked=True)
        for c, hpc in zip(self.cache.get("tail", ()),
                          self.host_pool.get("tail", ())):
            pull(c, hpc, stacked=False)
        self.swap_samples.append(
            (n_bytes, (time.perf_counter() - t0) * 1e3))

    def _swap_in_blocks(self, host_ids: List[int],
                        dev_ids: List[int]) -> None:
        """Copy host blocks back into freshly allocated device blocks:
        one ``device_put`` + scatter per layer, DISPATCHED without
        blocking (jax async dispatch) — the copy overlaps the admission
        bookkeeping and whatever else runs before the next forward
        touches the pool, which is the swap-in-ahead-of-resume the
        scheduler's pricing assumes."""
        t0 = time.perf_counter()
        didx = jnp.asarray(dev_ids, jnp.int32)
        hidx = np.asarray(host_ids, np.int64)
        n_bytes = 0
        def push(c, hpc, stacked: bool):
            nonlocal n_bytes
            out = dict(c)
            for key in ("k", "v"):
                rows = hpc[key][:, hidx] if stacked else hpc[key][hidx]
                n_bytes += rows.nbytes
                out[key] = c[key].at[:, didx].set(rows) if stacked \
                    else c[key].at[didx].set(rows)
            return out

        new: Dict = {}
        if "units" in self.cache:
            new["units"] = tuple(
                push(c, hpc, stacked=True)
                for c, hpc in zip(self.cache["units"],
                                  self.host_pool["units"]))
        if "tail" in self.cache:
            new["tail"] = tuple(
                push(c, hpc, stacked=False)
                for c, hpc in zip(self.cache["tail"],
                                  self.host_pool["tail"]))
        self.cache = new
        self.swap_samples.append(
            (n_bytes, (time.perf_counter() - t0) * 1e3))

    def _spill_block(self, bid: int, hid: int) -> None:
        """Allocator spill hook: demote one reclaimed prefix block."""
        self._swap_out_blocks([bid], [hid])

    def _unspill_block(self, hid: int, bid: int) -> None:
        """Allocator revival hook: promote one spilled prefix block."""
        self._swap_in_blocks([hid], [bid])

    def _graft(self, one_cache, slot: int, block_ids=None,
               skip_blocks: int = 0) -> None:
        """Scatter a freshly-prefilled single-sequence cache into the
        persistent cache. Dense layers (and windowed/recurrent state in
        both layouts) write batch row ``slot``, zero-padding each leaf up
        to the slot cache's length axes (same semantics as ``pad_cache``:
        prefill wrote [0, S), decode writes from S on). Paged linear-KV
        layers instead ``scatter_blocks`` the prefilled rows into the
        physical blocks ``block_ids`` the allocator handed this slot —
        grafting is block-granular, no ``cache_len`` copy. The first
        ``skip_blocks`` ids are prefix-cache blocks mapped SHARED: they
        already hold the right content (possibly for other sequences
        too), so the scatter starts past them — writes only ever target
        unshared blocks."""
        def graft_layer(full_c, one_c, batch_axis: int):
            def leaf(t, s):
                row = jnp.take(s, 0, axis=batch_axis)
                tslice = t.shape[:batch_axis] + t.shape[batch_axis + 1:]
                pads = [(0, want - have)
                        for have, want in zip(row.shape, tslice)]
                if any(p != (0, 0) for p in pads):
                    row = jnp.pad(row, pads)
                idx = (slice(None),) * batch_axis + (slot,)
                return t.at[idx].set(row)
            return jax.tree.map(leaf, full_c, one_c)

        def graft_paged(full_c, one_c, stacked: bool):
            ids = jnp.asarray(block_ids[skip_blocks:], jnp.int32)
            # a chunked-prefill staging cache is cache_len long; only the
            # rows the allocated blocks cover are scattered (the written
            # prefix always fits them, the rest is zeros). Shared prefix
            # blocks are skipped: start is block-aligned by construction.
            start = skip_blocks * self.block_size
            cap = len(block_ids) * self.block_size
            scatter = scatter_blocks_stacked if stacked else scatter_blocks
            return {key: scatter(full_c[key],
                                 one_c[key][:, 0, start:cap] if stacked
                                 else one_c[key][0, start:cap], ids)
                    for key in ("k", "v")}

        paged = self.kv_layout == "paged"
        _, tail_kinds = _split_layers(self.cfg)
        new: Dict = {}
        if "units" in self.cache:
            new["units"] = tuple(
                graft_paged(fc, oc, stacked=True)
                if paged and paged_layer_kind(self.cfg, kind)
                else graft_layer(fc, oc, batch_axis=1)
                for kind, fc, oc in zip(self.cfg.block_pattern,
                                        self.cache["units"],
                                        one_cache["units"]))
        if "tail" in self.cache:
            new["tail"] = tuple(
                graft_paged(fc, oc, stacked=False)
                if paged and paged_layer_kind(self.cfg, kind)
                else graft_layer(fc, oc, batch_axis=0)
                for kind, fc, oc in zip(tail_kinds, self.cache["tail"],
                                        one_cache["tail"]))
        self.cache = new

    def admit(self) -> int:
        """Move waiting prompts into free slots. Returns #admissions.

        Chunked engines (plain token prompts) only ASSIGN the slot here —
        reserve blocks, build the padded token sequence, allocate the
        staging cache — and the prefill itself advances in budget-bounded
        chunks inside ``step()`` (docs/ARCHITECTURE.md §5), so admission
        never blocks resident decodes for a whole prompt. Frontend
        models keep the single-shot inline prefill.

        Paged layout: FIFO admission is additionally gated on the
        allocator — the head request's worst-case block count
        (prompt bucket + requested decode tokens) must be reservable, or
        it (and everything behind it) stays queued until evictions free
        blocks."""
        n = 0
        free = self.free_slots
        while self.waiting and free:
            w = self.waiting[0]
            if w.swap is not None:
                if not self._admit_swap(w, free):
                    break  # FIFO: head of queue blocks on memory
                n += 1
                continue
            if w.prepadded:
                seq = w.prompt
                base_len = w.base_len
            else:
                S = _bucket(len(w.prompt), buckets=SEQ_BUCKETS)
                F = self._frontend_tokens()
                base_len = F + S
                seq = None
                if self.chunked:
                    seq = np.zeros((S,), np.int32)
                    seq[S - len(w.prompt):] = w.prompt
            reserved = 0
            shared_ids: List[int] = []
            pos0 = 0
            cow_key: Optional[str] = None
            if self.kv_layout == "paged":
                need_tokens = len(seq) + w.max_new if seq is not None \
                    else self._seq_tokens(len(w.prompt), w.max_new)
                need = self.allocator.blocks_for(need_tokens)
                if self.prefix_cache and seq is not None:
                    # map the longest cached block-aligned prefix at
                    # refcount+1 and reserve only the remainder — the
                    # admission-capacity gain sharing buys. acquire can
                    # refuse an LRU revival (every remaining block
                    # promised): the chain simply stops there.
                    self.n_prefix_lookups += 1
                    hit_keys, pos0, cow_key = self._prefix_lookup(seq)
                    for k in hit_keys:
                        bid = self.allocator.acquire(k)
                        if bid is None:
                            break
                        shared_ids.append(bid)
                    if len(shared_ids) < len(hit_keys):
                        pos0 = len(shared_ids) * self.block_size
                        cow_key = None
                reserved = need - len(shared_ids)
                if not self.allocator.reserve(reserved):
                    if shared_ids:
                        self.allocator.free(shared_ids)
                    break  # FIFO: head of queue blocks on memory
            self.waiting.pop(0)
            slot = free.pop(0)
            if self.chunked:
                n0 = 0
                ids: List[int] = list(shared_ids)
                if self.kv_layout == "paged":
                    # physically allocate the uncached prefill prefix
                    # now; the decode tail of the reservation is claimed
                    # lazily at block boundaries in step(). block_tables
                    # stays on the null block until the prefill lands
                    # (mid-prefill dummy decode writes must keep sinking
                    # into the null block) — fused chunks carry their own
                    # table row built from ``ids``.
                    n0 = self.allocator.blocks_for(len(seq))
                    ids += [self.allocator.alloc_reserved()
                            for _ in range(n0 - len(shared_ids))]
                staging = None
                if self.fused_prefill:
                    # fused path: chunks attend the shared prefix blocks
                    # IN PLACE through the table — no staging cache, no
                    # prefix gather. A fully-covering cached chain still
                    # copies its tail block into the slot's private tail
                    # block (copy-on-write) so re-scoring the final
                    # token writes only unshared blocks.
                    if pos0 and cow_key is not None:
                        tmp = self.allocator.acquire(cow_key)
                        if tmp is None:  # LRU revival refused: shrink
                            pos0 = len(shared_ids) * self.block_size
                        else:
                            self._copy_pool_block(ids[-1], tmp)
                            self.allocator.free([tmp])
                else:
                    # non-fused (dense / hybrid) layouts never see prefix
                    # hits — the prefix cache requires the same layer gate
                    # as fused prefill — so the staging cache starts empty
                    assert pos0 == 0 and not shared_ids
                    staging = self.model.init_cache(1, self.cache_len,
                                                    self.dtype)
                if pos0:
                    self.n_prefix_hits += 1
                    self.n_prefix_hit_tokens += pos0
                self.slots[slot] = _Slot(
                    request_id=w.request_id, remaining=w.max_new,
                    submit_s=w.submit_s, admit_s=self._now(), blocks=ids,
                    n_outstanding=reserved - (n0 - len(shared_ids)),
                    n_shared=len(shared_ids), seq_tokens=seq,
                    base_len=base_len, prefill_pos=pos0, staging=staging,
                    requested_new=w.requested_new, truncated=w.truncated,
                    n_preempted=w.n_preempted,
                    first_token_s=w.first_token_s)
                self.pos[slot] = 0
                if self.on_state is not None:
                    self.on_state(w.request_id, "prefill")
            else:
                self._admit_inline(w, slot, reserved)
            self.n_admitted += 1
            n += 1
        return n

    def _admit_swap(self, w: _WaitingReq, free: List[int]) -> bool:
        """Admit a swap-mode resume from the head of the queue: reserve
        the full remaining footprint, immediately convert the swapped
        portion into fresh device blocks, dispatch the host->device copy
        (async — jax dispatch returns before the transfer lands, and the
        next forward orders after it), release the host blocks, and hand
        the slot straight to the decode loop at its carried frontier.
        NO prefill happens: this is the whole point of the swap tier.
        Returns False (leaving the queue untouched) when the reservation
        cannot be met — the FIFO head blocks on memory, same as a fresh
        admission."""
        req = w.swap
        need = self.allocator.blocks_for(req.pos + req.max_new)
        if not self.allocator.reserve(need):
            return False
        self.waiting.pop(0)
        slot = free.pop(0)
        n_have = len(req.host_blocks)
        ids = [self.allocator.alloc_reserved() for _ in range(n_have)]
        self._swap_in_blocks(req.host_blocks, ids)
        self.allocator.host_free(req.host_blocks)
        self.block_tables[slot, :n_have] = ids
        # prefill_pos == len(seq_tokens): the slot is DECODING from the
        # first step — the re-mapped blocks already hold rows [0, pos)
        self.slots[slot] = _Slot(
            request_id=w.request_id, remaining=req.max_new,
            n_emitted=len(req.tokens), tokens=list(req.tokens),
            submit_s=req.submit_s, admit_s=self._now(), blocks=ids,
            n_outstanding=need - n_have, n_shared=0,
            seq_tokens=np.asarray(req.seq_tokens, np.int32),
            base_len=req.base_len, prefill_pos=len(req.seq_tokens),
            requested_new=req.requested_new, truncated=req.truncated,
            n_preempted=req.n_preempted, first_token_s=req.first_token_s)
        self.pos[slot] = req.pos
        self.pending_tok[slot] = req.pending_tok
        if self.prefix_cache:
            # the prompt chain came back bit-identical: re-publish any
            # full prompt blocks whose keys fell out of both tiers while
            # the sequence was swapped out (first writer wins, so keys
            # still cached elsewhere are untouched)
            for i, key in enumerate(self._chain_keys(
                    self.slots[slot].seq_tokens)):
                if i < n_have:
                    self.allocator.register(key, ids[i])
        self.n_admitted += 1
        self.n_swap_resumes += 1
        if self.on_state is not None:
            self.on_state(w.request_id, "decode")
        return True

    def _admit_inline(self, w: _WaitingReq, slot: int,
                      reserved: int) -> None:
        """Legacy single-shot prefill admission (frontend models only:
        their prompt carries frontend embeds the chunk path cannot
        replicate). Blocks every resident decode for the whole prefill."""
        batch, S, _ = make_prefill_batch(self.cfg, [w.prompt])
        self.prefill_shapes.add(tuple(batch["tokens"].shape))
        logits, one_cache = self._prefill(self.params, batch)
        F = 0
        if self.cfg.frontend is not None and not self.cfg.enc_dec:
            F = batch["frontend_embeds"].shape[1]
        if self.kv_layout == "paged":
            n0 = self.allocator.blocks_for(F + S)
            ids = [self.allocator.alloc_reserved() for _ in range(n0)]
            self.block_tables[slot, :n0] = ids
            self._graft(one_cache, slot, block_ids=ids)
            self.slots[slot] = _Slot(
                request_id=w.request_id, remaining=w.max_new,
                submit_s=w.submit_s, admit_s=self._now(), blocks=ids,
                n_outstanding=reserved - n0,
                requested_new=w.requested_new, truncated=w.truncated)
        else:
            self._graft(one_cache, slot)
            self.slots[slot] = _Slot(
                request_id=w.request_id, remaining=w.max_new,
                submit_s=w.submit_s, admit_s=self._now(),
                requested_new=w.requested_new, truncated=w.truncated)
        self.pos[slot] = F + S
        self.pending_tok[slot] = int(sample_tokens(logits[0, -1, :]))
        if self.on_state is not None:
            # single-shot prefill: the slot is decoding the moment
            # admission returns (QUEUED -> DECODE, docs/RUNTIME.md §11)
            self.on_state(w.request_id, "decode")

    # ---- chunked prefill (docs/ARCHITECTURE.md §5) -----------------------
    def _prefill_step(self, budget_left: int) -> int:
        """Advance in-slot chunked prefills by at most ``budget_left``
        tokens (power-of-two chunk pieces so the compile cache stays
        bounded at one shape per piece size). Returns tokens processed.
        A slot whose last chunk lands is grafted (non-fused layouts) or
        just published (fused mode) and joins the decode batch of this
        same iteration.

        Fused mode runs each chunk directly against the paged pool: the
        batch carries the slot's block-table row (built from its
        allocated blocks — the engine-level table stays on the null
        block until the prefill completes) and the chunk's K/V lands in
        the pool as it is computed, attending shared prefix blocks in
        place."""
        done_tokens = 0
        for i in list(self.prefilling_slots):
            s = self.slots[i]
            logits = None
            while s.prefilling and budget_left > 0:
                rem = len(s.seq_tokens) - s.prefill_pos
                c = min(rem, budget_left, _MAX_CHUNK)
                c = 1 << (c.bit_length() - 1)  # largest power of two <= c
                toks = s.seq_tokens[s.prefill_pos:s.prefill_pos + c]
                shape = (c, self.cache_len)
                if shape not in self.prefill_shapes:
                    self.prefill_shapes.add(shape)
                    self.last_step_compiled = True
                batch = {"tokens": jnp.asarray(toks[None, :]),
                         "pos": jnp.asarray([s.prefill_pos], jnp.int32)}
                if self.fused_prefill:
                    tbl = np.zeros((1, self.blocks_per_slot), np.int32)
                    tbl[0, :len(s.blocks)] = s.blocks
                    batch["block_tables"] = jnp.asarray(tbl)
                    logits, self.cache = self._prefill_chunk(
                        self.params, self.cache, batch)
                else:
                    logits, s.staging = self._prefill_chunk(
                        self.params, s.staging, batch)
                s.prefill_pos += c
                budget_left -= c
                done_tokens += c
            if logits is not None and not s.prefilling:
                self._finish_prefill(i, logits)
        self.n_prefill_chunk_tokens += done_tokens
        return done_tokens

    def _finish_prefill(self, slot: int, logits) -> None:
        """Last chunk landed: point the block table at the allocated
        prefix blocks and hand the slot to the decode loop. Non-fused
        layouts graft the staging cache into the slot first (skipping
        the shared prefix blocks, which are immutable); in fused mode
        the chunks already wrote the pool through the table, so there is
        nothing to scatter. With the prefix cache on, the now-complete
        full prompt blocks are published under their chain keys so later
        same-prefix admissions can share them."""
        s = self.slots[slot]
        if self.kv_layout == "paged":
            self.block_tables[slot, :len(s.blocks)] = s.blocks
            if not self.fused_prefill:
                self._graft(s.staging, slot, block_ids=s.blocks,
                            skip_blocks=s.n_shared)
            if self.prefix_cache:
                for i, key in enumerate(self._chain_keys(s.seq_tokens)):
                    if i >= s.n_shared:
                        self.allocator.register(key, s.blocks[i])
        else:
            self._graft(s.staging, slot)
        s.staging = None
        self.pos[slot] = s.prefill_pos
        self.pending_tok[slot] = int(sample_tokens(logits[0, -1, :]))
        if self.on_state is not None:
            self.on_state(s.request_id, "decode")

    # ---- preemption (docs/RUNTIME.md §8) ---------------------------------
    def preemption_candidates(self) -> List[Tuple[int, int, int]]:
        """(slot, request_id, freeable_blocks) for every preemptible
        resident — decoding slots only, never a mid-chunk prefill (its
        staging work would be thrown away and re-bought immediately).
        A block mapped by other sequences too (refcount > 1) does not
        free capacity when this slot releases its reference, so only
        sole-reference blocks count as freeable."""
        if not self.chunked:
            return []
        out = []
        for i, s in enumerate(self.slots):
            if not s.active or s.prefilling:
                continue
            freeable = s.n_outstanding
            if self.kv_layout == "paged":
                freeable += sum(1 for b in s.blocks
                                if self.allocator.refcount(b) == 1)
            else:
                freeable += len(s.blocks)
            out.append((i, s.request_id, freeable))
        return out

    def can_swap(self, slot: int) -> bool:
        """Could the sequence in ``slot`` be preempted with
        ``mode="swap"`` right now? Needs the host tier (``swap_ok``:
        configured AND every layer's decode state in the block pool) and
        enough available host blocks to hold the slot's KV."""
        if not self.swap_ok:
            return False
        s = self.slots[slot]
        return s.active and not s.prefilling \
            and self.allocator.n_host_available >= len(s.blocks)

    def preempt(self, slot: int, requeue: bool = True,
                mode: str = "recompute") -> PreemptedRequest:
        """Evict the resident sequence in ``slot`` back to a waiting
        queue, returning its blocks (and the unconsumed reservation
        tail) to the allocator immediately.

        ``mode="recompute"`` (default): the snapshot resumes by
        re-prefilling the padded prompt plus every token emitted so
        far — greedy output is token-identical to an uninterrupted run
        (asserted in tests/test_preemption.py).

        ``mode="swap"``: the slot's KV blocks are copied to the host
        tier first (one batched device_get per layer), so resume only
        re-maps them onto fresh device blocks — no recompute at all.
        Emitted tokens, decode position and the pending token ride in
        the snapshot verbatim; output stays token-identical because the
        resumed state IS the preempted state (fuzzed against the
        recompute path in tests/test_engine_fuzz.py). Raises when
        ``can_swap(slot)`` does not hold — callers price and pick the
        mode (docs/RUNTIME.md §8), the engine never falls back silently.

        ``requeue=True`` reinserts at the head of THIS engine's FIFO
        (standalone use); a pool passes ``requeue=False`` and routes the
        snapshot through its own EDF queue (``submit_resume``)."""
        if mode not in ("recompute", "swap"):
            raise ValueError(f"unknown preempt mode {mode!r}")
        s = self.slots[slot]
        if not s.active:
            raise ValueError(f"slot {slot} holds no sequence")
        if s.prefilling:
            raise ValueError("cannot preempt a mid-chunk prefill")
        if not self.chunked:
            raise NotImplementedError(
                "preemption needs the chunked-prefill path "
                "(plain token prompts)")
        if mode == "swap":
            if not self.can_swap(slot):
                raise ValueError(
                    f"slot {slot} is not swappable (host tier off, "
                    "non-pageable stack, or host pool full)")
            host_ids = self.allocator.swap_out_alloc(len(s.blocks))
            assert host_ids is not None  # can_swap checked availability
            self._swap_out_blocks(s.blocks, host_ids)
            req = PreemptedRequest(
                s.request_id, s.seq_tokens, base_len=s.base_len,
                max_new=s.remaining, submit_s=s.submit_s,
                requested_new=s.requested_new, truncated=s.truncated,
                n_preempted=s.n_preempted + 1,
                first_token_s=s.first_token_s,
                tokens=list(s.tokens), pos=int(self.pos[slot]),
                pending_tok=int(self.pending_tok[slot]),
                host_blocks=host_ids, host_engine_id=id(self))
            self.n_swap_preempts += 1
        else:
            seq = np.concatenate([s.seq_tokens,
                                  np.asarray(s.tokens, np.int32)])
            req = PreemptedRequest(
                s.request_id, seq, base_len=s.base_len, max_new=s.remaining,
                submit_s=s.submit_s, requested_new=s.requested_new,
                truncated=s.truncated, n_preempted=s.n_preempted + 1,
                first_token_s=s.first_token_s)
        if self.kv_layout == "paged":
            self.allocator.free(s.blocks)
            self.allocator.unreserve(s.n_outstanding)
            self.block_tables[slot, :] = 0
        self.pos[slot] = 0
        self.slots[slot] = _Slot()
        self.n_preempted += 1
        if requeue:
            self.waiting.insert(0, _WaitingReq(
                req.request_id, req.seq_tokens, req.max_new, req.submit_s,
                prepadded=True, base_len=req.base_len,
                requested_new=req.requested_new, truncated=req.truncated,
                n_preempted=req.n_preempted,
                first_token_s=req.first_token_s,
                swap=req if req.swapped else None))
        return req

    def release_swap(self, req: PreemptedRequest) -> PreemptedRequest:
        """Convert a swap snapshot back into a recompute snapshot,
        freeing its host blocks — the escape hatch when the owning
        engine is draining/retired and the snapshot must resume
        elsewhere. Token identity is preserved: the recompute context is
        the padded prompt plus the emitted tokens, and greedy re-prefill
        regenerates the dropped pending token deterministically."""
        if not req.swapped:
            return req
        if req.host_engine_id != id(self):
            raise ValueError(
                "swap snapshot is pinned to a different engine's host "
                "pool")
        self.allocator.host_free(req.host_blocks)
        seq = np.concatenate([req.seq_tokens,
                              np.asarray(req.tokens, np.int32)])
        return PreemptedRequest(
            req.request_id, seq, base_len=req.base_len,
            max_new=req.max_new, submit_s=req.submit_s,
            requested_new=req.requested_new, truncated=req.truncated,
            n_preempted=req.n_preempted,
            first_token_s=req.first_token_s)

    # ---- cancellation (docs/RUNTIME.md §11) ------------------------------
    def cancel(self, request_id: int) -> Optional[ContinuousResult]:
        """Tear down ``request_id`` at WHATEVER phase it is in — queued,
        mid-chunk prefill, decoding, or requeued-after-preemption — and
        free its memory synchronously: blocks (shared prefix references
        included) return to the allocator and the unconsumed reservation
        tail is cancelled before this returns, so a mass disconnect
        frees capacity for the next admission pass, not after a drain.

        Returns a ``ContinuousResult`` with ``cancelled=True`` carrying
        the partial completion, or ``None`` if the id is not live here
        (already finished, or resident elsewhere in a pool). Unlike
        ``preempt`` this is legal mid-prefill: the staging cache /
        partially written pool blocks are simply discarded — nothing was
        registered in the prefix cache yet, so no key can reference
        them."""
        for qi, w in enumerate(self.waiting):
            if w.request_id == request_id:
                self.waiting.pop(qi)
                # a requeued preemption carries its pre-eviction tokens
                # in the prepadded context (recompute) or in the swap
                # snapshot; a fresh prompt has none
                if w.swap is not None:
                    self.allocator.host_free(w.swap.host_blocks)
                    emitted = np.asarray(w.swap.tokens, np.int32)
                else:
                    emitted = w.prompt[w.base_len:] if w.prepadded \
                        else np.zeros((0,), np.int32)
                self.n_cancelled += 1
                return ContinuousResult(
                    request_id, np.asarray(emitted, np.int32),
                    submit_s=w.submit_s, admit_s=-1.0,
                    finish_s=self._now(), n_iters=0,
                    truncated=w.truncated, n_preempted=w.n_preempted,
                    first_token_s=w.first_token_s, cancelled=True)
        for i, s in enumerate(self.slots):
            if not (s.active and s.request_id == request_id):
                continue
            emitted = s.tokens
            if s.seq_tokens is not None and s.base_len < len(s.seq_tokens):
                emitted = list(s.seq_tokens[s.base_len:]) + s.tokens
            res = ContinuousResult(
                request_id, np.asarray(emitted, np.int32),
                submit_s=s.submit_s, admit_s=s.admit_s,
                finish_s=self._now(), n_iters=len(emitted),
                truncated=s.truncated, n_preempted=s.n_preempted,
                n_spec_proposed=s.n_spec_proposed,
                n_spec_accepted=s.n_spec_accepted,
                first_token_s=s.first_token_s, cancelled=True)
            if self.kv_layout == "paged":
                # same free path as eviction: refcounted frees park
                # still-registered prefix blocks in the LRU pool
                self.allocator.free(s.blocks)
                self.allocator.unreserve(s.n_outstanding)
                self.block_tables[i, :] = 0
            self.pos[i] = 0
            self.slots[i] = _Slot()
            self.n_cancelled += 1
            self.n_evicted += 1
            return res
        return None

    # ---- iteration -------------------------------------------------------
    def step(self) -> List[ContinuousResult]:
        """One engine iteration: admit, advance chunked prefills under
        the per-iteration token budget, then ONE decode iteration over
        all slots; evicts after.

        The token budget caps prefill-chunk tokens plus resident decode
        tokens, so iteration latency stays bounded no matter how long
        the queued prompts are (docs/ARCHITECTURE.md §5). Returns the
        sequences that finished this iteration. Inactive slots decode a
        dummy token in place (their cache row is masked by ``pos`` and
        overwritten at the next admission), keeping the compiled decode
        shape fixed at (n_slots, 1).
        """
        self.last_step_compiled = False
        self.admit()
        n_dec = len(self.decoding_slots)
        budget = self.token_budget if self.token_budget is not None \
            else 1 << 62
        eff_k = self._effective_spec_k(n_dec, budget)
        self.last_step_tokens = self._prefill_step(
            max(0, budget - n_dec * (1 + eff_k)))
        active = self.decoding_slots
        if not active:
            return []
        if eff_k > 0:
            return self._step_speculative(active, eff_k)
        self.last_step_tokens += len(active)
        for i in active:
            s = self.slots[i]
            s.tokens.append(int(self.pending_tok[i]))
            s.n_emitted += 1
            s.remaining -= 1
            self._note_tokens(s, 1)
        batch = {"tokens": jnp.asarray(self.pending_tok[:, None]),
                 "pos": jnp.asarray(self.pos)}
        if self.kv_layout == "paged":
            # alloc-on-decode-boundary: the write at ``pos`` needs its
            # block mapped before the decode runs; the admission
            # reservation guarantees the free list cannot be empty here
            bs = self.block_size
            for i in active:
                s = self.slots[i]
                while self.pos[i] >= len(s.blocks) * bs:
                    bid = self.allocator.alloc_reserved()
                    s.n_outstanding -= 1
                    self.block_tables[i, len(s.blocks)] = bid
                    s.blocks.append(bid)
            batch["block_tables"] = jnp.asarray(self.block_tables)
        if not self._decode_warm:
            self._decode_warm = True
            self.last_step_compiled = True
        logits, self.cache = self._decode(self.params, self.cache, batch)
        nxt = sample_tokens(logits[:, -1, :])
        self.n_iters += 1
        finished: List[ContinuousResult] = []
        now = self._now()
        for i in active:
            s = self.slots[i]
            # stay inside the cache: clip sequences at capacity (and
            # record the truncation — the caller asked for more tokens)
            if self.pos[i] + 1 >= self.cache_len and s.remaining > 0:
                s.truncated = True
                s.remaining = 0
            if s.remaining <= 0:
                emitted = s.tokens
                if s.seq_tokens is not None and s.base_len < len(s.seq_tokens):
                    # resumed sequence: tokens emitted before the
                    # preemption live in the re-prefilled context
                    emitted = list(s.seq_tokens[s.base_len:]) + s.tokens
                finished.append(ContinuousResult(
                    s.request_id, np.asarray(emitted, np.int32),
                    submit_s=s.submit_s, admit_s=s.admit_s, finish_s=now,
                    n_iters=len(emitted), truncated=s.truncated,
                    n_preempted=s.n_preempted,
                    first_token_s=s.first_token_s))
                if self.kv_layout == "paged":
                    # free-on-evict: blocks return to the pool, the
                    # unconsumed tail of the reservation is cancelled
                    self.allocator.free(s.blocks)
                    self.allocator.unreserve(s.n_outstanding)
                    self.block_tables[i, :] = 0
                    self.pos[i] = 0
                self.slots[i] = _Slot()
                self.n_evicted += 1
            else:
                self.pending_tok[i] = nxt[i]
                self.pos[i] = self.pos[i] + 1
        return finished

    # ---- speculative decoding (docs/ARCHITECTURE.md §5) ------------------
    def _effective_spec_k(self, n_dec: int, budget: int) -> int:
        """Speculation depth this iteration actually runs: the current
        ``spec_k`` clamped to the compiled ``spec_max``, degraded to fit
        ``n_dec * (1 + k)`` decode tokens inside the iteration token
        budget (the engine-level collapse to k=0 under pressure — the
        scheduler's guard does the same degradation proactively)."""
        k = min(max(0, self.spec_k), self.spec_max)
        if k and n_dec and self.token_budget is not None:
            k = max(0, min(k, budget // n_dec - 1))
        return k

    def _step_speculative(self, active: List[int],
                          k: int) -> List[ContinuousResult]:
        """One speculative iteration over the decoding slots: propose up
        to ``k`` draft tokens per slot from its own context, score the
        pending token + drafts in ONE ``(n_slots, 1+k)`` verify forward,
        accept the longest draft prefix matching the verify argmax, and
        roll the KV state back over the rejected tail — dense rows are
        masked by ``pos`` (never attended before being overwritten);
        paged blocks are freed back to the allocator at block
        granularity. Greedy output is token-identical to k=0 because
        acceptance IS greedy equality: every emitted token equals the
        argmax a sequential decode would have produced (asserted in
        tests/test_speculative.py and fuzzed in tests/test_engine_fuzz.py).

        Speculative writes start at ``pos >= prefill_len``, past every
        shared/registered prefix block, so rollback only ever frees
        sole-reference decode-region blocks (asserted in
        :meth:`_trim_blocks`)."""
        W = 1 + k
        toks = np.zeros((self.n_slots, W), np.int32)
        k_eff: Dict[int, int] = {}
        for i in active:
            s = self.slots[i]
            # participation cap: never draft past the request's remaining
            # tokens or the logical cache capacity (rows j > k_i of the
            # fixed-width forward land in the null block / scratch margin
            # and their logits are ignored)
            ki = max(0, min(k, s.remaining - 1,
                            self.cache_len - 1 - int(self.pos[i])))
            k_eff[i] = ki
            toks[i, 0] = self.pending_tok[i]
            if ki > 0:
                context = np.concatenate(
                    [s.seq_tokens, np.asarray(s.tokens, np.int32),
                     [self.pending_tok[i]]]) \
                    if s.seq_tokens is not None \
                    else np.asarray(s.tokens + [self.pending_tok[i]],
                                    np.int32)
                toks[i, 1:1 + ki] = self.proposer.propose(context, ki)
        batch = {"tokens": jnp.asarray(toks),
                 "pos": jnp.asarray(self.pos)}
        if self.kv_layout == "paged":
            # pre-allocate blocks covering each slot's deepest draft row
            # (the admission reservation covers them: pos + k_i is within
            # the granted footprint), then hand the forward a block table
            # padded with null columns so rows past cache_len can never
            # clip into a live block (JAX clamps out-of-bounds gathers)
            bs = self.block_size
            for i in active:
                s = self.slots[i]
                top = int(self.pos[i]) + k_eff[i]
                while top >= len(s.blocks) * bs:
                    bid = self.allocator.alloc_reserved()
                    s.n_outstanding -= 1
                    self.block_tables[i, len(s.blocks)] = bid
                    s.blocks.append(bid)
            pad = -(-self.spec_max // bs)
            vt = np.zeros((self.n_slots, self.blocks_per_slot + pad),
                          np.int32)
            vt[:, :self.blocks_per_slot] = self.block_tables
            batch["block_tables"] = jnp.asarray(vt)
        if W not in self._spec_shapes:
            self._spec_shapes.add(W)
            self.last_step_compiled = True
        logits, self.cache = self._verify(self.params, self.cache, batch)
        nxt_all = sample_tokens(logits)  # (n_slots, W) verify argmax
        self.n_iters += 1
        self.n_spec_steps += 1
        finished: List[ContinuousResult] = []
        now = self._now()
        for i in active:
            s = self.slots[i]
            ki = k_eff[i]
            a = 0
            while a < ki and toks[i, a + 1] == nxt_all[i, a]:
                a += 1
            self.n_spec_proposed += ki
            self.n_spec_accepted += a
            s.n_spec_proposed += ki
            s.n_spec_accepted += a
            self.last_step_tokens += 1 + ki
            # emit the pending token plus the accepted drafts
            s.tokens.extend(int(t) for t in toks[i, :a + 1])
            s.n_emitted += a + 1
            s.remaining -= a + 1
            self._note_tokens(s, a + 1)
            new_pos = int(self.pos[i]) + a + 1
            if self.kv_layout == "paged":
                self._trim_blocks(i, new_pos)
            if new_pos >= self.cache_len and s.remaining > 0:
                s.truncated = True
                s.remaining = 0
            if s.remaining <= 0:
                emitted = s.tokens
                if s.seq_tokens is not None \
                        and s.base_len < len(s.seq_tokens):
                    emitted = list(s.seq_tokens[s.base_len:]) + s.tokens
                finished.append(ContinuousResult(
                    s.request_id, np.asarray(emitted, np.int32),
                    submit_s=s.submit_s, admit_s=s.admit_s, finish_s=now,
                    n_iters=len(emitted), truncated=s.truncated,
                    n_preempted=s.n_preempted,
                    n_spec_proposed=s.n_spec_proposed,
                    n_spec_accepted=s.n_spec_accepted,
                    first_token_s=s.first_token_s))
                if self.kv_layout == "paged":
                    self.allocator.free(s.blocks)
                    self.allocator.unreserve(s.n_outstanding)
                    self.block_tables[i, :] = 0
                    self.pos[i] = 0
                self.slots[i] = _Slot()
                self.n_evicted += 1
            else:
                # the model's next token after the accepted prefix — what
                # sequential decode would have produced as the new pending
                self.pending_tok[i] = nxt_all[i, a]
                self.pos[i] = new_pos
        return finished

    def _trim_blocks(self, slot: int, pos: int) -> None:
        """Block-granular KV rollback: free the trailing blocks past the
        last committed row ``pos - 1`` back to the allocator and restore
        the admission reservation, leaving the slot's block list exactly
        what an unspeculated decode at ``pos`` would hold (the
        alloc-on-decode-boundary loop re-claims them as the frontier
        advances). Only sole-reference decode-region blocks are ever
        trimmed: shared and registered prefix blocks cover rows below
        the prefill length, and ``pos`` never rolls back past it."""
        s = self.slots[slot]
        keep = self.allocator.blocks_for(pos)
        if keep >= len(s.blocks):
            return
        drop = s.blocks[keep:]
        for b in drop:
            assert self.allocator.refcount(b) == 1, \
                f"rollback would free block {b} with refcount " \
                f"{self.allocator.refcount(b)}"
        del s.blocks[keep:]
        self.block_tables[slot, keep:keep + len(drop)] = 0
        self.allocator.free(drop)
        ok = self.allocator.reserve(len(drop))
        assert ok, "re-reserving just-freed blocks cannot fail"
        s.n_outstanding += len(drop)

    def rollback(self, slot: int, n: int) -> None:
        """Undo the last ``n`` emitted tokens of the sequence in
        ``slot``: the committed context shrinks by ``n``, the pending
        token becomes what it was before those emissions, and (paged)
        the trailing KV blocks past the new frontier are freed back to
        the allocator with the reservation restored — the primitive the
        speculative path's rejection handling is built on, exposed for
        the property tests (tests/test_speculative.py). Re-decoding from
        the rolled-back state is token-identical: greedy decode is
        deterministic, and rows at or past the new ``pos`` are never
        attended before being overwritten."""
        if not supports_speculation(self.cfg):
            raise ValueError(
                f"{self.cfg.name}: rollback needs rewindable decode "
                "state (linear attention only)")
        s = self.slots[slot]
        if not s.active or s.prefilling:
            raise ValueError(f"slot {slot} is not decoding")
        if not 1 <= n <= len(s.tokens):
            raise ValueError(
                f"can roll back 1..{len(s.tokens)} tokens, got {n}")
        new_pos = int(self.pos[slot]) - n
        self.pending_tok[slot] = s.tokens[-n]
        del s.tokens[-n:]
        s.n_emitted -= n
        s.remaining += n
        self.pos[slot] = new_pos
        if self.kv_layout == "paged":
            self._trim_blocks(slot, new_pos)

    @property
    def spec_accept_rate(self) -> float:
        """Draft tokens accepted as a fraction of drafts proposed over
        the engine's lifetime — the scheduler's acceptance feature (0.0
        before any speculative step)."""
        return self.n_spec_accepted / self.n_spec_proposed \
            if self.n_spec_proposed else 0.0

    def run(self, prompts: List[np.ndarray], max_new_tokens: int = 8,
            max_iters: int = 10_000) -> List[ContinuousResult]:
        """Submit ``prompts`` and iterate until every sequence finishes."""
        for p in prompts:
            self.submit(p, max_new_tokens)
        done: List[ContinuousResult] = []
        while (self.waiting or self.active_slots) and max_iters > 0:
            done.extend(self.step())
            max_iters -= 1
        done.sort(key=lambda r: r.request_id)
        return done

    # ---- KV occupancy accounting (docs/ARCHITECTURE.md §5) --------------
    @property
    def kv_used_tokens(self) -> int:
        """Cache positions live sequences actually occupy (written or
        about to be written next iteration); mid-prefill sequences count
        the staging tokens their chunks have produced so far."""
        return int(sum(int(self.pos[i]) + 1 for i in self.decoding_slots)
                   + sum(self.slots[i].prefill_pos
                         for i in self.prefilling_slots))

    @property
    def kv_allocated_tokens(self) -> int:
        """Cache positions *committed*: the whole slab for the dense
        layout, LIVE blocks × block_size for the paged one — a block
        shared by N sequences is counted ONCE, and evicted-but-cached
        LRU blocks are reclaimable so they do not count."""
        if self.kv_layout == "paged":
            return self.allocator.n_live * self.block_size
        return self.n_slots * self.cache_len

    @property
    def kv_unique_used_tokens(self) -> int:
        """Distinct physical cache positions live sequences occupy:
        per-block coverage with shared blocks counted once (the paged
        counterpart of ``kv_used_tokens``, which stays per-sequence
        logical — under sharing the logical sum can exceed the physical
        footprint, which is the whole point)."""
        if self.kv_layout != "paged":
            return self.kv_used_tokens
        bs = self.block_size
        cov: Dict[int, int] = {}
        for i, s in enumerate(self.slots):
            if not s.active:
                continue
            if s.prefilling:
                # fused chunks write the pool directly, so every
                # prefilled token occupies its block; non-fused hybrid
                # layouts hold nothing in the pool until the graft (the
                # chunked prefix lives in the staging cache)
                c = s.prefill_pos if self.fused_prefill \
                    else min(s.prefill_pos, s.n_shared * bs)
            else:
                c = int(self.pos[i]) + 1
            for idx, bid in enumerate(s.blocks):
                t = min(bs, c - idx * bs)
                if t <= 0:
                    break
                cov[bid] = max(cov.get(bid, 0), t)
        return sum(cov.values())

    def kv_block_mapping(self) -> Tuple[int, int]:
        """(logical block mappings, distinct physical blocks) over the
        active slots — the pool sums these across instances to price
        effective blocks without reaching into slot internals."""
        mapped = [b for s in self.slots if s.active for b in s.blocks]
        return len(mapped), len(set(mapped))

    @property
    def kv_shared_frac(self) -> float:
        """Fraction of live block *mappings* backed by a physical block
        some other sequence also maps: 1 - distinct/logical. 0 without
        sharing."""
        logical, distinct = self.kv_block_mapping()
        return 1.0 - distinct / logical if logical else 0.0

    @property
    def prefix_hit_rate(self) -> float:
        """Prompt tokens served from the prefix cache as a fraction of
        all prompt tokens processed (hit + chunked-prefill) over the
        engine's lifetime."""
        total = self.n_prefix_hit_tokens + self.n_prefill_chunk_tokens
        return self.n_prefix_hit_tokens / total if total else 0.0

    @property
    def kv_free_tokens(self) -> int:
        """Admission headroom in tokens: unreserved blocks (paged) or
        free slots × cache_len (dense)."""
        if self.kv_layout == "paged":
            return self.allocator.n_available * self.block_size
        return len(self.free_slots) * self.cache_len

    def stats(self) -> Dict[str, float]:
        """Counters + KV occupancy metrics, so benchmarks can report
        dense-vs-paged waste without poking engine internals.
        ``kv_waste_frac`` counts shared blocks ONCE (unique physical
        coverage over live allocation); ``kv_used_tokens`` stays
        per-sequence logical, so used/allocated can exceed 1 under
        sharing — that surplus is the capacity the prefix cache buys."""
        used = float(self.kv_used_tokens)
        uniq = float(self.kv_unique_used_tokens)
        alloc = float(self.kv_allocated_tokens)
        return {
            "n_iters": float(self.n_iters),
            "n_admitted": float(self.n_admitted),
            "n_evicted": float(self.n_evicted),
            "n_prefill_shapes": float(len(self.prefill_shapes)),
            "n_slots": float(self.n_slots),
            "kv_used_tokens": used,
            "kv_allocated_tokens": alloc,
            "kv_waste_frac": 1.0 - uniq / alloc if alloc else 0.0,
            "kv_reserved_tokens": float(
                self.allocator.n_reserved * self.block_size
                if self.kv_layout == "paged" else 0),
            "kv_cached_tokens": float(
                self.allocator.n_cached * self.block_size
                if self.kv_layout == "paged" else 0),
            "kv_shared_frac": self.kv_shared_frac,
            "prefix_hit_rate": self.prefix_hit_rate,
            "n_prefix_hits": float(self.n_prefix_hits),
            "queue_depth": float(len(self.waiting)),
            "n_preempted": float(self.n_preempted),
            "n_cancelled": float(self.n_cancelled),
            # host KV tier (docs/ARCHITECTURE.md §5)
            "kv_host_blocks": float(self.kv_host_blocks),
            "kv_host_free": float(
                self.allocator.n_host_free
                if self.kv_layout == "paged" else 0),
            "kv_host_live": float(
                self.allocator.n_host_live
                if self.kv_layout == "paged" else 0),
            "kv_host_cached": float(
                self.allocator.n_host_cached
                if self.kv_layout == "paged" else 0),
            "n_swap_preempts": float(self.n_swap_preempts),
            "n_swap_resumes": float(self.n_swap_resumes),
            "n_spilled": float(
                self.allocator.n_spilled
                if self.kv_layout == "paged" else 0),
            "n_unspilled": float(
                self.allocator.n_unspilled
                if self.kv_layout == "paged" else 0),
            "prefill_backlog_tokens": float(self.prefill_backlog_tokens),
            "token_budget": float(self.token_budget or 0),
            "spec_k": float(min(max(0, self.spec_k), self.spec_max)),
            "spec_accept_rate": self.spec_accept_rate,
            "n_spec_proposed": float(self.n_spec_proposed),
            "n_spec_accepted": float(self.n_spec_accepted),
            "n_spec_steps": float(self.n_spec_steps),
            "tp_degree": float(self.tp_degree),
        }
