"""State featurisation for the scheduler agents (paper state s_t parts
I-V: model type, input type/shape, SLO, available resources, queue info)."""
from __future__ import annotations

from typing import Dict, List, Sequence

import numpy as np

from repro.configs.paper_edge_models import EDGE_MODELS
from repro.serving.platforms import HardwareSpec

EXTRA_FEATURES = 8


def state_dim(models: Sequence[str]) -> int:
    return len(models) + EXTRA_FEATURES


def queue_feature_index(models: Sequence[str]) -> int:
    """Index of the queue-length feature (used by the EDF baseline)."""
    return len(models) + 4


def featurize(model: str, models: Sequence[str], hw: HardwareSpec,
              queue_len: int, oldest_age_ms: float, mem_used_gb: float,
              active_instances: int, accel_util: float) -> np.ndarray:
    prof = EDGE_MODELS[model]
    onehot = np.zeros(len(models), np.float32)
    onehot[list(models).index(model)] = 1.0
    extras = np.array([
        prof.slo_ms / 100.0,                    # (III) SLO
        np.log1p(prof.gflops),                  # (II) input/compute shape
        prof.params_m / 25.0,
        (hw.mem_gb - mem_used_gb) / hw.mem_gb,  # (IV) available memory
        np.log1p(float(queue_len)),             # (V) queue info [EDF: expm1]
        np.log1p(oldest_age_ms / max(prof.slo_ms, 1.0)),
        active_instances / 8.0,
        accel_util,
    ], np.float32)
    return np.concatenate([onehot, extras])
