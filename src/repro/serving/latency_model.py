"""Analytic edge-platform latency/memory model (computing model, §III-A-3).

End-to-end latency of a request (Eq. 2):
    t_r = t_t (transmit) + t_s (serialize) + t_w (queue) + t_m (infer) + t_o

The simulator produces t_w from actual queueing; this module models
t_t, t_s, t_m and memory. The inference term reproduces the qualitative
surface of the paper's Fig. 1:

* throughput rises with batch size until the batching-efficiency curve
  saturates;
* concurrent instances first help (fill the accelerator) then hurt via
  contention — super-linearly once memory pressure passes the knee;
* past memory capacity the batch fails (the Fig. 1 overflow region).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence, Tuple

from repro.configs.paper_edge_models import EdgeModelProfile
from repro.serving.platforms import HardwareSpec


@dataclasses.dataclass(frozen=True)
class ExecutionEstimate:
    """One batch execution under the §III-A-3 computing model: raw
    compute time, interference inflation (docs/ARCHITECTURE.md §2), and
    the Eq.-4 memory-overflow flag."""
    compute_ms: float
    interference_factor: float
    mem_used_gb: float     # total accelerator memory in use (all instances)
    overflow: bool

    @property
    def total_ms(self) -> float:
        return self.compute_ms * self.interference_factor


def batching_efficiency(hw: HardwareSpec, b: int) -> float:
    return hw.eff_max * b / (b + hw.eff_half)


def instance_memory_gb(model: EdgeModelProfile, b: int) -> float:
    # fp16 weights + activations scale with batch; +20% runtime arena
    return 1.2 * (2.0 * model.params_m / 1024.0
                  + model.activation_mb * b / 1024.0)


def interference_factor(hw: HardwareSpec, total_instances: int,
                        mem_used_gb: float) -> float:
    """Latency inflation from co-located execution (what the NN predictor
    learns). Linear in extra instances; super-linear past the memory knee."""
    f = 1.0 + hw.contention * max(0, total_instances - 1)
    pressure = mem_used_gb / hw.mem_gb
    if pressure > hw.mem_knee:
        over = (pressure - hw.mem_knee) / max(1e-6, 1.0 - hw.mem_knee)
        f *= 1.0 + 2.5 * over ** 2 * total_instances
    return f


def estimate_execution(hw: HardwareSpec, model: EdgeModelProfile, b: int,
                       m_c: int, other_instances: int = 0,
                       other_mem_gb: float = 0.0) -> ExecutionEstimate:
    """Latency of ONE batch of size b when m_c instances of this model (and
    ``other_instances`` of other tenants) run concurrently. Each instance
    time-shares the accelerator => effective throughput divides by the
    number of co-resident instances."""
    total_inst = max(1, m_c + other_instances)
    eff = batching_efficiency(hw, b)
    # one instance only achieves eff(b) of peak (launch gaps, host pre/post);
    # n co-resident instances fill the accelerator up to saturation — this
    # is WHY concurrency helps at small batches (Fig. 1), and why it stops
    # helping once n*eff(b) >= 1 and contention takes over.
    util = min(1.0, total_inst * eff)
    gops = model.gflops * b
    compute_ms = gops * total_inst / (hw.tops * util) + hw.overhead_ms
    mem = m_c * instance_memory_gb(model, b) + other_mem_gb
    overflow = mem > hw.mem_gb
    f = interference_factor(hw, total_inst, mem)
    return ExecutionEstimate(compute_ms, f, mem, overflow)


def _least_squares(xs: Sequence[float], ys: Sequence[float]
                   ) -> Tuple[float, float]:
    """Ordinary least squares ``y ≈ intercept + slope * x``. With fewer
    than two distinct x values the slope is unidentifiable:
    ``(mean(y), 0.0)`` is returned."""
    n = len(xs)
    if len(set(xs)) < 2:
        return sum(ys) / n, 0.0
    mx, my = sum(xs) / n, sum(ys) / n
    sxx = sum((x - mx) ** 2 for x in xs)
    sxy = sum((x - mx) * (y - my) for x, y in zip(xs, ys))
    slope = sxy / sxx
    return my - slope * mx, slope


def fit_contention(samples: Sequence[Tuple[int, float]]
                   ) -> Tuple[float, float]:
    """Calibrate the linear part of :func:`interference_factor` from
    MEASURED per-iteration latencies (docs/RUNTIME.md: the multi-model
    runtime records (total live instances, iteration wall latency) pairs
    while instances overlap).

    Fits ``iter_ms ≈ t1 * (1 + c * (n - 1))`` by least squares and returns
    ``(t1_ms, c)`` — the single-instance iteration latency and the
    per-extra-instance slowdown coefficient (the measured counterpart of
    ``HardwareSpec.contention``). With fewer than two distinct overlap
    levels the slope is unidentifiable and ``c = 0.0`` is returned.
    """
    if not samples:
        return 0.0, 0.0
    xs = [float(max(1, n) - 1) for n, _ in samples]
    ys = [float(t) for _, t in samples]
    t1, slope = _least_squares(xs, ys)
    if t1 <= 1e-9:  # degenerate fit: fall back to the overlap-1 mean
        base = [y for x, y in zip(xs, ys) if x == min(xs)]
        t1 = sum(base) / len(base)
    return t1, max(0.0, slope / max(t1, 1e-9))


def predicted_iter_ms(t1_ms: float, contention: float, n_instances: int
                      ) -> float:
    """Iteration latency the :func:`fit_contention` model predicts when
    ``n_instances`` engine instances are live on the host."""
    return t1_ms * (1.0 + contention * max(0, n_instances - 1))


def fit_token_cost(samples: Sequence[Tuple[int, float]]
                   ) -> Tuple[float, float]:
    """Calibrate per-iteration cost as a function of the tokens the
    iteration actually processed (docs/RUNTIME.md §8: the pool records
    (prefill-chunk + decode tokens, iteration wall ms) pairs, excluding
    compile iterations).

    Fits ``iter_ms ≈ base + per_token * tokens`` by least squares and
    returns ``(base_ms, per_token_ms)``. This is what makes the
    per-iteration token budget a schedulable knob: the guard can price a
    proposed budget directly instead of assuming iteration cost is
    independent of prefill work. With fewer than two distinct token
    counts the slope is unidentifiable and ``per_token_ms = 0.0``.
    """
    if not samples:
        return 0.0, 0.0
    xs = [float(max(0, t)) for t, _ in samples]
    ys = [float(ms) for _, ms in samples]
    base, slope = _least_squares(xs, ys)
    slope = max(0.0, slope)
    # re-anchor the intercept to the clamped slope so the prediction
    # still passes through the sample mean
    base = max(0.0, sum(ys) / len(ys) - slope * sum(xs) / len(xs))
    return base, slope


def predicted_token_iter_ms(base_ms: float, per_token_ms: float,
                            tokens: int) -> float:
    """Iteration latency the :func:`fit_token_cost` model predicts for an
    iteration processing ``tokens`` (decode + prefill-chunk) tokens."""
    return base_ms + per_token_ms * max(0, tokens)


def fit_swap_cost(samples: Sequence[Tuple[int, float]]
                  ) -> Tuple[float, float]:
    """Calibrate one-way KV swap cost from MEASURED transfers
    (docs/RUNTIME.md §8: the engine records (bytes moved, wall ms) for
    every host-tier swap-out/swap-in and block spill/unspill).

    Fits ``transfer_ms ≈ base + per_mb * megabytes`` by least squares
    and returns ``(base_ms, ms_per_mb)`` — the per-transfer launch
    overhead and the inverse host-link bandwidth. This is the term that
    makes recompute-vs-swap a costed decision: ``_pick_preempt_mode``
    compares ``2 * predicted_swap_ms(...)`` (out + back in) against the
    recompute prefill priced by :func:`fit_token_cost`. With fewer than
    two distinct sizes the slope is unidentifiable and ``ms_per_mb = 0``.
    """
    if not samples:
        return 0.0, 0.0
    xs = [max(0.0, float(b)) / 1e6 for b, _ in samples]
    ys = [float(ms) for _, ms in samples]
    base, slope = _least_squares(xs, ys)
    slope = max(0.0, slope)
    # re-anchor the intercept to the clamped slope so the prediction
    # still passes through the sample mean
    base = max(0.0, sum(ys) / len(ys) - slope * sum(xs) / len(xs))
    return base, slope


def predicted_swap_ms(base_ms: float, ms_per_mb: float, mb: float) -> float:
    """One-way transfer latency the :func:`fit_swap_cost` model predicts
    for ``mb`` megabytes of KV pages."""
    return base_ms + ms_per_mb * max(0.0, mb)


def fit_occupancy(samples: Sequence[Tuple[int, float]]) -> float:
    """Calibrate mean KV tokens per resident sequence from MEASURED
    occupancy (docs/RUNTIME.md: the pool records
    (total resident sequences, Σ engine ``kv_used_tokens``) pairs every
    pure-decode iteration).

    Through-origin least squares — zero resident sequences must use zero
    tokens. This replaces :func:`instance_memory_gb`'s analytic
    activation curve as the memory term the ``PoolScheduler`` guard uses
    once a paged pool reports real occupancy: a proposed (b, m_c) is
    memory-feasible iff ``b * m_c * fit_occupancy(...)`` (plus the other
    tenants' measured usage) fits the shared block budget.
    """
    num = sum(float(n) * float(t) for n, t in samples)
    den = sum(float(n) * float(n) for n, _ in samples)
    return num / den if den > 0.0 else 0.0


def predicted_kv_tokens(tokens_per_seq: float, n_seqs: int) -> float:
    """KV tokens the :func:`fit_occupancy` model predicts for ``n_seqs``
    concurrently resident sequences."""
    return tokens_per_seq * max(0, n_seqs)


def transmission_ms(hw: HardwareSpec, model: EdgeModelProfile) -> float:
    size_mb = 2.0 * math.prod(model.input_shape) / 1e6  # fp16 payload
    return hw.io_ms_per_mb * size_mb + 0.2


def serialization_ms(b: int) -> float:
    return 0.05 * b + 0.1


def peak_throughput_rps(hw: HardwareSpec, model: EdgeModelProfile,
                        b: int, m_c: int) -> float:
    est = estimate_execution(hw, model, b, m_c)
    if est.overflow:
        return 0.0
    return 1000.0 * b * m_c / (est.total_ms)
