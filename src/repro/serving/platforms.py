"""Hardware models for the serving simulator.

Edge platforms follow the paper's Table III/V; the TPU cell entry is the
v5e target used by the pod serving path (launch/serve.py), with constants
matching the roofline analysis (197 bf16 TFLOP/s, 819 GB/s HBM).

``eff_max``/``eff_half`` shape the batching-efficiency curve
eff(b) = eff_max * b / (b + eff_half): small batches underutilise the
accelerator, which is exactly the effect adaptive batching exploits.
``contention`` scales the latency inflation per additional concurrent
instance; ``mem_knee`` is the memory-pressure fraction beyond which
interference turns super-linear (paper Fig. 1's collapse region).
"""
from __future__ import annotations

import dataclasses
from typing import Dict


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    """One edge platform's calibrated constants (paper Tables III/V;
    calibration method in docs/ARCHITECTURE.md §2)."""
    name: str
    tops: float            # effective accelerator throughput (G-ops/ms = TOPS)
    mem_gb: float
    cpu_cores: int
    eff_max: float
    eff_half: float
    contention: float      # per-extra-instance slowdown coefficient
    mem_knee: float        # fraction of memory where contention knees
    overhead_ms: float     # per-batch fixed launch overhead
    io_ms_per_mb: float    # request transmission cost (network model)


#: ``tops`` is the *effective achievable* Gops/ms on these small CNN/BERT
#: workloads (TensorRT-measured effective throughput is a small fraction of
#: the marketing peak in Table V; ratios follow the table, absolute values
#: calibrated so b=1 latencies match published TRT measurements, e.g.
#: YOLOv5s ≈ 29 ms on Xavier NX).
PLATFORMS: Dict[str, HardwareSpec] = {
    # Table V: 0.47 TFLOPS fp16, 4 GB, 128 CUDA cores
    "jetson_nano": HardwareSpec(
        "Jetson Nano", tops=0.11, mem_gb=4.0, cpu_cores=4,
        eff_max=0.50, eff_half=1.8, contention=0.10, mem_knee=0.70,
        overhead_ms=3.0, io_ms_per_mb=0.35),
    # Table V: 1.33 TFLOPS fp16, 8 GB, 256 CUDA cores
    "jetson_tx2": HardwareSpec(
        "Jetson TX2", tops=0.24, mem_gb=8.0, cpu_cores=6,
        eff_max=0.50, eff_half=1.6, contention=0.08, mem_knee=0.75,
        overhead_ms=2.0, io_ms_per_mb=0.30),
    # Table III: 21 TOPS INT8 (TensorRT path), 8 GB, 384 cores
    "xavier_nx": HardwareSpec(
        "Xavier NX", tops=0.50, mem_gb=8.0, cpu_cores=6,
        eff_max=0.50, eff_half=1.5, contention=0.06, mem_knee=0.78,
        overhead_ms=1.2, io_ms_per_mb=0.25),
    # TPU v5e serving cell (one chip's share of a pod slice)
    "tpu_v5e": HardwareSpec(
        "TPU v5e", tops=20.0, mem_gb=16.0, cpu_cores=8,
        eff_max=0.60, eff_half=4.0, contention=0.10, mem_knee=0.85,
        overhead_ms=0.3, io_ms_per_mb=0.05),
}
