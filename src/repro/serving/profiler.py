"""Performance profiler (paper §IV-E).

Periodically collects per-(model, b, m_c) execution records — throughput,
end-to-end latency, utilisation, memory — and exposes the aggregated
profile the scheduler and the interference predictor consume. This is the
component that lets BCEdge "avoid system overload and improve resource
utilization" (§IV-E): the guard asks it for observed latency quantiles and
the benchmark harness uses it to build Fig.-1-style surfaces from live
traffic instead of probe episodes.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.serving.simulator import CompletedRound, EdgeServingEnv


@dataclasses.dataclass
class ProfileEntry:
    """Aggregated per-(model, b, m_c) execution record (paper §IV-E)."""
    count: int = 0
    total_requests: int = 0
    lat_ms: List[float] = dataclasses.field(default_factory=list)
    exec_ms: List[float] = dataclasses.field(default_factory=list)
    violations: int = 0
    overflows: int = 0
    mem_gb: List[float] = dataclasses.field(default_factory=list)

    def add(self, rnd: CompletedRound) -> None:
        self.count += 1
        self.total_requests += rnd.n_requests
        self.lat_ms.extend(rnd.latencies_ms)
        self.exec_ms.append(rnd.finish_ms - rnd.start_ms)
        self.violations += rnd.violations
        self.overflows += int(rnd.overflow)
        self.mem_gb.append(rnd.mem_used_gb)

    def summary(self) -> Dict[str, float]:
        lat = np.asarray(self.lat_ms) if self.lat_ms else np.zeros(1)
        return {
            "rounds": float(self.count),
            "requests": float(self.total_requests),
            "mean_latency_ms": float(lat.mean()),
            "p95_latency_ms": float(np.percentile(lat, 95)),
            "mean_exec_ms": float(np.mean(self.exec_ms)) if self.exec_ms
            else 0.0,
            "violation_rate": self.violations / max(self.total_requests, 1),
            "overflow_rate": self.overflows / max(self.count, 1),
            "mean_mem_gb": float(np.mean(self.mem_gb)) if self.mem_gb
            else 0.0,
        }


class PerformanceProfiler:
    """Incremental consumer of the simulator's round history — the §IV-E
    periodic performance profiler (continuous-mode sessions are ingested
    the same way, one CompletedRound per session)."""

    def __init__(self, window_rounds: int = 512):
        self.window = window_rounds
        self.table: Dict[Tuple[str, int, int], ProfileEntry] = \
            defaultdict(ProfileEntry)
        self._seen = 0
        self._recent: List[CompletedRound] = []

    # ---- collection -----------------------------------------------------
    def poll(self, env: EdgeServingEnv) -> int:
        """Ingest rounds completed since the last poll. Returns #new."""
        new = env.history[self._seen:]
        self._seen = len(env.history)
        for rnd in new:
            self.table[(rnd.model, rnd.b, rnd.m_c)].add(rnd)
            self._recent.append(rnd)
        if len(self._recent) > self.window:
            self._recent = self._recent[-self.window:]
        return len(new)

    def reset_env(self) -> None:
        """Call when the env is reset (history index restarts)."""
        self._seen = 0

    # ---- queries ---------------------------------------------------------
    def profile(self, model: str, b: int, m_c: int
                ) -> Optional[Dict[str, float]]:
        e = self.table.get((model, b, m_c))
        return e.summary() if e else None

    def best_config(self, model: str, max_violation: float = 0.1
                    ) -> Optional[Tuple[int, int]]:
        """Highest-throughput (b, m_c) whose observed violation rate is
        within budget — the profiler-informed fallback configuration."""
        best, best_thr = None, -1.0
        for (m, b, mc), e in self.table.items():
            if m != model or e.count < 3:
                continue
            s = e.summary()
            thr = s["requests"] / max(sum(e.exec_ms) / 1000.0, 1e-6)
            if s["violation_rate"] <= max_violation and thr > best_thr:
                best, best_thr = (b, mc), thr
        return best

    def utilization(self) -> Dict[str, float]:
        """Recent-window platform-level metrics (§IV-E periodic report)."""
        if not self._recent:
            return {"mem_gb_mean": 0.0, "busy_frac": 0.0}
        span = max(self._recent[-1].finish_ms
                   - self._recent[0].decision_ms, 1e-3)
        busy = sum(r.finish_ms - r.start_ms for r in self._recent)
        return {
            "mem_gb_mean": float(np.mean([r.mem_used_gb
                                          for r in self._recent])),
            "busy_frac": min(1.0, busy / span),
        }

    def fig1_surface(self, model: str) -> Dict[Tuple[int, int],
                                               Dict[str, float]]:
        """Observed throughput/latency surface for one model (live Fig. 1)."""
        return {(b, mc): e.summary()
                for (m, b, mc), e in self.table.items() if m == model}
