"""Request model (§III-A-1), per-model queues with SLO-priority
ordering (§IV-C: "the shorter the SLO, the higher the priority"; FIFO
within equal priority), and the per-request lifecycle state machine the
async serving core pushes events through (docs/RUNTIME.md §11)."""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Callable, Dict, List, Optional

_counter = itertools.count()


# ---------------------------------------------------------------------
# request lifecycle state machine (docs/RUNTIME.md §11)
# ---------------------------------------------------------------------
#: lifecycle states: a request is QUEUED from submission until an engine
#: assigns it a slot, PREFILLING while its prompt chunks advance,
#: DECODING once tokens stream, and ends in exactly one terminal state.
#: Preemption sends DECODING back to QUEUED; the edge is annotated with
#: whether the KV was swapped to the host tier (resume skips recompute)
#: or freed (recompute-on-resume).
QUEUED = "queued"
PREFILL = "prefill"
DECODE = "decode"
FINISHED = "finished"
CANCELLED = "cancelled"
REJECTED = "rejected"

#: who may drive each edge is specified in docs/RUNTIME.md §11; the
#: machine itself only enforces the edge set. CANCELLED is reachable
#: from every non-terminal state (client disconnect at any phase).
LIFECYCLE_TRANSITIONS: Dict[str, frozenset] = {
    QUEUED: frozenset({PREFILL, DECODE, CANCELLED, REJECTED}),
    PREFILL: frozenset({DECODE, CANCELLED}),
    DECODE: frozenset({QUEUED, FINISHED, CANCELLED}),
    FINISHED: frozenset(),
    CANCELLED: frozenset(),
    REJECTED: frozenset(),
}

TERMINAL_STATES = frozenset({FINISHED, CANCELLED, REJECTED})


class RequestLifecycle:
    """Event-driven view of one request (docs/RUNTIME.md §11): the
    explicit QUEUED → PREFILL → DECODE → {FINISHED, CANCELLED, REJECTED}
    state machine, with wall-clock timestamps (enqueue, first token,
    finish) and per-token / per-event callbacks.

    The serving core owns the transitions (the engine reports
    slot-assignment and prefill completion, the pool reports terminal
    outcomes); the callbacks are how a streaming front-end observes
    them without polling. ``to()`` raises on an illegal edge — a
    lifecycle bug must fail loudly, not silently skip a state."""

    def __init__(self, request_id: int, enqueue_s: float,
                 on_event: Optional[Callable] = None,
                 on_token: Optional[Callable] = None):
        self.request_id = request_id
        self.state = QUEUED
        self.enqueue_s = enqueue_s
        self.admit_s = -1.0        # slot assignment (QUEUED -> PREFILL)
        self.first_token_s = -1.0  # first emitted token
        self.finish_s = -1.0       # terminal transition
        self.n_tokens = 0
        self.n_preempted = 0
        #: preemptions whose KV went to the host tier instead of being
        #: recomputed (subset of ``n_preempted``)
        self.n_swapped = 0
        #: ``on_event(lifecycle, state)`` after every transition;
        #: ``on_token(lifecycle, token, index)`` per emitted token
        self.on_event = on_event
        self.on_token = on_token

    @property
    def terminal(self) -> bool:
        return self.state in TERMINAL_STATES

    def to(self, state: str, now_s: float, swapped: bool = False) -> None:
        """Transition to ``state``, stamping the matching timestamp.
        ``swapped`` annotates the preemption edge (DECODE -> QUEUED):
        True means the KV pages moved to the host tier and resume will
        skip recompute. Raises ``ValueError`` on an edge outside
        ``LIFECYCLE_TRANSITIONS`` (e.g. FINISHED -> anything)."""
        if state not in LIFECYCLE_TRANSITIONS:
            raise ValueError(f"unknown lifecycle state {state!r}")
        if state not in LIFECYCLE_TRANSITIONS[self.state]:
            raise ValueError(
                f"illegal lifecycle transition {self.state} -> {state} "
                f"(request {self.request_id})")
        prev, self.state = self.state, state
        if state == PREFILL or (state == DECODE and prev == QUEUED):
            self.admit_s = now_s
        elif state == QUEUED:
            self.n_preempted += 1  # DECODE -> QUEUED is preemption
            if swapped:
                self.n_swapped += 1
        elif state in TERMINAL_STATES:
            self.finish_s = now_s
        if self.on_event is not None:
            self.on_event(self, state)

    def token(self, tok: int, index: int, now_s: float) -> None:
        """Record one emitted token (``index`` is the global position in
        the completion, stable across preemption/resume)."""
        if self.first_token_s < 0:
            self.first_token_s = now_s
        self.n_tokens = max(self.n_tokens, index + 1)
        if self.on_token is not None:
            self.on_token(self, tok, index)

    # ---- derived timing (the client-observed serving metrics) ------------
    def ttft_s(self) -> float:
        """Enqueue -> first token (negative means no token yet)."""
        return self.first_token_s - self.enqueue_s \
            if self.first_token_s >= 0 else -1.0

    def tpot_s(self) -> float:
        """Mean seconds per output token after the first (-1 before two
        tokens have landed)."""
        if self.first_token_s < 0 or self.n_tokens < 2 \
                or self.finish_s < 0:
            return -1.0
        return (self.finish_s - self.first_token_s) / (self.n_tokens - 1)


@dataclasses.dataclass(order=False)
class Request:
    """One inference request (paper §III-A-1 request model)."""
    model: str            # m_t: DNN model type
    input_type: str       # d_t: "image" | "text" | "speech"
    input_shape: tuple    # d_s
    slo_ms: float         # SLO_i
    arrival_ms: float
    seq: int = dataclasses.field(default_factory=lambda: next(_counter))
    #: decode iterations this request needs (1 = single-shot inference;
    #: >1 models an autoregressive request under exec_mode="continuous",
    #: docs/ARCHITECTURE.md §5)
    decode_steps: int = 1
    #: iterations still to run once admitted (continuous-mode bookkeeping)
    remaining: int = 0
    #: prompt tokens that must be prefilled before decoding (0 = the
    #: paper's single-shot regime; >0 models long-prompt arrivals whose
    #: prefill is chunked under the per-iteration token budget)
    prefill_tokens: int = 0
    #: prefill tokens still to process (reset to prompt + emitted context
    #: on preemption — recompute-on-resume, docs/RUNTIME.md §8)
    prefill_remaining: int = 0
    #: templated workload (docs/ARCHITECTURE.md §5): the leading
    #: ``prefix_tokens`` of the prompt are one of a small population of
    #: shared prefixes, identified by ``prefix_id`` (-1 = no shared
    #: prefix); a prefix-cache hit skips their prefill
    prefix_id: int = -1
    prefix_tokens: int = 0
    #: times this request was preempted (hysteresis caps it)
    n_preempted: int = 0
    # filled at dispatch/completion:
    start_ms: Optional[float] = None
    finish_ms: Optional[float] = None

    @property
    def deadline_ms(self) -> float:
        return self.arrival_ms + self.slo_ms

    def queue_wait_ms(self) -> float:
        assert self.start_ms is not None
        return self.start_ms - self.arrival_ms

    def latency_ms(self) -> float:
        assert self.finish_ms is not None
        return self.finish_ms - self.arrival_ms

    def violated(self) -> bool:
        return self.latency_ms() > self.slo_ms


class RequestQueue:
    """SLO-priority queue (paper §IV-C: "the shorter the SLO, the higher
    the priority"): pops shortest-SLO first, FIFO among equals."""

    def __init__(self, model: str, max_len: int = 4096):
        self.model = model
        self._heap: List[tuple] = []
        self.max_len = max_len
        self.dropped = 0

    def __len__(self) -> int:
        return len(self._heap)

    def push(self, r: Request) -> bool:
        if len(self._heap) >= self.max_len:
            self.dropped += 1
            return False
        heapq.heappush(self._heap, (r.slo_ms, r.seq, r))
        return True

    def pop_batch(self, b: int) -> List[Request]:
        out = []
        while self._heap and len(out) < b:
            out.append(heapq.heappop(self._heap)[2])
        return out

    def peek_oldest_age(self, now_ms: float) -> float:
        if not self._heap:
            return 0.0
        return max(now_ms - r.arrival_ms for _, _, r in self._heap)

    def peek_most_urgent(self, now_ms: float):
        """(slack_ms, request) of the queued request closest to its
        deadline — the preemption trigger reads this
        (docs/RUNTIME.md §8). (inf, None) when empty."""
        best, slack = None, float("inf")
        for _, _, r in self._heap:
            s = r.deadline_ms - now_ms
            if s < slack:
                best, slack = r, s
        return slack, best

    def slo_sum_ms(self, b: int) -> float:
        slos = sorted(r.slo_ms for _, _, r in self._heap)[:b]
        return float(sum(slos))
