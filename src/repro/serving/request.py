"""Request model (§III-A-1) and per-model queues with SLO-priority
ordering (§IV-C: "the shorter the SLO, the higher the priority"; FIFO
within equal priority)."""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import List, Optional

_counter = itertools.count()


@dataclasses.dataclass(order=False)
class Request:
    """One inference request (paper §III-A-1 request model)."""
    model: str            # m_t: DNN model type
    input_type: str       # d_t: "image" | "text" | "speech"
    input_shape: tuple    # d_s
    slo_ms: float         # SLO_i
    arrival_ms: float
    seq: int = dataclasses.field(default_factory=lambda: next(_counter))
    #: decode iterations this request needs (1 = single-shot inference;
    #: >1 models an autoregressive request under exec_mode="continuous",
    #: docs/ARCHITECTURE.md §5)
    decode_steps: int = 1
    #: iterations still to run once admitted (continuous-mode bookkeeping)
    remaining: int = 0
    #: prompt tokens that must be prefilled before decoding (0 = the
    #: paper's single-shot regime; >0 models long-prompt arrivals whose
    #: prefill is chunked under the per-iteration token budget)
    prefill_tokens: int = 0
    #: prefill tokens still to process (reset to prompt + emitted context
    #: on preemption — recompute-on-resume, docs/RUNTIME.md §8)
    prefill_remaining: int = 0
    #: templated workload (docs/ARCHITECTURE.md §5): the leading
    #: ``prefix_tokens`` of the prompt are one of a small population of
    #: shared prefixes, identified by ``prefix_id`` (-1 = no shared
    #: prefix); a prefix-cache hit skips their prefill
    prefix_id: int = -1
    prefix_tokens: int = 0
    #: times this request was preempted (hysteresis caps it)
    n_preempted: int = 0
    # filled at dispatch/completion:
    start_ms: Optional[float] = None
    finish_ms: Optional[float] = None

    @property
    def deadline_ms(self) -> float:
        return self.arrival_ms + self.slo_ms

    def queue_wait_ms(self) -> float:
        assert self.start_ms is not None
        return self.start_ms - self.arrival_ms

    def latency_ms(self) -> float:
        assert self.finish_ms is not None
        return self.finish_ms - self.arrival_ms

    def violated(self) -> bool:
        return self.latency_ms() > self.slo_ms


class RequestQueue:
    """SLO-priority queue (paper §IV-C: "the shorter the SLO, the higher
    the priority"): pops shortest-SLO first, FIFO among equals."""

    def __init__(self, model: str, max_len: int = 4096):
        self.model = model
        self._heap: List[tuple] = []
        self.max_len = max_len
        self.dropped = 0

    def __len__(self) -> int:
        return len(self._heap)

    def push(self, r: Request) -> bool:
        if len(self._heap) >= self.max_len:
            self.dropped += 1
            return False
        heapq.heappush(self._heap, (r.slo_ms, r.seq, r))
        return True

    def pop_batch(self, b: int) -> List[Request]:
        out = []
        while self._heap and len(out) < b:
            out.append(heapq.heappop(self._heap)[2])
        return out

    def peek_oldest_age(self, now_ms: float) -> float:
        if not self._heap:
            return 0.0
        return max(now_ms - r.arrival_ms for _, _, r in self._heap)

    def peek_most_urgent(self, now_ms: float):
        """(slack_ms, request) of the queued request closest to its
        deadline — the preemption trigger reads this
        (docs/RUNTIME.md §8). (inf, None) when empty."""
        best, slack = None, float("inf")
        for _, _, r in self._heap:
            s = r.deadline_ms - now_ms
            if s < slack:
                best, slack = r, s
        return slack, best

    def slo_sum_ms(self, b: int) -> float:
        slos = sorted(r.slo_ms for _, _, r in self._heap)[:b]
        return float(sum(slos))
