"""Multi-model concurrent serving runtime: the REAL m_c axis
(docs/RUNTIME.md; state machine, admission rules and Eq.-1 accounting
are specified there).

BCEdge's scheduler co-optimises batch size and the number of concurrent
model instances, but until this module the second axis only existed
analytically in the simulator. ``ModelInstancePool`` owns N live
``ContinuousBatchingEngine`` instances across heterogeneous
``ModelConfig``s, so a ``(b, m_c)`` action really creates/destroys
concurrent engine instances:

* **router** — one earliest-deadline-first queue per model; at every
  iteration boundary waiting requests are admitted into the least-loaded
  RUNNING instance of their model (docs/RUNTIME.md admission rules);
* **lifecycle** — ``scale_to(model, m_c)`` spawns or drains instances
  (STARTING → RUNNING → DRAINING → RETIRED); draining instances finish
  their resident sequences before they are retired, so scale-down never
  truncates in-flight work;
* **interference path** — every ``step()`` measures the wall-clock
  iteration latency together with the number of live instances that
  overlapped it; the samples calibrate the contention model
  (``latency_model.fit_contention``) and, via ``engine_features``, feed
  the §IV-F NN interference predictor with real measurements.

Instances of the same model share weights and jit caches
(``ContinuousBatchingEngine(share_from=...)``) so ``spawn`` is cheap
enough to be a per-decision action; each instance keeps its own KV slot
cache, which is what actually bounds m_c on a real host.

Under ``kv_layout="paged"`` (docs/RUNTIME.md §7) every engine uses the
block-pool KV layout and the pool shares ONE ``kv_block_budget`` across
instances: ``spawn``/``scale_to`` are constrained by actual free blocks,
the router's admission gate is the per-engine ``BlockAllocator``, and
every pure-decode iteration records real occupancy samples that
calibrate ``latency_model.fit_occupancy`` — the measured memory model
the ``PoolScheduler``'s Eq.-4 guard checks proposed (b, m_c) actions
against, in place of the analytic ``instance_memory_gb`` curve.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.config.base import ModelConfig
from repro.core.interference import engine_features
from repro.core.utility import utility
from repro.serving import latency_model as lm
from repro.serving import request as lifecycle
from repro.serving.engine import (ContinuousBatchingEngine,
                                  ContinuousResult, PreemptedRequest,
                                  supports_prefix_cache,
                                  supports_speculation, to_recompute)
from repro.serving.request import RequestLifecycle

# instance lifecycle states (docs/RUNTIME.md state machine)
STARTING = "starting"
RUNNING = "running"
DRAINING = "draining"
RETIRED = "retired"

_seq = itertools.count()

#: trailing window the contention/occupancy fits read (and the bound the
#: sample lists are trimmed to, so long-lived serving loops do not leak)
_SAMPLE_WINDOW = 512


@dataclasses.dataclass
class PoolRequest:
    """One request routed by the pool (paper §III-A-1, with an absolute
    deadline for the EDF router)."""
    request_id: int
    model: str
    prompt: np.ndarray
    slo_ms: float
    max_new_tokens: int
    submit_s: float            # pool clock
    admit_s: float = -1.0      # set by the router at admission
    #: preempted-sequence snapshot awaiting re-admission; the router
    #: resumes it via ``engine.submit_resume`` instead of a fresh submit
    #: (docs/RUNTIME.md §8)
    resume: Optional[PreemptedRequest] = None
    n_preempted: int = 0
    #: pool-clock time the first token landed (-1 before any token);
    #: pool-level so it survives cross-instance preemption/resume, where
    #: engine clocks are not comparable
    first_token_s: float = -1.0
    #: tokens streamed to listeners so far (highest global index + 1)
    n_streamed: int = 0
    #: push-mode state machine + callbacks (docs/RUNTIME.md §11); always
    #: attached by ``submit`` — front-ends hook it via ``add_listener``
    lifecycle: Optional[RequestLifecycle] = None

    @property
    def deadline_s(self) -> float:
        return self.submit_s + self.slo_ms / 1000.0


@dataclasses.dataclass
class PoolResult:
    """One finished (or rejected) request, with per-request Eq.-3
    utility computed at completion time."""
    request_id: int
    model: str
    instance_id: int           # -1 when rejected before admission
    tokens: np.ndarray
    submit_s: float
    admit_s: float
    finish_s: float
    slo_ms: float
    utility: float = 0.0
    rejected: bool = False
    #: torn down before finishing (client disconnect / explicit cancel);
    #: ``tokens`` holds the partial completion
    cancelled: bool = False
    #: pool-clock first-token time (-1 if no token landed)
    first_token_s: float = -1.0

    @property
    def latency_ms(self) -> float:
        return (self.finish_s - self.submit_s) * 1000.0

    @property
    def ttft_ms(self) -> float:
        """Submit -> first token (-1 if no token landed)."""
        return (self.first_token_s - self.submit_s) * 1000.0 \
            if self.first_token_s >= 0 else -1.0

    @property
    def tpot_ms(self) -> float:
        """Mean ms per token after the first (-1 below two tokens)."""
        if self.first_token_s < 0 or len(self.tokens) < 2:
            return -1.0
        return (self.finish_s - self.first_token_s) * 1000.0 \
            / (len(self.tokens) - 1)

    @property
    def violated(self) -> bool:
        # a cancelled request has no completion to be late — the client
        # walked away; report() counts cancellations separately
        if self.cancelled:
            return False
        return self.rejected or self.latency_ms > self.slo_ms


class ModelInstance:
    """One live engine instance plus its lifecycle state and the pool's
    per-instance bookkeeping (resident requests, Eq.-1 slot share)."""

    def __init__(self, instance_id: int, model: str,
                 engine: ContinuousBatchingEngine, kv_blocks: int = 0,
                 tp_degree: int = 1):
        self.instance_id = instance_id
        self.model = model
        self.engine = engine
        self.kv_blocks = kv_blocks  # share of the pool's block budget
        self.tp_degree = tp_degree  # devices this instance spans
        self.state = STARTING
        self.requests: Dict[int, PoolRequest] = {}  # engine rid -> request
        self.n_served = 0

    @property
    def n_resident(self) -> int:
        """Sequences currently owned by this instance (decoding or
        waiting inside the engine for the next iteration boundary)."""
        return len(self.requests)

    @property
    def free_capacity(self) -> int:
        return self.engine.n_slots - self.n_resident

    @property
    def slo_sum_ms(self) -> float:
        """Σ SLO over resident requests — this instance's contribution to
        the model's Eq.-1 scheduling slot (docs/RUNTIME.md)."""
        return sum(r.slo_ms for r in self.requests.values())

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"ModelInstance({self.instance_id}, {self.model!r}, "
                f"{self.state}, resident={self.n_resident})")


class ModelInstancePool:
    """N concurrent engine instances behind per-model EDF queues
    (docs/RUNTIME.md). The unit of progress is ``step()``: route waiting
    requests, run one decode iteration on every busy instance, retire
    empty draining instances, and record the iteration's wall latency
    against the overlap level for interference calibration."""

    def __init__(self, configs: Dict[str, ModelConfig],
                 max_instances: int = 8, max_slots: int = 4,
                 max_seq: int = 128, seed: int = 0,
                 strict_admission: bool = False,
                 predictor=None, kv_layout: str = "dense",
                 block_size: int = 16,
                 kv_block_budget: Optional[int] = None,
                 blocks_per_instance: Optional[int] = None,
                 preemption: bool = False,
                 preempt_margin_ms: float = 50.0,
                 preempt_cooldown_steps: int = 8,
                 max_preemptions: int = 2,
                 kv_host_blocks: int = 0,
                 preempt_mode: str = "auto",
                 token_budget: Optional[int] = None,
                 prefix_cache: bool = False,
                 spec_k: int = 0,
                 tp_degree: int = 1,
                 n_devices: Optional[int] = None):
        self.configs = dict(configs)
        self.max_instances = max_instances
        self.max_slots = max_slots
        self.max_seq = max_seq
        self.seed = seed
        self.strict_admission = strict_admission
        self.predictor = predictor
        #: paged KV serving (docs/RUNTIME.md §7): every instance's engine
        #: uses the block-pool layout and the pool shares ONE block
        #: budget across all instances — memory becomes a managed
        #: resource instead of the analytic latency_model curve
        self.kv_layout = kv_layout
        self.block_size = block_size
        self.kv_block_budget = kv_block_budget
        self.kv_blocks_free = kv_block_budget
        #: vLLM-style prefix caching (docs/ARCHITECTURE.md §5): paged
        #: engines share full immutable prompt blocks at refcount+1 and
        #: the router gains prefix affinity. Models whose layer stack
        #: cannot page every decode state (recurrent/windowed/frontend)
        #: silently serve without it — per-model capability, one flag.
        self.prefix_cache = prefix_cache and kv_layout == "paged"
        #: speculative decoding (docs/ARCHITECTURE.md §speculation): the
        #: construction-time CAP on the proposal depth — engines are
        #: built with scratch capacity for ``spec_cap`` draft tokens and
        #: the scheduler's fourth axis (``set_spec_k``) moves the LIVE
        #: depth anywhere in [0, spec_cap] without respawning. Models
        #: whose cache cannot rewind (recurrent/windowed) silently serve
        #: with k=0, mirroring the prefix-cache capability gate.
        self.spec_cap = max(0, spec_k)
        self.spec_ks: Dict[str, int] = {m: self.spec_cap for m in configs}
        #: tensor parallelism (docs/RUNTIME.md §10): per-model TP degree
        #: — the scheduler's fifth axis (``set_tp_degree``). An instance
        #: at degree d spans d devices of the shared device set on a 1D
        #: ``("model",)`` mesh (heads sharded, block tables replicated),
        #: so ``m_c`` and the degree jointly partition the hardware:
        #: Σ tp over live instances is capped by ``n_devices`` when set.
        #: Unlike spec_k a live engine cannot re-shard, so a degree
        #: change drains mismatched instances and respawns via scale_to.
        self.tp_degrees: Dict[str, int] = {
            m: max(1, tp_degree) for m in configs}
        self.n_devices = n_devices
        #: one mesh per degree, lazily built over the FIRST tp devices —
        #: value-equal meshes are what lets same-degree instances share
        #: the weight/jit template (engine.share_from requires it). On
        #: the symmetric host meshes this runtime targets, which slice
        #: an instance sits on is interchangeable; the scheduler prices
        #: the device BUDGET, not slice identity.
        self._meshes: Dict[int, object] = {}
        #: target grant for a paged instance; default = dense-equivalent
        #: worst case. Sizing it from measured occupancy
        #: (``occupancy_tokens_per_seq``) is how a paged pool fits more
        #: instances into the same budget than dense slabs allow.
        self.blocks_per_instance = blocks_per_instance
        #: SLO-aware preemption policy (docs/RUNTIME.md §8): when the
        #: most urgent waiting request cannot be admitted anywhere and
        #: its slack no longer covers its predicted service time, evict
        #: the largest-slack resident (never a mid-chunk prefill), with
        #: margin + cooldown + per-request-cap hysteresis against thrash
        self.preemption = preemption
        self.preempt_margin_ms = preempt_margin_ms
        self.preempt_cooldown_steps = preempt_cooldown_steps
        self.max_preemptions = max_preemptions
        self.n_preempted = 0
        self.preempts_by_model: Dict[str, int] = {m: 0 for m in configs}
        self._last_preempt_step: Dict[str, int] = {}
        #: host KV tier (docs/RUNTIME.md §8): per-instance host-memory
        #: block pool preempted sequences swap into instead of being
        #: recomputed. ``preempt_mode`` picks the eviction flavour:
        #: "recompute" (legacy), "swap" (always swap when the slot can),
        #: or "auto" — price both with the calibrated token-cost and
        #: swap-bandwidth fits and take the cheaper (``swap_cost``).
        if preempt_mode not in ("recompute", "swap", "auto"):
            raise ValueError(
                f"preempt_mode must be 'recompute', 'swap' or 'auto', "
                f"got {preempt_mode!r}")
        if kv_host_blocks < 0:
            raise ValueError(
                f"kv_host_blocks must be >= 0, got {kv_host_blocks}")
        if kv_host_blocks > 0 and kv_layout != "paged":
            raise ValueError(
                "kv_host_blocks needs kv_layout='paged' (the host tier "
                "swaps block-granular KV)")
        self.kv_host_blocks = kv_host_blocks
        self.preempt_mode = preempt_mode
        self.n_swap_preempted = 0
        #: per-model per-iteration token budget applied to every live
        #: engine (None = uncapped); the scheduler's third knob
        self.token_budgets: Dict[str, Optional[int]] = {
            m: token_budget for m in configs}
        #: (tokens processed, iteration wall ms) over non-compiling busy
        #: iterations — calibrates latency_model.fit_token_cost
        self.token_samples: List[Tuple[int, float]] = []
        #: the same samples keyed by TP degree, recorded only on
        #: iterations whose busy instances all share one degree — the
        #: per-degree token-cost fits the guard prices layouts with
        #: (mixed-degree iterations feed only the global fit)
        self.tp_token_samples: Dict[int, List[Tuple[int, float]]] = {}
        #: (total resident sequences, Σ kv_used_tokens) per pure-decode
        #: iteration — calibrates latency_model.fit_occupancy
        self.occupancy_samples: List[Tuple[int, int]] = []
        self.instances: Dict[str, List[ModelInstance]] = {
            m: [] for m in self.configs}
        self.slot_caps: Dict[str, int] = {m: max_slots for m in self.configs}
        self.queues: Dict[str, List[tuple]] = {m: [] for m in self.configs}
        #: weight/jit donors keyed (model, tp_degree) — instances share
        #: a template only at the same degree (sharded params live on
        #: that degree's mesh)
        self._templates: Dict[Tuple[str, int],
                              ContinuousBatchingEngine] = {}
        self.admission_log: List[Tuple[int, int]] = []  # (request, instance)
        self.retired: List[ModelInstance] = []
        self.n_rejected = 0
        self.n_cancelled = 0
        self.n_steps = 0
        #: per-request event listeners (docs/RUNTIME.md §11): request_id
        #: -> callable taking one dict per event ("prefill", "decode",
        #: "token", "preempted", "finished", "cancelled", "rejected").
        #: Fired synchronously inside pool calls — a front-end bridges to
        #: its own loop (e.g. asyncio call_soon_threadsafe). A listener
        #: that raises is dropped: one dead client must not take the
        #: serving loop down.
        self._listeners: Dict[int, Callable] = {}
        self.n_listener_errors = 0
        #: client-observed serving metrics, HTTP-independent (pool clock,
        #: submit -> first token / finish): ms samples for the stats()
        #: percentiles, trimmed to the trailing window like every other
        #: sample list
        self.ttft_samples: List[float] = []
        self.tpot_samples: List[float] = []
        #: (total live instances, iteration wall ms) calibration samples
        self.contention_samples: List[Tuple[int, float]] = []
        self._results: Dict[str, List[PoolResult]] = {
            m: [] for m in self.configs}
        self._next_rid = 0
        self._next_iid = 0
        self._t0 = time.perf_counter()

    # ---- clock -----------------------------------------------------------
    def now(self) -> float:
        return time.perf_counter() - self._t0

    # ---- push-mode events (docs/RUNTIME.md §11) --------------------------
    def add_listener(self, request_id: int, fn: Callable) -> None:
        """Register ``fn(event_dict)`` for every lifecycle event of
        ``request_id``. One listener per request; removed automatically
        on the terminal event (or when it raises)."""
        self._listeners[request_id] = fn

    def remove_listener(self, request_id: int) -> None:
        self._listeners.pop(request_id, None)

    def _emit(self, req: PoolRequest, event: str, **payload) -> None:
        fn = self._listeners.get(req.request_id)
        if fn is None:
            return
        ev = {"event": event, "request_id": req.request_id,
              "t_s": self.now()}
        ev.update(payload)
        try:
            fn(ev)
        except Exception:  # noqa: BLE001 — dead client, not our bug
            self.n_listener_errors += 1
            self._listeners.pop(req.request_id, None)

    def _on_engine_token(self, inst: "ModelInstance", erid: int,
                         tok: int, idx: int) -> None:
        """Engine emitted one token for the sequence it knows as
        ``erid``: stamp pool-clock first-token time and push the event.
        ``idx`` is the global completion index (stable across
        preemption), so ``n_streamed`` never double-counts a resume."""
        req = inst.requests.get(erid)
        if req is None:  # defensive: engine-local sequence (warm drain)
            return
        now = self.now()
        if req.first_token_s < 0:
            req.first_token_s = now
        req.n_streamed = max(req.n_streamed, idx + 1)
        if req.lifecycle is not None:
            req.lifecycle.token(int(tok), int(idx), now)
        self._emit(req, "token", token=int(tok), index=int(idx))

    def _on_engine_state(self, inst: "ModelInstance", erid: int,
                         state: str) -> None:
        """Engine moved the sequence between phases ("prefill" at slot
        assignment, "decode" at prefill completion) — advance the
        lifecycle machine and surface the event."""
        req = inst.requests.get(erid)
        if req is None:
            return
        if req.lifecycle is not None and not req.lifecycle.terminal:
            req.lifecycle.to(state, self.now())
        self._emit(req, state, instance_id=inst.instance_id)

    # ---- lifecycle (docs/RUNTIME.md state machine) -----------------------
    def live(self, model: Optional[str] = None) -> List[ModelInstance]:
        """RUNNING + DRAINING instances (they still hold resources)."""
        models = [model] if model else list(self.instances)
        return [i for m in models for i in self.instances[m]
                if i.state in (RUNNING, DRAINING)]

    def running(self, model: str) -> List[ModelInstance]:
        return [i for i in self.instances[model] if i.state == RUNNING]

    def m_c(self, model: str) -> int:
        return len(self.running(model))

    def total_live(self) -> int:
        return len(self.live())

    def busy_count(self) -> int:
        """Live instances with resident work — the overlap level the
        contention samples are recorded against (idle instances cost no
        iteration time, so predictions must not count them)."""
        return sum(1 for i in self.live() if i.n_resident > 0)

    # ---- tensor parallelism (docs/RUNTIME.md §10) ------------------------
    def devices_in_use(self) -> int:
        """Devices the live instances span: Σ tp_degree. With
        ``n_devices`` set this is what bounds further spawns — m_c and
        TP degree jointly partition the shared device set."""
        return sum(i.tp_degree for i in self.live())

    def _tp_mesh(self, tp: int):
        """The shared 1D ``("model",)`` mesh for degree ``tp`` (None for
        tp=1: single-device engines never touch jax device state).
        Cached per degree so every same-degree instance spans a
        value-equal mesh and can share the weight/jit template."""
        if tp <= 1:
            return None
        mesh = self._meshes.get(tp)
        if mesh is None:
            from repro.launch.mesh import make_tp_mesh
            mesh = make_tp_mesh(tp)
            self._meshes[tp] = mesh
        return mesh

    def set_tp_degree(self, model: str, tp: int) -> None:
        """The fifth knob (docs/RUNTIME.md §10): TP degree for future
        spawns of ``model``. A live engine cannot re-shard its mesh in
        place, so RUNNING instances at a different degree start
        DRAINING (resident work completes first) and the next
        ``scale_to`` respawns at the new degree."""
        tp = max(1, tp)
        if self.tp_degrees.get(model) == tp:
            return
        self.tp_degrees[model] = tp
        for inst in self.instances[model]:
            if inst.state == RUNNING and inst.tp_degree != tp:
                inst.state = DRAINING

    def _dense_equiv_blocks(self) -> int:
        """Dense-equivalent worst-case grant: the whole
        (max_slots, max_seq) slab expressed in blocks — what a dense
        instance COMMITS by construction."""
        return self.max_slots * (-(-self.max_seq // self.block_size))

    def _min_viable_blocks(self) -> int:
        """Smallest grant a spawned paged instance can serve with: one
        slot's worst case, or the operator's explicit (right-sized)
        ``blocks_per_instance`` target if that is smaller — deliberate
        oversubscription against measured occupancy."""
        one_slot = -(-self.max_seq // self.block_size)
        if self.blocks_per_instance:
            return min(one_slot, self.blocks_per_instance)
        return one_slot

    def _spawn_grant(self) -> int:
        """Blocks the next spawn would charge against the budget."""
        if self.kv_layout != "paged":
            return self._dense_equiv_blocks()
        return self.blocks_per_instance or self._dense_equiv_blocks()

    def can_spawn(self, model: Optional[str] = None) -> bool:
        """Instance budget, device budget AND block budget allow one
        more spawn — ``scale_to`` is constrained by actual free blocks,
        not the analytic memory curve. A dense instance must fit its
        whole slab; a paged one can start on a partial grant (min one
        slot). ``model`` prices that model's TP degree against the
        shared device set (degree 1 assumed when omitted)."""
        if self.total_live() >= self.max_instances:
            return False
        tp = self.tp_degrees.get(model, 1) if model else 1
        if self.n_devices is not None and \
                self.devices_in_use() + tp > self.n_devices:
            return False
        if self.kv_blocks_free is None:
            return True
        if self.kv_layout == "paged":
            return self.kv_blocks_free >= \
                -(-self._min_viable_blocks() // tp)
        return self.kv_blocks_free >= self._dense_equiv_blocks()

    def spawn(self, model: str) -> ModelInstance:
        """STARTING → RUNNING. Raises when the pool-wide instance
        budget, the shared device set or the shared KV block budget is
        exhausted (use scale_to for clamped semantics)."""
        if self.total_live() >= self.max_instances:
            raise RuntimeError(
                f"pool at max_instances={self.max_instances}")
        tp = self.tp_degrees.get(model, 1)
        if self.n_devices is not None and \
                self.devices_in_use() + tp > self.n_devices:
            raise RuntimeError(
                f"device budget exhausted: {model!r} at tp_degree={tp} "
                f"needs {tp} of {self.n_devices} devices, "
                f"{self.n_devices - self.devices_in_use()} free")
        grant = self._spawn_grant()
        charge = grant
        kw = {}
        if self.kv_blocks_free is not None:
            if self.kv_layout == "paged":
                # head-sharding spreads every block over the instance's
                # tp devices, so one budget (per-device) block buys tp
                # pool blocks: the charge is ceil(grant / tp) and the
                # engine keeps the full grant (docs/RUNTIME.md §10)
                charge = min(-(-grant // tp), self.kv_blocks_free)
                grant = charge * tp
                if grant < self._min_viable_blocks():
                    raise RuntimeError(
                        f"KV block budget exhausted "
                        f"({self.kv_blocks_free} free of "
                        f"{self.kv_block_budget})")
            elif self.kv_blocks_free < charge:
                raise RuntimeError(
                    f"KV block budget exhausted: dense slab needs "
                    f"{charge} blocks, {self.kv_blocks_free} free")
            self.kv_blocks_free -= charge
        elif self.kv_layout != "paged":
            grant = charge = 0  # unlimited dense pool: nothing to account
        if self.kv_layout == "paged":
            kw = {"kv_layout": "paged", "block_size": self.block_size,
                  "kv_blocks": grant,
                  "prefix_cache": self.prefix_cache
                  and supports_prefix_cache(self.configs[model]),
                  # host tier is single-device: sharded instances keep
                  # recompute-on-resume (the engine rejects the combo)
                  "kv_host_blocks": self.kv_host_blocks if tp == 1 else 0}
        if self.spec_cap > 0 and supports_speculation(self.configs[model]):
            kw["spec_k"] = self.spec_cap
        tmpl = self._templates.get((model, tp))
        eng = ContinuousBatchingEngine(
            self.configs[model], max_slots=self.max_slots,
            max_seq=self.max_seq, seed=self.seed, share_from=tmpl,
            token_budget=self.token_budgets.get(model),
            mesh=self._tp_mesh(tp), **kw)
        # spawn into the CURRENT scheduler-set depth (≤ the built cap)
        eng.spec_k = min(self.spec_ks.get(model, 0), eng.spec_max)
        if tmpl is None:
            self._templates[(model, tp)] = eng
        inst = ModelInstance(self._next_iid, model, eng, kv_blocks=charge,
                             tp_degree=tp)
        # push-mode hooks (docs/RUNTIME.md §11): the engine reports
        # per-token emissions and phase changes; the pool translates
        # engine request ids to pool requests and fans out to listeners
        eng.on_token = (lambda erid, tok, idx, _inst=inst:
                        self._on_engine_token(_inst, erid, tok, idx))
        eng.on_state = (lambda erid, state, _inst=inst:
                        self._on_engine_state(_inst, erid, state))
        self._next_iid += 1
        self.instances[model].append(inst)
        inst.state = RUNNING  # engine construction == warm start
        return inst

    def drain(self, model: str, instance_id: Optional[int] = None) -> None:
        """RUNNING → DRAINING: no new admissions; resident sequences run
        to completion, then the sweep retires the instance."""
        for inst in self.instances[model]:
            if inst.state == RUNNING and (instance_id is None
                                          or inst.instance_id == instance_id):
                inst.state = DRAINING
                if instance_id is not None:
                    return

    def scale_to(self, model: str, m_c: int) -> int:
        """Set the RUNNING instance count for ``model`` (idempotent).

        Scaling up revives DRAINING instances first (cheapest — their
        engine is already warm), then spawns, clamped to the pool-wide
        ``max_instances`` budget shared by all models. Scaling down
        drains the least-loaded instances. Returns the RUNNING count
        actually reached.
        """
        m_c = max(0, m_c)
        run = self.running(model)
        if len(run) > m_c:
            for inst in sorted(run, key=lambda i: i.n_resident)[
                    : len(run) - m_c]:
                inst.state = DRAINING
            return m_c
        # revive only degree-matched instances: reviving a stale-degree
        # engine would undo a set_tp_degree decision
        draining = [i for i in self.instances[model]
                    if i.state == DRAINING
                    and i.tp_degree == self.tp_degrees.get(model, 1)]
        while len(self.running(model)) < m_c and draining:
            draining.pop(0).state = RUNNING  # revive
        while len(self.running(model)) < m_c and self.can_spawn(model):
            self.spawn(model)
        return len(self.running(model))

    def set_slot_cap(self, model: str, b: int) -> None:
        """The b axis on a live engine: cap concurrently-active slots per
        instance at ``min(b, max_slots)`` (engine slot count is fixed at
        construction; the router enforces the cap at admission)."""
        self.slot_caps[model] = max(1, min(b, self.max_slots))

    def set_token_budget(self, model: str, budget: Optional[int]) -> None:
        """The third knob (docs/RUNTIME.md §8): per-iteration cap on
        prefill-chunk + decode tokens, applied to every live engine of
        ``model`` and inherited by future spawns. ``None`` (or 0) lifts
        the cap."""
        budget = budget or None
        self.token_budgets[model] = budget
        for inst in self.instances[model]:
            if inst.engine is not None:
                inst.engine.token_budget = budget

    def set_spec_k(self, model: str, k: int) -> None:
        """The fourth knob (docs/RUNTIME.md §9): per-iteration speculative
        proposal depth, applied to every live engine of ``model`` and
        inherited by future spawns. Clamped per-engine to the scratch
        capacity the engine was built with (``spec_max``) — an engine
        built without speculation clamps to 0, so the call is always
        safe regardless of model capability."""
        k = max(0, k)
        self.spec_ks[model] = k
        for inst in self.instances[model]:
            if inst.engine is not None:
                inst.engine.spec_k = min(k, inst.engine.spec_max)

    def prefill_backlog_tokens(self, model: Optional[str] = None) -> int:
        """Prompt tokens queued or mid-chunk across the live instances of
        ``model`` (or all models) — a scheduler state feature."""
        return sum(i.engine.prefill_backlog_tokens
                   for i in self.live(model))

    def _sweep(self) -> None:
        """DRAINING instances with no resident work → RETIRED; the engine
        (its KV slot cache) is dropped so the memory really frees."""
        for model, lst in self.instances.items():
            keep = []
            retired_any = False
            for inst in lst:
                if inst.state == DRAINING and inst.n_resident == 0:
                    inst.state = RETIRED
                    inst.engine = None
                    retired_any = True
                    if self.kv_blocks_free is not None:
                        # the instance's KV block grant returns to the
                        # shared budget (the paged analogue of dropping
                        # the dense slot cache)
                        self.kv_blocks_free += inst.kv_blocks
                    inst.kv_blocks = 0
                    self.retired.append(inst)
                else:
                    keep.append(inst)
            self.instances[model] = keep
            if not keep:
                # last instance gone: drop the shared weight/jit
                # templates (every degree) so the model's memory really
                # frees (live instances hold their own references, so
                # this is always safe)
                for key in [k for k in self._templates if k[0] == model]:
                    self._templates.pop(key)
                if retired_any:
                    # per-model preemption bookkeeping dies with the
                    # last instance: a model respawned after scale_to(0)
                    # must not start inside a stale cooldown window or
                    # inherit an inflated preempt count
                    self.preempts_by_model[model] = 0
                    self._last_preempt_step.pop(model, None)

    # ---- router (docs/RUNTIME.md admission rules) ------------------------
    def submit(self, model: str, prompt: np.ndarray, slo_ms: float = 1000.0,
               max_new_tokens: int = 8,
               submit_s: Optional[float] = None) -> int:
        if model not in self.configs:
            raise KeyError(f"unknown model {model!r}; "
                           f"pool serves {sorted(self.configs)}")
        rid = self._next_rid
        self._next_rid += 1
        req = PoolRequest(rid, model, np.asarray(prompt, np.int32), slo_ms,
                          max_new_tokens,
                          self.now() if submit_s is None else submit_s)
        req.lifecycle = RequestLifecycle(rid, req.submit_s)
        heapq.heappush(self.queues[model],
                       (req.deadline_s, next(_seq), req))
        return rid

    # ---- cancellation (docs/RUNTIME.md §11) ------------------------------
    def _dequeue(self, model: str, request_id: int
                 ) -> Optional[PoolRequest]:
        """Remove ``request_id`` from the model's EDF queue EAGERLY
        (swap-pop + re-heapify). Eager removal is what fixes queue-head
        starvation on cancellation: a cancelled head used to sit in the
        heap blocking FIFO admission of everything behind it until an
        admission pass happened to reject it."""
        q = self.queues[model]
        for qi, (_, _, req) in enumerate(q):
            if req.request_id == request_id:
                q[qi] = q[-1]
                q.pop()
                heapq.heapify(q)
                return req
        return None

    def cancel(self, request_id: int) -> Optional[PoolResult]:
        """Tear down ``request_id`` wherever it lives — the EDF queue
        (including a preempted snapshot awaiting re-admission), an
        engine's waiting list, a mid-prefill slot, or a decoding slot —
        freeing its blocks synchronously. Returns the cancelled
        ``PoolResult`` (partial tokens included), or ``None`` when the
        id is unknown or already terminal (cancel after finish is a
        no-op: the race is inherent to streaming clients)."""
        for model in self.queues:
            req = self._dequeue(model, request_id)
            if req is not None:
                # a preempted snapshot carries its pre-eviction tokens
                snap = req.resume
                if snap is not None and snap.swapped:
                    # the snapshot will never resume: its host blocks go
                    # back to the source engine's host tier (nothing to
                    # free when that engine is already retired — the
                    # host pool died with it)
                    src = self._swap_source(model, snap)
                    if src is not None:
                        src.engine.allocator.host_free(snap.host_blocks)
                    tokens = np.asarray(snap.tokens, np.int32)
                elif snap is not None:
                    tokens = np.asarray(snap.seq_tokens[snap.base_len:],
                                        np.int32)
                else:
                    tokens = np.zeros((0,), np.int32)
                return self._finish_cancel(req, None, tokens)
        for inst in self.live():
            for erid, req in list(inst.requests.items()):
                if req.request_id != request_id:
                    continue
                r = inst.engine.cancel(erid)
                if r is None:  # engine already finished it this step
                    return None
                inst.requests.pop(erid, None)
                return self._finish_cancel(req, inst, r.tokens)
        return None

    def _finish_cancel(self, req: PoolRequest,
                       inst: Optional["ModelInstance"],
                       tokens: np.ndarray) -> PoolResult:
        now = self.now()
        res = PoolResult(req.request_id, req.model,
                         inst.instance_id if inst is not None else -1,
                         tokens, req.submit_s, req.admit_s, now,
                         req.slo_ms, utility=0.0, cancelled=True,
                         first_token_s=req.first_token_s)
        self.n_cancelled += 1
        self._results[req.model].append(res)
        if req.lifecycle is not None and not req.lifecycle.terminal:
            req.lifecycle.to(lifecycle.CANCELLED, now)
        self._emit(req, "cancelled", tokens=[int(t) for t in tokens])
        self._listeners.pop(req.request_id, None)
        return res

    def queue_len(self, model: str) -> int:
        return len(self.queues[model])

    def admission_headroom(self, model: str, prompt_len: int,
                           max_new_tokens: int) -> Dict[str, float]:
        """Backpressure signal for a front-end (docs/RUNTIME.md §11):
        could a request of this shape start NOW, and if not, when is it
        worth retrying? ``admissible_now`` is the engines' real admission
        gate (free slot + reservable blocks under the slot cap);
        ``retry_after_s`` prices the work queued ahead — prefill backlog
        plus queued requests' footprints plus this request's own — with
        the calibrated per-token iteration cost, falling back to a
        queue-depth heuristic before calibration."""
        cap = self.slot_caps[model]
        admissible_now = any(
            cap - i.n_resident > 0
            and i.engine.admissible(prompt_len, max_new_tokens)
            for i in self.running(model))
        qdepth = len(self.queues[model])
        backlog = self.prefill_backlog_tokens(model)

        def _queued_work(r: PoolRequest) -> int:
            if r.resume is None:
                return len(r.prompt) + r.max_new_tokens
            # a preempted snapshot owes its REMAINING decode tokens (not
            # the original budget — tokens already emitted are not work
            # ahead of the caller), plus the full context re-prefill in
            # recompute mode; swapped snapshots skip recompute entirely,
            # so their context contributes nothing
            ctx = 0 if r.resume.swapped else len(r.resume.seq_tokens)
            return ctx + r.resume.max_new

        queued_tokens = sum(_queued_work(r)
                            for _, _, r in self.queues[model])
        work = backlog + queued_tokens
        if not admissible_now:
            work += prompt_len + max_new_tokens
        base, per_tok = self.token_cost()
        if per_tok > 0.0:
            retry_s = (base + work * per_tok) / 1000.0
        else:
            retry_s = 0.05 * (1 + qdepth)
        return {
            "admissible_now": float(admissible_now),
            "queue_depth": float(qdepth),
            "backlog_tokens": float(backlog + queued_tokens),
            "retry_after_s": float(min(max(retry_s, 0.05), 30.0)),
        }

    def oldest_slack_ms(self, model: str) -> float:
        """Remaining SLO budget of the most urgent waiting request."""
        if not self.queues[model]:
            return float("inf")
        return (self.queues[model][0][0] - self.now()) * 1000.0

    def _request_blocks(self, eng: ContinuousBatchingEngine,
                        req: PoolRequest) -> int:
        """Worst-case block need of ``req`` on ``eng`` — the resumed
        context for a preempted sequence, the fresh-prompt shape
        otherwise."""
        if req.resume is not None:
            return eng.resume_blocks(req.resume)
        return eng.request_blocks(len(req.prompt), req.max_new_tokens)

    def _never_admissible(self, model: str, req: PoolRequest) -> bool:
        """True when ``req``'s worst-case block reservation exceeds every
        grant this pool could ever field for ``model`` — the largest
        live instance AND the (unclamped, optimistic) grant a future
        spawn would take. Such a request can never leave the EDF queue,
        so the router rejects it up front."""
        if self.kv_layout != "paged":
            return False
        insts = self.running(model)
        if not insts:
            return False
        need = self._request_blocks(insts[0].engine, req)
        cap = max(i.engine.allocator.n_blocks for i in insts)
        return need > max(cap, self._spawn_grant())

    # ---- SLO-aware preemption (docs/RUNTIME.md §8) -----------------------
    def _try_preempt(self, model: str, req: PoolRequest,
                     now: float) -> bool:
        """Preempt one resident of ``model`` to make room for the urgent
        waiting request ``req``. Fires only when (a) no instance can
        admit ``req``, (b) its slack no longer covers its predicted
        service time (calibrated contention model), and (c) a victim
        exists whose slack exceeds the urgent slack by the hysteresis
        margin, was not preempted too often already, is not mid-chunk
        prefill, and whose eviction actually makes ``req`` admissible.
        At most one preemption per model per cooldown window."""
        last = self._last_preempt_step.get(model)
        if last is not None and \
                self.n_steps - last < self.preempt_cooldown_steps:
            return False
        t1, c = self.contention()
        if t1 <= 0.0:
            return False  # uncalibrated: no service-time prediction yet
        need_ms = req.max_new_tokens * lm.predicted_iter_ms(
            t1, c, max(1, self.busy_count()))
        slack_ms = (req.deadline_s - now) * 1000.0
        if slack_ms >= need_ms:
            return False  # not urgent: waiting for an eviction is fine
        best = None
        for inst in self.running(model):
            eng = inst.engine
            if req.resume is not None and req.resume.swapped \
                    and id(eng) != req.resume.host_engine_id:
                continue  # a swapped head only fits its source engine
            for slot, erid, freeable in eng.preemption_candidates():
                vreq = inst.requests.get(erid)
                if vreq is None or vreq.n_preempted >= self.max_preemptions:
                    continue
                vslack_ms = (vreq.deadline_s - now) * 1000.0
                if vslack_ms <= slack_ms + self.preempt_margin_ms:
                    continue  # hysteresis: victim must be clearly lazier
                if self.kv_layout == "paged" and \
                        eng.allocator.n_available + freeable \
                        < self._request_blocks(eng, req):
                    continue  # eviction would not make req admissible
                if best is None or vslack_ms > best[0]:
                    best = (vslack_ms, inst, slot, erid)
        if best is None:
            return False
        _, inst, slot, erid = best
        mode = self._pick_preempt_mode(inst.engine, slot)
        snapshot = inst.engine.preempt(slot, requeue=False, mode=mode)
        vreq = inst.requests.pop(erid)
        vreq.resume = snapshot
        vreq.n_preempted += 1
        if mode == "swap":
            self.n_swap_preempted += 1
        if vreq.lifecycle is not None and not vreq.lifecycle.terminal:
            # DECODE -> QUEUED, annotated with HOW the edge was taken:
            # swapped KV waits in the host tier, recompute re-prefills
            vreq.lifecycle.to(lifecycle.QUEUED, now,
                              swapped=(mode == "swap"))
        self._emit(vreq, "preempted", instance_id=inst.instance_id,
                   swapped=(mode == "swap"))
        heapq.heappush(self.queues[model],
                       (vreq.deadline_s, next(_seq), vreq))
        self.n_preempted += 1
        self.preempts_by_model[model] += 1
        self._last_preempt_step[model] = self.n_steps
        return True

    def _pick_preempt_mode(self, eng: ContinuousBatchingEngine,
                           slot: int) -> str:
        """The recompute-vs-swap decision as a COSTED choice
        (docs/RUNTIME.md §8). ``recompute`` resumes by re-prefilling the
        victim's whole context: priced with the calibrated token-cost
        fit. ``swap`` pays two PCIe-ish transfers (out now, in at
        resume): priced with the swap-bandwidth fit over observed
        transfers. Uncalibrated fits prefer swap whenever the host tier
        has room — a transfer is the only way to collect swap samples,
        and recompute cost grows quadratically with context while swap
        cost is linear in resident blocks."""
        if self.preempt_mode == "recompute" or not eng.can_swap(slot):
            return "recompute"
        if self.preempt_mode == "swap":
            return "swap"
        pos = int(eng.pos[slot])
        base, per_tok = self.token_cost()
        swap_base, per_mb = self.swap_cost()
        if per_tok <= 0.0 or per_mb <= 0.0:
            return "swap"
        recompute_ms = base + pos * per_tok
        mb = len(eng.slots[slot].blocks) \
            * eng.swap_bytes_per_block / 1e6
        swap_ms = 2.0 * (swap_base + mb * per_mb)
        return "swap" if swap_ms < recompute_ms else "recompute"

    def swap_cost(self) -> Tuple[float, float]:
        """Calibrated ``(base_ms, ms_per_mb)`` swap-transfer model over
        every live engine's observed (bytes, ms) samples
        (``latency_model.fit_swap_cost``); ``(0, 0)`` before any
        transfer has been measured."""
        samples: List[Tuple[int, float]] = []
        for i in self.live():
            samples.extend(
                getattr(i.engine, "swap_samples", [])[-_SAMPLE_WINDOW:])
        if len(samples) < 4:
            return 0.0, 0.0
        return lm.fit_swap_cost(samples[-_SAMPLE_WINDOW:])

    def _swap_source(self, model: str,
                     snap: PreemptedRequest) -> Optional[ModelInstance]:
        """The instance whose engine's host pool holds ``snap``'s
        swapped blocks (None once it is retired)."""
        for inst in self.instances[model]:
            if inst.engine is not None \
                    and id(inst.engine) == snap.host_engine_id:
                return inst
        return None

    def _repin_swap(self, model: str, req: PoolRequest) -> None:
        """A swap snapshot can only resume on the engine holding its
        host blocks. When that engine is draining or gone, convert the
        snapshot back to recompute so the request stays routable —
        releasing the host blocks while the engine still exists, or
        rebuilding from the carried tokens after it is retired (the host
        pool died with it)."""
        snap = req.resume
        if snap is None or not snap.swapped:
            return
        src = self._swap_source(model, snap)
        if src is None:
            req.resume = to_recompute(snap)
        elif src.state != RUNNING:
            req.resume = src.engine.release_swap(snap)

    def _reject(self, req: PoolRequest) -> PoolResult:
        now = self.now()
        res = PoolResult(req.request_id, req.model, -1,
                         np.zeros((0,), np.int32), req.submit_s, now, now,
                         req.slo_ms, utility=0.0, rejected=True)
        self.n_rejected += 1
        self._results[req.model].append(res)
        # admission rejection is an EVENT, not a silent queue drop: a
        # streaming front-end relays it instead of holding the client
        # open against a request that will never run (docs/RUNTIME.md §11)
        if req.lifecycle is not None and not req.lifecycle.terminal:
            req.lifecycle.to(lifecycle.REJECTED, now)
        self._emit(req, "rejected", slo_ms=req.slo_ms)
        self._listeners.pop(req.request_id, None)
        return res

    def route(self) -> List[PoolResult]:
        """Admit waiting requests, earliest absolute deadline first, into
        the least-loaded RUNNING instance of their model; under
        ``strict_admission`` requests that can no longer meet their
        deadline are rejected instead of occupying a slot
        (docs/RUNTIME.md admission rules). Returns the rejections."""
        rejected: List[PoolResult] = []
        now = self.now()
        t1, c = self.contention()
        #: blocks promised to requests routed THIS pass that their engine
        #: has not reserved yet (reservation happens inside engine.admit,
        #: at the next iteration boundary) — without this debit a single
        #: route() pass could admit several EDF heads against the same
        #: free blocks
        pending: Dict[int, int] = {}
        for model, q in self.queues.items():
            cap = self.slot_caps[model]
            open_insts = [i for i in self.running(model)
                          if cap - i.n_resident > 0]
            while q:
                deadline_s, _, req = q[0]
                # swap snapshots resume only on their source engine; a
                # drained/retired source downgrades them to recompute
                # BEFORE any admissibility question is asked
                self._repin_swap(model, req)
                if self.strict_admission:
                    hopeless = now > deadline_s
                    if not hopeless and t1 > 0.0:
                        need_ms = req.max_new_tokens * lm.predicted_iter_ms(
                            t1, c, max(1, self.busy_count() + 1))
                        hopeless = now + need_ms / 1000.0 > deadline_s
                    if hopeless:
                        heapq.heappop(q)
                        rejected.append(self._reject(req))
                        continue
                def _open():
                    return [i for i in self.running(model)
                            if cap - i.n_resident > 0]

                def _cands():
                    insts = open_insts
                    if req.resume is not None and req.resume.swapped:
                        # swapped KV is resident in ONE engine's host
                        # pool: only that engine can re-map it
                        insts = [i for i in insts if id(i.engine)
                                 == req.resume.host_engine_id]
                    return [i for i in insts
                            if i.engine.admissible(
                                len(req.prompt), req.max_new_tokens,
                                pending.get(i.instance_id, 0),
                                resume=req.resume, prompt=req.prompt)]

                # paged engines additionally gate on free KV blocks —
                # a slot is only admissible when the request's worst-case
                # block need is reservable (docs/RUNTIME.md §7)
                cands = _cands() if open_insts else []
                if not cands and self.preemption and \
                        self._try_preempt(model, req, now):
                    # the victim's slot (and blocks) freed synchronously;
                    # its instance may now admit the urgent request
                    open_insts = _open()
                    cands = _cands()
                if not open_insts and not cands:
                    break
                if not cands:
                    if self._never_admissible(model, req):
                        # no current or future grant could ever hold the
                        # reservation: reject instead of livelocking the
                        # EDF head (and everything behind it) forever
                        heapq.heappop(q)
                        rejected.append(self._reject(req))
                        continue
                    break
                if self.prefix_cache:
                    # prefix affinity (docs/RUNTIME.md §7): same-prefix
                    # requests prefer the instance whose cache already
                    # holds their prefix (hit tokens first, least-loaded
                    # as the tie-break), so shared prompts concentrate
                    # instead of re-prefilling on every instance
                    inst = max(cands, key=lambda i: (
                        i.engine.cached_prefix_tokens(
                            req.resume.seq_tokens if req.resume is not None
                            else req.prompt,
                            prepadded=req.resume is not None),
                        cap - i.n_resident))
                else:
                    inst = max(cands, key=lambda i: cap - i.n_resident)
                heapq.heappop(q)
                if self.kv_layout == "paged":
                    pending[inst.instance_id] = \
                        pending.get(inst.instance_id, 0) \
                        + self._request_blocks(inst.engine, req)
                if req.resume is not None:
                    erid = inst.engine.submit_resume(req.resume)
                    req.resume = None
                else:
                    erid = inst.engine.submit(req.prompt,
                                              req.max_new_tokens)
                req.admit_s = now
                inst.requests[erid] = req
                self.admission_log.append((req.request_id,
                                           inst.instance_id))
                if cap - inst.n_resident <= 0:
                    open_insts.remove(inst)
        return rejected

    # ---- iteration -------------------------------------------------------
    def _finish(self, inst: ModelInstance,
                r: ContinuousResult) -> PoolResult:
        req = inst.requests.pop(r.request_id)
        tokens = r.tokens
        now = self.now()
        hist = self._results[req.model]
        # throughput term of Eq. 3: this model's completions per second
        # over a recent window (the streaming analogue of the simulator's
        # per-session throughput); the window always spans at least this
        # request's own lifetime so an empty history cannot fake an
        # arbitrarily high rate
        recent = [r.finish_s for r in hist[-32:] if not r.rejected] + [now]
        span_s = max(now - min(recent), now - req.submit_s, 1e-3)
        thr = len(recent) / span_s
        u = utility(max(thr, 1e-3), max(now - req.submit_s, 1e-4),
                    req.slo_ms / 1000.0, max(1, self.m_c(req.model)))
        res = PoolResult(req.request_id, req.model, inst.instance_id,
                         tokens, req.submit_s, req.admit_s, now, req.slo_ms,
                         utility=0.0 if r.cancelled else u,
                         cancelled=bool(r.cancelled),
                         first_token_s=req.first_token_s)
        inst.n_served += 1
        hist.append(res)
        # client-observed timing aggregates (satellite of RUNTIME §11):
        # recorded on the pool clock at completion, so they exist with or
        # without an HTTP front-end in the loop. Cancelled results are
        # EXCLUDED: a disconnect storm's partial timings would otherwise
        # drag ttft/tpot p99 below what completed clients observed, even
        # though cancellations are already excluded from SLO attainment
        if res.first_token_s >= 0 and not res.cancelled:
            self.ttft_samples.append(res.ttft_ms)
            if res.tpot_ms >= 0:
                self.tpot_samples.append(res.tpot_ms)
            if len(self.ttft_samples) > 2 * _SAMPLE_WINDOW:
                del self.ttft_samples[:-_SAMPLE_WINDOW]
            if len(self.tpot_samples) > 2 * _SAMPLE_WINDOW:
                del self.tpot_samples[:-_SAMPLE_WINDOW]
        if res.cancelled:
            self.n_cancelled += 1
        if req.lifecycle is not None and not req.lifecycle.terminal:
            req.lifecycle.to(lifecycle.CANCELLED if res.cancelled
                             else lifecycle.FINISHED, now)
        self._emit(req, "finished", tokens=[int(t) for t in tokens],
                   latency_ms=res.latency_ms, utility=u,
                   truncated=bool(r.truncated),
                   n_preempted=int(r.n_preempted))
        self._listeners.pop(req.request_id, None)
        return res

    def step(self) -> List[PoolResult]:
        """One pool iteration: sweep retirements, route admissions, then
        run ONE decode iteration on every busy live instance. Returns the
        requests that finished (or were rejected) this iteration."""
        self._sweep()
        out: List[PoolResult] = list(self.route())
        busy = [i for i in self.live()
                if i.engine.active_slots or i.engine.waiting]
        if not busy:
            self.n_steps += 1
            return out
        # the latency a sequence experiences per decode token is the wall
        # time of the WHOLE pool iteration (every busy instance steps once
        # before any sequence advances again) — that is the quantity the
        # contention model calibrates against the overlap level. Steps
        # that do prefill-chunk work are excluded from the CONTENTION fit
        # (their cost scales with chunk tokens, not overlap) but feed the
        # token-cost fit below, which prices exactly that.
        overlap = len(busy)
        pure_decode = not any(i.engine.prefill_backlog_tokens
                              for i in busy)
        t0 = time.perf_counter()
        for inst in busy:
            for r in inst.engine.step():
                out.append(self._finish(inst, r))
        iter_ms = (time.perf_counter() - t0) * 1000.0
        compiled = any(i.engine.last_step_compiled for i in busy)
        if not compiled:
            # (tokens processed, wall ms) — the fit behind the
            # per-iteration token-budget knob (docs/RUNTIME.md §8);
            # compile iterations would swamp the slope
            sample = (sum(i.engine.last_step_tokens for i in busy),
                      iter_ms)
            self.token_samples.append(sample)
            if len(self.token_samples) > 2 * _SAMPLE_WINDOW:
                del self.token_samples[:-_SAMPLE_WINDOW]
            degrees = {i.tp_degree for i in busy}
            if len(degrees) == 1:
                # degree-homogeneous iteration: attributable to ONE
                # layout, so it also feeds that degree's token-cost fit
                bucket = self.tp_token_samples.setdefault(
                    degrees.pop(), [])
                bucket.append(sample)
                if len(bucket) > 2 * _SAMPLE_WINDOW:
                    del bucket[:-_SAMPLE_WINDOW]
        if pure_decode and not compiled:
            self.contention_samples.append((overlap, iter_ms))
            self.occupancy_samples.append(
                (sum(i.n_resident for i in busy),
                 sum(i.engine.kv_used_tokens for i in busy)))
            if len(self.contention_samples) > 2 * _SAMPLE_WINDOW:
                # long-lived serving loops step for hours: keep only the
                # trailing window the calibration fits ever read
                del self.contention_samples[:-_SAMPLE_WINDOW]
            if len(self.occupancy_samples) > 2 * _SAMPLE_WINDOW:
                del self.occupancy_samples[:-_SAMPLE_WINDOW]
        if self.predictor is not None and pure_decode:
            for inst in busy:
                self.predictor.observe(
                    engine_features(self.configs[inst.model],
                                    self.m_c(inst.model),
                                    inst.n_resident, overlap),
                    iter_ms / 1000.0)
        self.n_steps += 1
        return out

    def _work_pending(self) -> bool:
        return any(self.queues.values()) \
            or any(i.n_resident for i in self.live())

    def _can_progress(self) -> bool:
        """Stepping can still move work: something is resident on a live
        instance, or a queued model has a RUNNING instance to route to.
        Queued work with every instance retired is NOT progressable —
        the caller must scale up first."""
        if any(i.n_resident for i in self.live()):
            return True
        return any(q and self.running(m) for m, q in self.queues.items())

    def run_until_drained(self, max_steps: int = 10_000
                          ) -> List[PoolResult]:
        """Step until every queue and instance is empty (tests/benchmarks;
        the serving loop calls ``step()`` directly).

        Raises ``RuntimeError`` when ``max_steps`` is exhausted with work
        still pending — a silent partial return here made benchmarks read
        partial completions as full drains. Queued work that CANNOT
        progress (its model has no RUNNING instance) returns normally
        instead of spinning: everything drainable was drained."""
        done: List[PoolResult] = []
        while max_steps > 0 and self._work_pending():
            if not self._can_progress():
                break
            done.extend(self.step())
            max_steps -= 1
        self._sweep()
        if self._work_pending() and self._can_progress():
            queued = {m: len(q) for m, q in self.queues.items() if q}
            resident = sum(i.n_resident for i in self.live())
            raise RuntimeError(
                f"run_until_drained: max_steps exhausted with work still "
                f"pending (queued={queued}, resident={resident}) — raise "
                f"max_steps or treat the workload as undrainable")
        return done

    def warmup(self, prompt_lens: Tuple[int, ...] = (8, 20),
               seed: int = 0) -> None:
        """Compile the serving shapes before traffic: one prompt per
        length bucket per model (at an effectively-infinite SLO), drained
        to completion, then metrics reset — so neither compile time nor
        the warmup traffic pollutes SLO stats or the contention fit.
        Callers scale first; models at m_c = 0 are skipped."""
        rng = np.random.default_rng(seed)
        submitted = False
        for m, cfg in self.configs.items():
            if self.m_c(m) == 0:
                continue
            for n in prompt_lens:
                self.submit(m, rng.integers(1, cfg.vocab_size, n).astype(
                    np.int32), slo_ms=600_000.0, max_new_tokens=2)
                submitted = True
        if submitted:
            self.run_until_drained()
        self.reset_metrics()

    # ---- accounting ------------------------------------------------------
    def reset_metrics(self) -> None:
        """Clear serving metrics (results, admission log, counters,
        calibration samples) but keep instances and warm jit caches —
        called after a warmup pass so compile time pollutes neither the
        SLO stats nor the contention fit."""
        self._results = {m: [] for m in self.configs}
        self.admission_log = []
        self.contention_samples = []
        self.occupancy_samples = []
        self.token_samples = []
        self.tp_token_samples = {}
        self.ttft_samples = []
        self.tpot_samples = []
        self.n_rejected = 0
        self.n_cancelled = 0
        self.n_preempted = 0
        self.n_swap_preempted = 0
        self.preempts_by_model = {m: 0 for m in self.configs}
        self._last_preempt_step = {}
        self.n_steps = 0
        for lst in self.instances.values():
            for inst in lst:
                inst.n_served = 0
        self._t0 = time.perf_counter()

    def contention(self) -> Tuple[float, float]:
        """Calibrated ``(t1_ms, c)`` from the measured samples
        (``latency_model.fit_contention``); ``(0, 0)`` before warmup."""
        if len(self.contention_samples) < 8:
            return 0.0, 0.0
        return lm.fit_contention(self.contention_samples[-_SAMPLE_WINDOW:])

    def token_cost(self, tp_degree: Optional[int] = None
                   ) -> Tuple[float, float]:
        """Calibrated ``(base_ms, per_token_ms)`` iteration-cost model
        (``latency_model.fit_token_cost``); ``(0, 0)`` before warmup.
        Prices the per-iteration token budget for the scheduler guard.

        ``tp_degree`` selects that degree's fit (measured only on
        degree-homogeneous iterations); a degree without enough samples
        yet falls back to the global fit, and the guard layers the
        analytic collective term on top (docs/RUNTIME.md §10)."""
        if tp_degree is not None:
            bucket = self.tp_token_samples.get(tp_degree, [])
            if len(bucket) >= 8:
                return lm.fit_token_cost(bucket[-_SAMPLE_WINDOW:])
        if len(self.token_samples) < 8:
            return 0.0, 0.0
        return lm.fit_token_cost(self.token_samples[-_SAMPLE_WINDOW:])

    # ---- KV occupancy (docs/RUNTIME.md §7) -------------------------------
    def kv_used_tokens(self, model: Optional[str] = None) -> int:
        """Σ cache tokens resident sequences occupy right now, over the
        live instances of ``model`` (or all models)."""
        return sum(i.engine.kv_used_tokens for i in self.live(model))

    def occupancy_tokens_per_seq(self) -> float:
        """Measured mean KV tokens per resident sequence
        (``latency_model.fit_occupancy``); 0.0 before calibration."""
        if len(self.occupancy_samples) < 8:
            return 0.0
        return lm.fit_occupancy(self.occupancy_samples[-_SAMPLE_WINDOW:])

    def prefix_hit_rate(self) -> float:
        """Prompt tokens served from prefix caches as a fraction of all
        prompt tokens processed, aggregated over live instances — a
        scheduler state feature (docs/RUNTIME.md §7)."""
        live = self.live()
        hit = sum(getattr(i.engine, "n_prefix_hit_tokens", 0)
                  for i in live)
        total = hit + sum(getattr(i.engine, "n_prefill_chunk_tokens", 0)
                          for i in live)
        return hit / total if total else 0.0

    def spec_accept_rate(self) -> float:
        """Draft tokens accepted as a fraction of draft tokens proposed,
        aggregated over live instances — the scheduler state feature
        behind the k axis (docs/RUNTIME.md §9). 0.0 before any
        speculative step (and always, for spec-off pools)."""
        live = self.live()
        acc = sum(getattr(i.engine, "n_spec_accepted", 0) for i in live)
        prop = sum(getattr(i.engine, "n_spec_proposed", 0) for i in live)
        return acc / prop if prop else 0.0

    def kv_shared_frac(self) -> float:
        """Fraction of live block mappings backed by a block another
        resident sequence also maps, pool-wide: 1 - distinct/logical.
        The guard uses it to price *effective* blocks — refcounted
        blocks charge the shared budget once."""
        logical = distinct = 0
        for i in self.live():
            if i.engine.kv_layout != "paged":
                continue
            lg, d = i.engine.kv_block_mapping()
            logical += lg
            distinct += d
        return 1.0 - distinct / logical if logical else 0.0

    def kv_occupancy(self) -> Dict[str, float]:
        """Real occupancy of the shared KV budget — what grounds the
        ``PoolScheduler`` Eq.-4 guard when the pool is paged. Budget
        fields are 0 for unlimited budgets. ``allocated_tokens`` counts
        a refcount-shared block ONCE (each engine reports distinct live
        blocks), so the gap to the logical ``used_tokens`` is exactly
        what prefix sharing saves."""
        budget_blocks = self.kv_block_budget or 0
        committed = sum(i.kv_blocks for i in self.live())
        # host-tier occupancy across live paged engines (0 everywhere
        # when no engine carries a host pool)
        host_blocks = host_free = host_live = host_cached = 0
        for i in self.live():
            if i.engine.kv_layout != "paged":
                continue
            a = i.engine.allocator
            host_blocks += a.n_host_blocks
            host_free += a.n_host_free
            host_live += a.n_host_live
            host_cached += a.n_host_cached
        return {
            "used_tokens": float(self.kv_used_tokens()),
            "allocated_tokens": float(sum(
                i.engine.kv_allocated_tokens for i in self.live())),
            "budget_tokens": float(budget_blocks * self.block_size),
            "free_blocks": float(self.kv_blocks_free or 0),
            "committed_blocks": float(committed),
            "tokens_per_seq": self.occupancy_tokens_per_seq(),
            "shared_frac": self.kv_shared_frac(),
            "prefix_hit_rate": self.prefix_hit_rate(),
            "host_blocks": float(host_blocks),
            "host_free": float(host_free),
            "host_live": float(host_live),
            "host_cached": float(host_cached),
            "host_frac": float((host_live + host_cached) / host_blocks)
            if host_blocks else 0.0,
        }

    def slot_ms(self, model: str) -> float:
        """Eq. 1 for the live allocation: t_i = Σ SLO of the model's
        resident requests / m_c. The PoolScheduler re-decides once per
        slot (docs/RUNTIME.md Eq.-1 accounting)."""
        slo_sum = sum(i.slo_sum_ms for i in self.instances[model]
                      if i.state in (RUNNING, DRAINING))
        return slo_sum / max(1, self.m_c(model))

    def results(self, model: str) -> List[PoolResult]:
        """All finished/rejected results for ``model`` so far."""
        return list(self._results[model])

    def states(self, model: str) -> List[str]:
        return [i.state for i in self.instances[model]] + \
            [i.state for i in self.retired if i.model == model]

    def report(self) -> Dict[str, Dict[str, float]]:
        """Per-model serving metrics over the pool's lifetime."""
        out: Dict[str, Dict[str, float]] = {}
        for model, results in self._results.items():
            # cancelled requests left on their client's initiative: they
            # are reported, but neither served nor violated — attainment
            # is over the requests the pool was actually asked to finish
            considered = [r for r in results if not r.cancelled]
            served = [r for r in considered if not r.rejected]
            viol = sum(1 for r in considered if r.violated)
            lats = [r.latency_ms for r in served]
            out[model] = {
                "served": float(len(served)),
                "rejected": float(len(considered) - len(served)),
                "cancelled": float(len(results) - len(considered)),
                "violations": float(viol),
                "slo_attainment": 1.0 - viol / max(1, len(considered)),
                "mean_latency_ms": float(np.mean(lats)) if lats else 0.0,
                "mean_utility": float(np.mean(
                    [r.utility for r in served])) if served else 0.0,
                "m_c": float(self.m_c(model)),
                "tp_degree": float(self.tp_degrees.get(model, 1)),
                "queued": float(len(self.queues[model])),
                "preempted": float(self.preempts_by_model.get(model, 0)),
            }
        return out

    def stats(self) -> Dict[str, float]:
        t1, c = self.contention()
        base, per_tok = self.token_cost()
        swap_base, per_mb = self.swap_cost()
        out = {
            "n_steps": float(self.n_steps),
            "live_instances": float(self.total_live()),
            "devices_in_use": float(self.devices_in_use()),
            "retired_instances": float(len(self.retired)),
            "n_rejected": float(self.n_rejected),
            "n_cancelled": float(self.n_cancelled),
            "n_preempted": float(self.n_preempted),
            "n_swap_preempted": float(self.n_swap_preempted),
            "prefill_backlog_tokens": float(self.prefill_backlog_tokens()),
            "contention_t1_ms": t1,
            "contention_c": c,
            "token_base_ms": base,
            "token_per_ms": per_tok,
            "swap_base_ms": swap_base,
            "swap_ms_per_mb": per_mb,
            "spec_accept_rate": self.spec_accept_rate(),
            # client-observed timing percentiles over the trailing window
            # (pool clock, HTTP-independent); 0.0 before any completion
            "ttft_ms_p50": float(np.percentile(self.ttft_samples, 50))
            if self.ttft_samples else 0.0,
            "ttft_ms_p99": float(np.percentile(self.ttft_samples, 99))
            if self.ttft_samples else 0.0,
            "tpot_ms_p50": float(np.percentile(self.tpot_samples, 50))
            if self.tpot_samples else 0.0,
            "tpot_ms_p99": float(np.percentile(self.tpot_samples, 99))
            if self.tpot_samples else 0.0,
        }
        if self.kv_layout == "paged" or self.kv_block_budget:
            out.update({f"kv_{k}": v for k, v in self.kv_occupancy().items()})
        return out
