"""Discrete-event simulator of a multi-tenant edge serving platform.

The container is CPU-only, so the Xavier-NX/Nano/TX2 hardware is simulated
by the calibrated latency model (docs/ARCHITECTURE.md §2). Semantics follow
the paper:

* requests arrive Poisson (§V-A), one SLO-priority queue per model (§IV-C);
* a scheduling decision for a model picks (b, m_c); the dynamic batcher
  then FORMS the round: it waits until b requests are queued or the
  Eq.-1 scheduling slot t_i = Σ SLO / m_c elapses (adaptive batching's
  time-window — this queue wait t_w is exactly why larger batches trade
  latency for throughput, Fig. 1);
* m_c instances execute concurrently (§IV-D) under the interference model;
* the next decision for a model happens when its round completes;
* reward = utility U (Eq. 3/6) of the round; memory overflow fails it.

Under ``ServingConfig.exec_mode == "continuous"`` the round is replaced by
an iteration-level *session* (docs/ARCHITECTURE.md §5): the action becomes
(max slots per instance, concurrency), the session advances one decode
iteration at a time ("iter" events in the heap loop), finished requests
leave and queued requests join at iteration boundaries, and utility/SLO
metrics (Eq. 3/6) are computed per request rather than per round.

Because rounds of different models overlap in time, the env is a per-model
semi-MDP (docs/ARCHITECTURE.md §3): ``step(action)`` commits the focus
model's round and advances the event loop to the NEXT decision point (any
model). Completed transitions (s, a, r, s') are emitted in
``info["transitions"]`` when their model reaches its next decision, so the
RL agents see properly-ordered per-model experience.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.config.base import ServingConfig
from repro.configs.paper_edge_models import EDGE_MODELS
from repro.core.interference import interference_features
from repro.core.utility import utility
from repro.serving import latency_model as lm
from repro.serving.features import featurize, state_dim
from repro.serving.platforms import PLATFORMS, HardwareSpec
from repro.serving.request import Request, RequestQueue
from repro.serving.workload import PoissonWorkload

IDLE, PENDING, ACTIVE = 0, 1, 2


@dataclasses.dataclass
class CompletedRound:
    """One completed execution unit: a (b, m_c) round (paper §IV-D), or —
    under exec_mode="continuous" — a whole iteration-level session
    (docs/ARCHITECTURE.md §5), in which case ``n_iters`` > 1 and the
    per-request lists carry the join/leave accounting."""
    model: str
    b: int
    m_c: int
    n_requests: int
    decision_ms: float
    start_ms: float
    finish_ms: float
    latencies_ms: List[float]
    violations: int
    overflow: bool
    utility: float
    mem_used_gb: float
    features: object = None  # interference-predictor features at start
    exec_mode: str = "round"
    n_iters: int = 1         # decode iterations (1 = single-shot round)
    queue_waits_ms: Optional[List[float]] = None  # per request, >= 0
    request_utilities: Optional[List[float]] = None  # per-request Eq. 3
    n_preempted: int = 0     # preemptions during this session
    token_budget: int = 0    # per-iteration token cap (0 = uncapped)
    spec_k: int = 0          # speculative proposal depth (0 = off)

    @property
    def throughput_rps(self) -> float:
        dur = max(self.finish_ms - self.decision_ms, 1e-3)
        return 1000.0 * self.n_requests / dur


@dataclasses.dataclass
class _Pending:
    model: str
    b: int
    m_c: int
    target: int
    decision_ms: float
    deadline_ms: float
    state: np.ndarray
    action: int
    token_budget: int = 0    # per-iteration token cap (0 = uncapped)
    spec_k: int = 0          # speculative proposal depth (0 = off)


@dataclasses.dataclass
class _Session:
    """In-flight continuous-batching session (docs/ARCHITECTURE.md §5).

    ``b * m_c`` KV slots are allocated for the whole session; ``active``
    requests each consume one slot until their ``remaining`` decode
    iterations run out, at which point they leave and a queued request
    may join at the next iteration boundary. Admission closes at
    ``admit_until_ms`` (the Eq.-1 scheduling slot) so the session — and
    with it the semi-MDP decision epoch — always terminates."""
    model: str
    b: int
    m_c: int
    decision_ms: float
    start_ms: float
    admit_until_ms: float
    mem_gb: float
    state: np.ndarray
    action: int
    active: List[Request] = dataclasses.field(default_factory=list)
    done: List[Request] = dataclasses.field(default_factory=list)
    n_iters: int = 0
    features: object = None
    token_budget: int = 0    # per-iteration token cap (0 = uncapped)
    n_preempted: int = 0
    spec_k: int = 0          # speculative proposal depth (0 = off)

    @property
    def capacity(self) -> int:
        return self.b * self.m_c

    def plan_tokens(self) -> Tuple[int, List[int]]:
        """Work of the NEXT iteration under the token budget: decoding
        requests take one token each; the leftover budget is handed to
        prefilling requests in admission order (chunked prefill,
        docs/ARCHITECTURE.md §5). Deterministic between scheduling the
        ``iter`` event and handling it — joins/leaves only happen at
        iteration boundaries — so the event's latency prices exactly the
        work the handler then applies. Returns (total tokens,
        per-request prefill allocation parallel to ``active``).

        With speculation on (``spec_k`` > 0) every decoding request
        costs ``1 + spec_k`` tokens — the verify forward processes the
        pending token plus k drafts — which is exactly how the real
        engine's verify step bills the token budget. The *progress* per
        iteration (acceptance) is drawn in ``_handle_iter``; the COST is
        always the full proposal."""
        n_dec = sum(1 for r in self.active if r.prefill_remaining <= 0)
        n_dec *= 1 + max(0, self.spec_k)
        cap = self.token_budget if self.token_budget > 0 else (1 << 62)
        left = max(0, cap - n_dec)
        alloc: List[int] = []
        for r in self.active:
            take = min(left, r.prefill_remaining) \
                if r.prefill_remaining > 0 else 0
            alloc.append(take)
            left -= take
        return n_dec + sum(alloc), alloc


class EdgeServingEnv:
    """Per-model semi-MDP serving environment (paper §IV; event-loop and
    decision semantics in docs/ARCHITECTURE.md §3, continuous mode §5).

    ``step(action)`` commits the focus model's (b, m_c) round — or
    continuous session — and advances the discrete-event loop to the
    next decision point of any model; ``info["transitions"]`` carries
    the completed per-model (s, a, r, s') tuples."""

    def __init__(self, cfg: ServingConfig = ServingConfig(),
                 models: Optional[Sequence[str]] = None,
                 episode_ms: float = 60_000.0, seed: int = 0):
        self.cfg = cfg
        self.hw: HardwareSpec = PLATFORMS[cfg.platform]
        self.models = list(models or EDGE_MODELS.keys())
        self.episode_ms = episode_ms
        self.seed = seed
        self.state_dim = state_dim(self.models)
        self.n_actions = cfg.n_actions
        self.history: List[CompletedRound] = []
        self.reset()

    # ------------------------------------------------------------ reset
    def reset(self) -> np.ndarray:
        self.now = 0.0
        self.workload = PoissonWorkload(
            self.cfg.arrival_rps, self.models, seed=self.seed,
            decode_steps_mean=self.cfg.decode_steps_mean,
            prefill_tokens_mean=self.cfg.prefill_tokens_mean,
            shared_prefix_tokens=self.cfg.shared_prefix_tokens,
            prefix_population=self.cfg.prefix_population)
        #: prefix cache twin (docs/ARCHITECTURE.md §5): per-model set of
        #: shared-prefix ids some admitted request already prefilled —
        #: later same-prefix admissions skip those tokens
        self._seen_prefixes: Dict[str, set] = {m: set()
                                               for m in self.models}
        self.prefix_hit_tokens = 0
        #: speculation twin (docs/ARCHITECTURE.md §speculation): decode
        #: progress per iteration is 1 + the run of consecutive draft
        #: acceptances, each a Bernoulli(cfg.spec_accept_rate) draw from
        #: a dedicated stream (spec-off runs consume no draws, so their
        #: traces are bit-identical to pre-speculation builds)
        self._spec_rng = np.random.default_rng(self.seed + 1)
        self.spec_proposed = 0
        self.spec_accepted = 0
        self.queues: Dict[str, RequestQueue] = {
            m: RequestQueue(m, self.cfg.max_queue) for m in self.models}
        self._events: List[tuple] = []
        self._evseq = 0
        self.status: Dict[str, int] = {m: IDLE for m in self.models}
        self.pending: Dict[str, _Pending] = {}
        self.active: Dict[str, Tuple[int, float]] = {}  # model -> (inst, mem)
        self._last_sa: Dict[str, Tuple[np.ndarray, int]] = {}
        self._ready_reward: Dict[str, float] = {}
        self._out_transitions: List[tuple] = []
        self.history = []
        self.total_requests = 0
        self._focus = self.models[0]  # placeholder until first decision
        r = self.workload.next_request()
        self._push_event(r.arrival_ms, "arrival", r)
        self._advance_to_decision()
        return self._observe(self._focus)

    def _push_event(self, t: float, kind: str, payload) -> None:
        self._evseq += 1
        heapq.heappush(self._events, (t, self._evseq, kind, payload))

    # ------------------------------------------------------------ event loop
    def _handle_arrival(self, r: Request) -> None:
        self.queues[r.model].push(r)
        self.total_requests += 1
        nxt = self.workload.next_request()
        self._push_event(nxt.arrival_ms, "arrival", nxt)
        p = self.pending.get(r.model)
        if p and len(self.queues[r.model]) >= p.target:
            self._start_round(p)

    def _handle_deadline(self, model: str) -> None:
        p = self.pending.get(model)
        if not p or self.now < p.deadline_ms - 1e-9:
            return  # stale deadline (round already started)
        if len(self.queues[model]) == 0:
            # nothing arrived inside the slot: no-op round, zero reward
            self.pending.pop(model)
            self.status[model] = IDLE
            self._ready_reward[model] = 0.0
            return
        self._start_round(p)

    def _start_round(self, p: _Pending) -> None:
        if self.cfg.exec_mode == "continuous":
            return self._start_session(p)
        model = p.model
        self.pending.pop(model, None)
        prof = EDGE_MODELS[model]
        q = self.queues[model]
        # formation waits for ONE batch (b); at dispatch all m_c instances
        # pull whatever is queued, up to b each (Triton instance semantics)
        slo_sum_ms = q.slo_sum_ms(p.b * p.m_c) * self.cfg.slo_scale
        reqs = q.pop_batch(p.b * p.m_c)
        n = len(reqs)
        b_eff = max(1, int(np.ceil(n / p.m_c)))
        other_inst, other_mem = self._other_load(exclude=model)
        est = lm.estimate_execution(self.hw, prof, b_eff, p.m_c,
                                    other_inst, other_mem)
        # run-to-completion: the whole batch decodes in lock-step until the
        # LONGEST sequence finishes (single-shot requests: n_iters = 1, the
        # paper's CNN/BERT regime; exec_mode="continuous" removes this wait)
        n_iters = max([r.decode_steps for r in reqs], default=1)
        t_exec = est.total_ms * n_iters
        if est.overflow:
            t_exec = 10.0 * max(slo_sum_ms / max(p.m_c, 1),
                                self.hw.overhead_ms)
        start = self.now
        finish = start + t_exec
        self.status[model] = ACTIVE
        self.active[model] = (p.m_c, est.mem_used_gb - other_mem)

        t_t = lm.transmission_ms(self.hw, prof)
        t_s = lm.serialization_ms(b_eff)
        lats, waits, violations = [], [], 0
        for r in reqs:
            r.start_ms = start
            r.finish_ms = finish + t_t + t_s
            lat = r.latency_ms()
            lats.append(lat)
            waits.append(r.queue_wait_ms())
            if est.overflow or lat > r.slo_ms * self.cfg.slo_scale:
                violations += 1

        # utility (Eq. 3) with T_{t_i} = requests per scheduling slot
        # (Eq. 1): U = log( (n/t_i) / (L/t_i) ) — the slot cancels, giving
        # a clean requests-per-second-of-latency trade-off with an interior
        # optimum in (b, m_c), as in Fig. 1.
        slot_s = max(slo_sum_ms, 1.0) / 1000.0 / max(p.m_c, 1)
        thr = n / slot_s
        mean_lat_s = (float(np.mean(lats)) if lats else t_exec) / 1000.0
        u = utility(max(thr, 1e-3), mean_lat_s,
                    max(slo_sum_ms, 1.0) / 1000.0, p.m_c)
        # Eq. 4 constraints as penalties: SLO misses and memory overflow
        u -= 3.5 * (violations / max(n, 1))
        if est.overflow:
            u -= 5.0
        feats = interference_features(
            self.hw.mem_gb - other_mem, 0.3 + 0.05 * other_inst,
            self._accel_util(), p.m_c, b_eff, prof.gflops,
            est.mem_used_gb - other_mem)
        rnd = CompletedRound(model, p.b, p.m_c, n, p.decision_ms, start,
                             finish, lats, violations, est.overflow, u,
                             est.mem_used_gb, feats, exec_mode="round",
                             n_iters=n_iters, queue_waits_ms=waits)
        self._push_event(finish, "complete", rnd)

    def _handle_complete(self, rnd: CompletedRound) -> None:
        self.active.pop(rnd.model, None)
        self.status[rnd.model] = IDLE
        self.history.append(rnd)
        self._ready_reward[rnd.model] = rnd.utility

    # ------------------------------------------- continuous sessions (§5)
    def _start_session(self, p: _Pending) -> None:
        """Continuous-mode dispatch (docs/ARCHITECTURE.md §5): allocate
        b*m_c KV slots for an iteration-level session instead of forming a
        run-to-completion round."""
        model = p.model
        self.pending.pop(model, None)
        prof = EDGE_MODELS[model]
        other_inst, other_mem = self._other_load(exclude=model)
        own_mem = p.m_c * lm.instance_memory_gb(prof, p.b)
        mem = own_mem + other_mem
        self.status[model] = ACTIVE
        self.active[model] = (p.m_c, own_mem)
        if mem > self.hw.mem_gb:
            # Eq.-4 memory violation: the slot allocation itself does not
            # fit — fail the formed batch outright, as round mode does
            reqs = self.queues[model].pop_batch(p.b * p.m_c)
            t_fail = 10.0 * max(prof.slo_ms * self.cfg.slo_scale,
                                self.hw.overhead_ms)
            finish = self.now + t_fail
            lats, waits = [], []
            for r in reqs:
                r.start_ms = self.now
                r.finish_ms = finish
                lats.append(r.latency_ms())
                waits.append(r.queue_wait_ms())
            rnd = CompletedRound(model, p.b, p.m_c, len(reqs),
                                 p.decision_ms, self.now, finish, lats,
                                 len(reqs), True, -8.5, mem, None,
                                 exec_mode="continuous", n_iters=1,
                                 queue_waits_ms=waits)
            self._push_event(finish, "complete", rnd)
            return
        # admission window = the Eq.-1 scheduling slot for the allocation:
        # t_i = Σ_{j=1..b*m_c} SLO / m_c ≈ b * SLO. After it closes the
        # session drains, so the semi-MDP decision epoch always terminates.
        admit_window = p.b * prof.slo_ms * self.cfg.slo_scale
        sess = _Session(model, p.b, p.m_c, p.decision_ms, self.now,
                        self.now + admit_window, mem, p.state, p.action,
                        token_budget=p.token_budget, spec_k=p.spec_k)
        sess.features = interference_features(
            self.hw.mem_gb - other_mem, 0.3 + 0.05 * other_inst,
            self._accel_util(), p.m_c, p.b, prof.gflops, own_mem)
        self._session_join(sess)
        self._push_event(self.now + self._iter_ms(sess), "iter", sess)

    def _session_join(self, sess: _Session) -> int:
        """Admit queued requests into free slots (iteration boundary).
        A joining request first owes its prompt's prefill (chunked under
        the session token budget); decode starts once it is paid."""
        if self.now > sess.admit_until_ms:
            return 0
        q = self.queues[sess.model]
        n = 0
        while len(sess.active) < sess.capacity and len(q):
            r = q.pop_batch(1)[0]
            r.start_ms = self.now
            if r.n_preempted == 0:
                # fresh admission; a resumed request keeps the decode
                # progress it already earned (remaining) and the
                # recompute bill set at preemption time
                # (prefill_remaining = prompt + emitted context)
                r.remaining = max(1, r.decode_steps)
                r.prefill_remaining = r.prefill_tokens
                if self.cfg.prefix_cache and r.prefix_id >= 0:
                    # prefix-cache twin: a shared prefix some earlier
                    # request of this model already prefilled is skipped
                    # (the engine's block-sharing hit, analytically)
                    seen = self._seen_prefixes[sess.model]
                    if r.prefix_id in seen:
                        r.prefill_remaining = max(
                            0, r.prefill_remaining - r.prefix_tokens)
                        self.prefix_hit_tokens += r.prefix_tokens
                    else:
                        seen.add(r.prefix_id)
            sess.active.append(r)
            n += 1
        return n

    def _iter_ms(self, sess: _Session) -> float:
        """Latency of ONE iteration pricing the tokens it processes:
        resident decodes plus budget-bounded prefill chunks (each prompt
        token costs like one decode row, so the batch dimension is the
        iteration's total token work)."""
        prof = EDGE_MODELS[sess.model]
        tokens, _ = sess.plan_tokens()
        b_eff = max(1, int(np.ceil(tokens / sess.m_c)))
        other_inst, other_mem = self._other_load(exclude=sess.model)
        est = lm.estimate_execution(self.hw, prof, b_eff, sess.m_c,
                                    other_inst, other_mem)
        return est.total_ms

    def _maybe_preempt(self, sess: _Session) -> None:
        """SLO-aware preemption (docs/RUNTIME.md §8), simulator twin of
        the pool policy: when the session is full, the most urgent queued
        request's slack no longer covers its predicted service time, and
        a resident decoding request out-slacks it by the hysteresis
        margin, evict that largest-slack resident back to the queue with
        a recompute bill (prompt + emitted context re-prefilled on
        resume). At most one eviction per iteration; per-request cap."""
        if not self.cfg.preemption or len(sess.active) < sess.capacity:
            return
        slack_ms, urgent = self.queues[sess.model].peek_most_urgent(self.now)
        if urgent is None:
            return
        iter_ms = self._iter_ms(sess)
        need_ms = (urgent.decode_steps + urgent.prefill_tokens
                   / max(1, sess.token_budget or urgent.prefill_tokens or 1)
                   ) * iter_ms
        if slack_ms >= need_ms:
            return
        margin = self.cfg.preempt_margin_ms
        best = None
        for r in sess.active:
            if r.prefill_remaining > 0:   # never a mid-chunk prefill
                continue
            if r.n_preempted >= self.cfg.max_preemptions:
                continue
            if r.slo_ms <= urgent.slo_ms:
                # the queue pops shortest-SLO first: a victim whose SLO
                # class is not strictly laxer would re-admit ahead of the
                # urgent request at this very boundary (thrash)
                continue
            vslack = r.deadline_ms - self.now
            if vslack <= slack_ms + margin:
                continue
            if best is None or vslack > best[0]:
                best = (vslack, r)
        if best is None:
            return
        victim = best[1]
        sess.active.remove(victim)
        victim.n_preempted += 1
        emitted = victim.decode_steps - victim.remaining
        victim.prefill_remaining = victim.prefill_tokens + emitted
        sess.n_preempted += 1
        if not self.queues[sess.model].push(victim):
            # queue full: the evicted request is dropped (counted there)
            pass

    def _handle_iter(self, sess: _Session) -> None:
        """One iteration just finished: apply its planned prefill/decode
        work, then leaves, preemption check, joins, then either the next
        iteration or session completion."""
        _, alloc = sess.plan_tokens()
        sess.n_iters += 1
        prof = EDGE_MODELS[sess.model]
        t_t = lm.transmission_ms(self.hw, prof)
        still = []
        for r, take in zip(sess.active, alloc):
            if r.prefill_remaining > 0:
                r.prefill_remaining -= take
                still.append(r)
                continue
            # speculative advance: 1 committed token plus the run of
            # consecutively-accepted drafts (acceptance is prefix-based
            # in the real engine, so the first rejection ends the run)
            adv = 1
            for _ in range(max(0, sess.spec_k)):
                self.spec_proposed += 1
                if self._spec_rng.random() >= self.cfg.spec_accept_rate:
                    break
                self.spec_accepted += 1
                adv += 1
            r.remaining -= adv
            if r.remaining <= 0:
                r.finish_ms = self.now + t_t + lm.serialization_ms(1)
                sess.done.append(r)
            else:
                still.append(r)
        sess.active = still
        self._maybe_preempt(sess)
        self._session_join(sess)
        if sess.active:
            self._push_event(self.now + self._iter_ms(sess), "iter", sess)
        else:
            self._finish_session(sess)

    def _finish_session(self, sess: _Session) -> None:
        """Per-request utility/SLO accounting (Eq. 3/6 per request, then
        averaged) — the continuous-mode replacement for round utility."""
        n = len(sess.done)
        dur_s = max(self.now - sess.decision_ms, 1e-3) / 1000.0
        thr = n / dur_s
        lats, waits, utils = [], [], []
        violations = 0
        for r in sess.done:
            lat = r.latency_ms()
            lats.append(lat)
            waits.append(r.queue_wait_ms())
            if lat > r.slo_ms * self.cfg.slo_scale:
                violations += 1
            utils.append(utility(
                max(thr, 1e-3), lat / 1000.0,
                r.slo_ms * self.cfg.slo_scale / 1000.0, sess.m_c))
        u = float(np.mean(utils)) if utils else 0.0
        u -= 3.5 * (violations / max(n, 1))
        rnd = CompletedRound(sess.model, sess.b, sess.m_c, n,
                             sess.decision_ms, sess.start_ms, self.now,
                             lats, violations, False, u, sess.mem_gb,
                             sess.features, exec_mode="continuous",
                             n_iters=sess.n_iters, queue_waits_ms=waits,
                             request_utilities=utils,
                             n_preempted=sess.n_preempted,
                             token_budget=sess.token_budget,
                             spec_k=sess.spec_k)
        self._handle_complete(rnd)

    # ------------------------------------------------------------ decisions
    def _decision_ready(self) -> List[str]:
        return [m for m in self.models
                if self.status[m] == IDLE and len(self.queues[m]) > 0]

    def _advance_to_decision(self) -> bool:
        """Process events until a decision point exists. Returns done."""
        while True:
            ready = self._decision_ready()
            if ready:
                self._focus = max(
                    ready,
                    key=lambda m: self.queues[m].peek_oldest_age(self.now))
                return self.now >= self.episode_ms
            if not self._events:
                return True
            t, _, kind, payload = heapq.heappop(self._events)
            self.now = max(self.now, t)
            if self.now >= self.episode_ms and kind == "arrival":
                return True
            if kind == "arrival":
                self._handle_arrival(payload)
            elif kind == "deadline":
                self._handle_deadline(payload)
            elif kind == "iter":
                self._handle_iter(payload)
            elif kind == "complete":
                self._handle_complete(payload)

    # ------------------------------------------------------------ resources
    def _other_load(self, exclude: str) -> Tuple[int, float]:
        inst = sum(i for m, (i, _) in self.active.items() if m != exclude)
        mem = sum(g for m, (_, g) in self.active.items() if m != exclude)
        return inst, mem

    def _accel_util(self) -> float:
        u = 0.0
        for m, (inst, _) in self.active.items():
            u += inst * lm.batching_efficiency(self.hw, 8)
        return min(1.0, u)

    def _observe(self, model: str) -> np.ndarray:
        q = self.queues[model]
        inst, mem = self._other_load(exclude="")
        return featurize(model, self.models, self.hw, len(q),
                         q.peek_oldest_age(self.now), mem, inst,
                         self._accel_util())

    def predict_features(self, model: str, b: int, m_c: int) -> np.ndarray:
        prof = EDGE_MODELS[model]
        inst, mem = self._other_load(exclude=model)
        return interference_features(
            self.hw.mem_gb - mem, 0.3 + 0.05 * inst, self._accel_util(),
            m_c, b, prof.gflops, m_c * lm.instance_memory_gb(prof, b))

    FORMATION_FRAC = 0.25  # batch-collection share of the Eq.-1 slot

    def slot_budget_ms(self, model: str, b: int, m_c: int) -> float:
        """Formation window: a quarter of the Eq.-1 slot t_i = Σ SLO / m_c
        (execution + transmission must fit in the remainder, else every
        formed batch would already be past its budget)."""
        slot = b * m_c * EDGE_MODELS[model].slo_ms * self.cfg.slo_scale \
            / max(m_c, 1)
        return self.FORMATION_FRAC * slot

    # ------------------------------------------------------------ step
    def step(self, action: int) -> Tuple[np.ndarray, float, bool, Dict]:
        model = self._focus
        state = self._observe(model)
        b, m_c, token_budget, spec_k = self.cfg.action_to_quad(action)
        target = b  # formation waits for one instance-batch
        budget = self.slot_budget_ms(model, b, m_c)
        p = _Pending(model, b, m_c, target, self.now, self.now + budget,
                     state, action, token_budget=token_budget,
                     spec_k=spec_k if self.cfg.exec_mode == "continuous"
                     else 0)
        self.status[model] = PENDING
        self.pending[model] = p
        self._last_sa[model] = (state, action)
        if len(self.queues[model]) >= target:
            self._start_round(p)
        else:
            self._push_event(p.deadline_ms, "deadline", model)

        done = self._advance_to_decision()
        obs = self._observe(self._focus) if not done else state

        # emit per-model transitions whose reward is ready and whose model
        # is at (or past) its next decision
        transitions = []
        for m, r in list(self._ready_reward.items()):
            if m in self._last_sa and (self.status[m] == IDLE or done):
                s0, a0 = self._last_sa.pop(m)
                s1 = self._observe(m)
                transitions.append((s0, a0, r, s1, done))
                self._ready_reward.pop(m)
        last_round = self.history[-1] if self.history else None
        info = {"transitions": transitions, "model": model, "b": b,
                "m_c": m_c, "round": last_round}
        reward = transitions[-1][2] if transitions else 0.0
        return obs, float(reward), done, info

    # ------------------------------------------------------------ summary
    def summarize(self) -> Dict[str, float]:
        rounds = self.history
        if not rounds:
            return {}
        n_req = sum(r.n_requests for r in rounds)
        viol = sum(r.violations for r in rounds)
        lats = [l for r in rounds for l in r.latencies_ms]
        waits = [w for r in rounds for w in (r.queue_waits_ms or [])]
        return {
            "rounds": float(len(rounds)),
            "requests": float(n_req),
            "mean_utility": float(np.mean([r.utility for r in rounds])),
            "throughput_rps": 1000.0 * n_req / max(self.now, 1.0),
            # goodput = SLO-met completions per second (Eq. 4 objective)
            "goodput_rps": 1000.0 * (n_req - viol) / max(self.now, 1.0),
            "mean_latency_ms": float(np.mean(lats)) if lats else 0.0,
            "p50_latency_ms": float(np.percentile(lats, 50)) if lats else 0.0,
            "p99_latency_ms": float(np.percentile(lats, 99)) if lats else 0.0,
            "mean_queue_wait_ms": float(np.mean(waits)) if waits else 0.0,
            "slo_violation_rate": viol / max(n_req, 1),
            "overflow_rate": float(np.mean([r.overflow for r in rounds])),
            "mean_batch": float(np.mean([r.n_requests for r in rounds])),
            "mean_mc": float(np.mean([r.m_c for r in rounds])),
            "mean_iters": float(np.mean([r.n_iters for r in rounds])),
            "prefix_hit_tokens": float(self.prefix_hit_tokens),
        }
