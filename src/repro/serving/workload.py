"""Workload generators: the paper's open-loop Poisson trace (§V-A:
arrivals at 30 rps, Poisson, across the six Table-IV models), plus the
non-stationary arrival traces and the closed-loop HTTP load generator
behind the async serving figure (docs/RUNTIME.md §11) — diurnal /
bursty / flash-crowd rate profiles, mixed SLO tiers, client abandonment,
and client-observed TTFT/TPOT accounting through the real front-end."""
from __future__ import annotations

import asyncio
import dataclasses
import json
import math
import time
from typing import (Callable, Dict, Iterator, List, Optional, Sequence,
                    Tuple)

import numpy as np

from repro.configs.paper_edge_models import EDGE_MODELS
from repro.serving.request import Request


class PoissonWorkload:
    """Open-loop Poisson request generator (paper §V-A), optionally
    autoregressive via ``decode_steps_mean`` (docs/ARCHITECTURE.md §5)."""

    def __init__(self, rps: float = 30.0, models: Optional[Sequence[str]] = None,
                 mix: Optional[Dict[str, float]] = None, seed: int = 0,
                 decode_steps_mean: float = 1.0,
                 prefill_tokens_mean: float = 0.0,
                 shared_prefix_tokens: float = 0.0,
                 prefix_population: int = 4):
        """``rps`` is the PER-MODEL arrival rate (paper §V-A: 30 rps per
        served model); the aggregate rate is rps * len(models).

        ``decode_steps_mean`` > 1 makes the workload autoregressive: each
        request draws a geometric number of decode iterations with that
        mean, so sequences finish at different lengths — the regime
        continuous batching (docs/ARCHITECTURE.md §5) exploits.
        ``prefill_tokens_mean`` > 0 additionally gives each request a
        geometric prompt length that must be prefilled before decoding
        (the chunked-prefill regime).

        ``shared_prefix_tokens`` > 0 makes the trace *templated*
        (docs/ARCHITECTURE.md §5): each request's prompt starts with one
        of ``prefix_population`` shared prefixes of that length (drawn
        uniformly), prepended to its geometric unique tail — the
        workload regime the prefix cache exploits."""
        self.models = list(models or EDGE_MODELS.keys())
        self.rps = rps * len(self.models)
        if mix is None:
            mix = {m: 1.0 for m in self.models}
        total = sum(mix.values())
        self.probs = np.array([mix[m] / total for m in self.models])
        self.rng = np.random.default_rng(seed)
        self.decode_steps_mean = max(1.0, decode_steps_mean)
        self.prefill_tokens_mean = max(0.0, prefill_tokens_mean)
        self.shared_prefix_tokens = max(0.0, shared_prefix_tokens)
        self.prefix_population = max(1, prefix_population)
        self.now_ms = 0.0

    def _draw_decode_steps(self) -> int:
        if self.decode_steps_mean <= 1.0:
            return 1
        return int(self.rng.geometric(1.0 / self.decode_steps_mean))

    def _draw_prefill_tokens(self) -> int:
        if self.prefill_tokens_mean <= 0.0:
            return 0
        return int(self.rng.geometric(1.0 / self.prefill_tokens_mean))

    def _draw_prefix(self) -> tuple:
        """(prefix_id, prefix_tokens) of the shared template this
        request starts with; (-1, 0) for untemplated workloads."""
        if self.shared_prefix_tokens <= 0.0:
            return -1, 0
        return (int(self.rng.integers(self.prefix_population)),
                int(self.shared_prefix_tokens))

    def next_request(self) -> Request:
        gap_ms = self.rng.exponential(1000.0 / self.rps)
        self.now_ms += gap_ms
        name = self.rng.choice(self.models, p=self.probs)
        prof = EDGE_MODELS[name]
        prefix_id, prefix_tokens = self._draw_prefix()
        return Request(model=name, input_type=prof.task,
                       input_shape=prof.input_shape, slo_ms=prof.slo_ms,
                       arrival_ms=self.now_ms,
                       decode_steps=self._draw_decode_steps(),
                       prefill_tokens=prefix_tokens
                       + self._draw_prefill_tokens(),
                       prefix_id=prefix_id, prefix_tokens=prefix_tokens)

    def until(self, t_ms: float) -> Iterator[Request]:
        while True:
            r = self.next_request()
            if r.arrival_ms > t_ms:
                # rewind the clock so the pending gap is preserved
                self.now_ms = t_ms
                return
            yield r

    def burst(self, n: int) -> List[Request]:
        return [self.next_request() for _ in range(n)]


# ---------------------------------------------------------------------
# non-stationary arrival traces (docs/RUNTIME.md §11)
# ---------------------------------------------------------------------
#: mixed SLO tiers for trace workloads: (slo_ms, mix weight). "tight" is
#: the tier the async serving figure's attainment assertion reads.
SLO_TIERS: Dict[str, Tuple[float, float]] = {
    "tight": (400.0, 0.25),
    "standard": (2000.0, 0.50),
    "relaxed": (8000.0, 0.25),
}


class ArrivalTrace:
    """Non-homogeneous Poisson arrivals from a rate function ``rate_fn:
    t_s -> requests/s``, sampled by thinning against the peak rate. The
    three canonical profiles are the load regimes an edge serving stack
    must survive (BCEdge §I; SLICE/EdgeServing evaluate the same
    shapes): a **diurnal** sinusoid, **bursty** on/off square waves, and
    a **flash crowd** — baseline load with a sudden many-fold spike."""

    def __init__(self, rate_fn: Callable[[float], float],
                 duration_s: float, peak_rps: float):
        self.rate_fn = rate_fn
        self.duration_s = duration_s
        self.peak_rps = peak_rps

    def arrival_times(self, seed: int = 0) -> np.ndarray:
        """Arrival offsets in [0, duration_s), by thinning: candidate
        arrivals at the peak rate, kept with probability rate(t)/peak."""
        rng = np.random.default_rng(seed)
        out: List[float] = []
        t = 0.0
        while True:
            t += rng.exponential(1.0 / self.peak_rps)
            if t >= self.duration_s:
                return np.asarray(out)
            if rng.random() < self.rate_fn(t) / self.peak_rps:
                out.append(t)

    @classmethod
    def diurnal(cls, duration_s: float, base_rps: float,
                peak_rps: float) -> "ArrivalTrace":
        """One full sinusoidal day compressed into ``duration_s``:
        trough at t=0, peak at duration/2."""
        def rate(t: float) -> float:
            phase = 2.0 * math.pi * t / duration_s
            return base_rps + (peak_rps - base_rps) \
                * 0.5 * (1.0 - math.cos(phase))
        return cls(rate, duration_s, peak_rps)

    @classmethod
    def bursty(cls, duration_s: float, base_rps: float, burst_rps: float,
               period_s: float, duty: float = 0.3) -> "ArrivalTrace":
        """Square-wave bursts: ``burst_rps`` for the first ``duty``
        fraction of every ``period_s``, ``base_rps`` otherwise."""
        def rate(t: float) -> float:
            return burst_rps if (t % period_s) < duty * period_s \
                else base_rps
        return cls(rate, duration_s, burst_rps)

    @classmethod
    def flash_crowd(cls, duration_s: float, base_rps: float,
                    flash_rps: float, flash_start_frac: float = 0.3,
                    flash_frac: float = 0.3) -> "ArrivalTrace":
        """Steady ``base_rps`` with a ``flash_rps`` spike over
        ``[start, start + flash_frac * duration)`` — the regime where
        accept-everything collapses and backpressure keeps the tight
        tier alive (benchmarks/fig_async_serving.py)."""
        t0 = flash_start_frac * duration_s
        t1 = t0 + flash_frac * duration_s

        def rate(t: float) -> float:
            return flash_rps if t0 <= t < t1 else base_rps
        return cls(rate, duration_s, flash_rps)


@dataclasses.dataclass
class TraceRequest:
    """One client of a trace workload: issue time, shape, SLO tier, and
    the abandonment deadline after which the client hangs up."""
    t_s: float                 # issue offset from trace start
    model: str
    prompt: np.ndarray
    max_new_tokens: int
    slo_ms: float
    tier: str
    #: client walks away (disconnects mid-stream) after this many
    #: seconds without completion; None = infinitely patient
    abandon_after_s: Optional[float] = None


def make_trace_requests(trace: ArrivalTrace, models: Dict[str, int],
                        seed: int = 0, prompt_len: Tuple[int, int] = (4, 24),
                        max_new: Tuple[int, int] = (4, 12),
                        tiers: Optional[Dict[str, Tuple[float, float]]]
                        = None,
                        abandon_factor: float = 4.0
                        ) -> List[TraceRequest]:
    """Materialise a trace into concrete per-client requests. ``models``
    maps model name -> vocab size (prompts are uniform token ids).
    Each request draws a tier from the ``tiers`` mix (default
    ``SLO_TIERS``) and abandons at ``abandon_factor``× its SLO — patient
    enough to outwait transient queueing, impatient enough that a
    collapsed pool sees mass disconnects."""
    tiers = tiers or SLO_TIERS
    rng = np.random.default_rng(seed)
    names = sorted(tiers)
    weights = np.asarray([tiers[n][1] for n in names])
    weights = weights / weights.sum()
    model_names = sorted(models)
    out: List[TraceRequest] = []
    for t in trace.arrival_times(seed):
        model = model_names[int(rng.integers(len(model_names)))]
        tier = names[int(rng.choice(len(names), p=weights))]
        slo_ms = tiers[tier][0]
        n_p = int(rng.integers(prompt_len[0], prompt_len[1] + 1))
        n_new = int(rng.integers(max_new[0], max_new[1] + 1))
        prompt = rng.integers(
            1, models[model], n_p).astype(np.int32)
        out.append(TraceRequest(
            float(t), model, prompt, n_new, slo_ms, tier,
            abandon_after_s=abandon_factor * slo_ms / 1000.0))
    return out


# ---------------------------------------------------------------------
# closed-loop HTTP client (docs/RUNTIME.md §11) — stdlib asyncio only
# ---------------------------------------------------------------------
@dataclasses.dataclass
class ClientOutcome:
    """Client-observed result of one streamed request: wall-clock TTFT /
    TPOT as the CLIENT saw them (connect -> first token event), and how
    the exchange ended."""
    outcome: str               # finished|rejected|throttled|abandoned|error
    tier: str = "standard"
    slo_ms: float = 0.0
    issue_s: float = 0.0       # wall clock at first connect
    ttft_s: float = -1.0       # first token event - issue
    finish_s: float = -1.0     # terminal event - issue
    n_tokens: int = 0
    retry_after_s: float = -1.0
    n_attempts: int = 1

    @property
    def tpot_s(self) -> float:
        if self.ttft_s < 0 or self.n_tokens < 2 or self.finish_s < 0:
            return -1.0
        return (self.finish_s - self.ttft_s) / (self.n_tokens - 1)

    @property
    def attained(self) -> bool:
        """Finished within the SLO, measured from the FIRST issue —
        retries after a 429 do not reset the clock."""
        return self.outcome == "finished" \
            and self.finish_s * 1000.0 <= self.slo_ms


async def _read_chunked_events(reader: asyncio.StreamReader):
    """Yield parsed ndjson events from a chunked HTTP body (the server
    writes exactly one event line per chunk)."""
    while True:
        size_line = await reader.readline()
        if not size_line:
            return
        size = int(size_line.strip() or b"0", 16)
        if size == 0:
            return
        data = await reader.readexactly(size)
        await reader.readexactly(2)  # trailing CRLF
        yield json.loads(data.decode())


async def http_generate(host: str, port: int, model: str,
                        prompt: np.ndarray, max_new_tokens: int,
                        slo_ms: float, tier: str = "standard",
                        abandon_after_s: Optional[float] = None,
                        t0: Optional[float] = None) -> ClientOutcome:
    """One closed-loop client: POST /v1/generate, stream events, record
    client-observed TTFT/TPOT. Abandons (closes the socket mid-stream —
    the server must propagate that to a cancel) when no terminal event
    arrives within ``abandon_after_s``."""
    issue = time.perf_counter() if t0 is None else t0
    out = ClientOutcome("error", tier=tier, slo_ms=slo_ms)
    body = json.dumps({
        "model": model, "prompt": [int(t) for t in prompt],
        "max_new_tokens": int(max_new_tokens),
        "slo_ms": float(slo_ms)}).encode()
    try:
        reader, writer = await asyncio.open_connection(host, port)
    except OSError:
        return out
    try:
        writer.write((
            f"POST /v1/generate HTTP/1.1\r\n"
            f"Host: {host}:{port}\r\n"
            f"Content-Type: application/json\r\n"
            f"Content-Length: {len(body)}\r\n\r\n").encode() + body)
        await writer.drain()
        status_line = await reader.readline()
        status = int(status_line.split()[1])
        headers: Dict[str, str] = {}
        while True:
            h = await reader.readline()
            if h in (b"\r\n", b"\n", b""):
                break
            k, _, v = h.decode("latin-1").partition(":")
            headers[k.strip().lower()] = v.strip()
        if status == 429:
            out.outcome = "throttled"
            out.retry_after_s = float(headers.get("retry-after", "0.05"))
            out.finish_s = time.perf_counter() - issue
            return out
        if status != 200:
            out.finish_s = time.perf_counter() - issue
            return out

        async def consume() -> None:
            async for ev in _read_chunked_events(reader):
                now = time.perf_counter() - issue
                kind = ev.get("event")
                if kind == "token":
                    if out.ttft_s < 0:
                        out.ttft_s = now
                    out.n_tokens = max(out.n_tokens, ev["index"] + 1)
                elif kind in ("finished", "rejected", "cancelled"):
                    out.outcome = kind
                    out.finish_s = now
                    if kind == "finished":
                        out.n_tokens = len(ev.get("tokens", []))
                    return

        try:
            await asyncio.wait_for(consume(), timeout=abandon_after_s)
        except asyncio.TimeoutError:
            out.outcome = "abandoned"
            out.finish_s = time.perf_counter() - issue
        except (asyncio.IncompleteReadError, ConnectionError, OSError):
            out.finish_s = time.perf_counter() - issue
        return out
    finally:
        writer.close()
        try:
            await writer.wait_closed()
        except (ConnectionError, OSError):
            pass


async def run_closed_loop(host: str, port: int,
                          requests: Sequence[TraceRequest],
                          time_scale: float = 1.0,
                          retry_on_429: bool = True,
                          max_retries: int = 2
                          ) -> List[ClientOutcome]:
    """Drive a materialised trace against a live server: one asyncio
    task per client, issued at ``t_s * time_scale`` offsets. Each client
    is closed-loop — it waits for its own completion (or abandons) and,
    on a 429, honours ``Retry-After`` before retrying (up to
    ``max_retries``; the abandonment clock keeps running from first
    issue, so a throttled tight-SLO client gives up rather than retry
    forever)."""
    start = time.perf_counter()

    async def one(tr: TraceRequest) -> ClientOutcome:
        await asyncio.sleep(max(0.0, tr.t_s * time_scale
                                - (time.perf_counter() - start)))
        issue = time.perf_counter()
        attempts = 0
        while True:
            budget = None if tr.abandon_after_s is None else \
                tr.abandon_after_s - (time.perf_counter() - issue)
            if budget is not None and budget <= 0:
                return ClientOutcome("abandoned", tier=tr.tier,
                                     slo_ms=tr.slo_ms, issue_s=issue,
                                     finish_s=time.perf_counter() - issue,
                                     n_attempts=attempts + 1)
            res = await http_generate(
                host, port, tr.model, tr.prompt, tr.max_new_tokens,
                tr.slo_ms, tier=tr.tier, abandon_after_s=budget, t0=issue)
            attempts += 1
            res.issue_s = issue
            res.n_attempts = attempts
            if res.outcome == "throttled" and retry_on_429 \
                    and attempts <= max_retries:
                await asyncio.sleep(max(0.01, res.retry_after_s))
                continue
            return res

    return list(await asyncio.gather(*(one(tr) for tr in requests)))


def summarize_outcomes(outcomes: Sequence[ClientOutcome]
                       ) -> Dict[str, float]:
    """Client-observed serving metrics over a closed-loop run: outcome
    counts, TTFT/TPOT percentiles (finished requests), and per-tier SLO
    attainment over ALL issued requests of that tier — throttled and
    abandoned clients count against attainment, which is exactly why
    backpressure has to EARN its 429s."""
    out: Dict[str, float] = {"n": float(len(outcomes))}
    for kind in ("finished", "rejected", "throttled", "abandoned",
                 "cancelled", "error"):
        out[f"n_{kind}"] = float(
            sum(1 for o in outcomes if o.outcome == kind))
    ttfts = [o.ttft_s * 1000.0 for o in outcomes if o.ttft_s >= 0]
    tpots = [o.tpot_s * 1000.0 for o in outcomes if o.tpot_s >= 0]
    out["ttft_ms_p50"] = float(np.percentile(ttfts, 50)) if ttfts else 0.0
    out["ttft_ms_p99"] = float(np.percentile(ttfts, 99)) if ttfts else 0.0
    out["tpot_ms_p50"] = float(np.percentile(tpots, 50)) if tpots else 0.0
    out["tpot_ms_p99"] = float(np.percentile(tpots, 99)) if tpots else 0.0
    for tier in sorted({o.tier for o in outcomes}):
        of_tier = [o for o in outcomes if o.tier == tier]
        out[f"attainment_{tier}"] = \
            sum(1 for o in of_tier if o.attained) / len(of_tier)
    return out
