"""Poisson request generator (§V-A: arrivals at 30 rps, Poisson, across the
six Table-IV models)."""
from __future__ import annotations

from typing import Dict, Iterator, List, Optional, Sequence

import numpy as np

from repro.configs.paper_edge_models import EDGE_MODELS
from repro.serving.request import Request


class PoissonWorkload:
    """Open-loop Poisson request generator (paper §V-A), optionally
    autoregressive via ``decode_steps_mean`` (docs/ARCHITECTURE.md §5)."""

    def __init__(self, rps: float = 30.0, models: Optional[Sequence[str]] = None,
                 mix: Optional[Dict[str, float]] = None, seed: int = 0,
                 decode_steps_mean: float = 1.0,
                 prefill_tokens_mean: float = 0.0,
                 shared_prefix_tokens: float = 0.0,
                 prefix_population: int = 4):
        """``rps`` is the PER-MODEL arrival rate (paper §V-A: 30 rps per
        served model); the aggregate rate is rps * len(models).

        ``decode_steps_mean`` > 1 makes the workload autoregressive: each
        request draws a geometric number of decode iterations with that
        mean, so sequences finish at different lengths — the regime
        continuous batching (docs/ARCHITECTURE.md §5) exploits.
        ``prefill_tokens_mean`` > 0 additionally gives each request a
        geometric prompt length that must be prefilled before decoding
        (the chunked-prefill regime).

        ``shared_prefix_tokens`` > 0 makes the trace *templated*
        (docs/ARCHITECTURE.md §5): each request's prompt starts with one
        of ``prefix_population`` shared prefixes of that length (drawn
        uniformly), prepended to its geometric unique tail — the
        workload regime the prefix cache exploits."""
        self.models = list(models or EDGE_MODELS.keys())
        self.rps = rps * len(self.models)
        if mix is None:
            mix = {m: 1.0 for m in self.models}
        total = sum(mix.values())
        self.probs = np.array([mix[m] / total for m in self.models])
        self.rng = np.random.default_rng(seed)
        self.decode_steps_mean = max(1.0, decode_steps_mean)
        self.prefill_tokens_mean = max(0.0, prefill_tokens_mean)
        self.shared_prefix_tokens = max(0.0, shared_prefix_tokens)
        self.prefix_population = max(1, prefix_population)
        self.now_ms = 0.0

    def _draw_decode_steps(self) -> int:
        if self.decode_steps_mean <= 1.0:
            return 1
        return int(self.rng.geometric(1.0 / self.decode_steps_mean))

    def _draw_prefill_tokens(self) -> int:
        if self.prefill_tokens_mean <= 0.0:
            return 0
        return int(self.rng.geometric(1.0 / self.prefill_tokens_mean))

    def _draw_prefix(self) -> tuple:
        """(prefix_id, prefix_tokens) of the shared template this
        request starts with; (-1, 0) for untemplated workloads."""
        if self.shared_prefix_tokens <= 0.0:
            return -1, 0
        return (int(self.rng.integers(self.prefix_population)),
                int(self.shared_prefix_tokens))

    def next_request(self) -> Request:
        gap_ms = self.rng.exponential(1000.0 / self.rps)
        self.now_ms += gap_ms
        name = self.rng.choice(self.models, p=self.probs)
        prof = EDGE_MODELS[name]
        prefix_id, prefix_tokens = self._draw_prefix()
        return Request(model=name, input_type=prof.task,
                       input_shape=prof.input_shape, slo_ms=prof.slo_ms,
                       arrival_ms=self.now_ms,
                       decode_steps=self._draw_decode_steps(),
                       prefill_tokens=prefix_tokens
                       + self._draw_prefill_tokens(),
                       prefix_id=prefix_id, prefix_tokens=prefix_tokens)

    def until(self, t_ms: float) -> Iterator[Request]:
        while True:
            r = self.next_request()
            if r.arrival_ms > t_ms:
                # rewind the clock so the pending gap is preserved
                self.now_ms = t_ms
                return
            yield r

    def burst(self, n: int) -> List[Request]:
        return [self.next_request() for _ in range(n)]
