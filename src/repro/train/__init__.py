from repro.train.optimizer import adam, adamw, sgd, chain_clip  # noqa: F401
from repro.train.train_state import TrainState  # noqa: F401
