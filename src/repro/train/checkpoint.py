"""npz-based pytree checkpointing with structure round-trip.

Checkpoints are written atomically (tmp + rename). The pytree structure is
recovered from the dotted leaf paths, so arbitrary nested dict/list/NamedTuple
states restore as nested dicts with identical leaf ordering (the optimizer /
model code treats params as dicts throughout, so this is lossless for us).
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Dict, List, Optional

import numpy as np

from repro.common.tree import flatten_with_paths


def save_checkpoint(path: str, tree: Any, metadata: Optional[Dict] = None) -> str:
    flat = flatten_with_paths(tree)
    arrays = {k: np.asarray(v) for k, v in flat}
    os.makedirs(os.path.dirname(os.path.abspath(path)) or ".", exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(os.path.abspath(path)),
                               suffix=".tmp")
    os.close(fd)
    try:
        with open(tmp, "wb") as f:
            np.savez(f, __meta__=np.frombuffer(
                json.dumps(metadata or {}).encode(), dtype=np.uint8), **arrays)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)
    return path


def load_checkpoint(path: str) -> Dict[str, Any]:
    """Returns a nested dict keyed by the original paths, plus '__meta__'."""
    out: Dict[str, Any] = {}
    with np.load(path) as z:
        meta = json.loads(bytes(z["__meta__"]).decode()) if "__meta__" in z else {}
        for key in z.files:
            if key == "__meta__":
                continue
            _insert(out, key.split("/"), z[key])
    out["__meta__"] = meta
    return out


def _insert(d: Dict, parts: List[str], value) -> None:
    for p in parts[:-1]:
        d = d.setdefault(p, {})
    d[parts[-1]] = value


def restore_like(template: Any, loaded: Dict[str, Any]) -> Any:
    """Map loaded arrays back onto the structure of ``template``."""
    import jax

    flat = flatten_with_paths(template)
    leaves = []
    for key, leaf in flat:
        node: Any = loaded
        for part in key.split("/"):
            node = node[part]
        arr = np.asarray(node)
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch at {key}: {arr.shape} vs {leaf.shape}")
        leaves.append(arr.astype(leaf.dtype))
    treedef = jax.tree_util.tree_structure(template)
    return jax.tree_util.tree_unflatten(treedef, leaves)
