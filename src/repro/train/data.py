"""Synthetic-but-deterministic data pipeline.

For LM training we synthesise token streams from a mixture of n-gram-ish
processes so the loss actually decreases (pure uniform tokens give a flat
loss and hide bugs). The pipeline is seeded, shardable (each data-parallel
rank draws a disjoint stream), and prefetches on a background thread.
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np


class TokenPipeline:
    """Markov-chain token stream -> (tokens, labels) batches."""

    def __init__(self, vocab_size: int, seq_len: int, batch: int,
                 seed: int = 0, order: int = 1, rank: int = 0,
                 world: int = 1, prefetch: int = 2):
        # order=1 keeps the context table (vocab^order) small enough that a
        # few hundred demo steps actually see each context repeatedly
        self.vocab = vocab_size
        self.seq_len = seq_len
        self.batch = batch
        self.rng = np.random.default_rng(seed * 9176 + rank)
        # sparse-ish transition preference: each context hashes to a small
        # set of likely next tokens => learnable structure.
        self._hash_a = int(self.rng.integers(1, 2**31 - 1)) | 1
        self._hash_b = int(self.rng.integers(1, 2**31 - 1))
        self.order = order
        self._stop = threading.Event()
        self._q: "queue.Queue[Dict[str, np.ndarray]]" = queue.Queue(maxsize=prefetch)
        self._thread: Optional[threading.Thread] = None

    def _next_tokens(self, ctx: np.ndarray) -> np.ndarray:
        # ctx: (batch, order) int64
        h = (ctx * self._hash_a).sum(-1) + self._hash_b
        base = (h % self.vocab).astype(np.int64)
        noise = self.rng.random(ctx.shape[0])
        rand_tok = self.rng.integers(0, self.vocab, size=ctx.shape[0])
        return np.where(noise < 0.75, base, rand_tok)

    def sample_batch(self) -> Dict[str, np.ndarray]:
        toks = np.zeros((self.batch, self.seq_len + 1), dtype=np.int32)
        toks[:, : self.order] = self.rng.integers(
            0, self.vocab, size=(self.batch, self.order))
        for t in range(self.order, self.seq_len + 1):
            toks[:, t] = self._next_tokens(
                toks[:, t - self.order: t].astype(np.int64))
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    # background prefetch -------------------------------------------------
    def _worker(self):
        while not self._stop.is_set():
            batch = self.sample_batch()
            while not self._stop.is_set():
                try:
                    self._q.put(batch, timeout=0.2)
                    break
                except queue.Full:
                    continue

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        if self._thread is None:
            self._thread = threading.Thread(target=self._worker, daemon=True)
            self._thread.start()
        try:
            while True:
                yield self._q.get()
        finally:
            self.close()

    def close(self):
        self._stop.set()
