"""Minimal optax-like optimizer library in pure JAX.

An optimizer is a pair of functions ``(init, update)``::

    state = init(params)
    updates, state = update(grads, state, params)
    params = apply_updates(params, updates)

Implemented: SGD(+momentum), Adam, AdamW, global-norm clipping, and
warmup-cosine / constant schedules. This is the full substrate used by both
the LM trainer (train_4k shape) and the SAC scheduler networks.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.tree import global_norm

Schedule = Callable[[jax.Array], jax.Array]


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], Tuple[Any, Any]]


def apply_updates(params: Any, updates: Any) -> Any:
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)


# ---------------------------------------------------------------- schedules
def constant(lr: float) -> Schedule:
    return lambda step: jnp.asarray(lr, jnp.float32)


def warmup_cosine(peak_lr: float, warmup: int, total: int,
                  floor: float = 0.1) -> Schedule:
    def sched(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / jnp.maximum(warmup, 1)
        frac = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup, warm, cos)

    return sched


def _as_schedule(lr) -> Schedule:
    return lr if callable(lr) else constant(lr)


# ---------------------------------------------------------------- SGD
class SGDState(NamedTuple):
    step: jax.Array
    momentum: Any


def sgd(lr, momentum: float = 0.0) -> Optimizer:
    sched = _as_schedule(lr)

    def init(params):
        mom = jax.tree.map(jnp.zeros_like, params) if momentum else None
        return SGDState(jnp.zeros((), jnp.int32), mom)

    def update(grads, state, params=None):
        lr_t = sched(state.step)
        if momentum:
            mom = jax.tree.map(lambda m, g: momentum * m + g, state.momentum, grads)
            updates = jax.tree.map(lambda m: -lr_t * m, mom)
        else:
            mom = None
            updates = jax.tree.map(lambda g: -lr_t * g, grads)
        return updates, SGDState(state.step + 1, mom)

    return Optimizer(init, update)


# ---------------------------------------------------------------- Adam(W)
class AdamState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def adam(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         weight_decay: float = 0.0,
         mask: Optional[Callable[[str], bool]] = None) -> Optimizer:
    """Adam; with ``weight_decay`` > 0 this is AdamW (decoupled decay).

    ``mask(path)`` (if given) returns False for leaves that should not be
    decayed (biases / norm scales).
    """
    sched = _as_schedule(lr)

    def init(params):
        zeros = lambda: jax.tree.map(  # noqa: E731
            lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
        return AdamState(jnp.zeros((), jnp.int32), zeros(), zeros())

    def update(grads, state, params=None):
        step = state.step + 1
        lr_t = sched(state.step)
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                          state.mu, grads)
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state.nu, grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(m, v, p):
            u = -lr_t * (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if weight_decay and p is not None:
                u = u - lr_t * weight_decay * p.astype(jnp.float32)
            return u

        if weight_decay and params is not None:
            if mask is not None:
                from repro.common.tree import tree_map_with_path

                decay_tree = tree_map_with_path(lambda k, p: mask(k), params)
                updates = jax.tree.map(
                    lambda m, v, p, d: upd(m, v, p if d else None),
                    mu, nu, params, decay_tree)
            else:
                updates = jax.tree.map(upd, mu, nu, params)
        else:
            updates = jax.tree.map(lambda m, v: upd(m, v, None), mu, nu)
        return updates, AdamState(step, mu, nu)

    return Optimizer(init, update)


def adamw(lr, weight_decay: float = 0.01, **kw) -> Optimizer:
    return adam(lr, weight_decay=weight_decay, **kw)


# ---------------------------------------------------------------- clipping
def chain_clip(opt: Optimizer, max_norm: float) -> Optimizer:
    """Global-norm gradient clipping composed in front of ``opt``."""

    def update(grads, state, params=None):
        gn = global_norm(grads)
        scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)
        return opt.update(grads, state, params)

    return Optimizer(opt.init, update)
