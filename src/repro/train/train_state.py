"""TrainState bundling params + optimizer state, flax-free."""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.train.optimizer import Optimizer, apply_updates


class TrainState(NamedTuple):
    step: jax.Array
    params: Any
    opt_state: Any

    @classmethod
    def create(cls, params: Any, opt: Optimizer) -> "TrainState":
        return cls(jnp.zeros((), jnp.int32), params, opt.init(params))

    def apply_gradients(self, grads: Any, opt: Optimizer) -> "TrainState":
        updates, opt_state = opt.update(grads, self.opt_state, self.params)
        params = apply_updates(self.params, updates)
        return TrainState(self.step + 1, params, opt_state)
