"""LM trainer: the end-to-end training driver (examples/train_small_lm.py
trains a ~100M-param model for a few hundred steps with it)."""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Iterator, Optional

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig
from repro.models import build_model
from repro.train.checkpoint import save_checkpoint
from repro.train.data import TokenPipeline
from repro.train.optimizer import adam, apply_updates, chain_clip, \
    warmup_cosine
from repro.train.train_state import TrainState


@dataclasses.dataclass
class TrainerConfig:
    batch: int = 8
    seq_len: int = 256
    steps: int = 300
    lr: float = 3e-4
    warmup: int = 50
    clip_norm: float = 1.0
    weight_decay: float = 0.01
    log_every: int = 20
    ckpt_path: Optional[str] = None
    seed: int = 0


class Trainer:
    def __init__(self, cfg: ModelConfig, tcfg: TrainerConfig = TrainerConfig()):
        self.cfg = cfg
        self.tcfg = tcfg
        self.model = build_model(cfg, remat=False)
        sched = warmup_cosine(tcfg.lr, tcfg.warmup, tcfg.steps)
        self.opt = chain_clip(
            adam(sched, weight_decay=tcfg.weight_decay,
                 mask=lambda path: path.split("/")[-1] not in
                 ("scale", "bias")), tcfg.clip_norm)
        params = self.model.init(jax.random.PRNGKey(tcfg.seed))
        self.state = TrainState.create(params, self.opt)
        self._step = jax.jit(self._train_step)

    def _train_step(self, state: TrainState, batch: Dict):
        loss, grads = jax.value_and_grad(self.model.loss)(state.params,
                                                          batch)
        new_state = state.apply_gradients(grads, self.opt)
        return new_state, loss

    def data(self) -> TokenPipeline:
        return TokenPipeline(self.cfg.vocab_size, self.tcfg.seq_len,
                             self.tcfg.batch, seed=self.tcfg.seed)

    def run(self, log: Callable[[str], None] = print) -> Dict[str, float]:
        pipe = self.data()
        losses = []
        t0 = time.time()
        it: Iterator = iter(pipe)
        for step in range(self.tcfg.steps):
            raw = next(it)
            batch = {k: jnp.asarray(v) for k, v in raw.items()}
            self.state, loss = self._step(self.state, batch)
            losses.append(float(loss))
            if step % self.tcfg.log_every == 0 or step == self.tcfg.steps - 1:
                tok_s = (self.tcfg.batch * self.tcfg.seq_len
                         * (step + 1)) / (time.time() - t0)
                log(f"step {step:4d} loss {losses[-1]:.4f} "
                    f"({tok_s:,.0f} tok/s)")
        pipe.close()
        if self.tcfg.ckpt_path:
            save_checkpoint(self.tcfg.ckpt_path, self.state.params,
                            {"steps": self.tcfg.steps,
                             "final_loss": losses[-1]})
        return {"first_loss": losses[0], "final_loss": losses[-1],
                "min_loss": min(losses)}
