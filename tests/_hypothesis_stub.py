"""Minimal fallback for the ``hypothesis`` API used by this test suite.

Some containers this repo runs in don't ship ``hypothesis``. Rather than
skipping every property test there, ``conftest.py`` registers this stub
in ``sys.modules`` when (and only when) the real library is missing. It
implements exactly the subset the suite uses — ``given``, ``settings``,
``strategies.integers/floats/sampled_from`` — as a seeded random sampler
that always exercises the strategy boundaries first. It does NOT shrink,
track coverage, or persist a failure database; when real hypothesis is
installed it is never imported.
"""
from __future__ import annotations

import functools
import inspect
import random
import zlib

__version__ = "0.stub"

_MAX_EXAMPLES_CAP = 25  # keep stubbed property sweeps fast


class _Strategy:
    def __init__(self, draw, boundary=()):
        self._draw = draw
        #: boundary values tried before random sampling (min/max etc.)
        self.boundary = tuple(boundary)

    def example(self, rng: random.Random):
        return self._draw(rng)


def integers(min_value: int = 0, max_value: int = 1 << 16) -> _Strategy:
    return _Strategy(lambda rng: rng.randint(min_value, max_value),
                     boundary=(min_value, max_value))


def floats(min_value: float = 0.0, max_value: float = 1.0) -> _Strategy:
    return _Strategy(lambda rng: rng.uniform(min_value, max_value),
                     boundary=(min_value, max_value))


def sampled_from(elements) -> _Strategy:
    elements = list(elements)
    return _Strategy(lambda rng: rng.choice(elements),
                     boundary=elements[:1])


def settings(**kw):
    """Decorator recording settings; composes with ``given`` either way."""
    def deco(fn):
        fn._stub_settings = {**getattr(fn, "_stub_settings", {}), **kw}
        return fn
    return deco


def given(**strats):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            cfg = {**getattr(fn, "_stub_settings", {}),
                   **getattr(wrapper, "_stub_settings", {})}
            n = min(int(cfg.get("max_examples", _MAX_EXAMPLES_CAP)),
                    _MAX_EXAMPLES_CAP)
            rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
            names = sorted(strats)
            # boundary sweep first (aligned tuples), then random examples
            width = max((len(strats[k].boundary) for k in names),
                        default=0)
            for i in range(width):
                drawn = {k: (strats[k].boundary[i]
                             if i < len(strats[k].boundary)
                             else strats[k].example(rng))
                         for k in names}
                fn(*args, **kwargs, **drawn)
            for _ in range(max(0, n - width)):
                drawn = {k: strats[k].example(rng) for k in names}
                fn(*args, **kwargs, **drawn)
        # hide the drawn parameters from pytest's fixture resolution
        # (real hypothesis rewrites the signature the same way)
        del wrapper.__wrapped__
        sig = inspect.signature(fn)
        wrapper.__signature__ = sig.replace(parameters=[
            p for name, p in sig.parameters.items() if name not in strats])
        return wrapper
    return deco


class strategies:  # noqa: N801 — mimics the hypothesis.strategies module
    integers = staticmethod(integers)
    floats = staticmethod(floats)
    sampled_from = staticmethod(sampled_from)
