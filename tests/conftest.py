"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches must
see the real single CPU device; only launch/dryrun.py forces 512."""
import importlib.util
import os
import sys

# make `import repro` work without installing
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# property tests prefer real hypothesis; fall back to the bundled sampler
# stub (tests/_hypothesis_stub.py) in containers that don't ship it
try:
    import hypothesis  # noqa: F401
except ImportError:
    _spec = importlib.util.spec_from_file_location(
        "hypothesis", os.path.join(os.path.dirname(__file__),
                                   "_hypothesis_stub.py"))
    _stub = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_stub)
    sys.modules["hypothesis"] = _stub

import dataclasses  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402

from repro.config.base import ModelConfig  # noqa: E402

# ---------------------------------------------------------------- models
#: the canonical tiny dense model the serving tests drive (one shared
#: definition instead of a copy per test module)
TINY = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=32,
                   n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=97)

#: one config per layer-kind family engine surgery (graft, chunked
#: prefill, fuzz) must round-trip — parametrize over sorted(KIND_CFGS);
#: the 5 layer families plus the all-windowed small-window edge case
KIND_CFGS = {
    "global": TINY,
    "windowed": dataclasses.replace(TINY, name="tiny-win",
                                    block_pattern=("attn", "local_attn"),
                                    sliding_window=16),
    "rglru": dataclasses.replace(TINY, name="tiny-rg", family="hybrid",
                                 block_pattern=("rglru", "attn")),
    "rwkv": dataclasses.replace(TINY, name="tiny-rwkv", family="ssm",
                                d_model=64, block_pattern=("rwkv",),
                                rwkv_head_size=32),
    "tail": dataclasses.replace(TINY, name="tiny-tail", n_layers=3,
                                block_pattern=("attn", "attn")),
    # every layer a ring buffer, window SMALLER than typical chunk
    # sizes — the wraparound edge chunked prefill must round-trip
    "swa": dataclasses.replace(TINY, name="tiny-swa", sliding_window=8,
                               block_pattern=("local_attn",)),
}


def tiny_variant(**overrides) -> ModelConfig:
    """A one-off TINY derivative (name it, or collide in jit caches)."""
    return dataclasses.replace(TINY, **overrides)


def make_cont_engine(cfg: ModelConfig = TINY, max_slots: int = 2,
                     max_seq: int = 64, **kw):
    """Continuous-engine factory with the suite's default tiny shape."""
    from repro.serving.engine import ContinuousBatchingEngine

    return ContinuousBatchingEngine(cfg, max_slots=max_slots,
                                    max_seq=max_seq, **kw)


def make_pool(cfg: ModelConfig = TINY, max_instances: int = 2,
              max_slots: int = 2, max_seq: int = 64, **kw):
    """Single-model pool factory over the shared tiny config."""
    from repro.serving.runtime import ModelInstancePool

    return ModelInstancePool({cfg.name: cfg}, max_instances=max_instances,
                             max_slots=max_slots, max_seq=max_seq, **kw)


@pytest.fixture(scope="session")
def tiny_cfg() -> ModelConfig:
    return TINY


@pytest.fixture(params=sorted(KIND_CFGS))
def kind_cfg(request) -> ModelConfig:
    """Parametrized fixture over the layer-family configs."""
    return KIND_CFGS[request.param]


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture()
def key():
    return jax.random.PRNGKey(0)
