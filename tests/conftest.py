"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches must
see the real single CPU device; only launch/dryrun.py forces 512."""
import importlib.util
import os
import sys

# make `import repro` work without installing
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# property tests prefer real hypothesis; fall back to the bundled sampler
# stub (tests/_hypothesis_stub.py) in containers that don't ship it
try:
    import hypothesis  # noqa: F401
except ImportError:
    _spec = importlib.util.spec_from_file_location(
        "hypothesis", os.path.join(os.path.dirname(__file__),
                                   "_hypothesis_stub.py"))
    _stub = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_stub)
    sys.modules["hypothesis"] = _stub

import jax  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture()
def key():
    return jax.random.PRNGKey(0)
