"""Per-architecture smoke tests (deliverable f): a REDUCED variant of each
assigned arch runs one forward/train step on CPU — output shapes + no NaNs.
Full configs are exercised only via the dry-run (ShapeDtypeStruct)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import INPUT_SHAPES, get_config, get_reduced_config

# one forward/train step per architecture adds up to minutes: excluded
# from the tier-1 CI job, covered by the full-suite job (pytest.ini)
pytestmark = pytest.mark.slow
from repro.models import build_model
from repro.train.optimizer import adam, apply_updates

ARCHS = [
    "llama4-maverick-400b-a17b", "rwkv6-3b", "starcoder2-15b",
    "qwen2-vl-7b", "recurrentgemma-2b", "chatglm3-6b",
    "seamless-m4t-large-v2", "yi-34b", "arctic-480b", "qwen3-0.6b",
]


def make_batch(cfg, B=2, S=32, seed=0):
    rng = np.random.default_rng(seed)
    batch = {}
    if cfg.enc_dec:
        batch["frontend_embeds"] = jnp.asarray(
            rng.standard_normal((B, max(8, S // 4), cfg.d_model)) * 0.1,
            jnp.float32)
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    elif cfg.frontend is not None:
        F = cfg.frontend_tokens
        batch["frontend_embeds"] = jnp.asarray(
            rng.standard_normal((B, F, cfg.d_model)) * 0.1, jnp.float32)
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, S - F)), jnp.int32)
    else:
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    batch["labels"] = jnp.asarray(
        rng.integers(0, cfg.vocab_size, batch["tokens"].shape), jnp.int32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_config_constraints(arch):
    cfg = get_reduced_config(arch)
    assert cfg.n_layers <= 3
    assert cfg.d_model <= 512
    assert cfg.n_experts <= 4
    assert get_config(arch).family == cfg.family


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch):
    cfg = get_reduced_config(arch)
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg)

    loss = model.loss(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"

    # one SGD-ish train step must also be finite and change params
    opt = adam(1e-3)
    opt_state = opt.init(params)
    grads = jax.grad(model.loss)(params, batch)
    for g in jax.tree.leaves(grads):
        assert bool(jnp.isfinite(g).all()), f"{arch}: non-finite grads"
    updates, _ = opt.update(grads, opt_state, params)
    new_params = apply_updates(params, updates)
    moved = any(
        not np.allclose(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(params),
                        jax.tree.leaves(new_params)))
    assert moved, f"{arch}: train step did not change params"


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_shapes(arch):
    cfg = get_reduced_config(arch)
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 16
    batch = make_batch(cfg, B=B, S=S)
    batch.pop("labels")
    logits, cache = model.prefill(params, batch)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite prefill"

    total = S if not (cfg.frontend and not cfg.enc_dec) else S
    from repro.models.transformer import pad_cache

    cache = pad_cache(cfg, cache, 4)
    db = {"tokens": jnp.ones((B, 1), jnp.int32),
          "pos": jnp.full((B,), total, jnp.int32)}
    logits2, cache2 = model.decode_step(params, cache, db)
    assert logits2.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits2).all()), f"{arch}: non-finite decode"


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "rwkv6-3b",
                                  "recurrentgemma-2b", "chatglm3-6b",
                                  "qwen2-vl-7b", "arctic-480b"])
def test_decode_matches_full_forward(arch):
    """Teacher-forced decode must reproduce the full-sequence logits."""
    cfg = get_reduced_config(arch)
    model = build_model(cfg, remat=False, attn_impl="naive")
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 12
    rng = np.random.default_rng(3)
    batch = make_batch(cfg, B=B, S=S, seed=3)
    batch.pop("labels")

    # full prefill over S tokens -> last-token logits
    full_logits, _ = model.prefill(params, batch)

    # prefill S-1 tokens, then decode token S-1 step by step
    short = dict(batch)
    short["tokens"] = batch["tokens"][:, :-1]
    _, cache = model.prefill(params, short)
    from repro.models.transformer import pad_cache

    cache = pad_cache(cfg, cache, 2)
    # total context = S for every family (vlm: F frontend + (S-F) text)
    pos = jnp.full((B,), S - 1, jnp.int32)
    step_logits, _ = model.decode_step(
        params, cache, {"tokens": batch["tokens"][:, -1:], "pos": pos})
    np.testing.assert_allclose(
        np.asarray(step_logits, np.float32),
        np.asarray(full_logits, np.float32), atol=2e-3, rtol=2e-3)


def test_full_configs_match_assignment():
    spec = {
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048, 128),
        "rwkv6-3b": (32, 2560, None, None, 8960, 65536, 0),
        "starcoder2-15b": (40, 6144, 48, 4, 24576, 49152, 0),
        "qwen2-vl-7b": (28, 3584, 28, 4, 18944, 152064, 0),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000, 0),
        "chatglm3-6b": (28, 4096, 32, 2, 13696, 65024, 0),
        "seamless-m4t-large-v2": (24, 1024, 16, 16, 8192, 256208, 0),
        "yi-34b": (60, 7168, 56, 8, 20480, 64000, 0),
        "arctic-480b": (35, 7168, 56, 8, 4864, 32000, 128),
        "qwen3-0.6b": (28, 1024, 16, 8, 3072, 151936, 0),
    }
    for arch, (L, d, H, KV, ff, V, E) in spec.items():
        cfg = get_config(arch)
        assert cfg.n_layers == L, arch
        assert cfg.d_model == d, arch
        if H is not None:
            assert cfg.n_heads == H, arch
            assert cfg.n_kv_heads == KV, arch
        assert cfg.d_ff == ff, arch
        assert cfg.vocab_size == V, arch
        assert cfg.n_experts == E, arch


def test_param_count_estimates():
    """Analytic param counts should land near the advertised sizes."""
    import math

    targets = {"llama4-maverick-400b-a17b": (400e9, 0.25),
               "yi-34b": (34e9, 0.15),
               "arctic-480b": (480e9, 0.15),
               "qwen3-0.6b": (0.6e9, 0.25),
               "starcoder2-15b": (15e9, 0.25),
               "rwkv6-3b": (3e9, 0.4)}
    for arch, (target, tol) in targets.items():
        got = get_config(arch).param_count_estimate()
        assert math.isclose(got, target, rel_tol=tol), \
            f"{arch}: {got/1e9:.1f}B vs {target/1e9:.0f}B"
