"""Push-mode serving: request lifecycle, cancellation at every phase,
wall-clock timing, the background driver, and the HTTP front-end
(docs/RUNTIME.md §11).

Four layers, mirroring the stack:

* ``RequestLifecycle`` — the explicit state machine (legal edges only,
  timestamps and token counters stamped on transition);
* engine ``cancel()`` — queued / mid-prefill / mid-decode / preempted,
  with synchronous block free and token-identical survivors;
* pool ``cancel()`` + events + TTFT/TPOT stats — including the
  queue-head starvation regression (a cancelled-while-QUEUED request
  must leave the EDF queue immediately, not rot at the head);
* ``ServingDriver`` + ``ServingFrontend`` — background stepping, event
  streaming over HTTP, disconnect-cancel, and 429 backpressure.
"""
import asyncio
import json
import threading
import time

import numpy as np
import pytest

from conftest import TINY, make_cont_engine, make_pool
from repro.serving import request as lc
from repro.serving.driver import ServingDriver
from repro.serving.request import RequestLifecycle


def _prompt(n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(1, TINY.vocab_size, n).astype(np.int32)


# ------------------------------------------------------------ lifecycle
def test_lifecycle_legal_path_and_stamps():
    events = []
    l = RequestLifecycle(7, enqueue_s=10.0,
                         on_event=lambda l, s: events.append(s))
    assert l.state == lc.QUEUED and not l.terminal
    l.to(lc.PREFILL, now_s=10.5)
    assert l.admit_s == 10.5
    l.token(42, 0, now_s=10.8)
    l.to(lc.DECODE, now_s=10.9)
    l.token(43, 1, now_s=11.0)
    l.to(lc.FINISHED, now_s=11.1)
    assert l.terminal and l.finish_s == 11.1
    assert l.first_token_s == 10.8 and l.n_tokens == 2
    assert l.ttft_s() == pytest.approx(0.8)
    assert l.tpot_s() == pytest.approx(0.3)  # (finish - first) / (n - 1)
    assert events == [lc.PREFILL, lc.DECODE, lc.FINISHED]


def test_lifecycle_illegal_edges_raise():
    l = RequestLifecycle(1, enqueue_s=0.0)
    with pytest.raises(ValueError):
        l.to(lc.FINISHED, now_s=1.0)  # QUEUED -/-> FINISHED
    l.to(lc.PREFILL, now_s=1.0)
    with pytest.raises(ValueError):
        l.to(lc.QUEUED, now_s=2.0)  # PREFILL -/-> QUEUED
    l.to(lc.DECODE, now_s=2.0)
    l.to(lc.QUEUED, now_s=3.0)  # preemption edge
    assert l.n_preempted == 1
    l.to(lc.DECODE, now_s=4.0)  # inline re-admission
    l.to(lc.CANCELLED, now_s=5.0)
    with pytest.raises(ValueError):
        l.to(lc.DECODE, now_s=6.0)  # terminal is terminal


def test_lifecycle_cancellable_from_every_nonterminal():
    for path in ([], [lc.PREFILL], [lc.PREFILL, lc.DECODE],
                 [lc.DECODE, lc.QUEUED]):
        l = RequestLifecycle(1, enqueue_s=0.0)
        t = 1.0
        for s in path:
            l.to(s, now_s=t)
            t += 1.0
        l.to(lc.CANCELLED, now_s=t)
        assert l.terminal and l.state == lc.CANCELLED


# ------------------------------------------------------- engine cancel
def _drain(eng, results):
    guard = 600
    while (eng.waiting or eng.active_slots) and guard:
        for r in eng.step():
            results[r.request_id] = r
        guard -= 1
    assert guard, "engine failed to drain"


def _assert_no_leak(eng):
    if eng.allocator is not None:
        assert eng.allocator.n_live == 0
        assert eng.allocator.n_reserved == 0


def test_engine_cancel_queued_and_survivor_identity():
    eng = make_cont_engine(TINY, max_slots=1, max_seq=64,
                           kv_layout="paged", block_size=8)
    p1, p2 = _prompt(8, 1), _prompt(8, 2)
    oracle = make_cont_engine(TINY, max_slots=1, max_seq=64,
                              share_from=eng).run(
        [p1], max_new_tokens=5)[0].tokens
    r1 = eng.submit(p1, max_new_tokens=5)
    r2 = eng.submit(p2, max_new_tokens=5)  # waits: single slot
    for _ in range(2):
        eng.step()
    res = eng.cancel(r2)
    assert res is not None and res.cancelled and res.request_id == r2
    assert not eng.waiting, "cancelled request still queued"
    results = {}
    _drain(eng, results)
    np.testing.assert_array_equal(results[r1].tokens, oracle)
    assert eng.stats()["n_cancelled"] == 1
    _assert_no_leak(eng)


def test_engine_cancel_mid_decode_frees_blocks_synchronously():
    eng = make_cont_engine(TINY, max_slots=2, max_seq=64,
                           kv_layout="paged", block_size=8)
    p1, p2 = _prompt(8, 3), _prompt(8, 4)
    oracle = make_cont_engine(TINY, max_slots=1, max_seq=64,
                              share_from=eng).run(
        [p1], max_new_tokens=6)[0].tokens
    r1 = eng.submit(p1, max_new_tokens=6)
    r2 = eng.submit(p2, max_new_tokens=20)
    for _ in range(3):
        eng.step()
    live_before = eng.allocator.n_live
    res = eng.cancel(r2)
    assert res.cancelled and 0 < len(res.tokens) < 20
    assert eng.allocator.n_live < live_before, \
        "blocks not freed synchronously on cancel"
    results = {}
    _drain(eng, results)
    np.testing.assert_array_equal(results[r1].tokens, oracle)
    _assert_no_leak(eng)


def test_engine_cancel_mid_prefill_and_preempted():
    # token budget forces multi-chunk prefill AND enables preemption
    eng = make_cont_engine(TINY, max_slots=2, max_seq=64,
                           kv_layout="paged", block_size=8,
                           token_budget=8)
    long_p, short_p = _prompt(30, 5), _prompt(6, 6)
    oracle = make_cont_engine(TINY, max_slots=1, max_seq=64,
                              share_from=eng).run(
        [short_p], max_new_tokens=5)[0].tokens
    rid_long = eng.submit(long_p, max_new_tokens=5)
    eng.step()  # first prefill chunk lands, slot is mid-prefill
    res = eng.cancel(rid_long)
    assert res is not None and res.cancelled
    _assert_no_leak(eng)

    # preempted phase: get one decoding, preempt it, cancel it
    rid_a = eng.submit(long_p, max_new_tokens=5)
    rid_b = eng.submit(short_p, max_new_tokens=5)
    guard = 100
    while rid_a not in [eng.slots[i].request_id
                        for i in eng.decoding_slots] and guard:
        eng.step()
        guard -= 1
    assert guard, "request never reached decode"
    slot = next(i for i in eng.decoding_slots
                if eng.slots[i].request_id == rid_a)
    eng.preempt(slot)
    res = eng.cancel(rid_a)  # cancelled while preempted-awaiting-resume
    assert res is not None and res.cancelled
    results = {}
    _drain(eng, results)
    np.testing.assert_array_equal(results[rid_b].tokens, oracle)
    assert eng.stats()["n_cancelled"] == 2
    _assert_no_leak(eng)


def test_engine_cancel_unknown_or_finished_is_noop():
    eng = make_cont_engine(TINY, max_slots=1, max_seq=64)
    assert eng.cancel(999) is None
    rid = eng.submit(_prompt(6, 7), max_new_tokens=2)
    results = {}
    _drain(eng, results)
    assert rid in results
    assert eng.cancel(rid) is None  # already finished
    assert eng.stats()["n_cancelled"] == 0


# --------------------------------------------------------- pool cancel
def test_pool_cancel_dequeues_immediately_no_head_starvation():
    pool = make_pool(TINY, max_instances=1, max_slots=1, max_seq=64,
                     kv_layout="paged", block_size=8)
    pool.scale_to(TINY.name, 1)
    pool.warmup(seed=0)
    # r0 occupies the only slot for a while
    r0 = pool.submit(TINY.name, _prompt(6, 8), slo_ms=5000.0,
                     max_new_tokens=24)
    pool.step()
    # r1 goes to the EDF queue HEAD (tightest deadline), r2 behind it
    r1 = pool.submit(TINY.name, _prompt(6, 9), slo_ms=10.0,
                     max_new_tokens=4)
    r2 = pool.submit(TINY.name, _prompt(6, 10), slo_ms=8000.0,
                     max_new_tokens=4)
    assert len(pool.queues[TINY.name]) == 2
    res = pool.cancel(r1)
    assert res is not None and res.cancelled
    # the regression: the cancelled head must leave the queue NOW —
    # not linger as a tombstone that starves r2 behind it
    assert len(pool.queues[TINY.name]) == 1
    pool.run_until_drained()
    by_id = {r.request_id: r for r in pool.results(TINY.name)}
    assert not by_id[r0].cancelled and not by_id[r2].cancelled
    assert len(by_id[r2].tokens) == 4
    assert pool.stats()["n_cancelled"] == 1
    rep = pool.report()[TINY.name]
    assert rep["cancelled"] == 1
    # cancelled requests are excluded from attainment accounting
    assert rep["served"] == 2


def test_pool_cancel_running_and_unknown():
    pool = make_pool(TINY, max_instances=1, max_slots=2, max_seq=64)
    pool.scale_to(TINY.name, 1)
    pool.warmup(seed=0)
    rid = pool.submit(TINY.name, _prompt(6, 11), slo_ms=5000.0,
                      max_new_tokens=24)
    for _ in range(3):
        pool.step()
    res = pool.cancel(rid)
    assert res is not None and res.cancelled and len(res.tokens) < 24
    assert pool.cancel(rid) is None  # second cancel: no-op
    assert pool.cancel(12345) is None
    pool.run_until_drained()


def test_pool_events_and_wallclock_stats():
    pool = make_pool(TINY, max_instances=1, max_slots=2, max_seq=64)
    pool.scale_to(TINY.name, 1)
    pool.warmup(seed=0)
    events = []
    rid = pool.submit(TINY.name, _prompt(6, 12), slo_ms=5000.0,
                      max_new_tokens=4)
    pool.add_listener(rid, events.append)
    pool.run_until_drained()
    kinds = [e["event"] for e in events]
    assert kinds.count("token") == 4
    assert kinds[-1] == "finished"
    assert kinds.index("prefill" if "prefill" in kinds else "decode") \
        < kinds.index("token")
    tok_events = [e for e in events if e["event"] == "token"]
    assert [e["index"] for e in tok_events] == [0, 1, 2, 3]
    res = pool.results(TINY.name)[-1]
    assert res.first_token_s > 0 and res.ttft_ms >= 0
    assert res.tpot_ms >= 0
    st = pool.stats()
    assert st["ttft_ms_p99"] > 0 and st["tpot_ms_p50"] >= 0
    req_lc = events[-1]  # finished event carries the terminal payload
    assert req_lc["request_id"] == rid


def test_pool_admission_headroom_fields():
    pool = make_pool(TINY, max_instances=1, max_slots=1, max_seq=64)
    pool.scale_to(TINY.name, 1)
    pool.warmup(seed=0)
    head = pool.admission_headroom(TINY.name, 8, 4)
    assert head["admissible_now"] and head["queue_depth"] == 0
    # clog the slot + queue
    pool.submit(TINY.name, _prompt(6, 13), slo_ms=5000.0,
                max_new_tokens=24)
    pool.step()
    for s in range(4):
        pool.submit(TINY.name, _prompt(6, 20 + s), slo_ms=5000.0,
                    max_new_tokens=8)
    head = pool.admission_headroom(TINY.name, 8, 4)
    assert not head["admissible_now"] and head["queue_depth"] == 4
    assert head["retry_after_s"] > 0 and head["backlog_tokens"] > 0
    pool.run_until_drained()


# -------------------------------------------------------------- driver
def test_driver_background_submit_and_events():
    pool = make_pool(TINY, max_instances=1, max_slots=2, max_seq=64)
    pool.scale_to(TINY.name, 1)
    pool.warmup(seed=0)
    done = threading.Event()
    events = []

    def listener(ev):
        events.append(ev)
        if ev["event"] in ("finished", "cancelled", "rejected"):
            done.set()

    with ServingDriver(pool, idle_sleep_s=0.001) as driver:
        assert driver.running
        rid = driver.submit(TINY.name, _prompt(6, 14), slo_ms=5000.0,
                            max_new_tokens=4)
        driver.add_listener(rid, listener)
        assert done.wait(timeout=30.0), "no terminal event from driver"
        driver.drain(timeout_s=30.0)
    assert not driver.running
    assert [e["event"] for e in events][-1] == "finished"
    assert driver.n_loop_steps > 0


def test_driver_cancel_and_stop_idempotent():
    pool = make_pool(TINY, max_instances=1, max_slots=1, max_seq=64)
    pool.scale_to(TINY.name, 1)
    pool.warmup(seed=0)
    driver = ServingDriver(pool).start()
    try:
        rid = driver.submit(TINY.name, _prompt(6, 15), slo_ms=5000.0,
                            max_new_tokens=48)
        deadline = time.perf_counter() + 30.0
        res = None
        while res is None and time.perf_counter() < deadline:
            time.sleep(0.01)
            res = driver.cancel(rid)
        assert res is not None and res.cancelled
    finally:
        driver.stop()
        driver.stop()  # idempotent
    assert pool.stats()["n_cancelled"] == 1


# ---------------------------------------------------------------- http
def _http_stack(backpressure=True, max_queue_depth=2, max_slots=2):
    pool = make_pool(TINY, max_instances=1, max_slots=max_slots,
                     max_seq=64, kv_layout="paged", block_size=8)
    pool.scale_to(TINY.name, 1)
    pool.warmup(seed=0)
    driver = ServingDriver(pool)
    from repro.launch.server import ServingFrontend
    fe = ServingFrontend(driver, port=0, backpressure=backpressure,
                         max_queue_depth=max_queue_depth)
    return pool, driver, fe


def test_http_stream_end_to_end():
    from repro.serving.workload import http_generate

    async def run():
        pool, driver, fe = _http_stack()
        driver.start()
        await fe.start()
        try:
            out = await http_generate("127.0.0.1", fe.port, TINY.name,
                                      _prompt(8, 16), 5, 5000.0)
        finally:
            await fe.stop()
            driver.stop()
        return pool, out

    pool, out = asyncio.run(run())
    assert out.outcome == "finished" and out.n_tokens == 5
    assert out.ttft_s >= 0 and out.tpot_s >= 0
    assert pool.stats()["n_cancelled"] == 0


def test_http_disconnect_cancels_and_frees():
    from repro.serving.workload import _read_chunked_events

    async def run():
        pool, driver, fe = _http_stack()
        driver.start()
        await fe.start()
        try:
            # raw client: read up to the FIRST token event, then hang up
            # mid-stream — deterministic regardless of decode speed
            body = json.dumps({"model": TINY.name,
                               "prompt": _prompt(8, 17).tolist(),
                               "max_new_tokens": 48,
                               "slo_ms": 5000.0}).encode()
            reader, writer = await asyncio.open_connection(
                "127.0.0.1", fe.port)
            writer.write((f"POST /v1/generate HTTP/1.1\r\n"
                          f"Content-Length: {len(body)}\r\n\r\n"
                          ).encode() + body)
            await writer.drain()
            status = await reader.readline()
            assert b"200" in status, status
            while await reader.readline() not in (b"\r\n", b"\n", b""):
                pass
            async for ev in _read_chunked_events(reader):
                if ev.get("event") == "token":
                    break
            writer.close()
            deadline = time.perf_counter() + 30.0
            while pool.stats()["n_cancelled"] < 1 \
                    and time.perf_counter() < deadline:
                await asyncio.sleep(0.01)
            await asyncio.get_running_loop().run_in_executor(
                None, driver.drain, 30.0)
        finally:
            await fe.stop()
            driver.stop()
        return pool, fe

    pool, fe = asyncio.run(run())
    assert fe.n_disconnects == 1
    assert pool.stats()["n_cancelled"] == 1
    for inst in pool.live():
        assert inst.engine.allocator.n_live == 0
        assert inst.engine.allocator.n_reserved == 0


def test_http_backpressure_429_with_retry_after():
    from repro.serving.workload import http_generate

    async def run():
        pool, driver, fe = _http_stack(max_queue_depth=1, max_slots=1)
        driver.start()
        await fe.start()
        try:
            # pin the only slot first and wait until the pool reports
            # non-admissible — otherwise all 6 checks below race ahead
            # of the driver thread and see a still-empty engine
            driver.submit(TINY.name, _prompt(8, 29), slo_ms=5000.0,
                          max_new_tokens=48)
            deadline = time.perf_counter() + 30.0
            while pool.admission_headroom(TINY.name, 8, 32)[
                    "admissible_now"] and time.perf_counter() < deadline:
                await asyncio.sleep(0.001)
            outs = await asyncio.gather(*(
                http_generate("127.0.0.1", fe.port, TINY.name,
                              _prompt(8, 30 + i), 32, 5000.0)
                for i in range(6)))
        finally:
            await fe.stop()
            driver.stop()
        return fe, outs

    fe, outs = asyncio.run(run())
    throttled = [o for o in outs if o.outcome == "throttled"]
    assert throttled, "no 429 under saturation"
    assert all(o.retry_after_s > 0 for o in throttled)
    assert any(o.outcome == "finished" for o in outs)
    assert fe.n_throttled == len(throttled)


def test_http_bad_requests():
    async def run():
        pool, driver, fe = _http_stack()
        driver.start()
        await fe.start()
        results = []
        try:
            for body in (json.dumps({"model": "nope", "prompt": [1]}),
                         json.dumps({"model": TINY.name, "prompt": []}),
                         "not json"):
                reader, writer = await asyncio.open_connection(
                    "127.0.0.1", fe.port)
                data = body.encode()
                writer.write((f"POST /v1/generate HTTP/1.1\r\n"
                              f"Content-Length: {len(data)}\r\n\r\n"
                              ).encode() + data)
                await writer.drain()
                status = await reader.readline()
                results.append(status.decode())
                writer.close()
        finally:
            await fe.stop()
            driver.stop()
        return results

    for status in asyncio.run(run()):
        assert "400" in status, status
