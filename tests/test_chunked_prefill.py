"""Chunked prefill (docs/ARCHITECTURE.md §5): model-level chunk
continuation is math-identical to single-shot prefill, the engine's
per-iteration token budget bounds prefill+decode work, and the submit
clamp is surfaced as ``ContinuousResult.truncated``."""
import numpy as np
import pytest

from conftest import KIND_CFGS, TINY
from repro.serving.engine import (ContinuousBatchingEngine, InferenceEngine,
                                  SEQ_BUCKETS, _bucket)


# ------------------------------------------------- model-level identity
@pytest.mark.slow
@pytest.mark.parametrize("kind", sorted(KIND_CFGS))
def test_prefill_chunk_matches_full_prefill(kind):
    """Processing a prompt in chunks through ``prefill_chunk`` must be
    token-identical to one full ``prefill`` — for every layer family
    (linear attention, sliding-window rings, recurrent state, unrolled
    tails)."""
    import jax
    import jax.numpy as jnp

    from repro.models import build_model
    from repro.models.transformer import pad_cache

    cfg = KIND_CFGS[kind]
    S, extra = 32, 6
    m = build_model(cfg, remat=False)
    p = m.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    toks = rng.integers(1, cfg.vocab_size, S).astype(np.int32)
    dec = jax.jit(m.decode_step)

    logits, cache = jax.jit(m.prefill)(p, {"tokens": jnp.asarray(toks[None])})
    cache = pad_cache(cfg, cache, extra)
    ref = [int(jnp.argmax(logits[0, -1]))]
    pos = jnp.array([S], jnp.int32)
    for _ in range(extra - 1):
        lg, cache = dec(p, cache, {"tokens": jnp.asarray([[ref[-1]]],
                                                         jnp.int32),
                                   "pos": pos})
        ref.append(int(jnp.argmax(lg[0, -1])))
        pos = pos + 1

    cache2 = m.init_cache(1, S + extra)
    pc = jax.jit(m.prefill_chunk)
    off = 0
    for c in (8, 8, 16):  # includes a ragged mix of chunk sizes
        lg2, cache2 = pc(p, cache2,
                         {"tokens": jnp.asarray(toks[None, off:off + c]),
                          "pos": jnp.array([off], jnp.int32)})
        off += c
    out = [int(jnp.argmax(lg2[0, -1]))]
    pos = jnp.array([S], jnp.int32)
    for _ in range(extra - 1):
        lg2, cache2 = dec(p, cache2, {"tokens": jnp.asarray([[out[-1]]],
                                                            jnp.int32),
                                      "pos": pos})
        out.append(int(jnp.argmax(lg2[0, -1])))
        pos = pos + 1
    assert ref == out


# ------------------------------------------------- engine semantics
@pytest.mark.slow
def test_budgeted_engine_matches_round_engine_greedy():
    """A tight token budget changes WHEN prefill work happens, never the
    result: greedy outputs stay identical to the round engine."""
    round_eng = InferenceEngine(TINY, max_seq=64)
    eng = ContinuousBatchingEngine(TINY, max_slots=2, max_seq=64,
                                   token_budget=8)
    rng = np.random.default_rng(2)
    prompts = [rng.integers(1, 97, n).astype(np.int32) for n in (4, 9, 13)]
    ref = [round_eng.generate([p], max_new_tokens=4).tokens[0]
           for p in prompts]
    res = eng.run(prompts, max_new_tokens=4)
    for r, expected in zip(res, ref):
        assert np.array_equal(r.tokens, expected)


@pytest.mark.slow
@pytest.mark.parametrize("prefill_mode", ["fused"])
@pytest.mark.parametrize("prefix_cache", [False, True])
def test_paged_chunked_identity_staging_vs_fused(prefill_mode, prefix_cache):
    """The fused path (chunks attend the block pool directly through
    their table row) stays token-identical to the dense round engine on
    paged chunked prefill, with and without prefix reuse. This
    parametrization was the deletion gate for the legacy staging round
    trip — "staging" was dropped and the code deleted (the staging
    cache survives only for layouts fused prefill cannot serve: dense
    and hybrid stacks, covered by the engine fuzz harness)."""
    round_eng = InferenceEngine(TINY, max_seq=64)
    eng = ContinuousBatchingEngine(
        TINY, max_slots=2, max_seq=64, kv_layout="paged", block_size=8,
        token_budget=12, prefix_cache=prefix_cache,
        prefill_mode=prefill_mode)
    assert eng.fused_prefill == (prefill_mode == "fused")
    rng = np.random.default_rng(6)
    shared = rng.integers(1, 97, 17).astype(np.int32)
    prompts = [np.concatenate([shared,
                               rng.integers(1, 97, n).astype(np.int32)])
               for n in (3, 11)]
    prompts += [rng.integers(1, 97, 5).astype(np.int32), prompts[0].copy()]
    ref = [round_eng.generate([p], max_new_tokens=5).tokens[0]
           for p in prompts]
    res = eng.run(prompts, max_new_tokens=5)
    for r, expected in zip(res, ref):
        assert np.array_equal(r.tokens, expected)
    if prefix_cache:
        assert eng.n_prefix_hits >= 1


@pytest.mark.slow
def test_token_budget_bounds_iteration_work():
    """Every step processes at most budget tokens of prefill + the
    resident decodes; a long prompt therefore spans several iterations
    while a resident sequence keeps decoding (no prefill stall)."""
    eng = ContinuousBatchingEngine(TINY, max_slots=2, max_seq=128,
                                   token_budget=16)
    rng = np.random.default_rng(3)
    eng.submit(rng.integers(1, 97, 5).astype(np.int32), max_new_tokens=12)
    for _ in range(3):  # short sequence is resident and decoding
        eng.step()
    long_prompt = rng.integers(1, 97, 60).astype(np.int32)  # bucket 64
    eng.submit(long_prompt, max_new_tokens=2)
    interleaved = 0
    prefill_steps = 0
    done = []
    for _ in range(40):
        decoding_before = len(eng.decoding_slots)
        done.extend(eng.step())
        # budget caps chunk tokens + decodes counted at step start; a
        # prefill completing mid-step adds at most its own decode row
        assert eng.last_step_tokens <= 16 + eng.n_slots
        if eng.prefilling_slots:
            prefill_steps += 1
            if decoding_before:
                interleaved += 1
        if len(done) == 2:
            break
    assert len(done) == 2
    # 64-token bucket at <=15 spare tokens/step: several chunked steps,
    # and the resident decode advanced during them
    assert prefill_steps >= 2
    assert interleaved >= 1
    by_id = {r.request_id: r for r in done}
    assert len(by_id[0].tokens) == 12 and len(by_id[1].tokens) == 2


@pytest.mark.slow
def test_chunk_shapes_stay_bounded():
    """Chunk pieces are powers of two: the prefill-chunk compile cache
    is bounded by piece sizes, not raw prompt lengths."""
    eng = ContinuousBatchingEngine(TINY, max_slots=2, max_seq=128,
                                   token_budget=32)
    rng = np.random.default_rng(4)
    lengths = (3, 9, 15, 17, 30, 33, 50, 60)
    prompts = [rng.integers(1, 97, n).astype(np.int32) for n in lengths]
    res = eng.run(prompts, max_new_tokens=2)
    assert len(res) == len(lengths)
    sizes = {t for t, _ in eng.prefill_shapes}
    assert all(s & (s - 1) == 0 for s in sizes)  # powers of two
    # bounded by piece sizes <= budget, not by raw prompt lengths
    assert len(sizes) <= 6 and max(sizes) <= 32


# ------------------------------------------------- truncation satellite
@pytest.mark.slow
def test_submit_clamp_is_surfaced_as_truncated():
    """Regression: submit() silently clamped max_new_tokens to the cache
    room — callers got fewer tokens than requested with no signal. The
    clamp is now recorded and surfaced on the result."""
    eng = ContinuousBatchingEngine(TINY, max_slots=1, max_seq=32)
    rng = np.random.default_rng(5)
    prompt = rng.integers(1, 97, 10).astype(np.int32)  # bucket 16, room 16
    eng.submit(prompt, max_new_tokens=100)
    eng.submit(rng.integers(1, 97, 10).astype(np.int32), max_new_tokens=4)
    res = sorted(eng.run([], max_new_tokens=0),
                 key=lambda r: r.request_id)
    clamped, ok = res[0], res[1]
    assert clamped.truncated and len(clamped.tokens) == 16
    assert not ok.truncated and len(ok.tokens) == 4
