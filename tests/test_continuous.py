"""Continuous (iteration-level) batching — engine slot mechanics and
simulator parity with round mode (docs/ARCHITECTURE.md §5/§6)."""
import numpy as np
import pytest

from conftest import TINY
from repro.config.base import ServingConfig
from repro.core.baselines import FixedScheduler
from repro.serving.bcedge import run_episode
from repro.serving.engine import ContinuousBatchingEngine, InferenceEngine
from repro.serving.simulator import EdgeServingEnv
from repro.serving.workload import PoissonWorkload


@pytest.fixture(scope="module")
def cont_engine():
    return ContinuousBatchingEngine(TINY, max_slots=3, max_seq=64)


# ------------------------------------------------------------ engine
def test_engine_slot_admission_and_eviction(cont_engine):
    eng = cont_engine
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, 97, rng.integers(3, 12)).astype(np.int32)
               for _ in range(6)]
    res = eng.run(prompts, max_new_tokens=5)
    # more requests than slots: finished sequences freed slots for the rest
    assert eng.n_slots == 3 and len(prompts) == 6
    assert [r.request_id for r in res] == list(range(6))
    assert all(len(r.tokens) == 5 for r in res)
    assert eng.n_admitted == 6 and eng.n_evicted == 6
    assert len(eng.free_slots) == eng.n_slots  # fully drained
    # iteration-level: 6 sequences shared slots, far fewer iterations than
    # 6 sequential 5-token generations
    assert 5 <= eng.n_iters < 30


def test_engine_unequal_lengths_free_slots_early():
    eng = ContinuousBatchingEngine(TINY, max_slots=2, max_seq=64)
    rng = np.random.default_rng(1)
    long_p = rng.integers(1, 97, 8).astype(np.int32)
    eng.submit(long_p, max_new_tokens=8)
    for _ in range(3):
        eng.submit(rng.integers(1, 97, 5).astype(np.int32),
                   max_new_tokens=2)
    done = []
    for _ in range(20):
        done.extend(eng.step())
        if len(done) == 4:
            break
    assert len(done) == 4
    by_id = {r.request_id: r for r in done}
    assert len(by_id[0].tokens) == 8
    assert all(len(by_id[i].tokens) == 2 for i in (1, 2, 3))
    # short requests drained through the second slot while the long one
    # ran: total iterations ~ the LONGEST sequence, not the sum
    assert eng.n_iters <= 10


def test_engine_matches_round_engine_greedy():
    round_eng = InferenceEngine(TINY, max_seq=64)
    cont_eng = ContinuousBatchingEngine(TINY, max_slots=2, max_seq=64)
    rng = np.random.default_rng(2)
    prompts = [rng.integers(1, 97, n).astype(np.int32) for n in (4, 9, 13)]
    ref = [round_eng.generate([p], max_new_tokens=4).tokens[0]
           for p in prompts]
    res = cont_eng.run(prompts, max_new_tokens=4)
    for r, expected in zip(res, ref):
        assert np.array_equal(r.tokens, expected)


def test_engine_jit_cache_stays_bucketed():
    eng = ContinuousBatchingEngine(TINY, max_slots=2, max_seq=128)
    rng = np.random.default_rng(3)
    # 8 distinct prompt lengths spanning 3 length buckets (16, 32, 64)
    lengths = (3, 9, 15, 17, 30, 33, 50, 60)
    prompts = [rng.integers(1, 97, n).astype(np.int32) for n in lengths]
    res = eng.run(prompts, max_new_tokens=2)
    assert len(res) == len(lengths)
    assert eng.stats()["n_prefill_shapes"] <= 3  # buckets, not raw lengths
    # decode compiled exactly one shape: (n_slots, 1) for the lifetime
    if hasattr(eng._decode, "_cache_size"):
        assert eng._decode._cache_size() == 1


def test_engine_rejects_oversized_prompt():
    eng = ContinuousBatchingEngine(TINY, max_slots=2, max_seq=32)
    with pytest.raises(ValueError):
        eng.submit(np.arange(1, 40, dtype=np.int32))


def test_bucket_rejects_overlength_prompt():
    """Regression: _bucket silently clamped n > buckets[-1] to the
    largest bucket, so submit() under-counted S and its cache-fit check
    passed for prompts that do not actually fit the cache. (The largest
    bucket is 640 since the prefix-cache work: 512-token shared
    prefixes plus a tail must fit one bucket.)"""
    from repro.serving.engine import SEQ_BUCKETS, _bucket
    assert _bucket(512, buckets=SEQ_BUCKETS) == 512
    assert _bucket(640, buckets=SEQ_BUCKETS) == 640
    with pytest.raises(ValueError):
        _bucket(641, buckets=SEQ_BUCKETS)
    eng = ContinuousBatchingEngine(TINY, max_slots=1, max_seq=1024)
    with pytest.raises(ValueError):
        # would have been admitted pre-fix (clamped S=640 "fits" 1024)
        eng.submit(np.arange(1, 701, dtype=np.int32) % 97)


def test_engine_rejects_enc_dec():
    import dataclasses
    enc = dataclasses.replace(TINY, name="tiny-ed", enc_dec=True,
                              n_enc_layers=1)
    with pytest.raises(NotImplementedError):
        ContinuousBatchingEngine(enc, max_slots=2, max_seq=32)


# ------------------------------------------------------------ workload
def test_workload_decode_steps_geometric():
    wl = PoissonWorkload(rps=30.0, seed=0, decode_steps_mean=6.0)
    steps = [wl.next_request().decode_steps for _ in range(4000)]
    assert min(steps) >= 1
    assert np.mean(steps) == pytest.approx(6.0, rel=0.15)
    wl1 = PoissonWorkload(rps=30.0, seed=0)  # default: single-shot
    assert all(wl1.next_request().decode_steps == 1 for _ in range(50))


# ------------------------------------------------------------ simulator
def _drive(cfg: ServingConfig, seed: int, action: int, episode_ms=3000.0):
    env = EdgeServingEnv(cfg, episode_ms=episode_ms, seed=seed)
    done, steps = False, 0
    while not done and steps < 400:
        _, _, done, _ = env.step(action)
        steps += 1
    return env


def _in_flight(env) -> int:
    n = 0
    for t, _, kind, payload in env._events:
        if kind == "complete":
            n += payload.n_requests
        elif kind == "iter":
            n += len(payload.active) + len(payload.done)
    return n


@pytest.mark.parametrize("seed,action", [(0, 5), (1, 20), (2, 41), (3, 9)])
def test_continuous_conserves_requests(seed, action):
    cfg = ServingConfig(exec_mode="continuous", decode_steps_mean=4.0)
    env = _drive(cfg, seed, action)
    served = sum(r.n_requests for r in env.history)
    queued = sum(len(q) for q in env.queues.values())
    dropped = sum(q.dropped for q in env.queues.values())
    assert served + queued + _in_flight(env) + dropped == env.total_requests


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_continuous_queue_waits_nonnegative(seed):
    cfg = ServingConfig(exec_mode="continuous", decode_steps_mean=4.0)
    env = _drive(cfg, seed, action=12)
    assert env.history, "no sessions completed"
    for rnd in env.history:
        assert rnd.exec_mode == "continuous"
        assert rnd.n_iters >= 1
        assert rnd.finish_ms >= rnd.start_ms >= rnd.decision_ms
        assert len(rnd.queue_waits_ms) == rnd.n_requests
        for w in rnd.queue_waits_ms:
            assert w >= 0.0
        for lat in rnd.latencies_ms:
            assert lat > 0.0
        if not rnd.overflow:
            assert len(rnd.request_utilities) == rnd.n_requests


def test_continuous_beats_round_on_decode_heavy():
    """Decode-heavy workload: iteration-level batching must win p50
    latency AND goodput over run-to-completion rounds."""
    summaries = {}
    for mode in ("round", "continuous"):
        cfg = ServingConfig(exec_mode=mode, decode_steps_mean=6.0)
        env = EdgeServingEnv(cfg, episode_ms=8000.0, seed=0)
        res = run_episode(env, FixedScheduler(cfg.pair_to_action(4, 2)),
                          predictor=None, guard=False, learn=False)
        summaries[mode] = res.summary
    assert summaries["continuous"]["p50_latency_ms"] < \
        summaries["round"]["p50_latency_ms"]
    assert summaries["continuous"]["goodput_rps"] >= \
        summaries["round"]["goodput_rps"]


def test_round_mode_single_shot_unchanged():
    """decode_steps_mean=1 keeps round mode in the paper's regime:
    every round is a single lock-step iteration."""
    cfg = ServingConfig()  # defaults: round, single-shot
    env = _drive(cfg, seed=0, action=5)
    assert env.history
    for rnd in env.history:
        assert rnd.exec_mode == "round"
        assert rnd.n_iters == 1


def test_continuous_sessions_batch_more_than_capacity():
    """Join/leave really happens: with slot capacity b*m_c = 8, sessions
    should serve more requests than their initial allocation when the
    queue is deep."""
    cfg = ServingConfig(exec_mode="continuous", decode_steps_mean=4.0,
                        arrival_rps=60.0)
    env = _drive(cfg, seed=0, action=cfg.pair_to_action(4, 2),
                 episode_ms=6000.0)
    assert any(r.n_requests > 8 for r in env.history if not r.overflow)
