"""Unit tests for the SAC scheduler, baselines and the utility function."""
import numpy as np
import pytest

from repro.config.base import ServingConfig
from repro.core.baselines import (DDQNAgent, EDFScheduler, FixedScheduler,
                                  GAScheduler, PPOAgent, TACAgent)
from repro.core.sac import SACAgent, SACConfig
from repro.core.utility import scheduling_slot, utility


# ---------------------------------------------------------------- utility
def test_scheduling_slot_eq1():
    assert scheduling_slot(1.2, 4) == pytest.approx(0.3)
    assert scheduling_slot(1.2, 1) == pytest.approx(1.2)


def test_utility_monotonic_in_throughput():
    us = [utility(t, 0.05, 1.0, 2) for t in (1.0, 10.0, 100.0)]
    assert us == sorted(us)


def test_utility_monotonic_in_latency():
    us = [utility(10.0, l, 1.0, 2) for l in (0.01, 0.1, 1.0)]
    assert us == sorted(us, reverse=True)


def test_action_pair_roundtrip():
    cfg = ServingConfig()
    for a in range(cfg.n_actions):
        b, mc = cfg.action_to_pair(a)
        assert cfg.pair_to_action(b, mc) == a


def test_action_quad_roundtrip():
    cfg = ServingConfig(token_budgets=(0, 32, 8), spec_depths=(0, 2, 4))
    assert cfg.n_actions == len(cfg.batch_sizes) * \
        len(cfg.concurrency_levels) * 3 * 3
    for a in range(cfg.n_actions):
        b, mc, tb, k = cfg.action_to_quad(a)
        assert cfg.quad_to_action(b, mc, tb, k) == a
        # inner digits agree with every narrower codec (k is OUTERMOST)
        assert cfg.action_to_triple(a) == (b, mc, tb)
        assert cfg.action_to_pair(a) == (b, mc)


def test_action_codecs_stable_without_spec_axis():
    """At spec_depths=(0,) the quad codec is the triple codec plus k=0 —
    pre-speculation action ids (and trained policies) are unaffected."""
    cfg = ServingConfig(token_budgets=(0, 16))
    assert cfg.spec_depths == (0,)
    for a in range(cfg.n_actions):
        b, mc, tb = cfg.action_to_triple(a)
        assert cfg.action_to_quad(a) == (b, mc, tb, 0)
        assert cfg.quad_to_action(b, mc, tb, 0) == \
            cfg.triple_to_action(b, mc, tb) == a


def test_spec_depths_validation():
    with pytest.raises(AssertionError):
        ServingConfig(spec_depths=())
    with pytest.raises(AssertionError):
        ServingConfig(spec_depths=(0, -2))
    with pytest.raises(AssertionError):
        ServingConfig(spec_accept_rate=1.5)


def test_action_quint_roundtrip():
    cfg = ServingConfig(token_budgets=(0, 32), spec_depths=(0, 4),
                        tp_degrees=(1, 2, 4))
    assert cfg.n_actions == len(cfg.batch_sizes) * \
        len(cfg.concurrency_levels) * 2 * 2 * 3
    for a in range(cfg.n_actions):
        b, mc, tb, k, tp = cfg.action_to_quint(a)
        assert cfg.quint_to_action(b, mc, tb, k, tp) == a
        # inner digits agree with every narrower codec (tp OUTERMOST,
        # then k): pre-tp callers fold the axis away by modulus
        assert cfg.action_to_quad(a) == (b, mc, tb, k)
        assert cfg.action_to_triple(a) == (b, mc, tb)
        assert cfg.action_to_pair(a) == (b, mc)


def test_action_codecs_stable_without_tp_axis():
    """At tp_degrees=(1,) the quint codec is the quad codec plus tp=1 —
    pre-TP action ids (and trained policies) are unaffected."""
    cfg = ServingConfig(token_budgets=(0, 16), spec_depths=(0, 2))
    assert cfg.tp_degrees == (1,)
    for a in range(cfg.n_actions):
        b, mc, tb, k = cfg.action_to_quad(a)
        assert cfg.action_to_quint(a) == (b, mc, tb, k, 1)
        assert cfg.quint_to_action(b, mc, tb, k, 1) == \
            cfg.quad_to_action(b, mc, tb, k) == a


def test_tp_degrees_validation():
    with pytest.raises(AssertionError):
        ServingConfig(tp_degrees=())
    with pytest.raises(AssertionError):
        ServingConfig(tp_degrees=(1, 0))


# ---------------------------------------------------------------- SAC
class Bandit:
    """Contextual bandit: best action = argmax ctx-dependent payoff."""

    def __init__(self, dim=6, n_actions=8, seed=0):
        self.rng = np.random.default_rng(seed)
        self.w = self.rng.standard_normal((dim, n_actions)) * 0.5
        self.dim, self.n_actions = dim, n_actions

    def ctx(self):
        return self.rng.standard_normal(self.dim).astype(np.float32)

    def reward(self, s, a):
        return float(s @ self.w[:, a]) + 0.05 * self.rng.standard_normal()


def _train(agent, env, steps=1500):
    s = env.ctx()
    for _ in range(steps):
        a = agent.act(s)
        r = env.reward(s, a)
        s2 = env.ctx()
        agent.observe(s, a, r, s2, False)
        agent.update()
        s = s2


def _greedy_regret(agent, env, n=300):
    regret = 0.0
    for _ in range(n):
        s = env.ctx()
        a = agent.act(s, greedy=True)
        best = float(np.max(s @ env.w))
        regret += best - float(s @ env.w[:, a])
    return regret / n


@pytest.mark.slow
def test_sac_learns_bandit():
    env = Bandit()
    agent = SACAgent(env.dim, env.n_actions,
                     SACConfig(batch_size=128, lr=3e-3, gamma=0.0,
                               reward_scale=1.0), seed=1)
    _train(agent, env)
    assert _greedy_regret(agent, env) < 0.35


def test_sac_alpha_positive_and_bounded():
    env = Bandit()
    agent = SACAgent(env.dim, env.n_actions,
                     SACConfig(batch_size=64), seed=0)
    _train(agent, env, steps=300)
    assert 0 < agent.metrics["alpha"] < 10.0
    assert agent.metrics["entropy"] >= 0.0


@pytest.mark.slow
@pytest.mark.parametrize("cls", [TACAgent, DDQNAgent])
def test_baseline_agents_learn_bandit(cls):
    env = Bandit()
    agent = cls(env.dim, env.n_actions, lr=3e-3, gamma=0.0,
                batch_size=128, seed=1)
    _train(agent, env)
    assert _greedy_regret(agent, env) < 0.6


@pytest.mark.slow
def test_ppo_runs_and_improves():
    env = Bandit()
    agent = PPOAgent(env.dim, env.n_actions, lr=3e-3, gamma=0.0,
                     horizon=128, seed=1)
    before = _greedy_regret(agent, env)
    _train(agent, env, steps=2000)
    assert _greedy_regret(agent, env) < before


def test_ga_converges_to_good_action():
    env = Bandit(dim=4, n_actions=6, seed=2)
    # GA optimises a static action: use a fixed context
    s_fixed = env.ctx()
    ga = GAScheduler(env.dim, env.n_actions, pop=12, seed=0)
    for _ in range(800):
        a = ga.act(s_fixed)
        ga.observe(s_fixed, a, env.reward(s_fixed, a), s_fixed, False)
        ga.update()
    best = int(np.argmax(s_fixed @ env.w))
    chosen = ga.act(s_fixed, greedy=True)
    payoffs = s_fixed @ env.w
    assert payoffs[chosen] >= np.sort(payoffs)[-3]  # top-3 action


def test_edf_and_fixed_interfaces():
    cfg = ServingConfig()
    from repro.serving.features import queue_feature_index

    edf = EDFScheduler(cfg.batch_sizes, cfg.concurrency_levels,
                       queue_feature_index(["a", "b"]))
    s = np.zeros(10, np.float32)
    s[queue_feature_index(["a", "b"])] = np.log1p(8)
    a = edf.act(s)
    b, mc = cfg.action_to_pair(a)
    assert b <= 8 and mc == 1
    fx = FixedScheduler(5)
    assert fx.act(s) == 5
