"""Docs stay honest: every doc file / section / flag / path referenced
from docstrings, README.md and docs/*.md must exist (tools/check_docs_links.py)."""
import importlib.util
import os


def test_docs_links_resolve(capsys):
    root = os.path.join(os.path.dirname(__file__), "..")
    spec = importlib.util.spec_from_file_location(
        "check_docs_links", os.path.join(root, "tools",
                                         "check_docs_links.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    rc = mod.main()
    out = capsys.readouterr().out
    assert rc == 0, f"broken doc references:\n{out}"
