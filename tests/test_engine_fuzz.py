"""Randomized differential stress harness for the continuous engine
(docs/ARCHITECTURE.md §5).

Each seeded schedule interleaves submit / step / preempt-resume (both
recompute and host-tier SWAP flavours on offload-capable variants) /
CANCEL ops — plus live speculative-depth retuning on spec-capable
variants — over a pool of mixed-length prompts with shared AND
divergent prefixes, across nine engine variants (dense + paged
layouts, prefix cache on/off, token budget on/off in BOTH layouts —
the dense+budget variant runs the staging-cache chunked-prefill path,
paged+budget the fused one — tight block budgets that force LRU
reclaim, speculative k up to 4 with mid-flight k toggling, a
kitchen-sink variant stacking prefix cache + tight blocks + budget +
speculation + a host KV tier, and a dedicated offload variant whose
tight device budget forces prefix-block spills to host alongside
swap-mode preemption), and asserts:

* after EVERY operation — allocator conservation:
  ``n_free + n_cached + n_live == n_blocks`` (disjoint id sets),
  ``n_available >= 0``, refcount(b) == number of slots mapping b (no
  block owned by two slots without a refcount), block tables mirror the
  slot block lists, the null block is never mapped; host-tier
  conservation on offload variants:
  ``n_host_free + n_host_cached + n_host_live == n_host_blocks``
  (disjoint id sets) with the live host population EXACTLY the union
  of the waiting swap snapshots' block lists;
* for EVERY finished request — greedy output token-identical to a
  per-request uninterrupted oracle run (fresh single-slot dense engine,
  shared weights), regardless of how the schedule batched, preempted,
  chunked or block-shared it;
* for EVERY cancelled request — whatever it emitted before the cancel
  (at random phase: queued, mid-prefill, mid-decode, or preempted) is a
  PREFIX of its oracle run, and the cancel never perturbs survivors;
* after the drain — every reference returned (no leak, no double free).

``ENGINE_FUZZ_SCHEDULES`` sets the full-sweep schedule count (default
200 — the CI full-suite floor; the nightly fuzz job raises it). The
non-slow smoke variant keeps tier-1 fast.
"""
import os
import random

import numpy as np
import pytest

from conftest import KIND_CFGS, TINY
from repro.serving.engine import ContinuousBatchingEngine

N_SCHEDULES = int(os.environ.get("ENGINE_FUZZ_SCHEDULES", "200"))

MAX_SEQ = 128
MAX_NEW_CHOICES = (2, 4, 7)

_TEMPLATES = {}
_ORACLE = {}


def _template(cfg):
    """One weight/jit-cache donor per config, so every fuzz engine and
    every oracle run share identical parameters."""
    if cfg.name not in _TEMPLATES:
        _TEMPLATES[cfg.name] = ContinuousBatchingEngine(
            cfg, max_slots=1, max_seq=MAX_SEQ, seed=0)
    return _TEMPLATES[cfg.name]


def _oracle(cfg, prompt: np.ndarray, max_new: int) -> np.ndarray:
    """Uninterrupted single-request greedy run (memoized)."""
    key = (cfg.name, prompt.tobytes(), max_new)
    if key not in _ORACLE:
        eng = ContinuousBatchingEngine(cfg, max_slots=1, max_seq=MAX_SEQ,
                                       seed=0, share_from=_template(cfg))
        _ORACLE[key] = eng.run([prompt], max_new_tokens=max_new)[0].tokens
    return _ORACLE[key]


def _prompt_pool(cfg):
    """Mixed-length prompts: two shared-prefix families (equal and
    unequal tail lengths — unequal ones land at different pad offsets,
    so they must NOT share), divergent one-offs, and one exact duplicate
    (the full-cover copy-on-write path)."""
    rng = np.random.default_rng(99)
    v = cfg.vocab_size
    pool = []
    for _ in range(2):
        prefix = rng.integers(1, v, 24).astype(np.int32)
        for tail_len in (4, 4, 8):
            pool.append(np.concatenate(
                [prefix, rng.integers(1, v, tail_len).astype(np.int32)]))
    pool += [rng.integers(1, v, n).astype(np.int32)
             for n in (3, 9, 17, 30)]
    pool.append(pool[0].copy())  # exact duplicate
    return pool


def _check_invariants(eng, ctx: str) -> None:
    al = eng.allocator
    if al is not None:
        free, lru = set(al._free), set(al._lru)
        out = set(al._outstanding)
        assert not (free & lru) and not (free & out) and not (lru & out), \
            f"{ctx}: allocator id sets overlap"
        assert len(free) + len(lru) + len(out) == al.n_blocks, \
            f"{ctx}: conservation broken " \
            f"({len(free)}+{len(lru)}+{len(out)} != {al.n_blocks})"
        assert al.n_available >= 0, f"{ctx}: n_available < 0"
        assert al.n_reserved <= al.n_free + al.n_cached, \
            f"{ctx}: reservations exceed reclaimable blocks"
        counts = {}
        for s in eng.slots:
            if not s.active:
                continue
            assert len(set(s.blocks)) == len(s.blocks), \
                f"{ctx}: slot maps a block twice"
            for b in s.blocks:
                counts[b] = counts.get(b, 0) + 1
        assert 0 not in counts, f"{ctx}: null block mapped"
        for b, c in counts.items():
            assert al.refcount(b) == c, \
                f"{ctx}: block {b} mapped by {c} slots, refcount " \
                f"{al.refcount(b)}"
        assert set(counts) == out, \
            f"{ctx}: live blocks != mapped blocks"
        if al.n_host_blocks:
            hfree, hlive = set(al._host_free), set(al._host_live)
            hcache = set(al._host_cache.values())
            assert not (hfree & hcache) and not (hfree & hlive) \
                and not (hcache & hlive), \
                f"{ctx}: host-tier id sets overlap"
            assert len(hfree) + len(hcache) + len(hlive) \
                == al.n_host_blocks, \
                f"{ctx}: host conservation broken " \
                f"({len(hfree)}+{len(hcache)}+{len(hlive)} " \
                f"!= {al.n_host_blocks})"
            swap_ids = [b for w in eng.waiting if w.swap is not None
                        for b in w.swap.host_blocks]
            assert len(swap_ids) == len(set(swap_ids)), \
                f"{ctx}: host block shared by two swap snapshots"
            assert set(swap_ids) == hlive, \
                f"{ctx}: swap-pinned host blocks != waiting snapshots"
    if eng.block_tables is not None:
        for i, s in enumerate(eng.slots):
            if s.active and not s.prefilling:
                n = len(s.blocks)
                np.testing.assert_array_equal(
                    eng.block_tables[i, :n], s.blocks, err_msg=ctx)
                assert not eng.block_tables[i, n:].any(), ctx
            else:
                # mid-prefill slots point at the null block until the
                # prefill lands, in BOTH modes: non-fused chunks write a
                # staging cache, and fused chunks carry their own table
                # row — either way the decode batch's dummy writes for
                # this row must keep sinking into the null block
                assert not eng.block_tables[i].any(), ctx


N_VARIANTS = 9


def _engine_variant(cfg, variant: int):
    """Rotate the engine configurations the schedules exercise. Paged
    variants (1-5, 7) resolve ``prefill_mode="auto"`` to the FUSED path
    on these all-linear configs — so the prefix-cache (2, 3, 7) and
    speculative (4, 5, 7) variants prove token-identity of fused
    prefill under preempt/resume/rollback/cancel interleavings. The
    dense token-budget variant (6) and the hybrid layer-family sweeps
    below cover the staging-cache round trip (the non-fused path dense
    and hybrid layouts keep)."""
    if variant == 0:
        return ContinuousBatchingEngine(
            cfg, max_slots=3, max_seq=MAX_SEQ, seed=0,
            share_from=_template(cfg))
    if variant == 1:
        return ContinuousBatchingEngine(
            cfg, max_slots=3, max_seq=MAX_SEQ, seed=0,
            share_from=_template(cfg), kv_layout="paged", block_size=8)
    if variant == 2:
        kw = {"prefix_cache": True} \
            if cfg.name in ("tiny", "tiny-tail") else {}
        return ContinuousBatchingEngine(
            cfg, max_slots=3, max_seq=MAX_SEQ, seed=0,
            share_from=_template(cfg), kv_layout="paged", block_size=8,
            token_budget=12, **kw)
    if variant == 3:
        # tight block budget + prefix cache: forces queueing on memory,
        # LRU revivals and reclaims
        kw = {"prefix_cache": True} if cfg.name in ("tiny", "tiny-tail") \
            else {}
        return ContinuousBatchingEngine(
            cfg, max_slots=4, max_seq=MAX_SEQ, seed=0,
            share_from=_template(cfg), kv_layout="paged", block_size=8,
            kv_blocks=14, **kw)
    # speculative variants: propose/verify/rollback interleaved with
    # everything above. Only rewind-capable stacks can speculate — the
    # other layer families fall back to the plain paged variant so
    # every seed still runs a schedule.
    spec = {"spec_k": 4} if cfg.name in ("tiny", "tiny-tail") else {}
    if variant == 4:
        return ContinuousBatchingEngine(
            cfg, max_slots=3, max_seq=MAX_SEQ, seed=0,
            share_from=_template(cfg), kv_layout="paged", block_size=8,
            prefix_cache=bool(spec), **spec)
    if variant == 5:
        # tight budget + speculation: block rollback under LRU reclaim
        # pressure and budget-degraded effective k
        return ContinuousBatchingEngine(
            cfg, max_slots=3, max_seq=MAX_SEQ, seed=0,
            share_from=_template(cfg), kv_layout="paged", block_size=8,
            kv_blocks=16, token_budget=12, **spec)
    if variant == 6:
        # dense + token budget: the STAGING-cache chunked-prefill path
        # (fused prefill is paged-only), interleaved with preempt/
        # resume/cancel at chunk boundaries
        return ContinuousBatchingEngine(
            cfg, max_slots=3, max_seq=MAX_SEQ, seed=0,
            share_from=_template(cfg), token_budget=12)
    if variant == 7:
        # kitchen sink: prefix cache + tight blocks + token budget +
        # speculation + host tier stacked — every reclaim/rollback/
        # share/spill path at once
        kw = {"prefix_cache": True} if cfg.name in ("tiny", "tiny-tail") \
            else {}
        return ContinuousBatchingEngine(
            cfg, max_slots=4, max_seq=MAX_SEQ, seed=0,
            share_from=_template(cfg), kv_layout="paged", block_size=8,
            kv_blocks=18, token_budget=12, kv_host_blocks=10,
            **kw, **spec)
    # KV offload: a tight device budget under prefix caching forces LRU
    # spills to the host tier, while the preempt op below exercises the
    # swap-mode snapshot/resume path against the recompute oracle
    kw = {"prefix_cache": True} if cfg.name in ("tiny", "tiny-tail") \
        else {}
    return ContinuousBatchingEngine(
        cfg, max_slots=4, max_seq=MAX_SEQ, seed=0,
        share_from=_template(cfg), kv_layout="paged", block_size=8,
        kv_blocks=16, kv_host_blocks=12, **kw)


def _run_schedule(cfg, seed: int) -> None:
    rng = random.Random(seed)
    eng = _engine_variant(cfg, seed % N_VARIANTS)
    prompts = _prompt_pool(cfg)
    expected = {}
    results = {}
    cancelled = set()
    ctx = f"cfg={cfg.name} seed={seed} variant={seed % N_VARIANTS}"

    def step_engine():
        for r in eng.step():
            results[r.request_id] = r

    for _ in range(rng.randint(8, 18)):
        roll = rng.random()
        if roll < 0.40:
            p = rng.choice(prompts)
            mn = rng.choice(MAX_NEW_CHOICES)
            try:
                rid = eng.submit(p, max_new_tokens=mn)
            except ValueError:
                pass  # request larger than the whole pool: rejected
            else:
                expected[rid] = (p, mn)
        elif roll < 0.75:
            step_engine()
        elif roll < 0.85:
            # cancel a live request at whatever phase the schedule
            # caught it in — queued, mid-prefill, mid-decode, or
            # preempted-awaiting-resume; blocks must come back
            # synchronously and survivors must not notice
            live = sorted(set(expected) - set(results))
            if live:
                rid = rng.choice(live)
                r = eng.cancel(rid)
                assert r is not None and r.cancelled, \
                    f"{ctx}: cancel({rid}) did not land"
                results[rid] = r
                cancelled.add(rid)
        elif roll < 0.92 and eng.spec_max > 0:
            # the scheduler's fourth axis mid-flight: retune the live
            # proposal depth (speculate/verify/rollback must stay
            # token-identical at any k, switched at any boundary)
            eng.spec_k = rng.choice((0, 2, 4))
        else:
            cands = eng.decoding_slots
            if cands and eng.chunked:
                slot = rng.choice(cands)
                # offload-capable engines flip a coin between the two
                # eviction flavours: swap-resume must stay
                # token-identical to recompute-resume (both are checked
                # against the same uninterrupted oracle below)
                if eng.swap_ok and eng.can_swap(slot) \
                        and rng.random() < 0.5:
                    eng.preempt(slot, mode="swap")
                else:
                    eng.preempt(slot)  # requeue + resume
        _check_invariants(eng, ctx)

    guard = 600
    while (eng.waiting or eng.active_slots) and guard:
        step_engine()
        _check_invariants(eng, ctx)
        guard -= 1
    assert guard, f"{ctx}: engine failed to drain"
    assert set(results) == set(expected), \
        f"{ctx}: lost requests {set(expected) - set(results)}"
    for rid, (p, mn) in expected.items():
        got = results[rid]
        if rid in cancelled:
            # a cancelled request keeps whatever it had emitted — which
            # must be an oracle PREFIX (never a wrong token)
            assert got.cancelled, f"{ctx} rid={rid}: lost cancel flag"
            oracle = _oracle(cfg, p, mn)
            assert len(got.tokens) <= len(oracle) and np.array_equal(
                got.tokens, oracle[:len(got.tokens)]), \
                f"{ctx} rid={rid}: cancelled emission not an oracle " \
                f"prefix ({got.tokens} vs {oracle})"
            continue
        assert not got.truncated, f"{ctx} rid={rid}: unexpected clamp"
        assert np.array_equal(got.tokens, _oracle(cfg, p, mn)), \
            f"{ctx} rid={rid}: tokens diverge from oracle " \
            f"({got.tokens} vs {_oracle(cfg, p, mn)})"
    al = eng.allocator
    if al is not None:
        assert al.n_live == 0 and al.n_reserved == 0, \
            f"{ctx}: leaked references after drain"
        assert al.n_free + al.n_cached == al.n_blocks, ctx
        assert al.n_host_live == 0, \
            f"{ctx}: leaked host-tier blocks after drain"
        assert al.n_host_free + al.n_host_cached == al.n_host_blocks, ctx


def test_fuzz_smoke_schedules():
    """Tier-1 slice of the sweep: a handful of schedules covering every
    variant of the canonical tiny model once — including the
    speculative (4, 5), dense-staging (6), kitchen-sink (7) and KV
    offload (8) variants."""
    for seed in range(N_VARIANTS):
        _run_schedule(TINY, seed)


@pytest.mark.slow
def test_fuzz_full_sweep_tiny():
    """The CI sweep: >= ENGINE_FUZZ_SCHEDULES seeded schedules (default
    200) on the canonical model across all nine engine variants."""
    for seed in range(N_SCHEDULES):
        _run_schedule(TINY, seed)


@pytest.mark.slow
@pytest.mark.parametrize("kind", ["tail", "rglru", "windowed"])
def test_fuzz_layer_families(kind):
    """Shorter sweeps over the other layer families: the unrolled tail
    (prefix-cacheable) plus recurrent and windowed stacks, whose hybrid
    dense/paged cache surgery must hold under the same schedules."""
    cfg = KIND_CFGS[kind]
    for seed in range(max(8, N_SCHEDULES // 10)):
        _run_schedule(cfg, seed)
