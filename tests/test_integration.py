"""Integration tests: real engine round-trips, BCEdge episode end-to-end,
edge CNN forwards, guard behaviour."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# multi-second episodes / engine builds: excluded from the tier-1 CI
# job, covered by the full-suite job (pytest.ini)
pytestmark = pytest.mark.slow

from repro.config import get_reduced_config
from repro.config.base import ServingConfig
from repro.core.interference import NNInterferencePredictor
from repro.core.sac import SACAgent, SACConfig
from repro.serving.bcedge import BCEdgeScheduler, run_episode
from repro.serving.engine import InferenceEngine
from repro.serving.features import state_dim
from repro.serving.simulator import EdgeServingEnv


# ---------------------------------------------------------------- engine
def test_engine_generates_and_buckets():
    eng = InferenceEngine(get_reduced_config("qwen3-0.6b"))
    prompts = [np.array([1, 2, 3], np.int32), np.array([4, 5], np.int32),
               np.array([6], np.int32)]
    res = eng.generate(prompts, max_new_tokens=3)
    assert res.tokens.shape == (3, 3)
    assert res.tokens.dtype == np.int32
    assert (res.tokens >= 0).all()
    assert res.prefill_ms > 0 and res.decode_ms > 0


def test_engine_greedy_deterministic():
    eng = InferenceEngine(get_reduced_config("qwen3-0.6b"))
    p = [np.array([7, 8, 9, 10], np.int32)]
    a = eng.generate(p, max_new_tokens=4).tokens
    b = eng.generate(p, max_new_tokens=4).tokens
    np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------- episode
def test_sac_episode_end_to_end():
    cfg = ServingConfig()
    env = EdgeServingEnv(cfg, episode_ms=6000.0, seed=0)
    agent = SACAgent(state_dim(env.models), cfg.n_actions,
                     SACConfig(batch_size=64), seed=0)
    pred = NNInterferencePredictor()
    res = run_episode(env, agent, pred, guard=True)
    s = res.summary
    assert s["requests"] > 100
    assert 0 <= s["slo_violation_rate"] <= 1
    assert np.isfinite(s["mean_utility"])
    assert len(res.overhead_ms) > 10


def test_guard_degrades_infeasible_actions():
    cfg = ServingConfig()
    env = EdgeServingEnv(cfg, episode_ms=3000.0, seed=1)

    class AlwaysMax:
        def act(self, s, greedy=False):
            return cfg.n_actions - 1  # b=128, m_c=8

    pred = NNInterferencePredictor()
    # teach the predictor that big rounds are slow
    for _ in range(80):
        feats = env.predict_features("yolo", 128, 8)
        pred.observe(feats, 30.0)
        pred.observe(env.predict_features("yolo", 1, 1), 0.02)
    pred.fit_step()
    sched = BCEdgeScheduler(env, AlwaysMax(), pred, guard=True)
    s = env.reset()
    a = sched.select_action(s, env._focus)
    b, mc = cfg.action_to_pair(a)
    assert (b, mc) != (128, 8)
    assert sched.guard_interventions == 1


def test_episode_with_guard_no_worse_violations():
    cfg = ServingConfig()
    results = {}
    for guard in (False, True):
        agent = SACAgent(state_dim(list(
            EdgeServingEnv(cfg, episode_ms=1).models)), cfg.n_actions,
            SACConfig(batch_size=128), seed=3)
        pred = NNInterferencePredictor() if guard else None
        viols = []
        for ep in range(3):
            env = EdgeServingEnv(cfg, episode_ms=8000.0, seed=ep)
            res = run_episode(env, agent, pred, guard=guard)
            viols.append(res.summary["slo_violation_rate"])
        results[guard] = np.mean(viols)
    # the guard must not make things catastrophically worse (it usually
    # improves; the statistical comparison lives in benchmarks/fig14 — the
    # 3 short episodes here are too noisy for a tight bound)
    assert results[True] <= results[False] + 0.25


# ---------------------------------------------------------------- CNNs
@pytest.mark.parametrize("name", ["res", "mob", "inc", "yolo"])
def test_edge_cnn_forward(name):
    from repro.models.cnn import EDGE_NETS

    init, apply = EDGE_NETS[name]
    p = init(jax.random.PRNGKey(0))
    x = jnp.asarray(np.random.default_rng(0).standard_normal(
        (2, 64, 64, 3)), jnp.float32)
    y = apply(p, x)
    assert y.shape[0] == 2
    assert bool(jnp.isfinite(y).all())


def test_tinybert_forward():
    from repro.models.cnn import tinybert_apply, tinybert_init

    pb = tinybert_init(jax.random.PRNGKey(0), vocab=1000, d=64, n_layers=2)
    y = tinybert_apply(pb, jnp.ones((2, 14), jnp.int32))
    assert y.shape == (2, 35)
    assert bool(jnp.isfinite(y).all())


# ---------------------------------------------------------------- kernels in models
def test_model_attention_kernel_impl_matches_naive():
    """The Pallas flash path (interpret) must agree with the model's naive
    attention inside a full forward."""
    from repro.models import build_model

    cfg = get_reduced_config("qwen3-0.6b")
    m_naive = build_model(cfg, remat=False, attn_impl="naive")
    m_kernel = build_model(cfg, remat=False, attn_impl="kernel")
    params = m_naive.init(jax.random.PRNGKey(0))
    batch = {"tokens": jnp.arange(2 * 64, dtype=jnp.int32).reshape(2, 64)
             % cfg.vocab_size}
    l1, _ = m_naive.prefill(params, batch)
    l2, _ = m_kernel.prefill(params, batch)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=2e-3,
                               rtol=2e-3)
