"""Interference predictor tests (paper Fig. 13 behaviour)."""
import numpy as np
import pytest

from repro.core.interference import (LinearInterferencePredictor,
                                     NNInterferencePredictor,
                                     interference_features)


def _nonlinear_dataset(n=600, seed=0):
    rng = np.random.default_rng(seed)
    X = []
    y = []
    for _ in range(n):
        mem_avail = rng.uniform(1, 8)
        m_c = rng.integers(1, 9)
        b = 2 ** rng.integers(0, 8)
        gflops = rng.uniform(0.1, 3.5)
        feats = interference_features(mem_avail, 0.3 + 0.05 * m_c, 0.5,
                                      m_c, b, gflops, 0.1 * m_c * b / 8)
        # nonlinear latency: saturation + knee (like the simulator)
        eff = 0.5 * b / (b + 1.5)
        util = min(1.0, m_c * eff)
        lat = gflops * b * m_c / (0.5 * util)
        pressure = (0.1 * m_c * b / 8) / mem_avail
        if pressure > 0.5:
            lat *= 1 + 4 * (pressure - 0.5) ** 2 * m_c
        X.append(feats)
        y.append(lat / 1000.0)
    return np.stack(X), np.asarray(y)


@pytest.mark.slow
def test_nn_beats_linear_on_nonlinear_latency():
    X, y = _nonlinear_dataset()
    tr, va = slice(0, 480), slice(480, 600)
    nn = NNInterferencePredictor(lr=3e-3)
    nn.fit(X[tr], y[tr], epochs=3000)
    lin = LinearInterferencePredictor()
    lin.fit(X[tr], y[tr])

    def p90(pred):
        errs = [abs(pred.predict(x) - t) / abs(t)
                for x, t in zip(X[va], y[va])]
        return float(np.percentile(errs, 90))

    assert p90(nn) < p90(lin) * 0.8  # paper: NN ~2x better


def test_online_observe_path():
    nn = NNInterferencePredictor(batch_size=16)
    X, y = _nonlinear_dataset(64, seed=1)
    for x, t in zip(X, y):
        nn.observe(x, t)
    # after online fitting, prediction should be within an order of
    # magnitude on the training support
    preds = np.array([nn.predict(x) for x in X])
    assert np.all(np.isfinite(preds))
    assert np.median(np.abs(np.log(preds) - np.log(y))) < 2.0


def test_feature_vector_shape():
    f = interference_features(4.0, 0.3, 0.5, 2, 8, 1.8, 0.2)
    assert f.shape == (7,)
    assert np.isfinite(f).all()
