"""Per-kernel allclose sweeps vs the pure-jnp oracles (interpret mode)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

R = np.random.default_rng(7)


def rnd(*shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(R.standard_normal(shape) * scale, dtype)


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("B,S,H,KV,hd", [
    (1, 64, 2, 2, 64), (2, 96, 4, 2, 64), (1, 128, 8, 1, 128),
    (2, 80, 6, 3, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(B, S, H, KV, hd, dtype):
    q, k, v = rnd(B, S, H, hd, dtype=dtype), rnd(B, S, KV, hd, dtype=dtype), \
        rnd(B, S, KV, hd, dtype=dtype)
    out = ops.flash_attention(q, k, v, scale=hd ** -0.5,
                              block_q=32, block_k=32)
    want = ref.flash_attention_ref(q, k, v, scale=hd ** -0.5)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype] * 10)


@pytest.mark.parametrize("window", [8, 33, 64])
def test_flash_attention_window(window):
    B, S, H, KV, hd = 2, 96, 4, 2, 64
    q, k, v = rnd(B, S, H, hd), rnd(B, S, KV, hd), rnd(B, S, KV, hd)
    out = ops.flash_attention(q, k, v, scale=hd ** -0.5, window=window,
                              block_q=32, block_k=32)
    want = ref.flash_attention_ref(q, k, v, scale=hd ** -0.5, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5,
                               rtol=2e-4)


def test_flash_attention_noncausal_pad():
    """Non-causal path must mask T-padding explicitly."""
    B, S, H, KV, hd = 1, 40, 2, 2, 64  # S=40 pads to 64 with block 32
    q, k, v = rnd(B, S, H, hd), rnd(B, S, KV, hd), rnd(B, S, KV, hd)
    out = ops.flash_attention(q, k, v, scale=hd ** -0.5, causal=False,
                              block_q=32, block_k=32)
    want = ref.flash_attention_ref(q, k, v, scale=hd ** -0.5, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5,
                               rtol=2e-4)


@pytest.mark.parametrize("B,C,H,KV,hd", [
    (2, 80, 4, 2, 64), (1, 256, 8, 8, 128), (3, 100, 6, 2, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention(B, C, H, KV, hd, dtype):
    q = rnd(B, 1, H, hd, dtype=dtype)
    k, v = rnd(B, C, KV, hd, dtype=dtype), rnd(B, C, KV, hd, dtype=dtype)
    valid = jnp.asarray(R.random((B, C)) > 0.3)
    valid = valid.at[:, 0].set(True)  # at least one valid slot
    out = ops.decode_attention(q, k, v, valid, hd ** -0.5, block_c=32)
    want = ref.decode_attention_ref(q, k, v, valid, hd ** -0.5)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype] * 10)


@pytest.mark.parametrize("B,N,bs,nb,H,KV,hd", [
    (2, 17, 16, 4, 4, 2, 64), (1, 9, 32, 3, 8, 8, 128),
    (3, 33, 8, 6, 6, 2, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_decode_attention(B, N, bs, nb, H, KV, hd, dtype):
    """Block-table gather (paged KV pool) vs the dense-gather oracle,
    including ragged tails that end mid-block."""
    q = rnd(B, 1, H, hd, dtype=dtype)
    k_pool = rnd(N, bs, KV, hd, dtype=dtype)
    v_pool = rnd(N, bs, KV, hd, dtype=dtype)
    # distinct physical blocks per sequence, in shuffled order
    perm = R.permutation(N)[: B * nb].reshape(B, nb)
    tables = jnp.asarray(perm, jnp.int32)
    lens = jnp.asarray(R.integers(1, nb * bs + 1, B), jnp.int32)
    out = ops.paged_decode_attention(q, k_pool, v_pool, tables, lens,
                                     hd ** -0.5)
    want = ref.paged_decode_attention_ref(q, k_pool, v_pool, tables, lens,
                                          hd ** -0.5)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype] * 10)


def test_paged_decode_matches_dense_decode():
    """A paged pool holding the same logical cache as a dense layout must
    produce the same output as the dense decode kernel."""
    B, C, H, KV, hd, bs = 2, 64, 4, 2, 64, 16
    nb = C // bs
    q = rnd(B, 1, H, hd)
    k = rnd(B, C, KV, hd)
    v = rnd(B, C, KV, hd)
    lens = jnp.asarray([37, 64], jnp.int32)
    valid = jnp.arange(C)[None, :] < lens[:, None]
    dense = ops.decode_attention(q, k, v, valid, hd ** -0.5, block_c=32)
    # scatter the dense rows into a shuffled pool
    perm = R.permutation(B * nb)
    k_pool = jnp.zeros((B * nb, bs, KV, hd), jnp.float32)
    v_pool = jnp.zeros_like(k_pool)
    tables = np.zeros((B, nb), np.int32)
    for b in range(B):
        for j in range(nb):
            pid = int(perm[b * nb + j])
            k_pool = k_pool.at[pid].set(k[b, j * bs:(j + 1) * bs])
            v_pool = v_pool.at[pid].set(v[b, j * bs:(j + 1) * bs])
            tables[b, j] = pid
    paged = ops.paged_decode_attention(q, k_pool, v_pool,
                                       jnp.asarray(tables), lens,
                                       hd ** -0.5)
    np.testing.assert_allclose(np.asarray(paged), np.asarray(dense),
                               atol=2e-5, rtol=2e-4)


@pytest.mark.parametrize("B,S,H,hd,chunk", [
    (1, 32, 2, 64, 8), (2, 40, 4, 64, 16), (1, 64, 1, 128, 64),
])
def test_rwkv6_scan(B, S, H, hd, chunk):
    r = rnd(B, S, H, hd)
    k = rnd(B, S, H, hd, scale=0.3)
    v = rnd(B, S, H, hd, scale=0.3)
    w = jnp.asarray(R.random((B, S, H, hd)) * 0.5 + 0.4, jnp.float32)
    u = rnd(H, hd, scale=0.1)
    s0 = rnd(B, H, hd, hd, scale=0.1)
    out, sT = ops.rwkv6_scan(r, k, v, w, u, s0, chunk=chunk)
    want, wT = ref.rwkv6_scan_ref(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-4,
                               rtol=1e-3)
    np.testing.assert_allclose(np.asarray(sT), np.asarray(wT), atol=1e-4,
                               rtol=1e-3)


@pytest.mark.parametrize("B,S,W,chunk,bw", [
    (2, 40, 96, 16, 32), (1, 33, 64, 8, 64), (2, 128, 256, 64, 128),
])
def test_rglru_scan(B, S, W, chunk, bw):
    a = jnp.asarray(R.random((B, S, W)) * 0.5 + 0.4, jnp.float32)
    x = rnd(B, S, W, scale=0.3)
    h0 = rnd(B, W, scale=0.1)
    hs, hT = ops.rglru_scan(a, x, h0, chunk=chunk, block_w=bw)
    want, wT = ref.rglru_scan_ref(a, x, h0)
    np.testing.assert_allclose(np.asarray(hs), np.asarray(want), atol=1e-5,
                               rtol=1e-4)
    np.testing.assert_allclose(np.asarray(hT), np.asarray(wT), atol=1e-5,
                               rtol=1e-4)


@pytest.mark.parametrize("E,C,D,F", [(2, 32, 64, 48), (4, 40, 48, 56),
                                     (8, 16, 128, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_moe_matmul(E, C, D, F, dtype):
    x = rnd(E, C, D, dtype=dtype)
    w = rnd(E, D, F, dtype=dtype, scale=0.1)
    out = ops.moe_matmul(x, w, block_c=16, block_f=32, block_d=16)
    want = ref.moe_matmul_ref(x, w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype] * 10)
