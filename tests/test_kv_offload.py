"""KV offload tier (docs/RUNTIME.md §8): host-memory block tier in the
allocator, swap-mode preemption at the engine and pool levels, the
recompute-vs-swap pricing, and the three serving-stats /
preemption-accounting regression fixes that ride the same PR."""
import numpy as np
import pytest

from conftest import KIND_CFGS, TINY, make_cont_engine, make_pool
from repro.serving.engine import PreemptedRequest, to_recompute
from repro.serving.runtime import PoolRequest

VOCAB = TINY.vocab_size


def _prompt(rng, n):
    return rng.integers(1, VOCAB, n).astype(np.int32)


def _swap_engine(**kw):
    kw.setdefault("max_slots", 2)
    kw.setdefault("max_seq", 64)
    kw.setdefault("kv_layout", "paged")
    kw.setdefault("block_size", 8)
    kw.setdefault("kv_blocks", 24)
    kw.setdefault("kv_host_blocks", 16)
    kw.setdefault("prefix_cache", True)
    return make_cont_engine(TINY, **kw)


# ---------------------------------------------------------------- engine
def test_ctor_validation():
    with pytest.raises(ValueError):
        make_cont_engine(TINY, kv_layout="paged", block_size=8,
                         kv_host_blocks=-1)
    with pytest.raises(ValueError):
        make_cont_engine(TINY, kv_host_blocks=8)  # dense: no block tier
    # non-pageable stacks build the tier but never swap (recompute-only)
    eng = make_cont_engine(KIND_CFGS["rglru"], kv_layout="paged",
                           block_size=8, kv_host_blocks=8)
    assert not eng.swap_ok


def test_swap_resume_token_identical_and_no_leak():
    """The acceptance-criterion identity at engine level: swap-resume ==
    recompute-resume == uninterrupted, and both tiers conserve."""
    rng = np.random.default_rng(0)
    p = _prompt(rng, 20)
    want = _swap_engine().run([p], max_new_tokens=12)[0].tokens
    for mode in ("recompute", "swap"):
        eng = _swap_engine()
        eng.submit(p, max_new_tokens=12)
        for _ in range(5):
            eng.step()
        slot = eng.decoding_slots[0]
        snap = eng.preempt(slot, requeue=False, mode=mode)
        assert snap.swapped == (mode == "swap")
        for _ in range(2):
            eng.step()  # idle while preempted
        rid = eng.submit_resume(snap)  # resume under a fresh engine id
        out = {}
        guard = 100
        while (eng.waiting or eng.active_slots) and guard:
            for r in eng.step():
                out[r.request_id] = r
            guard -= 1
        np.testing.assert_array_equal(out[rid].tokens, want, err_msg=mode)
        al = eng.allocator
        assert al.n_live == 0 and al.n_reserved == 0
        assert al.n_host_live == 0
        assert al.n_host_free + al.n_host_cached == al.n_host_blocks
    eng = _swap_engine()
    assert eng.n_swap_preempts == 0  # counters start clean


def test_swap_preempt_counts_and_samples():
    rng = np.random.default_rng(1)
    eng = _swap_engine()
    eng.submit(_prompt(rng, 16), max_new_tokens=8)
    for _ in range(4):
        eng.step()
    eng.preempt(eng.decoding_slots[0], mode="swap")  # requeue path
    assert eng.n_swap_preempts == 1
    assert eng.swap_samples and eng.swap_samples[-1][0] > 0
    while eng.waiting or eng.active_slots:
        eng.step()
    assert eng.n_swap_resumes == 1
    assert eng.allocator.n_host_live == 0


def test_swap_requires_host_capacity():
    """mode="swap" must raise (not silently fall back) when the host
    tier cannot hold the victim — callers price and pick the mode."""
    rng = np.random.default_rng(2)
    eng = _swap_engine(kv_host_blocks=1)  # 20-token seq needs 3+ blocks
    eng.submit(_prompt(rng, 20), max_new_tokens=8)
    for _ in range(3):
        eng.step()
    slot = eng.decoding_slots[0]
    assert not eng.can_swap(slot)
    with pytest.raises(ValueError):
        eng.preempt(slot, mode="swap")


def test_prefix_spill_to_host_and_revival():
    """Cold prefix blocks spill to the host tier on LRU reclaim instead
    of invalidating, and a later same-prefix prompt revives them
    (unspill) with token-identical output."""
    rng = np.random.default_rng(3)
    prefix = _prompt(rng, 16)  # two full blocks at block_size=8
    # equal lengths: left-padding makes prefix sharing length-sensitive
    pa = np.concatenate([prefix, _prompt(rng, 4)])
    pb = np.concatenate([prefix, _prompt(rng, 4)])
    fillers = [_prompt(rng, 24), _prompt(rng, 24)]
    # device pool sized so the fillers' footprints force reclaim of the
    # cached prefix blocks left by the first run
    eng = _swap_engine(max_slots=1, kv_blocks=6, kv_host_blocks=16)
    want_a = eng.run([pa], max_new_tokens=4)[0].tokens
    for f in fillers:
        eng.run([f], max_new_tokens=4)
    assert eng.allocator.n_spilled > 0, "reclaim never spilled"
    # the spilled prefix revives from host for the next same-prefix run
    eng2_tokens = eng.run([pb], max_new_tokens=4)[0].tokens
    assert eng.allocator.n_unspilled > 0, "revival never unspilled"
    # correctness: replays of the ORIGINAL prompt still match a fresh run
    got_a = eng.run([pa], max_new_tokens=4)[0].tokens
    np.testing.assert_array_equal(got_a, want_a)
    fresh = _swap_engine(max_slots=1, kv_blocks=6, kv_host_blocks=16)
    np.testing.assert_array_equal(
        eng2_tokens, fresh.run([pb], max_new_tokens=4)[0].tokens)
    a = eng.allocator
    assert a.n_host_free + a.n_host_cached + a.n_host_live \
        == a.n_host_blocks


def test_cancel_waiting_swap_snapshot_frees_host_blocks():
    rng = np.random.default_rng(4)
    eng = _swap_engine()
    rid = eng.submit(_prompt(rng, 16), max_new_tokens=8)
    for _ in range(4):
        eng.step()
    eng.preempt(eng.decoding_slots[0], mode="swap")
    assert eng.allocator.n_host_live > 0
    r = eng.cancel(rid)
    assert r is not None and r.cancelled and len(r.tokens) > 0
    assert eng.allocator.n_host_live == 0
    assert eng.allocator.n_host_free + eng.allocator.n_host_cached \
        == eng.allocator.n_host_blocks


def test_release_swap_and_pinning():
    """release_swap converts a swap snapshot to recompute (freeing host
    blocks); foreign engines refuse both release and resume."""
    rng = np.random.default_rng(5)
    p = _prompt(rng, 16)
    want = _swap_engine().run([p], max_new_tokens=8)[0].tokens
    eng = _swap_engine()
    eng.submit(p, max_new_tokens=8)
    for _ in range(4):
        eng.step()
    snap = eng.preempt(eng.decoding_slots[0], requeue=False, mode="swap")
    other = _swap_engine()
    with pytest.raises(ValueError):
        other.submit_resume(snap)
    with pytest.raises(ValueError):
        other.release_swap(snap)
    rec = eng.release_swap(snap)
    assert not rec.swapped and eng.allocator.n_host_live == 0
    # the recompute snapshot resumes anywhere, token-identical
    rid = other.submit_resume(rec)
    out = {}
    while other.waiting or other.active_slots:
        for r in other.step():
            out[r.request_id] = r
    np.testing.assert_array_equal(out[rid].tokens, want)


def test_to_recompute_without_engine():
    """The module-level fallback rebuilds a recompute snapshot from the
    carried tokens when the owning engine is already retired."""
    snap = PreemptedRequest(
        request_id=7, seq_tokens=np.arange(1, 9, dtype=np.int32),
        base_len=8, max_new=3, submit_s=0.0, requested_new=5,
        truncated=False, n_preempted=1, tokens=[11, 12], pos=10,
        pending_tok=0, host_blocks=[1, 2], host_engine_id=123)
    rec = to_recompute(snap)
    assert not rec.swapped
    np.testing.assert_array_equal(
        rec.seq_tokens, np.array([1, 2, 3, 4, 5, 6, 7, 8, 11, 12]))
    # base_len stays at the prompt boundary: seq_tokens[base_len:] must
    # keep meaning "tokens already emitted" (the cancel path reads it)
    assert rec.base_len == 8 and rec.max_new == 3


# ------------------------------------------------------------------ pool
def _calibrated_swap_pool(preempt_mode="auto", **kw):
    kw.setdefault("max_instances", 2)
    kw.setdefault("max_slots", 1)
    kw.setdefault("max_seq", 64)
    kw.setdefault("preemption", True)
    kw.setdefault("kv_layout", "paged")
    kw.setdefault("block_size", 8)
    kw.setdefault("kv_block_budget", 64)
    kw.setdefault("prefix_cache", True)
    kw.setdefault("kv_host_blocks", 16)
    pool = make_pool(TINY, preempt_mode=preempt_mode, **kw)
    pool.scale_to("tiny", 1)
    rng = np.random.default_rng(3)
    for _ in range(2):
        pool.submit("tiny", _prompt(rng, 6), slo_ms=60_000.0,
                    max_new_tokens=8)
    pool.run_until_drained()
    assert pool.contention()[0] > 0.0
    return pool, rng


def _preempt_once(pool, rng, hog_new=24):
    hog = pool.submit("tiny", _prompt(rng, 8), slo_ms=60_000.0,
                      max_new_tokens=hog_new)
    for _ in range(6):
        pool.step()
    urgent = pool.submit("tiny", _prompt(rng, 6), slo_ms=0.001,
                         max_new_tokens=2)
    return hog, urgent


@pytest.mark.parametrize("mode,swaps", [("recompute", 0), ("swap", 1),
                                        ("auto", 1)])
def test_pool_preempt_mode(mode, swaps):
    """Forced modes obey the flag; auto prefers swap while the swap fit
    is uncalibrated (the only way to collect samples). Either way the
    hog resumes and emits every token."""
    pool, rng = _calibrated_swap_pool(preempt_mode=mode)
    hog, urgent = _preempt_once(pool, rng)
    res = pool.run_until_drained()
    by_id = {r.request_id: r for r in res}
    assert pool.n_preempted == 1
    assert pool.n_swap_preempted == swaps
    assert len(by_id[hog].tokens) == 24
    assert len(by_id[urgent].tokens) == 2
    st = pool.stats()
    assert st["n_swap_preempted"] == float(swaps)
    for inst in pool.live():
        a = inst.engine.allocator
        assert a.n_host_live == 0
        assert a.n_host_free + a.n_host_cached == a.n_host_blocks


def test_pool_auto_mode_prices_with_calibrated_fits():
    """With both fits calibrated, auto picks the cheaper side — force
    each side with extreme stub costs."""
    pool, rng = _calibrated_swap_pool(preempt_mode="auto")
    pool.token_cost = lambda tp_degree=None: (0.0, 1000.0)  # recompute slow
    pool.swap_cost = lambda: (0.01, 0.01)                   # swap ~free
    _preempt_once(pool, rng)
    pool.run_until_drained()
    assert pool.n_swap_preempted == 1

    pool, rng = _calibrated_swap_pool(preempt_mode="auto")
    pool.token_cost = lambda tp_degree=None: (0.0, 0.0001)  # recompute free
    pool.swap_cost = lambda: (10_000.0, 10_000.0)           # swap awful
    _preempt_once(pool, rng)
    pool.run_until_drained()
    assert pool.n_preempted == 1 and pool.n_swap_preempted == 0


def test_pool_swap_cancel_frees_host_blocks():
    pool, rng = _calibrated_swap_pool(preempt_mode="swap")
    hog, urgent = _preempt_once(pool, rng)
    # the hog is now a queued swap snapshot; cancel it there
    res = pool.cancel(hog)
    assert res is not None and res.cancelled and len(res.tokens) > 0
    pool.run_until_drained()
    for inst in pool.live():
        assert inst.engine.allocator.n_host_live == 0


def test_pool_swap_survives_source_retire():
    """A swap snapshot whose source engine drains away downgrades to
    recompute (releasing or rebuilding) and still finishes with every
    requested token — combined with the satellite-3 check that the
    respawned model starts with clean preemption bookkeeping."""
    pool, rng = _calibrated_swap_pool(preempt_mode="swap")
    hog, urgent = _preempt_once(pool, rng)
    # drain the urgent request, then retire the model entirely while the
    # hog is still a queued swap snapshot
    for _ in range(30):
        pool.step()
        if not any(i.n_resident for i in pool.live()):
            break
    pool.scale_to("tiny", 0)
    while pool.live():
        pool.step()
    assert pool.n_swap_preempted == 1
    # satellite 3: retire of the last instance cleared the per-model
    # preemption bookkeeping
    assert pool.preempts_by_model["tiny"] == 0
    assert "tiny" not in pool._last_preempt_step
    pool.scale_to("tiny", 1)
    res = pool.run_until_drained()
    by_id = {r.request_id: r for r in res}
    assert len(by_id[hog].tokens) == 24, "swap snapshot lost tokens"


def test_pool_state_has_host_feature():
    from repro.config.base import ServingConfig
    from repro.serving.bcedge import POOL_STATE_DIM, PoolScheduler

    pool, _ = _calibrated_swap_pool()
    scfg = ServingConfig(batch_sizes=(1,), concurrency_levels=(1,))
    sched = PoolScheduler(pool, scfg, slo_ms={"tiny": 1000.0},
                          learn=False, seed=0)
    s = sched._state("tiny")
    assert s.shape == (POOL_STATE_DIM,)
    occ = pool.kv_occupancy()
    assert {"host_blocks", "host_free", "host_live", "host_cached",
            "host_frac"} <= set(occ)
    assert s[-1] == pytest.approx(min(1.0, max(0.0, occ["host_frac"])))


# ------------------------------------------- satellite regression tests
def test_headroom_prices_preempted_snapshots():
    """Satellite 1: a preempted snapshot awaiting re-admission must
    contribute its remaining work to retry_after_s — context re-prefill
    + remaining decode for recompute snapshots, remaining decode only
    for swapped ones (their KV is already resident on the host)."""
    pool = make_pool(TINY, kv_layout="paged", block_size=8)
    pool.scale_to("tiny", 1)
    pool.token_cost = lambda tp_degree=None: (1.0, 2.0)  # calibrated
    base = pool.admission_headroom("tiny", 8, 4)

    def _queued(resume):
        r = PoolRequest(999, "tiny", np.zeros((0,), np.int32),
                        1000.0, 64, 0.0, resume=resume)
        import heapq
        heapq.heappush(pool.queues["tiny"], (r.deadline_s, 0, r))
        out = pool.admission_headroom("tiny", 8, 4)
        pool.queues["tiny"].clear()
        return out

    rec = PreemptedRequest(
        request_id=999, seq_tokens=np.zeros((40,), np.int32), base_len=30,
        max_new=6, submit_s=0.0, requested_new=16, truncated=False,
        n_preempted=1)
    h = _queued(rec)
    # 40 context + 6 remaining — NOT 40 + 64 (the original budget) and
    # NOT zero (the pre-fix behaviour the issue calls out)
    assert h["backlog_tokens"] - base["backlog_tokens"] == 46.0
    assert h["retry_after_s"] > base["retry_after_s"]

    swp = PreemptedRequest(
        request_id=999, seq_tokens=np.zeros((40,), np.int32), base_len=40,
        max_new=6, submit_s=0.0, requested_new=16, truncated=False,
        n_preempted=1, tokens=[1, 2], pos=42, host_blocks=[1, 2, 3],
        host_engine_id=0)
    h = _queued(swp)
    # swapped: remaining decode only, the context never re-prefills
    assert h["backlog_tokens"] - base["backlog_tokens"] == 6.0


def test_stats_exclude_cancelled_timings():
    """Satellite 2: partial timings from cancelled requests must not
    enter ttft/tpot samples (they already sit outside SLO attainment);
    a mid-stream cancel leaves stats() over completed requests only."""
    pool = make_pool(TINY)
    pool.scale_to("tiny", 1)
    rng = np.random.default_rng(7)
    pool.submit("tiny", _prompt(rng, 6), max_new_tokens=6)
    pool.run_until_drained()
    n_before = len(pool.ttft_samples)
    assert n_before >= 1
    rid = pool.submit("tiny", _prompt(rng, 6), max_new_tokens=12)
    for _ in range(4):
        pool.step()  # first token has landed
    res = pool.cancel(rid)
    assert res is not None and res.cancelled \
        and res.first_token_s >= 0, "cancel must catch a started stream"
    pool.run_until_drained()
    assert len(pool.ttft_samples) == n_before, \
        "cancelled request's partial TTFT leaked into stats"
    # the defensive path: a cancelled engine result reaching _finish is
    # flagged and still excluded
    from repro.serving.engine import ContinuousResult
    inst = pool.live()[0]
    req_id = pool.submit("tiny", _prompt(rng, 4), max_new_tokens=2)
    pool.step()
    erid, req = next(iter(inst.requests.items()))
    fake = ContinuousResult(erid, np.array([1], np.int32), 0.0, 0.0, 1.0,
                            n_iters=1, first_token_s=0.5, cancelled=True)
    res = pool._finish(inst, fake)
    assert res.cancelled and res.utility == 0.0
    assert len(pool.ttft_samples) == n_before


def test_preempt_bookkeeping_cleared_on_retire():
    """Satellite 3: scale_to(0) + sweep of the last instance clears
    per-model cooldown and preempt counts, so a respawned model does not
    start inside a stale cooldown window."""
    pool = make_pool(TINY, preemption=True)
    pool.scale_to("tiny", 1)
    rng = np.random.default_rng(9)
    pool.submit("tiny", _prompt(rng, 6), max_new_tokens=2)
    pool.run_until_drained()
    pool.preempts_by_model["tiny"] = 5
    pool._last_preempt_step["tiny"] = pool.n_steps
    pool.scale_to("tiny", 0)
    while pool.live():
        pool.step()
    assert pool.preempts_by_model["tiny"] == 0
    assert "tiny" not in pool._last_preempt_step
    # a model that never spawned keeps its (zero) entry untouched
    pool.scale_to("tiny", 1)
    assert pool.preempts_by_model["tiny"] == 0
