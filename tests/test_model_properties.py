"""Property tests on model-layer invariants (hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models.rope import apply_rope, default_positions


# ---------------------------------------------------------------- RoPE
@given(seed=st.integers(0, 100), s=st.integers(2, 16))
@settings(max_examples=25, deadline=None)
def test_rope_preserves_norm(seed, s):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((1, s, 2, 64)), jnp.float32)
    pos = default_positions(1, s)
    y = apply_rope(x, pos, "rope")
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1), rtol=1e-4)


def test_rope_relative_property():
    """q·k after RoPE depends only on relative distance."""
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((1, 1, 1, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 1, 1, 64)), jnp.float32)

    def score(pq, pk):
        qr = apply_rope(q, jnp.full((1, 1), pq, jnp.int32), "rope")
        kr = apply_rope(k, jnp.full((1, 1), pk, jnp.int32), "rope")
        return float(jnp.sum(qr * kr))

    assert score(5, 3) == pytest.approx(score(105, 103), rel=1e-3)
    assert score(5, 3) != pytest.approx(score(5, 4), rel=1e-3)


def test_rope2d_rotates_only_half():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((1, 4, 1, 64)), jnp.float32)
    pos = default_positions(1, 4)
    y = apply_rope(x, pos, "rope2d")
    # second half of head dim passes through
    np.testing.assert_array_equal(np.asarray(x[..., 32:]),
                                  np.asarray(y[..., 32:]))
    assert not np.allclose(np.asarray(x[..., 1:, :, :32]),
                           np.asarray(y[..., 1:, :, :32]))


def test_mrope_equals_rope_for_text():
    """With t=h=w=linear positions, M-RoPE must reduce to plain RoPE on
    the score level for equal positions."""
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((1, 6, 1, 64)), jnp.float32)
    pos = default_positions(1, 6)
    y1 = apply_rope(x, pos, "mrope", mrope_positions=(pos, pos, pos))
    y2 = apply_rope(x, pos, "mrope")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-6)


# ---------------------------------------------------------------- MoE
@given(seed=st.integers(0, 50), top_k=st.integers(1, 2))
@settings(max_examples=12, deadline=None)
def test_moe_conserves_token_mass(seed, top_k):
    """Every kept token's output is a convex combination over experts;
    dropped tokens produce zeros (residual path)."""
    from repro.config.base import ModelConfig
    from repro.models.moe import moe_apply, moe_init

    cfg = ModelConfig(name="m", family="moe", d_model=32, d_ff=64,
                      n_experts=4, top_k=top_k, capacity_factor=8.0,
                      vocab_size=64)
    rng = jax.random.PRNGKey(seed)
    p = moe_init(rng, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (2, 8, 32))
    y, aux = moe_apply(p, x, cfg)
    assert y.shape == x.shape
    assert bool(jnp.isfinite(y).all())
    assert float(aux["drop_frac"]) == 0.0  # cf=8 => no drops
    assert float(aux["lb_loss"]) >= 0.99  # >= 1 by Cauchy-Schwarz


def test_moe_capacity_drops_are_bounded():
    from repro.config.base import ModelConfig
    from repro.models.moe import moe_apply, moe_init

    cfg = ModelConfig(name="m", family="moe", d_model=32, d_ff=64,
                      n_experts=4, top_k=1, capacity_factor=0.5,
                      vocab_size=64)
    p = moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32))
    y, aux = moe_apply(p, x, cfg)
    assert 0.0 < float(aux["drop_frac"]) < 1.0


# ---------------------------------------------------------------- windows
@given(window=st.sampled_from([4, 8, 16]))
@settings(max_examples=6, deadline=None)
def test_sliding_window_blocks_distant_context(window):
    """Changing a token OUTSIDE the window must not change attention
    output; inside the window it must."""
    from repro.config.base import ModelConfig
    from repro.models.attention import attn_init, attention_full
    from repro.models.rope import default_positions

    cfg = ModelConfig(name="w", family="dense", d_model=64, n_heads=2,
                      n_kv_heads=2, d_ff=128, vocab_size=64,
                      sliding_window=window)
    p = attn_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    S = 32
    x = jax.random.normal(jax.random.PRNGKey(1), (1, S, 64))
    pos = default_positions(1, S)
    base = attention_full(p, x, cfg, pos, window=window, impl="naive")
    # perturb the FIRST token: outputs at positions >= window must not move
    x2 = x.at[:, 0, :].add(10.0)
    pert = attention_full(p, x2, cfg, pos, window=window, impl="naive")
    np.testing.assert_allclose(np.asarray(base[:, window:, :]),
                               np.asarray(pert[:, window:, :]), atol=1e-5)
    assert not np.allclose(np.asarray(base[:, :window, :]),
                           np.asarray(pert[:, :window, :]), atol=1e-3)
