"""Paged KV-cache subsystem: block allocator invariants, pure
gather/scatter block surgery, prefill-graft round trips across layer
kinds, dense/paged token identity, and the pool's shared block budget
(docs/ARCHITECTURE.md §5, docs/RUNTIME.md §7)."""
import os

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import jax.numpy as jnp

from conftest import KIND_CFGS, TINY
from repro.config.base import ModelConfig
from repro.models.transformer import (gather_blocks, paged_layer_kind,
                                      scatter_blocks)
from repro.serving.engine import (BlockAllocator, ContinuousBatchingEngine,
                                  InferenceEngine)
from repro.serving.runtime import ModelInstancePool


# ------------------------------------------------------------ allocator
def test_allocator_invariants():
    al = BlockAllocator(8, block_size=16)
    assert al.n_free == 8 and al.n_available == 8
    assert al.blocks_for(0) == 0
    assert al.blocks_for(1) == 1
    assert al.blocks_for(16) == 1
    assert al.blocks_for(17) == 2
    assert al.reserve(5)
    assert al.n_available == 3 and al.n_free == 8
    ids = [al.alloc_reserved() for _ in range(3)]
    assert len(set(ids)) == 3 and all(0 < i <= 8 for i in ids)
    assert al.n_free == 5 and al.n_reserved == 2 and al.n_available == 3
    assert not al.reserve(4)  # only 3 available
    al.free(ids)
    al.unreserve(2)
    assert al.n_free == 8 and al.n_available == 8 and al.n_reserved == 0


def test_allocator_never_hands_out_null_block():
    al = BlockAllocator(4, block_size=8)
    assert al.reserve(4)
    ids = [al.alloc_reserved() for _ in range(4)]
    assert sorted(ids) == [1, 2, 3, 4]  # id 0 (null) never allocated
    with pytest.raises(AssertionError):
        al.alloc_reserved()  # nothing reserved any more


# ----------------------------------------- refcount / prefix-cache
def test_refcount_decrement_to_zero_frees_exactly_once():
    """A block shared by two sequences frees on the SECOND release, and
    only once: unregistered blocks return to the free list, registered
    ones park in the cached-LRU pool (still reclaimable)."""
    al = BlockAllocator(4, block_size=8)
    assert al.reserve(2)
    a, b = al.alloc_reserved(), al.alloc_reserved()
    al.register("k-a", a)
    shared = al.acquire("k-a")
    assert shared == a and al.refcount(a) == 2
    assert al.n_live == 2  # refcounted blocks count ONCE
    al.free([a])
    assert al.refcount(a) == 1 and al.n_live == 2  # still held
    al.free([a])           # second (last) reference: parks in LRU
    assert al.refcount(a) == 0 and al.n_cached == 1 and al.n_free == 2
    al.free([b])           # unregistered: straight to the free list
    assert al.n_free == 3 and al.n_cached == 1
    assert al.n_free + al.n_cached + al.n_live == al.n_blocks


def test_double_free_still_raises_under_sharing():
    """More frees than references is a bug even when the block was
    legitimately shared for a while."""
    al = BlockAllocator(4, block_size=8)
    assert al.reserve(1)
    a = al.alloc_reserved()
    al.register("k", a)
    assert al.acquire("k") == a    # refcount 2
    al.free([a])
    al.free([a])                   # refcount 0: parked in LRU
    with pytest.raises(ValueError):
        al.free([a])               # third free of two references
    with pytest.raises(ValueError):
        al.free([a, a])            # duplicate within one call


def test_reserve_cancel_accounting_unchanged_by_cache_hits():
    """Cache hits must not leak into reservation accounting: acquiring a
    LIVE shared block costs nothing, and unreserve symmetry holds."""
    al = BlockAllocator(8, block_size=8)
    assert al.reserve(3)
    ids = [al.alloc_reserved() for _ in range(3)]
    for i, bid in enumerate(ids):
        al.register(f"k{i}", bid)
    avail0, res0 = al.n_available, al.n_reserved
    assert al.reserve(2)
    shared = [al.acquire(f"k{i}") for i in range(3)]  # live: free hits
    assert shared == ids
    assert al.n_available == avail0 - 2       # only the reserve moved it
    assert al.n_reserved == res0 + 2
    al.unreserve(2)
    al.free(shared)
    assert al.n_available == avail0 and al.n_reserved == res0


def test_lru_reclaim_never_frees_live_blocks():
    """Under pressure the allocator reclaims only refcount-0 cached
    blocks (oldest first, cache entry invalidated); live blocks are
    untouchable."""
    al = BlockAllocator(4, block_size=8)
    assert al.reserve(4)
    ids = [al.alloc_reserved() for _ in range(4)]
    for i, bid in enumerate(ids):
        al.register(f"k{i}", bid)
    al.free(ids[:2])               # k0, k1 parked in LRU (that order)
    assert al.n_cached == 2 and al.n_free == 0
    assert al.reserve(1)
    got = al.alloc_reserved()      # must reclaim the LRU-oldest: k0
    assert got == ids[0]
    assert not al.cached("k0")     # entry invalidated
    assert al.cached("k1") and al.cached("k2") and al.cached("k3")
    assert ids[2] in al._outstanding and ids[3] in al._outstanding
    assert al.n_reclaimed == 1


def test_acquire_refuses_lru_revival_that_breaks_reservations():
    """Reviving an evicted-but-cached block consumes an available block;
    when everything left is promised to reservations the acquire must
    miss instead of stealing the promise."""
    al = BlockAllocator(2, block_size=8)
    assert al.reserve(1)
    a = al.alloc_reserved()
    al.register("k", a)
    al.free([a])                   # parked in LRU; free list has 1
    assert al.reserve(2)           # promises BOTH remaining blocks
    assert al.n_available == 0
    assert al.acquire("k") is None  # revival would break the promise
    ids = [al.alloc_reserved(), al.alloc_reserved()]
    assert sorted(ids) == [1, 2]


#: the nightly fuzz job raises this (the bundled stub caps itself)
_MAX_EXAMPLES = int(os.environ.get("FUZZ_MAX_EXAMPLES", "25"))


@given(seed=st.integers(min_value=0, max_value=10_000))
@settings(max_examples=_MAX_EXAMPLES, deadline=None)
def test_allocator_conservation_under_random_ops(seed):
    """Randomized schedule of reserve/alloc/free/register/acquire ops:
    conservation (free + cached + live == n_blocks), non-negative
    availability and exact refcounts hold after every operation."""
    import random

    r = random.Random(seed)
    al = BlockAllocator(8, block_size=4)
    live = []          # [bid, refs] we still owe frees for
    registered = 0
    for _ in range(60):
        op = r.randrange(5)
        if op == 0 and al.n_available > 0:
            al.reserve(1)
        elif op == 1 and al.n_reserved > 0:
            live.append([al.alloc_reserved(), 1])
        elif op == 2 and live:
            ent = r.choice(live)
            al.free([ent[0]])
            ent[1] -= 1
            if ent[1] == 0:
                live.remove(ent)
        elif op == 3 and live:
            bid = r.choice(live)[0]
            al.register(f"key-{registered}", bid)
            registered += 1
        elif op == 4 and registered:
            bid = al.acquire(f"key-{r.randrange(registered)}")
            if bid is not None:
                for ent in live:
                    if ent[0] == bid:
                        ent[1] += 1
                        break
                else:
                    live.append([bid, 1])
        assert al.n_free + al.n_cached + al.n_live == al.n_blocks
        assert al.n_available >= 0
        assert al.n_reserved <= al.n_free + al.n_cached
        for bid, refs in live:
            assert al.refcount(bid) == refs
    for bid, refs in live:
        for _ in range(refs):      # dup check is per call: one at a time
            al.free([bid])
    assert al.n_free + al.n_cached == al.n_blocks and al.n_live == 0


# ------------------------------------------------------------ pure API
def test_scatter_gather_blocks_round_trip():
    pool = jnp.zeros((6, 4, 2, 3))
    rows = jnp.arange(10 * 2 * 3, dtype=jnp.float32).reshape(10, 2, 3)
    ids = jnp.asarray([5, 2, 4], jnp.int32)  # 3 blocks = 12 slots >= 10
    pool2 = scatter_blocks(pool, rows, ids)
    back = gather_blocks(pool2, ids)
    np.testing.assert_array_equal(np.asarray(back[:10]), np.asarray(rows))
    np.testing.assert_array_equal(np.asarray(back[10:]), 0.0)  # ragged tail
    # untouched blocks stay zero
    np.testing.assert_array_equal(np.asarray(pool2[0]), 0.0)
    with pytest.raises(ValueError):
        scatter_blocks(pool, rows, jnp.asarray([1, 2], jnp.int32))


def test_paged_layer_kind_predicate():
    assert paged_layer_kind(TINY, "attn")
    assert not paged_layer_kind(TINY, "rwkv")
    assert not paged_layer_kind(TINY, "rglru")
    assert not paged_layer_kind(KIND_CFGS["windowed"], "local_attn")
    # dense arch with a global sliding window: ring buffer, not paged
    swa = ModelConfig(name="t-swa", family="dense", n_layers=2, d_model=32,
                      n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=97,
                      sliding_window=32)
    assert not paged_layer_kind(swa, "attn")


# ------------------------------------------------- graft round trips
@pytest.mark.slow
@pytest.mark.parametrize("kind", sorted(KIND_CFGS))
@pytest.mark.parametrize("layout", ["dense", "paged"])
def test_graft_round_trip_matches_fresh_run(kind, layout):
    """prefill -> graft -> decode through the slot engine must equal a
    fresh single-sequence round-engine run, for every layer-kind family
    and both cache layouts."""
    cfg = KIND_CFGS[kind]
    kw = {"kv_layout": "paged", "block_size": 8} if layout == "paged" else {}
    eng = ContinuousBatchingEngine(cfg, max_slots=2, max_seq=64, **kw)
    ref = InferenceEngine(cfg, max_seq=64)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(1, 97, n).astype(np.int32) for n in (5, 11, 17)]
    res = eng.run(prompts, max_new_tokens=4)
    for p, r in zip(prompts, res):
        want = ref.generate([p], max_new_tokens=4).tokens[0]
        assert np.array_equal(r.tokens, want), kind


def test_paged_matches_dense_on_mixed_lengths():
    """Acceptance: token-identical greedy outputs across layouts on a
    mixed-length prompt set that churns slots and block boundaries."""
    rng = np.random.default_rng(4)
    prompts = [rng.integers(1, 97, n).astype(np.int32)
               for n in (3, 14, 9, 30, 6, 22, 12, 5)]
    dense = ContinuousBatchingEngine(TINY, max_slots=3, max_seq=64)
    paged = ContinuousBatchingEngine(TINY, max_slots=3, max_seq=64,
                                     kv_layout="paged", block_size=8)
    rd = dense.run(prompts, max_new_tokens=7)
    rp = paged.run(prompts, max_new_tokens=7)
    assert [r.request_id for r in rp] == [r.request_id for r in rd]
    for a, b in zip(rd, rp):
        assert np.array_equal(a.tokens, b.tokens)
    # eviction really returned every block
    al = paged.allocator
    assert al.n_free == al.n_blocks and al.n_reserved == 0


# ------------------------------------------------- engine block gating
def test_block_gated_admission_queues_and_drains():
    """With a tiny block budget the engine admits what fits, queues the
    rest, and serves everything as evictions free blocks."""
    # 6 blocks of 8 = 48 tokens; each request needs bucket16 + 4 = 3 blocks
    eng = ContinuousBatchingEngine(TINY, max_slots=4, max_seq=64,
                                   kv_layout="paged", block_size=8,
                                   kv_blocks=6)
    rng = np.random.default_rng(5)
    prompts = [rng.integers(1, 97, 10).astype(np.int32) for _ in range(5)]
    for p in prompts:
        eng.submit(p, max_new_tokens=4)
    eng.admit()
    # only 2 of the 4 free slots could take a reservation (2*3=6 blocks)
    assert len(eng.active_slots) == 2
    assert eng.stats()["queue_depth"] == 3.0
    res = eng.run([], max_new_tokens=4)
    assert len(res) == 5
    assert all(len(r.tokens) == 4 for r in res)
    assert eng.allocator.n_free == eng.allocator.n_blocks


def test_submit_rejects_request_larger_than_block_pool():
    """Regression (review finding): a reservation that exceeds the whole
    pool could never be admitted — submit() must raise instead of
    livelocking the FIFO head forever."""
    eng = ContinuousBatchingEngine(TINY, max_slots=2, max_seq=128,
                                   kv_layout="paged", block_size=16,
                                   kv_blocks=4)
    with pytest.raises(ValueError):
        # bucket 64 + 16 new = 80 tokens = 5 blocks > 4 total
        eng.submit(np.arange(1, 51, dtype=np.int32) % 97,
                   max_new_tokens=16)
    # a small request behind it still flows
    eng.submit(np.arange(1, 5, dtype=np.int32), max_new_tokens=4)
    res = eng.run([], max_new_tokens=4)
    assert len(res) == 1


@pytest.mark.slow
def test_pool_rejects_never_admissible_request():
    """Regression (review finding): a request no current or future grant
    could hold is rejected by the router instead of blocking the EDF
    queue (and everything behind it) forever."""
    pool = ModelInstancePool({TINY.name: TINY}, max_instances=2,
                             max_slots=2, max_seq=128, seed=0,
                             kv_layout="paged", block_size=16,
                             kv_block_budget=8, blocks_per_instance=4)
    pool.scale_to(TINY.name, 1)
    big = pool.submit(TINY.name, np.arange(1, 51, dtype=np.int32) % 97,
                      slo_ms=60_000.0, max_new_tokens=16)  # needs 5 > 4
    small = pool.submit(TINY.name, np.arange(1, 5, dtype=np.int32),
                        slo_ms=60_000.0, max_new_tokens=4)
    res = pool.run_until_drained()
    by_id = {r.request_id: r for r in res}
    assert by_id[big].rejected
    assert not by_id[small].rejected and len(by_id[small].tokens) == 4


@pytest.mark.slow
def test_route_does_not_oversubscribe_blocks_in_one_pass():
    """Regression (review finding): one route() pass must not admit two
    EDF heads against the same free blocks — the second stays in the
    pool queue (re-routable to whichever instance frees first) instead
    of being stranded in one engine's internal FIFO."""
    pool = ModelInstancePool({TINY.name: TINY}, max_instances=1,
                             max_slots=4, max_seq=64, seed=0,
                             kv_layout="paged", block_size=8,
                             kv_block_budget=6, blocks_per_instance=6)
    pool.scale_to(TINY.name, 1)
    rng = np.random.default_rng(8)
    # each request reserves bucket16 + 8 = 3 blocks; 6 free -> only 2 fit
    for _ in range(3):
        pool.submit(TINY.name, rng.integers(1, 97, 10).astype(np.int32),
                    slo_ms=60_000.0, max_new_tokens=8)
    pool.route()
    inst = pool.running(TINY.name)[0]
    assert inst.n_resident == 2          # not 3: third was not submitted
    assert pool.queue_len(TINY.name) == 1
    assert len(inst.engine.waiting) == 2  # both admissible at the engine
    res = pool.run_until_drained()
    assert len(res) == 3 and not any(r.rejected for r in res)


def test_admissible_reflects_blocks_and_slots():
    eng = ContinuousBatchingEngine(TINY, max_slots=2, max_seq=64,
                                   kv_layout="paged", block_size=8,
                                   kv_blocks=3)
    assert eng.admissible(4, 4)         # 16+4 tokens -> 3 blocks, all free
    eng.submit(np.arange(1, 5, dtype=np.int32), max_new_tokens=4)
    eng.admit()
    assert not eng.admissible(4, 4)     # blocks exhausted, slot free
    dense = ContinuousBatchingEngine(TINY, max_slots=1, max_seq=64)
    assert dense.admissible(4, 4)
    dense.submit(np.arange(1, 5, dtype=np.int32))
    dense.admit()
    assert not dense.admissible(4, 4)   # no free slot


def test_stats_report_kv_occupancy_metrics():
    dense = ContinuousBatchingEngine(TINY, max_slots=2, max_seq=64)
    paged = ContinuousBatchingEngine(TINY, max_slots=2, max_seq=64,
                                     kv_layout="paged", block_size=8)
    rng = np.random.default_rng(6)
    p = rng.integers(1, 97, 6).astype(np.int32)
    for eng in (dense, paged):
        eng.submit(p, max_new_tokens=8)
        eng.step()
        s = eng.stats()
        for key in ("kv_used_tokens", "kv_allocated_tokens",
                    "kv_waste_frac", "kv_reserved_tokens", "queue_depth"):
            assert key in s
        assert s["kv_used_tokens"] > 0
    # dense commits the whole slab; paged only the sequence's blocks
    assert dense.stats()["kv_allocated_tokens"] == 2 * 64
    assert paged.stats()["kv_allocated_tokens"] < 2 * 64
    assert paged.stats()["kv_waste_frac"] \
        < dense.stats()["kv_waste_frac"]


# ------------------------------------------------- pool shared budget
@pytest.mark.slow
def test_pool_shared_block_budget_clamps_scale_to():
    """One shared budget: dense slabs fit once, right-sized paged grants
    fit four times; retiring instances returns their grant."""
    # budget = one dense slab (2 slots * 64 tokens = 16 blocks of 8)
    common = dict(max_instances=4, max_slots=2, max_seq=64, seed=0,
                  kv_block_budget=16, block_size=8)
    dense = ModelInstancePool({TINY.name: TINY}, **common)
    assert dense.scale_to(TINY.name, 3) == 1  # slab-clamped
    paged = ModelInstancePool({TINY.name: TINY}, kv_layout="paged",
                              blocks_per_instance=4, **common)
    assert paged.scale_to(TINY.name, 4) == 4  # right-sized grants fit
    assert paged.kv_blocks_free == 0
    occ = paged.kv_occupancy()
    assert occ["budget_tokens"] == 16 * 8
    assert occ["committed_blocks"] == 16
    # drain-and-retire returns the grant to the shared budget
    paged.scale_to(TINY.name, 1)
    paged._sweep()
    assert paged.kv_blocks_free == 12
    assert paged.scale_to(TINY.name, 4) == 4


@pytest.mark.slow
def test_pool_paged_serves_and_calibrates_occupancy():
    """End to end: a paged pool under a shared budget serves a burst,
    reports real occupancy, and calibrates tokens-per-sequence."""
    pool = ModelInstancePool({TINY.name: TINY}, max_instances=2,
                             max_slots=2, max_seq=64, seed=0,
                             kv_layout="paged", block_size=8,
                             kv_block_budget=32)
    pool.scale_to(TINY.name, 2)
    rng = np.random.default_rng(7)
    for _ in range(8):
        pool.submit(TINY.name,
                    rng.integers(1, 97, rng.integers(4, 12)).astype(
                        np.int32), slo_ms=60_000.0, max_new_tokens=6)
    res = pool.run_until_drained()
    assert len(res) == 8 and not any(r.rejected for r in res)
    assert len(pool.occupancy_samples) >= 8
    tps = pool.occupancy_tokens_per_seq()
    # sequences occupy bucket(<=16) + decode tokens: O(20ish)
    assert 8.0 < tps < 40.0
    stats = pool.stats()
    assert stats["kv_budget_tokens"] == 32 * 8
    # drained: nothing used any more
    assert pool.kv_used_tokens() == 0


@pytest.mark.slow
def test_pool_guard_uses_free_blocks():
    """PoolScheduler guard: with a calibrated occupancy model and a tiny
    budget, an oversized (b, m_c) is degraded to fit the real free-block
    budget instead of the analytic memory curve."""
    from repro.config.base import ServingConfig
    from repro.serving.bcedge import PoolScheduler

    pool = ModelInstancePool({TINY.name: TINY}, max_instances=4,
                             max_slots=4, max_seq=64, seed=0,
                             kv_layout="paged", block_size=8,
                             kv_block_budget=12, blocks_per_instance=12)
    cfg = ServingConfig(batch_sizes=(1, 2, 4),
                        concurrency_levels=(1, 2, 3))
    sched = PoolScheduler(pool, cfg, slo_ms={TINY.name: 1000.0},
                          guard=True, learn=False)
    # calibrate: pretend each resident sequence occupies ~24 tokens
    pool.occupancy_samples = [(n, 24 * n) for n in (1, 2, 3, 4) * 3]
    # budget = 96 tokens; b=4, m_c=3 would need ~288 -> infeasible;
    # the guard must degrade to something that fits
    assert not sched._kv_feasible(TINY.name, 4, 3)
    assert sched._kv_feasible(TINY.name, 4, 1)
    a = cfg.pair_to_action(4, 3)
    applied = sched._apply(TINY.name, a)
    b, m_c = cfg.action_to_pair(applied)
    assert sched.guard_interventions == 1
    assert sched._kv_feasible(TINY.name, b, m_c)
    assert (b, m_c) != (4, 3)


# ------------------------------------------------- replay satellite
def test_replay_buffer_lazy_allocation():
    from repro.core.replay import ReplayBuffer

    buf = ReplayBuffer(4, capacity=1_000_000)
    # paper-sized capacity no longer eagerly commits (1e6, dim) arrays
    assert buf.allocated_rows == ReplayBuffer.INITIAL_ROWS
    for i in range(3000):
        buf.add(np.full(4, i), i, float(i), np.full(4, i + 1), False)
    assert len(buf) == 3000
    assert buf.allocated_rows == 4096  # doubled, still << capacity
    out = buf.sample(16)
    assert out["s"].shape == (16, 4) and out["a"].max() < 3000


def test_replay_buffer_ring_semantics_preserved():
    from repro.core.replay import ReplayBuffer

    buf = ReplayBuffer(2, capacity=10)
    for i in range(27):
        buf.add(np.full(2, i), i, float(i), np.full(2, i), i % 2)
    assert len(buf) == 10 and buf.full
    assert buf.allocated_rows == 10
    # ring holds exactly the last `capacity` transitions
    assert sorted(buf.a.tolist()) == list(range(17, 27))
    s = buf.sample(32)
    assert s["a"].min() >= 17
