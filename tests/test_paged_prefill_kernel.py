"""Property-based oracle tests for the fused paged-attention kernels.

`paged_prefill_attention` (chunk queries over block tables) and
`paged_decode_attention_splitk` (split-K flash-decode) vs the pure-jnp
oracles in ``repro.kernels.ref``, across ragged seq_lens, chunk sizes,
block sizes and null-block-padded tables, plus the degenerate
single-block and full-capacity cases. Runs in Pallas interpreter mode on
CPU (the ``kernels-interpret`` CI job); ``hypothesis`` falls back to the
in-tree stub (tests/_hypothesis_stub.py) when the real library is
missing.

The NaN-poison tests pin the satellite fix to the serial sweep bound:
table columns past a sequence's frontier are NEVER read (the index map
redirects them to the null block), so they may hold arbitrary garbage —
previously they were fetched and merely masked, which required them to
stay valid pool indices.
"""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels import ops, ref

MAX_EXAMPLES = 20
ATOL, RTOL = 2e-5, 2e-4


def _pools(rng, n_pool, bs, KV, hd):
    k = jnp.asarray(rng.standard_normal((n_pool, bs, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((n_pool, bs, KV, hd)), jnp.float32)
    return k, v


def _tables(rng, B, nb, n_pool):
    """Distinct physical blocks per sequence (never the null block 0),
    in shuffled order."""
    perm = rng.permutation(np.arange(1, n_pool))[:B * nb]
    return perm.reshape(B, nb).astype(np.int32)


def _null_pad_dead(tables, live, value=0):
    """Overwrite every table column past each sequence's live block
    count with ``value`` (the engine null-pads; poison tests plant a
    NaN block instead)."""
    out = np.array(tables)
    for b in range(out.shape[0]):
        out[b, live[b]:] = value
    return out


# ---------------------------------------------------------------- prefill
@given(seed=st.integers(min_value=0, max_value=10_000),
       bs=st.sampled_from([4, 8, 16]),
       T=st.sampled_from([1, 3, 5, 8, 13, 16]),
       B=st.integers(min_value=1, max_value=3),
       null_pad=st.sampled_from([False, True]))
@settings(max_examples=MAX_EXAMPLES, deadline=None)
def test_paged_prefill_matches_oracle(seed, bs, T, B, null_pad):
    """Chunk queries at ragged start positions over shuffled block
    tables: kernel == gather oracle, with and without null-padded dead
    columns."""
    rng = np.random.default_rng(seed * 7 + bs + T)
    H, KV, hd = 4, 2, 16
    nb = int(rng.integers(1, 5))
    cap = nb * bs
    T = min(T, cap)
    kp, vp = _pools(rng, 1 + B * nb + 2, bs, KV, hd)
    tables = _tables(rng, B, nb, kp.shape[0])
    pos = rng.integers(0, cap - T + 1, B).astype(np.int32)
    if null_pad:
        live = [-(-int(p + T) // bs) for p in pos]
        tables = _null_pad_dead(tables, live)
    q = jnp.asarray(rng.standard_normal((B, T, H, hd)), jnp.float32)
    scale = hd ** -0.5
    out = ops.paged_prefill_attention(q, kp, vp, jnp.asarray(tables),
                                      jnp.asarray(pos), scale)
    want = ref.paged_prefill_attention_ref(q, kp, vp, jnp.asarray(tables),
                                           jnp.asarray(pos), scale)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=ATOL, rtol=RTOL)


def test_paged_prefill_single_block_degenerate():
    """nb == 1: the whole sequence lives in one block; chunk == whole
    capacity starting at 0."""
    rng = np.random.default_rng(3)
    B, bs, H, KV, hd = 2, 8, 2, 1, 16
    kp, vp = _pools(rng, 4, bs, KV, hd)
    tables = jnp.asarray([[1], [3]], jnp.int32)
    pos = jnp.asarray([0, 0], jnp.int32)
    q = jnp.asarray(rng.standard_normal((B, bs, H, hd)), jnp.float32)
    out = ops.paged_prefill_attention(q, kp, vp, tables, pos, hd ** -0.5)
    want = ref.paged_prefill_attention_ref(q, kp, vp, tables, pos,
                                           hd ** -0.5)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=ATOL, rtol=RTOL)


def test_paged_prefill_full_capacity():
    """pos + T == nb * bs for every sequence: the last chunk row attends
    every slot of every mapped block (no dead column anywhere)."""
    rng = np.random.default_rng(4)
    B, bs, nb, T, H, KV, hd = 2, 4, 3, 5, 4, 2, 16
    cap = nb * bs
    kp, vp = _pools(rng, 1 + B * nb, bs, KV, hd)
    tables = _tables(rng, B, nb, kp.shape[0])
    pos = jnp.full((B,), cap - T, jnp.int32)
    q = jnp.asarray(rng.standard_normal((B, T, H, hd)), jnp.float32)
    out = ops.paged_prefill_attention(q, kp, vp, jnp.asarray(tables), pos,
                                      hd ** -0.5)
    want = ref.paged_prefill_attention_ref(q, kp, vp, jnp.asarray(tables),
                                           pos, hd ** -0.5)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=ATOL, rtol=RTOL)


def test_paged_prefill_t1_matches_decode_semantics():
    """A one-row chunk at position p attends slots <= p — exactly what
    the decode kernel attends with seq_len = p + 1 (ties the two
    kernels' masking conventions together)."""
    rng = np.random.default_rng(5)
    B, bs, nb, H, KV, hd = 3, 8, 3, 4, 2, 16
    kp, vp = _pools(rng, 1 + B * nb, bs, KV, hd)
    tables = jnp.asarray(_tables(rng, B, nb, kp.shape[0]))
    pos = jnp.asarray([0, 9, 23], jnp.int32)
    q = jnp.asarray(rng.standard_normal((B, 1, H, hd)), jnp.float32)
    chunk = ops.paged_prefill_attention(q, kp, vp, tables, pos, hd ** -0.5)
    dec = ops.paged_decode_attention(q, kp, vp, tables, pos + 1, hd ** -0.5)
    np.testing.assert_allclose(np.asarray(chunk), np.asarray(dec),
                               atol=ATOL, rtol=RTOL)


# ---------------------------------------------------------------- split-K
@given(seed=st.integers(min_value=0, max_value=10_000),
       bs=st.sampled_from([4, 8, 16]),
       n_splits=st.sampled_from([1, 2, 3, 4, 8]),
       B=st.integers(min_value=1, max_value=3))
@settings(max_examples=MAX_EXAMPLES, deadline=None)
def test_splitk_matches_oracle(seed, bs, n_splits, B):
    """Split-K decode vs the paged decode oracle across ragged seq_lens
    (including lengths that leave entire splits empty)."""
    rng = np.random.default_rng(seed * 11 + bs + n_splits)
    H, KV, hd = 4, 2, 16
    nb = int(rng.integers(1, 7))
    kp, vp = _pools(rng, 1 + B * nb + 2, bs, KV, hd)
    tables = _tables(rng, B, nb, kp.shape[0])
    lens = rng.integers(1, nb * bs + 1, B).astype(np.int32)
    q = jnp.asarray(rng.standard_normal((B, 1, H, hd)), jnp.float32)
    scale = hd ** -0.5
    out = ops.paged_decode_attention_splitk(q, kp, vp, jnp.asarray(tables),
                                            jnp.asarray(lens), scale,
                                            n_splits=n_splits)
    want = ref.paged_decode_attention_splitk_ref(
        q, kp, vp, jnp.asarray(tables), jnp.asarray(lens), scale)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=ATOL, rtol=RTOL)


def test_splitk_matches_serial_kernel():
    """Partitioning is an implementation detail: split-K and the serial
    sweep kernel must agree bit-for-bit up to reduction rounding."""
    rng = np.random.default_rng(6)
    B, bs, nb, H, KV, hd = 2, 8, 6, 4, 2, 16
    kp, vp = _pools(rng, 1 + B * nb, bs, KV, hd)
    tables = jnp.asarray(_tables(rng, B, nb, kp.shape[0]))
    lens = jnp.asarray([5, 48], jnp.int32)
    q = jnp.asarray(rng.standard_normal((B, 1, H, hd)), jnp.float32)
    serial = ops.paged_decode_attention(q, kp, vp, tables, lens, hd ** -0.5)
    for ns in (2, 3, 6):
        split = ops.paged_decode_attention_splitk(q, kp, vp, tables, lens,
                                                  hd ** -0.5, n_splits=ns)
        np.testing.assert_allclose(np.asarray(split), np.asarray(serial),
                                   atol=ATOL, rtol=RTOL)


def test_splitk_single_block_and_full_capacity():
    rng = np.random.default_rng(8)
    bs, H, KV, hd = 8, 2, 2, 16
    kp, vp = _pools(rng, 5, bs, KV, hd)
    q = jnp.asarray(rng.standard_normal((2, 1, H, hd)), jnp.float32)
    # single block, more splits than blocks
    t1 = jnp.asarray([[2], [4]], jnp.int32)
    l1 = jnp.asarray([3, bs], jnp.int32)  # ragged + full capacity
    out = ops.paged_decode_attention_splitk(q, kp, vp, t1, l1, hd ** -0.5,
                                            n_splits=4)
    want = ref.paged_decode_attention_ref(q, kp, vp, t1, l1, hd ** -0.5)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=ATOL, rtol=RTOL)


# ------------------------------------------- padded columns are never read
def _poisoned_setup(rng, B=3, bs=8, nb=4):
    """Pools with one all-NaN block; per-sequence tables whose dead
    columns (past the frontier) all point at it. If any kernel fetched a
    dead column the NaN would propagate through the softmax."""
    H, KV, hd = 4, 2, 16
    n_pool = 2 + B * nb
    kp, vp = _pools(rng, n_pool, bs, KV, hd)
    bad = n_pool - 1
    kp = kp.at[bad].set(jnp.nan)
    vp = vp.at[bad].set(jnp.nan)
    tables = _tables(rng, B, nb, n_pool - 1)  # live entries avoid `bad`
    lens = np.array([1, 2 * bs, 3 * bs - 3][:B], np.int32)
    live = [-(-int(n) // bs) for n in lens]
    poisoned = _null_pad_dead(tables, live, value=bad)
    clean = _null_pad_dead(tables, live, value=1)
    q = jnp.asarray(rng.standard_normal((B, 1, H, hd)), jnp.float32)
    return q, kp, vp, poisoned, clean, lens, hd ** -0.5


def test_decode_never_reads_padded_columns():
    """Regression for the serial sweep bound: the grid is bounded by the
    live block count, so dead table columns may hold ANY value — even an
    index of a NaN-filled block — without affecting the output."""
    rng = np.random.default_rng(9)
    q, kp, vp, poisoned, clean, lens, scale = _poisoned_setup(rng)
    out = ops.paged_decode_attention(q, kp, vp, jnp.asarray(poisoned),
                                     jnp.asarray(lens), scale)
    want = ref.paged_decode_attention_ref(q, kp, vp, jnp.asarray(clean),
                                          jnp.asarray(lens), scale)
    assert np.isfinite(np.asarray(out)).all()
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=ATOL, rtol=RTOL)


def test_decode_max_blocks_trims_grid():
    """`max_blocks` statically trims the sweep to the caller's live
    bound without changing the result."""
    rng = np.random.default_rng(10)
    q, kp, vp, poisoned, clean, lens, scale = _poisoned_setup(rng)
    full = ops.paged_decode_attention(q, kp, vp, jnp.asarray(clean),
                                      jnp.asarray(lens), scale)
    trimmed = ops.paged_decode_attention(q, kp, vp, jnp.asarray(poisoned),
                                         jnp.asarray(lens), scale,
                                         max_blocks=3)
    np.testing.assert_allclose(np.asarray(trimmed), np.asarray(full),
                               atol=ATOL, rtol=RTOL)


def test_splitk_never_reads_padded_columns():
    rng = np.random.default_rng(11)
    q, kp, vp, poisoned, clean, lens, scale = _poisoned_setup(rng)
    for ns in (2, 4):
        out = ops.paged_decode_attention_splitk(
            q, kp, vp, jnp.asarray(poisoned), jnp.asarray(lens), scale,
            n_splits=ns)
        want = ref.paged_decode_attention_ref(
            q, kp, vp, jnp.asarray(clean), jnp.asarray(lens), scale)
        assert np.isfinite(np.asarray(out)).all()
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=ATOL, rtol=RTOL)


@pytest.mark.parametrize("T", [1, 4, 7])
def test_prefill_never_reads_padded_columns(T):
    rng = np.random.default_rng(12 + T)
    B, bs, nb, H, KV, hd = 2, 8, 4, 4, 2, 16
    n_pool = 2 + B * nb
    kp, vp = _pools(rng, n_pool, bs, KV, hd)
    bad = n_pool - 1
    kp = kp.at[bad].set(jnp.nan)
    vp = vp.at[bad].set(jnp.nan)
    tables = _tables(rng, B, nb, n_pool - 1)
    pos = np.array([0, bs + 2], np.int32)
    live = [-(-int(p + T) // bs) for p in pos]
    poisoned = _null_pad_dead(tables, live, value=bad)
    clean = _null_pad_dead(tables, live, value=1)
    q = jnp.asarray(rng.standard_normal((B, T, H, hd)), jnp.float32)
    out = ops.paged_prefill_attention(q, kp, vp, jnp.asarray(poisoned),
                                      jnp.asarray(pos), hd ** -0.5)
    want = ref.paged_prefill_attention_ref(q, kp, vp, jnp.asarray(clean),
                                           jnp.asarray(pos), hd ** -0.5)
    assert np.isfinite(np.asarray(out)).all()
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=ATOL, rtol=RTOL)
