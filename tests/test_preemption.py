"""SLO-aware preemption (docs/RUNTIME.md §8) and allocator/queue
hardening: preempted blocks are fully returned, preempt-resume greedy
output is token-identical to an uninterrupted run, the pool policy
triggers/holds back correctly, double-frees raise, and
``run_until_drained`` no longer returns silent partial results."""
import numpy as np
import pytest

from conftest import TINY, make_pool
from repro.config.base import ServingConfig
from repro.serving.engine import BlockAllocator, ContinuousBatchingEngine
from repro.serving.simulator import EdgeServingEnv


def _prompt(rng, n):
    return rng.integers(1, 97, n).astype(np.int32)


# ------------------------------------------------- allocator hardening
def test_double_free_raises():
    """Regression: free() only range-checked ids, so a double-freed id
    entered the free list twice and one physical block could be handed
    to two sequences."""
    alloc = BlockAllocator(4, 16)
    assert alloc.reserve(2)
    a, b = alloc.alloc_reserved(), alloc.alloc_reserved()
    alloc.free([a])
    with pytest.raises(ValueError):
        alloc.free([a])          # double free
    with pytest.raises(ValueError):
        alloc.free([b, b])       # duplicate within one call
    with pytest.raises(ValueError):
        alloc.free([0])          # null block was never handed out
    alloc.free([b])
    assert alloc.n_free == 4


# ------------------------------------------------- engine mechanics
@pytest.mark.slow
@pytest.mark.parametrize("layout", ["dense", "paged"])
def test_preempt_returns_blocks_and_resumes_identically(layout):
    """The core invariants: (1) a preempted sequence's blocks are fully
    returned to the allocator, (2) after resume the greedy output is
    token-identical to an uninterrupted run."""
    kw = {} if layout == "dense" else \
        {"kv_layout": "paged", "block_size": 16}
    rng = np.random.default_rng(0)
    prompt = _prompt(rng, 20)

    ref_eng = ContinuousBatchingEngine(TINY, max_slots=2, max_seq=128,
                                       seed=0, **kw)
    ref = ref_eng.run([prompt], max_new_tokens=10)[0].tokens

    eng = ContinuousBatchingEngine(TINY, max_slots=2, max_seq=128,
                                   seed=0, **kw)
    eng.submit(prompt, max_new_tokens=10)
    for _ in range(4):  # emit a few tokens, then evict mid-sequence
        eng.step()
    [slot] = eng.decoding_slots
    if layout == "paged":
        held = len(eng.slots[slot].blocks) + eng.slots[slot].n_outstanding
        avail_before = eng.allocator.n_available
    eng.preempt(slot)  # requeues at the engine FIFO head
    assert eng.n_preempted == 1
    if layout == "paged":
        assert eng.allocator.n_available == avail_before + held
        assert eng.allocator.n_free == eng.allocator.n_blocks
        assert eng.allocator.n_reserved == 0
    done = []
    for _ in range(60):
        done.extend(eng.step())
        if done:
            break
    assert len(done) == 1
    assert done[0].n_preempted == 1
    assert np.array_equal(done[0].tokens, ref)
    if layout == "paged":
        assert eng.allocator.n_free == eng.allocator.n_blocks


@pytest.mark.slow
def test_preempt_refuses_mid_prefill_and_empty_slots():
    eng = ContinuousBatchingEngine(TINY, max_slots=2, max_seq=128,
                                   token_budget=8)
    rng = np.random.default_rng(1)
    with pytest.raises(ValueError):
        eng.preempt(0)  # nothing resident
    eng.submit(_prompt(rng, 60), max_new_tokens=2)  # bucket 64 > budget
    eng.step()
    [slot] = eng.prefilling_slots
    with pytest.raises(ValueError):
        eng.preempt(slot)  # never a mid-chunk prefill
    assert eng.preemption_candidates() == []


@pytest.mark.slow
def test_double_preempt_round_trip_stays_token_identical():
    """Two preemptions of the same sequence still reconstruct the exact
    uninterrupted greedy continuation (recompute covers prompt + all
    emitted context each time)."""
    rng = np.random.default_rng(2)
    prompt = _prompt(rng, 12)
    ref = ContinuousBatchingEngine(TINY, max_slots=1, max_seq=128,
                                   seed=0).run([prompt],
                                               max_new_tokens=12)[0].tokens
    eng = ContinuousBatchingEngine(TINY, max_slots=1, max_seq=128, seed=0)
    eng.submit(prompt, max_new_tokens=12)
    done = []
    kicked = 0
    for step in range(100):
        done.extend(eng.step())
        if done:
            break
        if step in (3, 9) and eng.decoding_slots:
            eng.preempt(eng.decoding_slots[0])
            kicked += 1
    assert kicked == 2 and len(done) == 1
    assert done[0].n_preempted == 2
    assert np.array_equal(done[0].tokens, ref)


# ------------------------------------------------- pool policy
def _calibrated_pool(**kw):
    """Pool with one running instance and a warm contention fit (the
    preemption trigger needs a calibrated service-time prediction)."""
    kw.setdefault("max_instances", 2)
    kw.setdefault("max_slots", 1)
    kw.setdefault("max_seq", 64)
    kw.setdefault("preemption", True)
    pool = make_pool(TINY, **kw)
    pool.scale_to("tiny", 1)
    rng = np.random.default_rng(3)
    for _ in range(2):
        pool.submit("tiny", _prompt(rng, 6), slo_ms=60_000.0,
                    max_new_tokens=8)
    pool.run_until_drained()
    assert pool.contention()[0] > 0.0
    return pool, rng


@pytest.mark.slow
def test_pool_preempts_largest_slack_for_urgent_request():
    pool, rng = _calibrated_pool()
    hog = pool.submit("tiny", _prompt(rng, 8), slo_ms=60_000.0,
                      max_new_tokens=24)
    for _ in range(6):  # hog resident and decoding, slots now full
        pool.step()
    urgent = pool.submit("tiny", _prompt(rng, 6), slo_ms=0.001,
                         max_new_tokens=2)
    res = pool.run_until_drained()
    assert pool.n_preempted == 1
    by_id = {r.request_id: r for r in res}
    # urgent got the slot and finished; the hog resumed afterwards and
    # still emitted every requested token
    assert len(by_id[urgent].tokens) == 2
    assert len(by_id[hog].tokens) == 24
    assert by_id[urgent].finish_s < by_id[hog].finish_s
    assert pool.report()["tiny"]["preempted"] == 1.0


@pytest.mark.slow
def test_pool_preemption_holds_back_without_urgency_or_margin():
    """Hysteresis: a waiting request with plenty of slack, or a victim
    that is no laxer than the waiter, must NOT trigger an eviction."""
    pool, rng = _calibrated_pool()
    pool.submit("tiny", _prompt(rng, 8), slo_ms=60_000.0,
                max_new_tokens=24)
    for _ in range(6):
        pool.step()
    # ample slack: waiting is cheaper than recompute
    pool.submit("tiny", _prompt(rng, 6), slo_ms=60_000.0,
                max_new_tokens=2)
    pool.run_until_drained()
    assert pool.n_preempted == 0


@pytest.mark.slow
def test_no_preemption_thrash_under_sustained_overload():
    """Sustained tight-SLO overload: cooldown + per-request caps keep
    preemptions rare and every admitted sequence completes in full."""
    pool, rng = _calibrated_pool()
    want = {}
    for k in range(10):
        rid = pool.submit("tiny", _prompt(rng, 6), slo_ms=0.001,
                          max_new_tokens=4)
        want[rid] = 4
    res = pool.run_until_drained()
    by_id = {r.request_id: r for r in res}
    for rid, n in want.items():
        assert len(by_id[rid].tokens) == n  # no sequence lost or clipped
    # at most one eviction per cooldown window ever fires
    assert pool.n_preempted <= pool.n_steps // pool.preempt_cooldown_steps \
        + 1


# ------------------------------------------------- drained-flag satellite
@pytest.mark.slow
def test_run_until_drained_raises_on_exhaustion():
    """Regression: max_steps exhaustion silently returned partial
    results, so benchmarks read partial completions as full drains."""
    pool = make_pool(TINY, max_instances=1, max_slots=1)
    pool.scale_to("tiny", 1)
    rng = np.random.default_rng(4)
    pool.submit("tiny", _prompt(rng, 6), slo_ms=60_000.0, max_new_tokens=8)
    with pytest.raises(RuntimeError, match="max_steps exhausted"):
        pool.run_until_drained(max_steps=2)
    # with room to finish, the same workload drains cleanly
    assert len(pool.run_until_drained()) == 1


@pytest.mark.slow
def test_run_until_drained_returns_on_unservable_queue():
    """Queued work whose model has NO running instance cannot progress:
    that is a clean return (everything drainable was drained), not an
    exhaustion error — and not a 10k-step spin."""
    pool = make_pool(TINY, max_instances=1, max_slots=1)
    rng = np.random.default_rng(5)
    pool.submit("tiny", _prompt(rng, 6), slo_ms=60_000.0, max_new_tokens=2)
    assert pool.run_until_drained() == []
    assert pool.queue_len("tiny") == 1
    assert pool.n_steps == 0  # detected immediately, no spin


# ------------------------------------------------- simulator twin
@pytest.mark.parametrize("seed", [0, 1])
def test_sim_preemption_conserves_requests(seed):
    cfg = ServingConfig(exec_mode="continuous", decode_steps_mean=4.0,
                        prefill_tokens_mean=24.0, token_budgets=(0, 16),
                        preemption=True, arrival_rps=60.0)
    env = EdgeServingEnv(cfg, episode_ms=3000.0, seed=seed)
    done, steps = False, 0
    while not done and steps < 400:
        _, _, done, _ = env.step(steps % cfg.n_actions)
        steps += 1
    served = sum(r.n_requests for r in env.history)
    queued = sum(len(q) for q in env.queues.values())
    dropped = sum(q.dropped for q in env.queues.values())
    in_flight = 0
    for _, _, kind, payload in env._events:
        if kind == "complete":
            in_flight += payload.n_requests
        elif kind == "iter":
            in_flight += len(payload.active) + len(payload.done)
    assert served + queued + in_flight + dropped == env.total_requests


def test_sim_token_budget_caps_iteration_tokens():
    """With a token budget, a session's planned iteration work never
    exceeds budget (decode rows included once prefill is paid)."""
    from repro.serving.request import Request
    from repro.serving.simulator import _Session

    reqs = []
    for i, (pf, dec) in enumerate([(40, 4), (0, 3), (10, 2)]):
        r = Request(model="m", input_type="text", input_shape=(1,),
                    slo_ms=1000.0, arrival_ms=0.0, decode_steps=dec,
                    prefill_tokens=pf)
        r.remaining = dec
        r.prefill_remaining = pf
        reqs.append(r)
    sess = _Session("m", 4, 1, 0.0, 0.0, 1e9, 0.0, None, 0,
                    token_budget=8)
    sess.active = reqs
    total, alloc = sess.plan_tokens()
    assert total <= 8
    assert alloc == [7, 0, 0]  # 1 decode row + 7 budgeted prefill tokens
    sess.token_budget = 0
    total, alloc = sess.plan_tokens()
    assert total == 1 + 40 + 10  # uncapped: all prefill in one iteration
