"""Prefix caching with copy-on-write block sharing
(docs/ARCHITECTURE.md §5): same-prefix sequences map full immutable
prompt blocks once (refcount+1), chunked prefill skips straight to the
first uncached token, evicted-but-cached blocks revive from an LRU pool,
greedy outputs stay token-identical, and stats count shared blocks once.
The randomized cross-feature schedules live in tests/test_engine_fuzz.py.
"""
import numpy as np
import pytest

from conftest import KIND_CFGS, TINY, make_pool
from repro.serving.engine import (ContinuousBatchingEngine,
                                  supports_prefix_cache)


def _mk(prefix_cache=True, **kw):
    kw.setdefault("max_slots", 4)
    kw.setdefault("max_seq", 128)
    kw.setdefault("seed", 0)
    return ContinuousBatchingEngine(TINY, kv_layout="paged", block_size=8,
                                    prefix_cache=prefix_cache, **kw)


def _family(rng, n_prompts, prefix_len=24, tail_len=4):
    """Same-length prompts sharing one prefix (left-padding makes
    sharing length-sensitive, so the family keeps tails equal-length)."""
    prefix = rng.integers(1, 97, prefix_len).astype(np.int32)
    return [np.concatenate([prefix,
                            rng.integers(1, 97, tail_len).astype(np.int32)])
            for _ in range(n_prompts)]


# ------------------------------------------------------------ gating
def test_prefix_cache_requires_paged_and_pageable_layers():
    with pytest.raises(ValueError):
        ContinuousBatchingEngine(TINY, max_slots=2, max_seq=64,
                                 prefix_cache=True)  # dense layout
    for kind in ("windowed", "rglru", "rwkv", "swa"):
        assert not supports_prefix_cache(KIND_CFGS[kind])
        with pytest.raises(ValueError):
            ContinuousBatchingEngine(KIND_CFGS[kind], max_slots=2,
                                     max_seq=64, kv_layout="paged",
                                     block_size=8, prefix_cache=True)
    assert supports_prefix_cache(TINY)
    assert supports_prefix_cache(KIND_CFGS["tail"])


# ------------------------------------------------------------ sharing
@pytest.mark.slow
def test_sequential_same_prefix_hits_and_stays_token_identical():
    """The second identical-prefix request skips the cached prefix
    (prefill jumps to the first uncached token) and still produces the
    exact no-cache greedy output — including the full-cover case, where
    the tail block is copied on divergence rather than written shared."""
    rng = np.random.default_rng(0)
    prompts = _family(rng, 3)
    ref = _mk(prefix_cache=False)
    want = [ref.run([p], max_new_tokens=6)[0].tokens for p in prompts]

    eng = _mk()
    for p, w in zip(prompts, want):
        got = eng.run([p], max_new_tokens=6)[0].tokens
        assert np.array_equal(got, w)
    s = eng.stats()
    assert s["n_prefix_hits"] == 2.0           # first run seeds the cache
    assert s["prefix_hit_rate"] > 0.4
    # identical FULL prompt resubmitted: full-cover hit (CoW tail), and
    # the cached content must not have been corrupted by earlier writes
    again = eng.run([prompts[0]], max_new_tokens=6)[0].tokens
    assert np.array_equal(again, want[0])


@pytest.mark.slow
def test_concurrent_sharing_maps_blocks_once():
    """Same-prefix residents decode concurrently off ONE physical copy
    of the prefix: refcounts > 1, kv_shared_frac > 0, and the distinct
    live allocation is far below the logical per-sequence sum."""
    rng = np.random.default_rng(1)
    prompts = _family(rng, 4)
    eng = _mk()
    # seed the cache, then admit the rest at the same boundary
    eng.run([prompts[0]], max_new_tokens=2)
    for p in prompts:
        eng.submit(p, max_new_tokens=8)
    eng.step()
    assert len(eng.active_slots) == 4
    shared = [b for s in eng.slots if s.active for b in s.blocks
              if eng.allocator.refcount(b) > 1]
    assert shared, "no block is shared across residents"
    st = eng.stats()
    assert st["kv_shared_frac"] > 0.3
    # logical usage exceeds the distinct physical allocation: that
    # surplus is the capacity the cache buys
    assert st["kv_used_tokens"] > st["kv_allocated_tokens"]
    assert 0.0 <= st["kv_waste_frac"] <= 1.0
    res = []
    while eng.active_slots or eng.waiting:
        res.extend(eng.step())
    ref = _mk(prefix_cache=False)
    for p, r in zip(prompts, sorted(res, key=lambda r: r.request_id)):
        assert np.array_equal(
            r.tokens, ref.run([p], max_new_tokens=8)[0].tokens)
    al = eng.allocator
    assert al.n_free + al.n_cached + al.n_live == al.n_blocks
    assert al.n_reserved == 0 and al.n_live == 0


def test_stats_count_shared_blocks_once():
    """Regression (fuzz-harness find): ``kv_waste_frac`` used the
    per-sequence logical sum, which double-counts refcount-shared blocks
    and went NEGATIVE under sharing; it now uses unique physical
    coverage over the distinct live allocation."""
    rng = np.random.default_rng(2)
    prompts = _family(rng, 3)
    eng = _mk()
    eng.run([prompts[0]], max_new_tokens=2)
    for p in prompts:
        eng.submit(p, max_new_tokens=8)
    for _ in range(3):
        eng.step()
    st = eng.stats()
    assert st["kv_shared_frac"] > 0.0, "no sharing: regression untested"
    assert st["kv_waste_frac"] >= 0.0
    assert eng.kv_unique_used_tokens <= eng.kv_allocated_tokens
    assert eng.kv_used_tokens > eng.kv_unique_used_tokens


@pytest.mark.slow
def test_lru_reuse_and_reclaim_under_pressure():
    """Evicted-but-cached blocks revive on a later same-prefix admission
    (LRU pool), and when fresh allocations need the space the oldest
    cached blocks are reclaimed — never a live one — with outputs still
    token-identical."""
    rng = np.random.default_rng(3)
    fam_a = _family(rng, 2)
    eng = _mk(kv_blocks=12)  # tight: 96 tokens
    ref = _mk(prefix_cache=False)
    w0 = ref.run([fam_a[0]], max_new_tokens=4)[0].tokens
    assert np.array_equal(eng.run([fam_a[0]], max_new_tokens=4)[0].tokens,
                          w0)
    assert eng.allocator.n_cached > 0      # prompt blocks parked, cached
    # same prefix again: revived from the LRU pool, prefill mostly skipped
    assert np.array_equal(eng.run([fam_a[1]], max_new_tokens=4)[0].tokens,
                          ref.run([fam_a[1]], max_new_tokens=4)[0].tokens)
    assert eng.stats()["n_prefix_hits"] >= 1.0
    # now flood with DIFFERENT prefixes: the parked blocks must be
    # reclaimed (cache entries invalidated), allocation must not fail
    for _ in range(4):
        p = rng.integers(1, 97, 28).astype(np.int32)
        assert np.array_equal(eng.run([p], max_new_tokens=4)[0].tokens,
                              ref.run([p], max_new_tokens=4)[0].tokens)
    assert eng.allocator.n_reclaimed > 0
    al = eng.allocator
    assert al.n_free + al.n_cached + al.n_live == al.n_blocks


# ------------------------------------------------------------ admission
@pytest.mark.slow
def test_admissible_discounts_live_shared_blocks():
    """While a same-prefix sequence is resident, ``admissible`` prices
    only the unshared remainder — the admission headroom sharing buys."""
    rng = np.random.default_rng(4)
    prompts = _family(rng, 2)
    eng = _mk(kv_blocks=8)  # 64 tokens: one 32-bucket seq + decode fits
    eng.run([prompts[0]], max_new_tokens=2)  # seed cache (parks in LRU)
    eng.submit(prompts[0], max_new_tokens=8)
    eng.step()  # resident again, prefix blocks LIVE now
    assert eng.active_slots
    # worst case would need 5 blocks (32 + 8 tokens); only ~2 are free,
    # but 3 prompt blocks are live-shared -> admissible with the prompt
    assert not eng.admissible(len(prompts[1]), 8)
    assert eng.admissible(len(prompts[1]), 8, prompt=prompts[1])


@pytest.mark.slow
def test_admission_capacity_gain_vs_no_cache():
    """Under one tight block budget, same-prefix requests reach a
    strictly higher peak residency with the cache on."""
    rng = np.random.default_rng(5)
    prompts = _family(rng, 6, prefix_len=24, tail_len=4)

    def peak(prefix_cache):
        eng = _mk(prefix_cache=prefix_cache, kv_blocks=16, max_slots=6)
        eng.run([prompts[0]], max_new_tokens=2)   # warm/seed
        for p in prompts:
            eng.submit(p, max_new_tokens=8)
        peak_resident = 0
        while eng.active_slots or eng.waiting:
            eng.step()
            peak_resident = max(peak_resident, len(eng.active_slots))
        return peak_resident

    assert peak(True) > peak(False)


# ------------------------------------------------------------ pool
@pytest.mark.slow
def test_router_prefix_affinity_concentrates_same_prefix():
    """Same-prefix requests prefer the instance already holding the
    prefix instead of re-prefilling it on every instance."""
    rng = np.random.default_rng(6)
    prompts = _family(rng, 3, prefix_len=24, tail_len=4)
    pool = make_pool(TINY, max_instances=2, max_slots=4, max_seq=64,
                     kv_layout="paged", block_size=8, kv_block_budget=64,
                     prefix_cache=True)
    pool.scale_to(TINY.name, 2)
    first = pool.submit(TINY.name, prompts[0], slo_ms=60_000.0,
                        max_new_tokens=4)
    pool.run_until_drained()
    rest = [pool.submit(TINY.name, p, slo_ms=60_000.0, max_new_tokens=4)
            for p in prompts[1:]]
    pool.run_until_drained()
    placed = dict(pool.admission_log)
    assert all(placed[r] == placed[first] for r in rest), placed
    assert pool.prefix_hit_rate() > 0.0


@pytest.mark.slow
def test_pool_prefix_cache_skips_unsupported_models():
    """A mixed pool downgrades per model: pageable models get the cache,
    recurrent/windowed ones serve correctly without it."""
    from repro.serving.runtime import ModelInstancePool

    cfgs = {TINY.name: TINY,
            KIND_CFGS["rglru"].name: KIND_CFGS["rglru"]}
    pool = ModelInstancePool(cfgs, max_instances=2, max_slots=2,
                             max_seq=64, seed=0, kv_layout="paged",
                             block_size=8, prefix_cache=True)
    pool.scale_to(TINY.name, 1)
    pool.scale_to(KIND_CFGS["rglru"].name, 1)
    assert pool.running(TINY.name)[0].engine.prefix_cache
    assert not pool.running(KIND_CFGS["rglru"].name)[0].engine.prefix_cache
    rng = np.random.default_rng(7)
    for m in cfgs:
        pool.submit(m, rng.integers(1, 97, 10).astype(np.int32),
                    slo_ms=60_000.0, max_new_tokens=4)
    res = pool.run_until_drained()
    assert len(res) == 2 and not any(r.rejected for r in res)
