"""Tests for the performance profiler (§IV-E) and scheduler deployment
checkpointing (train-offline / deploy)."""
import os

import numpy as np
import pytest

from repro.config.base import ServingConfig
from repro.core.baselines import FixedScheduler
from repro.core.sac import SACAgent, SACConfig
from repro.serving.bcedge import run_episode
from repro.serving.profiler import PerformanceProfiler
from repro.serving.simulator import EdgeServingEnv


def _run_with_profiler(action, seed=0, ms=6000.0):
    cfg = ServingConfig()
    env = EdgeServingEnv(cfg, episode_ms=ms, seed=seed)
    prof = PerformanceProfiler()
    agent = FixedScheduler(action)
    s = env.reset()
    prof.reset_env()
    done = False
    while not done:
        s, _, done, _ = env.step(agent.act(s))
        prof.poll(env)
    return cfg, env, prof


def test_profiler_collects_rounds():
    cfg, env, prof = _run_with_profiler(cfg_action(2, 2))
    total = sum(e.total_requests for e in prof.table.values())
    assert total == sum(r.n_requests for r in env.history)
    # all records belong to the configured (b, m_c)
    for (m, b, mc) in prof.table:
        assert (b, mc) == (2, 2)


def cfg_action(b, mc):
    return ServingConfig().pair_to_action(b, mc)


def test_profiler_summary_fields():
    _, env, prof = _run_with_profiler(cfg_action(4, 1))
    key = next(iter(prof.table))
    s = prof.profile(*key)
    assert s["rounds"] >= 1
    assert s["mean_latency_ms"] > 0
    assert 0 <= s["violation_rate"] <= 1
    util = prof.utilization()
    assert 0 <= util["busy_frac"] <= 1


def test_profiler_best_config_prefers_feasible():
    cfg = ServingConfig()
    env = EdgeServingEnv(cfg, episode_ms=12_000.0, seed=3)
    prof = PerformanceProfiler()
    rng = np.random.default_rng(0)
    s = env.reset()
    done = False
    # explore a few configs so the table has alternatives
    actions = [cfg.pair_to_action(b, mc)
               for b, mc in ((1, 1), (2, 2), (64, 8))]
    while not done:
        s, _, done, _ = env.step(actions[int(rng.integers(len(actions)))])
        prof.poll(env)
    best = prof.best_config("yolo", max_violation=0.6)
    if best is not None:  # enough data collected
        assert best != (64, 8)  # the pathological config never wins


def test_fig1_surface_shape():
    _, env, prof = _run_with_profiler(cfg_action(2, 1))
    surf = prof.fig1_surface("res")
    if surf:
        assert all(len(k) == 2 for k in surf)


# ---------------------------------------------------------------- deploy
def test_sac_save_load_roundtrip(tmp_path):
    agent = SACAgent(10, 16, SACConfig(batch_size=8), seed=0)
    # a few updates so weights move off init
    rng = np.random.default_rng(0)
    for _ in range(32):
        s = rng.standard_normal(10).astype(np.float32)
        agent.observe(s, int(rng.integers(16)), float(rng.random()),
                      rng.standard_normal(10).astype(np.float32), False)
    agent.update()
    path = os.path.join(tmp_path, "sac.npz")
    agent.save(path)

    fresh = SACAgent(10, 16, SACConfig(batch_size=8), seed=99)
    probe = np.ones(10, np.float32)
    before = fresh.act(probe, greedy=True)
    fresh.load(path)
    assert fresh.act(probe, greedy=True) == agent.act(probe, greedy=True)


def test_sac_load_rejects_mismatched_actions(tmp_path):
    agent = SACAgent(10, 16, seed=0)
    path = os.path.join(tmp_path, "sac.npz")
    agent.save(path)
    other = SACAgent(10, 8, seed=0)
    with pytest.raises(ValueError):
        other.load(path)
