"""Multi-model instance-pool runtime: router EDF ordering, lifecycle
(scale/drain/retire) and two-model concurrent serving (docs/RUNTIME.md)."""
import numpy as np
import pytest

from repro.config.base import ModelConfig, ServingConfig
from repro.serving.bcedge import PoolScheduler
from repro.serving.engine import ContinuousBatchingEngine
from repro.serving.latency_model import fit_contention, predicted_iter_ms
from repro.serving.runtime import (DRAINING, RETIRED, RUNNING,
                                   ModelInstancePool)

TINY_A = ModelConfig(name="tiny-a", family="dense", n_layers=2, d_model=32,
                     n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=97)
TINY_B = ModelConfig(name="tiny-b", family="dense", n_layers=2, d_model=48,
                     n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=83)


def _prompt(rng, vocab=97, n=None):
    return rng.integers(1, vocab, n or rng.integers(4, 12)).astype(np.int32)


def _pool(**kw):
    kw.setdefault("max_instances", 4)
    kw.setdefault("max_slots", 2)
    kw.setdefault("max_seq", 64)
    return ModelInstancePool({"tiny-a": TINY_A}, **kw)


# ------------------------------------------------------------ router
def test_router_admits_by_deadline():
    pool = _pool(max_slots=1)
    pool.scale_to("tiny-a", 1)
    rng = np.random.default_rng(0)
    t = pool.now()
    # same submit instant, deadlines out of submission order
    loose = pool.submit("tiny-a", _prompt(rng), slo_ms=60_000.0,
                        max_new_tokens=2, submit_s=t)
    tight = pool.submit("tiny-a", _prompt(rng), slo_ms=5_000.0,
                        max_new_tokens=2, submit_s=t)
    mid = pool.submit("tiny-a", _prompt(rng), slo_ms=30_000.0,
                      max_new_tokens=2, submit_s=t)
    res = pool.run_until_drained()
    assert len(res) == 3
    admitted = [rid for rid, _ in pool.admission_log]
    assert admitted == [tight, mid, loose]


def test_router_balances_across_instances():
    pool = _pool(max_slots=2)
    pool.scale_to("tiny-a", 2)
    rng = np.random.default_rng(1)
    for _ in range(4):
        pool.submit("tiny-a", _prompt(rng), slo_ms=60_000.0,
                    max_new_tokens=2)
    pool.step()
    used = {iid for _, iid in pool.admission_log}
    assert len(used) == 2  # least-loaded placement spreads the work
    pool.run_until_drained()


def test_slot_cap_is_the_b_axis():
    pool = _pool(max_slots=2)
    pool.scale_to("tiny-a", 1)
    pool.set_slot_cap("tiny-a", 1)
    rng = np.random.default_rng(2)
    for _ in range(3):
        pool.submit("tiny-a", _prompt(rng), slo_ms=60_000.0,
                    max_new_tokens=2)
    pool.step()
    inst = pool.running("tiny-a")[0]
    assert inst.n_resident == 1  # capped below the engine's 2 slots
    res = pool.run_until_drained()
    assert len(res) == 3


def test_strict_admission_rejects_expired():
    pool = _pool(strict_admission=True)
    pool.scale_to("tiny-a", 1)
    rng = np.random.default_rng(3)
    dead = pool.submit("tiny-a", _prompt(rng), slo_ms=0.0,
                       max_new_tokens=2)  # deadline == submit instant
    ok = pool.submit("tiny-a", _prompt(rng), slo_ms=60_000.0,
                     max_new_tokens=2)
    res = pool.run_until_drained()
    by_id = {r.request_id: r for r in res}
    assert by_id[dead].rejected and by_id[dead].violated
    assert not by_id[ok].rejected and len(by_id[ok].tokens) == 2
    assert pool.n_rejected == 1
    assert all(rid != dead for rid, _ in pool.admission_log)


# ------------------------------------------------------------ lifecycle
def test_scale_up_down_idempotent():
    pool = _pool()
    assert pool.scale_to("tiny-a", 3) == 3
    ids = sorted(i.instance_id for i in pool.running("tiny-a"))
    assert pool.scale_to("tiny-a", 3) == 3  # idempotent: same instances
    assert sorted(i.instance_id for i in pool.running("tiny-a")) == ids
    assert pool.scale_to("tiny-a", 1) == 1
    states = [i.state for i in pool.instances["tiny-a"]]
    assert states.count(RUNNING) == 1 and states.count(DRAINING) == 2
    pool.scale_to("tiny-a", 1)  # idempotent on the way down too
    assert [i.state for i in pool.instances["tiny-a"]] == states
    # scale-up revives draining instances instead of spawning new ones
    assert pool.scale_to("tiny-a", 2) == 2
    assert all(i.instance_id in ids for i in pool.running("tiny-a"))
    pool.step()  # sweep retires the remaining empty draining instance
    assert pool.total_live() == 2
    assert sum(1 for i in pool.retired if i.model == "tiny-a") == 1


def test_scale_to_clamps_at_max_instances():
    pool = ModelInstancePool({"tiny-a": TINY_A, "tiny-b": TINY_B},
                             max_instances=3, max_slots=2, max_seq=64)
    assert pool.scale_to("tiny-a", 2) == 2
    assert pool.scale_to("tiny-b", 4) == 1  # only one budget slot left
    assert pool.total_live() == 3
    with pytest.raises(RuntimeError):
        pool.spawn("tiny-b")


def test_drain_before_retire_finishes_resident_work():
    pool = _pool()
    pool.scale_to("tiny-a", 1)
    rng = np.random.default_rng(4)
    rid = pool.submit("tiny-a", _prompt(rng), slo_ms=60_000.0,
                      max_new_tokens=6)
    pool.step()  # admitted
    pool.drain("tiny-a")
    inst = pool.live("tiny-a")[0]
    assert inst.state == DRAINING and inst.n_resident == 1
    # draining instances accept no new work
    late = pool.submit("tiny-a", _prompt(rng), slo_ms=60_000.0,
                       max_new_tokens=2)
    res = pool.run_until_drained()
    by_id = {r.request_id: r for r in res}
    assert len(by_id[rid].tokens) == 6  # scale-down did not truncate
    assert late not in by_id  # still queued: no running instance took it
    assert pool.queue_len("tiny-a") == 1
    assert pool.states("tiny-a") == [RETIRED]
    # scale back up: the queued request is finally served
    pool.scale_to("tiny-a", 1)
    res2 = pool.run_until_drained()
    assert [r.request_id for r in res2] == [late]


# ------------------------------------------------------------ concurrency
def test_two_model_concurrent_smoke():
    pool = ModelInstancePool({"tiny-a": TINY_A, "tiny-b": TINY_B},
                             max_instances=4, max_slots=2, max_seq=64)
    pool.scale_to("tiny-a", 1)
    pool.scale_to("tiny-b", 1)
    rng = np.random.default_rng(5)
    for _ in range(3):
        pool.submit("tiny-a", _prompt(rng, 97), slo_ms=60_000.0,
                    max_new_tokens=3)
        pool.submit("tiny-b", _prompt(rng, 83), slo_ms=60_000.0,
                    max_new_tokens=3)
    res = pool.run_until_drained()
    assert len(res) == 6
    report = pool.report()
    assert report["tiny-a"]["served"] == 3
    assert report["tiny-b"]["served"] == 3
    assert all(len(r.tokens) == 3 for r in res)
    # both models really overlapped inside single pool iterations
    assert any(n >= 2 for n, _ in pool.contention_samples)


def test_pool_matches_standalone_engine_greedy():
    """Routing through the pool must not change what the model computes:
    same weights (shared seed), token-identical greedy output."""
    rng = np.random.default_rng(6)
    prompt = _prompt(rng, 97, 9)
    ref_eng = ContinuousBatchingEngine(TINY_A, max_slots=2, max_seq=64,
                                       seed=0)
    ref = ref_eng.run([prompt], max_new_tokens=4)[0].tokens
    pool = _pool(seed=0)
    pool.scale_to("tiny-a", 2)
    pool.submit("tiny-a", prompt, slo_ms=60_000.0, max_new_tokens=4)
    res = pool.run_until_drained()
    assert np.array_equal(res[0].tokens, ref)


def test_instances_share_weights_and_jit():
    pool = _pool()
    pool.scale_to("tiny-a", 3)
    a, b, c = pool.running("tiny-a")
    assert a.engine.params is b.engine.params is c.engine.params
    assert a.engine._decode is b.engine._decode


# ------------------------------------------------------------ calibration
def test_fit_contention_recovers_linear_model():
    t1, c = 4.0, 0.8
    samples = [(n, predicted_iter_ms(t1, c, n)) for n in (1, 2, 3, 4) * 8]
    ft1, fc = fit_contention(samples)
    assert ft1 == pytest.approx(t1, rel=1e-6)
    assert fc == pytest.approx(c, rel=1e-6)
    # single overlap level: slope unidentifiable, falls back to mean
    ft1, fc = fit_contention([(2, 5.0), (2, 7.0)])
    assert ft1 == pytest.approx(6.0) and fc == 0.0
    assert fit_contention([]) == (0.0, 0.0)


def test_pool_records_contention_samples():
    pool = _pool()
    pool.scale_to("tiny-a", 2)
    rng = np.random.default_rng(7)
    for _ in range(6):
        pool.submit("tiny-a", _prompt(rng), slo_ms=60_000.0,
                    max_new_tokens=3)
    pool.run_until_drained()
    assert len(pool.contention_samples) >= 3
    assert all(ms > 0.0 for _, ms in pool.contention_samples)
    t1, c = pool.contention()
    assert t1 >= 0.0 and c >= 0.0


# ------------------------------------------------------------ scheduler
def test_pool_scheduler_drives_real_scaling():
    pool = ModelInstancePool({"tiny-a": TINY_A}, max_instances=3,
                             max_slots=2, max_seq=64)
    scfg = ServingConfig(batch_sizes=(1, 2), concurrency_levels=(1, 2, 3))
    sched = PoolScheduler(pool, scfg, slo_ms={"tiny-a": 60_000.0},
                          decode_steps_mean=3.0, guard=False, seed=0)
    applied = sched.control()
    b, m_c = applied["tiny-a"]
    assert pool.m_c("tiny-a") == m_c >= 1
    assert pool.slot_caps["tiny-a"] == b
    rng = np.random.default_rng(8)
    for _ in range(4):
        pool.submit("tiny-a", _prompt(rng), slo_ms=60_000.0,
                    max_new_tokens=2)
        sched.record(pool.step())
    applied = sched.control()  # closes the transition, re-decides
    assert pool.m_c("tiny-a") == applied["tiny-a"][1]
    pool.run_until_drained()


# ------------------------------------------------ speculation (fourth axis)
def test_set_spec_k_applies_to_live_engines_and_spawns():
    pool = _pool(kv_layout="paged", block_size=8, spec_k=4)
    pool.scale_to("tiny-a", 1)
    eng = pool.live("tiny-a")[0].engine
    assert eng.spec_max == 4 and eng.spec_k == 4
    pool.set_spec_k("tiny-a", 2)
    assert eng.spec_k == 2 and pool.spec_ks["tiny-a"] == 2
    # future spawns inherit the CURRENT depth under the built cap
    pool.scale_to("tiny-a", 2)
    assert all(i.engine.spec_k == 2 for i in pool.live("tiny-a"))
    # clamped per-engine to the construction-time scratch capacity
    pool.set_spec_k("tiny-a", 99)
    assert all(i.engine.spec_k == 4 for i in pool.live("tiny-a"))


def test_spec_cap_zero_pool_is_inert():
    pool = _pool()  # dense, spec off
    pool.scale_to("tiny-a", 1)
    eng = pool.live("tiny-a")[0].engine
    assert eng.spec_max == 0
    pool.set_spec_k("tiny-a", 4)  # always safe: clamps to 0
    assert eng.spec_k == 0
    assert pool.spec_accept_rate() == 0.0
    assert pool.stats()["spec_accept_rate"] == 0.0


def test_pool_speculative_serving_matches_baseline():
    rng = np.random.default_rng(4)
    prompts = [_prompt(rng) for _ in range(4)]
    base = ContinuousBatchingEngine(TINY_A, max_slots=2, max_seq=64, seed=0)
    want = {tuple(p): base.run([p], max_new_tokens=4)[0].tokens
            for p in prompts}
    pool = _pool(kv_layout="paged", block_size=8, spec_k=4)
    pool.scale_to("tiny-a", 1)
    rids = {pool.submit("tiny-a", p, slo_ms=60_000.0, max_new_tokens=4):
            tuple(p) for p in prompts}
    got = {}
    for _ in range(400):
        for r in pool.step():
            got[r.request_id] = r.tokens
        if len(got) == len(rids):
            break
    assert len(got) == len(rids)
    for rid, key in rids.items():
        np.testing.assert_array_equal(got[rid], want[key])
    assert pool.spec_accept_rate() >= 0.0


def test_guard_degrades_spec_k_first():
    """Infeasible k collapses toward 0 BEFORE the token budget,
    concurrency or batch degrade: the verify surcharge is pure overhead,
    so shedding it never costs capacity (docs/RUNTIME.md §8)."""
    from repro.serving.bcedge import POOL_STATE_DIM

    pool = _pool()
    scfg = ServingConfig(batch_sizes=(1, 2), concurrency_levels=(1, 2),
                         token_budgets=(0, 16), spec_depths=(0, 2, 4))
    sched = PoolScheduler(pool, scfg, slo_ms={"tiny-a": 1000.0},
                          decode_steps_mean=1.0, learn=False, seed=0)
    # calibrated token cost: 200ms/token makes any k > 0 overshoot the
    # 1000ms iteration budget at b=2 (work = b + k*b >= 6 tokens) while
    # k=0 with tb=0 prices nothing
    pool.token_cost = lambda: (0.0, 200.0)
    a = scfg.quad_to_action(2, 1, 0, 4)
    applied = sched._apply("tiny-a", a)
    b, m_c, tb, k = scfg.action_to_quad(applied)
    assert (b, m_c, tb, k) == (2, 1, 0, 0), (b, m_c, tb, k)
    assert sched.guard_interventions == 1
    assert pool.spec_ks["tiny-a"] == 0
    # the state vector carries the acceptance feature, winsorized to [0,1]
    pool.spec_accept_rate = lambda: 3.7
    s = sched._state("tiny-a")
    assert s.shape == (POOL_STATE_DIM,) == (13,)
    assert s[10] == 1.0
    pool.spec_accept_rate = lambda: -0.5
    assert sched._state("tiny-a")[10] == 0.0


def test_guard_prices_spec_k_through_token_cost():
    """_feasible: k*b extra verify tokens ride the token-cost fit — on
    top of the token budget when one is set, on top of the b-token
    decode floor when not."""
    pool = _pool()
    scfg = ServingConfig(batch_sizes=(1, 2), concurrency_levels=(1,),
                         token_budgets=(0, 8), spec_depths=(0, 4))
    sched = PoolScheduler(pool, scfg, slo_ms={"tiny-a": 1000.0},
                          decode_steps_mean=1.0, learn=False, seed=0)
    pool.token_cost = lambda: (0.0, 50.0)  # 50ms/token, 1000ms budget
    assert sched._feasible("tiny-a", 2, 1, 0, 0)       # nothing priced
    assert sched._feasible("tiny-a", 2, 1, 8, 0)       # 8 tok = 400ms
    assert sched._feasible("tiny-a", 2, 1, 0, 4)       # 2+8 tok = 500ms
    assert sched._feasible("tiny-a", 2, 1, 8, 4)       # 8+8 tok = 800ms
    assert not sched._feasible("tiny-a", 2, 1, 16, 4)  # 16+8 = 1200ms
    assert not sched._feasible("tiny-a", 2, 1, 0, 16)  # 2+32 = 1700ms
