"""Tensor-parallel sharded engine tests (docs/RUNTIME.md §10).

The sharded engine spans a 1D ``("model",)`` mesh: params land under
``engine_param_shardings`` (column-sharded wq/wk/wv, row-sharded wo),
the KV pool — dense slabs and paged blocks alike — head-shards over the
model axis, and decode/prefill/verify are jitted with NamedSharding
in/out specs. The acceptance bar is TOKEN IDENTITY: a sharded engine
must produce bit-identical greedy outputs to the unsharded engine on
the same weights, across layouts (dense + paged), prefix cache on/off,
speculation, and TP degrees 2 and 4.

Multi-device tests run in a SUBPROCESS with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the flag must
be set before jax imports; the main test process keeps its single
device). Error-path tests that need no devices run in-process.
"""
import os
import subprocess
import sys

import pytest

from conftest import TINY
from repro.serving.engine import ContinuousBatchingEngine

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_PRELUDE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
from repro.config.base import ModelConfig
from repro.launch.mesh import make_tp_mesh
from repro.serving.engine import ContinuousBatchingEngine

TINY2 = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=32,
                    n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=97)
TINY4 = ModelConfig(name="tiny4", family="dense", n_layers=2, d_model=32,
                    n_heads=4, n_kv_heads=4, d_ff=64, vocab_size=97)

def prompts(cfg, seed=7):
    # shared-prefix family (prefix-cache hit + full-cover duplicate)
    # plus a divergent one-off
    rng = np.random.default_rng(seed)
    v = cfg.vocab_size
    shared = rng.integers(1, v, 20).astype(np.int32)
    ps = [np.concatenate([shared, rng.integers(1, v, n).astype(np.int32)])
          for n in (4, 12)]
    ps += [rng.integers(1, v, 9).astype(np.int32), ps[0].copy()]
    return ps

def toks(cfg, mesh, share_from=None, **kw):
    eng = ContinuousBatchingEngine(cfg, max_slots=3, max_seq=128, seed=0,
                                   mesh=mesh, share_from=share_from, **kw)
    return eng, [r.tokens for r in eng.run(prompts(cfg),
                                           max_new_tokens=8)]

def check(name, ref, got):
    assert len(ref) == len(got), name
    for i, (r, g) in enumerate(zip(ref, got)):
        assert np.array_equal(r, g), (name, i, r, g)
    print(name, "OK")
"""


def _run_sub(code: str) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _PRELUDE + code], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


@pytest.mark.slow
@pytest.mark.parametrize("tp", [2, 4])
def test_sharded_engine_token_identity(tp):
    """Sharded greedy outputs == unsharded, at TP degree 2 and 4, for
    the paged layout (prefix cache on AND off, token budget on) and the
    dense layout. tp=4 head-shards a 4-head variant; the 2-head config
    proves the divisibility filter (heads replicate, projections still
    shard) on the 2-way mesh."""
    cfg = "TINY2" if tp == 2 else "TINY4"
    out = _run_sub(f"""
cfg = {cfg}
mesh = make_tp_mesh({tp})
_, ref = toks(cfg, None, kv_layout="paged", block_size=8)
donor, got = toks(cfg, mesh, kv_layout="paged", block_size=8)
check("paged", ref, got)
_, got = toks(cfg, mesh, kv_layout="paged", block_size=8,
              prefix_cache=True, token_budget=24)
check("paged_prefix_budget", ref, got)
_, dref = toks(cfg, None)
_, dgot = toks(cfg, mesh)
check("dense", dref, dgot)
_, got = toks(cfg, mesh, share_from=donor, kv_layout="paged",
              block_size=8)
check("share_from", ref, got)
try:
    ContinuousBatchingEngine(
        ModelConfig(name="bad", family="dense", n_layers=1, d_model=30,
                    n_heads=3, n_kv_heads=3, d_ff=32, vocab_size=97),
        max_slots=1, max_seq=64, seed=0, mesh=mesh)
except ValueError as e:
    assert "must divide" in str(e), e
    print("divide OK")
""")
    for name in ("paged", "paged_prefix_budget", "dense", "share_from",
                 "divide"):
        assert f"{name} OK" in out


@pytest.mark.slow
def test_sharded_engine_speculative_identity():
    """Speculative decode (propose/verify/rollback) on a 2-way mesh
    stays token-identical to the unsharded plain-decode engine."""
    out = _run_sub("""
mesh = make_tp_mesh(2)
_, ref = toks(TINY2, None, kv_layout="paged", block_size=8)
_, got = toks(TINY2, mesh, kv_layout="paged", block_size=8,
              prefix_cache=True, spec_k=3)
check("speculative", ref, got)
""")
    assert "speculative OK" in out


@pytest.mark.slow
def test_pool_spawns_tp_instances():
    """ModelInstancePool carves TP instances from the shared device
    set: instances span their degree's mesh, devices_in_use sums the
    degrees, head-sharding discounts the KV budget charge (one budget
    block buys tp pool blocks), outputs stay identical to a plain
    engine, and set_tp_degree drains mismatched instances so the next
    scale_to respawns at the new degree."""
    out = _run_sub("""
from repro.serving.runtime import ModelInstancePool

pool = ModelInstancePool({"tiny": TINY2}, max_instances=4, max_slots=2,
                         max_seq=128, kv_layout="paged", block_size=8,
                         kv_block_budget=64, tp_degree=2, n_devices=8)
assert pool.scale_to("tiny", 2) == 2
insts = pool.running("tiny")
assert all(i.tp_degree == 2 for i in insts)
assert all(i.engine.tp_degree == 2 for i in insts)
assert pool.devices_in_use() == 4
# dense-equiv grant 2*16=32 blocks; at tp=2 the budget charge halves
# while the engine keeps the full grant
for i in insts:
    assert i.kv_blocks == 16 and i.engine.allocator.n_blocks == 32
assert pool.kv_blocks_free == 64 - 32
# same-degree instances share one weight/jit template
assert len(pool._templates) == 1 and ("tiny", 2) in pool._templates
print("spawn OK")

ref = ContinuousBatchingEngine(TINY2, max_slots=1, max_seq=128, seed=0)
ps = prompts(TINY2)
want = [ref.run([p], max_new_tokens=4)[0].tokens for p in ps]
rids = {pool.submit("tiny", p, slo_ms=60_000.0, max_new_tokens=4): i
        for i, p in enumerate(ps)}
got = {rids[r.request_id]: r.tokens
       for r in pool.run_until_drained()}
for i, w in enumerate(want):
    assert np.array_equal(got[i], w), (i, got[i], w)
print("identity OK")

pool.set_tp_degree("tiny", 1)
assert not pool.running("tiny")      # old degree drains
assert pool.scale_to("tiny", 1) == 1
inst = pool.running("tiny")[0]
assert inst.tp_degree == 1 and inst.engine.mesh is None
assert inst.kv_blocks == inst.engine.allocator.n_blocks == 32
pool.step()                          # sweep retires the drained pair
assert pool.devices_in_use() == 1
assert pool.kv_blocks_free == 64 - 32
print("retune OK")
""")
    for name in ("spawn", "identity", "retune"):
        assert f"{name} OK" in out


@pytest.mark.slow
def test_pool_device_budget_bounds_joint_partition():
    """m_c and TP degree jointly partition the shared device set:
    scale_to clamps when Σ tp_degree would exceed n_devices."""
    out = _run_sub("""
from repro.serving.runtime import ModelInstancePool

pool = ModelInstancePool({"tiny4": TINY4}, max_instances=8, max_slots=2,
                         max_seq=128, tp_degree=4, n_devices=8)
assert pool.scale_to("tiny4", 3) == 2   # 3 x tp=4 > 8 devices
assert pool.devices_in_use() == 8
assert not pool.can_spawn("tiny4")
pool.set_tp_degree("tiny4", 1)
pool._sweep()                           # retire the drained degree-4 pair
assert pool.devices_in_use() == 0
assert pool.scale_to("tiny4", 3) == 3
assert pool.devices_in_use() == 3
print("budget OK")
""")
    assert "budget OK" in out


# ------------------------------------------------- in-process error paths
def test_mesh_helpers_raise_actionable_errors():
    """Device-count failures must name the mesh being built and the
    XLA_FLAGS workaround (the raw jax error names neither)."""
    from repro.launch.mesh import (make_debug_mesh, make_production_mesh,
                                   make_tp_mesh)
    for build, pat in ((lambda: make_tp_mesh(64), "make_tp_mesh(64)"),
                       (lambda: make_debug_mesh(16, 16),
                        "make_debug_mesh(16, 16)"),
                       (lambda: make_production_mesh(),
                        "make_production_mesh")):
        with pytest.raises(ValueError) as exc:
            build()
        msg = str(exc.value)
        assert pat in msg and "XLA_FLAGS" in msg \
            and "--xla_force_host_platform_device_count" in msg
    with pytest.raises(ValueError):
        make_tp_mesh(0)


def test_engine_validates_mesh():
    """A mesh without a 'model' axis is rejected at construction (the
    head-divisibility rejection needs a >1-device mesh and is covered
    by the subprocess identity test above)."""
    import jax
    import numpy as np_

    mesh = jax.sharding.Mesh(np_.asarray(jax.devices()[:1]), ("data",))
    with pytest.raises(ValueError, match="'model' axis"):
        ContinuousBatchingEngine(TINY, max_slots=1, max_seq=64, seed=0,
                                 mesh=mesh)


def test_guard_degrades_tp_degree_before_concurrency():
    """An infeasible TP degree steps down BEFORE m_c or b degrade: the
    collective surcharge and the device claim go first (the ladder is
    k → token budget → tp → m_c → b). With a 1-device budget the
    degree-2 half of the action space is never applied, so this runs
    on the single-device test process."""
    from conftest import make_pool
    from repro.config.base import ServingConfig
    from repro.serving.bcedge import PoolScheduler

    pool = make_pool()
    pool.n_devices = 1
    scfg = ServingConfig(batch_sizes=(1, 2), concurrency_levels=(1,),
                         tp_degrees=(1, 2))
    sched = PoolScheduler(pool, scfg, slo_ms={m: 1000.0
                                              for m in pool.configs},
                          decode_steps_mean=1.0, learn=False, seed=0)
    model = next(iter(pool.configs))
    a = scfg.quint_to_action(2, 1, 0, 0, 2)
    applied = sched._apply(model, a)
    assert scfg.action_to_quint(applied) == (2, 1, 0, 0, 1)
    assert sched.guard_interventions == 1
    assert pool.tp_degrees[model] == 1
    # the 12th state feature is the shared-device-set utilization
    from repro.serving.bcedge import POOL_STATE_DIM
    s = sched._state(model)
    assert s.shape == (POOL_STATE_DIM,)
    pool.scale_to(model, 1)
    assert sched._state(model)[11] == 1.0  # 1 of 1 devices in use
