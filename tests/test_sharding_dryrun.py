"""Sharding-rule + small-mesh lowering tests.

These run in a SUBPROCESS with a small forced device count (the main test
process must keep the default single device for the smoke tests)."""
import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run_sub(code: str) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-2000:]
    return out.stdout


def test_param_pspec_rules_unit():
    """Pure-python rule checks (no devices needed)."""
    sys.path.insert(0, SRC)
    import numpy as np

    from repro.launch.sharding import _base_spec, param_pspec

    class Leaf:
        def __init__(self, shape):
            self.shape = shape
            self.ndim = len(shape)

    assert _base_spec("embed", 2) == ("model", None)
    assert _base_spec("units/0/attn/wq", 2) == (None, "model")
    assert _base_spec("units/0/attn/wo", 2) == ("model", None)
    assert _base_spec("units/0/ffn/w_up", 3) == ("model", None, None)
    assert _base_spec("tail/0/ffn/w_up", 2) == (None, "model")
    assert _base_spec("units/0/time_mix/W_v", 2) == (None, "model")
    assert _base_spec("units/0/channel_mix/W_v", 2) == ("model", None)
    # stacked leaf gets a leading None
    spec = param_pspec("units/0/attn/wq", Leaf((8, 256, 256)))
    assert spec == (None, None, "model") or tuple(spec) == (
        None, None, "model")
    # 2d mode upgrades a divisible None dim to data
    spec = param_pspec("units/0/attn/wq", Leaf((8, 256, 256)), mode="2d",
                       data_size=16)
    assert tuple(spec) == (None, "data", "model")
    # non-divisible dims are not upgraded
    spec = param_pspec("tail/0/rec/conv_w", Leaf((4, 256)), mode="2d",
                       data_size=16)
    assert tuple(spec)[0] is None


@pytest.mark.parametrize("arch,shape", [
    ("qwen3-0.6b", "decode_32k"),
    ("recurrentgemma-2b", "long_500k"),
])
def test_small_mesh_lowering(arch, shape):
    """Reduced configs must lower+compile on a 2x2 debug mesh."""
    code = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp
from repro.config import get_reduced_config, INPUT_SHAPES
from repro.launch import sharding
from repro.launch.mesh import make_debug_mesh
from repro.models import build_model
import dataclasses

cfg = get_reduced_config("{arch}")
mesh = make_debug_mesh(2, 2)
model = build_model(cfg, remat=False)
params_abs = model.abstract_params(jnp.float32)
p_shard = sharding.param_shardings(mesh, params_abs)
shape = dataclasses.replace(INPUT_SHAPES["{shape}"], seq_len=128,
                            global_batch=4)
cache_abs = model.cache_spec(4, 128, jnp.float32)
c_shard = sharding.cache_shardings(mesh, cfg, cache_abs, 4)
inputs = model.input_specs(shape, jnp.float32)
in_shard = sharding.input_shardings(mesh, cfg, inputs)
with mesh:
    compiled = jax.jit(model.decode_step,
                       in_shardings=(p_shard, c_shard, in_shard)
                       ).lower(params_abs, cache_abs, inputs).compile()
print("OK", compiled.memory_analysis().temp_size_in_bytes >= 0)
"""
    out = _run_sub(code)
    assert "OK" in out


def test_dryrun_roofline_artifacts_valid():
    """Every saved dry-run artifact must be schema-complete."""
    base = os.path.join(os.path.dirname(__file__), "..", "experiments",
                        "dryrun")
    if not os.path.isdir(base):
        pytest.skip("no dry-run artifacts yet")
    files = [f for f in os.listdir(base) if f.endswith(".json")]
    assert files, "dry-run directory is empty"
    n_ok = 0
    for f in files:
        with open(os.path.join(base, f)) as fh:
            r = json.load(fh)
        assert r["status"] in ("ok", "skipped"), f"{f}: {r.get('error')}"
        if r["status"] == "ok":
            n_ok += 1
            assert r["bytes_per_device"] > 0
            for key in ("compute_s", "memory_s", "collective_s"):
                assert r["roofline"][key] >= 0
            assert r["dominant"].endswith("_s")
    assert n_ok >= 30


def test_collective_parser_trip_counts():
    sys.path.insert(0, SRC)
    from repro.launch.roofline import parse_collectives

    hlo = """
body_1 (p: (s32[], f32[8])) -> (s32[], f32[8]) {
  %ar = f32[8]{0} all-reduce(f32[8]{0} %x), replica_groups={}
}

cond_1 (p: (s32[], f32[8])) -> pred[] {
  %c = s32[] constant(10)
  ROOT %lt = pred[] compare(s32[] %i, s32[] %c), direction=LT
}

ENTRY main () -> f32[8] {
  %w = (s32[], f32[8]) while((s32[], f32[8]) %init), condition=%cond_1, body=%body_1
  %ag = f32[16]{0} all-gather(f32[8]{0} %y), dimensions={0}
}
"""
    out = parse_collectives(hlo)
    assert out["all-reduce_bytes"] == 8 * 4 * 10  # x10 trip count
    assert out["all-gather_bytes"] == 16 * 4
