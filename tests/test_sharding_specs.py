"""Sharding-spec divisibility coverage across the layer families
(docs/ARCHITECTURE.md §11).

Two rule sets are checked, both against the invariant jax enforces at
``device_put``/``jit`` time — every axis a spec shards must DIVIDE its
dim on the target mesh (jax rejects uneven sharding outright):

* the launch-scale rules (``param_pspec``/``cache_shardings``) on the
  2x2 debug mesh, over one reduced config per layer family —
  attention, windowed, RWKV, RG-LRU, and a frontend (vision) stack;
* the serving-engine rules (``engine_param_shardings`` /
  ``engine_cache_shardings``) on a 2-way TP mesh, over the tiny
  serving configs — here the invariant must hold for ARBITRARY dims
  (odd vocab, 2-head caches) because ``_fit_mesh`` drops any
  non-dividing axis to replicated.

Runs in a SUBPROCESS with 4 forced host devices (mesh construction
needs them; the main test process keeps its single device).
"""
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

#: one reduced config per layer family (attention / windowed / rwkv /
#: rglru / frontend) — starcoder2 is the sliding-window family,
#: qwen2-vl carries the vision frontend stack
FAMILY_ARCHS = ("qwen3-0.6b", "starcoder2-15b", "rwkv6-3b",
                "recurrentgemma-2b", "qwen2-vl-7b")

_CODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp
from repro.config import get_reduced_config
from repro.config.base import ModelConfig
from repro.launch.mesh import make_debug_mesh, make_tp_mesh
from repro.launch import sharding
from repro.models import build_model
from repro.common.tree import tree_map_with_path

def assert_divides(shardings, arrays, mesh, ctx):
    leaves_s = dict()
    tree_map_with_path(lambda p, s: leaves_s.__setitem__(p, s), shardings)
    n_sharded = 0
    def chk(path, leaf):
        nonlocal n_sharded
        spec = leaves_s[path].spec
        assert len(spec) <= leaf.ndim, (ctx, path, tuple(spec), leaf.shape)
        for dim, ax in zip(leaf.shape, spec):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            n = 1
            for a in axes:
                n *= mesh.shape[a]
            assert dim % n == 0, (ctx, path, spec, leaf.shape)
            n_sharded += 1
    tree_map_with_path(chk, arrays)
    return n_sharded

mesh = make_debug_mesh(2, 2)
for arch in {archs!r}:
    cfg = get_reduced_config(arch)
    model = build_model(cfg, remat=False)
    params = model.abstract_params(jnp.float32)
    n = assert_divides(sharding.param_shardings(mesh, params),
                       params, mesh, arch)
    assert n > 0, f"{{arch}}: no parameter leaf sharded at all"
    cache = model.cache_spec(4, 128, jnp.float32)
    assert_divides(sharding.cache_shardings(mesh, cfg, cache, 4),
                   cache, mesh, arch)
    print(arch, "OK", n)

# engine rules: arbitrary (odd) dims must still satisfy the invariant
tp = make_tp_mesh(2)
for kwargs in (dict(name="tiny", family="dense",
                    block_pattern=("attn",)),
               dict(name="tiny-w", family="dense",
                    block_pattern=("local_attn",), sliding_window=16),
               dict(name="tiny-rwkv", family="ssm",
                    block_pattern=("rwkv",), rwkv_head_size=16),
               dict(name="tiny-rglru", family="ssm",
                    block_pattern=("rglru",))):
    cfg = ModelConfig(n_layers=2, d_model=32, n_heads=2,
                      n_kv_heads=2, d_ff=64, vocab_size=97, **kwargs)
    model = build_model(cfg, remat=False)
    params = model.abstract_params(jnp.float32)
    n = assert_divides(sharding.engine_param_shardings(tp, params),
                       params, tp, cfg.name)
    assert n > 0, f"{{cfg.name}}: no parameter leaf sharded at all"
    cache = model.cache_spec(3, 128, jnp.float32)
    assert_divides(sharding.engine_cache_shardings(tp, cache),
                   cache, tp, cfg.name)
    print("engine", cfg.name, "OK", n)
print("DONE")
"""


@pytest.mark.slow
def test_sharding_specs_divide_mesh_all_families():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("XLA_FLAGS", None)
    code = _CODE.format(archs=FAMILY_ARCHS)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    for arch in FAMILY_ARCHS:
        assert f"{arch} OK" in out.stdout
    assert "DONE" in out.stdout
