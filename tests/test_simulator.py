"""Simulator invariants — unit + hypothesis property tests."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.config.base import ServingConfig
from repro.configs.paper_edge_models import EDGE_MODELS
from repro.serving import latency_model as lm
from repro.serving.platforms import PLATFORMS
from repro.serving.request import Request, RequestQueue
from repro.serving.simulator import EdgeServingEnv
from repro.serving.workload import PoissonWorkload


# ------------------------------------------------------------ queues
def test_queue_slo_priority_order():
    q = RequestQueue("m")
    for slo in (100.0, 20.0, 50.0, 20.0):
        q.push(Request("m", "image", (3,), slo, arrival_ms=0.0))
    batch = q.pop_batch(4)
    assert [r.slo_ms for r in batch] == [20.0, 20.0, 50.0, 100.0]


def test_queue_fifo_within_priority():
    q = RequestQueue("m")
    rs = [Request("m", "image", (3,), 50.0, arrival_ms=float(i))
          for i in range(5)]
    for r in rs:
        q.push(r)
    assert [r.arrival_ms for r in q.pop_batch(5)] == [0, 1, 2, 3, 4]


def test_queue_drop_at_capacity():
    q = RequestQueue("m", max_len=2)
    ok = [q.push(Request("m", "i", (1,), 10.0, 0.0)) for _ in range(4)]
    assert ok == [True, True, False, False]
    assert q.dropped == 2


# ------------------------------------------------------------ workload
def test_poisson_rate():
    wl = PoissonWorkload(rps=30.0, seed=0)
    reqs = wl.burst(8000)
    dur_s = (reqs[-1].arrival_ms - reqs[0].arrival_ms) / 1000.0
    rate = len(reqs) / dur_s
    assert rate == pytest.approx(30.0 * len(EDGE_MODELS), rel=0.1)


def test_poisson_mix_uniform():
    wl = PoissonWorkload(rps=30.0, seed=1)
    reqs = wl.burst(6000)
    counts = {m: 0 for m in EDGE_MODELS}
    for r in reqs:
        counts[r.model] += 1
    for c in counts.values():
        assert c == pytest.approx(1000, rel=0.25)


# ------------------------------------------------------------ latency model
@given(b=st.integers(1, 128), mc=st.integers(1, 8))
@settings(max_examples=60, deadline=None)
def test_latency_positive_and_memory_monotonic(b, mc):
    hw = PLATFORMS["xavier_nx"]
    prof = EDGE_MODELS["yolo"]
    est = lm.estimate_execution(hw, prof, b, mc)
    assert est.compute_ms > 0
    assert est.interference_factor >= 1.0
    est2 = lm.estimate_execution(hw, prof, b, mc + 1)
    assert est2.mem_used_gb > est.mem_used_gb


@given(b=st.integers(1, 64))
@settings(max_examples=40, deadline=None)
def test_batching_amortizes_per_request_compute(b):
    hw = PLATFORMS["xavier_nx"]
    prof = EDGE_MODELS["res"]
    t1 = lm.estimate_execution(hw, prof, b, 1).compute_ms / b
    t2 = lm.estimate_execution(hw, prof, b * 2, 1).compute_ms / (b * 2)
    assert t2 <= t1 + 1e-6


def test_overflow_at_huge_batch():
    hw = PLATFORMS["jetson_nano"]
    prof = EDGE_MODELS["inc"]
    est = lm.estimate_execution(hw, prof, 128, 8)
    assert est.overflow


# ------------------------------------------------------------ env invariants
@given(seed=st.integers(0, 50), action=st.integers(0, 63))
@settings(max_examples=20, deadline=None)
def test_env_conserves_requests(seed, action):
    cfg = ServingConfig()
    env = EdgeServingEnv(cfg, episode_ms=3000.0, seed=seed)
    done, steps = False, 0
    while not done and steps < 200:
        _, _, done, _ = env.step(action)
        steps += 1
    served = sum(r.n_requests for r in env.history)
    queued = sum(len(q) for q in env.queues.values())
    pending_exec = 0  # rounds in flight hold popped requests
    for t, _, kind, payload in env._events:
        if kind == "complete":
            pending_exec += payload.n_requests
    dropped = sum(q.dropped for q in env.queues.values())
    assert served + queued + pending_exec + dropped == env.total_requests


@given(seed=st.integers(0, 30))
@settings(max_examples=10, deadline=None)
def test_env_latencies_nonnegative_and_time_monotone(seed):
    cfg = ServingConfig()
    env = EdgeServingEnv(cfg, episode_ms=3000.0, seed=seed)
    rng = np.random.default_rng(seed)
    done, last_now = False, 0.0
    while not done:
        assert env.now >= last_now
        last_now = env.now
        _, _, done, _ = env.step(int(rng.integers(cfg.n_actions)))
    for rnd in env.history:
        assert rnd.finish_ms >= rnd.start_ms >= rnd.decision_ms
        for lat in rnd.latencies_ms:
            assert lat > 0


def test_env_violation_accounting():
    cfg = ServingConfig()
    env = EdgeServingEnv(cfg, episode_ms=5000.0, seed=3)
    done = False
    while not done:
        _, _, done, _ = env.step(cfg.pair_to_action(128, 8))  # absurd batch
    s = env.summarize()
    assert s["slo_violation_rate"] > 0.3  # extreme config must violate


def test_transitions_are_per_model_consistent():
    cfg = ServingConfig()
    env = EdgeServingEnv(cfg, episode_ms=4000.0, seed=0)
    done = False
    count = 0
    while not done:
        _, _, done, info = env.step(5)
        for (s, a, r, s2, d) in info["transitions"]:
            assert s.shape == s2.shape == (env.state_dim,)
            assert 0 <= a < env.n_actions
            assert np.isfinite(r)
            count += 1
    assert count > 10
